"""Layer-2 models: the training workloads whose gradients the workers
compute. Written in plain JAX (fwd differentiable; the Pallas kernels
live in the non-differentiated aggregation/update graphs), flattened to
a single parameter vector so the rust coordinator treats every model as
an opaque `f32[d]`.

Models:

* ``mlp``  — 784→128→10 MLP (the CPU-scaled Fig. 3 classifier).
* ``cnn``  — the paper's §V-A convnet: conv5×5 → pool → conv5×5 → pool →
  fc → fc-10, ReLU; width-reduced by default (DESIGN.md §Substitutions),
  paper-width (20/50/500 ⇒ d = 431,080) via ``cnn_paper``.
* ``transformer`` — a 2-layer causal LM for the e2e driver (synthetic
  bigram corpus; see rust `data::TokenStream`).

Every model exposes:
  init(seed) → flat f32[d]
  grad_fn(flat, features, labels) → (flat_grad[d], mean_loss[])
  eval_fn(flat, features, labels) → (correct_flags[E] f32, mean_loss[])
"""

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

# ---------------------------------------------------------------------------
# Common pieces
# ---------------------------------------------------------------------------

IMAGE_SIDE = 28
IMAGE_DIM = IMAGE_SIDE * IMAGE_SIDE
NUM_CLASSES = 10


def _cross_entropy(logits, labels):
    """Mean cross-entropy (log-softmax + NLL, the paper's §V-A loss)."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def _glorot(key, shape):
    fan_in, fan_out = shape[-2] if len(shape) >= 2 else shape[0], shape[-1]
    if len(shape) == 4:  # HWIO conv kernel
        rf = shape[0] * shape[1]
        fan_in, fan_out = rf * shape[2], rf * shape[3]
    limit = jnp.sqrt(6.0 / (fan_in + fan_out))
    return jax.random.uniform(key, shape, jnp.float32, -limit, limit)


@dataclass(frozen=True)
class ModelDef:
    """A model family: pytree init + apply, with flat-vector adapters."""

    name: str
    init_params: callable  # seed → pytree
    apply: callable  # (pytree, features) → logits
    feature_shape: tuple  # per-example feature shape fed to apply
    num_classes: int
    is_lm: bool = False

    def flat_init(self, seed: int = 0):
        params = self.init_params(seed)
        flat, unravel = ravel_pytree(params)
        return flat.astype(jnp.float32), unravel

    def dim(self) -> int:
        return int(self.flat_init()[0].shape[0])

    def make_grad_fn(self):
        """(flat[d], features[b,...], labels[b,...]) → (grad[d], loss[])."""
        _, unravel = self.flat_init()

        def loss_fn(flat, features, labels):
            params = unravel(flat)
            logits = self.apply(params, features)
            return _cross_entropy(logits, labels)

        def grad_fn(flat, features, labels):
            loss, grad = jax.value_and_grad(loss_fn)(flat, features, labels)
            return grad, loss

        return grad_fn

    def make_eval_fn(self):
        """(flat[d], features[E,...], labels[E]) → (correct[E] f32, loss[])."""
        _, unravel = self.flat_init()

        def eval_fn(flat, features, labels):
            params = unravel(flat)
            logits = self.apply(params, features)
            pred = jnp.argmax(logits, axis=-1)
            if self.is_lm:
                # Per-sequence correctness = mean over positions.
                correct = jnp.mean((pred == labels).astype(jnp.float32), axis=-1)
            else:
                correct = (pred == labels).astype(jnp.float32)
            return correct, _cross_entropy(logits, labels)

        return eval_fn


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def _mlp_init(seed, hidden=128):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    return {
        "w1": _glorot(k1, (IMAGE_DIM, hidden)),
        "b1": jnp.zeros((hidden,), jnp.float32),
        "w2": _glorot(k2, (hidden, NUM_CLASSES)),
        "b2": jnp.zeros((NUM_CLASSES,), jnp.float32),
    }


def _mlp_apply(params, x):
    h = jax.nn.relu(x @ params["w1"] + params["b1"])
    return h @ params["w2"] + params["b2"]


MLP = ModelDef(
    name="mlp",
    init_params=_mlp_init,
    apply=_mlp_apply,
    feature_shape=(IMAGE_DIM,),
    num_classes=NUM_CLASSES,
)

# ---------------------------------------------------------------------------
# CNN (paper §V-A architecture, width-parameterised)
# ---------------------------------------------------------------------------


def _cnn_init(seed, c1, c2, fc):
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(seed), 4)
    # After conv5 (valid) + pool2 twice: 28→24→12→8→4.
    flat_dim = 4 * 4 * c2
    return {
        "k1": _glorot(k1, (5, 5, 1, c1)),
        "b1": jnp.zeros((c1,), jnp.float32),
        "k2": _glorot(k2, (5, 5, c1, c2)),
        "b2": jnp.zeros((c2,), jnp.float32),
        "w3": _glorot(k3, (flat_dim, fc)),
        "b3": jnp.zeros((fc,), jnp.float32),
        "w4": _glorot(k4, (fc, NUM_CLASSES)),
        "b4": jnp.zeros((NUM_CLASSES,), jnp.float32),
    }


def _maxpool2(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def _cnn_apply(params, x):
    b = x.shape[0]
    h = x.reshape(b, IMAGE_SIDE, IMAGE_SIDE, 1)
    h = jax.lax.conv_general_dilated(
        h, params["k1"], (1, 1), "VALID", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )
    h = jax.nn.relu(h + params["b1"])
    h = _maxpool2(h)
    h = jax.lax.conv_general_dilated(
        h, params["k2"], (1, 1), "VALID", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )
    h = jax.nn.relu(h + params["b2"])
    h = _maxpool2(h)
    h = h.reshape(b, -1)
    h = jax.nn.relu(h @ params["w3"] + params["b3"])
    return h @ params["w4"] + params["b4"]


CNN = ModelDef(
    name="cnn",
    init_params=functools.partial(_cnn_init, c1=8, c2=16, fc=128),
    apply=_cnn_apply,
    feature_shape=(IMAGE_DIM,),
    num_classes=NUM_CLASSES,
)

#: Paper-width CNN: 20/50/500 channels/units ⇒ d = 431,080 (§V-A).
CNN_PAPER = ModelDef(
    name="cnn_paper",
    init_params=functools.partial(_cnn_init, c1=20, c2=50, fc=500),
    apply=_cnn_apply,
    feature_shape=(IMAGE_DIM,),
    num_classes=NUM_CLASSES,
)

# ---------------------------------------------------------------------------
# Transformer LM (e2e driver)
# ---------------------------------------------------------------------------

VOCAB = 64
SEQ_LEN = 32
D_MODEL = 64
N_HEADS = 2
N_LAYERS = 2
D_FF = 128


def _tf_init(seed):
    keys = jax.random.split(jax.random.PRNGKey(seed), 4 + 6 * N_LAYERS)
    params = {
        "tok_emb": 0.02 * jax.random.normal(keys[0], (VOCAB, D_MODEL)),
        "pos_emb": 0.02 * jax.random.normal(keys[1], (SEQ_LEN, D_MODEL)),
        "ln_f_g": jnp.ones((D_MODEL,), jnp.float32),
        "ln_f_b": jnp.zeros((D_MODEL,), jnp.float32),
        "head": _glorot(keys[2], (D_MODEL, VOCAB)),
    }
    for layer in range(N_LAYERS):
        k = keys[4 + 6 * layer : 4 + 6 * (layer + 1)]
        params[f"l{layer}"] = {
            "wqkv": _glorot(k[0], (D_MODEL, 3 * D_MODEL)),
            "wo": _glorot(k[1], (D_MODEL, D_MODEL)),
            "w1": _glorot(k[2], (D_MODEL, D_FF)),
            "w2": _glorot(k[3], (D_FF, D_MODEL)),
            "ln1_g": jnp.ones((D_MODEL,), jnp.float32),
            "ln1_b": jnp.zeros((D_MODEL,), jnp.float32),
            "ln2_g": jnp.ones((D_MODEL,), jnp.float32),
            "ln2_b": jnp.zeros((D_MODEL,), jnp.float32),
        }
    return params


def _layernorm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return g * (x - mu) / jnp.sqrt(var + eps) + b


def _attention(x, wqkv, wo):
    b, t, dm = x.shape
    hd = dm // N_HEADS
    qkv = x @ wqkv  # (b, t, 3*dm)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(b, t, N_HEADS, hd).transpose(0, 2, 1, 3)
    k = k.reshape(b, t, N_HEADS, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, t, N_HEADS, hd).transpose(0, 2, 1, 3)
    att = (q @ k.transpose(0, 1, 3, 2)) / jnp.sqrt(float(hd))
    causal = jnp.tril(jnp.ones((t, t), bool))
    att = jnp.where(causal[None, None], att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    out = (att @ v).transpose(0, 2, 1, 3).reshape(b, t, dm)
    return out @ wo


def _tf_apply(params, tokens):
    b, t = tokens.shape
    h = params["tok_emb"][tokens] + params["pos_emb"][None, :t]
    for layer in range(N_LAYERS):
        p = params[f"l{layer}"]
        a = _attention(_layernorm(h, p["ln1_g"], p["ln1_b"]), p["wqkv"], p["wo"])
        h = h + a
        m = _layernorm(h, p["ln2_g"], p["ln2_b"])
        h = h + jax.nn.relu(m @ p["w1"]) @ p["w2"]
    h = _layernorm(h, params["ln_f_g"], params["ln_f_b"])
    return h @ params["head"]  # (b, t, vocab)


TRANSFORMER = ModelDef(
    name="transformer",
    init_params=_tf_init,
    apply=_tf_apply,
    feature_shape=(SEQ_LEN,),
    num_classes=VOCAB,
    is_lm=True,
)

#: Registry used by aot.py and the tests.
MODELS = {
    "mlp": MLP,
    "cnn": CNN,
    "cnn_paper": CNN_PAPER,
    "transformer": TRANSFORMER,
}
