"""AOT pipeline: lower every Layer-2 graph to HLO **text** artifacts +
manifest.json + initial-parameter binaries.

HLO text (not serialized HloModuleProto) is the interchange format: jax
≥ 0.5 emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Usage:  python -m compile.aot --out-dir ../artifacts [--quick] [--full-cnn]

``--quick`` builds the minimal artifact set for smoke tests; the default
builds everything the benches need. Incrementality is handled by the
Makefile (mtime comparison), not here.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import gar as gar_graphs
from . import model as models
from .kernels.sgd import sgd_momentum_update


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True so the
    rust side always unpacks a tuple)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


class Builder:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.artifacts = {}
        self.models = {}
        os.makedirs(out_dir, exist_ok=True)

    def add_artifact(self, name: str, fn, example_args, outputs: int):
        """Lower ``fn`` at ``example_args`` (ShapeDtypeStructs) and record
        the manifest entry."""
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        self.artifacts[name] = {
            "file": fname,
            "inputs": [
                {
                    "dtype": {"float32": "f32", "int32": "i32"}[str(a.dtype)],
                    "shape": list(a.shape),
                }
                for a in example_args
            ],
            "outputs": outputs,
        }
        print(f"  {name}: {len(text)} chars")

    def write_init(self, fname: str, flat) -> None:
        np.asarray(flat, dtype="<f4").tofile(os.path.join(self.out_dir, fname))

    def finish(self):
        manifest = {"artifacts": self.artifacts, "models": self.models}
        with open(os.path.join(self.out_dir, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1, sort_keys=True)
        print(
            f"manifest: {len(self.artifacts)} artifacts, "
            f"{len(self.models)} models → {self.out_dir}/manifest.json"
        )


def sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def build_model(b: Builder, name: str, batch_sizes, eval_batch: int):
    """Gradient artifacts (one per batch size) + eval artifact + init."""
    mdef = models.MODELS[name]
    flat, _ = mdef.flat_init(seed=0)
    d = int(flat.shape[0])
    print(f"model {name}: d = {d}")
    init_file = f"{name}_init.f32bin"
    b.write_init(init_file, flat)

    grad_fn = mdef.make_grad_fn()
    feat = mdef.feature_shape
    label_dtype = jnp.int32
    grad_map = {}
    for bs in batch_sizes:
        art = f"{name}_grad_b{bs}"
        if mdef.is_lm:
            args = (sds((d,)), sds((bs,) + feat, label_dtype), sds((bs,) + feat, label_dtype))
        else:
            args = (sds((d,)), sds((bs,) + feat), sds((bs,), label_dtype))
        b.add_artifact(art, grad_fn, args, outputs=2)
        grad_map[str(bs)] = art

    eval_art = None
    if eval_batch and not mdef.is_lm:
        eval_art = f"{name}_eval_b{eval_batch}"
        eval_fn = mdef.make_eval_fn()
        args = (sds((d,)), sds((eval_batch,) + feat), sds((eval_batch,), label_dtype))
        b.add_artifact(eval_art, eval_fn, args, outputs=2)

    b.models[name] = {
        "dim": d,
        "init_file": init_file,
        "grad": grad_map,
        "eval": eval_art,
        "eval_batch": eval_batch if eval_art else 0,
        "feature_dim": int(np.prod(feat)),
        "num_classes": mdef.num_classes,
    }


def build_gars(b: Builder, n: int, f: int, d: int):
    """GAR artifacts at a fixed (n, f, d) — the rust↔python cross-check
    set and the `gar-demo` path."""
    for rule in ["average", "median", "krum", "multi-krum", "bulyan", "multi-bulyan"]:
        fn = gar_graphs.RULES[rule]
        name = f"gar_{rule.replace('-', '_')}_n{n}_f{f}_d{d}"
        b.add_artifact(
            name, lambda g, _fn=fn: (_fn(g, f),), (sds((n, d)),), outputs=1
        )


def build_sgd(b: Builder, d: int):
    """Fused SGD+momentum update artifact at dimension d."""
    b.add_artifact(
        f"sgd_d{d}",
        sgd_momentum_update,
        (sds((d,)), sds((d,)), sds((d,)), sds((1,)), sds((1,))),
        outputs=2,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--quick", action="store_true", help="minimal artifact set")
    ap.add_argument(
        "--full-cnn", action="store_true", help="paper-width CNN (d=431,080)"
    )
    args = ap.parse_args()
    b = Builder(args.out_dir)

    if args.quick:
        build_model(b, "mlp", [5, 25], eval_batch=200)
        build_gars(b, n=11, f=2, d=1024)
        build_sgd(b, d=1024)
    else:
        build_model(b, "mlp", [5, 10, 15, 20, 25, 30, 35, 40, 45, 50], eval_batch=200)
        build_model(b, "cnn", [5, 25, 50], eval_batch=200)
        build_model(b, "transformer", [8], eval_batch=0)
        if args.full_cnn:
            build_model(b, "cnn_paper", [25], eval_batch=200)
        build_gars(b, n=11, f=2, d=1024)
        build_gars(b, n=7, f=1, d=1024)
        build_sgd(b, d=1024)

    b.finish()


if __name__ == "__main__":
    main()
