"""Layer-1 Pallas kernels for the MULTI-BULYAN aggregation hot spots.

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, and interpret mode lowers the kernel body to
plain HLO ops that any backend runs (see /opt/xla-example/README.md).
The BlockSpec structure — how HBM tiles stream through VMEM — is the TPU
design being expressed; DESIGN.md §Hardware-Adaptation maps it back to
the paper's CUDA formulation.
"""

from .pairwise import pairwise_sq_distances
from .coordwise import bulyan_coordwise
from .sgd import sgd_momentum_update

__all__ = [
    "pairwise_sq_distances",
    "bulyan_coordwise",
    "sgd_momentum_update",
]
