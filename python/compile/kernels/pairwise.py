"""Pairwise squared ℓ2 distances — the O(n²d) MULTI-KRUM hot spot as a
Pallas kernel.

TPU formulation (DESIGN.md §Hardware-Adaptation): the distance matrix is
computed via the Gram trick ‖Gᵢ−Gⱼ‖² = ‖Gᵢ‖² + ‖Gⱼ‖² − 2⟨Gᵢ,Gⱼ⟩, so the
dominant work is the (n×BLOCK_D)·(BLOCK_D×n) stripe matmul which maps
straight onto the MXU systolic array. The grid iterates over d in
BLOCK_D-wide stripes; each grid step streams one stripe of all n rows
through VMEM (n·BLOCK_D·4 B ≈ 1 MiB at n=64, BLOCK_D=4096) and
accumulates into the (n, n) output block, which stays resident across
the whole grid (Pallas keeps same-index output blocks in VMEM between
steps). This is the HBM↔VMEM schedule the paper's CUDA kernel expressed
with threadblocks + shared memory.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Stripe width in elements. See the VMEM budget above.
DEFAULT_BLOCK_D = 4096


def _pairwise_kernel(g_ref, out_ref):
    """One grid step: accumulate the stripe's partial distances."""
    step = pl.program_id(0)
    g = g_ref[...].astype(jnp.float32)  # (n, block_d) stripe
    sq = jnp.sum(g * g, axis=1)  # ‖Gᵢ‖² over the stripe
    gram = jnp.dot(g, g.T)  # MXU: (n, n) stripe Gram
    partial = sq[:, None] + sq[None, :] - 2.0 * gram

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += partial


def pairwise_sq_distances(grads: jax.Array, block_d: int = DEFAULT_BLOCK_D) -> jax.Array:
    """All-pairs squared distances of the rows of ``grads`` (n, d).

    Returns an (n, n) symmetric matrix with zero diagonal. ``d`` is padded
    to a multiple of ``block_d`` with zeros — padding both operands with
    equal values adds (0−0)² = 0 to every distance, so the result is
    exact.
    """
    n, d = grads.shape
    pad = (-d) % block_d
    if pad:
        grads = jnp.pad(grads, ((0, 0), (0, pad)))
    d_padded = d + pad
    steps = d_padded // block_d

    out = pl.pallas_call(
        _pairwise_kernel,
        grid=(steps,),
        in_specs=[pl.BlockSpec((n, block_d), lambda i: (0, i))],
        out_specs=pl.BlockSpec((n, n), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(grads)
    # Numerical hygiene: the Gram trick can produce tiny negatives for
    # near-identical rows; distances are non-negative by definition.
    out = jnp.maximum(out, 0.0)
    # Exact-zero diagonal (the Gram trick leaves ~1e-6 residue there).
    return out * (1.0 - jnp.eye(n, dtype=out.dtype))


@functools.partial(jax.jit, static_argnums=(1,))
def pairwise_sq_distances_jit(grads: jax.Array, block_d: int = DEFAULT_BLOCK_D) -> jax.Array:
    """Jitted wrapper (used by the pytest benchmarks)."""
    return pairwise_sq_distances(grads, block_d)
