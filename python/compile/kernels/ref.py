"""Pure-jnp oracles for every Pallas kernel — the correctness reference
the pytest suite asserts against (`python/tests/test_kernels.py`), and
the baseline for the roofline comparison in EXPERIMENTS.md §Perf."""

import jax.numpy as jnp


def pairwise_sq_distances_ref(grads):
    """Naive all-pairs squared distances: (n, d) → (n, n)."""
    diff = grads[:, None, :] - grads[None, :, :]
    return jnp.sum(diff * diff, axis=-1)


def bulyan_coordwise_ref(ext, agr, beta):
    """Per coordinate: average of the beta values of ``agr`` closest to
    the median of ``ext``. (θ, d) × (θ, d) → (d,)."""
    med = jnp.median(ext, axis=0)
    dev = jnp.abs(agr - med[None, :])
    order = jnp.argsort(dev, axis=0)
    closest = jnp.take_along_axis(agr, order[:beta, :], axis=0)
    return jnp.mean(closest, axis=0)


def sgd_momentum_update_ref(params, velocity, grad, lr, momentum):
    """PyTorch-convention SGD+momentum (matches rust `training::Sgd`)."""
    v_new = momentum * velocity + grad
    p_new = params - lr * v_new
    return p_new, v_new


def krum_scores_ref(dists, f):
    """Krum scores from a (n, n) distance matrix: sum of the n−f−2
    smallest distances to *other* gradients (paper Equation 4)."""
    n = dists.shape[0]
    neighbors = n - f - 2
    # Exclude self-distance by masking the diagonal to +inf.
    masked = dists + jnp.where(jnp.eye(n, dtype=bool), jnp.inf, 0.0)
    sorted_d = jnp.sort(masked, axis=1)
    return jnp.sum(sorted_d[:, :neighbors], axis=1)
