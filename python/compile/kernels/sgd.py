"""Fused SGD-with-momentum parameter update as a Pallas kernel — the
parameter server's per-round update (Equation 2 of the paper), PyTorch
convention to match the rust-native `training::Sgd`:

    v ← µ·v + g
    x ← x − γ·v

One fused pass over the parameter vector (instead of three element-wise
HLO ops) — on TPU this is a single HBM read-modify-write stream through
VMEM, gridded in BLOCK_D chunks.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_D = 8192


def _sgd_kernel(p_ref, v_ref, g_ref, lr_ref, mu_ref, p_out_ref, v_out_ref):
    lr = lr_ref[0]
    mu = mu_ref[0]
    v_new = mu * v_ref[...] + g_ref[...]
    p_out_ref[...] = p_ref[...] - lr * v_new
    v_out_ref[...] = v_new


def sgd_momentum_update(
    params: jax.Array,
    velocity: jax.Array,
    grad: jax.Array,
    lr: jax.Array,
    momentum: jax.Array,
    block_d: int = DEFAULT_BLOCK_D,
):
    """Returns ``(new_params, new_velocity)``. ``lr``/``momentum`` are
    shape-(1,) f32 arrays so the artifact takes them at runtime (LR
    schedules without recompilation)."""
    (d,) = params.shape
    assert velocity.shape == (d,) and grad.shape == (d,)
    pad = (-d) % block_d
    if pad:
        params = jnp.pad(params, (0, pad))
        velocity = jnp.pad(velocity, (0, pad))
        grad = jnp.pad(grad, (0, pad))
    d_padded = d + pad
    steps = d_padded // block_d

    vec = pl.BlockSpec((block_d,), lambda i: (i,))
    scalar = pl.BlockSpec((1,), lambda i: (0,))
    p_new, v_new = pl.pallas_call(
        _sgd_kernel,
        grid=(steps,),
        in_specs=[vec, vec, vec, scalar, scalar],
        out_specs=[vec, vec],
        out_shape=[
            jax.ShapeDtypeStruct((d_padded,), jnp.float32),
            jax.ShapeDtypeStruct((d_padded,), jnp.float32),
        ],
        interpret=True,
    )(params, velocity, grad, lr, momentum)
    return p_new[:d], v_new[:d]


@functools.partial(jax.jit, static_argnums=(5,))
def sgd_momentum_update_jit(params, velocity, grad, lr, momentum, block_d=DEFAULT_BLOCK_D):
    return sgd_momentum_update(params, velocity, grad, lr, momentum, block_d)
