"""BULYAN's coordinate-wise median + closest-β average as a Pallas kernel
(lines 21–24 of the paper's Algorithm 1 — the "single loop through the
coordinates" behind the O(d) complexity claim).

Grid: d is tiled into BLOCK_D-wide column stripes. Each grid step loads
the (θ, BLOCK_D) stripes of G^ext (the per-iteration MULTI-KRUM winners)
and G^agr (the per-iteration MULTI-KRUM averages), computes the
per-column median of ext, ranks |agr − median| per column, and averages
the β closest agr values. θ ≤ 64, so the per-column sort vectorises on
the VPU's 8×128 lanes — no shared-memory bitonic network needed
(DESIGN.md §Hardware-Adaptation).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_D = 2048


def _make_kernel(beta: int):
    def kernel(ext_ref, agr_ref, out_ref):
        ext = ext_ref[...].astype(jnp.float32)  # (theta, block_d)
        agr = agr_ref[...].astype(jnp.float32)  # (theta, block_d)
        med = jnp.median(ext, axis=0)  # (block_d,)
        dev = jnp.abs(agr - med[None, :])
        # Rank each column by deviation; keep the β smallest.
        order = jnp.argsort(dev, axis=0)  # (theta, block_d)
        closest = jnp.take_along_axis(agr, order[:beta, :], axis=0)
        out_ref[...] = jnp.mean(closest, axis=0)

    return kernel


def bulyan_coordwise(
    ext: jax.Array,
    agr: jax.Array,
    beta: int,
    block_d: int = DEFAULT_BLOCK_D,
) -> jax.Array:
    """Per coordinate: average of the ``beta`` values of ``agr`` closest
    to the median of ``ext`` (classic BULYAN passes ``agr = ext``).

    ``ext``/``agr``: (θ, d). Returns (d,).
    """
    theta, d = ext.shape
    assert agr.shape == (theta, d), (ext.shape, agr.shape)
    assert 1 <= beta <= theta, (beta, theta)
    pad = (-d) % block_d
    if pad:
        # Zero-padding is safe: padded columns produce garbage that the
        # final slice drops.
        ext = jnp.pad(ext, ((0, 0), (0, pad)))
        agr = jnp.pad(agr, ((0, 0), (0, pad)))
    d_padded = d + pad
    steps = d_padded // block_d

    out = pl.pallas_call(
        _make_kernel(beta),
        grid=(steps,),
        in_specs=[
            pl.BlockSpec((theta, block_d), lambda i: (0, i)),
            pl.BlockSpec((theta, block_d), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((block_d,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((d_padded,), jnp.float32),
        interpret=True,
    )(ext, agr)
    return out[:d]


@functools.partial(jax.jit, static_argnums=(2, 3))
def bulyan_coordwise_jit(ext, agr, beta: int, block_d: int = DEFAULT_BLOCK_D):
    return bulyan_coordwise(ext, agr, beta, block_d)
