"""Layer-2 GAR computation graphs — the paper's Algorithm 1 in JAX,
built on the Layer-1 Pallas kernels (`kernels/pairwise.py`,
`kernels/coordwise.py`).

These graphs are AOT-lowered per (n, f, d) to HLO artifacts
(``gar_<rule>_n{n}_f{f}_d{d}``) that the rust runtime cross-checks
against its native implementations — three independent implementations
(jnp oracle ↔ Pallas/JAX graph ↔ native rust) of the same algorithm.

Static-shape notes: BULYAN's θ iterations remove one gradient from the
pool each time, so the per-iteration MULTI-KRUM runs with a *traced*
pool size k. Dynamic counts (neighbors = k−f−2, selection size m = k−f−2)
are expressed with `arange < k` masks over sorted/argsorted arrays, which
keeps every shape static while matching the dynamic-pool semantics of
the rust implementation exactly.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels.coordwise import bulyan_coordwise
from .kernels.pairwise import pairwise_sq_distances

_INF = jnp.float32(jnp.inf)


def average(grads):
    """The non-resilient baseline: coordinate-wise mean. (n, d) → (d,)."""
    return jnp.mean(grads, axis=0)


def coord_median(grads):
    """Coordinate-wise median (the paper's MEDIAN comparator)."""
    return jnp.median(grads, axis=0)


def _krum_scores_static(dists, f):
    """Krum scores with the full pool (static neighbor count n−f−2)."""
    n = dists.shape[0]
    neighbors = n - f - 2
    masked = dists + jnp.where(jnp.eye(n, dtype=bool), _INF, 0.0)
    sorted_d = jnp.sort(masked, axis=1)
    return jnp.sum(sorted_d[:, :neighbors], axis=1)


def multi_krum(grads, f, m=None):
    """MULTI-KRUM: average of the m = n−f−2 smallest-scoring gradients.

    Uses the Pallas pairwise-distance kernel for the O(n²d) hot spot.
    """
    n = grads.shape[0]
    if m is None:
        m = n - f - 2
    assert 1 <= m <= n - f - 2, (n, f, m)
    dists = pairwise_sq_distances(grads)
    scores = _krum_scores_static(dists, f)
    selected = jnp.argsort(scores)[:m]  # static m → static shapes
    return jnp.mean(grads[selected], axis=0)


def krum(grads, f):
    """KRUM: the single smallest-scoring gradient."""
    return multi_krum(grads, f, m=1)


def _masked_krum_scores(dists, alive, k, f):
    """Krum scores over the alive sub-pool of (traced) size k.

    Dead rows/columns are masked to +inf; the neighbor count k−f−2 is a
    traced scalar handled with an `arange < count` mask over the sorted
    distances.
    """
    n = dists.shape[0]
    neighbors = k - f - 2  # traced i32
    pair_alive = alive[:, None] * alive[None, :]
    masked = jnp.where(pair_alive > 0, dists, _INF)
    masked = masked + jnp.where(jnp.eye(n, dtype=bool), _INF, 0.0)
    sorted_d = jnp.sort(masked, axis=1)
    take = (jnp.arange(n)[None, :] < neighbors).astype(dists.dtype)
    # +inf entries can only be hit if take already zero there (alive pool
    # has ≥ neighbors finite distances by construction) — but 0·inf = nan,
    # so zero them out before weighting.
    finite = jnp.where(jnp.isfinite(sorted_d), sorted_d, 0.0)
    scores = jnp.sum(finite * take, axis=1)
    return jnp.where(alive > 0, scores, _INF)


def multi_bulyan(grads, f, multi=True):
    """MULTI-BULYAN (Algorithm 1). ``multi=False`` gives classic BULYAN
    over KRUM (G^agr = G^ext)."""
    n, d = grads.shape
    assert n >= 4 * f + 3, (n, f)
    theta = n - 2 * f - 2
    beta = theta - 2 * f
    dists = pairwise_sq_distances(grads)  # computed ONCE (paper §V-B)

    def body(t, state):
        alive, ext, agr = state
        k = n - t  # traced pool size
        scores = _masked_krum_scores(dists, alive, k, f)
        winner = jnp.argmin(scores)
        m_round = k - f - 2
        # Selection mask: the m_round smallest-scoring alive gradients.
        order = jnp.argsort(scores)
        sel = jnp.zeros((n,), jnp.float32).at[order].set(
            (jnp.arange(n) < m_round).astype(jnp.float32)
        )
        agr_row = (sel @ grads) / m_round.astype(jnp.float32)
        ext_row = grads[winner]
        ext = jax.lax.dynamic_update_slice(ext, ext_row[None, :], (t, 0))
        agr = jax.lax.dynamic_update_slice(agr, agr_row[None, :], (t, 0))
        alive = alive.at[winner].set(0.0)
        return alive, ext, agr

    alive0 = jnp.ones((n,), jnp.float32)
    ext0 = jnp.zeros((theta, d), jnp.float32)
    agr0 = jnp.zeros((theta, d), jnp.float32)
    alive, ext, agr = jax.lax.fori_loop(0, theta, body, (alive0, ext0, agr0))
    src = agr if multi else ext
    return bulyan_coordwise(ext, src, beta)


def bulyan(grads, f):
    """Classic BULYAN over KRUM winners."""
    return multi_bulyan(grads, f, multi=False)


#: name → (fn(grads, f), needs_f) registry used by aot.py and the tests.
RULES = {
    "average": lambda g, f: average(g),
    "median": lambda g, f: coord_median(g),
    "krum": krum,
    "multi-krum": multi_krum,
    "bulyan": bulyan,
    "multi-bulyan": multi_bulyan,
}


@functools.partial(jax.jit, static_argnums=(1, 2))
def aggregate_jit(grads, rule: str, f: int):
    """Jitted dispatch (test convenience)."""
    return RULES[rule](grads, f)
