"""Build-time Python for the multibulyan repro: Layer-1 Pallas kernels
(`kernels/`), Layer-2 JAX models and GAR graphs (`model.py`, `gar.py`),
and the AOT pipeline (`aot.py`) that lowers everything to the HLO-text
artifacts the rust runtime executes. Never imported at serving time."""
