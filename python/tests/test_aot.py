"""AOT pipeline: the --quick artifact set builds, the manifest is
self-consistent, and HLO text round-trips through the XLA parser."""

import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


@pytest.fixture(scope="module")
def quick_artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out), "--quick"],
        cwd=os.path.join(REPO, "python"),
        check=True,
        capture_output=True,
    )
    return out


def test_manifest_schema(quick_artifacts):
    manifest = json.loads((quick_artifacts / "manifest.json").read_text())
    assert "artifacts" in manifest and "models" in manifest
    for name, art in manifest["artifacts"].items():
        assert (quick_artifacts / art["file"]).exists(), name
        assert art["outputs"] >= 1
        for t in art["inputs"]:
            assert t["dtype"] in ("f32", "i32")
            assert all(isinstance(s, int) and s >= 0 for s in t["shape"])
    mlp = manifest["models"]["mlp"]
    init = quick_artifacts / mlp["init_file"]
    assert init.exists()
    assert init.stat().st_size == 4 * mlp["dim"]
    for b, art in mlp["grad"].items():
        assert art in manifest["artifacts"], (b, art)


def test_hlo_text_is_parseable_hlo(quick_artifacts):
    manifest = json.loads((quick_artifacts / "manifest.json").read_text())
    name, art = next(iter(manifest["artifacts"].items()))
    text = (quick_artifacts / art["file"]).read_text()
    assert text.startswith("HloModule"), name
    assert "ENTRY" in text


def test_grad_artifact_signature_matches_model(quick_artifacts):
    manifest = json.loads((quick_artifacts / "manifest.json").read_text())
    mlp = manifest["models"]["mlp"]
    d = mlp["dim"]
    art = manifest["artifacts"][mlp["grad"]["5"]]
    shapes = [t["shape"] for t in art["inputs"]]
    assert shapes == [[d], [5, mlp["feature_dim"]], [5]]
    dtypes = [t["dtype"] for t in art["inputs"]]
    assert dtypes == ["f32", "f32", "i32"]
