"""L1 correctness: every Pallas kernel vs its pure-jnp oracle, swept over
shapes/dtypes with hypothesis — the CORE correctness signal of the
build-time stack."""

import jax
import jax.numpy as jnp
import numpy as np
import numpy.testing as npt
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.coordwise import bulyan_coordwise
from compile.kernels.pairwise import pairwise_sq_distances
from compile.kernels.sgd import sgd_momentum_update
from compile.kernels import ref

SETTINGS = dict(max_examples=12, deadline=None)


def rand(rs, *shape, scale=1.0):
    return jnp.asarray(rs.randn(*shape) * scale, jnp.float32)


# ---------------------------------------------------------------------------
# pairwise
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    n=st.integers(3, 16),
    d=st.integers(1, 600),
    block=st.sampled_from([64, 256, 4096]),
    seed=st.integers(0, 2**16),
)
def test_pairwise_matches_ref(n, d, block, seed):
    g = rand(np.random.RandomState(seed), n, d)
    got = np.array(pairwise_sq_distances(g, block_d=block))
    want = np.array(ref.pairwise_sq_distances_ref(g))
    npt.assert_allclose(got, want, rtol=5e-4, atol=5e-3)


def test_pairwise_symmetric_zero_diagonal():
    g = rand(np.random.RandomState(0), 9, 1000)
    d = np.array(pairwise_sq_distances(g))
    npt.assert_allclose(d, d.T, rtol=0, atol=0)
    npt.assert_allclose(np.diag(d), 0.0)
    assert (d >= 0).all()


def test_pairwise_identical_rows_are_zero_distance():
    row = rand(np.random.RandomState(1), 1, 300)
    g = jnp.tile(row, (5, 1))
    d = np.array(pairwise_sq_distances(g))
    npt.assert_allclose(d, 0.0, atol=1e-3)


def test_pairwise_scale_invariance_structure():
    # d(a·G) = a²·d(G): the kernel must preserve this exactly up to fp.
    g = rand(np.random.RandomState(2), 6, 500)
    d1 = np.array(pairwise_sq_distances(g))
    d2 = np.array(pairwise_sq_distances(2.0 * g))
    npt.assert_allclose(d2, 4.0 * d1, rtol=1e-4, atol=1e-3)


# ---------------------------------------------------------------------------
# coordwise (BULYAN inner loop)
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    theta=st.integers(1, 12),
    d=st.integers(1, 500),
    block=st.sampled_from([128, 2048]),
    seed=st.integers(0, 2**16),
)
def test_coordwise_matches_ref(theta, d, block, seed):
    rs = np.random.RandomState(seed)
    beta = rs.randint(1, theta + 1)
    ext = rand(rs, theta, d)
    agr = rand(rs, theta, d)
    got = np.array(bulyan_coordwise(ext, agr, beta, block_d=block))
    want = np.array(ref.bulyan_coordwise_ref(ext, agr, beta))
    npt.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_coordwise_beta_equals_theta_is_mean():
    rs = np.random.RandomState(3)
    ext = rand(rs, 5, 200)
    agr = rand(rs, 5, 200)
    got = np.array(bulyan_coordwise(ext, agr, 5))
    npt.assert_allclose(got, np.array(agr).mean(0), rtol=1e-5, atol=1e-6)


def test_coordwise_filters_outlier_row():
    # One huge row in agr must never be selected when beta < theta and
    # ext's median sits at the clean values.
    rs = np.random.RandomState(4)
    clean = rand(rs, 4, 100, scale=0.1)
    ext = clean
    agr = jnp.concatenate([clean[:3], 1e6 + jnp.zeros((1, 100), jnp.float32)])
    out = np.array(bulyan_coordwise(ext, agr, 2))
    assert (np.abs(out) < 10.0).all()


# ---------------------------------------------------------------------------
# sgd
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    d=st.integers(1, 3000),
    lr=st.floats(1e-4, 1.0),
    mu=st.floats(0.0, 0.99),
    seed=st.integers(0, 2**16),
)
def test_sgd_matches_ref(d, lr, mu, seed):
    rs = np.random.RandomState(seed)
    p, v, g = rand(rs, d), rand(rs, d), rand(rs, d)
    lr_a = jnp.array([lr], jnp.float32)
    mu_a = jnp.array([mu], jnp.float32)
    got_p, got_v = sgd_momentum_update(p, v, g, lr_a, mu_a, block_d=1024)
    want_p, want_v = ref.sgd_momentum_update_ref(p, v, g, np.float32(lr), np.float32(mu))
    npt.assert_allclose(np.array(got_p), np.array(want_p), rtol=3e-5, atol=1e-6)
    npt.assert_allclose(np.array(got_v), np.array(want_v), rtol=3e-5, atol=1e-6)


def test_sgd_zero_momentum_is_plain_sgd():
    p = jnp.ones((100,), jnp.float32)
    v = jnp.zeros((100,), jnp.float32)
    g = jnp.full((100,), 2.0, jnp.float32)
    new_p, new_v = sgd_momentum_update(
        p, v, g, jnp.array([0.5], jnp.float32), jnp.array([0.0], jnp.float32)
    )
    npt.assert_allclose(np.array(new_p), 0.0, atol=1e-6)
    npt.assert_allclose(np.array(new_v), 2.0, atol=1e-6)


def test_sgd_matches_rust_convention():
    # Two steps by hand, mirroring rust training::optimizer tests:
    # lr=1, mu=0.5, g=1 twice from p=0 → p=-1 then p=-2.5.
    p = jnp.zeros((1,), jnp.float32)
    v = jnp.zeros((1,), jnp.float32)
    g = jnp.ones((1,), jnp.float32)
    one = jnp.array([1.0], jnp.float32)
    half = jnp.array([0.5], jnp.float32)
    p, v = sgd_momentum_update(p, v, g, one, half)
    npt.assert_allclose(np.array(p), [-1.0], atol=1e-7)
    p, v = sgd_momentum_update(p, v, g, one, half)
    npt.assert_allclose(np.array(p), [-2.5], atol=1e-7)


# ---------------------------------------------------------------------------
# kernels under jit (the form that gets AOT-lowered)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("d", [100, 4097])
def test_pairwise_under_jit(d):
    g = rand(np.random.RandomState(7), 7, d)
    jitted = jax.jit(pairwise_sq_distances)
    npt.assert_allclose(
        np.array(jitted(g)),
        np.array(ref.pairwise_sq_distances_ref(g)),
        rtol=5e-4,
        atol=5e-3,
    )
