"""L2 models: shapes, gradient sanity, learnability of each model on a
tiny synthetic task (a few SGD steps must reduce the loss)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as models


@pytest.mark.parametrize("name", ["mlp", "cnn", "transformer"])
def test_flat_init_dim_consistency(name):
    mdef = models.MODELS[name]
    flat, unravel = mdef.flat_init(seed=0)
    assert flat.ndim == 1
    assert mdef.dim() == flat.shape[0]
    # Round trip through unravel/ravel preserves the vector.
    from jax.flatten_util import ravel_pytree

    back, _ = ravel_pytree(unravel(flat))
    np.testing.assert_allclose(np.array(back), np.array(flat))


@pytest.mark.parametrize("name,b", [("mlp", 4), ("cnn", 3)])
def test_classifier_grad_shapes_and_loss(name, b):
    mdef = models.MODELS[name]
    flat, _ = mdef.flat_init(0)
    grad_fn = mdef.make_grad_fn()
    rs = np.random.RandomState(0)
    x = jnp.asarray(rs.rand(b, models.IMAGE_DIM).astype(np.float32))
    y = jnp.asarray(rs.randint(0, 10, b).astype(np.int32))
    grad, loss = jax.jit(grad_fn)(flat, x, y)
    assert grad.shape == flat.shape
    assert np.isfinite(np.array(grad)).all()
    # Initial loss ≈ ln(10) for 10 balanced classes.
    assert 1.5 < float(loss) < 3.5


def test_transformer_grad_shapes_and_loss():
    mdef = models.MODELS["transformer"]
    flat, _ = mdef.flat_init(0)
    grad_fn = mdef.make_grad_fn()
    rs = np.random.RandomState(0)
    tok = jnp.asarray(rs.randint(0, models.VOCAB, (2, models.SEQ_LEN)).astype(np.int32))
    tgt = jnp.asarray(rs.randint(0, models.VOCAB, (2, models.SEQ_LEN)).astype(np.int32))
    grad, loss = jax.jit(grad_fn)(flat, tok, tgt)
    assert grad.shape == flat.shape
    assert np.isfinite(np.array(grad)).all()
    assert 3.0 < float(loss) < 6.0  # ≈ ln(64) at init


def test_transformer_is_causal():
    # Changing a future token must not change earlier logits.
    mdef = models.MODELS["transformer"]
    params = mdef.init_params(0)
    rs = np.random.RandomState(1)
    tok = rs.randint(0, models.VOCAB, (1, models.SEQ_LEN)).astype(np.int32)
    tok2 = tok.copy()
    tok2[0, -1] = (tok2[0, -1] + 1) % models.VOCAB
    l1 = np.array(mdef.apply(params, jnp.asarray(tok)))
    l2 = np.array(mdef.apply(params, jnp.asarray(tok2)))
    np.testing.assert_allclose(l1[0, :-1], l2[0, :-1], rtol=1e-5, atol=1e-5)
    assert np.abs(l1[0, -1] - l2[0, -1]).max() > 0  # last position differs


@pytest.mark.parametrize("name", ["mlp", "cnn"])
def test_classifier_learns_a_tiny_task(name):
    # 20 fixed samples with linearly-separable-ish structure: a few SGD
    # steps must reduce the loss.
    mdef = models.MODELS[name]
    flat, _ = mdef.flat_init(0)
    grad_fn = jax.jit(mdef.make_grad_fn())
    rs = np.random.RandomState(2)
    labels = np.arange(20) % 10
    x = np.zeros((20, models.IMAGE_DIM), np.float32)
    for i, l in enumerate(labels):
        x[i, l * 70 : l * 70 + 60] = 1.0
        x[i] += rs.rand(models.IMAGE_DIM).astype(np.float32) * 0.05
    x = jnp.asarray(x)
    y = jnp.asarray(labels.astype(np.int32))
    _, loss0 = grad_fn(flat, x, y)
    for _ in range(30):
        g, _ = grad_fn(flat, x, y)
        flat = flat - 0.2 * g
    _, loss1 = grad_fn(flat, x, y)
    assert float(loss1) < 0.6 * float(loss0), (float(loss0), float(loss1))


def test_eval_fn_counts_correct():
    mdef = models.MODELS["mlp"]
    flat, _ = mdef.flat_init(0)
    eval_fn = jax.jit(mdef.make_eval_fn())
    rs = np.random.RandomState(3)
    x = jnp.asarray(rs.rand(8, models.IMAGE_DIM).astype(np.float32))
    y = jnp.asarray(rs.randint(0, 10, 8).astype(np.int32))
    correct, loss = eval_fn(flat, x, y)
    assert correct.shape == (8,)
    assert set(np.unique(np.array(correct))).issubset({0.0, 1.0})
    assert np.isfinite(float(loss))


def test_cnn_paper_width_matches_paper_dim():
    # The §V-A convnet: d = 431,080 parameters.
    assert models.CNN_PAPER.dim() == 431_080
