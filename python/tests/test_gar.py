"""L2 GAR graphs: resilience semantics + agreement with a trusted numpy
re-implementation of Algorithm 1 (independent of both the JAX graph's
masking tricks and the rust code)."""

import jax.numpy as jnp
import numpy as np
import numpy.testing as npt
import pytest
from hypothesis import given, settings, strategies as st

from compile import gar

SETTINGS = dict(max_examples=10, deadline=None)


# ---------------------------------------------------------------------------
# A direct numpy transcription of Algorithm 1 (dynamic pool, no masking).
# ---------------------------------------------------------------------------


def np_krum_scores(grads, pool, f):
    k = len(pool)
    neighbors = k - f - 2
    scores = []
    for i in pool:
        dists = sorted(
            float(np.sum((grads[i] - grads[j]) ** 2)) for j in pool if j != i
        )
        scores.append(sum(dists[:neighbors]))
    return np.array(scores)


def np_multi_krum(grads, f, m=None):
    n = grads.shape[0]
    if m is None:
        m = n - f - 2
    pool = list(range(n))
    scores = np_krum_scores(grads, pool, f)
    selected = np.argsort(scores, kind="stable")[:m]
    return grads[selected].mean(axis=0)


def np_multi_bulyan(grads, f, multi=True):
    n, d = grads.shape
    theta = n - 2 * f - 2
    beta = theta - 2 * f
    pool = list(range(n))
    ext, agr = [], []
    for _ in range(theta):
        scores = np_krum_scores(grads, pool, f)
        order = np.argsort(scores, kind="stable")
        winner_pos = order[0]
        m_round = len(pool) - f - 2
        selected = [pool[p] for p in order[:m_round]]
        ext.append(grads[pool[winner_pos]].copy())
        agr.append(grads[selected].mean(axis=0))
        pool.pop(winner_pos)
    ext = np.stack(ext)
    agr = np.stack(agr) if multi else ext
    med = np.median(ext, axis=0)
    dev = np.abs(agr - med[None, :])
    order = np.argsort(dev, axis=0, kind="stable")
    closest = np.take_along_axis(agr, order[:beta], axis=0)
    return closest.mean(axis=0)


# ---------------------------------------------------------------------------
# Agreement tests
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**16), d=st.integers(2, 64))
def test_multi_krum_matches_numpy(seed, d):
    rs = np.random.RandomState(seed)
    g = rs.randn(11, d).astype(np.float32)
    got = np.array(gar.multi_krum(jnp.asarray(g), 2))
    want = np_multi_krum(g, 2)
    npt.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**16), d=st.integers(2, 48))
def test_multi_bulyan_matches_numpy(seed, d):
    rs = np.random.RandomState(seed)
    # Spread the rows so score ties (ordering ambiguity) are improbable.
    g = (rs.randn(11, d) * (1.0 + rs.rand(11, 1))).astype(np.float32)
    got = np.array(gar.multi_bulyan(jnp.asarray(g), 2))
    want = np_multi_bulyan(g, 2, multi=True)
    npt.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**16))
def test_bulyan_matches_numpy(seed):
    rs = np.random.RandomState(seed)
    g = (rs.randn(11, 24) * (1.0 + rs.rand(11, 1))).astype(np.float32)
    got = np.array(gar.bulyan(jnp.asarray(g), 2))
    want = np_multi_bulyan(g, 2, multi=False)
    npt.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n,f", [(7, 1), (11, 2), (15, 3)])
def test_krum_selects_from_cluster_not_outlier(n, f):
    rs = np.random.RandomState(0)
    g = rs.randn(n, 32).astype(np.float32) * 0.01
    g[-1] = 100.0  # outlier
    out = np.array(gar.krum(jnp.asarray(g), f))
    assert np.abs(out).max() < 1.0


# ---------------------------------------------------------------------------
# Semantics
# ---------------------------------------------------------------------------


def test_identical_gradients_fixed_point():
    row = np.random.RandomState(1).randn(40).astype(np.float32)
    g = jnp.asarray(np.tile(row, (11, 1)))
    for rule in gar.RULES:
        out = np.array(gar.RULES[rule](g, 2))
        npt.assert_allclose(out, row, rtol=1e-4, atol=1e-4, err_msg=rule)


def test_multi_bulyan_output_within_correct_range():
    rs = np.random.RandomState(2)
    g = rs.uniform(-1, 1, (11, 64)).astype(np.float32)
    g[9] = 1e6
    g[10] = -1e6
    out = np.array(gar.multi_bulyan(jnp.asarray(g), 2))
    lo = g[:9].min(axis=0) - 1e-4
    hi = g[:9].max(axis=0) + 1e-4
    assert (out >= lo).all() and (out <= hi).all()


def test_average_is_not_resilient_but_multibulyan_is():
    rs = np.random.RandomState(3)
    g = rs.randn(11, 32).astype(np.float32) * 0.1
    g[10] = 1e5
    avg = np.array(gar.average(jnp.asarray(g)))
    mb = np.array(gar.multi_bulyan(jnp.asarray(g), 2))
    assert np.abs(avg).max() > 1e3
    assert np.abs(mb).max() < 10.0


def test_multi_krum_m_one_equals_krum():
    rs = np.random.RandomState(4)
    g = jnp.asarray(rs.randn(9, 16).astype(np.float32))
    npt.assert_allclose(
        np.array(gar.multi_krum(g, 1, m=1)), np.array(gar.krum(g, 1)), rtol=0, atol=0
    )
