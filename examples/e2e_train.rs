//! End-to-end driver: the full three-layer stack on a real workload.
//!
//! Trains the AOT-compiled transformer (JAX fwd/bwd lowered to HLO,
//! executed from rust via PJRT) on the synthetic bigram corpus with n
//! distributed workers, f of them Byzantine running little-is-enough,
//! aggregated by MULTI-BULYAN — and logs the loss curve. Then repeats
//! with plain averaging to show the attack destroying the baseline.
//!
//! ```bash
//! make artifacts   # build python/compile → artifacts/*.hlo.txt
//! cargo run --release --example e2e_train
//! ```

use multibulyan::attacks::AttackKind;
use multibulyan::config::{ClusterConfig, ExperimentConfig, ModelConfig, TrainConfig};
use multibulyan::coordinator::launch;
use multibulyan::gar::GarKind;
use multibulyan::runtime::{ComputeServer, Manifest};
use multibulyan::Result;

fn main() -> Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let manifest = Manifest::load(&artifacts)?;
    let model = manifest.model("transformer")?;
    println!(
        "transformer: d = {} parameters, grad batch sizes {:?}",
        model.dim,
        model.batch_sizes()
    );
    let server = ComputeServer::start(manifest.clone())?;

    let steps = std::env::var("E2E_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(200);
    let (n, f) = (11, 2);

    let mut results = Vec::new();
    // Sign-flip at scale 5 with f=2 of n=11 colluders reverses the mean
    // update entirely: averaging ascends the loss while MULTI-BULYAN
    // filters the coalition out — the paper's robustness story end-to-end.
    for (gar, attack, label) in [
        (
            GarKind::MultiBulyan,
            AttackKind::SignFlip { scale: 5.0 },
            "multi-bulyan under sign-flip(5)",
        ),
        (
            GarKind::Average,
            AttackKind::SignFlip { scale: 5.0 },
            "averaging under sign-flip(5)",
        ),
        (GarKind::Average, AttackKind::None, "averaging, no attack"),
    ] {
        let config = ExperimentConfig {
            cluster: ClusterConfig {
                n,
                f: if gar == GarKind::Average { 0 } else { f },
                actual_byzantine: Some(if attack == AttackKind::None { 0 } else { f }),
                net_delay_us: 0,
                drop_prob: 0.0,
                round_timeout_ms: 60_000,
                ..Default::default()
            },
            gar,
            pre: Vec::new(),
            attack,
            model: ModelConfig::Artifact {
                name: "transformer".into(),
                dir: artifacts.clone(),
            },
            train: TrainConfig {
                learning_rate: 0.05,
                momentum: 0.9,
                steps,
                batch_size: 8,
                eval_every: (steps / 8).max(1),
                seed: 1,
            },
            threads: 0,
            transport: Default::default(),
            collect: Default::default(),
            overlap: Default::default(),
            overlap_window: 1,
            codec: None,
            groups: 1,
            output_dir: None,
            journal: None,
            crash_after_round: None,
        };
        println!("\n=== {label} ({steps} steps) ===");
        let cluster = launch(&config, Some((server.handle(), manifest.clone())))?;
        let mut coordinator = cluster.coordinator;
        let mut evaluator = cluster.evaluator;
        coordinator
            .train(steps, config.train.eval_every, &mut evaluator)?;
        for p in coordinator.metrics.curve() {
            println!("  step {:>5}   held-out loss {:.4}", p.step, p.loss);
        }
        let final_loss = coordinator.metrics.final_loss().unwrap_or(f32::NAN);
        coordinator
            .metrics
            .write_curve_csv(format!("results/e2e_{}.csv", gar))?;
        results.push((label, final_loss));
        coordinator.shutdown();
    }

    println!("\n=== summary ===");
    for (label, loss) in &results {
        println!("  {label:<42} final loss {loss:.4}");
    }
    // The paper's story in one assertion: the robust rule under attack
    // lands close to the clean baseline; poisoned averaging does not.
    if results.len() == 3 {
        let (robust, poisoned, clean) = (results[0].1, results[1].1, results[2].1);
        println!(
            "\nrobust-vs-clean gap: {:+.4}; poisoned-averaging-vs-clean gap: {:+.4}",
            robust - clean,
            poisoned - clean
        );
    }
    Ok(())
}
