//! Byzantine gauntlet demo: every GAR against every attack on the
//! quadratic workload — the "who survives what" matrix of the paper's
//! resilience claims (weak rules fall to little-is-enough; MULTI-BULYAN
//! survives everything at n ≥ 4f+3).
//!
//! ```bash
//! cargo run --release --example byzantine_gauntlet
//! ```

use multibulyan::bench::resilience::{run, GauntletConfig};
use multibulyan::Result;

fn main() -> Result<()> {
    let cfg = GauntletConfig {
        steps: 300,
        dim: 256,
        ..Default::default()
    };
    println!(
        "resilience gauntlet: n={}, f={}, quadratic dim={}, {} steps\n",
        cfg.n, cfg.f, cfg.dim, cfg.steps
    );
    let rows = run(&cfg, false)?;

    // Headline checks, mirroring the paper's claims.
    let get = |gar: &str, attack: &str| {
        rows.iter()
            .find(|r| r.gar.as_str() == gar && r.attack == attack)
            .map(|r| r.converged)
            .unwrap_or(false)
    };
    println!("\npaper-claim checklist:");
    println!(
        "  averaging breaks under sign-flip:        {}",
        if !get("average", "sign-flip") { "✓" } else { "✗ (unexpected)" }
    );
    println!(
        "  multi-krum survives sign-flip:           {}",
        if get("multi-krum", "sign-flip") { "✓" } else { "✗" }
    );
    println!(
        "  multi-bulyan survives little-is-enough:  {}",
        if get("multi-bulyan", "little-is-enough") { "✓" } else { "✗" }
    );
    println!(
        "  multi-bulyan survives omniscient:        {}",
        if get("multi-bulyan", "omniscient") { "✓" } else { "✗" }
    );
    Ok(())
}
