//! Slowdown sweep: measure the m̃/n slowdown of Theorem 1.ii / 2.iii by
//! comparing steps-to-convergence against averaging in the Byzantine-free
//! setting, across m values for MULTI-KRUM plus MULTI-BULYAN and MEDIAN.
//!
//! ```bash
//! cargo run --release --example slowdown_sweep
//! ```

use multibulyan::bench::slowdown::{run, SlowdownConfig};
use multibulyan::Result;

fn main() -> Result<()> {
    let cfg = SlowdownConfig::default();
    println!(
        "slowdown sweep on the quadratic workload: n={}, f={}, d={}, σ={} (b={})\n\
         slowdown := steps(average)/steps(rule); theory predicts m̃/n\n",
        cfg.n, cfg.f, cfg.dim, cfg.noise, cfg.batch_size
    );
    let rows = run(&cfg, false)?;
    println!("\nmeasured-vs-predicted:");
    for r in rows {
        if let Some(s) = r.slowdown_vs_average {
            println!(
                "  {:<18} measured {:.3} vs predicted {:.3} (×n: {:.1} vs {})",
                r.label,
                s,
                r.predicted,
                s * cfg.n as f64,
                r.gradients_used
            );
        }
    }
    Ok(())
}
