#!/usr/bin/env bash
# Multi-process socket-transport demo: one coordinator + N external
# `multibulyan worker` processes speaking the MBWP frame protocol
# (docs/wire-protocol.md) over a Unix domain socket.
#
#   examples/socket_cluster.sh             # cargo-built release binary
#   MULTIBULYAN=path/to/multibulyan examples/socket_cluster.sh
#
# The coordinator binds --socket-listen and simulates the Byzantine
# coalition in-process; each *honest* worker slot is a real OS process
# that registers over the socket and streams its gradient chunk-wise.
# The quadratic workload derives every gradient from (dim, noise, seed,
# worker, round), so the printed params_checksum is bit-identical to
# the same seeded run on the pooled or threaded transport.
set -euo pipefail
cd "$(dirname "$0")/.."

BIN="${MULTIBULYAN:-target/release/multibulyan}"
if [[ ! -x "$BIN" ]]; then
    echo "building $BIN ..." >&2
    cargo build --release
fi

# Experiment shape. Honest workers = N - BYZ external processes; the
# worker flags below MUST match the coordinator's (--dim/--seed/
# --batch-size here; noise is 0.5 by default on both sides).
N=7 F=1 BYZ=1
DIM=200 SEED=7 BATCH=8 STEPS=40
CHUNK=64   # GradientChunk coordinates per frame (wire-protocol.md §4.3)
# Gradient wire codec (wire-protocol.md §7): off|raw|lossless|fp16|int8|
# topk. Workers advertise it in their Hello and tag every chunk with it;
# the coordinator decodes server-side. `raw` (and `lossless`) keep the
# params_checksum bit-identical to the pooled run; the lossy codecs
# trade bytes for gradient fidelity (see `multibulyan bench codec`).
CODEC="${CODEC:-raw}"
ADDR="unix:${TMPDIR:-/tmp}/multibulyan-demo-$$.sock"

PIDS=()
cleanup() {
    for pid in "${PIDS[@]:-}"; do kill "$pid" 2>/dev/null || true; done
    wait 2>/dev/null || true
}
trap cleanup EXIT

HONEST=$((N - BYZ))
for ((k = 0; k < HONEST; k++)); do
    "$BIN" worker --connect "$ADDR" --worker-id "$k" \
        --dim "$DIM" --seed "$SEED" --batch-size "$BATCH" \
        --chunk "$CHUNK" --codec "$CODEC" --retry-ms 10000 &
    PIDS+=("$!")
done

# The workers retry with bounded exponential backoff (50 ms doubling to
# a 2 s cap, --retry-ms total) until the coordinator binds, so start
# order is free and the startup race is benign.
"$BIN" train --transport socket --socket-listen "$ADDR" \
    --socket-chunk "$CHUNK" --codec "$CODEC" \
    --gar multi-bulyan --attack sign-flip \
    --n "$N" --f "$F" --byzantine "$BYZ" \
    --dim "$DIM" --seed "$SEED" --batch-size "$BATCH" --steps "$STEPS" \
    --params-checksum

echo "socket_cluster: OK (compare the checksum against:"
echo "  $BIN train --transport pooled --gar multi-bulyan --attack sign-flip \\"
echo "      --n $N --f $F --byzantine $BYZ --dim $DIM --seed $SEED \\"
echo "      --batch-size $BATCH --steps $STEPS --params-checksum)"
