//! Quickstart: aggregate gradients with every GAR, then run a short
//! Byzantine-free distributed training on the rust-native workload.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! No AOT artifacts required — this exercises the pure-rust path. For the
//! full three-layer stack (JAX/Pallas artifacts via PJRT), see
//! `examples/e2e_train.rs` after `make artifacts`.

use multibulyan::config::{ClusterConfig, ExperimentConfig, ModelConfig, TrainConfig};
use multibulyan::coordinator::launch;
use multibulyan::gar::GarKind;
use multibulyan::tensor::GradMatrix;
use multibulyan::util::Rng64;
use multibulyan::Result;

fn main() -> Result<()> {
    // --- 1. One-shot aggregation with each rule -------------------------
    let (n, f, d) = (11, 2, 10_000);
    let mut rng = Rng64::seed_from_u64(0);
    let grads = GradMatrix::uniform(n, d, -1.0, 1.0, &mut rng);
    println!("aggregating {n} random gradients of dimension {d} (f = {f}):");
    for kind in GarKind::ALL {
        let gar = kind.instantiate(n, f)?;
        let sw = multibulyan::metrics::Stopwatch::start();
        let out = gar.aggregate(&grads)?;
        println!(
            "  {:<13} {:>8.3} ms   ‖out‖ = {:.4}   gradients used: {}",
            gar.name(),
            sw.elapsed_ms(),
            multibulyan::tensor::l2_norm(&out),
            gar.gradients_used()
        );
    }

    // --- 2. A short distributed training run ----------------------------
    let config = ExperimentConfig {
        cluster: ClusterConfig {
            n,
            f,
            actual_byzantine: Some(0),
            net_delay_us: 50,
            drop_prob: 0.0,
            round_timeout_ms: 60_000,
            ..Default::default()
        },
        gar: GarKind::MultiBulyan,
        pre: Vec::new(),
        attack: multibulyan::attacks::AttackKind::None,
        model: ModelConfig::Quadratic {
            dim: 1_000,
            noise: 0.5,
        },
        train: TrainConfig {
            learning_rate: 0.1,
            momentum: 0.9,
            steps: 200,
            batch_size: 16,
            eval_every: 40,
            seed: 1,
        },
        // Auto-detected aggregation threads — results are bit-identical
        // to `threads: 1`, just faster at large d.
        threads: 0,
        transport: Default::default(),
        collect: Default::default(),
        overlap: Default::default(),
        overlap_window: 1,
        codec: None,
        groups: 1,
        output_dir: None,
        journal: None,
        crash_after_round: None,
    };
    println!("\ntraining the quadratic workload with MULTI-BULYAN (n={n}, f={f}, no attack):");
    let cluster = launch(&config, None)?;
    let mut coordinator = cluster.coordinator;
    let mut evaluator = cluster.evaluator;
    coordinator.train(200, 40, &mut evaluator)?;
    for p in coordinator.metrics.curve() {
        println!("  step {:>4}   loss {:.6}", p.step, p.loss);
    }
    let final_loss = coordinator.metrics.final_loss().unwrap();
    coordinator.shutdown();
    println!("final loss: {final_loss:.6} (converged: {})", final_loss < 1e-3);
    Ok(())
}
