#!/usr/bin/env bash
# Tier-1 verification + formatting gate.
#
#   scripts/verify.sh          # build, test, fmt-check
#   scripts/verify.sh --quick  # skip the release build (debug test only)
#
# The tier-1 contract is `cargo build --release && cargo test -q`; the
# fmt check rides along so drift is caught where a rustfmt toolchain is
# installed (it is skipped with a warning where `cargo fmt` is absent,
# e.g. minimal CI images with cargo but no rustfmt component).
set -euo pipefail
cd "$(dirname "$0")/.."

if [[ "${1:-}" != "--quick" ]]; then
    cargo build --release
fi
cargo test -q

if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --all --check
else
    echo "warning: rustfmt not installed; skipping cargo fmt --check" >&2
fi

echo "verify: OK"
