#!/usr/bin/env bash
# Tier-1 verification + formatting gate.
#
#   scripts/verify.sh          # build, test, fmt-check
#   scripts/verify.sh --quick  # skip the release build (debug test only)
#
# The tier-1 contract is `cargo build --release && cargo test -q`; the
# fmt check rides along so drift is caught where a rustfmt toolchain is
# installed. The skip/enforce decision is printed explicitly: CI images
# install rustfmt and therefore ENFORCE it; minimal local images without
# the component SKIP it (and say so) rather than failing the build.
set -euo pipefail
cd "$(dirname "$0")/.."

if [[ "${1:-}" != "--quick" ]]; then
    cargo build --release
fi
cargo test -q

# Repo-specific invariant linter (unsafe audit, wall-clock, pool-only
# parallelism, hash-iteration, float-reduction rules). Exits nonzero on
# any finding; `multibulyan lint --list` prints the rule catalog.
cargo run -q -- lint

if cargo fmt --version >/dev/null 2>&1; then
    echo "fmt: ENFORCED (cargo fmt --all --check)"
    cargo fmt --all --check
else
    echo "fmt: SKIPPED — no rustfmt in this toolchain; CI enforces it" >&2
fi

echo "verify: OK"
