//! `artifacts/manifest.json` — the contract between `python/compile/aot.py`
//! (producer) and the rust runtime (consumer).
//!
//! The manifest maps artifact names to HLO files with their input/output
//! signatures, and model names to the artifact family implementing them
//! (gradient step per batch size, evaluation step, initial parameters).
//! Parsed with the in-repo JSON parser ([`crate::util::json`]).

use crate::util::json::Json;
use crate::Result;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Tensor signature: dtype + shape.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    /// "f32" or "i32".
    pub dtype: String,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    fn from_json(v: &Json) -> Result<Self> {
        Ok(Self {
            dtype: v.field("dtype")?.as_str()?.to_string(),
            shape: v
                .field("shape")?
                .as_arr()?
                .iter()
                .map(|d| d.as_usize())
                .collect::<Result<_>>()?,
        })
    }
}

/// One AOT-compiled computation.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactSpec {
    /// HLO text file, relative to the manifest's directory.
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    /// Number of outputs in the result tuple.
    pub outputs: usize,
}

impl ArtifactSpec {
    fn from_json(v: &Json) -> Result<Self> {
        Ok(Self {
            file: v.field("file")?.as_str()?.to_string(),
            inputs: v
                .field("inputs")?
                .as_arr()?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<_>>()?,
            outputs: v.field("outputs")?.as_usize()?,
        })
    }
}

/// A trainable model: its parameter dimension and artifact family.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpec {
    /// Flat parameter count `d`.
    pub dim: usize,
    /// Raw little-endian f32 file with the seed-0 initial parameters,
    /// relative to the manifest's directory.
    pub init_file: String,
    /// batch size → gradient artifact name.
    /// Signature: `(params[d], features[b,F], labels[b]) → (grad[d], loss[])`.
    pub grad: BTreeMap<usize, String>,
    /// Evaluation artifact: `(params[d], features[E,F], labels[E]) →
    /// (correct_flags[E], loss[])`.
    pub eval: Option<String>,
    /// Eval artifact's batch size `E`.
    pub eval_batch: usize,
    /// Flattened feature dimension `F` fed to the model (or sequence
    /// length `L` for the LM).
    pub feature_dim: usize,
    /// Output classes (or vocab size for the LM).
    pub num_classes: usize,
}

impl ModelSpec {
    fn from_json(v: &Json) -> Result<Self> {
        let mut grad = BTreeMap::new();
        for (k, name) in v.field("grad")?.as_obj()? {
            grad.insert(
                k.parse::<usize>()
                    .map_err(|_| anyhow::anyhow!("bad batch size key '{k}'"))?,
                name.as_str()?.to_string(),
            );
        }
        Ok(Self {
            dim: v.field("dim")?.as_usize()?,
            init_file: v.field("init_file")?.as_str()?.to_string(),
            grad,
            eval: match v.field_opt("eval") {
                Some(e) => Some(e.as_str()?.to_string()),
                None => None,
            },
            eval_batch: v
                .field_opt("eval_batch")
                .map(|e| e.as_usize())
                .transpose()?
                .unwrap_or(0),
            feature_dim: v.field("feature_dim")?.as_usize()?,
            num_classes: v.field("num_classes")?.as_usize()?,
        })
    }

    /// The gradient artifact for batch size `b`.
    pub fn grad_artifact(&self, b: usize) -> Result<&str> {
        self.grad.get(&b).map(String::as_str).ok_or_else(|| {
            anyhow::anyhow!(
                "no gradient artifact for batch size {b} (available: {:?}); \
                 re-run `make artifacts` with this batch size added",
                self.batch_sizes()
            )
        })
    }

    /// Batch sizes with compiled gradient artifacts, ascending.
    pub fn batch_sizes(&self) -> Vec<usize> {
        self.grad.keys().copied().collect()
    }
}

/// The whole manifest.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Manifest {
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub models: BTreeMap<String, ModelSpec>,
    /// Directory the manifest was loaded from.
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `manifest.json` from an artifacts directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            anyhow::anyhow!(
                "reading {path:?}: {e}\nhint: run `make artifacts` to build the AOT artifacts"
            )
        })?;
        let root = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parsing {path:?}: {e}"))?;
        let mut artifacts = BTreeMap::new();
        if let Some(arts) = root.field_opt("artifacts") {
            for (name, v) in arts.as_obj()? {
                artifacts.insert(
                    name.clone(),
                    ArtifactSpec::from_json(v)
                        .map_err(|e| anyhow::anyhow!("artifact '{name}': {e}"))?,
                );
            }
        }
        let mut models = BTreeMap::new();
        if let Some(ms) = root.field_opt("models") {
            for (name, v) in ms.as_obj()? {
                models.insert(
                    name.clone(),
                    ModelSpec::from_json(v)
                        .map_err(|e| anyhow::anyhow!("model '{name}': {e}"))?,
                );
            }
        }
        let m = Manifest {
            artifacts,
            models,
            dir: dir.to_path_buf(),
        };
        m.validate()?;
        Ok(m)
    }

    /// Check internal consistency and that referenced files exist.
    pub fn validate(&self) -> Result<()> {
        for (name, art) in &self.artifacts {
            let path = self.dir.join(&art.file);
            anyhow::ensure!(path.exists(), "artifact '{name}': missing file {path:?}");
            anyhow::ensure!(art.outputs >= 1, "artifact '{name}': zero outputs");
            for (i, t) in art.inputs.iter().enumerate() {
                anyhow::ensure!(
                    t.dtype == "f32" || t.dtype == "i32",
                    "artifact '{name}' input {i}: unsupported dtype {}",
                    t.dtype
                );
            }
        }
        for (name, model) in &self.models {
            let init = self.dir.join(&model.init_file);
            anyhow::ensure!(init.exists(), "model '{name}': missing init file {init:?}");
            for (b, art) in &model.grad {
                anyhow::ensure!(
                    self.artifacts.contains_key(art),
                    "model '{name}' grad[{b}]: unknown artifact '{art}'"
                );
            }
            if let Some(eval) = &model.eval {
                anyhow::ensure!(
                    self.artifacts.contains_key(eval),
                    "model '{name}': unknown eval artifact '{eval}'"
                );
            }
        }
        Ok(())
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown artifact '{name}'"))
    }

    pub fn model(&self, name: &str) -> Result<&ModelSpec> {
        self.models.get(name).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown model '{name}' (available: {:?})",
                self.models.keys().collect::<Vec<_>>()
            )
        })
    }

    /// Absolute path of an artifact's HLO file.
    pub fn hlo_path(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.artifact(name)?.file))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_fixture(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("m.hlo.txt"), "HloModule m").unwrap();
        std::fs::write(dir.join("init.f32bin"), 4u32.to_le_bytes()).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{
              "artifacts": {
                "mlp_grad_b8": {
                  "file": "m.hlo.txt",
                  "inputs": [
                    {"dtype": "f32", "shape": [10]},
                    {"dtype": "f32", "shape": [8, 4]},
                    {"dtype": "i32", "shape": [8]}
                  ],
                  "outputs": 2
                }
              },
              "models": {
                "mlp": {
                  "dim": 10,
                  "init_file": "init.f32bin",
                  "grad": {"8": "mlp_grad_b8"},
                  "eval": null,
                  "eval_batch": 0,
                  "feature_dim": 4,
                  "num_classes": 2
                }
              }
            }"#,
        )
        .unwrap();
    }

    #[test]
    fn load_and_query() {
        let dir = std::env::temp_dir().join("mb_manifest_test");
        write_fixture(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.artifact("mlp_grad_b8").unwrap().outputs, 2);
        assert_eq!(m.artifact("mlp_grad_b8").unwrap().inputs[2].dtype, "i32");
        let model = m.model("mlp").unwrap();
        assert_eq!(model.grad_artifact(8).unwrap(), "mlp_grad_b8");
        assert!(model.grad_artifact(16).is_err());
        assert_eq!(model.batch_sizes(), vec![8]);
        assert!(model.eval.is_none());
        assert!(m.hlo_path("mlp_grad_b8").unwrap().ends_with("m.hlo.txt"));
        assert!(m.artifact("nope").is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn validate_catches_missing_file() {
        let dir = std::env::temp_dir().join("mb_manifest_test2");
        write_fixture(&dir);
        std::fs::remove_file(dir.join("m.hlo.txt")).unwrap();
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn missing_manifest_mentions_make_artifacts() {
        let err = Manifest::load("/nonexistent/dir").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn bad_dtype_rejected() {
        let dir = std::env::temp_dir().join("mb_manifest_test3");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("m.hlo.txt"), "x").unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"artifacts": {"a": {"file": "m.hlo.txt",
                "inputs": [{"dtype": "f64", "shape": [1]}], "outputs": 1}}}"#,
        )
        .unwrap();
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(dir).ok();
    }
}
