//! The compute thread: owns the PJRT client and all compiled executables,
//! serves execution requests from the rest of the system.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-backed (not `Send`), so it lives
//! on one dedicated thread; [`ComputeHandle`] (cloneable, `Send + Sync`)
//! sends `(artifact, args)` over an mpsc queue and blocks on a per-request
//! reply channel. The compute thread materialises literals, runs the
//! executable and converts every output to `Vec<f32>` (the JAX graphs
//! cast counts/scalars to f32 so one conversion path suffices).

use super::manifest::Manifest;
// The offline build compiles against the in-tree API shim instead of the
// real `xla` crate; swap this alias (plus a Cargo dependency) to restore
// actual PJRT execution. See `runtime::xla_stub` docs.
use super::xla_stub as xla;
use crate::Result;
// LINT: sorted -- the executable cache below is keyed get/insert only;
// it is never iterated, so hash order cannot reach any output.
use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

/// A host-side argument for an artifact input.
#[derive(Debug, Clone)]
pub enum ArgValue {
    /// f32 tensor with explicit shape.
    F32(Vec<f32>, Vec<usize>),
    /// i32 tensor with explicit shape (labels/tokens).
    I32(Vec<i32>, Vec<usize>),
}

impl ArgValue {
    /// Flat f32 vector (rank 1).
    pub fn f32_vec(v: Vec<f32>) -> Self {
        let n = v.len();
        ArgValue::F32(v, vec![n])
    }

    /// f32 scalar (rank 0).
    pub fn f32_scalar(v: f32) -> Self {
        ArgValue::F32(vec![v], vec![])
    }

    pub fn element_count(&self) -> usize {
        match self {
            ArgValue::F32(v, _) => v.len(),
            ArgValue::I32(v, _) => v.len(),
        }
    }

    fn shape(&self) -> &[usize] {
        match self {
            ArgValue::F32(_, s) | ArgValue::I32(_, s) => s,
        }
    }

    fn dtype(&self) -> &'static str {
        match self {
            ArgValue::F32(..) => "f32",
            ArgValue::I32(..) => "i32",
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let lit = match self {
            ArgValue::F32(v, shape) => {
                let dims: Vec<i64> = shape.iter().map(|&x| x as i64).collect();
                xla::Literal::vec1(v).reshape(&dims)?
            }
            ArgValue::I32(v, shape) => {
                let dims: Vec<i64> = shape.iter().map(|&x| x as i64).collect();
                xla::Literal::vec1(v).reshape(&dims)?
            }
        };
        Ok(lit)
    }
}

enum Request {
    Exec {
        artifact: String,
        args: Vec<ArgValue>,
        resp: mpsc::Sender<Result<Vec<Vec<f32>>>>,
    },
    /// Pre-compile an artifact (warmup), reply when done.
    Warm(String, mpsc::Sender<Result<()>>),
    Shutdown,
}

/// Cloneable, thread-safe handle to the compute thread.
#[derive(Clone)]
pub struct ComputeHandle {
    tx: Arc<Mutex<mpsc::Sender<Request>>>,
}

impl ComputeHandle {
    fn send(&self, req: Request) -> Result<()> {
        self.tx
            .lock()
            .map_err(|_| anyhow::anyhow!("compute handle poisoned"))?
            .send(req)
            .map_err(|_| anyhow::anyhow!("compute thread is down"))
    }

    /// Execute `artifact` with `args`; returns one `Vec<f32>` per output.
    /// Blocks until the compute thread replies (requests are served FIFO —
    /// the single-accelerator semantics of the paper's testbed).
    pub fn execute(&self, artifact: &str, args: Vec<ArgValue>) -> Result<Vec<Vec<f32>>> {
        let (resp, rx) = mpsc::channel();
        self.send(Request::Exec {
            artifact: artifact.to_string(),
            args,
            resp,
        })?;
        rx.recv()
            .map_err(|_| anyhow::anyhow!("compute thread dropped request"))?
    }

    /// Compile an artifact ahead of time (so round 1 is not a compile).
    pub fn warmup(&self, artifact: &str) -> Result<()> {
        let (resp, rx) = mpsc::channel();
        self.send(Request::Warm(artifact.to_string(), resp))?;
        rx.recv()
            .map_err(|_| anyhow::anyhow!("compute thread dropped request"))?
    }

    /// Ask the compute thread to exit (idempotent; best-effort).
    pub fn shutdown(&self) {
        let _ = self.send(Request::Shutdown);
    }
}

/// The compute thread itself.
pub struct ComputeServer {
    handle: ComputeHandle,
    join: Option<std::thread::JoinHandle<()>>,
}

impl ComputeServer {
    /// Spawn the compute thread for a loaded manifest.
    pub fn start(manifest: Manifest) -> Result<Self> {
        let (tx, rx) = mpsc::channel::<Request>();
        let join = std::thread::Builder::new()
            .name("pjrt-compute".into())
            .spawn(move || compute_loop(manifest, rx))?;
        Ok(Self {
            handle: ComputeHandle {
                tx: Arc::new(Mutex::new(tx)),
            },
            join: Some(join),
        })
    }

    pub fn handle(&self) -> ComputeHandle {
        self.handle.clone()
    }
}

impl Drop for ComputeServer {
    fn drop(&mut self) {
        self.handle.shutdown();
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn compute_loop(manifest: Manifest, rx: mpsc::Receiver<Request>) {
    // Client creation can fail only on broken installs; surface the error
    // on every request rather than panicking the thread.
    let client = xla::PjRtClient::cpu();
    // LINT: sorted -- keyed get/insert only; never iterated.
    let mut cache: HashMap<String, xla::PjRtLoadedExecutable> = HashMap::new();

    while let Ok(req) = rx.recv() {
        match req {
            Request::Shutdown => break,
            Request::Warm(name, resp) => {
                let r = match &client {
                    Ok(c) => get_or_compile(&manifest, c, &mut cache, &name).map(|_| ()),
                    Err(e) => Err(anyhow::anyhow!("PJRT client unavailable: {e}")),
                };
                let _ = resp.send(r);
            }
            Request::Exec {
                artifact,
                args,
                resp,
            } => {
                let r = match &client {
                    Ok(c) => run_one(&manifest, c, &mut cache, &artifact, &args),
                    Err(e) => Err(anyhow::anyhow!("PJRT client unavailable: {e}")),
                };
                let _ = resp.send(r);
            }
        }
    }
}

fn get_or_compile<'a>(
    manifest: &Manifest,
    client: &xla::PjRtClient,
    cache: &'a mut HashMap<String, xla::PjRtLoadedExecutable>, // LINT: sorted -- keyed access only
    name: &str,
) -> Result<&'a xla::PjRtLoadedExecutable> {
    if !cache.contains_key(name) {
        let path = manifest.hlo_path(name)?;
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("parsing HLO {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling artifact '{name}': {e}"))?;
        cache.insert(name.to_string(), exe);
    }
    Ok(cache.get(name).unwrap())
}

fn run_one(
    manifest: &Manifest,
    client: &xla::PjRtClient,
    cache: &mut HashMap<String, xla::PjRtLoadedExecutable>, // LINT: sorted -- keyed access only
    name: &str,
    args: &[ArgValue],
) -> Result<Vec<Vec<f32>>> {
    // Validate against the manifest signature before touching PJRT.
    let spec = manifest.artifact(name)?;
    anyhow::ensure!(
        args.len() == spec.inputs.len(),
        "artifact '{name}': expected {} inputs, got {}",
        spec.inputs.len(),
        args.len()
    );
    for (i, (arg, want)) in args.iter().zip(&spec.inputs).enumerate() {
        anyhow::ensure!(
            arg.dtype() == want.dtype,
            "artifact '{name}' input {i}: dtype {} != manifest {}",
            arg.dtype(),
            want.dtype
        );
        anyhow::ensure!(
            arg.shape() == want.shape.as_slice(),
            "artifact '{name}' input {i}: shape {:?} != manifest {:?}",
            arg.shape(),
            want.shape
        );
    }

    let exe = get_or_compile(manifest, client, cache, name)?;
    let literals: Vec<xla::Literal> = args
        .iter()
        .map(|a| a.to_literal())
        .collect::<Result<_>>()?;
    let result = exe
        .execute::<xla::Literal>(&literals)
        .map_err(|e| anyhow::anyhow!("executing '{name}': {e}"))?;
    let out = result[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow::anyhow!("fetching result of '{name}': {e}"))?;
    // aot.py lowers with return_tuple=True: unpack the tuple.
    let parts = out
        .to_tuple()
        .map_err(|e| anyhow::anyhow!("untupling result of '{name}': {e}"))?;
    anyhow::ensure!(
        parts.len() == spec.outputs,
        "artifact '{name}': manifest says {} outputs, got {}",
        spec.outputs,
        parts.len()
    );
    parts
        .into_iter()
        .map(|lit| {
            lit.to_vec::<f32>()
                .map_err(|e| anyhow::anyhow!("output of '{name}' is not f32: {e}"))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argvalue_shapes_and_literals() {
        let a = ArgValue::f32_vec(vec![1.0, 2.0, 3.0]);
        assert_eq!(a.shape(), &[3]);
        assert_eq!(a.dtype(), "f32");
        assert_eq!(a.element_count(), 3);
        let s = ArgValue::f32_scalar(5.0);
        assert_eq!(s.shape(), &[] as &[usize]);
        let i = ArgValue::I32(vec![1, 2, 3, 4], vec![2, 2]);
        assert_eq!(i.dtype(), "i32");
        // Literal conversion happens on the compute thread in production,
        // but is safe host-side too.
        let lit = i.to_literal().unwrap();
        assert_eq!(lit.element_count(), 4);
    }

    #[test]
    fn handle_reports_thread_down_after_shutdown() {
        let dir = std::env::temp_dir().join("mb_compute_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), r#"{"artifacts":{},"models":{}}"#).unwrap();
        let manifest = Manifest::load(&dir).unwrap();
        let server = ComputeServer::start(manifest).unwrap();
        let handle = server.handle();
        handle.shutdown();
        // Give the thread a moment to exit, then expect an error.
        std::thread::sleep(std::time::Duration::from_millis(50));
        assert!(handle.execute("missing", vec![]).is_err());
        std::fs::remove_dir_all(dir).ok();
    }
}
