//! Crate-internal thread pool + coordinate-sharding helpers — the parallel
//! aggregation engine (std-only; the offline environment has no rayon).
//!
//! Design:
//!
//! * [`ThreadPool`] owns `threads − 1` persistent workers; the calling
//!   thread participates in every parallel region, so `threads = 1` means
//!   "no pool at all" and the sequential path has zero synchronisation
//!   overhead.
//! * The one primitive is [`ThreadPool::run_sharded`]: run `f(0..shards)`
//!   with dynamic shard claiming (an atomic counter — load-balanced for
//!   unequal shard costs) and block until every shard has finished.
//! * [`Parallelism`] is the cheap, cloneable handle the GARs hold: either
//!   sequential or an `Arc<ThreadPool>` shared by every rule of a
//!   coordinator (the `threads` experiment-config knob).
//! * [`shard_slice`] / [`shard_slice_stateless`] split an output slice into
//!   disjoint contiguous coordinate ranges, one per shard, with an optional
//!   per-shard scratch state — the shared helper behind every
//!   per-coordinate GAR pass. Because shards own disjoint ranges and each
//!   coordinate's arithmetic is untouched, results are **bit-identical**
//!   to the sequential pass for every thread count (the property
//!   `rust/tests/prop_gar.rs::parallel_output_bit_identical_to_sequential`
//!   locks in).
//!
//! Reentrancy: a shard function must not call back into the same pool
//! (`run_sharded` from inside a shard deadlocks on the `active` lock). No
//! GAR pass nests parallel regions.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};

/// Coordinate ranges shorter than this stay sequential: below ~4k f32 the
/// wakeup + completion handshake costs more than the pass itself.
pub const MIN_COORDS_PER_SHARD: usize = 4096;

/// Lifetime-erased pointer to the scope's shard function. A raw pointer —
/// not a reference — so that a worker still holding `Arc<Task>` after the
/// submitting call returned holds only a (possibly dangling) address, not
/// a dangling reference; it is dereferenced strictly for claims made while
/// the submitter blocks on `pending` (see the SAFETY note in
/// [`ThreadPool::run_sharded`]).
struct TaskFn(*const (dyn Fn(usize) + Sync));
// SAFETY: the pointee is `Sync` (shared `&`-calls from any thread are
// fine), and the pointer is only dereferenced while the pointee is alive.
unsafe impl Send for TaskFn {}
unsafe impl Sync for TaskFn {}

/// One in-flight parallel region.
struct Task {
    f: TaskFn,
    /// Next shard index to claim.
    next: AtomicUsize,
    /// Shards not yet completed.
    pending: AtomicUsize,
    shards: usize,
    /// Set when any shard panicked; re-raised on the calling thread.
    panicked: AtomicBool,
    /// First panic payload, re-thrown by the caller so the original
    /// message/location survives the thread hop.
    panic_payload: Mutex<Option<Box<dyn std::any::Any + Send>>>,
}

#[derive(Default)]
struct PoolState {
    task: Option<Arc<Task>>,
    /// Bumped per task so sleeping workers can tell "new task" from
    /// spurious wakeups.
    generation: u64,
    shutdown: bool,
}

struct Shared {
    state: Mutex<PoolState>,
    /// Workers wait here for a new task (or shutdown).
    work_cv: Condvar,
    /// The caller waits here for `pending == 0`.
    done_cv: Condvar,
    /// Serialises parallel regions: one `run_sharded` at a time per pool.
    active: Mutex<()>,
}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    // A panicked shard is already recorded in `Task::panicked`; lock
    // poisoning carries no extra information here.
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Claim and run shards of `task` until none remain.
fn run_task(shared: &Shared, task: &Task) {
    loop {
        let i = task.next.fetch_add(1, Ordering::Relaxed);
        if i >= task.shards {
            break;
        }
        // SAFETY: `i < shards`, so this claim was handed out while the
        // submitting `run_sharded` is still blocked on `pending` — the
        // pointee is alive for the whole call.
        let f = unsafe { &*task.f.0 };
        if let Err(payload) = catch_unwind(AssertUnwindSafe(|| f(i))) {
            let mut slot = lock(&task.panic_payload);
            if slot.is_none() {
                *slot = Some(payload);
            }
            drop(slot);
            task.panicked.store(true, Ordering::Relaxed);
        }
        // AcqRel + the caller's Acquire load form the standard countdown
        // latch: when the caller observes 0, every shard's writes are
        // visible to it.
        if task.pending.fetch_sub(1, Ordering::AcqRel) == 1 {
            let _st = lock(&shared.state);
            shared.done_cv.notify_all();
        }
    }
}

fn worker_loop(shared: Arc<Shared>) {
    let mut seen_generation = 0u64;
    loop {
        let task = {
            let mut st = lock(&shared.state);
            loop {
                if st.shutdown {
                    return;
                }
                if st.generation != seen_generation {
                    seen_generation = st.generation;
                    if let Some(task) = st.task.clone() {
                        break task;
                    }
                }
                st = shared.work_cv.wait(st).unwrap_or_else(|e| e.into_inner());
            }
        };
        run_task(&shared, &task);
    }
}

/// A fixed-size pool of persistent worker threads (see module docs).
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<()>>,
    threads: usize,
}

impl std::fmt::Debug for ThreadPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ThreadPool")
            .field("threads", &self.threads)
            .finish()
    }
}

impl ThreadPool {
    /// Spawn a pool executing parallel regions on `threads` threads total
    /// (`threads − 1` workers + the calling thread).
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(PoolState::default()),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            active: Mutex::new(()),
        });
        let workers = (1..threads)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("gar-pool-{i}"))
                    .spawn(move || worker_loop(shared))
                    .expect("spawning pool worker thread")
            })
            .collect();
        Self {
            shared,
            workers,
            threads,
        }
    }

    /// Total threads participating in a parallel region (workers + caller).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(i)` for every `i in 0..shards` across the pool; shards are
    /// claimed dynamically. Blocks until all shards completed. Panics
    /// (after completion of the region) if any shard panicked.
    pub fn run_sharded(&self, shards: usize, f: &(dyn Fn(usize) + Sync)) {
        if shards == 0 {
            return;
        }
        if self.workers.is_empty() || shards == 1 {
            for i in 0..shards {
                f(i);
            }
            return;
        }
        let _active = lock(&self.shared.active);
        // SAFETY: the pointer escapes only into `Task`, and `run_task`
        // dereferences it exclusively for claims `i < shards` — all of
        // which complete before the matching `pending` decrement. This
        // function returns only after observing `pending == 0`, so every
        // dereference happens while `f` is alive; afterwards workers may
        // still hold the (now dangling) raw pointer inside `Arc<Task>`,
        // which is fine — it is never dereferenced again.
        let f_erased: &'static (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(f) };
        let task = Arc::new(Task {
            f: TaskFn(f_erased),
            next: AtomicUsize::new(0),
            pending: AtomicUsize::new(shards),
            shards,
            panicked: AtomicBool::new(false),
            panic_payload: Mutex::new(None),
        });
        {
            let mut st = lock(&self.shared.state);
            st.generation = st.generation.wrapping_add(1);
            st.task = Some(Arc::clone(&task));
            self.shared.work_cv.notify_all();
        }
        // The caller is a full participant.
        run_task(&self.shared, &task);
        let mut st = lock(&self.shared.state);
        while task.pending.load(Ordering::Acquire) != 0 {
            st = self
                .shared
                .done_cv
                .wait(st)
                .unwrap_or_else(|e| e.into_inner());
        }
        st.task = None;
        drop(st);
        if task.panicked.load(Ordering::Relaxed) {
            // Re-raise the original payload so message/location survive.
            if let Some(payload) = lock(&task.panic_payload).take() {
                resume_unwind(payload);
            }
            panic!("ThreadPool: a sharded task panicked");
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut st = lock(&self.shared.state);
            st.shutdown = true;
            self.shared.work_cv.notify_all();
        }
        for worker in self.workers.drain(..) {
            let _ = worker.join();
        }
    }
}

/// The execution-policy handle every GAR holds: sequential, or a shared
/// [`ThreadPool`]. Cloning shares the pool.
#[derive(Clone, Debug, Default)]
pub struct Parallelism {
    pool: Option<Arc<ThreadPool>>,
}

impl Parallelism {
    /// Single-threaded execution (the default; zero overhead).
    pub fn sequential() -> Self {
        Self { pool: None }
    }

    /// `threads = 0` auto-detects (`available_parallelism`), `1` is
    /// sequential, `n > 1` builds an `n`-thread pool.
    pub fn new(threads: usize) -> Self {
        let threads = if threads == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        } else {
            threads
        };
        if threads <= 1 {
            Self::sequential()
        } else {
            Self {
                pool: Some(Arc::new(ThreadPool::new(threads))),
            }
        }
    }

    pub fn threads(&self) -> usize {
        self.pool.as_ref().map_or(1, |p| p.threads())
    }

    /// Run `f(0..shards)`, on the pool when present, inline otherwise.
    /// Shard order is unspecified in the pooled case — callers must only
    /// rely on disjoint shards (results then cannot depend on order).
    pub fn run_sharded(&self, shards: usize, f: &(dyn Fn(usize) + Sync)) {
        match &self.pool {
            Some(pool) if shards > 1 => pool.run_sharded(shards, f),
            _ => {
                for i in 0..shards {
                    f(i);
                }
            }
        }
    }
}

/// A `Send + Sync` raw-pointer wrapper for the disjoint-range fan-outs:
/// each shard derives its own exclusive sub-range from the shard index, so
/// no two threads ever touch the same element. Replaces the old
/// `run_items` per-region work-item/slot vectors — the fan-out itself is
/// now allocation-free (ROADMAP item).
///
/// The pointer is deliberately private behind [`get`](Self::get): shard
/// closures must capture the *wrapper* (which carries the `Sync` impl),
/// not the bare `*mut T` — edition-2021 precise capture would otherwise
/// pull the non-`Sync` pointer field into the closure directly.
/// `pub(crate)` for the disjoint-range fan-outs other modules build on
/// the same pattern (the pairwise tree reduction in `gar::pairwise`).
pub(crate) struct SyncMutPtr<T>(pub(crate) *mut T);

impl<T> SyncMutPtr<T> {
    #[inline]
    pub(crate) fn get(&self) -> *mut T {
        self.0
    }
}

// SAFETY: shared across threads only by the fan-out helpers below, which
// hand each thread a disjoint element range; `T: Send` makes moving those
// ranges' exclusive access between threads sound.
unsafe impl<T: Send> Send for SyncMutPtr<T> {}
unsafe impl<T: Send> Sync for SyncMutPtr<T> {}

/// Run `f(c, chunk)` for every `chunk_len`-sized chunk of `data` (the last
/// chunk may be shorter), distributing chunks across the pool with dynamic
/// claiming. Zero allocation: chunks are derived from the chunk index, not
/// materialised as work items.
pub fn run_chunks<T: Send>(
    par: &Parallelism,
    data: &mut [T],
    chunk_len: usize,
    f: impl Fn(usize, &mut [T]) + Sync,
) {
    assert!(chunk_len > 0, "run_chunks: chunk_len must be ≥ 1");
    let len = data.len();
    if len == 0 {
        return;
    }
    let chunks = len.div_ceil(chunk_len);
    let base = SyncMutPtr(data.as_mut_ptr());
    par.run_sharded(chunks, &|c| {
        let start = c * chunk_len;
        let end = (start + chunk_len).min(len);
        // Shard-range disjointness: the derived range must stay in
        // bounds (ranges for distinct `c` are disjoint by construction).
        crate::strict_assert!(start < len && end <= len);
        // SAFETY: chunk `c` exclusively owns `[start, end)` (chunks are
        // disjoint by construction and `c < chunks` ⇒ `start < len`), and
        // `run_sharded` blocks until every chunk completed, so `data`
        // outlives every dereference.
        let chunk = unsafe { std::slice::from_raw_parts_mut(base.get().add(start), end - start) };
        f(c, chunk);
    });
}

/// Split `K` equal-length f32 slices into *matching* disjoint contiguous
/// ranges — the same partition for every slice, at most `par.threads()`
/// shards of at least `min_chunk` coordinates — and run
/// `f(offset, ranges, state)` on each, with a dedicated `S` per shard
/// (grown on demand via `mk_state` — the per-shard half of the
/// zero-allocation steady state). Bit-identical to the sequential pass by
/// construction: each coordinate is computed by exactly one shard with
/// unchanged arithmetic; and allocation-free — the ranges and states are
/// derived from the shard index.
///
/// The multi-slice form is what the fused combine+update pass needs: one
/// partition shared by the aggregate, parameter and velocity vectors
/// (`coordinator::core::fused_combine_update`), and by the gradient/
/// momentum rows of `gar::pipeline::ResilientMomentum`.
pub fn shard_zip<const K: usize, S: Send>(
    par: &Parallelism,
    mut slices: [&mut [f32]; K],
    states: &mut Vec<S>,
    mut mk_state: impl FnMut() -> S,
    min_chunk: usize,
    f: impl Fn(usize, [&mut [f32]; K], &mut S) + Sync,
) {
    if K == 0 {
        return;
    }
    let len = slices[0].len();
    for s in slices.iter() {
        assert_eq!(s.len(), len, "shard_zip: slice length mismatch");
    }
    if len == 0 {
        return;
    }
    let min_chunk = min_chunk.max(1);
    // Floor division: never split below `min_chunk` coordinates per shard
    // (a sub-threshold shard costs more in handshake than it computes).
    let max_useful = (len / min_chunk).max(1);
    let shards = par.threads().min(max_useful);
    while states.len() < shards {
        states.push(mk_state());
    }
    if shards == 1 {
        f(0, slices, &mut states[0]);
        return;
    }
    let chunk_len = len.div_ceil(shards);
    let ptrs: [SyncMutPtr<f32>; K] = std::array::from_fn(|s| SyncMutPtr(slices[s].as_mut_ptr()));
    let states_ptr = SyncMutPtr(states.as_mut_ptr());
    par.run_sharded(shards, &|i| {
        let start = i * chunk_len;
        if start >= len {
            // `div_ceil` rounding can leave the last shard(s) empty.
            return;
        }
        let end = (start + chunk_len).min(len);
        // Shard-range disjointness: shard `i`'s range starts on a chunk
        // boundary, stays in bounds, and owns state slot `i`. (Captures
        // `shards`, not `states` — the states Vec is already accessed
        // through the raw pointer and must not be re-borrowed here.)
        crate::strict_assert!(start % chunk_len == 0 && end <= len && i < shards);
        // SAFETY: shard `i` exclusively owns coordinates `[start, end)` of
        // every slice (the K slices are distinct `&mut` so they cannot
        // alias each other) and `states[i]` (`i < shards ≤ states.len()`);
        // all ranges are disjoint across shards, and `run_sharded` blocks
        // until every shard completed, so the slices and `states` outlive
        // every dereference.
        let ranges: [&mut [f32]; K] = std::array::from_fn(|s| unsafe {
            std::slice::from_raw_parts_mut(ptrs[s].get().add(start), end - start)
        });
        let state = unsafe { &mut *states_ptr.get().add(i) };
        f(start, ranges, state);
    });
}

/// Single-slice [`shard_zip`] — the shared helper behind every
/// per-coordinate GAR pass.
pub fn shard_slice<S: Send>(
    par: &Parallelism,
    out: &mut [f32],
    states: &mut Vec<S>,
    mk_state: impl FnMut() -> S,
    min_chunk: usize,
    f: impl Fn(usize, &mut [f32], &mut S) + Sync,
) {
    shard_zip(
        par,
        [out],
        states,
        mk_state,
        min_chunk,
        |offset, [range]: [&mut [f32]; 1], state| f(offset, range, state),
    );
}

/// [`shard_slice`] without per-shard state.
pub fn shard_slice_stateless(
    par: &Parallelism,
    out: &mut [f32],
    min_chunk: usize,
    f: impl Fn(usize, &mut [f32]) + Sync,
) {
    let mut states: Vec<()> = Vec::new();
    shard_slice(par, out, &mut states, || (), min_chunk, |offset, range, _| {
        f(offset, range)
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[test]
    fn every_shard_runs_exactly_once() {
        let pool = ThreadPool::new(4);
        for shards in [1usize, 2, 3, 7, 64] {
            let counts: Vec<AtomicU32> = (0..shards).map(|_| AtomicU32::new(0)).collect();
            pool.run_sharded(shards, &|i| {
                counts[i].fetch_add(1, Ordering::Relaxed);
            });
            for (i, c) in counts.iter().enumerate() {
                assert_eq!(c.load(Ordering::Relaxed), 1, "shard {i} of {shards}");
            }
        }
    }

    #[test]
    fn borrows_local_data_and_reuses_pool() {
        let pool = ThreadPool::new(3);
        let input: Vec<u64> = (0..1000).collect();
        for _round in 0..5 {
            let partial: Vec<AtomicU32> = (0..4).map(|_| AtomicU32::new(0)).collect();
            pool.run_sharded(4, &|s| {
                let chunk = 250;
                let sum: u64 = input[s * chunk..(s + 1) * chunk].iter().sum();
                partial[s].store(sum as u32, Ordering::Relaxed);
            });
            let total: u64 = partial
                .iter()
                .map(|p| p.load(Ordering::Relaxed) as u64)
                .sum();
            assert_eq!(total, 1000 * 999 / 2);
        }
    }

    #[test]
    fn shard_panic_propagates_and_pool_survives() {
        let pool = ThreadPool::new(2);
        let caught = catch_unwind(AssertUnwindSafe(|| {
            pool.run_sharded(4, &|i| {
                if i == 2 {
                    panic!("boom");
                }
            });
        }));
        assert!(caught.is_err(), "panic must propagate to the caller");
        // The pool must still work afterwards.
        let ran = AtomicU32::new(0);
        pool.run_sharded(3, &|_| {
            ran.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(ran.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn parallelism_thread_counts() {
        assert_eq!(Parallelism::sequential().threads(), 1);
        assert_eq!(Parallelism::new(1).threads(), 1);
        assert_eq!(Parallelism::new(3).threads(), 3);
        assert!(Parallelism::new(0).threads() >= 1);
        // Clones share the pool.
        let p = Parallelism::new(2);
        let q = p.clone();
        assert_eq!(q.threads(), 2);
    }

    #[test]
    fn run_chunks_visits_each_chunk_exactly_once() {
        for threads in [1usize, 2, 4] {
            let par = Parallelism::new(threads);
            for (len, chunk_len) in [(10usize, 3usize), (12, 4), (1, 5), (1000, 7)] {
                let mut data = vec![0u32; len];
                run_chunks(&par, &mut data, chunk_len, |c, chunk| {
                    assert!(chunk.len() <= chunk_len);
                    for v in chunk.iter_mut() {
                        *v += 1 + c as u32;
                    }
                });
                for (j, v) in data.iter().enumerate() {
                    assert_eq!(*v, 1 + (j / chunk_len) as u32, "len={len} coord {j}");
                }
            }
        }
    }

    #[test]
    fn shard_slice_covers_every_coordinate_once() {
        for threads in [1usize, 2, 4] {
            let par = Parallelism::new(threads);
            let mut out = vec![0.0f32; 10_000];
            let mut states: Vec<u32> = Vec::new();
            shard_slice(&par, &mut out, &mut states, || 0u32, 128, |offset, range, hits| {
                *hits += 1;
                for (k, v) in range.iter_mut().enumerate() {
                    *v += (offset + k) as f32;
                }
            });
            for (j, v) in out.iter().enumerate() {
                assert_eq!(*v, j as f32, "threads={threads} coord {j}");
            }
        }
    }

    #[test]
    fn shard_slice_sequential_below_min_chunk() {
        let par = Parallelism::new(4);
        let mut out = vec![0.0f32; 100];
        let mut states: Vec<u32> = Vec::new();
        shard_slice(&par, &mut out, &mut states, || 0u32, 4096, |offset, range, _| {
            assert_eq!(offset, 0);
            assert_eq!(range.len(), 100);
        });
        assert_eq!(states.len(), 1);
    }

    #[test]
    fn shard_zip_partitions_match_across_slices() {
        // The three slices must see the SAME offset partition; every
        // coordinate visited exactly once per slice.
        for threads in [1usize, 2, 4] {
            let par = Parallelism::new(threads);
            let mut a = vec![0.0f32; 9_000];
            let mut b = vec![0.0f32; 9_000];
            let mut c = vec![0.0f32; 9_000];
            let mut states: Vec<()> = Vec::new();
            shard_zip(
                &par,
                [&mut a, &mut b, &mut c],
                &mut states,
                || (),
                256,
                |offset, [ra, rb, rc], _| {
                    assert_eq!(ra.len(), rb.len());
                    assert_eq!(rb.len(), rc.len());
                    for k in 0..ra.len() {
                        let j = (offset + k) as f32;
                        ra[k] += j;
                        rb[k] += 2.0 * j;
                        rc[k] = ra[k] + rb[k];
                    }
                },
            );
            for j in 0..9_000 {
                assert_eq!(a[j], j as f32, "threads={threads}");
                assert_eq!(b[j], 2.0 * j as f32);
                assert_eq!(c[j], 3.0 * j as f32);
            }
        }
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn shard_zip_rejects_ragged_slices() {
        let par = Parallelism::sequential();
        let mut a = vec![0.0f32; 10];
        let mut b = vec![0.0f32; 11];
        let mut states: Vec<()> = Vec::new();
        shard_zip(&par, [&mut a, &mut b], &mut states, || (), 1, |_, _, _| {});
    }

    #[test]
    fn shard_slice_stateless_matches_sequential_fill() {
        let par = Parallelism::new(3);
        let mut a = vec![0.0f32; 5_000];
        let mut b = vec![0.0f32; 5_000];
        shard_slice_stateless(&par, &mut a, 512, |offset, range| {
            for (k, v) in range.iter_mut().enumerate() {
                *v = ((offset + k) as f32).sin();
            }
        });
        for (j, v) in b.iter_mut().enumerate() {
            *v = (j as f32).sin();
        }
        assert_eq!(a, b);
    }
}
