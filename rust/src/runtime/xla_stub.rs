//! API-compatible shim for the `xla` crate (PJRT bindings).
//!
//! The offline build environment ships no `xla`/`xla_extension` crate, so
//! the compute thread is compiled against this stub instead (see the
//! `use super::xla_stub as xla;` alias in [`super::compute`]). The shim
//! reproduces exactly the surface `compute.rs` touches:
//!
//! * [`Literal`] is fully functional host-side (typed storage + shape) —
//!   argument validation and the `ArgValue → Literal` conversion behave as
//!   they would against the real crate;
//! * [`PjRtClient::cpu`] returns an error, so every artifact execution
//!   reports "PJRT unavailable" at runtime instead of failing the build.
//!   Swapping in the real bindings is a one-line change in `runtime/mod.rs`
//!   plus a Cargo dependency — no call-site edits.

use std::fmt;
use std::path::Path;

/// Error type mirroring `xla::Error` closely enough for `?`/`Display`.
#[derive(Debug)]
pub struct Error(String);

impl Error {
    fn new(msg: impl Into<String>) -> Self {
        Error(msg.into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

/// Typed element storage for [`Literal`] (public only because the
/// [`NativeType`] trait mentions it; treat as opaque).
#[derive(Debug, Clone)]
pub enum Store {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Store {
    fn len(&self) -> usize {
        match self {
            Store::F32(v) => v.len(),
            Store::I32(v) => v.len(),
        }
    }
}

/// Element types a [`Literal`] can hold (`f32`/`i32` — all the AOT
/// artifacts use).
pub trait NativeType: Copy + Sized {
    fn wrap(v: Vec<Self>) -> Store;
    fn unwrap(s: &Store) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(v: Vec<Self>) -> Store {
        Store::F32(v)
    }

    fn unwrap(s: &Store) -> Option<Vec<Self>> {
        match s {
            Store::F32(v) => Some(v.clone()),
            Store::I32(_) => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(v: Vec<Self>) -> Store {
        Store::I32(v)
    }

    fn unwrap(s: &Store) -> Option<Vec<Self>> {
        match s {
            Store::I32(v) => Some(v.clone()),
            Store::F32(_) => None,
        }
    }
}

/// Host-side tensor literal: typed flat storage + dims.
#[derive(Debug, Clone)]
pub struct Literal {
    store: Store,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal {
            dims: vec![v.len() as i64],
            store: T::wrap(v.to_vec()),
        }
    }

    /// Reinterpret with new dims (element count must match; an empty dims
    /// list is a scalar, product 1).
    pub fn reshape(self, dims: &[i64]) -> Result<Literal, Error> {
        let want: i64 = dims.iter().product();
        if want < 0 || want as usize != self.store.len() {
            return Err(Error::new(format!(
                "reshape: {} elements cannot take shape {dims:?}",
                self.store.len()
            )));
        }
        Ok(Literal {
            store: self.store,
            dims: dims.to_vec(),
        })
    }

    pub fn element_count(&self) -> usize {
        self.store.len()
    }

    /// The stub never produces device tuples (execution is unavailable).
    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        Err(Error::new("stub literal is not a tuple (PJRT unavailable)"))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        T::unwrap(&self.store).ok_or_else(|| Error::new("literal dtype mismatch"))
    }
}

/// Parsed HLO module (text is retained; nothing interprets it here).
#[derive(Debug)]
pub struct HloModuleProto {
    #[allow(dead_code)]
    text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto, Error> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| Error::new(format!("reading {:?}: {e}", path.as_ref())))?;
        Ok(HloModuleProto { text })
    }
}

/// Computation wrapper (opaque here).
#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// PJRT client handle. [`PjRtClient::cpu`] always fails in this build; the
/// compute loop degrades to per-request "PJRT unavailable" errors.
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(Error::new(
            "PJRT unavailable: this build uses the in-tree xla stub \
             (offline environment without the xla_extension bindings); \
             artifact-backed models cannot execute",
        ))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(Error::new("PJRT unavailable: cannot compile artifacts"))
    }
}

/// Compiled executable handle (never constructed in the stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<T: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(Error::new("PJRT unavailable: cannot execute artifacts"))
    }
}

/// Device buffer handle (never constructed in the stub).
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(Error::new("PJRT unavailable: no device buffers"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(lit.element_count(), 4);
        let lit = lit.reshape(&[2, 2]).unwrap();
        assert_eq!(lit.element_count(), 4);
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(lit.to_vec::<i32>().is_err());
        let scalar = Literal::vec1(&[7i32]).reshape(&[]).unwrap();
        assert_eq!(scalar.element_count(), 1);
        assert!(Literal::vec1(&[1i32, 2]).reshape(&[3]).is_err());
    }

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("PJRT unavailable"));
    }
}
