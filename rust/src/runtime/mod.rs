//! Runtime services: the PJRT artifact executor and the crate's parallel
//! execution engine ([`pool`] — thread pool + coordinate sharding).
//!
//! The `xla` crate's `PjRtClient` is `Rc`-backed (not `Send`), so all PJRT
//! state lives on one dedicated **compute thread** ([`ComputeServer`]);
//! the rest of the system talks to it through a cloneable, `Send + Sync`
//! [`ComputeHandle`] (std-mpsc request queue + per-request std-mpsc reply
//! channels). This mirrors the paper's testbed anyway: a single
//! accelerator shared by all simulated workers, requests serialised at the
//! device. In this offline build the client is the [`xla_stub`] shim:
//! artifact execution reports "PJRT unavailable" at runtime while the
//! whole call surface still compiles and validates arguments.
//!
//! Artifacts are HLO **text** produced by `python/compile/aot.py`
//! (serialized protos from jax ≥ 0.5 are rejected by xla_extension 0.5.1 —
//! see `/opt/xla-example/README.md`), described by
//! `artifacts/manifest.json` ([`Manifest`]), and compiled on first use
//! (compilation cache keyed by artifact name).

mod compute;
mod manifest;
pub mod pool;
pub(crate) mod xla_stub;

pub use compute::{ArgValue, ComputeHandle, ComputeServer};
pub use manifest::{ArtifactSpec, Manifest, ModelSpec, TensorSpec};
pub use pool::{
    run_chunks, shard_slice, shard_slice_stateless, shard_zip, Parallelism, ThreadPool,
    MIN_COORDS_PER_SHARD,
};

/// Read a raw little-endian f32 binary file (initial parameter vectors).
pub fn read_f32_bin(path: impl AsRef<std::path::Path>) -> crate::Result<Vec<f32>> {
    let bytes = std::fs::read(path.as_ref())
        .map_err(|e| anyhow::anyhow!("reading {:?}: {e}", path.as_ref()))?;
    anyhow::ensure!(
        bytes.len() % 4 == 0,
        "f32 bin file {:?} has length {} not divisible by 4",
        path.as_ref(),
        bytes.len()
    );
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    #[test]
    fn f32_bin_roundtrip() {
        let dir = std::env::temp_dir().join("mb_runtime_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("x.f32bin");
        let values = [1.5f32, -2.25, 0.0, f32::MAX];
        let mut f = std::fs::File::create(&path).unwrap();
        for v in values {
            f.write_all(&v.to_le_bytes()).unwrap();
        }
        drop(f);
        assert_eq!(read_f32_bin(&path).unwrap(), values);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn f32_bin_bad_length_rejected() {
        let dir = std::env::temp_dir().join("mb_runtime_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.f32bin");
        std::fs::write(&path, [0u8; 5]).unwrap();
        assert!(read_f32_bin(&path).is_err());
        std::fs::remove_dir_all(dir).ok();
    }
}
