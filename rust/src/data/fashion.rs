//! FashionLike — a procedural Fashion-MNIST substitute.
//!
//! 28×28 grayscale, 10 classes, arbitrary train/test sizes. Each class has
//! a deterministic structured template (oriented stripes, checkers, filled
//! shapes, gradients — visually distinct "garment silhouettes"); a sample
//! is its class template under a random ±2px translation, amplitude jitter and
//! additive pixel noise. The task is easy enough for a small CNN/MLP to
//! exceed 90% top-1, yet noisy enough that per-step gradient variance is
//! non-trivial — which is precisely the regime the paper's Fig. 3
//! exercises (variance reduction from averaging more gradients).

use super::Batch;
use crate::util::rng::Rng64;

/// Image side length (28 × 28, like Fashion-MNIST).
pub const IMAGE_SIDE: usize = 28;
/// Flattened image dimension.
pub const IMAGE_DIM: usize = IMAGE_SIDE * IMAGE_SIDE;
/// Number of classes.
pub const NUM_CLASSES: usize = 10;

/// The generated dataset (materialised labels; images are generated on
/// demand from `(seed, split, index)` so a 60k-image train split costs no
/// memory up front).
#[derive(Debug, Clone)]
pub struct FashionLike {
    seed: u64,
    train_len: usize,
    test_len: usize,
    /// Per-sample additive noise std.
    noise: f32,
}

impl FashionLike {
    /// Paper-scale split: 60k train / 10k test.
    pub fn full(seed: u64) -> Self {
        Self::new(seed, 60_000, 10_000, 0.25)
    }

    /// Reduced split for CPU-budget runs.
    pub fn small(seed: u64) -> Self {
        Self::new(seed, 8_000, 2_000, 0.25)
    }

    pub fn new(seed: u64, train_len: usize, test_len: usize, noise: f32) -> Self {
        Self {
            seed,
            train_len,
            test_len,
            noise,
        }
    }

    pub fn train_len(&self) -> usize {
        self.train_len
    }

    pub fn test_len(&self) -> usize {
        self.test_len
    }

    /// Label of sample `index` in `split` (0 = train, 1 = test).
    /// Labels cycle through classes with a seeded permutation so every
    /// shard sees a balanced class mix.
    pub fn label(&self, split: u8, index: usize) -> usize {
        let mut rng = self.sample_rng(split, index);
        rng.gen_range_usize(NUM_CLASSES)
    }

    /// Render sample `index` of `split` into `out` (len `IMAGE_DIM`).
    /// Returns the label.
    pub fn render(&self, split: u8, index: usize, out: &mut [f32]) -> usize {
        assert_eq!(out.len(), IMAGE_DIM);
        let mut rng = self.sample_rng(split, index);
        let label = rng.gen_range_usize(NUM_CLASSES);
        let dx = rng.gen_range_i64(-2, 2) as i32;
        let dy = rng.gen_range_i64(-2, 2) as i32;
        let amp = rng.gen_range_f32(0.8, 1.2);
        for y in 0..IMAGE_SIDE {
            for x in 0..IMAGE_SIDE {
                let sx = (x as i32 - dx).rem_euclid(IMAGE_SIDE as i32) as usize;
                let sy = (y as i32 - dy).rem_euclid(IMAGE_SIDE as i32) as usize;
                let base = template(label, sx, sy);
                let noise = rng.gaussian() * self.noise;
                out[y * IMAGE_SIDE + x] = (amp * base + noise).clamp(0.0, 1.0);
            }
        }
        label
    }

    /// Fill a [`Batch`] with samples `indices` from `split`.
    pub fn fill_batch(&self, split: u8, indices: &[usize], batch: &mut Batch) {
        assert_eq!(batch.feature_dim, IMAGE_DIM);
        assert!(indices.len() <= batch.batch_size);
        for (row, &idx) in indices.iter().enumerate() {
            let label = {
                let dst = &mut batch.features[row * IMAGE_DIM..(row + 1) * IMAGE_DIM];
                self.render(split, idx, dst)
            };
            batch.labels[row] = label as i32;
        }
    }

    fn sample_rng(&self, split: u8, index: usize) -> Rng64 {
        // splitmix-style mixing of (seed, split, index).
        let mut z = self
            .seed
            .wrapping_add(0x9E3779B97F4A7C15u64.wrapping_mul(index as u64 + 1))
            .wrapping_add((split as u64) << 32);
        Rng64::seed_from_u64(crate::util::rng::splitmix64(&mut z))
    }
}

/// Deterministic class template, value in [0, 1].
fn template(class: usize, x: usize, y: usize) -> f32 {
    let xf = x as f32 / (IMAGE_SIDE - 1) as f32; // 0..1
    let yf = y as f32 / (IMAGE_SIDE - 1) as f32;
    let cx = xf - 0.5;
    let cy = yf - 0.5;
    match class {
        // Horizontal stripes (coarse).
        0 => ((yf * 4.0 * std::f32::consts::PI).sin() > 0.0) as u8 as f32,
        // Vertical stripes (fine).
        1 => ((xf * 8.0 * std::f32::consts::PI).sin() > 0.0) as u8 as f32,
        // Checkerboard.
        2 => (((x / 4) + (y / 4)) % 2) as f32,
        // Filled disk ("plate").
        3 => ((cx * cx + cy * cy).sqrt() < 0.32) as u8 as f32,
        // Ring ("bag handle").
        4 => {
            let r = (cx * cx + cy * cy).sqrt();
            (r > 0.22 && r < 0.40) as u8 as f32
        }
        // Diagonal gradient.
        5 => (xf + yf) * 0.5,
        // "Trouser" twin vertical bars.
        6 => ((xf > 0.2 && xf < 0.4) || (xf > 0.6 && xf < 0.8)) as u8 as f32,
        // "Pullover" T-shape: wide top band + central column.
        7 => ((yf < 0.35) || (xf > 0.35 && xf < 0.65)) as u8 as f32,
        // Diagonal stripes.
        8 => (((xf - yf) * 6.0 * std::f32::consts::PI).sin() > 0.0) as u8 as f32,
        // Centered bright square ("ankle boot" block).
        _ => (cx.abs() < 0.25 && cy.abs() < 0.25) as u8 as f32,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_rendering() {
        let ds = FashionLike::small(42);
        let mut a = vec![0.0; IMAGE_DIM];
        let mut b = vec![0.0; IMAGE_DIM];
        let la = ds.render(0, 17, &mut a);
        let lb = ds.render(0, 17, &mut b);
        assert_eq!(la, lb);
        assert_eq!(a, b);
        assert_eq!(la, ds.label(0, 17));
    }

    #[test]
    fn train_and_test_differ() {
        let ds = FashionLike::small(42);
        let mut a = vec![0.0; IMAGE_DIM];
        let mut b = vec![0.0; IMAGE_DIM];
        ds.render(0, 5, &mut a);
        ds.render(1, 5, &mut b);
        assert_ne!(a, b);
    }

    #[test]
    fn pixels_in_unit_range_and_classes_balanced() {
        let ds = FashionLike::small(1);
        let mut img = vec![0.0; IMAGE_DIM];
        let mut counts = [0usize; NUM_CLASSES];
        for i in 0..500 {
            let l = ds.render(0, i, &mut img);
            counts[l] += 1;
            assert!(img.iter().all(|&p| (0.0..=1.0).contains(&p)));
        }
        // Every class appears a reasonable number of times out of 500.
        for (c, &k) in counts.iter().enumerate() {
            assert!(k > 20, "class {c} only appeared {k} times");
        }
    }

    #[test]
    fn classes_are_separable_by_template() {
        // Mean intra-class pixel distance should be well below mean
        // inter-class distance — otherwise the task is unlearnable.
        let ds = FashionLike::new(3, 1000, 100, 0.2);
        let mut imgs: Vec<(usize, Vec<f32>)> = Vec::new();
        let mut img = vec![0.0; IMAGE_DIM];
        for i in 0..120 {
            let l = ds.render(0, i, &mut img);
            imgs.push((l, img.clone()));
        }
        let (mut intra, mut inter) = ((0.0f64, 0u32), (0.0f64, 0u32));
        for i in 0..imgs.len() {
            for j in (i + 1)..imgs.len() {
                let d = crate::tensor::sq_distance(&imgs[i].1, &imgs[j].1) as f64;
                if imgs[i].0 == imgs[j].0 {
                    intra.0 += d;
                    intra.1 += 1;
                } else {
                    inter.0 += d;
                    inter.1 += 1;
                }
            }
        }
        let intra_mean = intra.0 / intra.1.max(1) as f64;
        let inter_mean = inter.0 / inter.1.max(1) as f64;
        assert!(
            inter_mean > 1.4 * intra_mean,
            "inter {inter_mean} vs intra {intra_mean}"
        );
    }

    #[test]
    fn fill_batch_writes_rows_and_labels() {
        let ds = FashionLike::small(9);
        let mut batch = Batch::new(4, IMAGE_DIM);
        ds.fill_batch(0, &[0, 1, 2, 3], &mut batch);
        for r in 0..4 {
            assert_eq!(batch.labels[r], ds.label(0, r) as i32);
            assert!(batch.feature_row(r).iter().any(|&p| p > 0.0));
        }
    }
}
