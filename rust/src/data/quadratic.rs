//! QuadraticProblem — a synthetic linear least-squares workload with a
//! closed-form optimum and true gradient.
//!
//! Worker `i` holds a shard of rows of a design "matrix" generated on the
//! fly; the loss is `Q(x) = E‖a·x − y‖²/2` with `y = a·x* + ε`. Because
//! `∇Q(x) = Σ a(a·x − y)/B` is exact and cheap in pure rust, this workload
//! lets every convergence / resilience / slowdown property be tested
//! without PJRT artifacts, at any dimension, in milliseconds. Also the
//! substrate for the `(α,f)`-cone empirical check (the true gradient `g`
//! is known, so ⟨E GAR, g⟩ is measurable).

use crate::runtime::{shard_slice_stateless, Parallelism, MIN_COORDS_PER_SHARD};
use crate::util::{splitmix64, Rng64};

/// The shared problem definition (same on every worker; shards differ by
/// sample index).
#[derive(Debug, Clone)]
pub struct QuadraticProblem {
    dim: usize,
    /// Ground-truth parameters x*.
    optimum: Vec<f32>,
    /// Label noise std (the gradient-variance knob).
    noise: f32,
    seed: u64,
}

impl QuadraticProblem {
    pub fn new(dim: usize, noise: f32, seed: u64) -> Self {
        let mut rng = Rng64::seed_from_u64(seed ^ 0xA5A5_5A5A);
        let optimum = (0..dim).map(|_| rng.gen_range_f32(-1.0, 1.0)).collect();
        Self {
            dim,
            optimum,
            noise,
            seed,
        }
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn optimum(&self) -> &[f32] {
        &self.optimum
    }

    /// The exact full gradient `∇Q(x) = x − x*` (for the isotropic
    /// quadratic `Q(x) = ‖x − x*‖²/2`, which is what the sampled
    /// minibatch gradient estimates in expectation).
    pub fn true_gradient(&self, params: &[f32]) -> Vec<f32> {
        params
            .iter()
            .zip(&self.optimum)
            .map(|(p, o)| p - o)
            .collect()
    }

    /// The loss `Q(x) = ‖x − x*‖²/(2d)` (normalised by dimension so values
    /// are comparable across `d`).
    pub fn loss(&self, params: &[f32]) -> f32 {
        let sq = crate::tensor::sq_distance(params, &self.optimum);
        sq / (2.0 * self.dim as f32)
    }

    /// A stochastic minibatch gradient: the true gradient plus i.i.d.
    /// N(0, noise²/b) perturbation per coordinate — exactly the unbiased,
    /// bounded-variance estimator model of the paper's §II-A, with the
    /// minibatch size `b` controlling the variance like Equation 3.
    /// Allocating sequential wrapper over
    /// [`stochastic_gradient_into`](Self::stochastic_gradient_into).
    pub fn stochastic_gradient(
        &self,
        params: &[f32],
        batch_size: usize,
        sample_seed: u64,
    ) -> Vec<f32> {
        let mut out = Vec::new();
        self.stochastic_gradient_into(
            params,
            batch_size,
            sample_seed,
            &Parallelism::sequential(),
            &mut out,
        );
        out
    }

    /// Fill `out` with a stochastic minibatch gradient, coordinate-sharded
    /// across `par` (`runtime::shard_slice`). The noise is a *pure
    /// function of (problem seed, sample seed, coordinate)* — not a
    /// sequential RNG stream — so the result is bit-identical for every
    /// thread count and shard layout (the same contract the GAR passes
    /// keep; see `runtime::pool`).
    pub fn stochastic_gradient_into(
        &self,
        params: &[f32],
        batch_size: usize,
        sample_seed: u64,
        par: &Parallelism,
        out: &mut Vec<f32>,
    ) {
        out.clear();
        out.resize(self.dim, 0.0);
        shard_slice_stateless(par, out, MIN_COORDS_PER_SHARD, |offset, range| {
            self.stochastic_gradient_range(params, batch_size, sample_seed, offset, range);
        });
    }

    /// Fill `out` with coordinates `offset .. offset + out.len()` of the
    /// same stochastic gradient [`stochastic_gradient_into`] computes —
    /// the per-coordinate formula is a pure function of
    /// `(problem seed, sample seed, coordinate)`, so any partition of the
    /// coordinate space (a `shard_slice` fan-out, or the time-sliced
    /// drive's incremental `StepBody` chunks) is bit-identical to the
    /// one-shot computation.
    ///
    /// [`stochastic_gradient_into`]: Self::stochastic_gradient_into
    pub fn stochastic_gradient_range(
        &self,
        params: &[f32],
        batch_size: usize,
        sample_seed: u64,
        offset: usize,
        out: &mut [f32],
    ) {
        assert!(batch_size >= 1);
        assert_eq!(
            params.len(),
            self.dim,
            "stochastic_gradient: params have wrong dimension"
        );
        assert!(
            offset + out.len() <= self.dim,
            "stochastic_gradient_range: range {}..{} out of 0..{}",
            offset,
            offset + out.len(),
            self.dim
        );
        let scale = self.noise / (batch_size as f32).sqrt();
        let base = self.seed ^ sample_seed.wrapping_mul(0x9E37_79B9);
        let optimum = &self.optimum;
        for (k, v) in out.iter_mut().enumerate() {
            let j = offset + k;
            *v = params[j] - optimum[j] + scale * gaussian_at(base, j as u64);
        }
    }

    /// Per-coordinate gradient-noise std for a given batch size (σ of the
    /// paper's Lemma 1: `E‖G − g‖² = d·σ²`).
    pub fn sigma(&self, batch_size: usize) -> f32 {
        self.noise / (batch_size as f32).sqrt()
    }
}

/// One standard-normal draw as a pure function of `(seed, index)`: two
/// splitmix64 outputs → the same 24-bit-uniform Box–Muller conversion as
/// [`Rng64::gaussian`]. Counter-based, so any coordinate's noise can be
/// computed by any shard without a shared stream.
#[inline]
fn gaussian_at(seed: u64, index: u64) -> f32 {
    let mut s = seed ^ index.wrapping_mul(0xD1B5_4A32_D192_ED03);
    let a = splitmix64(&mut s);
    let b = splitmix64(&mut s);
    let to_unit = |u: u64| ((u >> 40) as f32) * (1.0 / (1u64 << 24) as f32);
    let u1 = to_unit(a).max(f32::EPSILON);
    let u2 = to_unit(b);
    (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gradient_vanishes_at_optimum() {
        let p = QuadraticProblem::new(50, 0.1, 7);
        let g = p.true_gradient(p.optimum());
        assert!(crate::tensor::l2_norm(&g) < 1e-6);
        assert!(p.loss(p.optimum()) < 1e-9);
    }

    #[test]
    fn stochastic_gradient_is_unbiased() {
        let p = QuadraticProblem::new(20, 0.5, 3);
        let x = vec![0.0f32; 20];
        let true_g = p.true_gradient(&x);
        let mut acc = vec![0.0f32; 20];
        let reps = 2000;
        for s in 0..reps {
            let g = p.stochastic_gradient(&x, 4, s);
            crate::tensor::add_assign(&mut acc, &g);
        }
        crate::tensor::scale(&mut acc, 1.0 / reps as f32);
        let err = crate::tensor::sq_distance(&acc, &true_g).sqrt();
        // Mean of 2000 draws with σ=0.25/coord: err ≈ 0.25·√20/√2000 ≈ 0.025.
        assert!(err < 0.1, "bias estimate {err}");
    }

    #[test]
    fn variance_shrinks_with_batch_size() {
        let p = QuadraticProblem::new(100, 1.0, 11);
        let x = vec![0.0f32; 100];
        let true_g = p.true_gradient(&x);
        let spread = |b: usize| -> f32 {
            (0..50)
                .map(|s| crate::tensor::sq_distance(&p.stochastic_gradient(&x, b, s), &true_g))
                .sum::<f32>()
                / 50.0
        };
        let v1 = spread(1);
        let v16 = spread(16);
        assert!(
            v16 < v1 / 8.0,
            "variance must shrink ≈16×: v1={v1} v16={v16}"
        );
        assert!((p.sigma(16) - 0.25).abs() < 1e-6);
    }

    #[test]
    fn deterministic_per_seed() {
        let p = QuadraticProblem::new(10, 0.3, 5);
        let x = vec![0.1f32; 10];
        assert_eq!(
            p.stochastic_gradient(&x, 2, 9),
            p.stochastic_gradient(&x, 2, 9)
        );
        assert_ne!(
            p.stochastic_gradient(&x, 2, 9),
            p.stochastic_gradient(&x, 2, 10)
        );
    }

    #[test]
    fn sharded_gradient_bit_identical_across_thread_counts() {
        // Large enough to split into several MIN_COORDS_PER_SHARD ranges.
        let d = 4 * MIN_COORDS_PER_SHARD + 129;
        let p = QuadraticProblem::new(d, 0.7, 13);
        let x: Vec<f32> = (0..d).map(|j| (j as f32 * 0.001).sin()).collect();
        let reference = p.stochastic_gradient(&x, 4, 21);
        for threads in [2usize, 3, 4] {
            let par = Parallelism::new(threads);
            let mut out = Vec::new();
            p.stochastic_gradient_into(&x, 4, 21, &par, &mut out);
            assert_eq!(reference, out, "threads={threads}");
        }
    }

    #[test]
    fn range_chunks_reassemble_the_full_gradient_bit_identically() {
        // Any chunking of the coordinate space (here: ragged chunks, the
        // StepBody drive pattern) must equal the one-shot gradient.
        let d = 1_037;
        let p = QuadraticProblem::new(d, 0.6, 23);
        let x: Vec<f32> = (0..d).map(|j| (j as f32 * 0.01).cos()).collect();
        let reference = p.stochastic_gradient(&x, 3, 77);
        let mut out = vec![0.0f32; d];
        let mut offset = 0;
        for (step, chunk) in [129usize, 1, 500, 300, 107].iter().enumerate() {
            let end = (offset + chunk).min(d);
            p.stochastic_gradient_range(&x, 3, 77, offset, &mut out[offset..end]);
            offset = end;
            assert!(offset <= d, "step {step}");
        }
        p.stochastic_gradient_range(&x, 3, 77, offset, &mut out[offset..]);
        assert_eq!(reference, out);
    }

    #[test]
    fn counter_noise_has_unit_moments() {
        let draws: Vec<f32> = (0..50_000).map(|j| gaussian_at(0xFEED, j)).collect();
        let mean = draws.iter().sum::<f32>() / draws.len() as f32;
        let var = draws.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>()
            / (draws.len() - 1) as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }
}
