//! QuadraticProblem — a synthetic linear least-squares workload with a
//! closed-form optimum and true gradient.
//!
//! Worker `i` holds a shard of rows of a design "matrix" generated on the
//! fly; the loss is `Q(x) = E‖a·x − y‖²/2` with `y = a·x* + ε`. Because
//! `∇Q(x) = Σ a(a·x − y)/B` is exact and cheap in pure rust, this workload
//! lets every convergence / resilience / slowdown property be tested
//! without PJRT artifacts, at any dimension, in milliseconds. Also the
//! substrate for the `(α,f)`-cone empirical check (the true gradient `g`
//! is known, so ⟨E GAR, g⟩ is measurable).

use crate::util::Rng64;

/// The shared problem definition (same on every worker; shards differ by
/// sample index).
#[derive(Debug, Clone)]
pub struct QuadraticProblem {
    dim: usize,
    /// Ground-truth parameters x*.
    optimum: Vec<f32>,
    /// Label noise std (the gradient-variance knob).
    noise: f32,
    seed: u64,
}

impl QuadraticProblem {
    pub fn new(dim: usize, noise: f32, seed: u64) -> Self {
        let mut rng = Rng64::seed_from_u64(seed ^ 0xA5A5_5A5A);
        let optimum = (0..dim).map(|_| rng.gen_range_f32(-1.0, 1.0)).collect();
        Self {
            dim,
            optimum,
            noise,
            seed,
        }
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn optimum(&self) -> &[f32] {
        &self.optimum
    }

    /// The exact full gradient `∇Q(x) = x − x*` (for the isotropic
    /// quadratic `Q(x) = ‖x − x*‖²/2`, which is what the sampled
    /// minibatch gradient estimates in expectation).
    pub fn true_gradient(&self, params: &[f32]) -> Vec<f32> {
        params
            .iter()
            .zip(&self.optimum)
            .map(|(p, o)| p - o)
            .collect()
    }

    /// The loss `Q(x) = ‖x − x*‖²/(2d)` (normalised by dimension so values
    /// are comparable across `d`).
    pub fn loss(&self, params: &[f32]) -> f32 {
        let sq = crate::tensor::sq_distance(params, &self.optimum);
        sq / (2.0 * self.dim as f32)
    }

    /// A stochastic minibatch gradient: the true gradient plus i.i.d.
    /// N(0, noise²/b) perturbation per coordinate — exactly the unbiased,
    /// bounded-variance estimator model of the paper's §II-A, with the
    /// minibatch size `b` controlling the variance like Equation 3.
    pub fn stochastic_gradient(
        &self,
        params: &[f32],
        batch_size: usize,
        sample_seed: u64,
    ) -> Vec<f32> {
        assert!(batch_size >= 1);
        let mut rng = Rng64::seed_from_u64(self.seed ^ sample_seed.wrapping_mul(0x9E37_79B9));
        let scale = self.noise / (batch_size as f32).sqrt();
        let mut g = self.true_gradient(params);
        for v in g.iter_mut() {
            *v += scale * rng.gaussian();
        }
        g
    }

    /// Per-coordinate gradient-noise std for a given batch size (σ of the
    /// paper's Lemma 1: `E‖G − g‖² = d·σ²`).
    pub fn sigma(&self, batch_size: usize) -> f32 {
        self.noise / (batch_size as f32).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gradient_vanishes_at_optimum() {
        let p = QuadraticProblem::new(50, 0.1, 7);
        let g = p.true_gradient(p.optimum());
        assert!(crate::tensor::l2_norm(&g) < 1e-6);
        assert!(p.loss(p.optimum()) < 1e-9);
    }

    #[test]
    fn stochastic_gradient_is_unbiased() {
        let p = QuadraticProblem::new(20, 0.5, 3);
        let x = vec![0.0f32; 20];
        let true_g = p.true_gradient(&x);
        let mut acc = vec![0.0f32; 20];
        let reps = 2000;
        for s in 0..reps {
            let g = p.stochastic_gradient(&x, 4, s);
            crate::tensor::add_assign(&mut acc, &g);
        }
        crate::tensor::scale(&mut acc, 1.0 / reps as f32);
        let err = crate::tensor::sq_distance(&acc, &true_g).sqrt();
        // Mean of 2000 draws with σ=0.25/coord: err ≈ 0.25·√20/√2000 ≈ 0.025.
        assert!(err < 0.1, "bias estimate {err}");
    }

    #[test]
    fn variance_shrinks_with_batch_size() {
        let p = QuadraticProblem::new(100, 1.0, 11);
        let x = vec![0.0f32; 100];
        let true_g = p.true_gradient(&x);
        let spread = |b: usize| -> f32 {
            (0..50)
                .map(|s| crate::tensor::sq_distance(&p.stochastic_gradient(&x, b, s), &true_g))
                .sum::<f32>()
                / 50.0
        };
        let v1 = spread(1);
        let v16 = spread(16);
        assert!(
            v16 < v1 / 8.0,
            "variance must shrink ≈16×: v1={v1} v16={v16}"
        );
        assert!((p.sigma(16) - 0.25).abs() < 1e-6);
    }

    #[test]
    fn deterministic_per_seed() {
        let p = QuadraticProblem::new(10, 0.3, 5);
        let x = vec![0.1f32; 10];
        assert_eq!(
            p.stochastic_gradient(&x, 2, 9),
            p.stochastic_gradient(&x, 2, 9)
        );
        assert_ne!(
            p.stochastic_gradient(&x, 2, 9),
            p.stochastic_gradient(&x, 2, 10)
        );
    }
}
