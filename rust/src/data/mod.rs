//! Synthetic datasets and sharding.
//!
//! The evaluation environment has no dataset downloads, so every workload
//! is generated procedurally with seeded RNGs (DESIGN.md §Substitutions):
//!
//! * [`FashionLike`] — the Fashion-MNIST substitute for the Fig. 3
//!   experiment: 10 classes of 28×28 grayscale "garment-like" images
//!   (structured class templates + per-sample deformation + noise).
//! * [`QuadraticProblem`] — a rust-native linear least-squares task whose
//!   exact minimiser and true gradient are known in closed form; the
//!   workhorse of the unit/integration tests and the cone/slowdown
//!   ablations (no PJRT required).
//! * [`TokenStream`] — a seeded bigram language for the end-to-end
//!   transformer driver.
//!
//! Sharding follows the parameter-server model: worker `i` of `k` sees the
//! samples `{ j : j ≡ i mod k }` of the training split.

mod fashion;
mod quadratic;
mod tokens;

pub use fashion::{FashionLike, IMAGE_DIM, NUM_CLASSES};
pub use quadratic::QuadraticProblem;
pub use tokens::TokenStream;

/// A contiguous batch of flattened examples.
#[derive(Debug, Clone)]
pub struct Batch {
    /// `batch_size × feature_dim`, row-major.
    pub features: Vec<f32>,
    /// One label per row (class index or next-token id).
    pub labels: Vec<i32>,
    pub batch_size: usize,
    pub feature_dim: usize,
}

impl Batch {
    pub fn new(batch_size: usize, feature_dim: usize) -> Self {
        Self {
            features: vec![0.0; batch_size * feature_dim],
            labels: vec![0; batch_size],
            batch_size,
            feature_dim,
        }
    }

    pub fn feature_row(&self, i: usize) -> &[f32] {
        &self.features[i * self.feature_dim..(i + 1) * self.feature_dim]
    }
}

/// Deterministic shard membership: which global indices worker `shard` of
/// `num_shards` owns within a dataset of `len` samples.
pub fn shard_indices(len: usize, shard: usize, num_shards: usize) -> impl Iterator<Item = usize> {
    assert!(num_shards > 0 && shard < num_shards);
    (shard..len).step_by(num_shards)
}

/// Size of a shard produced by [`shard_indices`].
pub fn shard_len(len: usize, shard: usize, num_shards: usize) -> usize {
    if shard >= len % num_shards {
        len / num_shards
    } else {
        len / num_shards + 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shards_partition_the_dataset() {
        let len = 103;
        let k = 7;
        let mut seen = vec![false; len];
        for s in 0..k {
            let idx: Vec<usize> = shard_indices(len, s, k).collect();
            assert_eq!(idx.len(), shard_len(len, s, k));
            for i in idx {
                assert!(!seen[i], "index {i} assigned twice");
                seen[i] = true;
            }
        }
        assert!(seen.into_iter().all(|b| b));
    }

    #[test]
    fn batch_views() {
        let mut b = Batch::new(2, 3);
        b.features[3..6].copy_from_slice(&[1.0, 2.0, 3.0]);
        assert_eq!(b.feature_row(1), &[1.0, 2.0, 3.0]);
        assert_eq!(b.feature_row(0), &[0.0, 0.0, 0.0]);
    }
}
