//! TokenStream — a seeded synthetic "language" for the end-to-end
//! transformer driver (`examples/e2e_train.rs`).
//!
//! Tokens are drawn from a sparse random bigram chain: each token has a
//! small set of likely successors, so a next-token predictor has real
//! signal (cross-entropy well below `ln(vocab)`) while the entropy floor
//! keeps the task non-degenerate. Deterministic in `(seed, position)`
//! via jump-ahead hashing, so shards/batches can be sliced anywhere
//! without replaying the chain.

use crate::util::Rng64;

/// Synthetic bigram corpus.
#[derive(Debug, Clone)]
pub struct TokenStream {
    vocab: usize,
    /// For each token, `fanout` likely successors (probability mass
    /// `1 − eps` spread uniformly among them; `eps` to the full vocab).
    successors: Vec<Vec<u32>>,
    eps: f64,
    seed: u64,
}

impl TokenStream {
    pub fn new(vocab: usize, fanout: usize, seed: u64) -> Self {
        assert!(vocab >= 2 && fanout >= 1 && fanout <= vocab);
        let mut rng = Rng64::seed_from_u64(seed ^ 0x7065_6e63_696c);
        let successors = (0..vocab)
            .map(|_| (0..fanout).map(|_| rng.gen_range_usize(vocab) as u32).collect())
            .collect();
        Self {
            vocab,
            successors,
            eps: 0.05,
            seed,
        }
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// Generate a sequence of `len + 1` tokens starting from a position
    /// hash, returning `(inputs[len], targets[len])` for next-token
    /// prediction.
    pub fn sequence(&self, stream_id: u64, len: usize) -> (Vec<i32>, Vec<i32>) {
        let mut rng =
            Rng64::seed_from_u64(self.seed ^ stream_id.wrapping_mul(0x9E3779B97F4A7C15));
        let mut tokens = Vec::with_capacity(len + 1);
        tokens.push(rng.gen_range_usize(self.vocab) as i32);
        for _ in 0..len {
            let prev = *tokens.last().unwrap() as usize;
            let next = if rng.gen_bool(self.eps) {
                rng.gen_range_usize(self.vocab) as u32
            } else {
                let succ = &self.successors[prev];
                succ[rng.gen_range_usize(succ.len())]
            };
            tokens.push(next as i32);
        }
        let inputs = tokens[..len].to_vec();
        let targets = tokens[1..].to_vec();
        (inputs, targets)
    }

    /// Theoretical cross-entropy floor (nats) of the chain — the loss a
    /// perfect model converges to. Used by the e2e driver to sanity-check
    /// the loss curve.
    pub fn entropy_floor(&self, fanout: usize) -> f32 {
        let v = self.vocab as f64;
        let f = fanout as f64;
        let p_likely = (1.0 - self.eps) / f + self.eps / v;
        let h = -(1.0 - self.eps) * p_likely.ln() - self.eps * (self.eps / v).ln();
        h as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_sequences() {
        let ts = TokenStream::new(64, 4, 9);
        let (a, ta) = ts.sequence(3, 32);
        let (b, tb) = ts.sequence(3, 32);
        assert_eq!(a, b);
        assert_eq!(ta, tb);
        let (c, _) = ts.sequence(4, 32);
        assert_ne!(a, c);
    }

    #[test]
    fn targets_are_shifted_inputs() {
        let ts = TokenStream::new(32, 2, 1);
        let (inp, tgt) = ts.sequence(0, 16);
        assert_eq!(inp.len(), 16);
        assert_eq!(tgt.len(), 16);
        assert_eq!(&inp[1..], &tgt[..15]);
    }

    #[test]
    fn bigram_structure_is_learnable() {
        // Empirical successor distribution must be concentrated: the top-4
        // successors of a token should carry most of the mass.
        let ts = TokenStream::new(32, 3, 5);
        let mut counts = vec![vec![0u32; 32]; 32];
        for sid in 0..200 {
            let (inp, tgt) = ts.sequence(sid, 64);
            for (a, b) in inp.iter().zip(&tgt) {
                counts[*a as usize][*b as usize] += 1;
            }
        }
        // Aggregate: fraction of transitions landing in the declared
        // successor sets.
        let mut hits = 0u32;
        let mut total = 0u32;
        for (a, row) in counts.iter().enumerate() {
            for (b, &c) in row.iter().enumerate() {
                total += c;
                if ts.successors[a].contains(&(b as u32)) {
                    hits += c;
                }
            }
        }
        let frac = hits as f64 / total as f64;
        assert!(frac > 0.85, "successor mass {frac}");
    }

    #[test]
    fn entropy_floor_below_uniform() {
        let ts = TokenStream::new(256, 4, 0);
        let floor = ts.entropy_floor(4);
        assert!(floor < (256f32).ln());
        assert!(floor > 0.0);
    }

    #[test]
    fn tokens_within_vocab() {
        let ts = TokenStream::new(16, 2, 2);
        let (inp, tgt) = ts.sequence(7, 100);
        assert!(inp.iter().chain(&tgt).all(|&t| (0..16).contains(&t)));
    }
}
