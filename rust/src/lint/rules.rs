//! The rule catalog: six repo-specific invariants, each matched at the
//! token/line level against the classified [`Line`](super::Line)s the
//! scanner produces. Every rule documents *why* it exists — the invariant
//! it guards is what the paper's resilience claims rest on, not style.

use super::{annotated, escape_allows, Finding, Line};

/// One catalog entry, surfaced by `multibulyan lint --list`.
pub struct RuleInfo {
    pub id: &'static str,
    pub summary: &'static str,
    /// How to annotate a legitimate exception.
    pub escape: &'static str,
}

pub const UNSAFE_BLOCK: &str = "unsafe-block";
pub const WALL_CLOCK: &str = "wall-clock";
pub const THREAD_SPAWN: &str = "thread-spawn";
pub const HASH_ITER: &str = "hash-iter";
pub const FLOAT_REDUCE: &str = "float-reduce";
pub const ALLOW_SYNTAX: &str = "allow-syntax";

pub const RULES: &[RuleInfo] = &[
    RuleInfo {
        id: UNSAFE_BLOCK,
        summary: "unsafe blocks only in audited modules, each with a // SAFETY: argument",
        escape: "// SAFETY: <disjointness/lifetime argument> within 15 lines above",
    },
    RuleInfo {
        id: WALL_CLOCK,
        summary: "no std::time::Instant/SystemTime in virtual-time code",
        escape: "// wall-clock: <why this site really measures wall time> within 3 lines",
    },
    RuleInfo {
        id: THREAD_SPAWN,
        summary: "no thread::spawn outside runtime/ and transport/ — parallelism goes through the pool",
        escape: "move the work onto the pool, or lint:allow with a reason",
    },
    RuleInfo {
        id: HASH_ITER,
        summary: "no HashMap/HashSet iteration in deterministic paths (hash order breaks bit-identity)",
        escape: "use BTreeMap/BTreeSet, or // LINT: sorted -- <why order cannot leak> within 3 lines",
    },
    RuleInfo {
        id: FLOAT_REDUCE,
        summary: "no bare .sum()/.fold( float reduction over gradient-length buffers outside the pairwise tree",
        escape: "use gar::pairwise::reduce_partials_tree, or // LINT: reduce-ok -- <why order-safe> within 3 lines",
    },
    RuleInfo {
        id: ALLOW_SYNTAX,
        summary: "every lint:allow(<rule>) escape names a real rule and carries a ` -- <reason>` justification",
        escape: "none — fix the escape",
    },
];

/// The only modules allowed to contain `unsafe` blocks (each audited:
/// raw-pointer shard fan-outs with disjointness proofs).
pub const UNSAFE_MODULES: &[&str] = &[
    "rust/src/runtime/pool.rs",
    "rust/src/coordinator/core.rs",
    "rust/src/gar/pairwise.rs",
    "rust/src/transport/pooled.rs",
];

/// Directory prefixes where `thread::spawn` / `thread::Builder` are
/// legitimate — everywhere else parallelism must go through the pool.
pub const SPAWN_MODULES: &[&str] = &["rust/src/runtime/", "rust/src/transport/"];

/// Directory prefixes where the float-reduce rule applies: the numeric
/// paths where a gradient-length `.sum()` would be order-sensitive.
pub const FLOAT_REDUCE_SCOPE: &[&str] = &[
    "rust/src/gar/",
    "rust/src/tensor/",
    "rust/src/coordinator/",
    "rust/src/training/",
    "rust/src/transport/",
    "rust/src/worker/",
    "rust/src/attacks/",
    "rust/src/metrics/",
    "rust/src/data/",
    "rust/src/codec/",
];

/// Files exempt from float-reduce: the designated reducers themselves.
pub const FLOAT_REDUCE_EXEMPT: &[&str] =
    &["rust/src/gar/pairwise.rs", "rust/src/tensor/stats.rs"];

/// How far above a line a `// SAFETY:` comment may sit (a multi-line
/// safety argument above a fan-out call).
pub const SAFETY_WINDOW: usize = 15;
/// Window for the short annotations (`wall-clock:`, `LINT: sorted`,
/// `LINT: reduce-ok`).
pub const ANNOTATION_WINDOW: usize = 3;

/// Word-boundary containment: `needle` appears in `hay` not embedded in a
/// larger identifier (so `Instant` does not fire on `Instantiate`).
fn contains_word(hay: &str, needle: &str) -> bool {
    let mut start = 0;
    while let Some(pos) = hay[start..].find(needle) {
        let at = start + pos;
        let before_ok = at == 0
            || hay[..at]
                .chars()
                .next_back()
                .is_none_or(|c| !(c.is_ascii_alphanumeric() || c == '_'));
        let after_ok = hay[at + needle.len()..]
            .chars()
            .next()
            .is_none_or(|c| !(c.is_ascii_alphanumeric() || c == '_'));
        if before_ok && after_ok {
            return true;
        }
        start = at + needle.len();
    }
    false
}

/// Does this code line perform a float `.sum()`? Flags bare `.sum()` and
/// float turbofishes (`.sum::<f32>()`); skips integer turbofishes
/// (`.sum::<usize>()` etc.), whose order cannot affect the result.
fn has_float_sum(code: &str) -> bool {
    let mut start = 0;
    while let Some(pos) = code[start..].find(".sum") {
        let at = start + pos;
        let rest = &code[at + ".sum".len()..];
        if let Some(tf) = rest.strip_prefix("::<") {
            let t = tf.trim_start();
            if !(t.starts_with('u') || t.starts_with('i')) {
                return true;
            }
        } else if rest.starts_with('(') {
            return true;
        }
        start = at + ".sum".len();
    }
    false
}

fn emit(
    findings: &mut Vec<Finding>,
    lines: &[Line],
    rel: &str,
    idx: usize,
    rule: &'static str,
    message: String,
) {
    if !escape_allows(lines, idx, rule) {
        findings.push(Finding {
            file: rel.to_string(),
            line: idx + 1,
            rule,
            message,
        });
    }
}

/// Run every rule over a classified file; returns the findings.
pub fn apply(rel: &str, lines: &[Line]) -> Vec<Finding> {
    let mut findings = Vec::new();
    check_unsafe_block(rel, lines, &mut findings);
    check_wall_clock(rel, lines, &mut findings);
    check_thread_spawn(rel, lines, &mut findings);
    check_hash_iter(rel, lines, &mut findings);
    check_float_reduce(rel, lines, &mut findings);
    check_allow_syntax(rel, lines, &mut findings);
    findings
}

/// Rule `unsafe-block`: every `unsafe` keyword in code (tests included —
/// test unsafe aliases just as hard) must sit in a whitelisted module AND
/// carry a `// SAFETY:` argument within [`SAFETY_WINDOW`] lines above.
fn check_unsafe_block(rel: &str, lines: &[Line], findings: &mut Vec<Finding>) {
    for (idx, line) in lines.iter().enumerate() {
        if !contains_word(&line.code, "unsafe") {
            continue;
        }
        if !UNSAFE_MODULES.contains(&rel) {
            emit(
                findings,
                lines,
                rel,
                idx,
                UNSAFE_BLOCK,
                format!(
                    "`unsafe` outside the audited modules ({}); move the raw-pointer work into \
                     runtime/pool.rs or annotate",
                    UNSAFE_MODULES.join(", ")
                ),
            );
        } else if !annotated(lines, idx, "SAFETY:", SAFETY_WINDOW) {
            emit(
                findings,
                lines,
                rel,
                idx,
                UNSAFE_BLOCK,
                "`unsafe` without a // SAFETY: argument on the preceding lines".to_string(),
            );
        }
    }
}

/// Rule `wall-clock`: `Instant` / `SystemTime` in non-test library code
/// must carry a per-site `// wall-clock: <reason>` annotation. The pooled
/// drive runs on a virtual clock; a stray `Instant::now()` there silently
/// reintroduces scheduling nondeterminism, so even `metrics/timing.rs`
/// (whose whole job is wall time) annotates each site instead of getting
/// a blanket module exemption.
fn check_wall_clock(rel: &str, lines: &[Line], findings: &mut Vec<Finding>) {
    if !rel.starts_with("rust/src/") {
        return;
    }
    for (idx, line) in lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        let hit = contains_word(&line.code, "Instant") || contains_word(&line.code, "SystemTime");
        if hit && !annotated(lines, idx, "wall-clock:", ANNOTATION_WINDOW) {
            emit(
                findings,
                lines,
                rel,
                idx,
                WALL_CLOCK,
                "wall-clock type in library code without a // wall-clock: <reason> annotation \
                 (virtual-time paths must not read real time)"
                    .to_string(),
            );
        }
    }
}

/// Rule `thread-spawn`: `thread::spawn` / `thread::Builder` only under
/// `runtime/` and `transport/`. Everything else uses the pool, so thread
/// count and shard layout stay centrally controlled (and deterministic).
fn check_thread_spawn(rel: &str, lines: &[Line], findings: &mut Vec<Finding>) {
    if SPAWN_MODULES.iter().any(|p| rel.starts_with(p)) {
        return;
    }
    for (idx, line) in lines.iter().enumerate() {
        if line.code.contains("thread::spawn") || line.code.contains("thread::Builder") {
            emit(
                findings,
                lines,
                rel,
                idx,
                THREAD_SPAWN,
                "thread spawn outside runtime/ and transport/ — route the work through \
                 runtime::pool instead"
                    .to_string(),
            );
        }
    }
}

/// Rule `hash-iter`: `HashMap`/`HashSet` in non-test library code must be
/// either replaced by the BTree variants or annotated `// LINT: sorted`
/// with an argument that iteration order never reaches an output. The
/// determinism matrix compares checksums across transports and thread
/// counts; one hash-ordered iteration breaks it.
fn check_hash_iter(rel: &str, lines: &[Line], findings: &mut Vec<Finding>) {
    if !rel.starts_with("rust/src/") {
        return;
    }
    for (idx, line) in lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        let hit = contains_word(&line.code, "HashMap") || contains_word(&line.code, "HashSet");
        if hit && !annotated(lines, idx, "LINT: sorted", ANNOTATION_WINDOW) {
            emit(
                findings,
                lines,
                rel,
                idx,
                HASH_ITER,
                "HashMap/HashSet in a deterministic path — use BTreeMap/BTreeSet or annotate \
                 // LINT: sorted -- <why iteration order cannot leak>"
                    .to_string(),
            );
        }
    }
}

/// Rule `float-reduce`: bare `.sum()` / `.fold(` in the numeric scope
/// (non-test) must be annotated `// LINT: reduce-ok` unless the file IS a
/// designated reducer. Gradient-length reductions must go through the
/// fixed pairwise tree so the result is independent of shard count.
fn check_float_reduce(rel: &str, lines: &[Line], findings: &mut Vec<Finding>) {
    if !FLOAT_REDUCE_SCOPE.iter().any(|p| rel.starts_with(p)) {
        return;
    }
    if FLOAT_REDUCE_EXEMPT.contains(&rel) {
        return;
    }
    for (idx, line) in lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        let hit = has_float_sum(&line.code) || line.code.contains(".fold(");
        if hit && !annotated(lines, idx, "LINT: reduce-ok", ANNOTATION_WINDOW) {
            emit(
                findings,
                lines,
                rel,
                idx,
                FLOAT_REDUCE,
                "bare float reduction — use gar::pairwise::reduce_partials_tree for \
                 gradient-length buffers, or annotate // LINT: reduce-ok -- <why order-safe>"
                    .to_string(),
            );
        }
    }
}

/// Rule `allow-syntax`: every `lint:allow` escape must name a rule from
/// the catalog in its parens and carry a ` -- <reason>` suffix. Malformed
/// escapes never suppress anything (see [`super::escape_allows`]), so
/// this rule is what surfaces them instead of letting them rot silently.
fn check_allow_syntax(rel: &str, lines: &[Line], findings: &mut Vec<Finding>) {
    for (idx, line) in lines.iter().enumerate() {
        let Some((rule, justified)) = super::parse_allow(&line.comment) else {
            continue;
        };
        if !RULES.iter().any(|r| r.id == rule) {
            findings.push(Finding {
                file: rel.to_string(),
                line: idx + 1,
                rule: ALLOW_SYNTAX,
                message: format!("lint:allow names unknown rule `{rule}`"),
            });
        } else if !justified {
            findings.push(Finding {
                file: rel.to_string(),
                line: idx + 1,
                rule: ALLOW_SYNTAX,
                message: format!(
                    "lint:allow({rule}) without a ` -- <reason>` justification (and therefore \
                     suppresses nothing)"
                ),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn word_boundaries_respected() {
        assert!(contains_word("let t = Instant::now();", "Instant"));
        assert!(!contains_word("fn Instantiate() {}", "Instant"));
        assert!(!contains_word("my_unsafe_name", "unsafe"));
        assert!(contains_word("unsafe {", "unsafe"));
    }

    #[test]
    fn float_sum_detection() {
        assert!(has_float_sum("let s = xs.iter().sum::<f32>();"));
        assert!(has_float_sum("let s: f32 = xs.iter().sum();"));
        assert!(!has_float_sum("let n = xs.iter().sum::<usize>();"));
        assert!(!has_float_sum("let n = xs.iter().sum::<u64>();"));
        assert!(!has_float_sum("m.summary();"));
    }
}
