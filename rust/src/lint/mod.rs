//! `multibulyan lint` — the repo-specific invariant linter.
//!
//! A std-only, token/line-level static pass (no external parser; `anyhow`
//! stays the crate's sole dependency) that walks `rust/src`, `rust/tests`
//! and `examples/` and enforces the determinism and safety invariants the
//! resilience claims rest on: unsafe blocks audited and confined, no wall
//! clock in virtual-time paths, pool-only parallelism, no hash-order
//! iteration in deterministic paths, and no bare float reductions outside
//! the pairwise tree. The rule catalog lives in [`rules`]; this module is
//! the scanner (line classification: code vs comment vs test region) and
//! the driver ([`lint_repo`] / [`lint_source`]).
//!
//! The scanner is deliberately not a Rust parser. It tracks just enough
//! state — line comments, nested block comments, string literals, raw
//! strings, char literals vs lifetimes — to split every source line into
//! a *code part* (string contents blanked, comments stripped) and a
//! *comment part* (where `// SAFETY:` / `// LINT:` / `// lint:allow`
//! annotations live), and to know whether a line sits inside a
//! `#[cfg(test)]` region. Rules match tokens in the code part only, so
//! pattern strings in doc text or string literals never fire.

pub mod rules;

use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

/// One diagnostic: a rule violation at `file:line`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// Repo-relative path, `/`-separated on every platform.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// Rule id from the catalog in [`rules::RULES`].
    pub rule: &'static str,
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: [{}] {}",
            self.file, self.line, self.rule, self.message
        )
    }
}

/// The outcome of a lint run over a file set.
#[derive(Debug, Default)]
pub struct LintReport {
    pub findings: Vec<Finding>,
    pub files_scanned: usize,
}

impl LintReport {
    pub fn is_clean(&self) -> bool {
        self.findings.is_empty()
    }
}

/// One classified source line: the code part (strings blanked, comments
/// stripped), the comment part (text of any `//` / `/* */` comment on the
/// line) and whether the line is inside a `#[cfg(test)]` region.
#[derive(Debug, Clone)]
pub struct Line {
    pub code: String,
    pub comment: String,
    pub in_test: bool,
}

/// Scanner state carried across lines.
#[derive(Clone, Copy)]
enum Mode {
    /// Plain code.
    Code,
    /// Inside a block comment, at the given nesting depth (>= 1).
    Block(usize),
    /// Inside a normal `"…"` string literal.
    Str,
    /// Inside a raw string `r#"…"#` with the given hash count.
    RawStr(usize),
}

fn is_ident_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// If `chars[i..]` starts a raw string opener (`r"`, `r#"`, `br##"`, …
/// with `i` at the `r`), return the hash count.
fn raw_str_hashes(chars: &[char], i: usize) -> Option<usize> {
    debug_assert_eq!(chars[i], 'r');
    let mut j = i + 1;
    let mut hashes = 0;
    while j < chars.len() && chars[j] == '#' {
        hashes += 1;
        j += 1;
    }
    if j < chars.len() && chars[j] == '"' {
        Some(hashes)
    } else {
        None
    }
}

/// Does `chars[i..]` close a raw string with `hashes` hashes (`i` at the
/// closing quote)?
fn closes_raw(chars: &[char], i: usize, hashes: usize) -> bool {
    debug_assert_eq!(chars[i], '"');
    let mut j = i + 1;
    let mut seen = 0;
    while seen < hashes {
        if j >= chars.len() || chars[j] != '#' {
            return false;
        }
        seen += 1;
        j += 1;
    }
    true
}

/// Split a source text into classified [`Line`]s. String/char-literal
/// contents are blanked (replaced by spaces) in the code part so token
/// matching never fires on literal text; comment text is collected in the
/// comment part so annotations are found there and only there.
pub fn split_lines(text: &str) -> Vec<Line> {
    let mut out = Vec::new();
    let mut mode = Mode::Code;
    for raw in text.lines() {
        let chars: Vec<char> = raw.chars().collect();
        let mut code = String::with_capacity(chars.len());
        let mut comment = String::new();
        let mut i = 0;
        while i < chars.len() {
            let c = chars[i];
            match mode {
                Mode::Block(depth) => {
                    if c == '*' && chars.get(i + 1) == Some(&'/') {
                        comment.push_str("*/");
                        i += 2;
                        mode = if depth == 1 {
                            Mode::Code
                        } else {
                            Mode::Block(depth - 1)
                        };
                    } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                        comment.push_str("/*");
                        i += 2;
                        mode = Mode::Block(depth + 1);
                    } else {
                        comment.push(c);
                        i += 1;
                    }
                }
                Mode::Str => {
                    if c == '\\' {
                        code.push_str("  ");
                        i += 2;
                    } else if c == '"' {
                        code.push('"');
                        i += 1;
                        mode = Mode::Code;
                    } else {
                        code.push(' ');
                        i += 1;
                    }
                }
                Mode::RawStr(hashes) => {
                    if c == '"' && closes_raw(&chars, i, hashes) {
                        code.push('"');
                        for _ in 0..hashes {
                            code.push(' ');
                        }
                        i += 1 + hashes;
                        mode = Mode::Code;
                    } else {
                        code.push(' ');
                        i += 1;
                    }
                }
                Mode::Code => {
                    if c == '/' && chars.get(i + 1) == Some(&'/') {
                        // Line comment: rest of the line is comment text.
                        comment.push_str(&chars[i..].iter().collect::<String>());
                        i = chars.len();
                    } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                        comment.push_str("/*");
                        i += 2;
                        mode = Mode::Block(1);
                    } else if c == '"' {
                        code.push('"');
                        i += 1;
                        mode = Mode::Str;
                    } else if c == 'r'
                        && (i == 0 || !is_ident_char(chars[i - 1]))
                        && raw_str_hashes(&chars, i).is_some()
                    {
                        let hashes = raw_str_hashes(&chars, i).unwrap();
                        code.push('r');
                        for _ in 0..hashes {
                            code.push(' ');
                        }
                        code.push('"');
                        i += 1 + hashes + 1;
                        mode = Mode::RawStr(hashes);
                    } else if c == 'b'
                        && chars.get(i + 1) == Some(&'"')
                        && (i == 0 || !is_ident_char(chars[i - 1]))
                    {
                        // Byte string literal.
                        code.push_str("b\"");
                        i += 2;
                        mode = Mode::Str;
                    } else if c == '\'' {
                        // Char literal vs lifetime. A lifetime is `'ident`
                        // NOT followed by a closing quote; a char literal
                        // always closes on the same line in valid Rust.
                        if chars.get(i + 1) == Some(&'\\') {
                            // Escaped char literal: find closing quote.
                            let mut j = i + 2;
                            if j < chars.len() {
                                j += 1; // the escaped char itself
                            }
                            while j < chars.len() && chars[j] != '\'' {
                                j += 1;
                            }
                            code.push('\'');
                            for _ in (i + 1)..=j.min(chars.len() - 1) {
                                code.push(' ');
                            }
                            i = (j + 1).min(chars.len());
                        } else if chars.get(i + 2) == Some(&'\'') {
                            // Simple one-char literal 'x'.
                            code.push_str("' '");
                            i += 3;
                        } else {
                            // Lifetime (or stray quote): keep as-is.
                            code.push('\'');
                            i += 1;
                        }
                    } else {
                        code.push(c);
                        i += 1;
                    }
                }
            }
        }
        // Block comments, raw strings AND normal strings may span lines
        // (a trailing `\` escapes the newline; an unescaped newline is a
        // literal one) — `mode` simply carries over to the next line.
        out.push(Line {
            code,
            comment,
            in_test: false,
        });
    }
    out
}

/// Mark lines inside `#[cfg(test)]` regions. Tracks brace depth; a
/// `#[cfg(test)]` attribute arms a pending flag that binds to the next
/// `{` opened (the `mod tests {` / `fn …() {` body) unless a `;` ends the
/// item first.
pub fn mark_test_regions(lines: &mut [Line]) {
    let mut depth: usize = 0;
    let mut pending = false;
    let mut region: Option<usize> = None; // depth at which the region closes
    for line in lines.iter_mut() {
        if line.code.contains("#[cfg(test)]") {
            pending = true;
        }
        let mut in_test_here = region.is_some();
        for c in line.code.chars() {
            match c {
                '{' => {
                    depth += 1;
                    if pending && region.is_none() {
                        region = Some(depth);
                        pending = false;
                        in_test_here = true;
                    }
                }
                '}' => {
                    if let Some(rd) = region {
                        if depth == rd {
                            region = None;
                        }
                    }
                    depth = depth.saturating_sub(1);
                }
                ';' => {
                    if region.is_none() {
                        pending = false;
                    }
                }
                _ => {}
            }
        }
        line.in_test = in_test_here || region.is_some();
    }
}

/// Does line `idx` carry annotation `needle` — in its own comment, or in
/// a comment within `window` lines above it (skipping only blank or
/// comment-only lines is *not* required: any line's comment counts)?
pub fn annotated(lines: &[Line], idx: usize, needle: &str, window: usize) -> bool {
    let start = idx.saturating_sub(window);
    lines[start..=idx].iter().any(|l| l.comment.contains(needle))
}

/// Parse a `lint:allow` escape (rule name in parens) out of a comment,
/// returning the rule name and whether a ` -- <reason>` justification
/// follows.
pub fn parse_allow(comment: &str) -> Option<(&str, bool)> {
    let pos = comment.find("lint:allow(")?;
    let rest = &comment[pos + "lint:allow(".len()..];
    let close = rest.find(')')?;
    let rule = rest[..close].trim();
    let tail = &rest[close + 1..];
    let justified = tail
        .trim_start()
        .strip_prefix("--")
        .is_some_and(|r| !r.trim().is_empty());
    Some((rule, justified))
}

/// Is finding `rule` at line `idx` suppressed by a well-formed
/// `lint:allow` escape — the rule name in parens, then ` -- <reason>` —
/// on the same line or within two lines above? Malformed escapes (wrong
/// rule, missing reason) do not suppress — they are themselves findings
/// (rule `allow-syntax`).
pub fn escape_allows(lines: &[Line], idx: usize, rule: &str) -> bool {
    let start = idx.saturating_sub(2);
    lines[start..=idx].iter().any(|l| {
        parse_allow(&l.comment).is_some_and(|(r, justified)| r == rule && justified)
    })
}

/// Lint one source text under its repo-relative path. Files under
/// `rust/tests/` are integration tests — wholly test code.
pub fn lint_source(rel: &str, text: &str) -> Vec<Finding> {
    let mut lines = split_lines(text);
    if rel.starts_with("rust/tests/") {
        for l in &mut lines {
            l.in_test = true;
        }
    } else {
        mark_test_regions(&mut lines);
    }
    rules::apply(rel, &lines)
}

/// Directories scanned relative to the repo root.
pub const LINT_DIRS: &[&str] = &["rust/src", "rust/tests", "examples"];

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let mut entries: Vec<PathBuf> = fs::read_dir(dir)
        .with_context(|| format!("reading {}", dir.display()))?
        .map(|e| e.map(|e| e.path()))
        .collect::<std::io::Result<_>>()?;
    entries.sort();
    for path in entries {
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Lint the repo rooted at `root`: walk [`LINT_DIRS`], scan every `.rs`
/// file, return all findings sorted by (file, line).
pub fn lint_repo(root: &Path) -> Result<LintReport> {
    let mut report = LintReport::default();
    for dir in LINT_DIRS {
        let abs = root.join(dir);
        if !abs.is_dir() {
            continue;
        }
        let mut files = Vec::new();
        collect_rs_files(&abs, &mut files)?;
        for path in files {
            let text = fs::read_to_string(&path)
                .with_context(|| format!("reading {}", path.display()))?;
            let rel: String = path
                .strip_prefix(root)
                .unwrap_or(&path)
                .components()
                .map(|c| c.as_os_str().to_string_lossy())
                .collect::<Vec<_>>()
                .join("/");
            report.findings.extend(lint_source(&rel, &text));
            report.files_scanned += 1;
        }
    }
    report.findings.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scanner_blanks_strings_and_strips_comments() {
        let lines = split_lines("let x = \"unsafe Instant\"; // trailing note\n");
        assert_eq!(lines.len(), 1);
        assert!(!lines[0].code.contains("unsafe"));
        assert!(!lines[0].code.contains("Instant"));
        assert!(lines[0].code.contains("let x ="));
        assert!(lines[0].comment.contains("trailing note"));
    }

    #[test]
    fn scanner_handles_raw_strings_and_block_comments() {
        let src = "let s = r#\"thread::spawn\"#;\n/* block\nstill comment HashMap\n*/ let y = 1;\n";
        let lines = split_lines(src);
        assert!(!lines[0].code.contains("spawn"));
        assert!(lines[1].comment.contains("block"));
        assert!(lines[2].comment.contains("HashMap"));
        assert!(!lines[2].code.contains("HashMap"));
        assert!(lines[3].code.contains("let y = 1;"));
    }

    #[test]
    fn scanner_keeps_lifetimes_but_blanks_char_literals() {
        let lines = split_lines("fn f<'a>(x: &'a u8) -> char { 'x' }\n");
        assert!(lines[0].code.contains("<'a>"));
        assert!(!lines[0].code.contains("'x'"));
        let esc = split_lines("let c = '\\n'; let d = unsafe_marker;\n");
        assert!(esc[0].code.contains("unsafe_marker"));
    }

    #[test]
    fn strings_continued_across_lines_stay_blanked() {
        // A trailing `\` escapes the newline: the literal continues on
        // the next line, which must not be scanned as code.
        let src = "let s = \"first \\\nunsafe Instant HashMap\";\nlet t = 1;\n";
        let lines = split_lines(src);
        assert!(!lines[1].code.contains("unsafe"));
        assert!(!lines[1].code.contains("HashMap"));
        assert!(lines[2].code.contains("let t = 1;"));
    }

    #[test]
    fn test_regions_tracked_by_brace_depth() {
        let src = "fn real() {}\n#[cfg(test)]\nmod tests {\n    fn t() {}\n}\nfn after() {}\n";
        let mut lines = split_lines(src);
        mark_test_regions(&mut lines);
        assert!(!lines[0].in_test);
        assert!(lines[2].in_test);
        assert!(lines[3].in_test);
        assert!(lines[4].in_test);
        assert!(!lines[5].in_test);
    }

    #[test]
    fn cfg_test_on_item_with_semicolon_does_not_arm_region() {
        let src = "#[cfg(test)]\nuse std::collections::HashMap;\nfn real() { body(); }\n";
        let mut lines = split_lines(src);
        mark_test_regions(&mut lines);
        assert!(!lines[2].in_test);
    }

    #[test]
    fn parse_allow_grammar() {
        assert_eq!(
            parse_allow("// lint:allow(wall-clock) -- measured here on purpose"),
            Some(("wall-clock", true))
        );
        assert_eq!(parse_allow("// lint:allow(wall-clock)"), Some(("wall-clock", false)));
        assert_eq!(parse_allow("// lint:allow(wall-clock) --   "), Some(("wall-clock", false)));
        assert_eq!(parse_allow("// nothing here"), None);
    }
}
