//! Averaging — the optimal but non-Byzantine-resilient baseline
//! (the GAR of the mainstream parameter server [Dean et al. 2012, Li et
//! al. 2014]; the reference point of both the slowdown theorems and
//! Fig. 3).

use super::selection::{CombinePlan, Selection};
use super::{check_select_shape, Gar, GarScratch};
use crate::runtime::Parallelism;
use crate::tensor::GradMatrix;
use crate::Result;

/// Coordinate-wise arithmetic mean of all `n` gradients.
#[derive(Debug, Clone)]
pub struct Average {
    n: usize,
    par: Parallelism,
}

impl Average {
    pub fn new(n: usize) -> Result<Self> {
        anyhow::ensure!(n >= 1, "average: need at least one worker, got {n}");
        Ok(Self {
            n,
            par: Parallelism::sequential(),
        })
    }

    /// Use `par` for the coordinate-sharded O(nd) combine.
    pub fn with_parallelism(mut self, par: Parallelism) -> Self {
        self.par = par;
        self
    }
}

impl Gar for Average {
    fn name(&self) -> &'static str {
        "average"
    }

    fn n(&self) -> usize {
        self.n
    }

    fn f(&self) -> usize {
        0
    }

    fn parallelism(&self) -> &Parallelism {
        &self.par
    }

    fn gradients_used(&self) -> usize {
        self.n
    }

    /// "Selection" is trivial: every row, in order. All O(nd) work lives
    /// in the combine phase (which is why averaging is the parallel
    /// yardstick of Theorem 2.ii).
    fn select_into(
        &self,
        grads: &GradMatrix,
        _scratch: &mut GarScratch,
        sel: &mut Selection,
    ) -> Result<()> {
        check_select_shape("average", grads, self.n)?;
        sel.reset(CombinePlan::MeanRows, self.n);
        sel.rows.extend(0..self.n);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn averages_rows() {
        let g = GradMatrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 6.0]]);
        let gar = Average::new(2).unwrap();
        assert_eq!(gar.aggregate(&g).unwrap(), vec![2.0, 4.0]);
        assert_eq!(gar.gradients_used(), 2);
    }

    #[test]
    fn single_worker_identity() {
        let g = GradMatrix::from_rows(&[vec![7.0, -1.0]]);
        assert_eq!(Average::new(1).unwrap().aggregate(&g).unwrap(), vec![7.0, -1.0]);
    }

    #[test]
    fn rejects_wrong_n() {
        let g = GradMatrix::zeros(3, 4);
        assert!(Average::new(2).unwrap().aggregate(&g).is_err());
    }

    #[test]
    fn not_byzantine_resilient_by_construction() {
        // Documents the vulnerability the paper opens with: one worker
        // proposing an outlier drags the average arbitrarily far.
        let mut rows = vec![vec![0.0f32; 4]; 9];
        rows.push(vec![1e9; 4]);
        let g = GradMatrix::from_rows(&rows);
        let out = Average::new(10).unwrap().aggregate(&g).unwrap();
        assert!(out[0] > 1e7);
    }

    #[test]
    fn selection_is_every_row() {
        let g = GradMatrix::zeros(3, 4);
        let gar = Average::new(3).unwrap();
        let mut scratch = GarScratch::new();
        let sel = gar.select(&g, &mut scratch).unwrap();
        assert_eq!(sel.selected_rows(), &[0, 1, 2]);
        assert_eq!(sel.plan(), CombinePlan::MeanRows);
    }

    #[test]
    fn parallel_matches_sequential_bitwise() {
        let g = GradMatrix::from_fn(9, 20_000, |i, j| ((i * 37 + j) % 101) as f32 * 0.017 - 0.5);
        let seq = Average::new(9).unwrap().aggregate(&g).unwrap();
        let par = Average::new(9)
            .unwrap()
            .with_parallelism(Parallelism::new(4))
            .aggregate(&g)
            .unwrap();
        assert_eq!(seq, par);
    }
}
