//! Pre-aggregation pipeline: composable stages that transform the `n × d`
//! proposal matrix *before* the GAR's selection phase runs.
//!
//! The two-phase GAR API makes aggregation composable; this module adds
//! the other half of the composition story — worker-side pre-aggregation
//! in the style of resilient momentum (Farhadkhani et al., "Byzantine
//! Machine Learning Made Easy by Resilient Averaging of Momentums", 2022):
//! each worker submits an exponential moving average of its gradients and
//! the GAR aggregates *momentums*, which shrinks the honest variance the
//! Byzantine coalition can hide inside. In this simulator workers are
//! deterministic, so the per-worker momentum state lives server-side in
//! the stage (equivalent: a Byzantine worker can realise any momentum
//! stream by choosing its raw submissions, so the threat model is
//! unchanged).
//!
//! ## Spec grammar (config `gar = "..."`, CLI `--gar`)
//!
//! ```text
//! spec  := (stage "+")* gar
//! stage := "rmom(" beta ")"          # resilient momentum, beta ∈ [0, 1)
//!        | "group(" g ")"            # two-level aggregation, g ≥ 1 groups
//! gar   := average | median | trimmed-mean | krum | multi-krum
//!        | bulyan | multi-bulyan
//! ```
//!
//! Examples: `multi-bulyan` (no stages), `rmom(0.9)+multi-bulyan`,
//! `group(8)+rmom(0.9)+multi-krum`. Parsed by [`GarSpec`].
//!
//! `group(g)` is special: it is the *collection* layer, not a matrix
//! transform — the coordinator partitions workers into `g` groups and
//! streams each group's mean through [`crate::gar::group::GroupReducer`]
//! before any matrix stage runs, so the launcher extracts it (it must
//! come first in the spec) instead of instantiating it, and every stage
//! after it — including `rmom` — operates on the `g × d` *group-row*
//! matrix (per-group momentum). It is equivalent to the config root key
//! `groups = g`.

use super::GarKind;
use crate::runtime::{shard_zip, Parallelism, MIN_COORDS_PER_SHARD};
use crate::tensor::GradMatrix;
use crate::Result;

/// A pre-aggregation stage: transforms the proposal matrix in place each
/// round, before the GAR's `select` phase. Stages may keep per-worker
/// state across rounds (momentum buffers); they must be deterministic in
/// `(grads, round)` and coordinate-wise independent so that sharded
/// execution stays bit-identical to sequential.
pub trait PreAggregate: Send + Sync {
    /// Stable stage name for logs/CSV.
    fn name(&self) -> &'static str;

    /// Transform the `n × d` matrix in place for round `round`.
    fn apply(&mut self, grads: &mut GradMatrix, round: u64) -> Result<()>;
}

/// Resilient momentum: per worker `i`, `m_i ← β·m_i + (1−β)·g_i` and the
/// worker's row is replaced by `m_i`. State is zero-initialised, so round
/// 1 submits `(1−β)·g` (the standard bias-uncorrected EMA).
///
/// **Re-zero-on-shape-change policy (deliberate):** the momentum state
/// is an `n × d` buffer whose row `i` means "worker `i`'s EMA" (or, in
/// two-level mode, "group `i`'s EMA"). If the matrix shape ever changes
/// — a different worker count, a different model, or a change of group
/// membership under `group(g)` — every row's identity is void, so the
/// whole buffer re-zeroes and the EMA restarts rather than silently
/// attributing one entity's momentum to another. The check compares the
/// `(n, d)` *pair*, not the product: `n×d → d×n` (and any equal-product
/// regrouping, e.g. `group(4) → group(8)` at `g·d` constant) must also
/// re-zero. Pinned by `shape_change_with_equal_product_resets_state`
/// and `group_membership_change_rezeros_even_at_equal_product` below.
pub struct ResilientMomentum {
    beta: f32,
    /// `n × d` momentum state, flat row-major; sized lazily on first
    /// apply (and re-zeroed if the cluster shape ever changes).
    state: Vec<f32>,
    /// The `(n, d)` the state was sized for. Tracked explicitly — a
    /// shape change with an equal product (n×d → d×n) must re-zero the
    /// buffer too, not silently reuse stale momentum laid out for the
    /// old shape.
    shape: (usize, usize),
    par: Parallelism,
}

impl ResilientMomentum {
    pub fn new(beta: f32, par: Parallelism) -> Result<Self> {
        anyhow::ensure!(
            (0.0..1.0).contains(&beta),
            "resilient momentum: beta must be in [0, 1), got {beta}"
        );
        Ok(Self {
            beta,
            state: Vec::new(),
            shape: (0, 0),
            par,
        })
    }

    pub fn beta(&self) -> f32 {
        self.beta
    }
}

impl PreAggregate for ResilientMomentum {
    fn name(&self) -> &'static str {
        "rmom"
    }

    fn apply(&mut self, grads: &mut GradMatrix, _round: u64) -> Result<()> {
        let (n, d) = (grads.n(), grads.d());
        if self.shape != (n, d) {
            self.state.clear();
            self.state.resize(n * d, 0.0);
            self.shape = (n, d);
        }
        let beta = self.beta;
        let keep = 1.0 - beta;
        // The EMA is pointwise, so it runs as ONE fan-out over the flat
        // n×d buffers (row boundaries are irrelevant to the arithmetic) —
        // a single pool barrier per round, not one per worker. Each
        // element's update is independent, so any partition is
        // bit-identical to the sequential pass.
        let mut states: Vec<()> = Vec::new();
        shard_zip(
            &self.par,
            [grads.flat_mut(), &mut self.state[..]],
            &mut states,
            || (),
            MIN_COORDS_PER_SHARD,
            |_, [g, m]: [&mut [f32]; 2], _| {
                for k in 0..g.len() {
                    m[k] = beta * m[k] + keep * g[k];
                    g[k] = m[k];
                }
            },
        );
        Ok(())
    }
}

/// One parsed pipeline stage — the config/CLI surface of [`PreAggregate`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum StageSpec {
    /// `rmom(beta)` — [`ResilientMomentum`].
    ResilientMomentum { beta: f32 },
    /// `group(g)` — two-level aggregation: partition workers into `g`
    /// groups whose streamed means become the matrix rows. Not a matrix
    /// transform — the launcher extracts it (see module docs) and wires
    /// [`crate::gar::group::GroupReducer`] into the transport instead.
    GroupAggregate { groups: usize },
}

impl StageSpec {
    /// Enforce parameter ranges (also called by config validation for
    /// programmatically built configs).
    pub fn validate(&self) -> Result<()> {
        match self {
            StageSpec::ResilientMomentum { beta } => {
                anyhow::ensure!(
                    (0.0..1.0).contains(beta),
                    "rmom: beta must be in [0, 1), got {beta}"
                );
            }
            StageSpec::GroupAggregate { groups } => {
                anyhow::ensure!(
                    *groups >= 1,
                    "group: need at least 1 group, got {groups}"
                );
            }
        }
        Ok(())
    }

    /// Build the stage, running its sharded passes on `par`.
    pub fn instantiate(&self, par: &Parallelism) -> Result<Box<dyn PreAggregate>> {
        match self {
            StageSpec::ResilientMomentum { beta } => {
                Ok(Box::new(ResilientMomentum::new(*beta, par.clone())?))
            }
            StageSpec::GroupAggregate { groups } => anyhow::bail!(
                "group({groups}) is the collection layer, applied by the \
                 coordinator during streaming collection — it cannot be \
                 instantiated as a matrix stage (launcher bug)"
            ),
        }
    }
}

impl std::fmt::Display for StageSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StageSpec::ResilientMomentum { beta } => write!(f, "rmom({beta})"),
            StageSpec::GroupAggregate { groups } => write!(f, "group({groups})"),
        }
    }
}

impl std::str::FromStr for StageSpec {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        let s = s.trim();
        let (name, rest) = match s.split_once('(') {
            Some((name, rest)) => (name.trim(), Some(rest)),
            None => (s, None),
        };
        match name.to_ascii_lowercase().replace('_', "-").as_str() {
            "rmom" | "resilient-momentum" => {
                let arg = rest
                    .and_then(|r| r.strip_suffix(')'))
                    .ok_or_else(|| anyhow::anyhow!("stage '{s}': expected rmom(beta)"))?;
                let beta: f32 = arg
                    .trim()
                    .parse()
                    .map_err(|e| anyhow::anyhow!("stage '{s}': bad beta: {e}"))?;
                let spec = StageSpec::ResilientMomentum { beta };
                spec.validate()?;
                Ok(spec)
            }
            "group" | "group-aggregate" => {
                let arg = rest
                    .and_then(|r| r.strip_suffix(')'))
                    .ok_or_else(|| anyhow::anyhow!("stage '{s}': expected group(g)"))?;
                let groups: usize = arg
                    .trim()
                    .parse()
                    .map_err(|e| anyhow::anyhow!("stage '{s}': bad group count: {e}"))?;
                let spec = StageSpec::GroupAggregate { groups };
                spec.validate()?;
                Ok(spec)
            }
            other => anyhow::bail!(
                "unknown pre-aggregation stage '{other}' (expected: rmom(beta) or group(g))"
            ),
        }
    }
}

/// A full aggregation spec: zero or more pre-aggregation stages applied in
/// order, then a terminal GAR — e.g. `rmom(0.9)+multi-bulyan`. This is
/// what the config key `gar = "..."` and the CLI `--gar` flag parse to.
#[derive(Debug, Clone, PartialEq)]
pub struct GarSpec {
    pub stages: Vec<StageSpec>,
    pub kind: GarKind,
}

impl GarSpec {
    /// A bare GAR with no stages.
    pub fn plain(kind: GarKind) -> Self {
        Self {
            stages: Vec::new(),
            kind,
        }
    }

    /// The `group(g)` stage, if present. Because grouping is the
    /// collection layer (it decides what the matrix *rows are*), it must
    /// be the first stage and appear at most once; any other placement is
    /// rejected here so both config validation and the launcher share one
    /// rule.
    pub fn group_stage(&self) -> Result<Option<usize>> {
        let mut found = None;
        for (i, stage) in self.stages.iter().enumerate() {
            if let StageSpec::GroupAggregate { groups } = stage {
                anyhow::ensure!(
                    i == 0,
                    "GAR spec '{self}': group({groups}) must be the first \
                     stage — it defines the matrix rows every later stage \
                     operates on"
                );
                anyhow::ensure!(
                    found.is_none(),
                    "GAR spec '{self}': group(...) may appear at most once"
                );
                found = Some(*groups);
            }
        }
        Ok(found)
    }

    /// The stages the coordinator instantiates as matrix transforms —
    /// everything except `group(g)`, which the launcher wires into the
    /// transport instead.
    pub fn matrix_stages(&self) -> impl Iterator<Item = &StageSpec> {
        self.stages
            .iter()
            .filter(|s| !matches!(s, StageSpec::GroupAggregate { .. }))
    }
}

impl std::fmt::Display for GarSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for stage in &self.stages {
            write!(f, "{stage}+")?;
        }
        write!(f, "{}", self.kind)
    }
}

impl std::str::FromStr for GarSpec {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        let parts: Vec<&str> = s.split('+').map(str::trim).collect();
        anyhow::ensure!(
            parts.iter().all(|p| !p.is_empty()),
            "empty component in GAR spec '{s}'"
        );
        let (gar, stages) = parts.split_last().expect("split always yields ≥ 1 part");
        let kind: GarKind = gar.parse().map_err(|e| {
            anyhow::anyhow!("GAR spec '{s}': terminal rule: {e}")
        })?;
        let stages = stages
            .iter()
            .map(|p| p.parse::<StageSpec>())
            .collect::<Result<Vec<_>>>()?;
        Ok(Self { stages, kind })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_round_trips() {
        for text in ["multi-bulyan", "rmom(0.9)+multi-bulyan", "rmom(0.5)+rmom(0.9)+krum"] {
            let spec: GarSpec = text.parse().unwrap();
            assert_eq!(spec.to_string(), text);
            let again: GarSpec = spec.to_string().parse().unwrap();
            assert_eq!(again, spec);
        }
        let spec: GarSpec = "rmom(0.9)+multi-bulyan".parse().unwrap();
        assert_eq!(spec.kind, GarKind::MultiBulyan);
        assert_eq!(spec.stages, vec![StageSpec::ResilientMomentum { beta: 0.9 }]);
    }

    #[test]
    fn spec_rejects_malformed_inputs() {
        assert!("".parse::<GarSpec>().is_err());
        assert!("rmom(0.9)".parse::<GarSpec>().is_err()); // stage without GAR
        assert!("rmom(0.9)+".parse::<GarSpec>().is_err());
        assert!("+multi-bulyan".parse::<GarSpec>().is_err());
        assert!("rmom(1.0)+krum".parse::<GarSpec>().is_err()); // beta out of range
        assert!("rmom(-0.1)+krum".parse::<GarSpec>().is_err());
        assert!("rmom0.9+krum".parse::<GarSpec>().is_err());
        assert!("frob(0.9)+krum".parse::<GarSpec>().is_err());
        assert!("rmom(abc)+krum".parse::<GarSpec>().is_err());
    }

    #[test]
    fn momentum_is_the_ema_of_submissions() {
        let par = Parallelism::sequential();
        let mut stage = ResilientMomentum::new(0.5, par).unwrap();
        let mut g1 = GradMatrix::from_rows(&[vec![2.0, 4.0], vec![-2.0, 0.0]]);
        stage.apply(&mut g1, 1).unwrap();
        // m_1 = 0.5·0 + 0.5·g = g/2.
        assert_eq!(g1.row(0), &[1.0, 2.0]);
        assert_eq!(g1.row(1), &[-1.0, 0.0]);
        let mut g2 = GradMatrix::from_rows(&[vec![2.0, 4.0], vec![2.0, 4.0]]);
        stage.apply(&mut g2, 2).unwrap();
        // m_2 = 0.5·m_1 + 0.5·g.
        assert_eq!(g2.row(0), &[1.5, 3.0]);
        assert_eq!(g2.row(1), &[0.5, 2.0]);
    }

    #[test]
    fn momentum_sharded_is_bit_identical_to_sequential() {
        let rounds = 4usize;
        let run = |threads: usize| -> Vec<f32> {
            let mut stage =
                ResilientMomentum::new(0.9, Parallelism::new(threads)).unwrap();
            let mut last = Vec::new();
            for r in 0..rounds {
                let mut g = GradMatrix::from_fn(5, 9_000, |i, j| {
                    ((i * 31 + j * 7 + r * 13) % 101) as f32 * 0.03 - 1.5
                });
                stage.apply(&mut g, r as u64 + 1).unwrap();
                last = g.flat().to_vec();
            }
            last
        };
        assert_eq!(run(1), run(4));
    }

    #[test]
    fn shape_change_with_equal_product_resets_state() {
        // Regression: 2×6 → 6×2 keeps n·d = 12, so the old
        // `state.len() != n*d` check skipped the re-zero and round 2 ran
        // an EMA over momentum laid out for the wrong shape.
        let mut stage = ResilientMomentum::new(0.5, Parallelism::sequential()).unwrap();
        let mut g1 = GradMatrix::from_fn(2, 6, |_, _| 2.0);
        stage.apply(&mut g1, 1).unwrap();
        assert!(g1.flat().iter().all(|&v| v == 1.0), "m_1 = g/2");
        let mut g2 = GradMatrix::from_fn(6, 2, |_, _| 2.0);
        stage.apply(&mut g2, 2).unwrap();
        // Fresh zero state for the new shape: (1−β)·g = 1.0 everywhere.
        // Stale reuse would have produced β·1.0 + 0.5·2.0 = 1.5.
        assert!(
            g2.flat().iter().all(|&v| v == 1.0),
            "stale momentum leaked across a shape change: {:?}",
            &g2.flat()[..4]
        );
    }

    #[test]
    fn group_stage_round_trips_and_is_position_checked() {
        for text in [
            "group(8)+multi-bulyan",
            "group(4)+rmom(0.9)+trimmed-mean",
            "group(1)+krum",
        ] {
            let spec: GarSpec = text.parse().unwrap();
            assert_eq!(spec.to_string(), text);
            assert!(spec.group_stage().unwrap().is_some());
        }
        let spec: GarSpec = "group(4)+rmom(0.9)+trimmed-mean".parse().unwrap();
        assert_eq!(spec.group_stage().unwrap(), Some(4));
        assert_eq!(
            spec.matrix_stages().copied().collect::<Vec<_>>(),
            vec![StageSpec::ResilientMomentum { beta: 0.9 }]
        );
        let flat: GarSpec = "rmom(0.9)+krum".parse().unwrap();
        assert_eq!(flat.group_stage().unwrap(), None);

        assert!("group(0)+krum".parse::<GarSpec>().is_err());
        assert!("group()+krum".parse::<GarSpec>().is_err());
        assert!("group(2.5)+krum".parse::<GarSpec>().is_err());
        // Parses, but placement is rejected by group_stage().
        let misplaced: GarSpec = "rmom(0.9)+group(4)+krum".parse().unwrap();
        assert!(misplaced.group_stage().is_err());
        let doubled: GarSpec = "group(4)+group(4)+krum".parse().unwrap();
        assert!(doubled.group_stage().is_err());
        // And instantiating group(g) as a matrix stage is a launcher bug.
        assert!(StageSpec::GroupAggregate { groups: 4 }
            .instantiate(&Parallelism::sequential())
            .is_err());
    }

    #[test]
    fn group_membership_change_rezeros_even_at_equal_product() {
        // Satellite: under group(g) the rows are *group* means, so a
        // regrouping that changes g (here 4×6 → 6×4 at equal g·d) makes
        // every momentum row refer to a different member set. The EMA
        // must restart from zero, not attribute group 0's old momentum
        // to the new group 0.
        let mut stage = ResilientMomentum::new(0.5, Parallelism::sequential()).unwrap();
        let mut r1 = GradMatrix::from_fn(4, 6, |_, _| 2.0);
        stage.apply(&mut r1, 1).unwrap();
        assert!(r1.flat().iter().all(|&v| v == 1.0), "m_1 = g/2");
        let mut r2 = GradMatrix::from_fn(6, 4, |_, _| 2.0);
        stage.apply(&mut r2, 2).unwrap();
        assert!(
            r2.flat().iter().all(|&v| v == 1.0),
            "regrouping at equal product must re-zero momentum: {:?}",
            &r2.flat()[..4]
        );
    }

    #[test]
    fn bad_beta_rejected_at_construction() {
        assert!(ResilientMomentum::new(1.0, Parallelism::sequential()).is_err());
        assert!(ResilientMomentum::new(-0.5, Parallelism::sequential()).is_err());
        assert!(StageSpec::ResilientMomentum { beta: 2.0 }.validate().is_err());
    }
}
