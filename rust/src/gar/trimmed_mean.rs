//! Coordinate-wise trimmed mean [Yin et al. 2018] — a weakly resilient
//! baseline the paper's related-work discusses; included as a comparator
//! for the resilience and slowdown benches.
//!
//! Like the median, the rule has no O(n²) decision: selection records the
//! `CoordTrimmed` plan (the per-coordinate trim parameter `f`), and the
//! combine drops the `f` largest and `f` smallest values per coordinate
//! and averages the remaining `n − 2f` (see `gar::selection`).

use super::selection::{CombinePlan, Selection};
use super::{check_select_shape, Gar, GarScratch};
use crate::runtime::Parallelism;
use crate::tensor::GradMatrix;
use crate::Result;

/// Per coordinate: drop the `f` largest and `f` smallest values, average
/// the remaining `n − 2f`.
#[derive(Debug, Clone)]
pub struct TrimmedMean {
    n: usize,
    f: usize,
    par: Parallelism,
}

impl TrimmedMean {
    pub fn new(n: usize, f: usize) -> Result<Self> {
        anyhow::ensure!(
            n >= 2 * f + 1,
            "trimmed-mean: requires n ≥ 2f+1 (got n={n}, f={f})"
        );
        Ok(Self {
            n,
            f,
            par: Parallelism::sequential(),
        })
    }

    /// Use `par` for the coordinate-sharded O(nd) combine.
    pub fn with_parallelism(mut self, par: Parallelism) -> Self {
        self.par = par;
        self
    }
}

impl Gar for TrimmedMean {
    fn name(&self) -> &'static str {
        "trimmed-mean"
    }

    fn n(&self) -> usize {
        self.n
    }

    fn f(&self) -> usize {
        self.f
    }

    fn parallelism(&self) -> &Parallelism {
        &self.par
    }

    fn gradients_used(&self) -> usize {
        self.n - 2 * self.f
    }

    fn select_into(
        &self,
        grads: &GradMatrix,
        _scratch: &mut GarScratch,
        sel: &mut Selection,
    ) -> Result<()> {
        check_select_shape("trimmed-mean", grads, self.n)?;
        sel.reset(CombinePlan::CoordTrimmed { trim: self.f }, self.n);
        // Which rows get trimmed is decided per coordinate; every row can
        // reach the output, so the selection reports all of them.
        sel.rows.extend(0..self.n);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trims_extremes() {
        let g = GradMatrix::from_rows(&[
            vec![-100.0],
            vec![1.0],
            vec![2.0],
            vec![3.0],
            vec![100.0],
        ]);
        let gar = TrimmedMean::new(5, 1).unwrap();
        assert_eq!(gar.aggregate(&g).unwrap(), vec![2.0]);
        assert_eq!(gar.gradients_used(), 3);
    }

    #[test]
    fn f_zero_is_plain_average() {
        let g = GradMatrix::from_rows(&[vec![1.0, 4.0], vec![3.0, 8.0]]);
        let gar = TrimmedMean::new(2, 0).unwrap();
        assert_eq!(gar.aggregate(&g).unwrap(), vec![2.0, 6.0]);
    }

    #[test]
    fn bounded_by_correct_values() {
        // With f Byzantine entries per coordinate, output stays within the
        // correct values' convex hull (each coordinate independently).
        let mut rows: Vec<Vec<f32>> = (0..7).map(|i| vec![i as f32]).collect();
        rows.push(vec![f32::MAX / 2.0]);
        rows.push(vec![f32::MIN / 2.0]);
        let g = GradMatrix::from_rows(&rows);
        let out = TrimmedMean::new(9, 2).unwrap().aggregate(&g).unwrap();
        assert!((0.0..=6.0).contains(&out[0]));
    }

    #[test]
    fn parallel_matches_sequential_bitwise() {
        let g = GradMatrix::from_fn(9, 12_000, |i, j| ((i * 19 + j * 3) % 127) as f32 * 0.02 - 1.0);
        let seq = TrimmedMean::new(9, 2).unwrap().aggregate(&g).unwrap();
        let par = TrimmedMean::new(9, 2)
            .unwrap()
            .with_parallelism(Parallelism::new(4))
            .aggregate(&g)
            .unwrap();
        assert_eq!(seq, par);
    }
}
