//! Reusable scratch buffers for the GAR hot path.
//!
//! The parameter server calls its GAR once per round with identical shapes;
//! [`GarScratch`] lets every rule run allocation-free in the steady state
//! (buffers are grown on first use and reused afterwards). One scratch may
//! be shared across different rules — each buffer resizes on demand.
//!
//! Since the two-phase redesign the *selection* phase stores row indices
//! only (`selection`, a [`Selection`]) — the old θ×d `G^ext`/`G^agr`
//! matrices are gone; the combine phase reads the winners straight from
//! the input matrix per coordinate range. What remains O(n²)-sized is the
//! distance matrix and its per-chunk partials; the only O(d)-independent
//! per-shard state is one [`CombineScratch`] per coordinate-range shard
//! (`shards`). The parallel fan-out itself is allocation-free: shards
//! derive their disjoint ranges from the shard index (`runtime::pool`),
//! so a steady-state round makes no allocation at all.

use super::selection::{CombineScratch, Selection};

/// Grow-only scratch space shared by all GAR implementations.
#[derive(Debug, Default)]
pub struct GarScratch {
    /// `n × n` pairwise squared-distance matrix.
    pub(crate) distances: Vec<f32>,
    /// Per-chunk partial distance matrices of the sharded pairwise pass.
    pub(crate) partials: Vec<f32>,
    /// Per-worker Krum scores.
    pub(crate) scores: Vec<f32>,
    /// Selection pool indices (BULYAN's shrinking candidate set).
    pub(crate) pool: Vec<usize>,
    /// The reusable selection of the default `aggregate_with_scratch`
    /// path (taken, filled by `select_into`, put back).
    pub(crate) selection: Selection,
    /// One working set per coordinate-range shard of the combine fan-out.
    pub(crate) shards: Vec<CombineScratch>,
}

impl GarScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Distance matrix buffer, zeroed to `n*n`.
    pub(crate) fn distances_mut(&mut self, n: usize) -> &mut Vec<f32> {
        self.distances.clear();
        self.distances.resize(n * n, 0.0);
        &mut self.distances
    }

    /// Total bytes currently held (for the metrics/perf reports).
    pub fn capacity_bytes(&self) -> usize {
        (self.distances.capacity() + self.partials.capacity() + self.scores.capacity())
            * std::mem::size_of::<f32>()
            + self.pool.capacity() * std::mem::size_of::<usize>()
            + self.selection.capacity_bytes()
            + self.shards.iter().map(CombineScratch::capacity_bytes).sum::<usize>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buffers_grow_and_reuse() {
        let mut s = GarScratch::new();
        s.distances_mut(4);
        assert_eq!(s.distances.len(), 16);
        let cap = s.distances.capacity();
        s.distances_mut(3);
        assert_eq!(s.distances.len(), 9);
        // No shrink: capacity retained for reuse.
        assert_eq!(s.distances.capacity(), cap);
        assert!(s.capacity_bytes() > 0);
    }

    #[test]
    fn combine_scratch_counts_toward_capacity() {
        let mut s = GarScratch::new();
        let before = s.capacity_bytes();
        let mut cs = CombineScratch::new();
        cs.column.reserve(64);
        cs.pairs.reserve(64);
        s.shards.push(cs);
        assert!(s.capacity_bytes() > before);
    }
}
