//! Reusable scratch buffers for the GAR hot path.
//!
//! The parameter server calls its GAR once per round with identical shapes;
//! [`GarScratch`] lets every rule run allocation-free in the steady state
//! (buffers are grown on first use and reused afterwards). One scratch may
//! be shared across different rules — each `get_*` accessor resizes on
//! demand.
//!
//! The parallel engine adds two grow-only members: `partials` (per-chunk
//! n×n matrices of the sharded pairwise-distance pass) and `shards` (one
//! [`ShardScratch`] per coordinate-range shard of the per-coordinate
//! passes), so the large O(d)/O(n²)-sized buffers are reused across
//! rounds. The parallel fan-out itself is allocation-free: shards derive
//! their disjoint ranges from the shard index (`runtime::pool`), so the
//! steady-state round makes no allocation at all.

/// Per-shard working buffers of the coordinate-sharded passes (median /
/// trimmed-mean columns, BULYAN's deviation pairs). Each shard of
/// `runtime::shard_slice` owns one, so threads never share hot buffers.
#[derive(Debug, Default)]
pub(crate) struct ShardScratch {
    /// Per-coordinate working column (n or θ values).
    pub(crate) column: Vec<f32>,
    /// (deviation, value) pairs for the per-coordinate β-selection.
    pub(crate) pairs: Vec<(f32, f32)>,
}

impl ShardScratch {
    fn capacity_bytes(&self) -> usize {
        self.column.capacity() * std::mem::size_of::<f32>()
            + self.pairs.capacity() * std::mem::size_of::<(f32, f32)>()
    }
}

/// Grow-only scratch space shared by all GAR implementations.
#[derive(Debug, Default)]
pub struct GarScratch {
    /// `n × n` pairwise squared-distance matrix.
    pub(crate) distances: Vec<f32>,
    /// Per-chunk partial distance matrices of the sharded pairwise pass.
    pub(crate) partials: Vec<f32>,
    /// Per-worker Krum scores.
    pub(crate) scores: Vec<f32>,
    /// Selection pool indices (BULYAN's shrinking candidate set).
    pub(crate) pool: Vec<usize>,
    /// θ × d matrix of per-iteration MULTI-KRUM averages (BULYAN's G^agr).
    pub(crate) agr: Vec<f32>,
    /// θ × d matrix of per-iteration winners (BULYAN's G^ext).
    pub(crate) ext: Vec<f32>,
    /// Per-coordinate medians (BULYAN's M).
    pub(crate) medians: Vec<f32>,
    /// Generic index buffer for argselect results.
    pub(crate) indices: Vec<usize>,
    /// Running sum of alive rows (BULYAN's incremental-average trick).
    pub(crate) sumbuf: Vec<f32>,
    /// One working set per coordinate-range shard.
    pub(crate) shards: Vec<ShardScratch>,
}

impl GarScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Distance matrix buffer, zeroed to `n*n`.
    pub(crate) fn distances_mut(&mut self, n: usize) -> &mut Vec<f32> {
        self.distances.clear();
        self.distances.resize(n * n, 0.0);
        &mut self.distances
    }

    /// Total bytes currently held (for the metrics/perf reports).
    pub fn capacity_bytes(&self) -> usize {
        (self.distances.capacity()
            + self.partials.capacity()
            + self.scores.capacity()
            + self.agr.capacity()
            + self.ext.capacity()
            + self.medians.capacity()
            + self.sumbuf.capacity()) * std::mem::size_of::<f32>()
            + (self.pool.capacity() + self.indices.capacity()) * std::mem::size_of::<usize>()
            + self.shards.iter().map(ShardScratch::capacity_bytes).sum::<usize>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buffers_grow_and_reuse() {
        let mut s = GarScratch::new();
        s.distances_mut(4);
        assert_eq!(s.distances.len(), 16);
        let cap = s.distances.capacity();
        s.distances_mut(3);
        assert_eq!(s.distances.len(), 9);
        // No shrink: capacity retained for reuse.
        assert_eq!(s.distances.capacity(), cap);
        assert!(s.capacity_bytes() > 0);
    }

    #[test]
    fn shard_scratch_counts_toward_capacity() {
        let mut s = GarScratch::new();
        let before = s.capacity_bytes();
        s.shards.push(ShardScratch {
            column: Vec::with_capacity(64),
            pairs: Vec::with_capacity(64),
        });
        assert!(s.capacity_bytes() > before);
    }
}
