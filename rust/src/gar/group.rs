//! Two-level hierarchical aggregation: the group layer.
//!
//! The paper's O(n²) selection cost is paid over whatever row count the
//! root GAR sees. This module shrinks that count from `n` workers to
//! `g` *groups*: workers are partitioned into contiguous groups, each
//! group pre-reduces its members' gradients to one mean vector as the
//! gradients **stream in**, and the root GAR's `select`/`combine` runs
//! over the `g` group rows (the two-level composition of Farhadkhani et
//! al. 2022 — aggregating means of honest subsets preserves the
//! resilience argument as long as the root rule tolerates
//! `f_root = ⌈f·g/n⌉` Byzantine rows).
//!
//! ## Determinism: the fixed positional pairwise tree
//!
//! IEEE f32 addition is commutative but not associative, so a group sum
//! naively accumulated in arrival order would differ between transports
//! and thread counts. [`GroupReducer`] therefore merges member
//! contributions over a **fixed-shape balanced positional tree**: member
//! `p` of a group is leaf `(level 0, index p)`; whenever a node's
//! sibling `(level, index ^ 1)` is present the pair merges eagerly into
//! `(level + 1, index >> 1)`, always adding the odd-index operand into
//! the even-index operand. The post-ingest slot state is a *canonical*
//! function of the set of delivered leaves (eager merging leaves exactly
//! the maximal complete aligned subtrees), and the finalize pass
//! promotes leftovers bottom-up in fixed `(level, index)` order with
//! pass-through for absent siblings — so the group value is a pure
//! function of **which** members delivered, never of arrival order,
//! thread count, or transport. `rust/tests/prop_groups.rs` pins this
//! across all three transports.
//!
//! ## Streaming: per-block trees and the memory bound
//!
//! Reduction happens per [`BLOCK`]-coordinate block (the codec block
//! grid), so the socket transport can feed chunks into the tree as they
//! arrive instead of reassembling whole gradients, and the pooled
//! transport's per-worker arena degenerates to an empty delivery
//! notification. Resident gradient memory is the live tree partials
//! plus at most one partial block staged per worker — O(g·d·log s +
//! n·block) against the flat path's O(n·d) — and the reducer keeps an
//! exact float ledger ([`GroupReducer::peak_resident_floats`]) that the
//! strict-invariants build cross-checks at every finalize.
//!
//! A member whose connection dies mid-gradient leaves its already
//! merged prefix blocks in the trees (streaming cannot un-merge);
//! per-block delivery counts make that case well defined — each block's
//! mean divides by the leaves *that block* received. Under complete
//! delivery every block count equals the member count and the value is
//! the plain member mean. The worker still counts as missing for
//! fallback/metrics purposes (its leaf never completed).

use crate::codec::BLOCK;
use crate::tensor::GradMatrix;
use crate::Result;
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

/// How the cluster's workers are partitioned into groups.
///
/// Honest workers `0..n−byz` land in `honest_groups` contiguous,
/// near-equal groups `0..honest_groups`; the simulated Byzantine ids
/// `n−byz..n` land in the trailing `byz_groups = ⌈byz·g/n⌉` groups, so a
/// forged *group row* stands for a coalition-controlled group exactly
/// like a forged worker row does on the flat path.
#[derive(Debug)]
pub struct GroupMap {
    n: usize,
    byz: usize,
    groups: usize,
    byz_groups: usize,
    /// Per worker (all `n`): its group id.
    of_worker: Vec<usize>,
    /// Per group: member worker ids, ascending and contiguous.
    members: Vec<Vec<usize>>,
}

impl GroupMap {
    /// Partition `n` workers (`byz` of them Byzantine) into `groups`
    /// groups. Fails when a side of the partition would produce an
    /// empty group.
    pub fn new(n: usize, byz: usize, groups: usize) -> Result<Arc<Self>> {
        anyhow::ensure!(groups >= 1, "groups must be ≥ 1, got {groups}");
        anyhow::ensure!(groups <= n, "groups = {groups} exceeds n = {n}");
        anyhow::ensure!(byz <= n, "byzantine count {byz} exceeds n = {n}");
        let honest = n - byz;
        let byz_groups = byz_groups_for(n, byz, groups);
        let honest_groups = groups - byz_groups;
        anyhow::ensure!(
            honest_groups >= 1 && honest_groups <= honest,
            "groups = {groups} with byz = {byz} leaves {honest_groups} honest group(s) \
             for {honest} honest worker(s)"
        );
        let mut of_worker = vec![0usize; n];
        let mut members = Vec::with_capacity(groups);
        for k in 0..honest_groups {
            let start = k * honest / honest_groups;
            let end = (k + 1) * honest / honest_groups;
            for w in start..end {
                of_worker[w] = k;
            }
            members.push((start..end).collect());
        }
        for j in 0..byz_groups {
            let start = honest + j * byz / byz_groups;
            let end = honest + (j + 1) * byz / byz_groups;
            for w in start..end {
                of_worker[w] = honest_groups + j;
            }
            members.push((start..end).collect());
        }
        debug_assert!(members.iter().all(|m| !m.is_empty()));
        Ok(Arc::new(Self {
            n,
            byz,
            groups,
            byz_groups,
            of_worker,
            members,
        }))
    }

    /// Total worker count (honest + Byzantine).
    pub fn n(&self) -> usize {
        self.n
    }

    /// Byzantine worker count.
    pub fn byz(&self) -> usize {
        self.byz
    }

    /// Total group count `g` — the root GAR's row count.
    pub fn groups(&self) -> usize {
        self.groups
    }

    /// Leading groups holding honest workers.
    pub fn honest_groups(&self) -> usize {
        self.groups - self.byz_groups
    }

    /// Trailing groups standing for the Byzantine coalition.
    pub fn byz_groups(&self) -> usize {
        self.byz_groups
    }

    /// The group holding `worker`.
    pub fn group_of(&self, worker: usize) -> usize {
        self.of_worker[worker]
    }

    /// `worker`'s leaf position within its group's pairwise tree.
    pub fn position(&self, worker: usize) -> usize {
        worker - self.members[self.of_worker[worker]][0]
    }

    /// Member worker ids of group `g`, ascending.
    pub fn members(&self, g: usize) -> &[usize] {
        &self.members[g]
    }
}

/// `⌈byz·g/n⌉` — group-level Byzantine budget for a `(n, byz)` cluster
/// partitioned into `g` groups (0 when `byz` is 0).
pub fn byz_groups_for(n: usize, byz: usize, groups: usize) -> usize {
    (byz * groups).div_ceil(n.max(1))
}

/// `⌈f·g/n⌉` — the declared tolerance the root GAR must be instantiated
/// with when `f` of `n` workers translate to `g` groups.
pub fn root_f_for(n: usize, f: usize, groups: usize) -> usize {
    (f * groups).div_ceil(n.max(1))
}

/// Outcome of feeding a whole gradient into the reducer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FullIngest {
    /// Merged; the worker counts as delivered.
    Accepted,
    /// Wrong length — rejected without touching the trees.
    BadLen,
    /// Not the round being collected — ignored.
    Stale,
}

/// Outcome of feeding one in-order chunk into the reducer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChunkIngest {
    /// Merged up to the chunk's end; more chunks expected.
    Accepted,
    /// This chunk completed the worker's gradient.
    Completed,
    /// Offset not in order / length overrun — rejected.
    Malformed,
    /// Not the round being collected — ignored.
    Stale,
}

/// One block's merge state: live tree nodes keyed `(level, index)` plus
/// the number of leaves merged so far. `BTreeMap` (not hash) so the
/// finalize sweep iterates in the fixed `(level, index)` order the
/// determinism argument needs.
#[derive(Default)]
struct BlockAcc {
    slots: BTreeMap<(u32, u32), Vec<f32>>,
    count: usize,
}

/// Per-round mutable state, behind the reducer's single mutex.
struct ReducerInner {
    round: u64,
    /// Per honest worker: floats ingested so far this round.
    cursor: Vec<usize>,
    /// Per honest worker: the round `cursor` counts for.
    worker_round: Vec<u64>,
    /// Per honest worker: completed a full gradient this round.
    delivered: Vec<bool>,
    /// Per honest worker: the staged prefix of its current block
    /// (chunks need not be block-aligned; always `< block length`).
    stage: Vec<Vec<f32>>,
    /// Per honest group × block: the pairwise-tree state.
    groups: Vec<Vec<BlockAcc>>,
    /// Float ledger: live floats across all slots and stages.
    resident: usize,
    /// High-water mark of `resident` since construction.
    peak: usize,
}

/// Streaming, order-independent group pre-reducer — see the module docs
/// for the tree construction and its determinism/memory contracts.
/// Shared by the transports (chunk/full ingest) and the coordinator
/// (finalize), so all methods take `&self` and serialize internally.
pub struct GroupReducer {
    map: Arc<GroupMap>,
    d: usize,
    nblocks: usize,
    inner: Mutex<ReducerInner>,
}

impl GroupReducer {
    /// Reducer for `d`-coordinate gradients under `map`'s partition.
    pub fn new(map: Arc<GroupMap>, d: usize) -> Self {
        let honest = map.n() - map.byz();
        let nblocks = d.div_ceil(BLOCK).max(1);
        let honest_groups = map.honest_groups();
        let inner = ReducerInner {
            round: 0,
            cursor: vec![0; honest],
            worker_round: vec![0; honest],
            delivered: vec![false; honest],
            stage: (0..honest).map(|_| Vec::new()).collect(),
            groups: (0..honest_groups)
                .map(|_| (0..nblocks).map(|_| BlockAcc::default()).collect())
                .collect(),
            resident: 0,
            peak: 0,
        };
        Self {
            map,
            d,
            nblocks,
            inner: Mutex::new(inner),
        }
    }

    /// Gradient length this reducer was built for.
    pub fn d(&self) -> usize {
        self.d
    }

    /// The worker → group partition.
    pub fn map(&self) -> &Arc<GroupMap> {
        &self.map
    }

    /// Start collecting `round`: drops any partial state of the previous
    /// round and resets the per-worker cursors.
    pub fn begin_round(&self, round: u64) {
        let mut inner = self.lock();
        inner.round = round;
        for c in inner.cursor.iter_mut() {
            *c = 0;
        }
        for f in inner.delivered.iter_mut() {
            *f = false;
        }
        for s in inner.stage.iter_mut() {
            s.clear();
            s.shrink_to_fit();
        }
        for g in inner.groups.iter_mut() {
            for b in g.iter_mut() {
                b.slots.clear();
                b.count = 0;
            }
        }
        inner.resident = 0;
    }

    /// Feed a whole `d`-length gradient from `worker` — the
    /// threaded-transport / coordinator-side ingest path. Iterates the
    /// block grid through the same tree merge the chunk path uses, so
    /// the two paths are bit-identical.
    pub fn ingest_full(&self, worker: usize, round: u64, gradient: &[f32]) -> FullIngest {
        if gradient.len() != self.d {
            return FullIngest::BadLen;
        }
        let mut guard = self.lock();
        let inner = &mut *guard;
        if round != inner.round {
            return FullIngest::Stale;
        }
        if inner.delivered[worker] {
            // Duplicate delivery (retried round): first one wins.
            return FullIngest::Accepted;
        }
        // A full ingest supersedes any staged chunk prefix.
        self.reset_worker(inner, worker, round);
        let (group, pos) = (self.map.group_of(worker), self.map.position(worker));
        for b in 0..self.nblocks {
            let lo = b * BLOCK;
            let hi = (lo + BLOCK).min(self.d);
            if lo >= hi {
                break;
            }
            let leaf = gradient[lo..hi].to_vec();
            inner.resident += leaf.len();
            merge_leaf(&mut inner.groups[group][b], 0, pos as u32, leaf, &mut inner.resident);
        }
        inner.cursor[worker] = self.d;
        inner.delivered[worker] = true;
        inner.peak = inner.peak.max(inner.resident);
        FullIngest::Accepted
    }

    /// Feed the next in-order chunk of `worker`'s round-`round` gradient
    /// (`offset` must equal the floats ingested so far; a new round
    /// starts at 0). Completed blocks merge into the group tree
    /// immediately; at most one partial block stays staged per worker.
    pub fn ingest_chunk(
        &self,
        worker: usize,
        round: u64,
        offset: usize,
        data: &[f32],
    ) -> ChunkIngest {
        let mut guard = self.lock();
        let inner = &mut *guard;
        if round != inner.round {
            return ChunkIngest::Stale;
        }
        if inner.worker_round[worker] != round {
            if offset != 0 {
                return ChunkIngest::Malformed;
            }
            self.reset_worker(inner, worker, round);
        }
        if offset != inner.cursor[worker] || offset + data.len() > self.d {
            return ChunkIngest::Malformed;
        }
        let (group, pos) = (self.map.group_of(worker), self.map.position(worker));
        let mut rest = data;
        while !rest.is_empty() {
            let cur = inner.cursor[worker];
            let block = cur / BLOCK;
            let block_lo = block * BLOCK;
            let block_len = (block_lo + BLOCK).min(self.d) - block_lo;
            let staged = cur - block_lo;
            crate::strict_assert_eq!(staged, inner.stage[worker].len());
            let take = (block_len - staged).min(rest.len());
            inner.stage[worker].extend_from_slice(&rest[..take]);
            inner.resident += take;
            inner.cursor[worker] = cur + take;
            rest = &rest[take..];
            if staged + take == block_len {
                let leaf = std::mem::take(&mut inner.stage[worker]);
                merge_leaf(
                    &mut inner.groups[group][block],
                    0,
                    pos as u32,
                    leaf,
                    &mut inner.resident,
                );
            }
        }
        inner.peak = inner.peak.max(inner.resident);
        if inner.cursor[worker] == self.d {
            inner.delivered[worker] = true;
            ChunkIngest::Completed
        } else {
            ChunkIngest::Accepted
        }
    }

    /// Whether `worker` completed a full gradient for `round` — the
    /// check behind the empty-slice delivery notifications the pooled
    /// and socket backends emit in grouped mode.
    pub fn delivered(&self, worker: usize, round: u64) -> bool {
        let inner = self.lock();
        inner.round == round && inner.delivered[worker]
    }

    /// Close the round: write each honest group's per-block mean into
    /// row `g` of `grads` (`honest_groups × d` or larger) and empty the
    /// trees. Returns, per honest group, whether any block received a
    /// contribution (a group with none needs the caller's fallback).
    pub fn finalize_into(&self, grads: &mut GradMatrix) -> Vec<bool> {
        let honest_groups = self.map.honest_groups();
        assert!(grads.n() >= honest_groups && grads.d() == self.d);
        let mut guard = self.lock();
        let inner = &mut *guard;
        let mut contributed = vec![false; honest_groups];
        for g in 0..honest_groups {
            let row = grads.row_mut(g);
            for b in 0..self.nblocks {
                let lo = b * BLOCK;
                let hi = (lo + BLOCK).min(self.d);
                if lo >= hi {
                    break;
                }
                // Split-borrow: the block state out of `inner.groups`,
                // the ledger stays addressable.
                let acc = std::mem::take(&mut inner.groups[g][b]);
                let (root, count, freed) = finalize_block(acc);
                inner.resident -= freed;
                let out = &mut row[lo..hi];
                match root {
                    Some(root) if count > 0 => {
                        contributed[g] = true;
                        let inv = 1.0f32 / count as f32;
                        for (o, v) in out.iter_mut().zip(root.iter()) {
                            *o = v * inv;
                        }
                        inner.resident -= root.len();
                    }
                    _ => out.fill(0.0),
                }
            }
        }
        // Ledger cross-check: every slot is gone; only staged partial
        // blocks of never-completed workers may remain resident.
        crate::strict_assert_eq!(
            inner.resident,
            inner.stage.iter().map(|s| s.len()).sum::<usize>()
        );
        contributed
    }

    /// High-water mark of resident gradient floats (tree partials +
    /// staged partial blocks) since construction — the arena-accounting
    /// probe behind the O(g·d + n·block) memory claim.
    pub fn peak_resident_floats(&self) -> usize {
        self.lock().peak
    }

    /// Currently resident gradient floats.
    pub fn resident_floats(&self) -> usize {
        self.lock().resident
    }

    fn reset_worker(&self, inner: &mut ReducerInner, worker: usize, round: u64) {
        inner.resident -= inner.stage[worker].len();
        inner.stage[worker].clear();
        inner.cursor[worker] = 0;
        inner.worker_round[worker] = round;
        inner.delivered[worker] = false;
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, ReducerInner> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }
}

/// Eagerly merge a leaf (or promoted node) into a block tree: while the
/// sibling `(level, idx ^ 1)` is live, fold the pair — odd index added
/// into even index, so the operand order is position-fixed — and carry
/// the result to `(level + 1, idx >> 1)`.
fn merge_leaf(acc: &mut BlockAcc, level: u32, idx: u32, buf: Vec<f32>, resident: &mut usize) {
    if level == 0 {
        acc.count += 1;
    }
    let (mut level, mut idx, mut buf) = (level, idx, buf);
    loop {
        let Some(other) = acc.slots.remove(&(level, idx ^ 1)) else {
            // Double delivery of a leaf position is excluded by the
            // per-worker cursor, so the landing slot must be free.
            crate::strict_assert!(!acc.slots.contains_key(&(level, idx)));
            acc.slots.insert((level, idx), buf);
            return;
        };
        *resident -= other.len();
        let (mut lo, hi) = if idx % 2 == 0 { (buf, other) } else { (other, buf) };
        for k in 0..hi.len() {
            lo[k] += hi[k];
        }
        buf = lo;
        level += 1;
        idx >>= 1;
    }
}

/// Collapse a block's leftover nodes bottom-up in `(level, index)`
/// order, passing lone nodes through absent siblings, until one root
/// remains. Returns `(root, leaf count, floats freed by merges)`.
fn finalize_block(acc: BlockAcc) -> (Option<Vec<f32>>, usize, usize) {
    let BlockAcc { mut slots, count } = acc;
    let mut freed = 0usize;
    while slots.len() > 1 {
        let &(level, idx) = slots.keys().next().expect("len > 1");
        let buf = slots.remove(&(level, idx)).expect("just seen");
        let parent = (level + 1, idx >> 1);
        match slots.get_mut(&parent) {
            Some(dst) => {
                // The occupant rose from the lower-index subtree (the
                // sweep is ascending), so occupant += incoming keeps the
                // left-to-right operand order.
                for k in 0..buf.len() {
                    dst[k] += buf[k];
                }
                freed += buf.len();
            }
            None => {
                slots.insert(parent, buf);
            }
        }
    }
    let root = slots.into_values().next();
    (root, count, freed)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grad_for(worker: usize, d: usize) -> Vec<f32> {
        (0..d)
            .map(|j| ((worker * 31 + j * 7) % 101) as f32 * 0.25 - 12.0)
            .collect()
    }

    fn finalize(r: &GroupReducer, honest_groups: usize) -> (GradMatrix, Vec<bool>) {
        let mut m = GradMatrix::zeros(honest_groups, r.d());
        let c = r.finalize_into(&mut m);
        (m, c)
    }

    #[test]
    fn partition_is_contiguous_and_total() {
        let map = GroupMap::new(16, 2, 8).unwrap();
        assert_eq!(map.honest_groups(), 7);
        assert_eq!(map.byz_groups(), 1);
        let mut seen = vec![false; 16];
        for g in 0..map.groups() {
            for &w in map.members(g) {
                assert!(!seen[w]);
                seen[w] = true;
                assert_eq!(map.group_of(w), g);
            }
        }
        assert!(seen.iter().all(|&s| s));
        // Byzantine ids live in the trailing groups only.
        assert!(map.members(7).iter().all(|&w| w >= 14));
    }

    #[test]
    fn partition_rejects_degenerate_shapes() {
        assert!(GroupMap::new(4, 0, 5).is_err()); // more groups than workers
        assert!(GroupMap::new(4, 4, 2).is_err()); // no honest group left
        assert!(GroupMap::new(8, 0, 0).is_err());
    }

    #[test]
    fn group_value_is_arrival_order_independent() {
        // 5 members, d spanning two blocks (tail block shorter): every
        // ingest order and chunking must produce bit-identical means.
        let d = BLOCK + 37;
        let map = GroupMap::new(5, 0, 1).unwrap();
        let reference = {
            let r = GroupReducer::new(Arc::clone(&map), d);
            r.begin_round(1);
            for w in 0..5 {
                assert_eq!(r.ingest_full(w, 1, &grad_for(w, d)), FullIngest::Accepted);
            }
            finalize(&r, 1).0.row(0).to_vec()
        };
        let orders: [[usize; 5]; 4] =
            [[4, 3, 2, 1, 0], [2, 0, 4, 1, 3], [1, 4, 0, 3, 2], [3, 1, 4, 2, 0]];
        for order in orders {
            let r = GroupReducer::new(Arc::clone(&map), d);
            r.begin_round(1);
            for &w in &order {
                r.ingest_full(w, 1, &grad_for(w, d));
            }
            assert_eq!(finalize(&r, 1).0.row(0), &reference[..], "order {order:?}");
        }
        // Interleaved chunk streaming at an unaligned chunk size.
        let r = GroupReducer::new(Arc::clone(&map), d);
        r.begin_round(1);
        let chunk = 1000usize;
        let grads: Vec<Vec<f32>> = (0..5).map(|w| grad_for(w, d)).collect();
        let mut offsets = [0usize; 5];
        loop {
            let mut progressed = false;
            for w in (0..5).rev() {
                let off = offsets[w];
                if off < d {
                    let hi = (off + chunk).min(d);
                    let out = r.ingest_chunk(w, 1, off, &grads[w][off..hi]);
                    assert!(matches!(out, ChunkIngest::Accepted | ChunkIngest::Completed));
                    offsets[w] = hi;
                    progressed = true;
                }
            }
            if !progressed {
                break;
            }
        }
        assert_eq!(finalize(&r, 1).0.row(0), &reference[..]);
    }

    #[test]
    fn mean_matches_direct_average_and_missing_members_rescale() {
        let d = 96;
        let map = GroupMap::new(4, 0, 1).unwrap();
        let r = GroupReducer::new(Arc::clone(&map), d);
        r.begin_round(3);
        for w in [0usize, 2, 3] {
            r.ingest_full(w, 3, &grad_for(w, d));
        }
        let (m, contributed) = finalize(&r, 1);
        assert_eq!(contributed, vec![true]);
        for j in 0..d {
            let want: f32 = (grad_for(0, d)[j] + grad_for(2, d)[j] + grad_for(3, d)[j]) / 3.0;
            assert!((m.row(0)[j] - want).abs() < 1e-5, "coord {j}");
        }
    }

    #[test]
    fn stale_malformed_and_empty_groups_are_handled() {
        let d = 64;
        let map = GroupMap::new(4, 0, 2).unwrap();
        let r = GroupReducer::new(Arc::clone(&map), d);
        r.begin_round(2);
        assert_eq!(r.ingest_full(0, 1, &grad_for(0, d)), FullIngest::Stale);
        assert_eq!(r.ingest_full(0, 2, &vec![0.0; d - 1]), FullIngest::BadLen);
        assert_eq!(r.ingest_chunk(0, 2, 5, &[1.0; 4]), ChunkIngest::Malformed);
        assert!(!r.delivered(0, 2));
        // Group 1 delivers, group 0 stays silent.
        r.ingest_full(2, 2, &grad_for(2, d));
        r.ingest_full(3, 2, &grad_for(3, d));
        let (m, contributed) = finalize(&r, 2);
        assert_eq!(contributed, vec![false, true]);
        assert!(m.row(0).iter().all(|&v| v == 0.0));
    }

    #[test]
    fn mid_stream_death_contributes_prefix_blocks_only() {
        // Worker 1 dies after its first block: that block's mean divides
        // by 2, the tail divides by 1 — and the worker is not delivered.
        let d = BLOCK + 10;
        let map = GroupMap::new(2, 0, 1).unwrap();
        let r = GroupReducer::new(Arc::clone(&map), d);
        r.begin_round(1);
        let (g0, g1) = (grad_for(0, d), grad_for(1, d));
        r.ingest_full(0, 1, &g0);
        assert_eq!(r.ingest_chunk(1, 1, 0, &g1[..BLOCK]), ChunkIngest::Accepted);
        assert!(!r.delivered(1, 1));
        let (m, contributed) = finalize(&r, 1);
        assert_eq!(contributed, vec![true]);
        assert!((m.row(0)[0] - (g0[0] + g1[0]) / 2.0).abs() < 1e-6);
        assert!((m.row(0)[BLOCK] - g0[BLOCK]).abs() < 1e-6);
    }

    #[test]
    fn chunked_ingest_is_bit_identical_to_full_ingest() {
        let d = 2 * BLOCK + 123;
        let map = GroupMap::new(3, 0, 1).unwrap();
        let full = {
            let r = GroupReducer::new(Arc::clone(&map), d);
            r.begin_round(7);
            for w in 0..3 {
                r.ingest_full(w, 7, &grad_for(w, d));
            }
            finalize(&r, 1).0.row(0).to_vec()
        };
        for chunk in [1usize, 64, BLOCK, BLOCK + 1, d] {
            let r = GroupReducer::new(Arc::clone(&map), d);
            r.begin_round(7);
            for w in 0..3 {
                let g = grad_for(w, d);
                let mut off = 0;
                while off < d {
                    let hi = (off + chunk).min(d);
                    r.ingest_chunk(w, 7, off, &g[off..hi]);
                    off = hi;
                }
                assert!(r.delivered(w, 7));
            }
            assert_eq!(finalize(&r, 1).0.row(0), &full[..], "chunk {chunk}");
        }
    }

    #[test]
    fn arena_accounting_never_approaches_the_flat_matrix() {
        // The satellite memory check: n = 512 workers, d = 1e5, g = 8.
        // In-order full-gradient ingest keeps at most a binary counter of
        // partials per group; the ledger's high-water mark must stay
        // within the O(g·d·log s + n·block) budget and far under the
        // flat path's n·d arena.
        let (n, d, g) = (512usize, 100_000usize, 8usize);
        let map = GroupMap::new(n, 0, g).unwrap();
        let r = GroupReducer::new(Arc::clone(&map), d);
        r.begin_round(1);
        let grad = vec![0.5f32; d];
        for w in 0..n {
            assert_eq!(r.ingest_full(w, 1, &grad), FullIngest::Accepted);
        }
        let s = n / g; // members per group
        let levels = usize::BITS as usize - s.leading_zeros() as usize; // ⌈log2 s⌉ + 1
        let budget = g * d * (levels + 1) + n * BLOCK;
        let peak = r.peak_resident_floats();
        assert!(peak <= budget, "peak {peak} floats exceeds budget {budget}");
        assert!(peak * 4 < n * d, "peak {peak} is not ≪ n·d = {}", n * d);
        let mut m = GradMatrix::zeros(g, d);
        let contributed = r.finalize_into(&mut m);
        assert!(contributed.iter().all(|&c| c));
        assert!(m.flat().iter().all(|&v| (v - 0.5).abs() < 1e-6));
        assert_eq!(r.resident_floats(), 0);
    }

    #[test]
    fn rounds_reset_state() {
        let d = 32;
        let map = GroupMap::new(2, 0, 1).unwrap();
        let r = GroupReducer::new(Arc::clone(&map), d);
        r.begin_round(1);
        r.ingest_chunk(0, 1, 0, &grad_for(0, d)[..16]);
        r.begin_round(2);
        assert_eq!(r.resident_floats(), 0);
        r.ingest_full(0, 2, &grad_for(0, d));
        r.ingest_full(1, 2, &grad_for(1, d));
        assert!(r.delivered(0, 2) && r.delivered(1, 2));
        let (m, c) = finalize(&r, 1);
        assert_eq!(c, vec![true]);
        let want: Vec<f32> = (0..d)
            .map(|j| (grad_for(0, d)[j] + grad_for(1, d)[j]) / 2.0)
            .collect();
        assert_eq!(m.row(0), &want[..]);
    }
}
