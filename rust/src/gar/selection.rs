//! [`Selection`] — the typed output of a GAR's O(n²) *selection* phase,
//! and the per-coordinate-range *combine* engine that consumes it.
//!
//! The paper's Theorem 2(ii) splits multi-Bulyan's cost into an O(n²)
//! gradient-selection step and an O(d) coordinate-wise combination step
//! that "parallelises like averaging". The two-phase [`crate::gar::Gar`]
//! API makes that split structural: `select` runs every score/distance
//! decision and returns a `Selection`; [`Selection::combine_range`] then
//! performs the purely coordinate-wise O(d) pass over any coordinate
//! range. Because every coordinate's arithmetic is independent of how the
//! ranges are partitioned, combining over *any* partition of `0..d` is
//! bit-identical to the one-shot aggregate (the
//! `select_combine_partition_bit_identical_to_aggregate` property in
//! `rust/tests/prop_gar.rs`) — which is what lets the coordinator fuse
//! combination with the SGD update and lets callers overlap combination
//! with gradient collection.
//!
//! A note on a rejected "optimization" (moved here from the old BULYAN
//! implementation, which materialised G^agr during selection): computing
//! each iteration's average as (running_sum − Σ non-selected)/m would cut
//! the row reads from m to f+2, but the running sum suffers catastrophic
//! f32 cancellation when a Byzantine row carries ±1e30-scale values (the
//! `infinity` attack) — the direct sum over the *selected* rows never
//! touches those. Correctness under adversarial inputs beats the constant
//! factor here.

use crate::tensor::{add_assign, insertion_sort, median_of_buf, scale, small_median_sorting, GradMatrix};
use crate::Result;

/// Below this n the per-coordinate median / trim uses insertion sort (see
/// `tensor::select::insertion_sort`); above, introselect.
const SMALL_N: usize = 64;

/// How the O(d) combine phase consumes a [`Selection`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CombinePlan {
    /// Coordinate-wise average of `rows` (AVERAGE, MULTI-KRUM).
    MeanRows,
    /// Copy the single row in `rows` (KRUM).
    CopyRow,
    /// Per-coordinate median over all `n` rows (MEDIAN).
    CoordMedian,
    /// Per-coordinate trimmed mean over all `n` rows, dropping the `trim`
    /// largest and `trim` smallest values (TRIMMED-MEAN).
    CoordTrimmed { trim: usize },
    /// BULYAN family: per coordinate, median over the θ winners in `rows`
    /// (G^ext), then average of the `beta` values closest to it — drawn
    /// from the per-iteration MULTI-KRUM averages (G^agr, `multi`) or the
    /// winners themselves (classic BULYAN).
    BulyanTrim { beta: usize, multi: bool },
}

/// Reusable per-call working buffers of the combine phase (the
/// per-coordinate column and deviation pairs). One per concurrent combine
/// stream — the coordinator keeps one per coordinate-range shard
/// (`GarScratch::shards`) so threads never share hot buffers.
#[derive(Debug, Default)]
pub struct CombineScratch {
    /// Per-coordinate working column (n or θ values).
    pub(crate) column: Vec<f32>,
    /// (deviation, value) pairs for the per-coordinate β-selection.
    pub(crate) pairs: Vec<(f32, f32)>,
}

impl CombineScratch {
    pub fn new() -> Self {
        Self::default()
    }

    pub(crate) fn capacity_bytes(&self) -> usize {
        self.column.capacity() * std::mem::size_of::<f32>()
            + self.pairs.capacity() * std::mem::size_of::<(f32, f32)>()
    }
}

/// Everything a GAR's O(n²) selection phase decided, in row indices —
/// no gradient data. Feed it (with the same `GradMatrix`) to
/// [`combine_range`](Self::combine_range) to produce any coordinate range
/// of the aggregate.
#[derive(Debug, Clone)]
pub struct Selection {
    plan: CombinePlan,
    /// Number of rows the input matrix must have.
    n: usize,
    /// Primary selected rows — plan-specific meaning:
    /// `MeanRows` → the averaged rows (MULTI-KRUM: ascending score);
    /// `CopyRow` → exactly one row; `CoordMedian`/`CoordTrimmed` → all
    /// `n` rows (every worker's value can reach the output of some
    /// coordinate); `BulyanTrim` → the θ extracted winners (G^ext), in
    /// iteration order.
    pub(crate) rows: Vec<usize>,
    /// `BulyanTrim` with `multi`: flattened per-iteration MULTI-KRUM
    /// selections; iteration `t` owns `sets[set_offsets[t]..set_offsets[t+1]]`.
    pub(crate) sets: Vec<usize>,
    pub(crate) set_offsets: Vec<usize>,
    /// Group provenance under two-level aggregation: when the selection
    /// ran over group rows rather than worker rows, the partition that
    /// produced them — so precision/recall metrics can attribute a
    /// selected group back to its underlying workers
    /// ([`attributed_workers`](Self::attributed_workers)). `None` on the
    /// flat path. Cleared by every `reset`, so the owning coordinator
    /// re-stamps it after each `select_into`.
    groups: Option<std::sync::Arc<super::group::GroupMap>>,
}

impl Default for Selection {
    fn default() -> Self {
        Self {
            plan: CombinePlan::MeanRows,
            n: 0,
            rows: Vec::new(),
            sets: Vec::new(),
            set_offsets: Vec::new(),
            groups: None,
        }
    }
}

impl Selection {
    /// Clear all buffers and start a fresh selection for an `n`-row input
    /// under `plan` (grow-only: capacities are retained across rounds).
    pub(crate) fn reset(&mut self, plan: CombinePlan, n: usize) {
        self.plan = plan;
        self.n = n;
        self.rows.clear();
        self.sets.clear();
        self.set_offsets.clear();
        self.groups = None;
    }

    /// Stamp the selection with the worker → group partition its rows
    /// were aggregated under (two-level mode).
    pub fn set_group_provenance(&mut self, map: std::sync::Arc<super::group::GroupMap>) {
        self.groups = Some(map);
    }

    /// The group partition behind this selection's rows, if it ran over
    /// group rows.
    pub fn group_provenance(&self) -> Option<&std::sync::Arc<super::group::GroupMap>> {
        self.groups.as_ref()
    }

    /// The *worker* ids this selection attributes to: on the flat path,
    /// [`selected_rows`](Self::selected_rows) verbatim; under group
    /// provenance, the union of the selected groups' members, ascending —
    /// which keeps selection precision/recall metrics expressed in
    /// workers no matter which level the GAR ran at.
    pub fn attributed_workers(&self) -> Vec<usize> {
        match &self.groups {
            None => self.rows.clone(),
            Some(map) => {
                let mut workers: Vec<usize> = self
                    .rows
                    .iter()
                    .flat_map(|&g| map.members(g).iter().copied())
                    .collect();
                workers.sort_unstable();
                workers.dedup();
                workers
            }
        }
    }

    pub fn plan(&self) -> CombinePlan {
        self.plan
    }

    /// Number of input rows the combine phase expects.
    pub fn n(&self) -> usize {
        self.n
    }

    /// The rows this selection reads in the combine phase — the
    /// "which workers did the rule pick" diagnostic behind
    /// `MetricsRecorder::record_selection` and `RoundOutcome::selected`.
    /// Coordinate-wise plans (`CoordMedian`/`CoordTrimmed`) report all
    /// `n` rows: which worker wins is decided per coordinate.
    pub fn selected_rows(&self) -> &[usize] {
        &self.rows
    }

    /// Bytes currently held by the index buffers (metrics/perf reports).
    pub fn capacity_bytes(&self) -> usize {
        (self.rows.capacity() + self.sets.capacity() + self.set_offsets.capacity())
            * std::mem::size_of::<usize>()
    }

    /// Check this selection is internally consistent and applicable to
    /// `grads`. The combine fan-outs validate once, then run the
    /// unchecked per-range engine.
    pub fn validate(&self, grads: &GradMatrix) -> Result<()> {
        anyhow::ensure!(
            grads.n() == self.n,
            "selection is for n={} rows, matrix has {}",
            self.n,
            grads.n()
        );
        anyhow::ensure!(
            self.rows.iter().all(|&r| r < self.n),
            "selection row index out of range (n={})",
            self.n
        );
        match self.plan {
            CombinePlan::MeanRows => {
                anyhow::ensure!(!self.rows.is_empty(), "mean-rows selection is empty");
            }
            CombinePlan::CopyRow => {
                anyhow::ensure!(
                    self.rows.len() == 1,
                    "copy-row selection must hold exactly one row, got {}",
                    self.rows.len()
                );
            }
            CombinePlan::CoordMedian => {
                anyhow::ensure!(self.n >= 1, "median selection over an empty matrix");
            }
            CombinePlan::CoordTrimmed { trim } => {
                anyhow::ensure!(
                    self.n > 2 * trim,
                    "trimmed selection: n={} leaves nothing after trimming {trim} per side",
                    self.n
                );
            }
            CombinePlan::BulyanTrim { beta, multi } => {
                let theta = self.rows.len();
                anyhow::ensure!(theta >= 1, "bulyan selection has no winners");
                anyhow::ensure!(
                    (1..=theta).contains(&beta),
                    "bulyan selection: beta={beta} not in [1, θ={theta}]"
                );
                if multi {
                    anyhow::ensure!(
                        self.set_offsets.len() == theta + 1
                            && self.set_offsets[0] == 0
                            && *self.set_offsets.last().unwrap() == self.sets.len()
                            && self.set_offsets.windows(2).all(|w| w[0] < w[1]),
                        "bulyan selection: malformed per-iteration sets"
                    );
                    anyhow::ensure!(
                        self.sets.iter().all(|&r| r < self.n),
                        "bulyan selection set row out of range"
                    );
                } else {
                    anyhow::ensure!(
                        self.sets.is_empty() && self.set_offsets.is_empty(),
                        "classic bulyan selection must not carry G^agr sets"
                    );
                }
            }
        }
        Ok(())
    }

    /// Combine coordinates `[offset, offset + out.len())` of the aggregate
    /// into `out`. Pure O(|range|·n) coordinate-wise work; any partition
    /// of `0..d` into ranges reproduces the one-shot aggregate bit for
    /// bit (coordinates never interact).
    pub fn combine_range(
        &self,
        grads: &GradMatrix,
        offset: usize,
        out: &mut [f32],
        cs: &mut CombineScratch,
    ) -> Result<()> {
        self.validate(grads)?;
        anyhow::ensure!(
            offset + out.len() <= grads.d(),
            "combine range [{offset}, {}) exceeds d={}",
            offset + out.len(),
            grads.d()
        );
        self.combine_range_unchecked(grads, offset, out, cs);
        Ok(())
    }

    /// The per-range combine engine. Callers must have run
    /// [`validate`](Self::validate) (and the range bound check) first —
    /// the sharded fan-outs validate once and then call this per shard.
    pub(crate) fn combine_range_unchecked(
        &self,
        grads: &GradMatrix,
        offset: usize,
        out: &mut [f32],
        cs: &mut CombineScratch,
    ) {
        let len = out.len();
        if len == 0 {
            return;
        }
        // Selection index bounds: the contract the skipped `validate`
        // would have enforced — every selected row exists in `grads` and
        // the coordinate range fits. Per-shard call, so feature-gated
        // rather than a release-mode re-validation.
        crate::strict_assert!(self.n == grads.n() && offset + len <= grads.d());
        crate::strict_assert!(self.rows.iter().all(|&r| r < grads.n()));
        match self.plan {
            CombinePlan::CopyRow => {
                let row = self.rows[0];
                out.copy_from_slice(&grads.row(row)[offset..offset + len]);
            }
            CombinePlan::MeanRows => {
                // Zero, add the rows in selection order, scale — the
                // single arithmetic definition behind AVERAGE and
                // MULTI-KRUM (and bit-identical for every partition).
                out.fill(0.0);
                for &i in &self.rows {
                    add_assign(out, &grads.row(i)[offset..offset + len]);
                }
                scale(out, 1.0 / self.rows.len() as f32);
            }
            CombinePlan::CoordMedian => {
                let n = self.n;
                let small = n <= SMALL_N;
                cs.column.clear();
                cs.column.resize(n, 0.0);
                let col = &mut cs.column;
                for (k, o) in out.iter_mut().enumerate() {
                    let j = offset + k;
                    for i in 0..n {
                        col[i] = grads.row(i)[j];
                    }
                    *o = if small {
                        small_median_sorting(col)
                    } else {
                        median_of_buf(col)
                    };
                }
            }
            CombinePlan::CoordTrimmed { trim: f } => {
                let n = self.n;
                let keep = n - 2 * f;
                cs.column.clear();
                cs.column.resize(n, 0.0);
                let col = &mut cs.column;
                for (k, o) in out.iter_mut().enumerate() {
                    let j = offset + k;
                    for i in 0..n {
                        col[i] = grads.row(i)[j];
                    }
                    // Order so that [f, n-f) holds the middle n-2f values.
                    if f > 0 {
                        if n <= SMALL_N {
                            insertion_sort(col);
                        } else {
                            col.select_nth_unstable_by(f - 1, f32::total_cmp);
                            col[f..].select_nth_unstable_by(keep - 1, f32::total_cmp);
                        }
                    }
                    // LINT: reduce-ok -- per-coordinate column of n ≤ 64
                    // values, summed sequentially in index order after a
                    // deterministic partition — not a d-length buffer.
                    *o = col[f..n - f].iter().sum::<f32>() / keep as f32;
                }
            }
            CombinePlan::BulyanTrim { beta, multi } => {
                self.bulyan_trim_range(grads, offset, out, cs, beta, multi);
            }
        }
    }

    /// Per-coordinate BULYAN tail: median of the θ winners, then average
    /// of the β values (of G^agr when `multi`, of the winners otherwise)
    /// closest to it. G^agr is computed here, per coordinate, from the
    /// recorded per-iteration row sets — the selection phase stores no
    /// gradient data at all, so this pass is callable over any coordinate
    /// range.
    ///
    /// Hot loop (runs per coordinate): insertion-sort median over θ ≤ 64
    /// values and a β-step partial selection sort over reused
    /// `(deviation, value)` pairs — zero allocation, no introselect
    /// overhead.
    fn bulyan_trim_range(
        &self,
        grads: &GradMatrix,
        offset: usize,
        out: &mut [f32],
        cs: &mut CombineScratch,
        beta: usize,
        multi: bool,
    ) {
        let theta = self.rows.len();
        cs.column.clear();
        cs.column.resize(theta, 0.0);
        cs.pairs.clear();
        cs.pairs.resize(theta, (0.0, 0.0));
        let col = &mut cs.column;
        let pairs = &mut cs.pairs;
        for (k, o) in out.iter_mut().enumerate() {
            let j = offset + k;
            for (t, &w) in self.rows.iter().enumerate() {
                col[t] = grads.row(w)[j];
            }
            // Fill the candidate values before the median sorts `col` in
            // place. G^agr: zero-accumulate the iteration's rows in
            // recorded (ascending-score) order, then scale — the same
            // arithmetic sequence the mean-rows plan uses.
            if multi {
                for t in 0..theta {
                    let set = &self.sets[self.set_offsets[t]..self.set_offsets[t + 1]];
                    let mut acc = 0.0f32;
                    for &i in set {
                        acc += grads.row(i)[j];
                    }
                    pairs[t].1 = acc * (1.0 / set.len() as f32);
                }
            } else {
                for t in 0..theta {
                    pairs[t].1 = col[t];
                }
            }
            let median = small_median_sorting(col);
            for p in pairs.iter_mut() {
                p.0 = (p.1 - median).abs();
            }
            // Partial selection sort: move the β smallest deviations to
            // the front (β·θ compares; β and θ are both ≤ n ≤ 64 here).
            let mut acc = 0.0f32;
            for b in 0..beta {
                let mut best = b;
                for t in (b + 1)..theta {
                    if pairs[t].0 < pairs[best].0 {
                        best = t;
                    }
                }
                pairs.swap(b, best);
                acc += pairs[b].1;
            }
            *o = acc / beta as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn matrix() -> GradMatrix {
        GradMatrix::from_fn(5, 7, |i, j| (i * 10 + j) as f32)
    }

    fn mean_sel(n: usize, rows: &[usize]) -> Selection {
        let mut sel = Selection::default();
        sel.reset(CombinePlan::MeanRows, n);
        sel.rows.extend_from_slice(rows);
        sel
    }

    #[test]
    fn copy_row_combines_any_partition() {
        let g = matrix();
        let mut sel = Selection::default();
        sel.reset(CombinePlan::CopyRow, 5);
        sel.rows.push(3);
        let mut cs = CombineScratch::default();
        let mut out = vec![0.0; 7];
        sel.combine_range(&g, 0, &mut out[..4], &mut cs).unwrap();
        sel.combine_range(&g, 4, &mut out[4..], &mut cs).unwrap();
        assert_eq!(out, g.row(3));
        assert_eq!(sel.selected_rows(), &[3]);
    }

    #[test]
    fn mean_rows_matches_matrix_mean() {
        let g = matrix();
        let sel = mean_sel(5, &[0, 2, 4]);
        let mut cs = CombineScratch::default();
        let mut out = vec![0.0; 7];
        sel.combine_range(&g, 0, &mut out, &mut cs).unwrap();
        assert_eq!(out, g.mean_of_rows(&[0, 2, 4]));
    }

    #[test]
    fn validation_rejects_malformed_selections() {
        let g = matrix();
        let mut cs = CombineScratch::default();
        let mut out = vec![0.0; 7];
        // Wrong n.
        let sel = mean_sel(4, &[0]);
        assert!(sel.combine_range(&g, 0, &mut out, &mut cs).is_err());
        // Row out of range.
        let sel = mean_sel(5, &[5]);
        assert!(sel.combine_range(&g, 0, &mut out, &mut cs).is_err());
        // Empty mean.
        let sel = mean_sel(5, &[]);
        assert!(sel.combine_range(&g, 0, &mut out, &mut cs).is_err());
        // Range past d.
        let sel = mean_sel(5, &[0]);
        assert!(sel.combine_range(&g, 4, &mut out, &mut cs).is_err());
        // Copy-row with two rows.
        let mut sel = Selection::default();
        sel.reset(CombinePlan::CopyRow, 5);
        sel.rows.extend_from_slice(&[0, 1]);
        assert!(sel.combine_range(&g, 0, &mut out, &mut cs).is_err());
    }

    #[test]
    fn reset_reuses_buffers() {
        let mut sel = mean_sel(5, &[0, 1, 2]);
        let cap = sel.rows.capacity();
        sel.reset(CombinePlan::CoordMedian, 5);
        assert!(sel.rows.is_empty());
        assert_eq!(sel.rows.capacity(), cap);
        assert!(sel.capacity_bytes() > 0);
    }
}
