//! Gradient Aggregation Rules (GARs) — the paper's contribution.
//!
//! A GAR consumes the `n × d` matrix of worker gradient proposals for one
//! SGD step and produces the single `d`-vector the parameter server applies
//! (Equation 2 of the paper). The rules implemented here:
//!
//! | Rule | Resilience | Cost | Requires |
//! |---|---|---|---|
//! | [`Average`] | none (one Byzantine worker suffices to break it) | O(nd) | n ≥ 1 |
//! | [`CoordMedian`] | weak | O(nd) | n ≥ 2f+1 |
//! | [`TrimmedMean`] | weak | O(nd) | n ≥ 2f+1 |
//! | [`Krum`] | weak (α,f) | O(n²d) | n ≥ 2f+3 |
//! | [`MultiKrum`] | weak (α,f), m̃/n slowdown | O(n²d) | n ≥ 2f+3 |
//! | [`Bulyan`] | strong | O(n²d) | n ≥ 4f+3 |
//! | [`MultiBulyan`] | strong, m̃/n slowdown | O(n²d) | n ≥ 4f+3 |
//!
//! All implementations follow Algorithm 1 of the paper; `MultiBulyan` is
//! literally `BULYAN ∘ MULTI-KRUM` with the distance matrix computed once
//! and score recomputation done on the cached matrix (the optimisation the
//! paper's §V-B calls out).
//!
//! Two entry points per rule: [`Gar::aggregate`] (allocates its scratch)
//! and [`Gar::aggregate_with_scratch`] (zero-allocation steady state — the
//! Fig. 2 benchmark path).

mod average;
mod bulyan;
mod krum;
mod median;
mod pairwise;
mod scratch;
mod trimmed_mean;

pub use average::Average;
pub use bulyan::{Bulyan, MultiBulyan};
pub use krum::{krum_scores_from_distances, Krum, MultiKrum};
pub use median::CoordMedian;
pub use pairwise::{
    pairwise_sq_distances, pairwise_sq_distances_into, pairwise_sq_distances_sharded, SHARD_D,
};
pub use scratch::GarScratch;
pub use trimmed_mean::TrimmedMean;

use crate::runtime::Parallelism;
use crate::tensor::GradMatrix;
use crate::Result;

/// A gradient aggregation rule with a fixed `(n, f)` contract.
///
/// `n` is the number of workers whose gradients arrive each round and `f`
/// the number of arbitrary (Byzantine) failures tolerated; the constructor
/// of each rule validates its `n ≥ g(f)` requirement, so an instantiated
/// `Gar` can assume well-formed inputs.
pub trait Gar: Send + Sync {
    /// Human-readable rule name (stable; used in configs, CSV and logs).
    fn name(&self) -> &'static str;

    /// Number of workers this instance was built for.
    fn n(&self) -> usize;

    /// Number of Byzantine workers tolerated.
    fn f(&self) -> usize;

    /// Aggregate `grads` (must be `n × d`) into a fresh `d`-vector.
    fn aggregate(&self, grads: &GradMatrix) -> Result<Vec<f32>> {
        let mut out = vec![0.0f32; grads.d()];
        let mut scratch = GarScratch::default();
        self.aggregate_with_scratch(grads, &mut out, &mut scratch)?;
        Ok(out)
    }

    /// Aggregate into `out`, reusing `scratch` across calls (no allocation
    /// after the first round with a given shape).
    fn aggregate_with_scratch(
        &self,
        grads: &GradMatrix,
        out: &mut [f32],
        scratch: &mut GarScratch,
    ) -> Result<()>;

    /// How many of the `n` input gradients influence the output (the `m̃`
    /// of the slowdown theorems; `n` for averaging, 1 for Krum/median).
    fn gradients_used(&self) -> usize;
}

/// Sharded per-coordinate mean of `rows` of `grads` into `out`: zero, add
/// the rows in the given order, scale by `1/rows.len()`. The single
/// implementation behind AVERAGE, MULTI-KRUM's selection average and
/// BULYAN's per-iteration `G^agr` — one arithmetic definition keeps the
/// bit-identical parallel/sequential contract from diverging per rule.
pub(crate) fn sharded_mean_rows_into(
    par: &Parallelism,
    grads: &GradMatrix,
    rows: &[usize],
    out: &mut [f32],
) {
    debug_assert!(!rows.is_empty());
    let inv = 1.0 / rows.len() as f32;
    crate::runtime::shard_slice_stateless(
        par,
        out,
        crate::runtime::MIN_COORDS_PER_SHARD,
        |offset, range| {
            range.fill(0.0);
            for &i in rows {
                crate::tensor::add_assign(range, &grads.row(i)[offset..offset + range.len()]);
            }
            crate::tensor::scale(range, inv);
        },
    );
}

/// Validate the common preconditions shared by all rules.
pub(crate) fn check_shape(rule: &str, grads: &GradMatrix, n: usize, out: &[f32]) -> Result<()> {
    anyhow::ensure!(
        grads.n() == n,
        "{rule}: expected {n} gradients, got {}",
        grads.n()
    );
    anyhow::ensure!(
        out.len() == grads.d(),
        "{rule}: output length {} != d {}",
        out.len(),
        grads.d()
    );
    anyhow::ensure!(grads.d() > 0, "{rule}: empty gradients (d = 0)");
    Ok(())
}

/// Enumeration of the available rules — the config-file / CLI surface.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum GarKind {
    Average,
    Median,
    TrimmedMean,
    Krum,
    MultiKrum,
    Bulyan,
    MultiBulyan,
}

impl GarKind {
    /// All kinds, in the order the paper's figures present them.
    pub const ALL: [GarKind; 7] = [
        GarKind::Average,
        GarKind::Median,
        GarKind::TrimmedMean,
        GarKind::Krum,
        GarKind::MultiKrum,
        GarKind::Bulyan,
        GarKind::MultiBulyan,
    ];

    /// Minimum `n` for a given `f` (the rule's resilience precondition).
    pub fn min_n(self, f: usize) -> usize {
        match self {
            GarKind::Average => 1.max(f + 1),
            GarKind::Median | GarKind::TrimmedMean => 2 * f + 1,
            GarKind::Krum | GarKind::MultiKrum => 2 * f + 3,
            GarKind::Bulyan | GarKind::MultiBulyan => 4 * f + 3,
        }
    }

    /// Build the rule for an `(n, f)` contract (sequential execution).
    pub fn instantiate(self, n: usize, f: usize) -> Result<Box<dyn Gar>> {
        self.instantiate_parallel(n, f, &Parallelism::sequential())
    }

    /// Build the rule for an `(n, f)` contract running its O(d) / O(n²d)
    /// passes on `par` (the `threads` experiment-config knob). Outputs are
    /// bit-identical to the sequential instantiation for every thread
    /// count — see `runtime::pool` and `tests/prop_gar.rs`.
    pub fn instantiate_parallel(
        self,
        n: usize,
        f: usize,
        par: &Parallelism,
    ) -> Result<Box<dyn Gar>> {
        Ok(match self {
            GarKind::Average => Box::new(Average::new(n)?.with_parallelism(par.clone())),
            GarKind::Median => Box::new(CoordMedian::new(n, f)?.with_parallelism(par.clone())),
            GarKind::TrimmedMean => {
                Box::new(TrimmedMean::new(n, f)?.with_parallelism(par.clone()))
            }
            GarKind::Krum => Box::new(Krum::new(n, f)?.with_parallelism(par.clone())),
            GarKind::MultiKrum => Box::new(MultiKrum::new(n, f)?.with_parallelism(par.clone())),
            GarKind::Bulyan => Box::new(Bulyan::new(n, f)?.with_parallelism(par.clone())),
            GarKind::MultiBulyan => {
                Box::new(MultiBulyan::new(n, f)?.with_parallelism(par.clone()))
            }
        })
    }

    pub fn as_str(self) -> &'static str {
        match self {
            GarKind::Average => "average",
            GarKind::Median => "median",
            GarKind::TrimmedMean => "trimmed-mean",
            GarKind::Krum => "krum",
            GarKind::MultiKrum => "multi-krum",
            GarKind::Bulyan => "bulyan",
            GarKind::MultiBulyan => "multi-bulyan",
        }
    }
}

impl std::fmt::Display for GarKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl std::str::FromStr for GarKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().replace('_', "-").as_str() {
            "average" | "mean" | "avg" => Ok(GarKind::Average),
            "median" | "coord-median" => Ok(GarKind::Median),
            "trimmed-mean" | "trmean" => Ok(GarKind::TrimmedMean),
            "krum" => Ok(GarKind::Krum),
            "multi-krum" | "multikrum" | "mkrum" => Ok(GarKind::MultiKrum),
            "bulyan" => Ok(GarKind::Bulyan),
            "multi-bulyan" | "multibulyan" | "mbulyan" => Ok(GarKind::MultiBulyan),
            other => anyhow::bail!(
                "unknown GAR '{other}' (expected one of: average, median, \
                 trimmed-mean, krum, multi-krum, bulyan, multi-bulyan)"
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_roundtrip_via_str() {
        for kind in GarKind::ALL {
            let parsed: GarKind = kind.as_str().parse().unwrap();
            assert_eq!(parsed, kind);
        }
        assert!("frobnicate".parse::<GarKind>().is_err());
    }

    #[test]
    fn min_n_ordering() {
        // Stronger guarantees require more workers.
        for f in 0..5 {
            assert!(GarKind::MultiBulyan.min_n(f) >= GarKind::MultiKrum.min_n(f));
            assert!(GarKind::MultiKrum.min_n(f) >= GarKind::Median.min_n(f));
        }
        assert_eq!(GarKind::MultiKrum.min_n(2), 7);
        assert_eq!(GarKind::MultiBulyan.min_n(2), 11);
    }

    #[test]
    fn instantiate_rejects_undersized_n() {
        assert!(GarKind::MultiBulyan.instantiate(10, 2).is_err());
        assert!(GarKind::MultiBulyan.instantiate(11, 2).is_ok());
        assert!(GarKind::Krum.instantiate(6, 2).is_err());
        assert!(GarKind::Krum.instantiate(7, 2).is_ok());
    }

    #[test]
    fn all_rules_agree_on_identical_gradients() {
        // When every worker proposes the same vector, every GAR must
        // return exactly that vector.
        let n = 11;
        let f = 2;
        let g: Vec<f32> = (0..32).map(|i| i as f32 * 0.25 - 3.0).collect();
        let rows = vec![g.clone(); n];
        let grads = GradMatrix::from_rows(&rows);
        for kind in GarKind::ALL {
            let gar = kind.instantiate(n, f).unwrap();
            let out = gar.aggregate(&grads).unwrap();
            for (a, b) in out.iter().zip(&g) {
                assert!(
                    (a - b).abs() < 1e-5,
                    "{kind}: expected identical output"
                );
            }
        }
    }
}
