//! Gradient Aggregation Rules (GARs) — the paper's contribution.
//!
//! A GAR consumes the `n × d` matrix of worker gradient proposals for one
//! SGD step and produces the single `d`-vector the parameter server applies
//! (Equation 2 of the paper). The rules implemented here:
//!
//! | Rule | Resilience | Cost | Requires | Combine plan |
//! |---|---|---|---|---|
//! | [`Average`] | none (one Byzantine worker suffices to break it) | O(nd) | n ≥ 1 | mean of all rows |
//! | [`CoordMedian`] | weak | O(nd) | n ≥ 2f+1 | per-coordinate median |
//! | [`TrimmedMean`] | weak | O(nd) | n ≥ 2f+1 | per-coordinate trim |
//! | [`Krum`] | weak (α,f) | O(n²d) | n ≥ 2f+3 | copy the winner row |
//! | [`MultiKrum`] | weak (α,f), m̃/n slowdown | O(n²d) | n ≥ 2f+3 | mean of m rows |
//! | [`Bulyan`] | strong | O(n²d) | n ≥ 4f+3 | median-then-β trim (G^ext) |
//! | [`MultiBulyan`] | strong, m̃/n slowdown | O(n²d) | n ≥ 4f+3 | median-then-β trim (G^agr) |
//!
//! # Two-phase API
//!
//! Theorem 2(ii) splits a GAR's cost into an O(n²) gradient-*selection*
//! step and an O(d) coordinate-wise *combination* step that parallelises
//! like averaging. The [`Gar`] trait mirrors that split:
//!
//! * [`Gar::select_into`] / [`Gar::select`] — **phase 1**: all O(n²d)
//!   decision work (the pairwise distance matrix, Krum scoring, BULYAN's
//!   iterative extraction) producing a typed [`Selection`]: the selected
//!   row sets, the per-coordinate trim parameters, and the per-iteration
//!   structure BULYAN needs. No gradient data is stored — only indices.
//! * [`Gar::combine`] — **phase 2**: the purely coordinate-wise O(d)
//!   pass, callable per coordinate range. Combining any partition of
//!   `0..d` is bit-identical to the one-shot aggregate (enforced by
//!   `rust/tests/prop_gar.rs`), which is what lets the coordinator fuse
//!   combination with the SGD update (`coordinator::core`) and lets
//!   callers overlap combination with collection.
//!
//! The legacy one-shot entry points are default methods over the two
//! phases: [`Gar::aggregate`] (allocates its scratch) and
//! [`Gar::aggregate_with_scratch`] (zero-allocation steady state — the
//! Fig. 2 benchmark path, `select_into` + a sharded `combine` over the
//! full range on the rule's [`Parallelism`]). External behaviour and the
//! bit-identical parallel/sequential guarantee are unchanged.
//!
//! `MultiBulyan` is literally `BULYAN ∘ MULTI-KRUM` with the distance
//! matrix computed once and score recomputation done on the cached matrix
//! (the optimisation the paper's §V-B calls out); all implementations
//! follow Algorithm 1.
//!
//! # Pre-aggregation pipeline
//!
//! [`pipeline`] composes a GAR with worker-side pre-aggregation stages
//! (resilient momentum, Farhadkhani et al. 2022). The config/CLI spec
//! grammar is
//!
//! ```text
//! spec  := (stage "+")* gar
//! stage := "rmom(" beta ")"          # resilient momentum, beta ∈ [0, 1)
//! gar   := average | median | trimmed-mean | krum | multi-krum
//!        | bulyan | multi-bulyan
//! ```
//!
//! e.g. `gar = "rmom(0.9)+multi-bulyan"` — see [`pipeline::GarSpec`].

mod average;
mod bulyan;
pub mod group;
mod krum;
mod median;
mod pairwise;
pub mod pipeline;
mod scratch;
mod selection;
mod trimmed_mean;

pub use average::Average;
pub use bulyan::{Bulyan, MultiBulyan};
pub use group::{GroupMap, GroupReducer};
pub use krum::{krum_scores_from_distances, Krum, MultiKrum};
pub use median::CoordMedian;
pub use pairwise::{
    pairwise_sq_distances, pairwise_sq_distances_into, pairwise_sq_distances_sharded, SHARD_D,
};
pub use pipeline::{GarSpec, PreAggregate, ResilientMomentum, StageSpec};
pub use scratch::GarScratch;
pub use selection::{CombinePlan, CombineScratch, Selection};
pub use trimmed_mean::TrimmedMean;

use crate::runtime::{shard_slice, Parallelism, MIN_COORDS_PER_SHARD};
use crate::tensor::GradMatrix;
use crate::Result;

/// A gradient aggregation rule with a fixed `(n, f)` contract.
///
/// `n` is the number of workers whose gradients arrive each round and `f`
/// the number of arbitrary (Byzantine) failures tolerated; the constructor
/// of each rule validates its `n ≥ g(f)` requirement, so an instantiated
/// `Gar` can assume well-formed inputs.
pub trait Gar: Send + Sync {
    /// Human-readable rule name (stable; used in configs, CSV and logs).
    fn name(&self) -> &'static str;

    /// Number of workers this instance was built for.
    fn n(&self) -> usize;

    /// Number of Byzantine workers tolerated.
    fn f(&self) -> usize;

    /// The execution policy the rule's sharded O(n²d)/O(d) passes run on.
    fn parallelism(&self) -> &Parallelism;

    /// Phase 1 — run all O(n²) selection work on `grads` (must be
    /// `n × d`) and record the decisions into `sel` (buffers reused; no
    /// allocation in the steady state beyond tiny index vectors).
    fn select_into(
        &self,
        grads: &GradMatrix,
        scratch: &mut GarScratch,
        sel: &mut Selection,
    ) -> Result<()>;

    /// Phase 1, allocating convenience: a fresh [`Selection`].
    fn select(&self, grads: &GradMatrix, scratch: &mut GarScratch) -> Result<Selection> {
        let mut sel = Selection::default();
        self.select_into(grads, scratch, &mut sel)?;
        Ok(sel)
    }

    /// Phase 2 — combine coordinates `[offset, offset + out.len())` of
    /// the aggregate from a prior selection. Callable over any partition
    /// of `0..d`; every partition is bit-identical to the one-shot
    /// aggregate. The default delegates to [`Selection::combine_range`].
    fn combine(
        &self,
        sel: &Selection,
        grads: &GradMatrix,
        offset: usize,
        out: &mut [f32],
        cs: &mut CombineScratch,
    ) -> Result<()> {
        sel.combine_range(grads, offset, out, cs)
    }

    /// Aggregate `grads` (must be `n × d`) into a fresh `d`-vector.
    fn aggregate(&self, grads: &GradMatrix) -> Result<Vec<f32>> {
        let mut out = vec![0.0f32; grads.d()];
        let mut scratch = GarScratch::default();
        self.aggregate_with_scratch(grads, &mut out, &mut scratch)?;
        Ok(out)
    }

    /// Aggregate into `out`, reusing `scratch` across calls (no allocation
    /// after the first round with a given shape): `select_into` followed
    /// by a `combine` sharded over disjoint coordinate ranges on the
    /// rule's [`Parallelism`] — bit-identical to sequential for every
    /// thread count (`rust/tests/prop_gar.rs`).
    fn aggregate_with_scratch(
        &self,
        grads: &GradMatrix,
        out: &mut [f32],
        scratch: &mut GarScratch,
    ) -> Result<()> {
        check_shape(self.name(), grads, self.n(), out)?;
        let mut sel = std::mem::take(&mut scratch.selection);
        self.select_into(grads, scratch, &mut sel)?;
        sel.validate(grads)?;
        shard_slice(
            self.parallelism(),
            out,
            &mut scratch.shards,
            CombineScratch::default,
            MIN_COORDS_PER_SHARD,
            |offset, range, cs| {
                sel.combine_range_unchecked(grads, offset, range, cs);
            },
        );
        scratch.selection = sel;
        Ok(())
    }

    /// How many of the `n` input gradients influence the output (the `m̃`
    /// of the slowdown theorems; `n` for averaging, 1 for Krum/median).
    fn gradients_used(&self) -> usize;
}

/// Validate the selection-phase preconditions (no output buffer yet).
pub(crate) fn check_select_shape(rule: &str, grads: &GradMatrix, n: usize) -> Result<()> {
    anyhow::ensure!(
        grads.n() == n,
        "{rule}: expected {n} gradients, got {}",
        grads.n()
    );
    anyhow::ensure!(grads.d() > 0, "{rule}: empty gradients (d = 0)");
    Ok(())
}

/// Validate the common preconditions shared by all rules.
pub(crate) fn check_shape(rule: &str, grads: &GradMatrix, n: usize, out: &[f32]) -> Result<()> {
    anyhow::ensure!(
        grads.n() == n,
        "{rule}: expected {n} gradients, got {}",
        grads.n()
    );
    anyhow::ensure!(
        out.len() == grads.d(),
        "{rule}: output length {} != d {}",
        out.len(),
        grads.d()
    );
    anyhow::ensure!(grads.d() > 0, "{rule}: empty gradients (d = 0)");
    Ok(())
}

/// Enumeration of the available rules — the config-file / CLI surface.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum GarKind {
    Average,
    Median,
    TrimmedMean,
    Krum,
    MultiKrum,
    Bulyan,
    MultiBulyan,
}

impl GarKind {
    /// All kinds, in the order the paper's figures present them.
    pub const ALL: [GarKind; 7] = [
        GarKind::Average,
        GarKind::Median,
        GarKind::TrimmedMean,
        GarKind::Krum,
        GarKind::MultiKrum,
        GarKind::Bulyan,
        GarKind::MultiBulyan,
    ];

    /// Minimum `n` for a given `f` (the rule's resilience precondition).
    pub fn min_n(self, f: usize) -> usize {
        match self {
            GarKind::Average => 1.max(f + 1),
            GarKind::Median | GarKind::TrimmedMean => 2 * f + 1,
            GarKind::Krum | GarKind::MultiKrum => 2 * f + 3,
            GarKind::Bulyan | GarKind::MultiBulyan => 4 * f + 3,
        }
    }

    /// Build the rule for an `(n, f)` contract (sequential execution).
    pub fn instantiate(self, n: usize, f: usize) -> Result<Box<dyn Gar>> {
        self.instantiate_parallel(n, f, &Parallelism::sequential())
    }

    /// Build the rule for an `(n, f)` contract running its O(d) / O(n²d)
    /// passes on `par` (the `threads` experiment-config knob). Outputs are
    /// bit-identical to the sequential instantiation for every thread
    /// count — see `runtime::pool` and `tests/prop_gar.rs`.
    pub fn instantiate_parallel(
        self,
        n: usize,
        f: usize,
        par: &Parallelism,
    ) -> Result<Box<dyn Gar>> {
        Ok(match self {
            GarKind::Average => Box::new(Average::new(n)?.with_parallelism(par.clone())),
            GarKind::Median => Box::new(CoordMedian::new(n, f)?.with_parallelism(par.clone())),
            GarKind::TrimmedMean => {
                Box::new(TrimmedMean::new(n, f)?.with_parallelism(par.clone()))
            }
            GarKind::Krum => Box::new(Krum::new(n, f)?.with_parallelism(par.clone())),
            GarKind::MultiKrum => Box::new(MultiKrum::new(n, f)?.with_parallelism(par.clone())),
            GarKind::Bulyan => Box::new(Bulyan::new(n, f)?.with_parallelism(par.clone())),
            GarKind::MultiBulyan => {
                Box::new(MultiBulyan::new(n, f)?.with_parallelism(par.clone()))
            }
        })
    }

    pub fn as_str(self) -> &'static str {
        match self {
            GarKind::Average => "average",
            GarKind::Median => "median",
            GarKind::TrimmedMean => "trimmed-mean",
            GarKind::Krum => "krum",
            GarKind::MultiKrum => "multi-krum",
            GarKind::Bulyan => "bulyan",
            GarKind::MultiBulyan => "multi-bulyan",
        }
    }
}

impl std::fmt::Display for GarKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl std::str::FromStr for GarKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().replace('_', "-").as_str() {
            "average" | "mean" | "avg" => Ok(GarKind::Average),
            "median" | "coord-median" => Ok(GarKind::Median),
            "trimmed-mean" | "trmean" => Ok(GarKind::TrimmedMean),
            "krum" => Ok(GarKind::Krum),
            "multi-krum" | "multikrum" | "mkrum" => Ok(GarKind::MultiKrum),
            "bulyan" => Ok(GarKind::Bulyan),
            "multi-bulyan" | "multibulyan" | "mbulyan" => Ok(GarKind::MultiBulyan),
            other => anyhow::bail!(
                "unknown GAR '{other}' (expected one of: average, median, \
                 trimmed-mean, krum, multi-krum, bulyan, multi-bulyan)"
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_roundtrip_via_str() {
        for kind in GarKind::ALL {
            let parsed: GarKind = kind.as_str().parse().unwrap();
            assert_eq!(parsed, kind);
        }
        assert!("frobnicate".parse::<GarKind>().is_err());
    }

    #[test]
    fn min_n_ordering() {
        // Stronger guarantees require more workers.
        for f in 0..5 {
            assert!(GarKind::MultiBulyan.min_n(f) >= GarKind::MultiKrum.min_n(f));
            assert!(GarKind::MultiKrum.min_n(f) >= GarKind::Median.min_n(f));
        }
        assert_eq!(GarKind::MultiKrum.min_n(2), 7);
        assert_eq!(GarKind::MultiBulyan.min_n(2), 11);
    }

    #[test]
    fn instantiate_rejects_undersized_n() {
        assert!(GarKind::MultiBulyan.instantiate(10, 2).is_err());
        assert!(GarKind::MultiBulyan.instantiate(11, 2).is_ok());
        assert!(GarKind::Krum.instantiate(6, 2).is_err());
        assert!(GarKind::Krum.instantiate(7, 2).is_ok());
    }

    #[test]
    fn all_rules_agree_on_identical_gradients() {
        // When every worker proposes the same vector, every GAR must
        // return exactly that vector.
        let n = 11;
        let f = 2;
        let g: Vec<f32> = (0..32).map(|i| i as f32 * 0.25 - 3.0).collect();
        let rows = vec![g.clone(); n];
        let grads = GradMatrix::from_rows(&rows);
        for kind in GarKind::ALL {
            let gar = kind.instantiate(n, f).unwrap();
            let out = gar.aggregate(&grads).unwrap();
            for (a, b) in out.iter().zip(&g) {
                assert!(
                    (a - b).abs() < 1e-5,
                    "{kind}: expected identical output"
                );
            }
        }
    }

    #[test]
    fn selection_reports_rows_within_bounds_for_every_rule() {
        let n = 11;
        let f = 2;
        let grads = GradMatrix::from_fn(n, 24, |i, j| ((i * 7 + j * 3) % 13) as f32 * 0.1);
        for kind in GarKind::ALL {
            let gar = kind.instantiate(n, f).unwrap();
            let mut scratch = GarScratch::new();
            let sel = gar.select(&grads, &mut scratch).unwrap();
            assert!(!sel.selected_rows().is_empty(), "{kind}");
            assert!(sel.selected_rows().iter().all(|&r| r < n), "{kind}");
            assert!(sel.validate(&grads).is_ok(), "{kind}");
        }
    }
}
