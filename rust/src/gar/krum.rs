//! KRUM and MULTI-KRUM [Blanchard et al., NIPS 2017; this paper §III].
//!
//! Krum scores each gradient `G_i` by the sum of squared distances to its
//! `k − f − 2` nearest neighbours (where `k` is the number of candidates)
//! and selects the minimiser. MULTI-KRUM — whose (α,f)-Byzantine resilience
//! is Lemma 1 of the paper — selects the `m = k − f − 2` smallest-scoring
//! gradients and returns their average, recovering an `m̃/n` slowdown
//! instead of Krum's `1/n` (Theorem 1).
//!
//! In the two-phase API all the O(n²d) work (distance matrix + scoring)
//! is the *selection* phase; the combine is a row copy (KRUM) or a
//! sharded m-row average (MULTI-KRUM) — both callable per coordinate
//! range, bit-identical to sequential.

use super::selection::{CombinePlan, Selection};
use super::{check_select_shape, pairwise_sq_distances_sharded, Gar, GarScratch};
use crate::runtime::Parallelism;
use crate::tensor::{argselect_smallest, GradMatrix};
use crate::Result;

/// Compute Krum scores for the candidates listed in `pool`, using the
/// cached full `n × n` distance matrix `dist` (row stride `n`).
///
/// `neighbors = |pool| − f − 2` per the paper's footnote 1. The score of
/// pool member `i` is the sum of its `neighbors` smallest squared distances
/// to other pool members. `scores[p]` corresponds to `pool[p]`.
///
/// This is the primitive BULYAN re-invokes on a shrinking pool; computing
/// scores from the cached matrix makes each re-invocation O(k²) instead of
/// O(k²·d) — the "distance computation done only once" optimisation of the
/// paper's §V-B.
pub fn krum_scores_from_distances(
    dist: &[f32],
    n: usize,
    pool: &[usize],
    f: usize,
    scores: &mut Vec<f32>,
) {
    let k = pool.len();
    let neighbors = k
        .checked_sub(f + 2)
        .expect("krum_scores: pool too small for f (need k ≥ f+2+1)");
    scores.clear();
    // Scratch row of distances from i to every other pool member.
    let mut row = Vec::with_capacity(k - 1);
    for &i in pool {
        row.clear();
        for &j in pool {
            if i != j {
                row.push(dist[i * n + j]);
            }
        }
        let mut s = 0.0f32;
        if neighbors > 0 {
            if neighbors < row.len() {
                row.select_nth_unstable_by(neighbors - 1, f32::total_cmp);
            }
            for &v in &row[..neighbors] {
                s += v;
            }
        }
        scores.push(s);
    }
}

/// Fill `scratch.distances` with the pairwise matrix for `grads`, sharded
/// over `par`, and hand the buffer out for score computations. Shared by
/// the Krum family and BULYAN (`bulyan.rs`).
pub(crate) fn distances_via_scratch(
    grads: &GradMatrix,
    par: &Parallelism,
    scratch: &mut GarScratch,
) -> Vec<f32> {
    scratch.distances_mut(grads.n());
    let mut dist = std::mem::take(&mut scratch.distances);
    let mut partials = std::mem::take(&mut scratch.partials);
    pairwise_sq_distances_sharded(grads, &mut dist, par, &mut partials);
    scratch.partials = partials;
    dist
}

/// Run the full-pool Krum scoring: distance matrix (sharded over `par`)
/// plus scores for every row. Shared by KRUM and MULTI-KRUM's selection
/// phases; `scratch.scores` holds the result on return.
fn score_full_pool(gar: &str, grads: &GradMatrix, n: usize, f: usize, par: &Parallelism, scratch: &mut GarScratch) -> Result<()> {
    check_select_shape(gar, grads, n)?;
    let dist = distances_via_scratch(grads, par, scratch);
    scratch.pool.clear();
    scratch.pool.extend(0..n);
    let mut scores = std::mem::take(&mut scratch.scores);
    krum_scores_from_distances(&dist, n, &scratch.pool, f, &mut scores);
    scratch.distances = dist;
    scratch.scores = scores;
    Ok(())
}

/// KRUM: select the single gradient with the smallest score.
#[derive(Debug, Clone)]
pub struct Krum {
    n: usize,
    f: usize,
    par: Parallelism,
}

impl Krum {
    pub fn new(n: usize, f: usize) -> Result<Self> {
        anyhow::ensure!(
            n >= 2 * f + 3,
            "krum: requires n ≥ 2f+3 (got n={n}, f={f})"
        );
        Ok(Self {
            n,
            f,
            par: Parallelism::sequential(),
        })
    }

    /// Use `par` for the sharded O(n²d) distance pass.
    pub fn with_parallelism(mut self, par: Parallelism) -> Self {
        self.par = par;
        self
    }
}

impl Gar for Krum {
    fn name(&self) -> &'static str {
        "krum"
    }

    fn n(&self) -> usize {
        self.n
    }

    fn f(&self) -> usize {
        self.f
    }

    fn parallelism(&self) -> &Parallelism {
        &self.par
    }

    fn gradients_used(&self) -> usize {
        1
    }

    fn select_into(
        &self,
        grads: &GradMatrix,
        scratch: &mut GarScratch,
        sel: &mut Selection,
    ) -> Result<()> {
        score_full_pool("krum", grads, self.n, self.f, &self.par, scratch)?;
        let winner = argselect_smallest(&scratch.scores, 1)[0];
        sel.reset(CombinePlan::CopyRow, self.n);
        sel.rows.push(winner);
        Ok(())
    }
}

/// MULTI-KRUM: average of the `m` smallest-scoring gradients
/// (`m = n − f − 2` by default — the `m̃` that maximises the Theorem 1
/// slowdown bound; smaller `m` supported for the ablation sweeps).
#[derive(Debug, Clone)]
pub struct MultiKrum {
    n: usize,
    f: usize,
    m: usize,
    par: Parallelism,
}

impl MultiKrum {
    /// Standard construction with `m = m̃ = n − f − 2`.
    pub fn new(n: usize, f: usize) -> Result<Self> {
        anyhow::ensure!(
            n >= 2 * f + 3,
            "multi-krum: requires n ≥ 2f+3 (got n={n}, f={f})"
        );
        Ok(Self {
            n,
            f,
            m: n - f - 2,
            par: Parallelism::sequential(),
        })
    }

    /// Construction with an explicit `m ≤ n − f − 2` (slowdown ablation).
    pub fn with_m(n: usize, f: usize, m: usize) -> Result<Self> {
        anyhow::ensure!(
            n >= 2 * f + 3,
            "multi-krum: requires n ≥ 2f+3 (got n={n}, f={f})"
        );
        anyhow::ensure!(
            (1..=n - f - 2).contains(&m),
            "multi-krum: m must be in [1, n-f-2] (got m={m}, n={n}, f={f})"
        );
        Ok(Self {
            n,
            f,
            m,
            par: Parallelism::sequential(),
        })
    }

    /// Use `par` for the sharded O(n²d) distance pass and the final
    /// average.
    pub fn with_parallelism(mut self, par: Parallelism) -> Self {
        self.par = par;
        self
    }

    pub fn m(&self) -> usize {
        self.m
    }
}

impl Gar for MultiKrum {
    fn name(&self) -> &'static str {
        "multi-krum"
    }

    fn n(&self) -> usize {
        self.n
    }

    fn f(&self) -> usize {
        self.f
    }

    fn parallelism(&self) -> &Parallelism {
        &self.par
    }

    fn gradients_used(&self) -> usize {
        self.m
    }

    fn select_into(
        &self,
        grads: &GradMatrix,
        scratch: &mut GarScratch,
        sel: &mut Selection,
    ) -> Result<()> {
        score_full_pool("multi-krum", grads, self.n, self.f, &self.par, scratch)?;
        let selected = argselect_smallest(&scratch.scores, self.m);
        sel.reset(CombinePlan::MeanRows, self.n);
        sel.rows.extend_from_slice(&selected);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gar::pairwise_sq_distances_into;

    /// n=7, f=1 ⇒ neighbors = 4, m = 4.
    fn cluster_with_outlier() -> GradMatrix {
        let mut rows: Vec<Vec<f32>> = (0..6)
            .map(|i| vec![i as f32 * 0.01, 1.0 - i as f32 * 0.01, 0.5])
            .collect();
        rows.push(vec![100.0, -100.0, 100.0]); // the outlier
        GradMatrix::from_rows(&rows)
    }

    #[test]
    fn krum_never_picks_the_outlier() {
        let g = cluster_with_outlier();
        let krum = Krum::new(7, 1).unwrap();
        let mut scratch = GarScratch::new();
        let sel = krum.select(&g, &mut scratch).unwrap();
        let winner = sel.selected_rows()[0];
        assert_ne!(winner, 6);
        let out = krum.aggregate(&g).unwrap();
        assert_eq!(out, g.row(winner));
    }

    #[test]
    fn multi_krum_excludes_outlier_from_selection() {
        let g = cluster_with_outlier();
        let mk = MultiKrum::new(7, 1).unwrap();
        assert_eq!(mk.m(), 4);
        let mut scratch = GarScratch::new();
        let sel = mk.select(&g, &mut scratch).unwrap();
        assert_eq!(sel.selected_rows().len(), 4);
        assert!(!sel.selected_rows().contains(&6), "outlier must not be selected");
        // Output is the average of the selected rows.
        let out = mk.aggregate(&g).unwrap();
        let expected = g.mean_of_rows(sel.selected_rows());
        for (a, b) in out.iter().zip(&expected) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn with_m_one_matches_krum() {
        let g = cluster_with_outlier();
        let mut scratch = GarScratch::new();
        let krum_out = Krum::new(7, 1).unwrap().aggregate(&g).unwrap();
        let mk1 = MultiKrum::with_m(7, 1, 1).unwrap();
        let sel = mk1.select(&g, &mut scratch).unwrap();
        assert_eq!(sel.selected_rows().len(), 1);
        let out = mk1.aggregate(&g).unwrap();
        for (a, b) in out.iter().zip(&krum_out) {
            assert!((a - b).abs() < 1e-6, "m=1 multi-krum must match krum");
        }
    }

    #[test]
    fn m_bounds_enforced() {
        assert!(MultiKrum::with_m(7, 1, 0).is_err());
        assert!(MultiKrum::with_m(7, 1, 5).is_err());
        assert!(MultiKrum::with_m(7, 1, 4).is_ok());
    }

    #[test]
    fn scores_from_cached_distances_match_direct() {
        // Scores computed on a sub-pool must equal scores computed on the
        // gathered sub-matrix directly.
        let g = GradMatrix::from_fn(9, 13, |i, j| ((i * 7 + j * 3) % 11) as f32);
        let n = g.n();
        let mut dist = vec![0.0; n * n];
        pairwise_sq_distances_into(&g, &mut dist);
        let pool = vec![0, 2, 3, 5, 6, 7, 8];
        let mut scores = Vec::new();
        krum_scores_from_distances(&dist, n, &pool, 1, &mut scores);

        let sub = g.gather_rows(&pool);
        let mut sub_dist = vec![0.0; pool.len() * pool.len()];
        pairwise_sq_distances_into(&sub, &mut sub_dist);
        let sub_pool: Vec<usize> = (0..pool.len()).collect();
        let mut sub_scores = Vec::new();
        krum_scores_from_distances(&sub_dist, pool.len(), &sub_pool, 1, &mut sub_scores);
        for (a, b) in scores.iter().zip(&sub_scores) {
            assert!((a - b).abs() <= 1e-3 * b.abs().max(1.0));
        }
    }

    #[test]
    fn byzantine_nan_gradient_never_selected() {
        // A NaN gradient gets NaN distances → NaN score → ranked last.
        let mut rows: Vec<Vec<f32>> = (0..6).map(|i| vec![i as f32 * 0.1; 4]).collect();
        rows.push(vec![f32::NAN; 4]);
        let g = GradMatrix::from_rows(&rows);
        let mk = MultiKrum::new(7, 1).unwrap();
        let mut scratch = GarScratch::new();
        let sel = mk.select(&g, &mut scratch).unwrap();
        assert!(!sel.selected_rows().contains(&6));
        let out = mk.aggregate(&g).unwrap();
        assert!(out.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn parallel_matches_sequential_bitwise() {
        let g = GradMatrix::from_fn(9, 40_000, |i, j| ((i * 11 + j) % 199) as f32 * 0.005 - 0.4);
        let seq = MultiKrum::new(9, 1).unwrap().aggregate(&g).unwrap();
        let par = MultiKrum::new(9, 1)
            .unwrap()
            .with_parallelism(Parallelism::new(4))
            .aggregate(&g)
            .unwrap();
        assert_eq!(seq, par);
    }
}
