//! BULYAN and MULTI-BULYAN [El Mhamdi et al., ICML 2018; this paper §IV].
//!
//! BULYAN runs a weakly-resilient selection rule `θ = n − 2f − 2` times
//! (removing the winner from the pool each time), then takes — per
//! coordinate — the average of the `β = θ − 2f` values closest to the
//! coordinate-wise median. The median step is what cuts the attacker's
//! `√d` leeway down to `O(1/√d)` per coordinate (strong Byzantine
//! resilience, Definition 2 / Theorem 2).
//!
//! * [`Bulyan`] — the classic composition over KRUM: each iteration keeps
//!   the single Krum winner, and the final trimmed average runs over the
//!   θ winners.
//! * [`MultiBulyan`] — the paper's contribution (Algorithm 1): each
//!   iteration additionally records the MULTI-KRUM *selection* of the
//!   round, the median is taken over the extracted winners (`G^ext`), and
//!   the final per-coordinate trimmed average runs over the iteration
//!   averages (`G^agr`) — recovering the `m̃/n` slowdown while keeping
//!   the strong-resilience bound.
//!
//! Both implementations compute the `n × n` distance matrix **once** and
//! re-score the shrinking pool from the cached matrix (O(k²) per
//! iteration), the optimisation the paper's §V-B highlights; total cost is
//! O(n²d) — linear in `d`, the paper's Theorem 2(ii).
//!
//! The two-phase split is exact here: `select_into` performs the distance
//! matrix plus the θ pool iterations and records **indices only** (the θ
//! winners and, for MULTI-BULYAN, each iteration's selected row set); the
//! entire O(d) tail — G^agr, the per-coordinate median, the β-closest
//! average — happens in the combine phase (`gar::selection`,
//! `CombinePlan::BulyanTrim`), per coordinate range, with no θ×d
//! intermediate matrices at all. Outputs are bit-identical to the old
//! monolithic path and to the sequential path for every thread count
//! ("multi-Bulyan's parallelisability further adds to its efficiency", §V).

use super::krum::{distances_via_scratch, krum_scores_from_distances};
use super::selection::{CombinePlan, Selection};
use super::{check_select_shape, Gar, GarScratch};
use crate::runtime::Parallelism;
use crate::tensor::{argselect_smallest, GradMatrix};
use crate::Result;

/// Shared BULYAN parameters and selection logic.
#[derive(Debug, Clone)]
struct BulyanCore {
    n: usize,
    f: usize,
    /// Number of selection iterations, θ = n − 2f − 2.
    theta: usize,
    /// Per-coordinate kept values, β = θ − 2f.
    beta: usize,
    par: Parallelism,
}

impl BulyanCore {
    fn new(rule: &'static str, n: usize, f: usize) -> Result<Self> {
        anyhow::ensure!(
            n >= 4 * f + 3,
            "{rule}: requires n ≥ 4f+3 (got n={n}, f={f})"
        );
        let theta = n - 2 * f - 2;
        let beta = theta - 2 * f;
        debug_assert!(beta >= 1);
        Ok(Self {
            n,
            f,
            theta,
            beta,
            par: Parallelism::sequential(),
        })
    }

    /// Phase 1: the θ selection iterations over the cached distance
    /// matrix. Records the per-iteration winners (and, when `multi`, the
    /// per-iteration MULTI-KRUM row sets) into `sel` — indices only.
    fn select_into(
        &self,
        rule: &'static str,
        grads: &GradMatrix,
        scratch: &mut GarScratch,
        sel: &mut Selection,
        multi: bool,
    ) -> Result<()> {
        check_select_shape(rule, grads, self.n)?;
        let n = self.n;
        let dist = distances_via_scratch(grads, &self.par, scratch);

        sel.reset(
            CombinePlan::BulyanTrim {
                beta: self.beta,
                multi,
            },
            n,
        );
        if multi {
            sel.set_offsets.push(0);
        }
        scratch.pool.clear();
        scratch.pool.extend(0..n);
        let mut pool = std::mem::take(&mut scratch.pool);
        let mut scores = std::mem::take(&mut scratch.scores);

        for _t in 0..self.theta {
            let k = pool.len();
            let m_round = k - self.f - 2;
            krum_scores_from_distances(&dist, n, &pool, self.f, &mut scores);
            // Indices *into the pool*, ascending score.
            let selected = argselect_smallest(&scores, m_round.max(1));
            let winner_pos = selected[0];
            let winner = pool[winner_pos];
            sel.rows.push(winner);
            if multi {
                // Resolve pool positions to row indices; the combine
                // phase re-derives G^agr from these per coordinate.
                sel.sets.extend(selected.iter().map(|&p| pool[p]));
                sel.set_offsets.push(sel.sets.len());
            }
            pool.swap_remove(winner_pos);
        }

        scratch.pool = pool;
        scratch.scores = scores;
        scratch.distances = dist;
        Ok(())
    }
}

/// Classic BULYAN over KRUM (strongly resilient, 1-gradient slowdown).
#[derive(Debug, Clone)]
pub struct Bulyan {
    core: BulyanCore,
}

impl Bulyan {
    pub fn new(n: usize, f: usize) -> Result<Self> {
        Ok(Self {
            core: BulyanCore::new("bulyan", n, f)?,
        })
    }

    /// Use `par` for the sharded O(n²d)/O(d) passes.
    pub fn with_parallelism(mut self, par: Parallelism) -> Self {
        self.core.par = par;
        self
    }

    /// θ = n − 2f − 2 selection iterations.
    pub fn theta(&self) -> usize {
        self.core.theta
    }

    /// β = θ − 2f values averaged per coordinate.
    pub fn beta(&self) -> usize {
        self.core.beta
    }
}

impl Gar for Bulyan {
    fn name(&self) -> &'static str {
        "bulyan"
    }

    fn n(&self) -> usize {
        self.core.n
    }

    fn f(&self) -> usize {
        self.core.f
    }

    fn parallelism(&self) -> &Parallelism {
        &self.core.par
    }

    fn gradients_used(&self) -> usize {
        self.core.beta
    }

    fn select_into(
        &self,
        grads: &GradMatrix,
        scratch: &mut GarScratch,
        sel: &mut Selection,
    ) -> Result<()> {
        self.core.select_into("bulyan", grads, scratch, sel, false)
    }
}

/// MULTI-BULYAN — Algorithm 1 of the paper: BULYAN over MULTI-KRUM.
///
/// Strong Byzantine resilience (Theorem 2.i), O(d) local computation
/// (Theorem 2.ii) and an `m̃/n = (n−2f−2)/n` slowdown relative to averaging
/// in the Byzantine-free case (Theorem 2.iii).
#[derive(Debug, Clone)]
pub struct MultiBulyan {
    core: BulyanCore,
}

impl MultiBulyan {
    pub fn new(n: usize, f: usize) -> Result<Self> {
        Ok(Self {
            core: BulyanCore::new("multi-bulyan", n, f)?,
        })
    }

    /// Use `par` for the sharded O(n²d)/O(d) passes.
    pub fn with_parallelism(mut self, par: Parallelism) -> Self {
        self.core.par = par;
        self
    }

    pub fn theta(&self) -> usize {
        self.core.theta
    }

    pub fn beta(&self) -> usize {
        self.core.beta
    }
}

impl Gar for MultiBulyan {
    fn name(&self) -> &'static str {
        "multi-bulyan"
    }

    fn n(&self) -> usize {
        self.core.n
    }

    fn f(&self) -> usize {
        self.core.f
    }

    fn parallelism(&self) -> &Parallelism {
        &self.core.par
    }

    /// m̃ = n − 2f − 2 — each kept coordinate is an average of MULTI-KRUM
    /// averages over ≥ m̃ distinct correct gradients (Theorem 2.iii).
    fn gradients_used(&self) -> usize {
        self.core.theta
    }

    fn select_into(
        &self,
        grads: &GradMatrix,
        scratch: &mut GarScratch,
        sel: &mut Selection,
    ) -> Result<()> {
        self.core
            .select_into("multi-bulyan", grads, scratch, sel, true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng64;

    /// n=11, f=2: θ=5, β=1 — the paper's Fig. 3 configuration.
    fn fig3_config() -> (usize, usize) {
        (11, 2)
    }

    #[test]
    fn parameters_match_algorithm_1() {
        let (n, f) = fig3_config();
        let mb = MultiBulyan::new(n, f).unwrap();
        assert_eq!(mb.theta(), n - 2 * f - 2);
        assert_eq!(mb.beta(), mb.theta() - 2 * f);
        assert!(MultiBulyan::new(10, 2).is_err()); // n < 4f+3
    }

    #[test]
    fn selection_records_theta_winners_and_sets() {
        let (n, f) = fig3_config();
        let mut rng = Rng64::seed_from_u64(11);
        let grads = GradMatrix::uniform(n, 40, -1.0, 1.0, &mut rng);
        let mb = MultiBulyan::new(n, f).unwrap();
        let mut scratch = GarScratch::new();
        let sel = mb.select(&grads, &mut scratch).unwrap();
        assert_eq!(sel.selected_rows().len(), mb.theta());
        // Winners are distinct (each iteration removes its winner).
        let mut sorted = sel.selected_rows().to_vec();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), mb.theta());
        // Classic BULYAN records winners only.
        let b = Bulyan::new(n, f).unwrap();
        let sel_b = b.select(&grads, &mut scratch).unwrap();
        assert_eq!(sel_b.selected_rows().len(), b.theta());
    }

    #[test]
    fn identical_gradients_are_a_fixed_point() {
        let (n, f) = fig3_config();
        let g_row: Vec<f32> = (0..40).map(|i| (i as f32 * 0.3).sin()).collect();
        let grads = GradMatrix::from_rows(&vec![g_row.clone(); n]);
        for gar in [
            Box::new(Bulyan::new(n, f).unwrap()) as Box<dyn Gar>,
            Box::new(MultiBulyan::new(n, f).unwrap()),
        ] {
            let out = gar.aggregate(&grads).unwrap();
            for (a, b) in out.iter().zip(&g_row) {
                assert!((a - b).abs() < 1e-5, "{}", gar.name());
            }
        }
    }

    #[test]
    fn output_within_correct_coordinate_range() {
        // Strong-resilience sanity: with f=2 Byzantine rows pushing ±1e6,
        // every output coordinate stays inside [min, max] of the correct
        // workers' values for that coordinate (a consequence of the
        // median-then-closest-β step).
        let (n, f) = fig3_config();
        let mut rng = Rng64::seed_from_u64(42);
        let mut grads = GradMatrix::uniform(n, 64, -1.0, 1.0, &mut rng);
        for b in 0..f {
            let sign = if b % 2 == 0 { 1.0 } else { -1.0 };
            grads.row_mut(n - 1 - b).iter_mut().for_each(|v| *v = sign * 1e6);
        }
        for gar in [
            Box::new(Bulyan::new(n, f).unwrap()) as Box<dyn Gar>,
            Box::new(MultiBulyan::new(n, f).unwrap()),
        ] {
            let out = gar.aggregate(&grads).unwrap();
            for j in 0..64 {
                let correct: Vec<f32> = (0..n - f).map(|i| grads.row(i)[j]).collect();
                let lo = correct.iter().copied().fold(f32::INFINITY, f32::min);
                let hi = correct.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                assert!(
                    out[j] >= lo - 1e-4 && out[j] <= hi + 1e-4,
                    "{}: coord {j} escaped [{lo}, {hi}]: {}",
                    gar.name(),
                    out[j]
                );
            }
        }
    }

    #[test]
    fn multi_bulyan_uses_more_gradients_than_bulyan() {
        let (n, f) = fig3_config();
        assert!(
            MultiBulyan::new(n, f).unwrap().gradients_used()
                > Bulyan::new(n, f).unwrap().gradients_used()
        );
    }

    #[test]
    fn f_zero_small_n() {
        // n=3, f=0: θ=1, β=1 — degenerate but legal; BULYAN reduces to the
        // Krum winner.
        let grads = GradMatrix::from_rows(&[vec![1.0, 2.0], vec![1.1, 2.1], vec![5.0, 5.0]]);
        let out = Bulyan::new(3, 0).unwrap().aggregate(&grads).unwrap();
        // Winner must be one of the two close rows.
        assert!(out[0] < 2.0);
    }

    #[test]
    fn deterministic_across_calls_and_scratch_reuse() {
        let (n, f) = fig3_config();
        let mut rng = Rng64::seed_from_u64(7);
        let grads = GradMatrix::uniform(n, 33, -1.0, 1.0, &mut rng);
        let mb = MultiBulyan::new(n, f).unwrap();
        let a = mb.aggregate(&grads).unwrap();
        let mut scratch = GarScratch::new();
        let mut b = vec![0.0; 33];
        mb.aggregate_with_scratch(&grads, &mut b, &mut scratch).unwrap();
        let mut c = vec![0.0; 33];
        mb.aggregate_with_scratch(&grads, &mut c, &mut scratch).unwrap();
        assert_eq!(a, b);
        assert_eq!(b, c);
    }

    #[test]
    fn parallel_matches_sequential_bitwise() {
        let (n, f) = fig3_config();
        let mut rng = Rng64::seed_from_u64(99);
        let grads = GradMatrix::uniform(n, 30_000, -1.0, 1.0, &mut rng);
        let cases: Vec<(Box<dyn Gar>, Box<dyn Gar>)> = vec![
            (
                Box::new(Bulyan::new(n, f).unwrap()),
                Box::new(Bulyan::new(n, f).unwrap().with_parallelism(Parallelism::new(4))),
            ),
            (
                Box::new(MultiBulyan::new(n, f).unwrap()),
                Box::new(MultiBulyan::new(n, f).unwrap().with_parallelism(Parallelism::new(3))),
            ),
        ];
        for (seq, par) in cases {
            let a = seq.aggregate(&grads).unwrap();
            let b = par.aggregate(&grads).unwrap();
            assert_eq!(a, b, "{}", seq.name());
        }
    }
}
