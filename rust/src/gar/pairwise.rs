//! Pairwise squared ℓ2 distances — the O(n²d) hot spot of MULTI-KRUM and
//! MULTI-BULYAN (and the subject of the paper's Fig. 2 timing study).
//!
//! The computation is tiled over the dimension `d`: each `BLOCK_D`-wide
//! stripe of all `n` rows is streamed through cache once and its partial
//! distances accumulated into the `n × n` output. For `d = 10⁷` and
//! `n = 39` the naive pair-major loop re-reads every row `n − 1` times
//! from DRAM (≈ n²·d traffic); the stripe-major loop reads each element
//! once (≈ n·d traffic) while the stripe (n·BLOCK_D·4 bytes ≤ 1.2 MiB)
//! stays L2-resident. This mirrors the Pallas kernel's HBM↔VMEM schedule
//! (`python/compile/kernels/pairwise.py`) — see DESIGN.md
//! §Hardware-Adaptation.
//!
//! ## Parallel path
//!
//! [`pairwise_sq_distances_sharded`] additionally splits `d` into
//! fixed-width [`SHARD_D`] chunks. Each chunk produces an independent
//! partial `n × n` matrix (chunks are claimed dynamically by the pool's
//! threads), and the partials are reduced with a **fixed pairwise tree**
//! whose shape depends only on the chunk count: level `s = 1, 2, 4, …`
//! folds partial `i + s` into partial `i` for every `i` that is a
//! multiple of `2s`, each level's folds running in parallel across the
//! pool. Both the decomposition and the tree shape depend only on `d` —
//! never on the thread count — so the result is bit-identical for every
//! `threads` setting, including 1. (f32 addition is not associative; a
//! thread-count-dependent reduction would break the
//! parallel-vs-sequential equality property that `tests/prop_gar.rs`
//! enforces.) The tree replaces the old single-thread ascending fold,
//! which was O(chunks·n²) on one core — visible at n ≥ 64 (ROADMAP item).

use crate::runtime::pool::SyncMutPtr;
use crate::runtime::{run_chunks, Parallelism};
use crate::tensor::{sq_distance, GradMatrix};

/// Stripe width in elements. 2048 f32 × n ≤ 39 rows ≈ 320 KiB — fits L2
/// comfortably while long enough to amortise loop overhead.
const BLOCK_D: usize = 2048;

/// Parallel chunk width: 8 stripes. Small enough that d = 10⁵ still yields
/// ~7 chunks (load balance at 4 threads), large enough that the per-chunk
/// n² partial buffer and claim overhead stay negligible.
pub const SHARD_D: usize = 8 * BLOCK_D;

/// Accumulate the distance contributions of columns `[start, end)` into
/// `out` (upper triangle only), stripe-major within the range. `out` must
/// be zeroed by the caller.
fn partial_distances_upper(grads: &GradMatrix, start: usize, end: usize, out: &mut [f32]) {
    let n = grads.n();
    let mut s = start;
    while s < end {
        let e = (s + BLOCK_D).min(end);
        for i in 0..n {
            let gi = &grads.row(i)[s..e];
            for j in (i + 1)..n {
                let gj = &grads.row(j)[s..e];
                out[i * n + j] += sq_distance(gi, gj);
            }
        }
        s = e;
    }
}

/// Mirror the upper triangle into the lower one (diagonal stays 0).
fn mirror_lower(out: &mut [f32], n: usize) {
    for i in 0..n {
        for j in (i + 1)..n {
            out[j * n + i] = out[i * n + j];
        }
    }
}

/// Compute all pairwise squared distances into `out` (`n*n`, row-major,
/// symmetric, zero diagonal), sharding the `d` dimension across `par`.
///
/// `partials` is the grow-only per-chunk scratch (⌈d/SHARD_D⌉ · n² floats,
/// normally `GarScratch::partials`, reused across rounds); the fan-out
/// itself is allocation-free — each pool thread derives its chunk's
/// disjoint partial buffer from the chunk index (`runtime::run_chunks`).
/// Results are bit-identical for every thread count; see the module docs.
pub fn pairwise_sq_distances_sharded(
    grads: &GradMatrix,
    out: &mut [f32],
    par: &Parallelism,
    partials: &mut Vec<f32>,
) {
    let n = grads.n();
    let d = grads.d();
    assert_eq!(out.len(), n * n, "pairwise: out must be n*n");
    out.fill(0.0);
    if d == 0 || n == 0 {
        return;
    }
    let nn = n * n;
    let chunks = d.div_ceil(SHARD_D);
    partials.clear();
    partials.resize(chunks * nn, 0.0);
    // One `nn`-sized partial buffer per chunk; the pool claims chunks
    // dynamically (load balance), zero allocations in the fan-out.
    run_chunks(par, &mut partials[..chunks * nn], nn, |c, buf| {
        let start = c * SHARD_D;
        let end = (start + SHARD_D).min(d);
        partial_distances_upper(grads, start, end, buf);
    });
    reduce_partials_tree(par, &mut partials[..chunks * nn], chunks, nn);
    out.copy_from_slice(&partials[..nn]);
    mirror_lower(out, n);
}

/// Fold `chunks` consecutive `nn`-sized partial matrices into
/// `partials[..nn]` with a fixed pairwise tree: level `s` (1, 2, 4, …)
/// adds partial `i + s` into partial `i` for every `i ≡ 0 (mod 2s)` with
/// `i + s < chunks`. The tree shape depends only on `chunks`, and every
/// fold of a level touches a disjoint pair of partials, so the levels
/// parallelise across `par` while the result stays bit-identical for
/// every thread count (the old ascending fold reduced all chunks on the
/// calling thread — O(chunks·n²) serial work).
fn reduce_partials_tree(par: &Parallelism, partials: &mut [f32], chunks: usize, nn: usize) {
    debug_assert!(chunks >= 1 && partials.len() >= chunks * nn);
    // Captured as a plain usize: the closure below must not borrow
    // `partials` while the raw-pointer fan-out writes through `base`.
    let partials_len = partials.len();
    let base = SyncMutPtr(partials.as_mut_ptr());
    let mut s = 1;
    while s < chunks {
        // Folds at this level: i = 0, 2s, 4s, … with i + s < chunks.
        let folds = (chunks - s).div_ceil(2 * s);
        par.run_sharded(folds, &|k| {
            let i = k * 2 * s;
            // Shard-range disjointness: both partials of the fold exist.
            crate::strict_assert!(i + s < chunks && (i + s + 1) * nn <= partials_len);
            // SAFETY: fold `k` exclusively owns partials `i` (written) and
            // `i + s` (read): within a level the (i, i+s) pairs are
            // disjoint (i is a multiple of 2s, i + s < chunks), and
            // `run_sharded` blocks until the level completes, so `partials`
            // outlives every dereference and levels never overlap.
            unsafe {
                let dst = std::slice::from_raw_parts_mut(base.get().add(i * nn), nn);
                let src = std::slice::from_raw_parts(base.get().add((i + s) * nn), nn);
                for (o, v) in dst.iter_mut().zip(src) {
                    *o += v;
                }
            }
        });
        s *= 2;
    }
}

/// Compute all pairwise squared distances into `out` (`n*n`, row-major,
/// symmetric, zero diagonal) on the calling thread. No allocation — the
/// stripe partials accumulate directly into `out` (left-associated, so
/// final-bit rounding can differ from the chunk-grouped
/// [`pairwise_sq_distances_sharded`] at d > [`SHARD_D`]; the GAR hot path
/// uses the sharded variant exclusively, keeping the bit-identical
/// contract within it).
pub fn pairwise_sq_distances_into(grads: &GradMatrix, out: &mut [f32]) {
    let n = grads.n();
    let d = grads.d();
    assert_eq!(out.len(), n * n, "pairwise: out must be n*n");
    out.fill(0.0);
    partial_distances_upper(grads, 0, d, out);
    mirror_lower(out, n);
}

/// Allocating convenience wrapper around [`pairwise_sq_distances_into`].
pub fn pairwise_sq_distances(grads: &GradMatrix) -> Vec<f32> {
    let mut out = vec![0.0f32; grads.n() * grads.n()];
    pairwise_sq_distances_into(grads, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive(grads: &GradMatrix) -> Vec<f32> {
        let n = grads.n();
        let mut out = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                out[i * n + j] = sq_distance(grads.row(i), grads.row(j));
            }
        }
        out
    }

    #[test]
    fn matches_naive_small() {
        let g = GradMatrix::from_fn(5, 17, |i, j| ((i * 31 + j * 7) % 13) as f32 - 6.0);
        let tiled = pairwise_sq_distances(&g);
        let reference = naive(&g);
        for (a, b) in tiled.iter().zip(&reference) {
            assert!((a - b).abs() <= 1e-3 * b.abs().max(1.0));
        }
    }

    #[test]
    fn matches_naive_across_block_boundary() {
        // d > BLOCK_D exercises the multi-stripe accumulation.
        let d = BLOCK_D + 137;
        let g = GradMatrix::from_fn(4, d, |i, j| ((i + 1) * j % 101) as f32 * 0.01);
        let tiled = pairwise_sq_distances(&g);
        let reference = naive(&g);
        for (a, b) in tiled.iter().zip(&reference) {
            assert!((a - b).abs() <= 1e-3 * b.abs().max(1.0), "{a} vs {b}");
        }
    }

    #[test]
    fn symmetric_zero_diagonal() {
        let g = GradMatrix::from_fn(6, 50, |i, j| (i as f32).sin() + (j as f32).cos());
        let d = pairwise_sq_distances(&g);
        for i in 0..6 {
            assert_eq!(d[i * 6 + i], 0.0);
            for j in 0..6 {
                assert_eq!(d[i * 6 + j], d[j * 6 + i]);
            }
        }
    }

    #[test]
    fn sharded_is_bit_identical_across_thread_counts() {
        // Crosses several SHARD_D boundaries; accumulation order must not
        // depend on the thread count.
        let d = 3 * SHARD_D + 517;
        let g = GradMatrix::from_fn(7, d, |i, j| ((i * 131 + j) % 251) as f32 * 0.013 - 1.5);
        let n = g.n();
        let mut seq = vec![0.0f32; n * n];
        let mut scratch_seq = Vec::new();
        pairwise_sq_distances_sharded(&g, &mut seq, &Parallelism::sequential(), &mut scratch_seq);
        for threads in [2usize, 3, 4] {
            let par = Parallelism::new(threads);
            let mut out = vec![0.0f32; n * n];
            let mut scratch = Vec::new();
            pairwise_sq_distances_sharded(&g, &mut out, &par, &mut scratch);
            assert_eq!(seq, out, "threads={threads}");
        }
    }

    #[test]
    fn tree_reduction_matches_plain_sum_for_any_chunk_count() {
        // The tree's total per element equals a full sum of the chunk
        // partials (within f32 tolerance — association differs by design)
        // and is identical for every thread count (same tree shape).
        for chunks in [1usize, 2, 3, 4, 5, 7, 8, 13] {
            let nn = 9;
            let make = || -> Vec<f32> {
                (0..chunks * nn)
                    .map(|i| ((i * 37 + 11) % 101) as f32 * 0.125)
                    .collect()
            };
            let mut seq = make();
            reduce_partials_tree(&Parallelism::sequential(), &mut seq, chunks, nn);
            for e in 0..nn {
                let total: f64 = (0..chunks).map(|c| make()[c * nn + e] as f64).sum();
                let got = seq[e] as f64;
                assert!(
                    (got - total).abs() <= 1e-3 * total.abs().max(1.0),
                    "chunks={chunks} elem {e}: {got} vs {total}"
                );
            }
            for threads in [2usize, 4] {
                let mut par = make();
                reduce_partials_tree(&Parallelism::new(threads), &mut par, chunks, nn);
                assert_eq!(&seq[..nn], &par[..nn], "chunks={chunks} threads={threads}");
            }
        }
    }

    #[test]
    fn sharded_scratch_reuse_across_shapes() {
        let par = Parallelism::new(2);
        let mut partials = Vec::new();
        for (n, d) in [(5usize, SHARD_D + 3), (3, 64), (5, 2 * SHARD_D)] {
            let g = GradMatrix::from_fn(n, d, |i, j| (i + j % 17) as f32 * 0.1);
            let mut out = vec![0.0f32; n * n];
            pairwise_sq_distances_sharded(&g, &mut out, &par, &mut partials);
            let reference = naive(&g);
            for (a, b) in out.iter().zip(&reference) {
                assert!((a - b).abs() <= 1e-3 * b.abs().max(1.0), "n={n} d={d}");
            }
        }
    }
}
