//! Pairwise squared ℓ2 distances — the O(n²d) hot spot of MULTI-KRUM and
//! MULTI-BULYAN (and the subject of the paper's Fig. 2 timing study).
//!
//! The computation is tiled over the dimension `d`: each `BLOCK_D`-wide
//! stripe of all `n` rows is streamed through cache once and its partial
//! distances accumulated into the `n × n` output. For `d = 10⁷` and
//! `n = 39` the naive pair-major loop re-reads every row `n − 1` times
//! from DRAM (≈ n²·d traffic); the stripe-major loop reads each element
//! once (≈ n·d traffic) while the stripe (n·BLOCK_D·4 bytes ≤ 1.2 MiB)
//! stays L2-resident. This mirrors the Pallas kernel's HBM↔VMEM schedule
//! (`python/compile/kernels/pairwise.py`) — see DESIGN.md
//! §Hardware-Adaptation.
//!
//! ## Parallel path
//!
//! [`pairwise_sq_distances_sharded`] additionally splits `d` into
//! fixed-width [`SHARD_D`] chunks. Each chunk produces an independent
//! partial `n × n` matrix (chunks are claimed dynamically by the pool's
//! threads), and the partials are reduced into `out` in **ascending chunk
//! order**. Both the decomposition and the reduction order depend only on
//! `d` — never on the thread count — so the result is bit-identical for
//! every `threads` setting, including 1. (f32 addition is not associative;
//! a thread-count-dependent reduction tree would break the
//! parallel-vs-sequential equality property that `tests/prop_gar.rs`
//! enforces.)

use crate::runtime::{run_chunks, Parallelism};
use crate::tensor::{sq_distance, GradMatrix};

/// Stripe width in elements. 2048 f32 × n ≤ 39 rows ≈ 320 KiB — fits L2
/// comfortably while long enough to amortise loop overhead.
const BLOCK_D: usize = 2048;

/// Parallel chunk width: 8 stripes. Small enough that d = 10⁵ still yields
/// ~7 chunks (load balance at 4 threads), large enough that the per-chunk
/// n² partial buffer and claim overhead stay negligible.
pub const SHARD_D: usize = 8 * BLOCK_D;

/// Accumulate the distance contributions of columns `[start, end)` into
/// `out` (upper triangle only), stripe-major within the range. `out` must
/// be zeroed by the caller.
fn partial_distances_upper(grads: &GradMatrix, start: usize, end: usize, out: &mut [f32]) {
    let n = grads.n();
    let mut s = start;
    while s < end {
        let e = (s + BLOCK_D).min(end);
        for i in 0..n {
            let gi = &grads.row(i)[s..e];
            for j in (i + 1)..n {
                let gj = &grads.row(j)[s..e];
                out[i * n + j] += sq_distance(gi, gj);
            }
        }
        s = e;
    }
}

/// Mirror the upper triangle into the lower one (diagonal stays 0).
fn mirror_lower(out: &mut [f32], n: usize) {
    for i in 0..n {
        for j in (i + 1)..n {
            out[j * n + i] = out[i * n + j];
        }
    }
}

/// Compute all pairwise squared distances into `out` (`n*n`, row-major,
/// symmetric, zero diagonal), sharding the `d` dimension across `par`.
///
/// `partials` is the grow-only per-chunk scratch (⌈d/SHARD_D⌉ · n² floats,
/// normally `GarScratch::partials`, reused across rounds); the fan-out
/// itself is allocation-free — each pool thread derives its chunk's
/// disjoint partial buffer from the chunk index (`runtime::run_chunks`).
/// Results are bit-identical for every thread count; see the module docs.
pub fn pairwise_sq_distances_sharded(
    grads: &GradMatrix,
    out: &mut [f32],
    par: &Parallelism,
    partials: &mut Vec<f32>,
) {
    let n = grads.n();
    let d = grads.d();
    assert_eq!(out.len(), n * n, "pairwise: out must be n*n");
    out.fill(0.0);
    if d == 0 || n == 0 {
        return;
    }
    let nn = n * n;
    let chunks = d.div_ceil(SHARD_D);
    partials.clear();
    partials.resize(chunks * nn, 0.0);
    // One `nn`-sized partial buffer per chunk; the pool claims chunks
    // dynamically (load balance), zero allocations in the fan-out.
    run_chunks(par, &mut partials[..chunks * nn], nn, |c, buf| {
        let start = c * SHARD_D;
        let end = (start + SHARD_D).min(d);
        partial_distances_upper(grads, start, end, buf);
    });
    // Ordered reduction: fixed ascending-chunk order keeps the result
    // independent of which thread computed which chunk.
    for c in 0..chunks {
        let src = &partials[c * nn..(c + 1) * nn];
        for (o, s) in out.iter_mut().zip(src) {
            *o += s;
        }
    }
    mirror_lower(out, n);
}

/// Compute all pairwise squared distances into `out` (`n*n`, row-major,
/// symmetric, zero diagonal) on the calling thread. No allocation — the
/// stripe partials accumulate directly into `out` (left-associated, so
/// final-bit rounding can differ from the chunk-grouped
/// [`pairwise_sq_distances_sharded`] at d > [`SHARD_D`]; the GAR hot path
/// uses the sharded variant exclusively, keeping the bit-identical
/// contract within it).
pub fn pairwise_sq_distances_into(grads: &GradMatrix, out: &mut [f32]) {
    let n = grads.n();
    let d = grads.d();
    assert_eq!(out.len(), n * n, "pairwise: out must be n*n");
    out.fill(0.0);
    partial_distances_upper(grads, 0, d, out);
    mirror_lower(out, n);
}

/// Allocating convenience wrapper around [`pairwise_sq_distances_into`].
pub fn pairwise_sq_distances(grads: &GradMatrix) -> Vec<f32> {
    let mut out = vec![0.0f32; grads.n() * grads.n()];
    pairwise_sq_distances_into(grads, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive(grads: &GradMatrix) -> Vec<f32> {
        let n = grads.n();
        let mut out = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                out[i * n + j] = sq_distance(grads.row(i), grads.row(j));
            }
        }
        out
    }

    #[test]
    fn matches_naive_small() {
        let g = GradMatrix::from_fn(5, 17, |i, j| ((i * 31 + j * 7) % 13) as f32 - 6.0);
        let tiled = pairwise_sq_distances(&g);
        let reference = naive(&g);
        for (a, b) in tiled.iter().zip(&reference) {
            assert!((a - b).abs() <= 1e-3 * b.abs().max(1.0));
        }
    }

    #[test]
    fn matches_naive_across_block_boundary() {
        // d > BLOCK_D exercises the multi-stripe accumulation.
        let d = BLOCK_D + 137;
        let g = GradMatrix::from_fn(4, d, |i, j| ((i + 1) * j % 101) as f32 * 0.01);
        let tiled = pairwise_sq_distances(&g);
        let reference = naive(&g);
        for (a, b) in tiled.iter().zip(&reference) {
            assert!((a - b).abs() <= 1e-3 * b.abs().max(1.0), "{a} vs {b}");
        }
    }

    #[test]
    fn symmetric_zero_diagonal() {
        let g = GradMatrix::from_fn(6, 50, |i, j| (i as f32).sin() + (j as f32).cos());
        let d = pairwise_sq_distances(&g);
        for i in 0..6 {
            assert_eq!(d[i * 6 + i], 0.0);
            for j in 0..6 {
                assert_eq!(d[i * 6 + j], d[j * 6 + i]);
            }
        }
    }

    #[test]
    fn sharded_is_bit_identical_across_thread_counts() {
        // Crosses several SHARD_D boundaries; accumulation order must not
        // depend on the thread count.
        let d = 3 * SHARD_D + 517;
        let g = GradMatrix::from_fn(7, d, |i, j| ((i * 131 + j) % 251) as f32 * 0.013 - 1.5);
        let n = g.n();
        let mut seq = vec![0.0f32; n * n];
        let mut scratch_seq = Vec::new();
        pairwise_sq_distances_sharded(&g, &mut seq, &Parallelism::sequential(), &mut scratch_seq);
        for threads in [2usize, 3, 4] {
            let par = Parallelism::new(threads);
            let mut out = vec![0.0f32; n * n];
            let mut scratch = Vec::new();
            pairwise_sq_distances_sharded(&g, &mut out, &par, &mut scratch);
            assert_eq!(seq, out, "threads={threads}");
        }
    }

    #[test]
    fn sharded_scratch_reuse_across_shapes() {
        let par = Parallelism::new(2);
        let mut partials = Vec::new();
        for (n, d) in [(5usize, SHARD_D + 3), (3, 64), (5, 2 * SHARD_D)] {
            let g = GradMatrix::from_fn(n, d, |i, j| (i + j % 17) as f32 * 0.1);
            let mut out = vec![0.0f32; n * n];
            pairwise_sq_distances_sharded(&g, &mut out, &par, &mut partials);
            let reference = naive(&g);
            for (a, b) in out.iter().zip(&reference) {
                assert!((a - b).abs() <= 1e-3 * b.abs().max(1.0), "n={n} d={d}");
            }
        }
    }
}
