//! Pairwise squared ℓ2 distances — the O(n²d) hot spot of MULTI-KRUM and
//! MULTI-BULYAN (and the subject of the paper's Fig. 2 timing study).
//!
//! The computation is tiled over the dimension `d`: each `BLOCK_D`-wide
//! stripe of all `n` rows is streamed through cache once and its partial
//! distances accumulated into the `n × n` output. For `d = 10⁷` and
//! `n = 39` the naive pair-major loop re-reads every row `n − 1` times
//! from DRAM (≈ n²·d traffic); the stripe-major loop reads each element
//! once (≈ n·d traffic) while the stripe (n·BLOCK_D·4 bytes ≤ 1.2 MiB)
//! stays L2-resident. This mirrors the Pallas kernel's HBM↔VMEM schedule
//! (`python/compile/kernels/pairwise.py`) — see DESIGN.md
//! §Hardware-Adaptation.

use crate::tensor::{sq_distance, GradMatrix};

/// Stripe width in elements. 2048 f32 × n ≤ 39 rows ≈ 320 KiB — fits L2
/// comfortably while long enough to amortise loop overhead.
const BLOCK_D: usize = 2048;

/// Compute all pairwise squared distances into `out` (`n*n`, row-major,
/// symmetric, zero diagonal). No allocation.
pub fn pairwise_sq_distances_into(grads: &GradMatrix, out: &mut [f32]) {
    let n = grads.n();
    let d = grads.d();
    assert_eq!(out.len(), n * n, "pairwise: out must be n*n");
    out.fill(0.0);
    let mut start = 0;
    while start < d {
        let end = (start + BLOCK_D).min(d);
        for i in 0..n {
            let gi = &grads.row(i)[start..end];
            for j in (i + 1)..n {
                let gj = &grads.row(j)[start..end];
                let partial = sq_distance(gi, gj);
                out[i * n + j] += partial;
            }
        }
        start = end;
    }
    // Mirror the upper triangle.
    for i in 0..n {
        for j in (i + 1)..n {
            out[j * n + i] = out[i * n + j];
        }
    }
}

/// Allocating convenience wrapper around [`pairwise_sq_distances_into`].
pub fn pairwise_sq_distances(grads: &GradMatrix) -> Vec<f32> {
    let mut out = vec![0.0f32; grads.n() * grads.n()];
    pairwise_sq_distances_into(grads, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive(grads: &GradMatrix) -> Vec<f32> {
        let n = grads.n();
        let mut out = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                out[i * n + j] = sq_distance(grads.row(i), grads.row(j));
            }
        }
        out
    }

    #[test]
    fn matches_naive_small() {
        let g = GradMatrix::from_fn(5, 17, |i, j| ((i * 31 + j * 7) % 13) as f32 - 6.0);
        let tiled = pairwise_sq_distances(&g);
        let reference = naive(&g);
        for (a, b) in tiled.iter().zip(&reference) {
            assert!((a - b).abs() <= 1e-3 * b.abs().max(1.0));
        }
    }

    #[test]
    fn matches_naive_across_block_boundary() {
        // d > BLOCK_D exercises the multi-stripe accumulation.
        let d = BLOCK_D + 137;
        let g = GradMatrix::from_fn(4, d, |i, j| ((i + 1) * j % 101) as f32 * 0.01);
        let tiled = pairwise_sq_distances(&g);
        let reference = naive(&g);
        for (a, b) in tiled.iter().zip(&reference) {
            assert!((a - b).abs() <= 1e-3 * b.abs().max(1.0), "{a} vs {b}");
        }
    }

    #[test]
    fn symmetric_zero_diagonal() {
        let g = GradMatrix::from_fn(6, 50, |i, j| (i as f32).sin() + (j as f32).cos());
        let d = pairwise_sq_distances(&g);
        for i in 0..6 {
            assert_eq!(d[i * 6 + i], 0.0);
            for j in 0..6 {
                assert_eq!(d[i * 6 + j], d[j * 6 + i]);
            }
        }
    }
}
