//! Coordinate-wise median — the paper's MEDIAN comparator (Fig. 2, Fig. 3).
//!
//! Weakly Byzantine resilient for `n ≥ 2f+1` but, by keeping (the
//! equivalent of) a single gradient per step, it forfeits the variance
//! reduction of averaging — the effect Fig. 3 quantifies.

use super::scratch::ShardScratch;
use super::{check_shape, Gar, GarScratch};
use crate::runtime::{shard_slice, Parallelism, MIN_COORDS_PER_SHARD};
use crate::tensor::{median_of_buf, small_median_sorting, GradMatrix};
use crate::Result;

/// Below this n the per-coordinate median uses insertion sort (see
/// `tensor::select::insertion_sort`); above, introselect.
const SMALL_N: usize = 64;

/// Coordinate-wise median over the `n` proposed gradients. Even `n`
/// averages the two central values (the `torch.median`-style convention
/// used by the paper's baseline is the lower median; we follow `jnp.median`
/// to stay bit-compatible with the L1/L2 artifact — the choice does not
/// affect any resilience property, see `tests::even_n_convention`).
#[derive(Debug, Clone)]
pub struct CoordMedian {
    n: usize,
    f: usize,
    par: Parallelism,
}

impl CoordMedian {
    pub fn new(n: usize, f: usize) -> Result<Self> {
        anyhow::ensure!(
            n >= 2 * f + 1,
            "median: requires n ≥ 2f+1 (got n={n}, f={f})"
        );
        Ok(Self {
            n,
            f,
            par: Parallelism::sequential(),
        })
    }

    /// Use `par` for the coordinate-sharded O(nd) pass.
    pub fn with_parallelism(mut self, par: Parallelism) -> Self {
        self.par = par;
        self
    }
}

impl Gar for CoordMedian {
    fn name(&self) -> &'static str {
        "median"
    }

    fn n(&self) -> usize {
        self.n
    }

    fn f(&self) -> usize {
        self.f
    }

    /// The median keeps the informational equivalent of one gradient.
    fn gradients_used(&self) -> usize {
        1
    }

    fn aggregate_with_scratch(
        &self,
        grads: &GradMatrix,
        out: &mut [f32],
        scratch: &mut GarScratch,
    ) -> Result<()> {
        check_shape("median", grads, self.n, out)?;
        let n = self.n;
        let small = n <= SMALL_N;
        // Each coordinate's median is independent: disjoint ranges per
        // shard with a per-shard column buffer ⇒ bit-identical to the
        // sequential pass.
        shard_slice(
            &self.par,
            out,
            &mut scratch.shards,
            ShardScratch::default,
            MIN_COORDS_PER_SHARD,
            |offset, range, shard| {
                shard.column.clear();
                shard.column.resize(n, 0.0);
                let col = &mut shard.column;
                for (k, o) in range.iter_mut().enumerate() {
                    let j = offset + k;
                    for i in 0..n {
                        col[i] = grads.row(i)[j];
                    }
                    *o = if small {
                        small_median_sorting(col)
                    } else {
                        median_of_buf(col)
                    };
                }
            },
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn per_coordinate_median() {
        let g = GradMatrix::from_rows(&[
            vec![1.0, 10.0],
            vec![2.0, 30.0],
            vec![9.0, 20.0],
        ]);
        let gar = CoordMedian::new(3, 1).unwrap();
        assert_eq!(gar.aggregate(&g).unwrap(), vec![2.0, 20.0]);
    }

    #[test]
    fn even_n_convention() {
        let g = GradMatrix::from_rows(&[vec![1.0], vec![2.0], vec![3.0], vec![10.0]]);
        let gar = CoordMedian::new(4, 1).unwrap();
        assert_eq!(gar.aggregate(&g).unwrap(), vec![2.5]);
    }

    #[test]
    fn resists_f_outliers() {
        // f=2 Byzantine rows at ±1e9 cannot move the median beyond the
        // correct values' range.
        let mut rows: Vec<Vec<f32>> = (0..9).map(|i| vec![i as f32; 3]).collect();
        rows.push(vec![1e9; 3]);
        rows.push(vec![-1e9; 3]);
        let g = GradMatrix::from_rows(&rows);
        let out = CoordMedian::new(11, 2).unwrap().aggregate(&g).unwrap();
        for v in out {
            assert!((0.0..=8.0).contains(&v));
        }
    }

    #[test]
    fn requires_majority() {
        assert!(CoordMedian::new(4, 2).is_err());
        assert!(CoordMedian::new(5, 2).is_ok());
    }

    #[test]
    fn parallel_matches_sequential_bitwise() {
        let g = GradMatrix::from_fn(11, 16_000, |i, j| ((i * 13 + j * 7) % 257) as f32 * 0.01);
        let seq = CoordMedian::new(11, 2).unwrap().aggregate(&g).unwrap();
        let par = CoordMedian::new(11, 2)
            .unwrap()
            .with_parallelism(Parallelism::new(3))
            .aggregate(&g)
            .unwrap();
        assert_eq!(seq, par);
    }
}
