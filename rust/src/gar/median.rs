//! Coordinate-wise median — the paper's MEDIAN comparator (Fig. 2, Fig. 3).
//!
//! Weakly Byzantine resilient for `n ≥ 2f+1` but, by keeping (the
//! equivalent of) a single gradient per step, it forfeits the variance
//! reduction of averaging — the effect Fig. 3 quantifies.
//!
//! There is no O(n²) decision to make: the selection phase is a no-op
//! recording the `CoordMedian` plan, and all the work happens in the
//! per-coordinate combine (insertion sort below n = 64, introselect
//! above — see `gar::selection`).

use super::selection::{CombinePlan, Selection};
use super::{check_select_shape, Gar, GarScratch};
use crate::runtime::Parallelism;
use crate::tensor::GradMatrix;
use crate::Result;

/// Coordinate-wise median over the `n` proposed gradients. Even `n`
/// averages the two central values (the `torch.median`-style convention
/// used by the paper's baseline is the lower median; we follow `jnp.median`
/// to stay bit-compatible with the L1/L2 artifact — the choice does not
/// affect any resilience property, see `tests::even_n_convention`).
#[derive(Debug, Clone)]
pub struct CoordMedian {
    n: usize,
    f: usize,
    par: Parallelism,
}

impl CoordMedian {
    pub fn new(n: usize, f: usize) -> Result<Self> {
        anyhow::ensure!(
            n >= 2 * f + 1,
            "median: requires n ≥ 2f+1 (got n={n}, f={f})"
        );
        Ok(Self {
            n,
            f,
            par: Parallelism::sequential(),
        })
    }

    /// Use `par` for the coordinate-sharded O(nd) combine.
    pub fn with_parallelism(mut self, par: Parallelism) -> Self {
        self.par = par;
        self
    }
}

impl Gar for CoordMedian {
    fn name(&self) -> &'static str {
        "median"
    }

    fn n(&self) -> usize {
        self.n
    }

    fn f(&self) -> usize {
        self.f
    }

    fn parallelism(&self) -> &Parallelism {
        &self.par
    }

    /// The median keeps the informational equivalent of one gradient.
    fn gradients_used(&self) -> usize {
        1
    }

    fn select_into(
        &self,
        grads: &GradMatrix,
        _scratch: &mut GarScratch,
        sel: &mut Selection,
    ) -> Result<()> {
        check_select_shape("median", grads, self.n)?;
        sel.reset(CombinePlan::CoordMedian, self.n);
        // Which worker's value wins is decided per coordinate; every row
        // can reach the output, so the selection reports all of them.
        sel.rows.extend(0..self.n);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn per_coordinate_median() {
        let g = GradMatrix::from_rows(&[
            vec![1.0, 10.0],
            vec![2.0, 30.0],
            vec![9.0, 20.0],
        ]);
        let gar = CoordMedian::new(3, 1).unwrap();
        assert_eq!(gar.aggregate(&g).unwrap(), vec![2.0, 20.0]);
    }

    #[test]
    fn even_n_convention() {
        let g = GradMatrix::from_rows(&[vec![1.0], vec![2.0], vec![3.0], vec![10.0]]);
        let gar = CoordMedian::new(4, 1).unwrap();
        assert_eq!(gar.aggregate(&g).unwrap(), vec![2.5]);
    }

    #[test]
    fn resists_f_outliers() {
        // f=2 Byzantine rows at ±1e9 cannot move the median beyond the
        // correct values' range.
        let mut rows: Vec<Vec<f32>> = (0..9).map(|i| vec![i as f32; 3]).collect();
        rows.push(vec![1e9; 3]);
        rows.push(vec![-1e9; 3]);
        let g = GradMatrix::from_rows(&rows);
        let out = CoordMedian::new(11, 2).unwrap().aggregate(&g).unwrap();
        for v in out {
            assert!((0.0..=8.0).contains(&v));
        }
    }

    #[test]
    fn requires_majority() {
        assert!(CoordMedian::new(4, 2).is_err());
        assert!(CoordMedian::new(5, 2).is_ok());
    }

    #[test]
    fn parallel_matches_sequential_bitwise() {
        let g = GradMatrix::from_fn(11, 16_000, |i, j| ((i * 13 + j * 7) % 257) as f32 * 0.01);
        let seq = CoordMedian::new(11, 2).unwrap().aggregate(&g).unwrap();
        let par = CoordMedian::new(11, 2)
            .unwrap()
            .with_parallelism(Parallelism::new(3))
            .aggregate(&g)
            .unwrap();
        assert_eq!(seq, par);
    }
}
