//! Scalar statistics + coordinate-wise reductions used by the GARs and the
//! benchmark harnesses (Fig. 2's "mean of the 5 runs closest to the
//! median" protocol lives on these primitives).

use super::select::median_inplace;

/// Arithmetic mean. Returns 0 for an empty slice.
pub fn mean(values: &[f32]) -> f32 {
    if values.is_empty() {
        return 0.0;
    }
    values.iter().sum::<f32>() / values.len() as f32
}

/// Sample standard deviation (n−1 denominator). Returns 0 for n < 2.
pub fn std_dev(values: &[f32]) -> f32 {
    if values.len() < 2 {
        return 0.0;
    }
    let m = mean(values);
    let var = values.iter().map(|v| (v - m) * (v - m)).sum::<f32>() / (values.len() - 1) as f32;
    var.sqrt()
}

/// Median of a slice, averaging the two central elements for even lengths
/// (the convention of `jnp.median` / `torch.median`-as-used-in-the-paper's
/// MEDIAN baseline). Copies the input; panics on empty.
pub fn coordinate_median(values: &[f32]) -> f32 {
    assert!(!values.is_empty(), "coordinate_median: empty");
    let mut buf = values.to_vec();
    median_of_buf(&mut buf)
}

/// Median over a scratch buffer the caller owns (no allocation); mutates
/// the buffer. Averages the two central elements for even lengths.
pub fn median_of_buf(buf: &mut [f32]) -> f32 {
    let n = buf.len();
    let lower = median_inplace(buf);
    if n % 2 == 1 {
        lower
    } else {
        // `median_inplace` partitioned around index (n-1)/2; the upper
        // median is the min of the right partition.
        let upper = buf[n / 2..]
            .iter()
            .copied()
            .fold(f32::INFINITY, f32::min);
        0.5 * (lower + upper)
    }
}

/// Welford online mean/variance accumulator — used by the metrics registry
/// for timing series without storing all samples.
#[derive(Debug, Clone, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n−1); 0 when n < 2.
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
        assert!((std_dev(&[2.0, 4.0]) - std::f32::consts::SQRT_2).abs() < 1e-6);
        assert_eq!(std_dev(&[1.0]), 0.0);
    }

    #[test]
    fn median_conventions() {
        assert_eq!(coordinate_median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(coordinate_median(&[4.0, 1.0, 3.0, 2.0]), 2.5);
        assert_eq!(coordinate_median(&[7.0]), 7.0);
        assert_eq!(coordinate_median(&[1.0, 2.0]), 1.5);
    }

    #[test]
    fn online_stats_matches_batch() {
        let xs = [1.0f64, 2.0, 3.0, 4.0, 10.0];
        let mut st = OnlineStats::new();
        for &x in &xs {
            st.push(x);
        }
        assert_eq!(st.count(), 5);
        assert!((st.mean() - 4.0).abs() < 1e-12);
        let batch_var = xs.iter().map(|x| (x - 4.0).powi(2)).sum::<f64>() / 4.0;
        assert!((st.variance() - batch_var).abs() < 1e-12);
        assert_eq!(st.min(), 1.0);
        assert_eq!(st.max(), 10.0);
    }
}
