//! [`GradMatrix`] — the `n × d` row-major matrix of worker gradients that
//! every GAR consumes. Rows are worker proposals; `d` is the model
//! dimension (up to 10⁷ in the Fig. 2 sweep, so the layout is flat and
//! contiguous, never `Vec<Vec<f32>>`).

use crate::util::Rng64;

/// Row-major `n × d` matrix of gradients (one row per worker).
#[derive(Debug, Clone, PartialEq)]
pub struct GradMatrix {
    data: Vec<f32>,
    n: usize,
    d: usize,
}

impl GradMatrix {
    /// Zero-filled `n × d` matrix.
    pub fn zeros(n: usize, d: usize) -> Self {
        Self {
            data: vec![0.0; n * d],
            n,
            d,
        }
    }

    /// Build from a generator `f(row, col)`.
    pub fn from_fn(n: usize, d: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(n * d);
        for i in 0..n {
            for j in 0..d {
                data.push(f(i, j));
            }
        }
        Self { data, n, d }
    }

    /// Wrap an existing flat buffer (must be exactly `n*d` long).
    pub fn from_flat(data: Vec<f32>, n: usize, d: usize) -> Self {
        assert_eq!(data.len(), n * d, "from_flat: buffer is not n*d");
        Self { data, n, d }
    }

    /// Stack `n` equally-sized row vectors.
    pub fn from_rows(rows: &[Vec<f32>]) -> Self {
        assert!(!rows.is_empty(), "from_rows: no rows");
        let d = rows[0].len();
        let mut data = Vec::with_capacity(rows.len() * d);
        for r in rows {
            assert_eq!(r.len(), d, "from_rows: ragged rows");
            data.extend_from_slice(r);
        }
        Self {
            data,
            n: rows.len(),
            d,
        }
    }

    /// i.i.d. `U(lo, hi)` samples — the Fig. 2 protocol uses `U(0,1)^d`.
    pub fn uniform(n: usize, d: usize, lo: f32, hi: f32, rng: &mut Rng64) -> Self {
        let data = (0..n * d).map(|_| rng.gen_range_f32(lo, hi)).collect();
        Self { data, n, d }
    }

    /// i.i.d. standard-normal samples scaled by `sigma`.
    pub fn gaussian(n: usize, d: usize, sigma: f32, rng: &mut Rng64) -> Self {
        let data = (0..n * d).map(|_| sigma * rng.gaussian()).collect();
        Self { data, n, d }
    }

    /// Number of rows (workers).
    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    /// Row width (model dimension `d`).
    #[inline]
    pub fn d(&self) -> usize {
        self.d
    }

    /// Borrow row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.d..(i + 1) * self.d]
    }

    /// Mutably borrow row `i`.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.d..(i + 1) * self.d]
    }

    /// Overwrite row `i`.
    pub fn set_row(&mut self, i: usize, values: &[f32]) {
        assert_eq!(values.len(), self.d, "set_row: wrong width");
        self.row_mut(i).copy_from_slice(values);
    }

    /// The full flat buffer (row-major).
    #[inline]
    pub fn flat(&self) -> &[f32] {
        &self.data
    }

    /// Mutable flat buffer.
    #[inline]
    pub fn flat_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Consume into the flat buffer.
    pub fn into_flat(self) -> Vec<f32> {
        self.data
    }

    /// New matrix keeping only `rows` (in the given order).
    pub fn gather_rows(&self, rows: &[usize]) -> Self {
        let mut data = Vec::with_capacity(rows.len() * self.d);
        for &r in rows {
            data.extend_from_slice(self.row(r));
        }
        Self {
            data,
            n: rows.len(),
            d: self.d,
        }
    }

    /// Column-wise mean of all rows (the averaging GAR's core).
    pub fn mean_rows(&self) -> Vec<f32> {
        self.mean_of_rows(&(0..self.n).collect::<Vec<_>>())
    }

    /// Column-wise mean of a subset of rows.
    pub fn mean_of_rows(&self, rows: &[usize]) -> Vec<f32> {
        assert!(!rows.is_empty(), "mean_of_rows: no rows");
        let mut out = vec![0.0f32; self.d];
        for &r in rows {
            super::add_assign(&mut out, self.row(r));
        }
        super::scale(&mut out, 1.0 / rows.len() as f32);
        out
    }

    /// True if any entry is NaN or infinite.
    pub fn has_non_finite(&self) -> bool {
        self.data.iter().any(|v| !v.is_finite())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    #[test]
    fn construction_and_views() {
        let m = GradMatrix::from_fn(3, 4, |i, j| (10 * i + j) as f32);
        assert_eq!(m.n(), 3);
        assert_eq!(m.d(), 4);
        assert_eq!(m.row(1), &[10.0, 11.0, 12.0, 13.0]);
        assert_eq!(m.flat().len(), 12);
    }

    #[test]
    fn from_rows_roundtrip() {
        let rows = vec![vec![1.0f32, 2.0], vec![3.0, 4.0]];
        let m = GradMatrix::from_rows(&rows);
        assert_eq!(m.row(0), &[1.0, 2.0]);
        assert_eq!(m.row(1), &[3.0, 4.0]);
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn ragged_rows_panic() {
        GradMatrix::from_rows(&[vec![1.0], vec![1.0, 2.0]]);
    }

    #[test]
    fn gather_and_mean() {
        let m = GradMatrix::from_fn(4, 2, |i, _| i as f32);
        let g = m.gather_rows(&[3, 1]);
        assert_eq!(g.n(), 2);
        assert_eq!(g.row(0), &[3.0, 3.0]);
        assert_eq!(m.mean_rows(), vec![1.5, 1.5]);
        assert_eq!(m.mean_of_rows(&[0, 3]), vec![1.5, 1.5]);
    }

    #[test]
    fn set_row_and_mut() {
        let mut m = GradMatrix::zeros(2, 3);
        m.set_row(1, &[1.0, 2.0, 3.0]);
        assert_eq!(m.row(1), &[1.0, 2.0, 3.0]);
        m.row_mut(0)[2] = 9.0;
        assert_eq!(m.row(0), &[0.0, 0.0, 9.0]);
    }

    #[test]
    fn random_in_unit_interval() {
        let mut rng = Rng64::seed_from_u64(7);
        let m = GradMatrix::uniform(5, 100, 0.0, 1.0, &mut rng);
        assert!(m.flat().iter().all(|&v| (0.0..1.0).contains(&v)));
        let g = GradMatrix::gaussian(3, 50, 2.0, &mut rng);
        assert!(g.flat().iter().any(|&v| v.abs() > 0.5));
    }

    #[test]
    fn non_finite_detection() {
        let mut m = GradMatrix::zeros(2, 2);
        assert!(!m.has_non_finite());
        m.row_mut(0)[1] = f32::NAN;
        assert!(m.has_non_finite());
    }
}
