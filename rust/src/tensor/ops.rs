//! Element-wise and reduction primitives over `&[f32]`.
//!
//! These are the innermost loops of every GAR; they are written so that
//! rustc/LLVM auto-vectorizes them (simple indexed loops over equal-length
//! slices, no bounds checks after the initial `assert_eq`).

/// Dot product `⟨a, b⟩`.
///
/// Panics if the slices have different lengths.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len(), "dot: length mismatch");
    // Four-way unrolled accumulation: breaks the sequential FP dependency
    // chain so LLVM can keep multiple vector accumulators in flight.
    let mut acc = [0f32; 4];
    let chunks = a.len() / 4;
    for c in 0..chunks {
        let i = c * 4;
        acc[0] += a[i] * b[i];
        acc[1] += a[i + 1] * b[i + 1];
        acc[2] += a[i + 2] * b[i + 2];
        acc[3] += a[i + 3] * b[i + 3];
    }
    let mut tail = 0f32;
    for i in chunks * 4..a.len() {
        tail += a[i] * b[i];
    }
    acc[0] + acc[1] + acc[2] + acc[3] + tail
}

/// Squared ℓ2 distance `‖a − b‖²` — the MULTI-KRUM scoring primitive.
#[inline]
pub fn sq_distance(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len(), "sq_distance: length mismatch");
    let mut acc = [0f32; 4];
    let chunks = a.len() / 4;
    for c in 0..chunks {
        let i = c * 4;
        let d0 = a[i] - b[i];
        let d1 = a[i + 1] - b[i + 1];
        let d2 = a[i + 2] - b[i + 2];
        let d3 = a[i + 3] - b[i + 3];
        acc[0] += d0 * d0;
        acc[1] += d1 * d1;
        acc[2] += d2 * d2;
        acc[3] += d3 * d3;
    }
    let mut tail = 0f32;
    for i in chunks * 4..a.len() {
        let d = a[i] - b[i];
        tail += d * d;
    }
    acc[0] + acc[1] + acc[2] + acc[3] + tail
}

/// Squared ℓ2 norm `‖a‖²`.
#[inline]
pub fn l2_norm_sq(a: &[f32]) -> f32 {
    dot(a, a)
}

/// ℓ2 norm `‖a‖`.
#[inline]
pub fn l2_norm(a: &[f32]) -> f32 {
    l2_norm_sq(a).sqrt()
}

/// `y += alpha * x` (BLAS axpy). The SGD update inner loop.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len(), "axpy: length mismatch");
    for i in 0..x.len() {
        y[i] += alpha * x[i];
    }
}

/// `y += x`.
#[inline]
pub fn add_assign(y: &mut [f32], x: &[f32]) {
    assert_eq!(x.len(), y.len(), "add_assign: length mismatch");
    for i in 0..x.len() {
        y[i] += x[i];
    }
}

/// `a *= alpha` in place.
#[inline]
pub fn scale(a: &mut [f32], alpha: f32) {
    for v in a.iter_mut() {
        *v *= alpha;
    }
}

/// `out = a − b` (allocates).
#[inline]
pub fn sub(a: &[f32], b: &[f32]) -> Vec<f32> {
    assert_eq!(a.len(), b.len(), "sub: length mismatch");
    a.iter().zip(b).map(|(x, y)| x - y).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f32> = (0..103).map(|i| i as f32 * 0.5).collect();
        let b: Vec<f32> = (0..103).map(|i| (i as f32).sin()).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-2 * naive.abs().max(1.0));
    }

    #[test]
    fn dot_empty_is_zero() {
        assert_eq!(dot(&[], &[]), 0.0);
    }

    #[test]
    fn sq_distance_basic() {
        assert_eq!(sq_distance(&[0.0, 0.0], &[3.0, 4.0]), 25.0);
        assert_eq!(sq_distance(&[1.0; 7], &[1.0; 7]), 0.0);
    }

    #[test]
    fn sq_distance_is_symmetric() {
        let a: Vec<f32> = (0..57).map(|i| (i as f32).cos()).collect();
        let b: Vec<f32> = (0..57).map(|i| (i as f32 * 1.3).sin()).collect();
        assert_eq!(sq_distance(&a, &b), sq_distance(&b, &a));
    }

    #[test]
    fn norms() {
        assert_eq!(l2_norm_sq(&[3.0, 4.0]), 25.0);
        assert_eq!(l2_norm(&[3.0, 4.0]), 5.0);
        assert_eq!(l2_norm(&[]), 0.0);
    }

    #[test]
    fn axpy_and_scale() {
        let x = vec![1.0f32, 2.0, 3.0];
        let mut y = vec![10.0f32, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![12.0, 24.0, 36.0]);
        scale(&mut y, 0.5);
        assert_eq!(y, vec![6.0, 12.0, 18.0]);
    }

    #[test]
    fn sub_basic() {
        assert_eq!(sub(&[5.0, 7.0], &[2.0, 3.0]), vec![3.0, 4.0]);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn dot_length_mismatch_panics() {
        dot(&[1.0], &[1.0, 2.0]);
    }
}
