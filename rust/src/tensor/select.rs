//! Selection primitives: k-smallest, argselect, in-place median.
//!
//! MULTI-KRUM needs "the m indices with smallest score" and "the n−f−2
//! nearest neighbours of gradient i"; BULYAN needs "the β values closest to
//! the median of each coordinate". All of these are *selection* problems —
//! a full sort would cost O(n log n) where O(n) suffices, and the paper's
//! O(d) complexity claim leans on exactly this. We use
//! `select_nth_unstable` (introselect) throughout.

/// Return the indices of the `k` smallest values of `scores`, in ascending
/// score order. `O(n + k log k)`.
///
/// NaN scores are ordered after all non-NaN scores (i.e. treated as +∞),
/// so a Byzantine NaN score can never be selected while a finite one
/// remains. Panics if `k > scores.len()`.
pub fn argselect_smallest(scores: &[f32], k: usize) -> Vec<usize> {
    assert!(
        k <= scores.len(),
        "argselect_smallest: k={k} > n={}",
        scores.len()
    );
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    if k == 0 {
        return Vec::new();
    }
    let cmp = |&a: &usize, &b: &usize| {
        scores[a]
            .partial_cmp(&scores[b])
            // At least one NaN: the NaN side must order *after* (treat
            // as +∞), so compare the is_nan flags (true > false).
            .unwrap_or_else(|| scores[a].is_nan().cmp(&scores[b].is_nan()))
    };
    if k < idx.len() {
        idx.select_nth_unstable_by(k - 1, cmp);
        idx.truncate(k);
    }
    idx.sort_unstable_by(cmp);
    idx
}

/// Copy of the `k` smallest values of `values`, ascending. `O(n + k log k)`.
pub fn select_k_smallest(values: &[f32], k: usize) -> Vec<f32> {
    argselect_smallest(values, k)
        .into_iter()
        .map(|i| values[i])
        .collect()
}

/// In-place median via introselect. For even lengths this returns the
/// *lower* median — matching `jnp.median`'s behaviour is handled one level
/// up (see [`crate::tensor::coordinate_median`], which averages the two
/// middle elements like the paper's `Median` reference implementation).
///
/// Panics on an empty slice.
pub fn median_inplace(values: &mut [f32]) -> f32 {
    assert!(!values.is_empty(), "median_inplace: empty slice");
    let mid = (values.len() - 1) / 2;
    let (_, m, _) = values.select_nth_unstable_by(mid, |a, b| a.total_cmp(b));
    *m
}

/// Insertion sort — for the tiny per-coordinate slices (n ≤ 64) of the
/// median-family GARs, where it beats the general introselect machinery
/// by 3-5× (no indirection, fully branch-predictable at small n).
#[inline]
pub fn insertion_sort(v: &mut [f32]) {
    for i in 1..v.len() {
        let x = v[i];
        let mut j = i;
        while j > 0 && v[j - 1] > x {
            v[j] = v[j - 1];
            j -= 1;
        }
        v[j] = x;
    }
}

/// Median of a small buffer via insertion sort; averages the two central
/// elements for even lengths (same convention as
/// [`crate::tensor::median_of_buf`]). Mutates the buffer.
#[inline]
pub fn small_median_sorting(v: &mut [f32]) -> f32 {
    debug_assert!(!v.is_empty());
    insertion_sort(v);
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argselect_basic() {
        let s = [5.0f32, 1.0, 4.0, 2.0, 3.0];
        assert_eq!(argselect_smallest(&s, 3), vec![1, 3, 4]);
        assert_eq!(argselect_smallest(&s, 5), vec![1, 3, 4, 2, 0]);
        assert_eq!(argselect_smallest(&s, 0), Vec::<usize>::new());
    }

    #[test]
    fn argselect_nan_goes_last() {
        let s = [f32::NAN, 2.0, 1.0];
        assert_eq!(argselect_smallest(&s, 2), vec![2, 1]);
        // Even selecting all, NaN ranks last.
        assert_eq!(argselect_smallest(&s, 3), vec![2, 1, 0]);
    }

    #[test]
    fn argselect_ties_stable_enough() {
        // With ties, any of the tied indices is acceptable; scores must be
        // ascending.
        let s = [2.0f32, 1.0, 2.0, 1.0];
        let picked = argselect_smallest(&s, 2);
        let mut vals: Vec<f32> = picked.iter().map(|&i| s[i]).collect();
        vals.sort_by(f32::total_cmp);
        assert_eq!(vals, vec![1.0, 1.0]);
    }

    #[test]
    fn select_k_values() {
        let s = [9.0f32, -1.0, 3.0, 0.0];
        assert_eq!(select_k_smallest(&s, 2), vec![-1.0, 0.0]);
    }

    #[test]
    fn median_odd_even() {
        let mut v = vec![3.0f32, 1.0, 2.0];
        assert_eq!(median_inplace(&mut v), 2.0);
        let mut v = vec![4.0f32, 1.0, 3.0, 2.0];
        // lower median of {1,2,3,4} is 2
        assert_eq!(median_inplace(&mut v), 2.0);
    }

    #[test]
    #[should_panic(expected = "empty")]
    fn median_empty_panics() {
        median_inplace(&mut []);
    }

    #[test]
    fn insertion_sort_and_small_median() {
        let mut v = vec![3.0f32, -1.0, 2.0, 0.0];
        insertion_sort(&mut v);
        assert_eq!(v, vec![-1.0, 0.0, 2.0, 3.0]);
        assert_eq!(small_median_sorting(&mut [5.0, 1.0, 3.0]), 3.0);
        assert_eq!(small_median_sorting(&mut [4.0, 1.0, 3.0, 2.0]), 2.5);
        // Agreement with the general path on random-ish data.
        for k in 1..20 {
            let mut a: Vec<f32> = (0..k).map(|i| ((i * 37 + 11) % 17) as f32).collect();
            let mut b = a.clone();
            let x = small_median_sorting(&mut a);
            let y = crate::tensor::median_of_buf(&mut b);
            assert_eq!(x, y, "k={k}");
        }
    }
}
