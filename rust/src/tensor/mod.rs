//! Dense f32 vector / matrix substrate.
//!
//! Everything in the aggregation path operates on flat `&[f32]` slices and
//! the row-major [`GradMatrix`] (one row per worker gradient). The module
//! is deliberately dependency-free: the GAR hot loops (pairwise distances,
//! coordinate-wise selection) are implemented here with cache-tiling and
//! no per-call allocation, which is what the Fig. 2 benchmarks time.

mod grad_matrix;
mod ops;
mod select;
mod stats;

pub use grad_matrix::GradMatrix;
pub use ops::{add_assign, axpy, dot, l2_norm, l2_norm_sq, scale, sq_distance, sub};
pub use select::{
    argselect_smallest, insertion_sort, median_inplace, select_k_smallest, small_median_sorting,
};
pub use stats::{coordinate_median, mean, median_of_buf, std_dev, OnlineStats};
