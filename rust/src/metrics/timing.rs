//! Timing utilities implementing the paper's Fig. 2 measurement protocol:
//! "7 runs per (n, d), remove the 2 furthest execution times from the
//! median, report mean and standard deviation of the 5 remaining".

use crate::tensor::{coordinate_median, mean, std_dev};
// wall-clock: this module IS the measurement substrate — every Instant
// here times real execution for the Fig. 2 protocol, never scheduling.
use std::time::Instant;

/// Simple monotonic stopwatch.
#[derive(Debug)]
pub struct Stopwatch {
    // wall-clock: stopwatch epoch — the thing being measured.
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Self {
            // wall-clock: reads real time by definition of a stopwatch.
            start: Instant::now(),
        }
    }

    /// Elapsed seconds since start.
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Elapsed milliseconds since start.
    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_s() * 1e3
    }

    pub fn restart(&mut self) {
        // wall-clock: re-arms the measured epoch.
        self.start = Instant::now();
    }
}

/// The Fig. 2 protocol: `runs` repetitions, keep the `keep` closest to the
/// median, report mean ± std of those.
#[derive(Debug, Clone, Copy)]
pub struct TimingProtocol {
    pub runs: usize,
    pub keep: usize,
    /// Untimed warmup iterations before the measured runs.
    pub warmup: usize,
}

impl Default for TimingProtocol {
    /// The paper's protocol: 7 runs, keep the 5 closest to the median.
    fn default() -> Self {
        Self {
            runs: 7,
            keep: 5,
            warmup: 1,
        }
    }
}

impl TimingProtocol {
    /// A faster protocol for smoke runs.
    pub fn quick() -> Self {
        Self {
            runs: 3,
            keep: 3,
            warmup: 0,
        }
    }

    /// Time `op` per the protocol; returns `(mean_ms, std_ms)`.
    pub fn measure(&self, mut op: impl FnMut()) -> (f64, f64) {
        for _ in 0..self.warmup {
            op();
        }
        let samples: Vec<f32> = (0..self.runs)
            .map(|_| {
                let sw = Stopwatch::start();
                op();
                sw.elapsed_ms() as f32
            })
            .collect();
        trimmed_timing(&samples, self.keep)
    }
}

/// Keep the `keep` samples closest to the median; return (mean, std).
pub fn trimmed_timing(samples_ms: &[f32], keep: usize) -> (f64, f64) {
    assert!(!samples_ms.is_empty());
    let keep = keep.min(samples_ms.len());
    let med = coordinate_median(samples_ms);
    let mut by_dist: Vec<f32> = samples_ms.to_vec();
    by_dist.sort_by(|a, b| (a - med).abs().total_cmp(&(b - med).abs()));
    let kept = &by_dist[..keep];
    (mean(kept) as f64, std_dev(kept) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trimmed_removes_outliers() {
        // 5 samples near 10ms plus two wild outliers.
        let samples = [10.0f32, 10.2, 9.8, 10.1, 9.9, 100.0, 0.1];
        let (m, s) = trimmed_timing(&samples, 5);
        assert!((m - 10.0).abs() < 0.2, "mean {m}");
        assert!(s < 0.3, "std {s}");
    }

    #[test]
    fn keep_larger_than_len_is_clamped() {
        let (m, _) = trimmed_timing(&[5.0], 10);
        assert_eq!(m, 5.0);
    }

    #[test]
    fn measure_counts_runs() {
        let mut calls = 0;
        let proto = TimingProtocol {
            runs: 4,
            keep: 3,
            warmup: 2,
        };
        let (m, s) = proto.measure(|| calls += 1);
        assert_eq!(calls, 6);
        assert!(m >= 0.0 && s >= 0.0);
    }

    #[test]
    fn stopwatch_monotonic() {
        let mut sw = Stopwatch::start();
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(sw.elapsed_ms() >= 1.0);
        sw.restart();
        assert!(sw.elapsed_ms() < 100.0);
    }
}
