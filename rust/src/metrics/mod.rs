//! Metrics: timers, counters, training curves and the CSV emission that
//! EXPERIMENTS.md is generated from.
//!
//! Deliberately minimal — a process-local registry, no global state, no
//! background threads; the coordinator owns one [`MetricsRecorder`] and
//! threads it through the round loop.

mod recorder;
mod timing;

pub use recorder::{MetricsRecorder, TrainPoint};
pub use timing::{trimmed_timing, Stopwatch, TimingProtocol};
