//! Training-run metrics: loss/accuracy curves, per-round timings, CSV and
//! JSONL emission. One [`MetricsRecorder`] per training run.

use crate::tensor::OnlineStats;
use crate::Result;
use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

/// One evaluation point on the training curve.
#[derive(Debug, Clone, PartialEq)]
pub struct TrainPoint {
    pub step: usize,
    pub loss: f32,
    /// Top-1 accuracy in [0,1]; NaN if not evaluated at this point.
    pub accuracy: f32,
}

/// Accumulates everything a run reports.
#[derive(Debug, Default)]
pub struct MetricsRecorder {
    curve: Vec<TrainPoint>,
    counters: BTreeMap<String, u64>,
    timers: BTreeMap<String, OnlineStats>,
    /// Per-worker selection counts (how often each worker's gradient was
    /// used by the GAR — the selection-bias diagnostic).
    selections: Vec<u64>,
}

impl MetricsRecorder {
    pub fn new(n_workers: usize) -> Self {
        Self {
            selections: vec![0; n_workers],
            ..Default::default()
        }
    }

    pub fn record_point(&mut self, point: TrainPoint) {
        self.curve.push(point);
    }

    pub fn incr(&mut self, counter: &str) {
        self.add(counter, 1);
    }

    pub fn add(&mut self, counter: &str, delta: u64) {
        *self.counters.entry(counter.to_string()).or_default() += delta;
    }

    pub fn time(&mut self, timer: &str, seconds: f64) {
        self.timers
            .entry(timer.to_string())
            .or_insert_with(OnlineStats::new)
            .push(seconds);
    }

    pub fn record_selection(&mut self, worker: usize) {
        if let Some(s) = self.selections.get_mut(worker) {
            *s += 1;
        }
    }

    pub fn curve(&self) -> &[TrainPoint] {
        &self.curve
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn timer(&self, name: &str) -> Option<&OnlineStats> {
        self.timers.get(name)
    }

    pub fn selections(&self) -> &[u64] {
        &self.selections
    }

    /// Best (max) accuracy over the run — the Fig. 3 metric ("maximum
    /// top-1 cross-accuracy reached over the whole training").
    pub fn max_accuracy(&self) -> f32 {
        self.curve
            .iter()
            .map(|p| p.accuracy)
            .filter(|a| a.is_finite())
            // LINT: reduce-ok -- max over finite values is associative
            // and commutative; order cannot change the result.
            .fold(f32::NEG_INFINITY, f32::max)
    }

    /// Final loss (last curve point).
    pub fn final_loss(&self) -> Option<f32> {
        self.curve.last().map(|p| p.loss)
    }

    /// Write the curve as CSV: `step,loss,accuracy`.
    pub fn write_curve_csv(&self, path: impl AsRef<Path>) -> Result<()> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
        writeln!(w, "step,loss,accuracy")?;
        for p in &self.curve {
            writeln!(w, "{},{},{}", p.step, p.loss, p.accuracy)?;
        }
        Ok(())
    }

    /// One-paragraph human summary for stdout.
    pub fn summary(&self) -> String {
        let mut s = String::new();
        if let Some(last) = self.curve.last() {
            s.push_str(&format!(
                "steps={} final_loss={:.5} max_acc={:.4}",
                last.step,
                last.loss,
                self.max_accuracy()
            ));
        }
        for (name, st) in &self.timers {
            s.push_str(&format!(
                " {}={:.3}ms(n={})",
                name,
                st.mean() * 1e3,
                st.count()
            ));
        }
        for (name, v) in &self.counters {
            s.push_str(&format!(" {name}={v}"));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn curve_and_max_accuracy() {
        let mut m = MetricsRecorder::new(3);
        m.record_point(TrainPoint {
            step: 0,
            loss: 2.0,
            accuracy: 0.1,
        });
        m.record_point(TrainPoint {
            step: 100,
            loss: 1.0,
            accuracy: 0.8,
        });
        m.record_point(TrainPoint {
            step: 200,
            loss: 0.9,
            accuracy: 0.7,
        });
        assert_eq!(m.max_accuracy(), 0.8);
        assert_eq!(m.final_loss(), Some(0.9));
        assert_eq!(m.curve().len(), 3);
    }

    #[test]
    fn counters_timers_selections() {
        let mut m = MetricsRecorder::new(2);
        m.incr("rounds");
        m.add("rounds", 2);
        assert_eq!(m.counter("rounds"), 3);
        assert_eq!(m.counter("missing"), 0);
        m.time("aggregate", 0.5);
        m.time("aggregate", 1.5);
        assert_eq!(m.timer("aggregate").unwrap().count(), 2);
        m.record_selection(0);
        m.record_selection(0);
        m.record_selection(1);
        m.record_selection(99); // out of range: ignored
        assert_eq!(m.selections(), &[2, 1]);
    }

    #[test]
    fn csv_emission() {
        let mut m = MetricsRecorder::new(1);
        m.record_point(TrainPoint {
            step: 5,
            loss: 0.5,
            accuracy: f32::NAN,
        });
        let dir = std::env::temp_dir().join("mb_metrics_test");
        let path = dir.join("curve.csv");
        m.write_curve_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("step,loss,accuracy\n5,0.5,NaN"));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn summary_mentions_key_numbers() {
        let mut m = MetricsRecorder::new(1);
        m.record_point(TrainPoint {
            step: 10,
            loss: 0.25,
            accuracy: 0.9,
        });
        m.incr("rounds");
        let s = m.summary();
        assert!(s.contains("final_loss=0.25"));
        assert!(s.contains("rounds=1"));
    }
}
