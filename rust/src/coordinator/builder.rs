//! Cluster launcher: wire transport + workers + coordinator + evaluator
//! from an [`ExperimentConfig`].

use crate::config::{ExperimentConfig, ModelConfig};
use crate::data::{FashionLike, QuadraticProblem, TokenStream};
use crate::runtime::{ComputeHandle, Manifest, Parallelism};
use crate::training::LrSchedule;
use crate::transport::{self, ComputeCost, FaultModel, SocketOptions, TransportKind};
use crate::worker::{serve_workers_coded, GradSource};
use crate::Result;
use std::sync::Arc;
use std::time::Duration;

use super::core::{Coordinator, CoordinatorOptions};
use super::evaluator::Evaluator;

/// A running cluster, ready to train.
pub struct LaunchedCluster {
    /// The parameter server, already connected to its workers.
    pub coordinator: Coordinator,
    /// Scores the parameters between training bursts (`train` calls it
    /// every `eval_every` rounds).
    pub evaluator: Evaluator,
    /// The declared experiment (for reporting).
    pub config: ExperimentConfig,
}

/// Build and launch everything described by `config`.
///
/// `compute` must be `Some` when the model is [`ModelConfig::Artifact`];
/// the quadratic workload runs entirely in rust.
pub fn launch(
    config: &ExperimentConfig,
    compute: Option<(ComputeHandle, Manifest)>,
) -> Result<LaunchedCluster> {
    config.validate()?;
    let n = config.cluster.n;
    let byz = config.byzantine_count();
    let honest = n - byz;
    let seed = config.train.seed;

    let churn = transport::ChurnModel {
        leave_round: config.cluster.churn_leave_round,
        leave_workers: config.cluster.churn_workers,
        rejoin_round: config.cluster.churn_rejoin_round,
    };
    let faults = FaultModel {
        delay_us: config.cluster.net_delay_us,
        drop_prob: config.cluster.drop_prob,
        seed,
        cost: ComputeCost {
            base_us: config.cluster.compute_cost_us,
            slow_workers: config.cluster.stragglers,
            slow_factor: config.cluster.straggler_factor as f32,
        },
        churn,
    };
    // One pool shared by the GAR passes and (on the pooled transport) the
    // logical workers; results are bit-identical to sequential for every
    // thread count.
    let par = Parallelism::new(config.threads);
    // An explicit listen address means external `multibulyan worker`
    // processes own the worker slots; without one the socket backend
    // binds an ephemeral loopback port and serves in-process clients.
    let socket = SocketOptions {
        listen: config.cluster.socket_listen.clone(),
        chunk: config.cluster.socket_chunk,
        external: config.cluster.socket_listen.is_some(),
        codec: config.codec.unwrap_or_default(),
    };
    let (mut server, endpoints) =
        transport::build_cluster(config.transport, honest, faults, &par, &socket)?;
    // Intra-gradient coordinate sharding for the quadratic workers: real
    // OS worker threads (threaded, socket clients) may share the
    // aggregation pool (regions serialise), but pooled logical workers
    // already run *on* it and the pool is not reentrant — they compute
    // sequentially, the across-worker fan-out is what saturates the pool
    // there.
    let worker_par = match config.transport {
        TransportKind::Threaded | TransportKind::Socket => par.clone(),
        TransportKind::Pooled => Parallelism::sequential(),
    };

    let (initial_params, evaluator) = match &config.model {
        ModelConfig::Quadratic { dim, noise } => {
            let problem = Arc::new(QuadraticProblem::new(*dim, *noise, seed));
            let pairs = endpoints
                .into_iter()
                .enumerate()
                .map(|(i, ep)| {
                    (
                        ep,
                        GradSource::quadratic_sharded(
                            Arc::clone(&problem),
                            i,
                            config.train.batch_size,
                            worker_par.clone(),
                        ),
                    )
                })
                .collect();
            serve_workers_coded(pairs, config.codec);
            (
                vec![0.0f32; *dim],
                Evaluator::Quadratic(Arc::clone(&problem)),
            )
        }
        ModelConfig::Artifact { name, dir: _ } => {
            let (handle, manifest) = compute.ok_or_else(|| {
                anyhow::anyhow!("model '{name}' needs a PJRT compute handle + manifest")
            })?;
            let model = manifest.model(name)?.clone();
            let grad_artifact = model.grad_artifact(config.train.batch_size)?.to_string();
            // Pre-compile once so round 1 isn't a compile stall.
            handle.warmup(&grad_artifact)?;

            let init = crate::runtime::read_f32_bin(manifest.dir.join(&model.init_file))?;
            anyhow::ensure!(
                init.len() == model.dim,
                "init file has {} params; manifest says {}",
                init.len(),
                model.dim
            );

            if name == "transformer" {
                // LM workload over the synthetic bigram corpus.
                let stream = Arc::new(TokenStream::new(model.num_classes, 4, seed));
                let seq_len = model.feature_dim;
                let pairs = endpoints
                    .into_iter()
                    .enumerate()
                    .map(|(i, ep)| {
                        (
                            ep,
                            GradSource::lm(
                                handle.clone(),
                                grad_artifact.clone(),
                                Arc::clone(&stream),
                                seq_len,
                                i,
                                honest,
                                config.train.batch_size,
                                seed.wrapping_add(1000 + i as u64),
                            ),
                        )
                    })
                    .collect();
                serve_workers_coded(pairs, config.codec);
                let evaluator = Evaluator::Lm {
                    handle,
                    artifact: grad_artifact,
                    stream,
                    seq_len,
                    batch_size: config.train.batch_size,
                    batches: 4,
                };
                (init, evaluator)
            } else {
                let dataset = Arc::new(FashionLike::small(seed));
                let pairs = endpoints
                    .into_iter()
                    .enumerate()
                    .map(|(i, ep)| {
                        (
                            ep,
                            GradSource::artifact(
                                handle.clone(),
                                grad_artifact.clone(),
                                Arc::clone(&dataset),
                                i,
                                honest,
                                config.train.batch_size,
                                seed.wrapping_add(1000 + i as u64),
                            ),
                        )
                    })
                    .collect();
                serve_workers_coded(pairs, config.codec);
                let evaluator = match &model.eval {
                    Some(eval_artifact) => Evaluator::Artifact {
                        handle,
                        artifact: eval_artifact.clone(),
                        dataset,
                        eval_batch: model.eval_batch,
                    },
                    None => Evaluator::Disabled,
                };
                (init, evaluator)
            }
        }
    };

    let options = CoordinatorOptions {
        round_timeout: Duration::from_millis(config.cluster.round_timeout_ms),
        schedule: LrSchedule::Fixed {
            base: config.train.learning_rate,
        },
        seed,
        collect: config.collect,
        overlap: config.overlap,
        overlap_window: config.overlap_window,
        churn,
        journal: config.journal.as_ref().map(std::path::PathBuf::from),
        crash_after_round: config.crash_after_round,
    };
    // Pre-aggregation pipeline stages (gar = "rmom(0.9)+…"), sharing the
    // aggregation pool. A leading group(g) stage is the collection layer
    // consumed by the grouped builder, not a matrix stage — it never
    // instantiates.
    let stages = config
        .pre
        .iter()
        .filter(|s| !matches!(s, crate::gar::StageSpec::GroupAggregate { .. }))
        .map(|s| s.instantiate(&par))
        .collect::<Result<Vec<_>>>()?;
    let groups = config.effective_groups();
    let coordinator = if groups > 1 {
        // Two-level hierarchy: workers stream-reduce into `groups` group
        // rows (transport-side where the backend supports it), and the
        // root GAR — instantiated over g rows with the scaled Byzantine
        // bound f_root — aggregates the group vectors. `validate()` has
        // already checked the partition shape and the root quorum; the
        // builder re-checks every cross-knob constraint once more at
        // build time (the single validation point).
        let map = crate::gar::GroupMap::new(n, byz, groups)?;
        let root_f = crate::gar::group::root_f_for(n, config.cluster.f, groups);
        let reducer = Arc::new(crate::gar::GroupReducer::new(map, initial_params.len()));
        server.install_group_reducer(Arc::clone(&reducer));
        Coordinator::builder(config.gar.instantiate_parallel(groups, root_f, &par)?)
            .attack(config.attack.instantiate(), byz)
            .options(options)
            .pre_stages(stages)
            .grouped(reducer)
            .build(
                server,
                initial_params,
                config.train.learning_rate,
                config.train.momentum,
            )?
    } else {
        // The flat path is always launched elastic: the factory lets a
        // round re-instantiate the rule when scripted churn or a live
        // (socket) departure shrinks the membership view.
        Coordinator::builder(config.gar.instantiate_parallel(n, config.cluster.f, &par)?)
            .attack(config.attack.instantiate(), byz)
            .options(options)
            .pre_stages(stages)
            .elastic(config.gar, par.clone())
            .build(
                server,
                initial_params,
                config.train.learning_rate,
                config.train.momentum,
            )?
    };

    Ok(LaunchedCluster {
        coordinator,
        evaluator,
        config: config.clone(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attacks::AttackKind;
    use crate::gar::GarKind;

    #[test]
    fn launch_quadratic_and_train() {
        let mut cfg = ExperimentConfig::fig3_default(GarKind::MultiKrum);
        cfg.model = ModelConfig::Quadratic {
            dim: 24,
            noise: 0.05,
        };
        cfg.cluster.n = 7;
        cfg.cluster.f = 1;
        cfg.cluster.actual_byzantine = Some(1);
        cfg.attack = AttackKind::SignFlip { scale: 5.0 };
        cfg.train.steps = 40;
        cfg.train.batch_size = 8;
        let mut cluster = launch(&cfg, None).unwrap();
        let mut evaluator = cluster.evaluator;
        cluster
            .coordinator
            .train(40, 10, &mut evaluator)
            .unwrap();
        let loss = cluster.coordinator.metrics.final_loss().unwrap();
        assert!(loss < 0.01, "loss {loss}");
        cluster.coordinator.shutdown();
    }

    #[test]
    fn thread_pool_run_is_bit_identical_to_sequential() {
        // The `threads` knob is a pure latency knob: a seeded run must
        // produce bit-identical parameters at every thread count.
        let run = |threads: usize| -> Vec<f32> {
            let mut cfg = ExperimentConfig::fig3_default(GarKind::MultiBulyan);
            cfg.model = ModelConfig::Quadratic {
                dim: 9_000,
                noise: 0.2,
            };
            cfg.threads = threads;
            cfg.train.steps = 5;
            cfg.train.batch_size = 4;
            let mut cluster = launch(&cfg, None).unwrap();
            for _ in 0..5 {
                let view = cluster.coordinator.next_view();
                cluster.coordinator.run_round(&view).unwrap();
            }
            let params = cluster.coordinator.params().to_vec();
            cluster.coordinator.shutdown();
            params
        };
        assert_eq!(run(1), run(4));
    }

    #[test]
    fn transport_is_a_pure_latency_knob() {
        // Same seed ⇒ bit-identical parameters on either transport (and
        // at any thread count): gradients are counter-seeded, fault RNGs
        // are per-worker, and the GAR passes are order-fixed.
        let run = |transport: TransportKind, threads: usize| -> Vec<f32> {
            let mut cfg = ExperimentConfig::fig3_default(GarKind::MultiKrum);
            cfg.model = ModelConfig::Quadratic {
                dim: 512,
                noise: 0.3,
            };
            cfg.transport = transport;
            cfg.threads = threads;
            cfg.train.batch_size = 4;
            let mut cluster = launch(&cfg, None).unwrap();
            for _ in 0..6 {
                let view = cluster.coordinator.next_view();
                cluster.coordinator.run_round(&view).unwrap();
            }
            let params = cluster.coordinator.params().to_vec();
            cluster.coordinator.shutdown();
            params
        };
        let reference = run(TransportKind::Threaded, 1);
        assert_eq!(reference, run(TransportKind::Pooled, 1));
        assert_eq!(reference, run(TransportKind::Pooled, 4));
        assert_eq!(reference, run(TransportKind::Threaded, 2));
        assert_eq!(reference, run(TransportKind::Socket, 1));
        assert_eq!(reference, run(TransportKind::Socket, 2));
    }

    #[test]
    fn resilient_momentum_pipeline_trains_and_stays_deterministic() {
        // gar = "rmom(0.9)+multi-bulyan": converges under sign-flip and
        // is bit-identical across thread counts (the momentum stage is
        // coordinate-sharded like every other pass).
        let run = |threads: usize| -> (f32, Vec<f32>) {
            let mut cfg = ExperimentConfig::from_text(
                r#"
                gar = "rmom(0.5)+multi-bulyan"
                attack = "sign-flip"
                [cluster]
                n = 11
                f = 2
                actual_byzantine = 2
                [model]
                kind = "quadratic"
                dim = 48
                noise = 0.05
                [train]
                learning_rate = 0.2
                momentum = 0.0
                steps = 80
                batch_size = 8
                seed = 3
                "#,
            )
            .unwrap();
            cfg.threads = threads;
            let mut cluster = launch(&cfg, None).unwrap();
            let mut evaluator = cluster.evaluator;
            cluster.coordinator.train(80, 10, &mut evaluator).unwrap();
            let loss = cluster.coordinator.metrics.final_loss().unwrap();
            let params = cluster.coordinator.params().to_vec();
            cluster.coordinator.shutdown();
            (loss, params)
        };
        let (loss, params) = run(1);
        assert!(loss < 1e-2, "rmom+multi-bulyan under sign-flip: loss {loss}");
        let (_, params4) = run(4);
        assert_eq!(params, params4, "threads must stay a pure latency knob");
    }

    #[test]
    fn artifact_model_requires_compute() {
        let cfg = ExperimentConfig::fig3_default(GarKind::MultiBulyan);
        match launch(&cfg, None) {
            Err(err) => assert!(err.to_string().contains("compute")),
            Ok(_) => panic!("expected launch to fail without a compute handle"),
        }
    }
}
