//! Per-round cluster membership — the elastic-fleet surface.
//!
//! A [`MembershipView`] names the honest workers expected to
//! participate in one round. The coordinator computes the next round's
//! view from the scripted churn model ([`crate::transport::ChurnModel`],
//! the pooled/threaded backends) and from live departure tracking
//! (Goodbye frames and crash-detected disconnects on the socket
//! backend, `ServerEndpoint::departed_workers`), then passes it to
//! [`crate::Coordinator::run_round`].
//!
//! **Determinism contract:** a *full* view routes the round through the
//! unchanged fixed-fleet path, bit for bit — elasticity costs nothing
//! until a worker actually leaves (property-tested in
//! `rust/tests/prop_membership.rs` across every GAR × transport ×
//! thread count). A *shrunken* view re-shards the round: active workers
//! are compacted to matrix rows by view rank, the GAR is
//! re-instantiated at `n' = active + byz` (construction revalidates the
//! quorum `n' ≥ min_n(f)`), and any shape change re-zeros
//! `ResilientMomentum` state deliberately (Farhadkhani et al.'s
//! momentum-then-aggregate composition is re-entered from a clean
//! state rather than mixing momentum across fleets).

use crate::Result;

/// The honest workers expected to participate in one round.
///
/// `workers` holds *original* worker ids (the launch-time numbering —
/// ids are never renumbered by churn), strictly ascending. `f` is the
/// declared Byzantine tolerance the round's GAR must honour.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MembershipView {
    /// The 1-based round this view applies to.
    pub round: u64,
    /// Original ids of the honest workers present this round, strictly
    /// ascending.
    pub workers: Vec<usize>,
    /// Byzantine tolerance `f` in force for this round.
    pub f: usize,
}

impl MembershipView {
    /// The full fixed-fleet view: every honest worker `0..n_honest`
    /// present. Rounds driven with a full view are bit-identical to the
    /// pre-elastic fixed-fleet path.
    pub fn full(round: u64, n_honest: usize, f: usize) -> Self {
        Self {
            round,
            workers: (0..n_honest).collect(),
            f,
        }
    }

    /// Number of honest workers present.
    pub fn active(&self) -> usize {
        self.workers.len()
    }

    /// Whether every honest worker of an `n_honest`-strong fleet is
    /// present (the view degenerates to the fixed-fleet path).
    pub fn is_full(&self, n_honest: usize) -> bool {
        self.workers.len() == n_honest
            && self.workers.iter().copied().eq(0..n_honest)
    }

    /// Whether `worker` (original id) participates this round.
    pub fn contains(&self, worker: usize) -> bool {
        self.workers.binary_search(&worker).is_ok()
    }

    /// The matrix row (view rank) assigned to `worker` this round, or
    /// `None` for a non-member. Rank compaction is the elastic
    /// re-shard: row `r` of the round's proposal matrix is the `r`-th
    /// present worker in ascending id order, a pure function of the
    /// view — identical across transports and thread counts.
    pub fn rank(&self, worker: usize) -> Option<usize> {
        self.workers.binary_search(&worker).ok()
    }

    /// Check the view is well-formed for an `n_honest`-strong fleet:
    /// strictly ascending ids, all `< n_honest`, at least one present.
    pub fn validate(&self, n_honest: usize) -> Result<()> {
        anyhow::ensure!(
            !self.workers.is_empty(),
            "membership view for round {} is empty",
            self.round
        );
        anyhow::ensure!(
            self.workers.windows(2).all(|w| w[0] < w[1]),
            "membership view for round {} is not strictly ascending",
            self.round
        );
        let max = *self.workers.last().expect("non-empty");
        anyhow::ensure!(
            max < n_honest,
            "membership view for round {} names worker {max} \
             (fleet has {n_honest} honest workers)",
            self.round
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_view_is_full() {
        let v = MembershipView::full(1, 5, 1);
        assert!(v.is_full(5));
        assert_eq!(v.active(), 5);
        assert!(v.contains(4));
        assert_eq!(v.rank(3), Some(3));
        v.validate(5).unwrap();
    }

    #[test]
    fn shrunken_view_ranks_compact() {
        let v = MembershipView {
            round: 3,
            workers: vec![0, 2, 4],
            f: 1,
        };
        assert!(!v.is_full(5));
        assert!(!v.contains(1));
        assert_eq!(v.rank(2), Some(1));
        assert_eq!(v.rank(4), Some(2));
        assert_eq!(v.rank(3), None);
        v.validate(5).unwrap();
    }

    #[test]
    fn validate_rejects_malformed_views() {
        let empty = MembershipView {
            round: 1,
            workers: vec![],
            f: 1,
        };
        assert!(empty.validate(4).is_err());
        let unsorted = MembershipView {
            round: 1,
            workers: vec![2, 1],
            f: 1,
        };
        assert!(unsorted.validate(4).is_err());
        let oob = MembershipView {
            round: 1,
            workers: vec![0, 7],
            f: 1,
        };
        assert!(oob.validate(4).is_err());
    }
}
