//! Model evaluation: the Fig. 3 metric (top-1 cross-accuracy on the held-
//! out split) plus loss curves for the quadratic and LM workloads.

use crate::data::{Batch, FashionLike, QuadraticProblem, TokenStream, IMAGE_DIM};
use crate::runtime::{ArgValue, ComputeHandle};
use crate::Result;
use std::sync::Arc;

/// How to score the current parameters. Returns `(loss, accuracy)`;
/// accuracy is NaN for workloads without a classification metric.
pub enum Evaluator {
    /// Closed-form loss of the quadratic problem.
    Quadratic(Arc<QuadraticProblem>),
    /// Classifier accuracy+loss over the FashionLike test split via the
    /// AOT eval artifact (fixed chunk size `eval_batch`).
    Artifact {
        /// PJRT executor for the eval artifact.
        handle: ComputeHandle,
        /// Eval artifact name in the manifest.
        artifact: String,
        /// Held-out split provider.
        dataset: Arc<FashionLike>,
        /// Fixed chunk size the artifact was compiled for.
        eval_batch: usize,
    },
    /// LM held-out loss via the gradient artifact's loss output (the
    /// gradient itself is discarded).
    Lm {
        /// PJRT executor for the gradient artifact.
        handle: ComputeHandle,
        /// Gradient artifact name (its loss output is what's scored).
        artifact: String,
        /// Held-out token sequences (MSB-set stream ids).
        stream: Arc<TokenStream>,
        /// Sequence length the artifact was compiled for.
        seq_len: usize,
        /// Sequences per eval batch.
        batch_size: usize,
        /// Number of eval batches averaged per call.
        batches: usize,
    },
    /// No evaluation (returns NaN/NaN).
    Disabled,
}

impl Evaluator {
    /// Score `params`: `(loss, accuracy)`; accuracy is NaN for workloads
    /// without a classification metric.
    pub fn evaluate(&mut self, params: &[f32]) -> Result<(f32, f32)> {
        match self {
            Evaluator::Quadratic(problem) => Ok((problem.loss(params), f32::NAN)),
            Evaluator::Artifact {
                handle,
                artifact,
                dataset,
                eval_batch,
            } => {
                let e = *eval_batch;
                let total = dataset.test_len();
                anyhow::ensure!(e > 0 && total > 0, "empty eval configuration");
                let mut correct = 0.0f64;
                let mut loss_sum = 0.0f64;
                let mut chunks = 0usize;
                let mut batch = Batch::new(e, IMAGE_DIM);
                let mut idx = Vec::with_capacity(e);
                let mut start = 0;
                while start < total {
                    idx.clear();
                    // Wrap the final partial chunk (duplicates score
                    // identically; counts use `seen`, not `e`).
                    let seen = e.min(total - start);
                    for k in 0..e {
                        idx.push((start + k) % total);
                    }
                    dataset.fill_batch(1, &idx, &mut batch);
                    let out = handle
                        .execute(
                            artifact,
                            vec![
                                ArgValue::f32_vec(params.to_vec()),
                                ArgValue::F32(batch.features.clone(), vec![e, IMAGE_DIM]),
                                ArgValue::I32(batch.labels.clone(), vec![e]),
                            ],
                        )?;
                    // Output 0: per-example correctness (f32 0/1, length e).
                    // Output 1: mean loss over the chunk.
                    let flags = out
                        .first()
                        .ok_or_else(|| anyhow::anyhow!("eval artifact returned no outputs"))?;
                    anyhow::ensure!(
                        flags.len() == e,
                        "eval artifact output 0 has length {}, expected {e}",
                        flags.len()
                    );
                    // LINT: reduce-ok -- counts 0/1 accuracy flags over
                    // one eval chunk, sequentially in index order.
                    correct += flags[..seen].iter().map(|&v| v as f64).sum::<f64>();
                    loss_sum += out
                        .get(1)
                        .and_then(|l| l.first())
                        .copied()
                        .unwrap_or(f32::NAN) as f64;
                    chunks += 1;
                    start += seen;
                }
                Ok((
                    (loss_sum / chunks as f64) as f32,
                    (correct / total as f64) as f32,
                ))
            }
            Evaluator::Lm {
                handle,
                artifact,
                stream,
                seq_len,
                batch_size,
                batches,
            } => {
                let (b, l) = (*batch_size, *seq_len);
                let mut loss_sum = 0.0f64;
                for chunk in 0..*batches {
                    let mut tokens = Vec::with_capacity(b * l);
                    let mut targets = Vec::with_capacity(b * l);
                    for row in 0..b {
                        // Held-out stream ids: odd ids reserved for eval.
                        let sid = 0x8000_0000_0000_0000u64 | ((chunk * b + row) as u64);
                        let (inp, tgt) = stream.sequence(sid, l);
                        tokens.extend(inp);
                        targets.extend(tgt);
                    }
                    let out = handle
                        .execute(
                            artifact,
                            vec![
                                ArgValue::f32_vec(params.to_vec()),
                                ArgValue::I32(tokens, vec![b, l]),
                                ArgValue::I32(targets, vec![b, l]),
                            ],
                        )?;
                    loss_sum += out
                        .get(1)
                        .and_then(|o| o.first())
                        .copied()
                        .unwrap_or(f32::NAN) as f64;
                }
                Ok(((loss_sum / *batches as f64) as f32, f32::NAN))
            }
            Evaluator::Disabled => Ok((f32::NAN, f32::NAN)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quadratic_evaluator_reports_loss() {
        let p = Arc::new(QuadraticProblem::new(10, 0.1, 2));
        let mut e = Evaluator::Quadratic(Arc::clone(&p));
        let (loss_at_opt, acc) = e.evaluate(p.optimum()).unwrap();
        assert!(loss_at_opt < 1e-9);
        assert!(acc.is_nan());
        let (loss_away, _) = e.evaluate(&vec![5.0; 10]).unwrap();
        assert!(loss_away > loss_at_opt);
    }

    #[test]
    fn disabled_evaluator_is_nan() {
        let mut e = Evaluator::Disabled;
        let (l, a) = e.evaluate(&[1.0]).unwrap();
        assert!(l.is_nan() && a.is_nan());
    }
}
