//! The durable round-journal — crash-safe coordinator state.
//!
//! An append-only file of framed round records (one fsync'd frame per
//! committed round) that lets a restarted coordinator prove it is
//! resuming the *same* run: on restart, rounds up to the journal's
//! last committed round are re-executed deterministically and each
//! replayed round's params checksum is **verified** against the journal
//! (a mismatch — wrong seed, wrong config, edited journal — is a hard
//! error, never a silent divergence); only genuinely new rounds append.
//! Because every layer below the coordinator is deterministic (see
//! `docs/architecture.md`), verified replay reconstructs the full
//! in-memory state — optimizer velocity, momentum-stage state,
//! straggler caches — that a params snapshot could not capture, and an
//! interrupted-then-resumed run is bit-identical to an uninterrupted
//! one (CI's crash-recovery determinism leg).
//!
//! ## On-disk format (normative copy in `docs/wire-protocol.md` §8)
//!
//! The file reuses the MBWP framing discipline: little-endian fixed
//! width fields, one FNV-1a-checksummed frame per record.
//!
//! ```text
//! file   := header record*
//! header := "MBJR" version:u16 reserved:u16          (8 bytes)
//! record := payload_len:u32 payload checksum:u64     (checksum = FNV-1a of payload)
//! payload := round:u64 params_checksum:u64 f:u32
//!            n_workers:u32 worker_id:u32 ×n_workers
//!            n_selected:u32 selected_row:u32 ×n_selected
//!            collected:u32 missing:u32
//! ```
//!
//! **Torn-tail rule:** an *incomplete* trailing frame (the coordinator
//! died mid-write) is truncated away on open — the journal recovers to
//! the last fully committed round. A *complete* frame whose checksum
//! does not match is corruption, not a torn write, and fails `open`
//! hard. **Exactly-once rule:** `commit` only accepts round
//! `last_committed + 1`; re-committing an already-journalled round is
//! an error, which is what makes the injected-crash recovery test
//! meaningful.

use crate::util::fnv1a;
use crate::Result;
use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

/// Journal file magic (`header` above).
pub const JOURNAL_MAGIC: [u8; 4] = *b"MBJR";

/// Journal format version.
pub const JOURNAL_VERSION: u16 = 1;

/// Largest accepted record payload (a torn length field can claim
/// anything; a real record is a few KiB even at n = 10⁴ workers).
const MAX_PAYLOAD: u32 = 64 << 20;

/// One committed round: everything needed to verify a deterministic
/// replay and to audit what the round did.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RoundRecord {
    /// 1-based round id.
    pub round: u64,
    /// FNV-1a over the post-round model parameters' LE bytes (the same
    /// digest `train --params-checksum` prints).
    pub params_checksum: u64,
    /// Byzantine tolerance in force for the round.
    pub f: u32,
    /// The round's membership view (original honest worker ids,
    /// ascending).
    pub workers: Vec<u32>,
    /// Worker ids the GAR's selection phase picked (original ids, as
    /// reported in `RoundOutcome::selected` — elastic rounds map matrix
    /// rows back before committing).
    pub selected: Vec<u32>,
    /// Honest gradients received before the quorum/deadline.
    pub collected: u32,
    /// Honest slots that fell through the straggler cache.
    pub missing: u32,
}

impl RoundRecord {
    fn encode(&self) -> Vec<u8> {
        let mut p = Vec::with_capacity(36 + 4 * (self.workers.len() + self.selected.len()));
        p.extend_from_slice(&self.round.to_le_bytes());
        p.extend_from_slice(&self.params_checksum.to_le_bytes());
        p.extend_from_slice(&self.f.to_le_bytes());
        p.extend_from_slice(&(self.workers.len() as u32).to_le_bytes());
        for w in &self.workers {
            p.extend_from_slice(&w.to_le_bytes());
        }
        p.extend_from_slice(&(self.selected.len() as u32).to_le_bytes());
        for s in &self.selected {
            p.extend_from_slice(&s.to_le_bytes());
        }
        p.extend_from_slice(&self.collected.to_le_bytes());
        p.extend_from_slice(&self.missing.to_le_bytes());
        p
    }

    fn decode(payload: &[u8]) -> Result<Self> {
        let mut c = Cursor { buf: payload, at: 0 };
        let round = c.u64()?;
        let params_checksum = c.u64()?;
        let f = c.u32()?;
        let nw = c.u32()? as usize;
        let mut workers = Vec::with_capacity(nw.min(1 << 16));
        for _ in 0..nw {
            workers.push(c.u32()?);
        }
        let ns = c.u32()? as usize;
        let mut selected = Vec::with_capacity(ns.min(1 << 16));
        for _ in 0..ns {
            selected.push(c.u32()?);
        }
        let collected = c.u32()?;
        let missing = c.u32()?;
        anyhow::ensure!(
            c.at == payload.len(),
            "journal record has {} trailing bytes",
            payload.len() - c.at
        );
        Ok(Self {
            round,
            params_checksum,
            f,
            workers,
            selected,
            collected,
            missing,
        })
    }
}

/// Bounds-checked little-endian reader over a record payload.
struct Cursor<'a> {
    buf: &'a [u8],
    at: usize,
}

impl Cursor<'_> {
    fn take(&mut self, n: usize) -> Result<&[u8]> {
        anyhow::ensure!(
            self.at + n <= self.buf.len(),
            "journal record truncated inside a field"
        );
        let s = &self.buf[self.at..self.at + n];
        self.at += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }
}

/// The append-only round-journal (see the module docs for the format
/// and the recovery rules).
pub struct Journal {
    file: File,
    path: PathBuf,
    records: Vec<RoundRecord>,
    /// Bytes dropped by torn-tail recovery on open (0 for a clean file).
    truncated_bytes: u64,
}

impl Journal {
    /// Open (or create) the journal at `path`, replaying every committed
    /// record. An incomplete trailing frame is truncated away (torn
    /// write — the commit never completed); a complete frame with a bad
    /// checksum, a bad magic/version, or a non-contiguous round sequence
    /// fails hard.
    pub fn open(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref().to_path_buf();
        let mut file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(false)
            .open(&path)
            .map_err(|e| anyhow::anyhow!("journal {}: {e}", path.display()))?;
        let mut bytes = Vec::new();
        file.read_to_end(&mut bytes)?;
        if bytes.is_empty() {
            let mut header = Vec::with_capacity(8);
            header.extend_from_slice(&JOURNAL_MAGIC);
            header.extend_from_slice(&JOURNAL_VERSION.to_le_bytes());
            header.extend_from_slice(&0u16.to_le_bytes());
            file.write_all(&header)?;
            file.sync_data()?;
            return Ok(Self {
                file,
                path,
                records: Vec::new(),
                truncated_bytes: 0,
            });
        }
        anyhow::ensure!(
            bytes.len() >= 8 && bytes[..4] == JOURNAL_MAGIC,
            "journal {}: bad magic (not a journal file)",
            path.display()
        );
        let version = u16::from_le_bytes([bytes[4], bytes[5]]);
        anyhow::ensure!(
            version == JOURNAL_VERSION,
            "journal {}: version {version} (this build speaks {JOURNAL_VERSION})",
            path.display()
        );
        let mut records: Vec<RoundRecord> = Vec::new();
        let mut good = 8usize; // offset past the last fully-committed record
        let mut at = 8usize;
        loop {
            if at == bytes.len() {
                break; // clean tail
            }
            // Frame = len:u32 payload checksum:u64. Anything that runs
            // past EOF — a partial length field, a length claiming more
            // bytes than remain, a missing checksum — is a torn tail.
            if at + 4 > bytes.len() {
                break;
            }
            let len = u32::from_le_bytes(bytes[at..at + 4].try_into().expect("4 bytes"));
            if len > MAX_PAYLOAD {
                break; // torn length field
            }
            let len = len as usize;
            if at + 4 + len + 8 > bytes.len() {
                break;
            }
            let payload = &bytes[at + 4..at + 4 + len];
            let sum = u64::from_le_bytes(
                bytes[at + 4 + len..at + 4 + len + 8]
                    .try_into()
                    .expect("8 bytes"),
            );
            // A *complete* frame with a bad checksum is corruption, not
            // a torn write — refuse to resume from a lying journal.
            anyhow::ensure!(
                fnv1a(payload.iter().copied()) == sum,
                "journal {}: record at offset {at} fails its checksum \
                 (corrupt journal; refusing to resume)",
                path.display()
            );
            let rec = RoundRecord::decode(payload)?;
            let expect = records.last().map_or(1, |r: &RoundRecord| r.round + 1);
            anyhow::ensure!(
                rec.round == expect,
                "journal {}: round {} follows round {} (gap or reorder)",
                path.display(),
                rec.round,
                expect - 1
            );
            at += 4 + len + 8;
            good = at;
            records.push(rec);
        }
        let truncated_bytes = (bytes.len() - good) as u64;
        if truncated_bytes > 0 {
            // Torn tail: drop the partial frame so the next commit
            // appends a clean one.
            file.set_len(good as u64)?;
            file.sync_data()?;
        }
        file.seek(SeekFrom::End(0))?;
        Ok(Self {
            file,
            path,
            records,
            truncated_bytes,
        })
    }

    /// The journal's path (for logs and the CI artifact upload).
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Last committed round (0 when the journal is empty).
    pub fn last_committed(&self) -> u64 {
        self.records.last().map_or(0, |r| r.round)
    }

    /// Bytes discarded by torn-tail recovery when the journal was
    /// opened (0 for a cleanly closed file).
    pub fn truncated_bytes(&self) -> u64 {
        self.truncated_bytes
    }

    /// The committed record for `round`, if any.
    pub fn record(&self, round: u64) -> Option<&RoundRecord> {
        if round == 0 || round > self.last_committed() {
            return None;
        }
        self.records.get((round - 1) as usize)
    }

    /// The committed params checksum for `round`, if any — what a
    /// replayed round must reproduce bit-exactly.
    pub fn expected_checksum(&self, round: u64) -> Option<u64> {
        self.record(round).map(|r| r.params_checksum)
    }

    /// Durably append one round. Exactly-once: the record's round must
    /// be `last_committed + 1` — a crashed-and-resumed coordinator that
    /// replays committed rounds verifies them against
    /// [`Journal::expected_checksum`] instead of re-committing. The
    /// frame is flushed and `fsync`'d before this returns; a crash at
    /// any point leaves either the old tail or the full new frame.
    pub fn commit(&mut self, rec: RoundRecord) -> Result<()> {
        anyhow::ensure!(
            rec.round == self.last_committed() + 1,
            "journal {}: commit for round {} but last committed is {} \
             (exactly-once: only round {} may commit)",
            self.path.display(),
            rec.round,
            self.last_committed(),
            self.last_committed() + 1
        );
        let payload = rec.encode();
        let mut frame = Vec::with_capacity(12 + payload.len());
        frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        frame.extend_from_slice(&payload);
        frame.extend_from_slice(&fnv1a(payload.iter().copied()).to_le_bytes());
        self.file.write_all(&frame)?;
        self.file.sync_data()?;
        self.records.push(rec);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("mb_journal_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn rec(round: u64) -> RoundRecord {
        RoundRecord {
            round,
            params_checksum: 0xDEAD_BEEF ^ round,
            f: 1,
            workers: vec![0, 1, 2, 4],
            selected: vec![0, 2],
            collected: 4,
            missing: 0,
        }
    }

    #[test]
    fn roundtrip_across_reopen() {
        let path = tmp("roundtrip.mbjr");
        let _ = std::fs::remove_file(&path);
        {
            let mut j = Journal::open(&path).unwrap();
            assert_eq!(j.last_committed(), 0);
            j.commit(rec(1)).unwrap();
            j.commit(rec(2)).unwrap();
            j.commit(rec(3)).unwrap();
        }
        let j = Journal::open(&path).unwrap();
        assert_eq!(j.last_committed(), 3);
        assert_eq!(j.truncated_bytes(), 0);
        assert_eq!(j.record(2), Some(&rec(2)));
        assert_eq!(j.expected_checksum(3), Some(0xDEAD_BEEF ^ 3));
        assert_eq!(j.record(4), None);
        assert_eq!(j.record(0), None);
    }

    #[test]
    fn commit_is_exactly_once() {
        let path = tmp("exactly_once.mbjr");
        let _ = std::fs::remove_file(&path);
        let mut j = Journal::open(&path).unwrap();
        j.commit(rec(1)).unwrap();
        // Re-committing round 1 or skipping to round 3 both violate the
        // gapless exactly-once contract.
        assert!(j.commit(rec(1)).is_err());
        assert!(j.commit(rec(3)).is_err());
        j.commit(rec(2)).unwrap();
        assert_eq!(j.last_committed(), 2);
    }

    #[test]
    fn torn_tail_recovers_to_last_committed() {
        let path = tmp("torn_tail.mbjr");
        let _ = std::fs::remove_file(&path);
        {
            let mut j = Journal::open(&path).unwrap();
            j.commit(rec(1)).unwrap();
            j.commit(rec(2)).unwrap();
        }
        // Simulate a crash mid-append: chop the file inside record 2's
        // frame.
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() - 7]).unwrap();
        let j = Journal::open(&path).unwrap();
        assert_eq!(j.last_committed(), 1);
        assert!(j.truncated_bytes() > 0);
        assert_eq!(j.record(2), None);
        // The torn bytes were physically truncated: a fresh reopen sees
        // a clean single-record file and the next commit appends round 2.
        let mut j = Journal::open(&path).unwrap();
        assert_eq!(j.truncated_bytes(), 0);
        j.commit(rec(2)).unwrap();
        assert_eq!(Journal::open(&path).unwrap().last_committed(), 2);
    }

    #[test]
    fn torn_length_field_is_a_torn_tail() {
        let path = tmp("torn_len.mbjr");
        let _ = std::fs::remove_file(&path);
        {
            let mut j = Journal::open(&path).unwrap();
            j.commit(rec(1)).unwrap();
        }
        // Append 3 stray bytes — not even a whole length field.
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.extend_from_slice(&[0xFF, 0xFF, 0xFF]);
        std::fs::write(&path, &bytes).unwrap();
        let j = Journal::open(&path).unwrap();
        assert_eq!(j.last_committed(), 1);
        assert_eq!(j.truncated_bytes(), 3);
    }

    #[test]
    fn corrupt_checksum_is_a_hard_error() {
        let path = tmp("corrupt.mbjr");
        let _ = std::fs::remove_file(&path);
        {
            let mut j = Journal::open(&path).unwrap();
            j.commit(rec(1)).unwrap();
            j.commit(rec(2)).unwrap();
        }
        // Flip a byte inside record 1's payload: the frame is complete,
        // so this is corruption, not a torn tail — open must refuse.
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[8 + 4 + 2] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        let err = Journal::open(&path).unwrap_err().to_string();
        assert!(err.contains("checksum"), "unexpected error: {err}");
    }

    #[test]
    fn bad_magic_and_version_are_rejected() {
        let path = tmp("bad_magic.mbjr");
        std::fs::write(&path, b"NOPE\x01\x00\x00\x00").unwrap();
        assert!(Journal::open(&path).unwrap_err().to_string().contains("magic"));
        let path = tmp("bad_version.mbjr");
        let mut h = Vec::new();
        h.extend_from_slice(&JOURNAL_MAGIC);
        h.extend_from_slice(&7u16.to_le_bytes());
        h.extend_from_slice(&0u16.to_le_bytes());
        std::fs::write(&path, &h).unwrap();
        assert!(Journal::open(&path).unwrap_err().to_string().contains("version"));
    }
}
