//! The round loop: broadcast → collect → forge → aggregate → update.

use crate::attacks::{Attack, AttackCtx};
use crate::gar::{Gar, GarScratch};
use crate::metrics::{MetricsRecorder, Stopwatch, TrainPoint};
use crate::tensor::GradMatrix;
use crate::training::{LrSchedule, Sgd};
use crate::transport::ServerEndpoint;
use crate::util::Rng64;
use crate::Result;
use std::sync::Arc;
use std::time::Duration;

use super::evaluator::Evaluator;

/// Tunables not covered by the experiment config.
#[derive(Debug, Clone)]
pub struct CoordinatorOptions {
    /// How long to wait for a round's gradients before falling back.
    pub round_timeout: Duration,
    /// LR schedule (defaults to the paper's fixed rate).
    pub schedule: LrSchedule,
    pub seed: u64,
}

impl Default for CoordinatorOptions {
    fn default() -> Self {
        Self {
            round_timeout: Duration::from_secs(30),
            schedule: LrSchedule::Fixed { base: 0.1 },
            seed: 1,
        }
    }
}

/// What one round produced (for logs/benches).
#[derive(Debug, Clone)]
pub struct RoundOutcome {
    pub round: u64,
    /// Honest gradients received before the timeout.
    pub collected: usize,
    /// Honest gradients substituted from the last-known cache.
    pub missing: usize,
    /// GAR aggregation wall time, seconds.
    pub agg_seconds: f64,
}

/// The parameter server.
pub struct Coordinator {
    n: usize,
    /// Number of Byzantine workers actually simulated this run.
    byz: usize,
    gar: Box<dyn Gar>,
    attack: Option<Box<dyn Attack>>,
    server: ServerEndpoint,
    params: Vec<f32>,
    opt: Sgd,
    options: CoordinatorOptions,
    grads: GradMatrix,
    agg: Vec<f32>,
    /// Last successfully received gradient per honest worker (straggler
    /// fallback — reusing a stale gradient keeps the GAR's input square
    /// and is the standard synchronous-PS recovery).
    last_good: Vec<Option<Vec<f32>>>,
    scratch: GarScratch,
    rng: Rng64,
    round: u64,
    pub metrics: MetricsRecorder,
}

impl Coordinator {
    /// `server` must be a star over exactly `n − byz` honest workers.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        gar: Box<dyn Gar>,
        attack: Option<Box<dyn Attack>>,
        byz: usize,
        server: ServerEndpoint,
        initial_params: Vec<f32>,
        lr: f32,
        momentum: f32,
        options: CoordinatorOptions,
    ) -> Result<Self> {
        let n = gar.n();
        anyhow::ensure!(byz <= n, "byzantine count {byz} > n {n}");
        anyhow::ensure!(
            server.num_workers() == n - byz,
            "transport has {} honest workers; expected n − byz = {}",
            server.num_workers(),
            n - byz
        );
        anyhow::ensure!(
            byz == 0 || attack.is_some(),
            "byz={byz} workers but no attack configured"
        );
        let d = initial_params.len();
        let opt = Sgd::new(d, lr, momentum)?;
        Ok(Self {
            n,
            byz,
            gar,
            attack,
            server,
            params: initial_params,
            opt,
            grads: GradMatrix::zeros(n, d),
            agg: vec![0.0; d],
            last_good: vec![None; n - byz],
            scratch: GarScratch::new(),
            rng: Rng64::seed_from_u64(options.seed ^ 0xC0FF_EE00),
            round: 0,
            metrics: MetricsRecorder::new(n),
            options,
        })
    }

    pub fn params(&self) -> &[f32] {
        &self.params
    }

    pub fn dim(&self) -> usize {
        self.params.len()
    }

    pub fn round(&self) -> u64 {
        self.round
    }

    pub fn gar_name(&self) -> &'static str {
        self.gar.name()
    }

    /// The aggregated gradient of the last completed round.
    pub fn last_aggregate(&self) -> &[f32] {
        &self.agg
    }

    /// Replace the GAR instance (must share the same `n` contract) —
    /// used by the ablation benches to test custom-m MULTI-KRUM variants
    /// that the `GarKind` registry does not expose.
    pub fn with_gar(mut self, gar: Box<dyn Gar>) -> Result<Self> {
        anyhow::ensure!(
            gar.n() == self.n,
            "replacement GAR is for n={}, coordinator has n={}",
            gar.n(),
            self.n
        );
        self.gar = gar;
        Ok(self)
    }

    /// Drive one synchronous SGD round.
    pub fn run_round(&mut self) -> Result<RoundOutcome> {
        self.round += 1;
        let round = self.round;
        let honest = self.n - self.byz;

        // 1. Broadcast current parameters.
        let params = Arc::new(self.params.clone());
        self.server.broadcast(round, params);

        // 2. Collect honest gradients (timeout-bounded), copying each
        //    straight into its GradMatrix row and the straggler cache —
        //    the zero-copy path of `ServerEndpoint::collect_with`, so a
        //    steady-state round allocates nothing per message.
        let mut have = vec![false; honest];
        let mut bad_len: Option<(usize, usize)> = None;
        {
            let d = self.params.len();
            let grads = &mut self.grads;
            let last_good = &mut self.last_good;
            let have = &mut have;
            let bad_len = &mut bad_len;
            self.server.collect_with(
                round,
                honest,
                self.options.round_timeout,
                |worker, gradient| {
                    if gradient.len() != d {
                        if bad_len.is_none() {
                            *bad_len = Some((worker, gradient.len()));
                        }
                        return;
                    }
                    grads.set_row(worker, gradient);
                    let cache = &mut last_good[worker];
                    if let Some(buf) = cache {
                        buf.copy_from_slice(gradient);
                    } else {
                        *cache = Some(gradient.to_vec());
                    }
                    have[worker] = true;
                },
            );
        }
        if let Some((worker, len)) = bad_len {
            anyhow::bail!(
                "worker {worker} sent gradient of length {len} (d = {})",
                self.dim()
            );
        }
        let collected = have.iter().filter(|&&h| h).count();

        // 3. Straggler fallback: last known gradient, else zero (copied
        //    row-to-row, no intermediate clone).
        let mut missing = 0;
        for (w, ok) in have.iter().enumerate() {
            if !ok {
                missing += 1;
                match &self.last_good[w] {
                    Some(g) => self.grads.set_row(w, g),
                    None => self.grads.row_mut(w).fill(0.0),
                }
            }
        }
        self.metrics.add("gradients_missing", missing as u64);

        // 4. Byzantine coalition forges its rows with full knowledge of
        //    the honest proposals.
        if self.byz > 0 {
            let attack = self.attack.as_ref().expect("checked in new()");
            let correct = self.grads.gather_rows(&(0..honest).collect::<Vec<_>>());
            let ctx = AttackCtx::new(&correct, self.byz, self.n);
            let forged = attack.forge(&ctx, &mut self.rng)?;
            anyhow::ensure!(
                forged.n() == self.byz && forged.d() == self.dim(),
                "attack '{}' forged a {}×{} matrix; expected {}×{}",
                attack.name(),
                forged.n(),
                forged.d(),
                self.byz,
                self.dim()
            );
            for b in 0..self.byz {
                self.grads.set_row(honest + b, forged.row(b));
            }
        }

        // 5. Aggregate (the timed hot path) and update.
        let sw = Stopwatch::start();
        self.gar
            .aggregate_with_scratch(&self.grads, &mut self.agg, &mut self.scratch)?;
        let agg_seconds = sw.elapsed_s();
        self.metrics.time("aggregate", agg_seconds);

        let lr = self.options.schedule.at((round - 1) as usize);
        self.opt.set_lr(lr);
        // Defensive: never apply a non-finite update (a GAR bug or an
        // un-filtered NaN attack would otherwise destroy the model).
        if self.agg.iter().any(|v| !v.is_finite()) {
            self.metrics.incr("non_finite_aggregate_skipped");
        } else {
            let agg = std::mem::take(&mut self.agg);
            self.opt.step(&mut self.params, &agg);
            self.agg = agg;
        }
        self.metrics.incr("rounds");

        Ok(RoundOutcome {
            round,
            collected,
            missing,
            agg_seconds,
        })
    }

    /// Run `steps` rounds, evaluating every `eval_every` (0 = only at the
    /// end). Records the training curve in `self.metrics`.
    pub fn train(
        &mut self,
        steps: usize,
        eval_every: usize,
        evaluator: &mut Evaluator,
    ) -> Result<()> {
        for step in 0..steps {
            self.run_round()?;
            let is_last = step + 1 == steps;
            if is_last || (eval_every > 0 && (step + 1) % eval_every == 0) {
                let (loss, acc) = evaluator.evaluate(&self.params)?;
                self.metrics.record_point(TrainPoint {
                    step: step + 1,
                    loss,
                    accuracy: acc,
                });
            }
        }
        Ok(())
    }

    /// Stop all workers.
    pub fn shutdown(&self) {
        self.server.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attacks::AttackKind;
    use crate::data::QuadraticProblem;
    use crate::gar::GarKind;
    use crate::runtime::Parallelism;
    use crate::transport::{build, star, FaultModel, TransportKind};
    use crate::worker::{serve_workers, GradSource};

    fn quadratic_cluster(
        n: usize,
        f: usize,
        byz: usize,
        gar: GarKind,
        attack: AttackKind,
        dim: usize,
        noise: f32,
    ) -> (Coordinator, Arc<QuadraticProblem>) {
        let problem = Arc::new(QuadraticProblem::new(dim, noise, 7));
        let honest = n - byz;
        // Default backend (pooled) over a 2-thread pool: the coordinator
        // unit tests double as pooled-runtime round-trip coverage.
        let (server, workers) = build(
            TransportKind::default(),
            honest,
            FaultModel::default(),
            &Parallelism::new(2),
        );
        let pairs = workers
            .into_iter()
            .enumerate()
            .map(|(i, ep)| (ep, GradSource::quadratic(Arc::clone(&problem), i, 8)))
            .collect();
        serve_workers(pairs);
        let coordinator = Coordinator::new(
            gar.instantiate(n, f).unwrap(),
            attack.instantiate(),
            byz,
            server,
            vec![0.0; dim],
            0.2,
            0.0,
            CoordinatorOptions {
                round_timeout: Duration::from_secs(10),
                schedule: LrSchedule::Fixed { base: 0.2 },
                seed: 3,
            },
        )
        .unwrap();
        (coordinator, problem)
    }

    #[test]
    fn byzantine_free_round_runs() {
        let (mut coord, _p) =
            quadratic_cluster(7, 1, 0, GarKind::MultiKrum, AttackKind::None, 32, 0.05);
        let out = coord.run_round().unwrap();
        assert_eq!(out.collected, 7);
        assert_eq!(out.missing, 0);
        assert!(out.agg_seconds >= 0.0);
        coord.shutdown();
    }

    #[test]
    fn training_converges_without_byzantine() {
        let (mut coord, problem) =
            quadratic_cluster(7, 1, 0, GarKind::MultiKrum, AttackKind::None, 32, 0.05);
        let mut eval = Evaluator::Quadratic(Arc::clone(&problem));
        coord.train(60, 10, &mut eval).unwrap();
        let final_loss = coord.metrics.final_loss().unwrap();
        assert!(final_loss < 1e-3, "loss {final_loss}");
        coord.shutdown();
    }

    #[test]
    fn multi_bulyan_survives_sign_flip() {
        let (mut coord, problem) = quadratic_cluster(
            11,
            2,
            2,
            GarKind::MultiBulyan,
            AttackKind::SignFlip { scale: 10.0 },
            32,
            0.05,
        );
        let mut eval = Evaluator::Quadratic(Arc::clone(&problem));
        coord.train(60, 10, &mut eval).unwrap();
        let final_loss = coord.metrics.final_loss().unwrap();
        assert!(final_loss < 1e-3, "loss {final_loss}");
        coord.shutdown();
    }

    #[test]
    fn averaging_is_destroyed_by_sign_flip() {
        let (mut coord, problem) = quadratic_cluster(
            11,
            0,
            2,
            GarKind::Average,
            AttackKind::SignFlip { scale: 10.0 },
            32,
            0.05,
        );
        let mut eval = Evaluator::Quadratic(Arc::clone(&problem));
        coord.train(30, 10, &mut eval).unwrap();
        let byz_loss = coord.metrics.final_loss().unwrap();
        coord.shutdown();

        let (mut clean, problem2) =
            quadratic_cluster(11, 0, 0, GarKind::Average, AttackKind::None, 32, 0.05);
        let mut eval2 = Evaluator::Quadratic(Arc::clone(&problem2));
        clean.train(30, 10, &mut eval2).unwrap();
        let clean_loss = clean.metrics.final_loss().unwrap();
        clean.shutdown();

        assert!(
            byz_loss > 10.0 * clean_loss.max(1e-9),
            "sign-flip should cripple averaging: byz {byz_loss} vs clean {clean_loss}"
        );
    }

    #[test]
    fn nan_attack_never_corrupts_params() {
        let (mut coord, _p) = quadratic_cluster(
            11,
            2,
            2,
            GarKind::MultiBulyan,
            AttackKind::Infinity { nan: true },
            16,
            0.05,
        );
        for _ in 0..10 {
            coord.run_round().unwrap();
        }
        assert!(coord.params().iter().all(|v| v.is_finite()));
        coord.shutdown();
    }

    #[test]
    fn straggler_fallback_keeps_round_square() {
        // All messages dropped: round must still complete via fallback.
        let problem = Arc::new(QuadraticProblem::new(8, 0.05, 1));
        let (server, workers) = star(
            7,
            FaultModel {
                drop_prob: 1.0,
                ..Default::default()
            },
        );
        let pairs = workers
            .into_iter()
            .enumerate()
            .map(|(i, ep)| (ep, GradSource::quadratic(Arc::clone(&problem), i, 4)))
            .collect();
        serve_workers(pairs);
        let mut coord = Coordinator::new(
            GarKind::MultiKrum.instantiate(7, 1).unwrap(),
            None,
            0,
            server,
            vec![0.0; 8],
            0.1,
            0.0,
            CoordinatorOptions {
                round_timeout: Duration::from_millis(100),
                ..Default::default()
            },
        )
        .unwrap();
        let out = coord.run_round().unwrap();
        assert_eq!(out.collected, 0);
        assert_eq!(out.missing, 7);
        assert_eq!(coord.metrics.counter("gradients_missing"), 7);
        // Zero-gradient fallback: params unchanged.
        assert!(coord.params().iter().all(|&v| v == 0.0));
        coord.shutdown();
    }

    #[test]
    fn with_gar_swaps_rule() {
        let (coord, _p) =
            quadratic_cluster(7, 1, 0, GarKind::MultiKrum, AttackKind::None, 8, 0.05);
        let swapped = coord
            .with_gar(GarKind::Median.instantiate(7, 1).unwrap())
            .unwrap();
        assert_eq!(swapped.gar_name(), "median");
        let bad = GarKind::Median.instantiate(9, 1).unwrap();
        assert!(swapped.with_gar(bad).is_err());
    }
}
