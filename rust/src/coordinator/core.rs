//! The round loop: broadcast → collect → forge → pre-aggregate → select →
//! fused combine+update.
//!
//! The aggregation tail exploits the two-phase GAR API: `select` runs the
//! O(n²) decision work once, then [`fused_combine_update`] walks the
//! coordinate space in a single sharded pass that combines each range
//! *and* immediately applies the SGD update to it — no separate full-`d`
//! aggregate-then-step traversal. Because combine and the SGD update are
//! both coordinate-wise, the fused pass is bit-identical to the old
//! two-pass path for every thread count and range partition.
//!
//! That partition-invariance is also what powers the **streaming
//! prefix-combine** ([`OverlapMode::Prefix`]): the round freezes its
//! gradient matrix at the collection quorum (the completion-order
//! *prefix* of arrivals), selection runs immediately, and the
//! combine+update tail then walks a fixed coordinate-chunk grid
//! co-scheduled with further transport drive slices
//! ([`prefix_combine_update`]) — stragglers keep computing while the
//! aggregate is applied, and anything that finishes late is salvaged
//! into the last-good cache without ever touching the current round.
//!
//! With `groups > 1` the round runs the **two-level hierarchy** instead
//! (see `gar::group`): collection streams every worker's gradient
//! block-by-block into a per-group pairwise reduction, the proposal
//! matrix shrinks to `g × d` group means, the Byzantine coalition forges
//! group rows, and the root GAR's O(g²) selection carries group
//! provenance so metrics still attribute to worker ids. Peak resident
//! gradient memory on that path is O(g·d + n·block) — the full `n × d`
//! matrix is never materialised.

use crate::attacks::{Attack, AttackCtx};
use crate::gar::group::FullIngest;
use crate::gar::{
    CombineScratch, Gar, GarKind, GarScratch, GroupMap, GroupReducer, PreAggregate, Selection,
};
use crate::metrics::{MetricsRecorder, Stopwatch, TrainPoint};
use crate::runtime::pool::SyncMutPtr;
use crate::runtime::{shard_zip, Parallelism, MIN_COORDS_PER_SHARD};
use crate::tensor::GradMatrix;
use crate::training::{LrSchedule, Sgd};
use crate::transport::{ChurnModel, CollectMode, CollectStatus, ServerEndpoint, TransportKind};
use crate::util::Rng64;
use crate::Result;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use super::evaluator::Evaluator;
use super::journal::{Journal, RoundRecord};
use super::membership::MembershipView;

/// When the O(d) combine+update tail starts relative to collection (the
/// `overlap` config knob / `--overlap` CLI flag).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum OverlapMode {
    /// Collect → select → combine strictly in sequence (default).
    #[default]
    Off,
    /// Streaming prefix-combine: selection runs as soon as the collection
    /// quorum (the completion-order *prefix* of arrivals) lands, and the
    /// combine+update tail proceeds in coordinate-range chunks
    /// co-scheduled with further drive slices, so stragglers keep
    /// computing while the aggregate is applied. A gradient arriving
    /// after the quorum lands in the last-good straggler cache and never
    /// perturbs the current round — the current round's `Selection` and
    /// parameters are bit-identical to [`OverlapMode::Off`] by
    /// construction (the matrix is frozen at the quorum and combine is
    /// partition-invariant). Effective on the pooled transport (the
    /// time-sliced drive); the threaded backend falls back to `Off`.
    Prefix,
}

impl OverlapMode {
    /// Every mode, for sweeps and parameterized tests.
    pub const ALL: [OverlapMode; 2] = [OverlapMode::Off, OverlapMode::Prefix];

    /// The config-file/CLI spelling (`FromStr` round-trips it).
    pub fn as_str(self) -> &'static str {
        match self {
            OverlapMode::Off => "off",
            OverlapMode::Prefix => "prefix",
        }
    }
}

impl std::fmt::Display for OverlapMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl std::str::FromStr for OverlapMode {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "off" => Ok(OverlapMode::Off),
            "prefix" => Ok(OverlapMode::Prefix),
            other => anyhow::bail!("unknown overlap mode '{other}' (off|prefix)"),
        }
    }
}

/// Coordinate chunk of the prefix-overlap combine grid: one chunk is
/// combined+applied per drive slice. The grid is a fixed function of `d`
/// — deliberately *not* of the thread count — so the late-acceptance
/// window (one slice per chunk) is deterministic for every `threads`
/// setting.
const OVERLAP_CHUNK: usize = MIN_COORDS_PER_SHARD;

/// Combine one coordinate range of the aggregate and immediately apply
/// the SGD update to it: exactly `Sgd::step`'s per-coordinate arithmetic
/// after `Selection::combine_range_unchecked`, with non-finite aggregate
/// coordinates (a GAR bug or an un-filtered NaN attack) skipped *per
/// coordinate* — their parameter and velocity entries left untouched.
/// Returns the skip count. Every decision is coordinate-local, so any
/// partition of `0..d` into ranges — the fused shard pass, the overlap
/// chunk grid, sequential — produces bit-identical results.
#[allow(clippy::too_many_arguments)]
fn combine_update_range(
    sel: &Selection,
    grads: &GradMatrix,
    offset: usize,
    agg_r: &mut [f32],
    p_r: &mut [f32],
    v_r: &mut [f32],
    lr: f32,
    mu: f32,
    cs: &mut CombineScratch,
) -> usize {
    sel.combine_range_unchecked(grads, offset, agg_r, cs);
    let mut skip = 0usize;
    for k in 0..agg_r.len() {
        let g = agg_r[k];
        if g.is_finite() {
            v_r[k] = mu * v_r[k] + g;
            p_r[k] -= lr * v_r[k];
        } else {
            skip += 1;
        }
    }
    skip
}

/// Shape preconditions shared by the fused and prefix-overlap tails.
fn check_update_shapes(grads: &GradMatrix, agg: &[f32], params: &[f32], opt: &Sgd) -> Result<()> {
    anyhow::ensure!(
        agg.len() == grads.d() && params.len() == agg.len(),
        "fused update: agg/params/d mismatch ({}/{}/{})",
        agg.len(),
        params.len(),
        grads.d()
    );
    anyhow::ensure!(
        opt.velocity().len() == params.len(),
        "fused update: optimizer dimension {} != d {}",
        opt.velocity().len(),
        params.len()
    );
    Ok(())
}

/// The fused O(d) tail of a round: combine each coordinate range of the
/// aggregate into `agg` and immediately apply the SGD update to the same
/// range of `params`/the optimizer velocity — one traversal of the
/// coordinate space instead of combine-then-step, sharded across `par`.
/// Returns the non-finite skip count (see [`combine_update_range`]).
///
/// `pub(crate)` so `bench::slowdown` can measure the exact fused pass the
/// coordinator runs (the fused-vs-unfused comparison column).
pub(crate) fn fused_combine_update(
    par: &Parallelism,
    sel: &Selection,
    grads: &GradMatrix,
    agg: &mut [f32],
    params: &mut [f32],
    opt: &mut Sgd,
    shards: &mut Vec<CombineScratch>,
) -> Result<usize> {
    sel.validate(grads)?;
    check_update_shapes(grads, agg, params, opt)?;
    let lr = opt.lr();
    let mu = opt.momentum();
    let velocity = opt.velocity_mut();
    let skipped = AtomicUsize::new(0);
    shard_zip(
        par,
        [agg, params, velocity],
        shards,
        CombineScratch::default,
        MIN_COORDS_PER_SHARD,
        |offset, [agg_r, p_r, v_r]: [&mut [f32]; 3], cs| {
            let skip = combine_update_range(sel, grads, offset, agg_r, p_r, v_r, lr, mu, cs);
            if skip > 0 {
                skipped.fetch_add(skip, Ordering::Relaxed);
            }
        },
    );
    Ok(skipped.load(Ordering::Relaxed))
}

/// What the prefix-overlap tail did this round (metrics fodder).
struct PrefixOutcome {
    /// Non-finite aggregate coordinates skipped.
    skipped: usize,
    /// Virtual microseconds of straggler drive progress overlapped with
    /// the combine+update tail (0 when the drive was already exhausted).
    saved_us: u64,
    /// Late gradients accepted into the last-good cache.
    late_cached: u64,
    /// Malformed late submissions rejected.
    late_malformed: u64,
}

/// The prefix-overlap O(d) tail: walk the fixed [`OVERLAP_CHUNK`] grid,
/// co-scheduling up to `window` combine+update chunks per remaining drive
/// slice (the transport session must be open, at quorum;
/// `CoordinatorOptions::overlap_window`, default 1), so stragglers keep
/// computing while the aggregate is applied. Late gradients land in
/// `last_good` **only** — never the frozen round matrix — so the round's
/// output is bit-identical to [`fused_combine_update`] (combine is
/// partition-invariant and the SGD arithmetic is coordinate-local). Once
/// the drive is exhausted (or was never running), the remaining
/// coordinate tail is drained at full parallelism; the session is closed
/// before returning.
#[allow(clippy::too_many_arguments)]
fn prefix_combine_update(
    par: &Parallelism,
    server: &mut ServerEndpoint,
    sel: &Selection,
    grads: &GradMatrix,
    agg: &mut [f32],
    params: &mut [f32],
    opt: &mut Sgd,
    last_good: &mut [Option<Vec<f32>>],
    shards: &mut Vec<CombineScratch>,
    window: usize,
) -> Result<PrefixOutcome> {
    sel.validate(grads)?;
    check_update_shapes(grads, agg, params, opt)?;
    let window = window.max(1);
    let d = grads.d();
    let lr = opt.lr();
    let mu = opt.momentum();
    let velocity = opt.velocity_mut();
    let chunks = d.div_ceil(OVERLAP_CHUNK);
    let cursor = AtomicUsize::new(0);
    let skipped = AtomicUsize::new(0);
    if shards.is_empty() {
        shards.push(CombineScratch::default());
    }
    let cs = Mutex::new(std::mem::take(&mut shards[0]));
    let agg_ptr = SyncMutPtr(agg.as_mut_ptr());
    let p_ptr = SyncMutPtr(params.as_mut_ptr());
    let v_ptr = SyncMutPtr(velocity.as_mut_ptr());
    let mut late_cached = 0u64;
    let mut late_malformed = 0u64;
    let v0 = server.collect_virtual_us();
    {
        let aux = |/* up to `window` grid chunks per drive slice */| {
            for _ in 0..window {
                let c = cursor.fetch_add(1, Ordering::Relaxed);
                if c >= chunks {
                    return;
                }
                let start = c * OVERLAP_CHUNK;
                let end = (start + OVERLAP_CHUNK).min(d);
                // Shard-range disjointness: the cursor-derived chunk must
                // stay inside the d-length vectors.
                crate::strict_assert!(start < d && end <= d);
                // SAFETY: chunk `c` exclusively owns coordinates
                // `[start, end)` of all three vectors — the cursor hands
                // out each chunk at most once (the window loop claims
                // each of its chunks through the same fetch_add), at
                // most one aux task runs per drive slice (slices are
                // separated by the fan-out barrier inside
                // `collect_step_aux`), and the drain pass below only
                // touches chunks the cursor never handed out. The
                // vectors outlive the session loop, which completes
                // before this function returns.
                let len = end - start;
                let agg_r =
                    unsafe { std::slice::from_raw_parts_mut(agg_ptr.get().add(start), len) };
                let p_r = unsafe { std::slice::from_raw_parts_mut(p_ptr.get().add(start), len) };
                let v_r = unsafe { std::slice::from_raw_parts_mut(v_ptr.get().add(start), len) };
                let mut cs = cs.lock().unwrap_or_else(|e| e.into_inner());
                let skip =
                    combine_update_range(sel, grads, start, agg_r, p_r, v_r, lr, mu, &mut cs);
                if skip > 0 {
                    skipped.fetch_add(skip, Ordering::Relaxed);
                }
            }
        };
        // Late-acceptance window: lift the quorum cap and keep slicing the
        // drive — one combine chunk per slice — until the grid is spent or
        // the drive exhausts. Late arrivals refresh the straggler cache
        // only; a malformed late submission is rejected like any other.
        server.collect_extend();
        while cursor.load(Ordering::Relaxed) < chunks {
            let status = server.collect_step_aux(
                &mut |worker, gradient: &[f32]| {
                    if gradient.len() != d {
                        late_malformed += 1;
                        return false;
                    }
                    match last_good.get_mut(worker) {
                        Some(Some(buf)) => buf.copy_from_slice(gradient),
                        Some(slot) => *slot = Some(gradient.to_vec()),
                        None => return false,
                    }
                    late_cached += 1;
                    true
                },
                Some(&aux),
            );
            if status == CollectStatus::Exhausted {
                break;
            }
        }
    }
    let saved_us = server.collect_virtual_us().saturating_sub(v0);
    server.collect_finish();
    shards[0] = cs.into_inner().unwrap_or_else(|e| e.into_inner());
    // Drain the coordinate tail the window did not reach, at full
    // parallelism (any partition of the remainder is bit-identical).
    let base = (cursor.load(Ordering::Relaxed).min(chunks)) * OVERLAP_CHUNK;
    if base < d {
        shard_zip(
            par,
            [&mut agg[base..], &mut params[base..], &mut velocity[base..]],
            shards,
            CombineScratch::default,
            MIN_COORDS_PER_SHARD,
            |offset, [agg_r, p_r, v_r]: [&mut [f32]; 3], cs| {
                let skip = combine_update_range(
                    sel,
                    grads,
                    base + offset,
                    agg_r,
                    p_r,
                    v_r,
                    lr,
                    mu,
                    cs,
                );
                if skip > 0 {
                    skipped.fetch_add(skip, Ordering::Relaxed);
                }
            },
        );
    }
    Ok(PrefixOutcome {
        skipped: skipped.load(Ordering::Relaxed),
        saved_us,
        late_cached,
        late_malformed,
    })
}

/// Tunables not covered by the experiment config.
#[derive(Debug, Clone)]
pub struct CoordinatorOptions {
    /// How long to wait for a round's gradients before falling back
    /// (wall-clock on the threaded transport; virtual time under the
    /// pooled backend's cost model — see `transport`).
    pub round_timeout: Duration,
    /// LR schedule (defaults to the paper's fixed rate).
    pub schedule: LrSchedule,
    /// Seed for the coordinator-side RNG (attack forgery draws); worker
    /// minibatches and fault RNGs are seeded independently per worker.
    pub seed: u64,
    /// Collection semantics: wait for every honest worker (`All`,
    /// default) or return at the fastest `m = n − f` gradients
    /// (`FirstM`, the paper's synchronous model — stragglers fall
    /// through the last-good cache).
    pub collect: CollectMode,
    /// Whether the combine+update tail overlaps the remaining collection
    /// (see [`OverlapMode`]; each round is bit-identical either way, and
    /// a straggler salvaged by the overlap window only changes *later*
    /// rounds' fallback).
    pub overlap: OverlapMode,
    /// How many combine grid chunks the prefix overlap applies per drive
    /// slice (`overlap_window` config knob, ≥ 1). The default 1 keeps
    /// the original one-aux-task-per-slice pacing — maximum straggler
    /// salvage; larger windows drain the combine grid faster at the cost
    /// of a shorter late-acceptance window. Bit-identity is unaffected
    /// (the grid itself never changes, only how many chunks each slice
    /// claims).
    pub overlap_window: usize,
    /// Scripted membership churn (`churn_*` config knobs): the same
    /// [`ChurnModel`] the transport's fault injection silences workers
    /// with. [`Coordinator::next_view`] derives each round's
    /// [`MembershipView`] from this schedule, so the pooled/threaded
    /// backends exercise elastic rounds deterministically. Requires an
    /// elastic GAR factory ([`CoordinatorBuilder::elastic`]) when
    /// non-static; incompatible with `groups > 1`.
    pub churn: ChurnModel,
    /// Append-only round-journal path (`journal` config knob /
    /// `--journal` CLI flag). When set, every completed round fsyncs a
    /// [`RoundRecord`]; restarting over an existing journal replays
    /// committed rounds deterministically, verifying each parameter
    /// checksum against the journal (divergence is a hard error) before
    /// committing new rounds — exactly-once round semantics.
    pub journal: Option<PathBuf>,
    /// Crash injection for the recovery-replay determinism leg
    /// (`--crash-after-round`): abort the process immediately after the
    /// given round commits to the journal, simulating a coordinator
    /// crash mid-run.
    pub crash_after_round: Option<u64>,
}

impl Default for CoordinatorOptions {
    fn default() -> Self {
        Self {
            round_timeout: Duration::from_secs(30),
            schedule: LrSchedule::Fixed { base: 0.1 },
            seed: 1,
            collect: CollectMode::All,
            overlap: OverlapMode::Off,
            overlap_window: 1,
            churn: ChurnModel::default(),
            journal: None,
            crash_after_round: None,
        }
    }
}

/// What one round produced (for logs/benches).
#[derive(Debug, Clone)]
pub struct RoundOutcome {
    /// The 1-based round id this outcome describes.
    pub round: u64,
    /// Honest gradients received this round — bounded by the collection
    /// deadline on *both* transports (the pooled backend time-slices its
    /// logical workers against a virtual clock), and by the first-m
    /// cutoff when `CoordinatorOptions::collect` is `FirstM` (the round
    /// proceeds as soon as the fastest `m = n − f` gradients arrived).
    pub collected: usize,
    /// Honest gradients substituted from the last-known cache (stragglers
    /// left behind by the deadline or the first-m race, fault-model
    /// drops, and malformed submissions).
    pub missing: usize,
    /// Wall time of the aggregation tail (selection + fused
    /// combine-and-update), seconds.
    pub agg_seconds: f64,
    /// Rows the GAR's selection phase picked this round (worker indices;
    /// forged Byzantine rows sit at `honest..n`). Coordinate-wise rules
    /// report all rows — see `Selection::selected_rows`. The resilience
    /// bench derives Byzantine-filtering precision from these.
    pub selected: Vec<usize>,
    /// Virtual microseconds of straggler drive progress that ran
    /// *during* the combine+update tail (`overlap = "prefix"` on the
    /// pooled transport; 0 otherwise) — the measured serialization win
    /// of the streaming prefix-combine, also accumulated in the
    /// `overlap_saved_us` metrics counter.
    pub overlap_saved_us: u64,
}

/// Two-level aggregation state (`groups > 1`): the worker → group
/// partition, the streaming per-block reducer the transports feed, and
/// the high-water mark already exported to metrics. When present, the
/// coordinator runs [`Coordinator::run_round`]'s grouped variant: the
/// proposal matrix is `g × d` group rows (never `n × d`), the straggler
/// cache is per *group*, and selection metrics attribute through the
/// [`Selection`]'s group provenance back to worker ids.
struct GroupState {
    map: Arc<GroupMap>,
    reducer: Arc<GroupReducer>,
    /// Last `group_reducer_peak_floats` value pushed to metrics (the
    /// counter tracks the running maximum via deltas).
    peak_floats: u64,
}

/// The parameter server.
pub struct Coordinator {
    n: usize,
    /// Number of Byzantine workers actually simulated this run.
    byz: usize,
    gar: Box<dyn Gar>,
    attack: Option<Box<dyn Attack>>,
    /// Pre-aggregation stages applied (in order) to the proposal matrix
    /// before the GAR's selection phase — see `gar::pipeline`.
    pre: Vec<Box<dyn PreAggregate>>,
    server: ServerEndpoint,
    params: Vec<f32>,
    opt: Sgd,
    options: CoordinatorOptions,
    grads: GradMatrix,
    agg: Vec<f32>,
    /// Reused selection of the round loop (cleared/refilled per round).
    selection: Selection,
    /// Last successfully received gradient per honest worker (straggler
    /// fallback — reusing a stale gradient keeps the GAR's input square
    /// and is the standard synchronous-PS recovery).
    last_good: Vec<Option<Vec<f32>>>,
    scratch: GarScratch,
    rng: Rng64,
    round: u64,
    /// Two-level aggregation (`groups > 1`) — `None` on the flat path.
    grouping: Option<GroupState>,
    /// Elastic GAR factory: re-instantiates the rule at `n' = active +
    /// byz` when a shrunken [`MembershipView`] arrives. `None` means the
    /// fleet is frozen — a shrunken view is a hard error.
    elastic: Option<(GarKind, Parallelism)>,
    /// Cached GAR instance for the current shrunken fleet size (avoids
    /// re-instantiating while the view is stable).
    elastic_gar: Option<Box<dyn Gar>>,
    /// Append-only round-journal (verified replay + exactly-once commit).
    journal: Option<Journal>,
    /// The previous round's member set (original ids) — view-change
    /// detection for the `membership_view_changes` metric.
    prev_workers: Vec<usize>,
    /// First malformed-gradient offender already reported (warn once).
    warned_malformed: bool,
    /// Per-round counters, timings and curves (summaries, CSV export).
    pub metrics: MetricsRecorder,
}

/// The single validated construction path for [`Coordinator`] — every
/// knob cross-constraint is checked once, in [`CoordinatorBuilder::build`],
/// instead of scattered across constructors and post-hoc mutators:
///
/// - `grouped` ⟹ `collect = all` ∧ `overlap = off` ∧ no churn ∧ no
///   elastic factory (the grouped round defines its own collection
///   semantics over a full fleet);
/// - a non-static [`CoordinatorOptions::churn`] schedule ⟹ an
///   [`elastic`](CoordinatorBuilder::elastic) GAR factory, and the
///   shrunken fleet must keep the rule's quorum (`n' ≥ min_n(f)`);
/// - `byz > 0` ⟹ an attack; the transport must span exactly the honest
///   workers.
///
/// `builder::launch` is the only config → coordinator path; there are no
/// post-construction mutators (`set_collect` / `set_overlap` are gone).
pub struct CoordinatorBuilder {
    gar: Box<dyn Gar>,
    attack: Option<Box<dyn Attack>>,
    byz: usize,
    options: CoordinatorOptions,
    pre: Vec<Box<dyn PreAggregate>>,
    reducer: Option<Arc<GroupReducer>>,
    elastic: Option<(GarKind, Parallelism)>,
}

impl CoordinatorBuilder {
    /// The omniscient Byzantine coalition: `byz` forged rows produced by
    /// `attack`. `byz > 0` requires `attack` to be `Some` (checked at
    /// [`build`](Self::build)). In grouped mode the Byzantine count
    /// comes from the group map and `byz` set here is ignored.
    pub fn attack(mut self, attack: Option<Box<dyn Attack>>, byz: usize) -> Self {
        self.attack = attack;
        self.byz = byz;
        self
    }

    /// Replace the default [`CoordinatorOptions`].
    pub fn options(mut self, options: CoordinatorOptions) -> Self {
        self.options = options;
        self
    }

    /// Install pre-aggregation stages (applied in order each round,
    /// after Byzantine forging and before the GAR's selection phase) —
    /// the `gar = "rmom(0.9)+multi-bulyan"` pipeline surface.
    pub fn pre_stages(mut self, stages: Vec<Box<dyn PreAggregate>>) -> Self {
        self.pre = stages;
        self
    }

    /// Two-level aggregation (`groups > 1`): the builder's GAR becomes
    /// the **root** rule over `g = reducer.map().groups()` rows and the
    /// `reducer` (already installed on the transport where the backend
    /// ingests worker-side) streams each honest group's mean
    /// block-by-block — the coordinator never materialises an `n × d`
    /// matrix.
    pub fn grouped(mut self, reducer: Arc<GroupReducer>) -> Self {
        self.reducer = Some(reducer);
        self
    }

    /// Enable elastic membership: when a round's [`MembershipView`] is
    /// shrunken (scripted churn, a socket Goodbye, or a crash-detected
    /// departure), the coordinator re-instantiates `kind` at
    /// `n' = active + byz` on `par` and re-shards rows by view rank.
    /// Without a factory a shrunken view is a hard error — the fleet is
    /// frozen, exactly the pre-elastic contract.
    pub fn elastic(mut self, kind: GarKind, par: Parallelism) -> Self {
        self.elastic = Some((kind, par));
        self
    }

    /// Validate every cross-knob constraint and construct the
    /// [`Coordinator`]. `server` must be a star over exactly the honest
    /// workers (`n − byz`, or the group map's honest count in grouped
    /// mode).
    pub fn build(
        self,
        server: ServerEndpoint,
        initial_params: Vec<f32>,
        lr: f32,
        momentum: f32,
    ) -> Result<Coordinator> {
        let Self {
            gar,
            attack,
            byz,
            options,
            pre,
            reducer,
            elastic,
        } = self;
        let d = initial_params.len();
        anyhow::ensure!(
            options.overlap_window >= 1,
            "overlap_window must be ≥ 1 (got {})",
            options.overlap_window
        );
        if let Some(reducer) = reducer {
            // Grouped construction: byz comes from the map; the flat-only
            // knobs must be off — checked here, once, not at mutation
            // sites (there are none any more).
            let map = Arc::clone(reducer.map());
            let (n, byz, g) = (map.n(), map.byz(), map.groups());
            anyhow::ensure!(
                gar.n() == g,
                "grouped coordinator: root GAR is over {} rows; expected g = {g}",
                gar.n()
            );
            anyhow::ensure!(
                server.num_workers() == n - byz,
                "transport has {} honest workers; expected n − byz = {}",
                server.num_workers(),
                n - byz
            );
            anyhow::ensure!(
                byz == 0 || attack.is_some(),
                "byz={byz} workers but no attack configured"
            );
            anyhow::ensure!(
                !initial_params.is_empty() && reducer.d() == d,
                "grouped coordinator: reducer is for d = {}, params have d = {d}",
                reducer.d(),
            );
            anyhow::ensure!(
                options.collect == CollectMode::All,
                "groups > 1 requires collect = all (first-m quorums are defined \
                 over workers, not group rows)"
            );
            anyhow::ensure!(
                options.overlap == OverlapMode::Off,
                "groups > 1 requires overlap = off (the grouped round has no \
                 frozen prefix matrix to overlap against)"
            );
            anyhow::ensure!(
                options.churn == ChurnModel::default(),
                "groups > 1 requires a static fleet (churn is a flat-path knob)"
            );
            anyhow::ensure!(
                elastic.is_none(),
                "groups > 1 is incompatible with an elastic GAR factory"
            );
            let opt = Sgd::new(d, lr, momentum)?;
            let journal = options.journal.as_ref().map(Journal::open).transpose()?;
            let honest = n - byz;
            return Ok(Coordinator {
                n,
                byz,
                gar,
                attack,
                pre,
                server,
                params: initial_params,
                opt,
                grads: GradMatrix::zeros(g, d),
                agg: vec![0.0; d],
                selection: Selection::default(),
                // Per *group* straggler cache: a group none of whose
                // members delivered this round falls back to its last
                // good mean.
                last_good: vec![None; map.honest_groups()],
                scratch: GarScratch::new(),
                rng: Rng64::seed_from_u64(options.seed ^ 0xC0FF_EE00),
                round: 0,
                grouping: Some(GroupState {
                    map,
                    reducer,
                    peak_floats: 0,
                }),
                elastic: None,
                elastic_gar: None,
                journal,
                prev_workers: (0..honest).collect(),
                warned_malformed: false,
                metrics: MetricsRecorder::new(n),
                options,
            });
        }
        let n = gar.n();
        anyhow::ensure!(byz <= n, "byzantine count {byz} > n {n}");
        anyhow::ensure!(
            server.num_workers() == n - byz,
            "transport has {} honest workers; expected n − byz = {}",
            server.num_workers(),
            n - byz
        );
        anyhow::ensure!(
            byz == 0 || attack.is_some(),
            "byz={byz} workers but no attack configured"
        );
        let honest = n - byz;
        if options.churn != ChurnModel::default() {
            anyhow::ensure!(
                elastic.is_some(),
                "churn is scripted but no elastic GAR factory is configured \
                 (CoordinatorBuilder::elastic)"
            );
            anyhow::ensure!(
                options.churn.leave_workers <= honest,
                "churn removes {} workers but only {honest} honest workers exist",
                options.churn.leave_workers
            );
        }
        if let Some((kind, _)) = &elastic {
            // The deepest scripted shrink must keep the rule's quorum;
            // live (socket) departures below the quorum fail at the
            // round that observes them.
            let c = options.churn;
            if c.leave_workers > 0 && c.leave_round > 0 {
                let active = honest - c.leave_workers;
                anyhow::ensure!(active >= 1, "churn leaves no honest workers");
                anyhow::ensure!(
                    active + byz >= kind.min_n(gar.f()),
                    "churn shrinks the fleet to n' = {} < min_n(f) = {} for {}",
                    active + byz,
                    kind.min_n(gar.f()),
                    kind.as_str()
                );
            }
        }
        let opt = Sgd::new(d, lr, momentum)?;
        let journal = options.journal.as_ref().map(Journal::open).transpose()?;
        Ok(Coordinator {
            n,
            byz,
            gar,
            attack,
            pre,
            server,
            params: initial_params,
            opt,
            grads: GradMatrix::zeros(n, d),
            agg: vec![0.0; d],
            selection: Selection::default(),
            last_good: vec![None; honest],
            scratch: GarScratch::new(),
            rng: Rng64::seed_from_u64(options.seed ^ 0xC0FF_EE00),
            round: 0,
            grouping: None,
            elastic,
            elastic_gar: None,
            journal,
            prev_workers: (0..honest).collect(),
            warned_malformed: false,
            metrics: MetricsRecorder::new(n),
            options,
        })
    }
}

impl Coordinator {
    /// Start building a coordinator around `gar` (the full-fleet rule;
    /// in grouped mode, the root rule). See [`CoordinatorBuilder`].
    pub fn builder(gar: Box<dyn Gar>) -> CoordinatorBuilder {
        CoordinatorBuilder {
            gar,
            attack: None,
            byz: 0,
            options: CoordinatorOptions::default(),
            pre: Vec::new(),
            reducer: None,
            elastic: None,
        }
    }

    /// The current model parameters.
    pub fn params(&self) -> &[f32] {
        &self.params
    }

    /// Model dimension `d`.
    pub fn dim(&self) -> usize {
        self.params.len()
    }

    /// Rounds completed so far.
    pub fn round(&self) -> u64 {
        self.round
    }

    /// The active GAR's display name (pipeline stages included).
    pub fn gar_name(&self) -> &'static str {
        self.gar.name()
    }

    /// The aggregated gradient of the last completed round.
    pub fn last_aggregate(&self) -> &[f32] {
        &self.agg
    }

    /// Replace the GAR instance (must share the same `n` contract) —
    /// used by the ablation benches to test custom-m MULTI-KRUM variants
    /// that the `GarKind` registry does not expose.
    pub fn with_gar(mut self, gar: Box<dyn Gar>) -> Result<Self> {
        anyhow::ensure!(
            gar.n() == self.n,
            "replacement GAR is for n={}, coordinator has n={}",
            gar.n(),
            self.n
        );
        self.gar = gar;
        // A custom rule has no `GarKind` to re-instantiate at a shrunken
        // fleet size: drop the elastic factory so a shrunken view errors
        // instead of silently running the wrong rule.
        self.elastic = None;
        self.elastic_gar = None;
        Ok(self)
    }

    /// How many honest gradients a round waits for. `FirstM` is the
    /// paper's synchronous model: proceed at the fastest `m = n − f`
    /// gradients. The `byz` forged rows are produced server-side by an
    /// omniscient coalition that never straggles, so they always count
    /// toward the quorum — the collection waits for `n − f − byz` honest
    /// gradients (saturating: a contract-violating `byz > n − f` run
    /// collects nothing and lives entirely off the fallback cache).
    fn expect_per_round(&self) -> usize {
        let honest = self.n - self.byz;
        match self.options.collect {
            CollectMode::All => honest,
            CollectMode::FirstM => (self.n - self.gar.f())
                .saturating_sub(self.byz)
                .min(honest),
        }
    }

    /// The membership view the *next* round should run under: the full
    /// honest fleet minus workers absent under the scripted
    /// [`CoordinatorOptions::churn`] schedule, minus live departures the
    /// transport has observed (socket Goodbye / crash-detected
    /// disconnects). The scripted part is deterministic; pass the result
    /// to [`Self::run_round`]. Grouped mode always returns the full view
    /// (a silent group member is handled by the per-group fallback).
    pub fn next_view(&self) -> MembershipView {
        let round = self.round + 1;
        let honest = self.n - self.byz;
        if self.grouping.is_some() {
            return MembershipView::full(round, honest, self.gar.f());
        }
        let departed = self.server.departed_workers();
        let workers: Vec<usize> = (0..honest)
            .filter(|&w| self.options.churn.present(w, round))
            .filter(|w| departed.binary_search(w).is_err())
            .collect();
        MembershipView {
            round,
            workers,
            f: self.gar.f(),
        }
    }

    /// The full fixed-fleet view for the next round, ignoring churn and
    /// departures — benches and tests that want the frozen-fleet path
    /// unconditionally.
    pub fn full_view(&self) -> MembershipView {
        MembershipView::full(self.round + 1, self.n - self.byz, self.gar.f())
    }

    /// Drive one synchronous SGD round under `view` — the single round
    /// entry for flat, elastic, and grouped execution. `view.round` must
    /// be `self.round() + 1`. A full view routes the unchanged
    /// fixed-fleet path (bit-identical to the frozen-fleet API — see
    /// `tests/prop_membership.rs`); a shrunken view re-shards the round
    /// (see [`MembershipView`]); grouped mode requires a full view. When
    /// a journal is configured, a round the journal already committed is
    /// *verified* against its recorded parameter checksum (warm-restart
    /// replay; divergence is a hard error) and a new round is committed
    /// before this returns — exactly-once round semantics.
    pub fn run_round(&mut self, view: &MembershipView) -> Result<RoundOutcome> {
        anyhow::ensure!(
            view.round == self.round + 1,
            "membership view is for round {}, coordinator is at round {}",
            view.round,
            self.round
        );
        let honest = self.n - self.byz;
        let outcome = if self.grouping.is_some() {
            anyhow::ensure!(
                view.is_full(honest),
                "groups > 1 requires a full membership view \
                 (round {}: {} of {honest} workers present)",
                view.round,
                view.active()
            );
            self.run_round_grouped()?
        } else {
            view.validate(honest)?;
            anyhow::ensure!(
                view.f == self.gar.f(),
                "membership view declares f = {}, the rule tolerates f = {}",
                view.f,
                self.gar.f()
            );
            if view.workers != self.prev_workers {
                self.metrics.incr("membership_view_changes");
                self.prev_workers = view.workers.clone();
            }
            if view.is_full(honest) {
                // Restore the full-fleet matrix shape if the fleet just
                // grew back (a rejoin); pre stages re-zero on the shape
                // change — the deliberate rmom policy (see ensure_rows).
                self.ensure_rows(self.n);
                self.run_round_flat()?
            } else {
                self.run_round_elastic(view)?
            }
        };
        self.journal_tail(view, &outcome)?;
        Ok(outcome)
    }

    /// Reshape the proposal matrix to `rows` rows. Pre-aggregation
    /// stages detect the (n, d) change mechanically and re-zero their
    /// state (see `gar::pipeline`) — counted here as the *deliberate*
    /// `ResilientMomentum` re-zero policy: Farhadkhani et al.'s
    /// momentum-then-aggregate composition is re-entered from a clean
    /// state rather than mixing momentum across fleets.
    fn ensure_rows(&mut self, rows: usize) {
        if self.grads.n() != rows {
            self.grads = GradMatrix::zeros(rows, self.dim());
            if !self.pre.is_empty() {
                self.metrics.incr("membership_rezeros");
            }
        }
    }

    /// The journal tail of a round: verify (replayed round) or commit
    /// (new round), then apply crash injection.
    fn journal_tail(&mut self, view: &MembershipView, out: &RoundOutcome) -> Result<()> {
        let Some(journal) = self.journal.as_mut() else {
            return Ok(());
        };
        let digest = crate::util::fnv1a(self.params.iter().flat_map(|v| v.to_le_bytes()));
        if out.round <= journal.last_committed() {
            // Warm restart: this round was committed by the interrupted
            // run. The deterministic re-execution must reproduce it bit
            // for bit — verified, never re-committed (exactly-once).
            let expected = journal
                .expected_checksum(out.round)
                .expect("round ≤ last_committed has a record");
            anyhow::ensure!(
                digest == expected,
                "replay divergence at round {}: params checksum {digest:#018x} \
                 != journalled {expected:#018x} (journal {})",
                out.round,
                journal.path().display()
            );
            self.metrics.incr("journal_replayed");
        } else {
            journal.commit(RoundRecord {
                round: out.round,
                params_checksum: digest,
                f: view.f as u32,
                workers: view.workers.iter().map(|&w| w as u32).collect(),
                selected: out.selected.iter().map(|&w| w as u32).collect(),
                collected: out.collected as u32,
                missing: out.missing as u32,
            })?;
            self.metrics.incr("journal_committed");
        }
        if self.options.crash_after_round == Some(out.round) {
            // Crash injection for the recovery-replay determinism leg:
            // the record above is already fsync'd, so a restarted run
            // resumes (replays) through exactly this round.
            eprintln!(
                "crash injection: aborting after round {} (journal {})",
                out.round,
                journal.path().display()
            );
            std::process::abort();
        }
        Ok(())
    }

    /// A shrunken-view round — the elastic path. Active workers compact
    /// to matrix rows by view rank, the GAR is re-instantiated at
    /// `n' = active + byz` (the quorum `n' ≥ min_n(f)` is revalidated
    /// here and by the rule's constructor), the straggler cache stays
    /// per *original* id, and selected rows map back to original ids in
    /// the outcome and metrics. Prefix overlap is a full-fleet
    /// optimisation; this path always runs the fused tail.
    fn run_round_elastic(&mut self, view: &MembershipView) -> Result<RoundOutcome> {
        let Some((kind, par)) = self.elastic.clone() else {
            anyhow::bail!(
                "round {}: membership shrank to {} of {} honest workers but no \
                 elastic GAR factory is configured (CoordinatorBuilder::elastic)",
                view.round,
                view.active(),
                self.n - self.byz
            );
        };
        self.round += 1;
        let round = self.round;
        let active = view.active();
        let n_eff = active + self.byz;
        let f = self.gar.f();
        anyhow::ensure!(
            n_eff >= kind.min_n(f),
            "round {round}: fleet shrank to n' = {n_eff} < min_n(f) = {} for {}",
            kind.min_n(f),
            kind.as_str()
        );
        if self.elastic_gar.as_ref().map(|g| g.n()) != Some(n_eff) {
            self.elastic_gar = Some(kind.instantiate_parallel(n_eff, f, &par)?);
        }
        self.ensure_rows(n_eff);
        let d = self.dim();

        // 1. Broadcast: every connected worker still receives the round
        //    (absent workers are silent by churn/departure, not
        //    unaddressed); a non-member that delivers anyway is rejected
        //    in step 2.
        let params = Arc::new(self.params.clone());
        self.server.broadcast(round, params);

        // 2. Collect the active members, compacting original ids to view
        //    ranks. The first-m quorum shrinks with the fleet:
        //    m' = (n' − f) − byz, capped at the active count.
        let expect = match self.options.collect {
            CollectMode::All => active,
            CollectMode::FirstM => (n_eff - f).saturating_sub(self.byz).min(active),
        };
        let mut have = vec![false; active];
        let mut non_member = 0u64;
        let mut malformed = 0u64;
        {
            let grads = &mut self.grads;
            let last_good = &mut self.last_good;
            let have = &mut have;
            let non_member = &mut non_member;
            let malformed = &mut malformed;
            let accept = |worker: usize, gradient: &[f32]| {
                let Some(rank) = view.rank(worker) else {
                    // A raced delivery from a departed worker: never a
                    // quorum slot, never a matrix row.
                    *non_member += 1;
                    return false;
                };
                if gradient.len() != d {
                    *malformed += 1;
                    return false;
                }
                grads.set_row(rank, gradient);
                let cache = &mut last_good[worker];
                if let Some(buf) = cache {
                    buf.copy_from_slice(gradient);
                } else {
                    *cache = Some(gradient.to_vec());
                }
                have[rank] = true;
                true
            };
            self.server
                .collect_with(round, expect, self.options.round_timeout, accept);
        }
        if non_member > 0 {
            self.metrics.add("gradients_non_member", non_member);
        }
        if malformed > 0 {
            self.metrics.add("gradients_malformed", malformed);
        }
        let collected = have.iter().filter(|&&h| h).count();
        crate::strict_assert!(collected <= expect);

        // 3. Straggler fallback per *original* id: a member that stayed
        //    silent falls back to its own last good gradient, else zero.
        let mut missing = 0;
        for (rank, ok) in have.iter().enumerate() {
            if !ok {
                missing += 1;
                let w = view.workers[rank];
                match &self.last_good[w] {
                    Some(g) => self.grads.set_row(rank, g),
                    None => self.grads.row_mut(rank).fill(0.0),
                }
            }
        }
        self.metrics.add("gradients_missing", missing as u64);

        // 4. Byzantine forging at the shrunken size — the coalition is
        //    assumed fully present (the worst case), its rows at
        //    active..n'.
        if self.byz > 0 {
            let attack = self.attack.as_ref().expect("checked at build()");
            let correct = self.grads.gather_rows(&(0..active).collect::<Vec<_>>());
            let ctx = AttackCtx::new(&correct, self.byz, n_eff);
            let forged = attack.forge(&ctx, &mut self.rng)?;
            anyhow::ensure!(
                forged.n() == self.byz && forged.d() == d,
                "attack '{}' forged a {}×{} matrix; expected {}×{}",
                attack.name(),
                forged.n(),
                forged.d(),
                self.byz,
                d
            );
            for b in 0..self.byz {
                self.grads.set_row(active + b, forged.row(b));
            }
        }

        // 5. Pre-aggregation over the shrunken matrix (rmom state was
        //    deliberately re-zeroed by the shape change, if any).
        if !self.pre.is_empty() {
            let sw = Stopwatch::start();
            for stage in &mut self.pre {
                stage.apply(&mut self.grads, round)?;
            }
            self.metrics.time("pre_aggregate", sw.elapsed_s());
        }

        // 6. Selection with the shrunken rule; selected rows map back to
        //    original worker ids (Byzantine pseudo-ids keep their
        //    full-fleet slots honest..n so metrics stay comparable
        //    across views).
        let honest = self.n - self.byz;
        let gar = self.elastic_gar.as_deref().expect("instantiated above");
        let sw = Stopwatch::start();
        let mut sel = std::mem::take(&mut self.selection);
        gar.select_into(&self.grads, &mut self.scratch, &mut sel)?;
        let select_seconds = sw.elapsed_s();
        self.metrics.time("select", select_seconds);
        let selected: Vec<usize> = sel
            .selected_rows()
            .iter()
            .map(|&r| {
                if r < active {
                    view.workers[r]
                } else {
                    honest + (r - active)
                }
            })
            .collect();
        for &w in &selected {
            self.metrics.record_selection(w);
        }

        // 7. Fused combine + SGD update (never overlapped on this path).
        let lr = self.options.schedule.at((round - 1) as usize);
        self.opt.set_lr(lr);
        let sw = Stopwatch::start();
        let skipped = fused_combine_update(
            gar.parallelism(),
            &sel,
            &self.grads,
            &mut self.agg,
            &mut self.params,
            &mut self.opt,
            &mut self.scratch.shards,
        )?;
        let combine_seconds = sw.elapsed_s();
        self.selection = sel;
        self.metrics.time("combine_update", combine_seconds);
        let agg_seconds = select_seconds + combine_seconds;
        self.metrics.time("aggregate", agg_seconds);
        if skipped > 0 {
            self.metrics.incr("non_finite_aggregate_skipped");
            self.metrics.add("non_finite_coords_skipped", skipped as u64);
        }
        self.metrics.incr("rounds");

        Ok(RoundOutcome {
            round,
            collected,
            missing,
            agg_seconds,
            selected,
            overlap_saved_us: 0,
        })
    }

    /// The unchanged fixed-fleet round — a full membership view.
    fn run_round_flat(&mut self) -> Result<RoundOutcome> {
        self.round += 1;
        let round = self.round;
        let honest = self.n - self.byz;
        let expect = self.expect_per_round();
        // Streaming prefix-combine needs the pooled time-sliced drive to
        // interleave with, and a nonzero quorum to define the prefix (a
        // contract-violating expect = 0 round lives off the cache on
        // either path).
        let overlap = self.options.overlap == OverlapMode::Prefix
            && self.server.transport() == TransportKind::Pooled
            && expect > 0;

        // 1. Broadcast current parameters.
        let params = Arc::new(self.params.clone());
        self.server.broadcast(round, params);

        // 2. Collect honest gradients (deadline-bounded, first-m aware),
        //    copying each straight into its GradMatrix row and the
        //    straggler cache — the zero-copy incremental session of
        //    `ServerEndpoint`, so a steady-state round allocates nothing
        //    per message. Under prefix overlap the session is left open
        //    at the quorum: the combine tail (step 7) keeps slicing the
        //    drive and salvages late arrivals into the cache.
        let mut have = vec![false; honest];
        let mut bad_len: Option<(usize, usize)> = None;
        let mut malformed: u64 = 0;
        {
            let d = self.params.len();
            let grads = &mut self.grads;
            let last_good = &mut self.last_good;
            let have = &mut have;
            let bad_len = &mut bad_len;
            let malformed = &mut malformed;
            let mut accept = |worker: usize, gradient: &[f32]| {
                if gradient.len() != d {
                    // A malformed submission is a dropped message,
                    // not a reason to abort training: the worker
                    // falls through the straggler cache below. (A
                    // single bad actor could otherwise DoS the run.)
                    // Rejecting it (`false`) also keeps it from
                    // filling a first-m quorum slot — the transport
                    // keeps collecting honest gradients instead.
                    *malformed += 1;
                    if bad_len.is_none() {
                        *bad_len = Some((worker, gradient.len()));
                    }
                    return false;
                }
                grads.set_row(worker, gradient);
                let cache = &mut last_good[worker];
                if let Some(buf) = cache {
                    buf.copy_from_slice(gradient);
                } else {
                    *cache = Some(gradient.to_vec());
                }
                have[worker] = true;
                true
            };
            if overlap {
                self.server
                    .collect_begin(round, expect, self.options.round_timeout);
                loop {
                    match self.server.collect_step(&mut accept) {
                        CollectStatus::Pending => continue,
                        CollectStatus::Quorum | CollectStatus::Exhausted => break,
                    }
                }
                // Session intentionally left open — see step 7.
            } else {
                self.server
                    .collect_with(round, expect, self.options.round_timeout, accept);
            }
        }
        if malformed > 0 {
            self.metrics.add("gradients_malformed", malformed);
            if !self.warned_malformed {
                self.warned_malformed = true;
                if let Some((worker, len)) = bad_len {
                    eprintln!(
                        "warning: worker {worker} sent a gradient of length {len} \
                         (d = {}); treating malformed gradients as dropped",
                        self.dim()
                    );
                }
            }
        }
        let collected = have.iter().filter(|&&h| h).count();
        // Quorum-slot accounting: the accept callback fills each worker's
        // slot at most once and the transports cap delivery at `expect`.
        crate::strict_assert!(collected <= expect);

        // 3. Straggler fallback: last known gradient, else zero (copied
        //    row-to-row, no intermediate clone).
        let mut missing = 0;
        for (w, ok) in have.iter().enumerate() {
            if !ok {
                missing += 1;
                match &self.last_good[w] {
                    Some(g) => self.grads.set_row(w, g),
                    None => self.grads.row_mut(w).fill(0.0),
                }
            }
        }
        self.metrics.add("gradients_missing", missing as u64);

        // 4. Byzantine coalition forges its rows with full knowledge of
        //    the honest proposals.
        if self.byz > 0 {
            let attack = self.attack.as_ref().expect("checked in builder build()");
            let correct = self.grads.gather_rows(&(0..honest).collect::<Vec<_>>());
            let ctx = AttackCtx::new(&correct, self.byz, self.n);
            let forged = attack.forge(&ctx, &mut self.rng)?;
            anyhow::ensure!(
                forged.n() == self.byz && forged.d() == self.dim(),
                "attack '{}' forged a {}×{} matrix; expected {}×{}",
                attack.name(),
                forged.n(),
                forged.d(),
                self.byz,
                self.dim()
            );
            for b in 0..self.byz {
                self.grads.set_row(honest + b, forged.row(b));
            }
        }

        // 5. Pre-aggregation stages (resilient momentum etc.) transform
        //    the full proposal matrix — Byzantine rows included, which is
        //    threat-model-equivalent: a coalition controlling its raw
        //    submissions can realise any momentum stream.
        if !self.pre.is_empty() {
            let sw = Stopwatch::start();
            for stage in &mut self.pre {
                stage.apply(&mut self.grads, round)?;
            }
            self.metrics.time("pre_aggregate", sw.elapsed_s());
        }

        // 6. Selection: the O(n²) phase, once per round.
        let sw = Stopwatch::start();
        let mut sel = std::mem::take(&mut self.selection);
        self.gar
            .select_into(&self.grads, &mut self.scratch, &mut sel)?;
        let select_seconds = sw.elapsed_s();
        self.metrics.time("select", select_seconds);
        for &w in sel.selected_rows() {
            self.metrics.record_selection(w);
        }
        let selected = sel.selected_rows().to_vec();

        // 7. Combine + SGD update: one pass over the coordinate space —
        //    no separate full-d aggregate materialisation. `self.agg`
        //    still receives the full aggregate (the `last_aggregate`
        //    API). Non-finite aggregate coordinates (a GAR bug or an
        //    un-filtered NaN attack) are skipped per coordinate, never
        //    applied. Under prefix overlap the pass walks a fixed chunk
        //    grid co-scheduled with the still-open collection session
        //    (late arrivals refresh the straggler cache only); the two
        //    paths are bit-identical because combine is
        //    partition-invariant and the update arithmetic is
        //    coordinate-local.
        let lr = self.options.schedule.at((round - 1) as usize);
        self.opt.set_lr(lr);
        let sw = Stopwatch::start();
        let mut overlap_saved_us = 0u64;
        let skipped = if overlap {
            let out = prefix_combine_update(
                self.gar.parallelism(),
                &mut self.server,
                &sel,
                &self.grads,
                &mut self.agg,
                &mut self.params,
                &mut self.opt,
                &mut self.last_good,
                &mut self.scratch.shards,
                self.options.overlap_window,
            )?;
            overlap_saved_us = out.saved_us;
            self.metrics.add("overlap_saved_us", out.saved_us);
            if out.late_cached > 0 {
                self.metrics.add("gradients_late_cached", out.late_cached);
            }
            if out.late_malformed > 0 {
                self.metrics.add("gradients_malformed", out.late_malformed);
            }
            out.skipped
        } else {
            fused_combine_update(
                self.gar.parallelism(),
                &sel,
                &self.grads,
                &mut self.agg,
                &mut self.params,
                &mut self.opt,
                &mut self.scratch.shards,
            )?
        };
        let combine_seconds = sw.elapsed_s();
        self.selection = sel;
        self.metrics.time("combine_update", combine_seconds);
        let agg_seconds = select_seconds + combine_seconds;
        self.metrics.time("aggregate", agg_seconds);
        if skipped > 0 {
            self.metrics.incr("non_finite_aggregate_skipped");
            self.metrics.add("non_finite_coords_skipped", skipped as u64);
        }
        self.metrics.incr("rounds");

        Ok(RoundOutcome {
            round,
            collected,
            missing,
            agg_seconds,
            selected,
            overlap_saved_us,
        })
    }

    /// One round of the two-level hierarchy (`groups > 1`): broadcast →
    /// stream-collect into the group reducer → finalize `g × d` group
    /// means (per-group straggler fallback) → forge Byzantine *group*
    /// rows → pre-aggregate → root select (stamped with group
    /// provenance) → fused combine+update. Peak resident gradient memory
    /// is the reducer's O(g·d + n·block) arena — no `n × d` matrix
    /// exists on this path.
    fn run_round_grouped(&mut self) -> Result<RoundOutcome> {
        let (map, reducer) = {
            let gs = self.grouping.as_ref().expect("checked by run_round");
            (Arc::clone(&gs.map), Arc::clone(&gs.reducer))
        };
        self.round += 1;
        let round = self.round;
        let honest = self.n - self.byz;
        let gh = map.honest_groups();
        let gb = map.byz_groups();
        let d = self.params.len();

        // 1. Open the reducer's round and broadcast the parameters.
        reducer.begin_round(round);
        let params = Arc::new(self.params.clone());
        self.server.broadcast(round, params);

        // 2. Collect every honest worker (collect = all, enforced at
        //    construction). Deliveries arrive in two shapes: an *empty*
        //    slice is a grouped-mode notification from a backend that
        //    already ingested worker-side (pooled emitter, socket chunk
        //    reassembly) — confirmed against the reducer; a full d-length
        //    slice is the threaded backend's channel delivery, ingested
        //    here. Either way no row buffer is written.
        let mut have = vec![false; honest];
        let mut bad_len: Option<(usize, usize)> = None;
        let mut malformed: u64 = 0;
        {
            let have = &mut have;
            let bad_len = &mut bad_len;
            let malformed = &mut malformed;
            let reducer = &*reducer;
            let accept = |worker: usize, gradient: &[f32]| -> bool {
                if worker >= have.len() {
                    return false;
                }
                if gradient.is_empty() {
                    // d ≥ 1 (validated), so an empty slice can only be
                    // the transport-side ingest notification.
                    if reducer.delivered(worker, round) {
                        have[worker] = true;
                        return true;
                    }
                    *malformed += 1;
                    false
                } else if gradient.len() == d {
                    match reducer.ingest_full(worker, round, gradient) {
                        FullIngest::Accepted => {
                            have[worker] = true;
                            true
                        }
                        FullIngest::BadLen | FullIngest::Stale => {
                            *malformed += 1;
                            false
                        }
                    }
                } else {
                    *malformed += 1;
                    if bad_len.is_none() {
                        *bad_len = Some((worker, gradient.len()));
                    }
                    false
                }
            };
            self.server
                .collect_with(round, honest, self.options.round_timeout, accept);
        }
        if malformed > 0 {
            self.metrics.add("gradients_malformed", malformed);
            if !self.warned_malformed {
                self.warned_malformed = true;
                if let Some((worker, len)) = bad_len {
                    eprintln!(
                        "warning: worker {worker} sent a gradient of length {len} \
                         (d = {}); treating malformed gradients as dropped",
                        self.dim()
                    );
                }
            }
        }
        let collected = have.iter().filter(|&&h| h).count();
        let missing = honest - collected;
        self.metrics.add("gradients_missing", missing as u64);

        // 3. Close the streams: each honest group's per-block mean lands
        //    in its row of the g × d matrix; a group with no contribution
        //    at all falls back to its last good mean (else stays zero).
        //    A partially-delivered group is already correct — the block
        //    means rescale by the delivered count.
        let contributed = reducer.finalize_into(&mut self.grads);
        crate::strict_assert_eq!(contributed.len(), gh);
        let mut groups_missing = 0u64;
        for (k, ok) in contributed.iter().enumerate() {
            if *ok {
                let row = self.grads.row(k);
                let cache = &mut self.last_good[k];
                if let Some(buf) = cache {
                    buf.copy_from_slice(row);
                } else {
                    *cache = Some(row.to_vec());
                }
            } else {
                groups_missing += 1;
                if let Some(g) = &self.last_good[k] {
                    self.grads.set_row(k, g);
                }
            }
        }
        if groups_missing > 0 {
            self.metrics.add("groups_missing", groups_missing);
        }

        // 4. The Byzantine coalition forges its *group* rows with full
        //    knowledge of the honest group means — the omniscient threat
        //    model lifted one level (a coalition owning whole groups can
        //    emit any group-mean it likes).
        if gb > 0 {
            let attack = self.attack.as_ref().expect("checked in builder build()");
            let correct = self.grads.gather_rows(&(0..gh).collect::<Vec<_>>());
            let ctx = AttackCtx::new(&correct, gb, map.groups());
            let forged = attack.forge(&ctx, &mut self.rng)?;
            anyhow::ensure!(
                forged.n() == gb && forged.d() == d,
                "attack '{}' forged a {}×{} matrix; expected {}×{}",
                attack.name(),
                forged.n(),
                forged.d(),
                gb,
                d
            );
            for b in 0..gb {
                self.grads.set_row(gh + b, forged.row(b));
            }
        }

        // 5. Pre-aggregation stages over the g × d group rows (per-group
        //    resilient momentum — the Farhadkhani composition applied at
        //    the hierarchy's root).
        if !self.pre.is_empty() {
            let sw = Stopwatch::start();
            for stage in &mut self.pre {
                stage.apply(&mut self.grads, round)?;
            }
            self.metrics.time("pre_aggregate", sw.elapsed_s());
        }

        // 6. Root selection over g rows — O(g²), the whole point of the
        //    hierarchy — stamped with group provenance so per-worker
        //    metrics survive the indirection.
        let sw = Stopwatch::start();
        let mut sel = std::mem::take(&mut self.selection);
        self.gar
            .select_into(&self.grads, &mut self.scratch, &mut sel)?;
        sel.set_group_provenance(Arc::clone(&map));
        let select_seconds = sw.elapsed_s();
        self.metrics.time("select", select_seconds);
        let selected = sel.attributed_workers();
        for &w in &selected {
            self.metrics.record_selection(w);
        }

        // 7. Fused combine + SGD update over the selected group rows.
        let lr = self.options.schedule.at((round - 1) as usize);
        self.opt.set_lr(lr);
        let sw = Stopwatch::start();
        let skipped = fused_combine_update(
            self.gar.parallelism(),
            &sel,
            &self.grads,
            &mut self.agg,
            &mut self.params,
            &mut self.opt,
            &mut self.scratch.shards,
        )?;
        let combine_seconds = sw.elapsed_s();
        self.selection = sel;
        self.metrics.time("combine_update", combine_seconds);
        let agg_seconds = select_seconds + combine_seconds;
        self.metrics.time("aggregate", agg_seconds);
        if skipped > 0 {
            self.metrics.incr("non_finite_aggregate_skipped");
            self.metrics.add("non_finite_coords_skipped", skipped as u64);
        }
        self.metrics.incr("rounds");

        // Export the reducer's high-water mark as a running maximum (the
        // memory-bound observable behind the O(g·d + n·block) claim).
        let peak = reducer.peak_resident_floats() as u64;
        if let Some(gs) = self.grouping.as_mut() {
            if peak > gs.peak_floats {
                self.metrics
                    .add("group_reducer_peak_floats", peak - gs.peak_floats);
                gs.peak_floats = peak;
            }
        }

        Ok(RoundOutcome {
            round,
            collected,
            missing,
            agg_seconds,
            selected,
            overlap_saved_us: 0,
        })
    }

    /// Run `steps` rounds, evaluating every `eval_every` (0 = only at the
    /// end). Records the training curve in `self.metrics`. Each round
    /// runs under [`Self::next_view`] — scripted churn and live
    /// departures shrink the fleet mid-run; a journal (if configured)
    /// verifies replayed rounds and commits new ones.
    pub fn train(
        &mut self,
        steps: usize,
        eval_every: usize,
        evaluator: &mut Evaluator,
    ) -> Result<()> {
        for step in 0..steps {
            let view = self.next_view();
            self.run_round(&view)?;
            let is_last = step + 1 == steps;
            if is_last || (eval_every > 0 && (step + 1) % eval_every == 0) {
                let (loss, acc) = evaluator.evaluate(&self.params)?;
                self.metrics.record_point(TrainPoint {
                    step: step + 1,
                    loss,
                    accuracy: acc,
                });
            }
        }
        Ok(())
    }

    /// Stop all workers.
    pub fn shutdown(&self) {
        self.server.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attacks::AttackKind;
    use crate::data::QuadraticProblem;
    use crate::gar::GarKind;
    use crate::runtime::Parallelism;
    use crate::transport::{build, star, FaultModel, TransportKind};
    use crate::worker::{serve_workers, GradSource};

    /// Drive one round under the coordinator's own next view (what the
    /// train loop does) — the standard test step.
    fn run_next(coord: &mut Coordinator) -> crate::Result<RoundOutcome> {
        let view = coord.next_view();
        coord.run_round(&view)
    }

    #[allow(clippy::too_many_arguments)]
    fn quadratic_cluster(
        n: usize,
        f: usize,
        byz: usize,
        gar: GarKind,
        attack: AttackKind,
        dim: usize,
        noise: f32,
        collect: CollectMode,
    ) -> (Coordinator, Arc<QuadraticProblem>) {
        let problem = Arc::new(QuadraticProblem::new(dim, noise, 7));
        let honest = n - byz;
        // Default backend (pooled) over a 2-thread pool: the coordinator
        // unit tests double as pooled-runtime round-trip coverage.
        let (server, workers) = build(
            TransportKind::default(),
            honest,
            FaultModel::default(),
            &Parallelism::new(2),
        );
        let pairs = workers
            .into_iter()
            .enumerate()
            .map(|(i, ep)| (ep, GradSource::quadratic(Arc::clone(&problem), i, 8)))
            .collect();
        serve_workers(pairs);
        let coordinator = Coordinator::builder(gar.instantiate(n, f).unwrap())
            .attack(attack.instantiate(), byz)
            .options(CoordinatorOptions {
                round_timeout: Duration::from_secs(10),
                schedule: LrSchedule::Fixed { base: 0.2 },
                seed: 3,
                collect,
                ..Default::default()
            })
            .build(server, vec![0.0; dim], 0.2, 0.0)
            .unwrap();
        (coordinator, problem)
    }

    #[test]
    fn byzantine_free_round_runs() {
        let (mut coord, _p) = quadratic_cluster(
            7,
            1,
            0,
            GarKind::MultiKrum,
            AttackKind::None,
            32,
            0.05,
            CollectMode::All,
        );
        let out = run_next(&mut coord).unwrap();
        assert_eq!(out.collected, 7);
        assert_eq!(out.missing, 0);
        assert!(out.agg_seconds >= 0.0);
        coord.shutdown();
    }

    #[test]
    fn training_converges_without_byzantine() {
        let (mut coord, problem) = quadratic_cluster(
            7,
            1,
            0,
            GarKind::MultiKrum,
            AttackKind::None,
            32,
            0.05,
            CollectMode::All,
        );
        let mut eval = Evaluator::Quadratic(Arc::clone(&problem));
        coord.train(60, 10, &mut eval).unwrap();
        let final_loss = coord.metrics.final_loss().unwrap();
        assert!(final_loss < 1e-3, "loss {final_loss}");
        coord.shutdown();
    }

    #[test]
    fn multi_bulyan_survives_sign_flip() {
        let (mut coord, problem) = quadratic_cluster(
            11,
            2,
            2,
            GarKind::MultiBulyan,
            AttackKind::SignFlip { scale: 10.0 },
            32,
            0.05,
            CollectMode::All,
        );
        let mut eval = Evaluator::Quadratic(Arc::clone(&problem));
        coord.train(60, 10, &mut eval).unwrap();
        let final_loss = coord.metrics.final_loss().unwrap();
        assert!(final_loss < 1e-3, "loss {final_loss}");
        coord.shutdown();
    }

    #[test]
    fn averaging_is_destroyed_by_sign_flip() {
        let (mut coord, problem) = quadratic_cluster(
            11,
            0,
            2,
            GarKind::Average,
            AttackKind::SignFlip { scale: 10.0 },
            32,
            0.05,
            CollectMode::All,
        );
        let mut eval = Evaluator::Quadratic(Arc::clone(&problem));
        coord.train(30, 10, &mut eval).unwrap();
        let byz_loss = coord.metrics.final_loss().unwrap();
        coord.shutdown();

        let (mut clean, problem2) = quadratic_cluster(
            11,
            0,
            0,
            GarKind::Average,
            AttackKind::None,
            32,
            0.05,
            CollectMode::All,
        );
        let mut eval2 = Evaluator::Quadratic(Arc::clone(&problem2));
        clean.train(30, 10, &mut eval2).unwrap();
        let clean_loss = clean.metrics.final_loss().unwrap();
        clean.shutdown();

        assert!(
            byz_loss > 10.0 * clean_loss.max(1e-9),
            "sign-flip should cripple averaging: byz {byz_loss} vs clean {clean_loss}"
        );
    }

    #[test]
    fn nan_attack_never_corrupts_params() {
        let (mut coord, _p) = quadratic_cluster(
            11,
            2,
            2,
            GarKind::MultiBulyan,
            AttackKind::Infinity { nan: true },
            16,
            0.05,
            CollectMode::All,
        );
        for _ in 0..10 {
            run_next(&mut coord).unwrap();
        }
        assert!(coord.params().iter().all(|v| v.is_finite()));
        coord.shutdown();
    }

    #[test]
    fn straggler_fallback_keeps_round_square() {
        // All messages dropped: round must still complete via fallback.
        let problem = Arc::new(QuadraticProblem::new(8, 0.05, 1));
        let (server, workers) = star(
            7,
            FaultModel {
                drop_prob: 1.0,
                ..Default::default()
            },
        );
        let pairs = workers
            .into_iter()
            .enumerate()
            .map(|(i, ep)| (ep, GradSource::quadratic(Arc::clone(&problem), i, 4)))
            .collect();
        serve_workers(pairs);
        let mut coord = Coordinator::builder(GarKind::MultiKrum.instantiate(7, 1).unwrap())
            .options(CoordinatorOptions {
                round_timeout: Duration::from_millis(100),
                ..Default::default()
            })
            .build(server, vec![0.0; 8], 0.1, 0.0)
            .unwrap();
        let out = run_next(&mut coord).unwrap();
        assert_eq!(out.collected, 0);
        assert_eq!(out.missing, 7);
        assert_eq!(coord.metrics.counter("gradients_missing"), 7);
        // Zero-gradient fallback: params unchanged.
        assert!(coord.params().iter().all(|&v| v == 0.0));
        coord.shutdown();
    }

    #[test]
    fn malformed_gradient_is_a_drop_not_a_crash() {
        // Regression (DoS): a wrong-length gradient used to abort the
        // whole training run. It must now be treated as a dropped
        // message — straggler fallback, a `gradients_malformed` count —
        // and the round must keep aggregating the well-formed rows.
        use crate::transport::{Emitter, WorkerBody};

        struct BadLenBody;
        impl WorkerBody for BadLenBody {
            fn on_round(&mut self, round: u64, _p: &[f32], emit: &mut Emitter<'_>) {
                emit.send(round, &[1.0, 2.0, 3.0]); // wrong length (d = 8)
            }
        }

        let problem = Arc::new(QuadraticProblem::new(8, 0.05, 1));
        let (server, workers) = star(7, FaultModel::default());
        for (i, ep) in workers.into_iter().enumerate() {
            if i == 2 {
                ep.serve(BadLenBody);
            } else {
                ep.serve(crate::worker::GradWorker::new(GradSource::quadratic(
                    Arc::clone(&problem),
                    i,
                    4,
                )));
            }
        }
        let mut coord = Coordinator::builder(GarKind::MultiKrum.instantiate(7, 1).unwrap())
            .options(CoordinatorOptions {
                // Short: the rejected gradient never fills the 7th
                // wait-all slot, so every round waits this out.
                round_timeout: Duration::from_millis(100),
                ..Default::default()
            })
            .build(server, vec![0.0; 8], 0.1, 0.0)
            .unwrap();
        for r in 1..=3u64 {
            let out = run_next(&mut coord).expect("malformed gradient must not abort");
            assert_eq!(out.collected, 6, "round {r}");
            assert_eq!(out.missing, 1, "round {r}");
        }
        assert_eq!(coord.metrics.counter("gradients_malformed"), 3);
        assert_eq!(coord.metrics.counter("gradients_missing"), 3);
        assert!(coord.params().iter().all(|v| v.is_finite()));
        coord.shutdown();
    }

    #[test]
    fn malformed_gradient_does_not_displace_the_first_m_quorum() {
        // Under first-m a rejected (wrong-length) gradient must not fill
        // one of the m quorum slots — the transport keeps collecting
        // honest gradients past it on both backends.
        use crate::transport::{Emitter, WorkerBody};

        struct BadLenBody;
        impl WorkerBody for BadLenBody {
            fn on_round(&mut self, round: u64, _p: &[f32], emit: &mut Emitter<'_>) {
                emit.send(round, &[0.0]); // wrong length (d = 8)
            }
        }

        for kind in TransportKind::ALL {
            let problem = Arc::new(QuadraticProblem::new(8, 0.05, 1));
            let (server, workers) =
                build(kind, 7, FaultModel::default(), &Parallelism::new(2));
            for (i, ep) in workers.into_iter().enumerate() {
                if i == 0 {
                    // The bad actor sits at the lowest index, where the
                    // pooled backend delivers it first.
                    ep.serve(BadLenBody);
                } else {
                    ep.serve(crate::worker::GradWorker::new(GradSource::quadratic(
                        Arc::clone(&problem),
                        i,
                        4,
                    )));
                }
            }
            let mut coord = Coordinator::builder(GarKind::MultiKrum.instantiate(7, 1).unwrap())
                .options(CoordinatorOptions {
                    round_timeout: Duration::from_millis(500),
                    collect: CollectMode::FirstM,
                    ..Default::default()
                })
                .build(server, vec![0.0; 8], 0.1, 0.0)
                .unwrap();
            // m = n − f = 6 = exactly the honest well-formed workers:
            // all six must be collected despite the rejected delivery.
            let out = run_next(&mut coord).unwrap();
            assert_eq!(out.collected, 6, "{kind}");
            assert_eq!(out.missing, 1, "{kind}");
            assert_eq!(coord.metrics.counter("gradients_malformed"), 1, "{kind}");
            coord.shutdown();
        }
    }

    #[test]
    fn first_m_collects_m_and_caches_cover_the_rest() {
        // n = 7, f = 2, byz = 0 ⇒ first-m waits for the fastest 5; the
        // two slowest workers fall through the fallback path every round.
        // (Collection semantics are a construction-time knob now — the
        // post-hoc `set_collect` mutator no longer exists.)
        let (mut coord, _p) = quadratic_cluster(
            7,
            2,
            0,
            GarKind::MultiKrum,
            AttackKind::None,
            32,
            0.05,
            CollectMode::FirstM,
        );
        let out = run_next(&mut coord).unwrap();
        assert_eq!(out.collected, 5);
        assert_eq!(out.missing, 2);
        assert_eq!(coord.metrics.counter("gradients_missing"), 2);
        coord.shutdown();
    }

    #[test]
    fn selected_sums_match_recorder_under_omniscient_attack() {
        // RoundOutcome::selected, summed per worker over the run, must
        // equal MetricsRecorder::selections() exactly.
        let (mut coord, _p) = quadratic_cluster(
            11,
            2,
            2,
            GarKind::MultiKrum,
            AttackKind::Omniscient { epsilon: 0.1 },
            16,
            0.05,
            CollectMode::All,
        );
        let mut counts = vec![0u64; 11];
        for _ in 0..8 {
            let out = run_next(&mut coord).unwrap();
            assert!(!out.selected.is_empty());
            assert!(out.selected.iter().all(|&w| w < 11));
            for &w in &out.selected {
                counts[w] += 1;
            }
        }
        assert_eq!(coord.metrics.selections(), &counts[..]);
        coord.shutdown();
    }

    #[test]
    fn fused_combine_update_is_bit_identical_to_two_pass() {
        // The fused pass must equal aggregate_with_scratch followed by
        // Sgd::step, bit for bit, at every thread count.
        let (n, f, d) = (11usize, 2usize, 9_000usize);
        let grads =
            GradMatrix::from_fn(n, d, |i, j| ((i * 17 + j * 5) % 97) as f32 * 0.02 - 0.9);
        for kind in [GarKind::MultiBulyan, GarKind::Median, GarKind::MultiKrum] {
            for threads in [1usize, 3] {
                let par = Parallelism::new(threads);
                let gar = kind.instantiate_parallel(n, f, &par).unwrap();
                let mut scratch = GarScratch::new();
                let mut agg = vec![0.0f32; d];
                gar.aggregate_with_scratch(&grads, &mut agg, &mut scratch)
                    .unwrap();
                let mut p1 = vec![0.5f32; d];
                let mut opt1 = Sgd::new(d, 0.1, 0.9).unwrap();
                opt1.step(&mut p1, &agg);

                let sel = gar.select(&grads, &mut scratch).unwrap();
                let mut agg2 = vec![0.0f32; d];
                let mut p2 = vec![0.5f32; d];
                let mut opt2 = Sgd::new(d, 0.1, 0.9).unwrap();
                let skipped = fused_combine_update(
                    &par,
                    &sel,
                    &grads,
                    &mut agg2,
                    &mut p2,
                    &mut opt2,
                    &mut scratch.shards,
                )
                .unwrap();
                assert_eq!(skipped, 0);
                assert_eq!(agg, agg2, "{kind} threads={threads}: aggregate diverged");
                assert_eq!(p1, p2, "{kind} threads={threads}: params diverged");
                assert_eq!(opt1.velocity(), opt2.velocity(), "{kind} threads={threads}");
            }
        }
    }

    #[test]
    fn fused_update_skips_non_finite_coordinates() {
        // A NaN aggregate coordinate must leave exactly that parameter
        // (and its velocity) untouched; finite coordinates still update.
        let d = 8;
        let mut grads = GradMatrix::zeros(3, d);
        grads.row_mut(0)[3] = f32::NAN; // poisons coordinate 3 of the mean
        grads.row_mut(1).fill(1.0);
        let gar = GarKind::Average.instantiate(3, 0).unwrap();
        let mut scratch = GarScratch::new();
        let sel = gar.select(&grads, &mut scratch).unwrap();
        let mut agg = vec![0.0f32; d];
        let mut params = vec![1.0f32; d];
        let mut opt = Sgd::new(d, 0.5, 0.0).unwrap();
        let skipped = fused_combine_update(
            &Parallelism::sequential(),
            &sel,
            &grads,
            &mut agg,
            &mut params,
            &mut opt,
            &mut scratch.shards,
        )
        .unwrap();
        assert_eq!(skipped, 1);
        assert_eq!(params[3], 1.0, "poisoned coordinate must be untouched");
        assert_eq!(opt.velocity()[3], 0.0);
        for (j, &v) in params.iter().enumerate() {
            if j != 3 {
                assert!(v < 1.0, "coordinate {j} should have been updated");
                assert!(v.is_finite());
            }
        }
    }

    #[test]
    fn overlap_mode_parses_and_displays() {
        assert_eq!("off".parse::<OverlapMode>().unwrap(), OverlapMode::Off);
        assert_eq!("prefix".parse::<OverlapMode>().unwrap(), OverlapMode::Prefix);
        assert!("eager".parse::<OverlapMode>().is_err());
        assert_eq!(OverlapMode::default(), OverlapMode::Off);
        for mode in OverlapMode::ALL {
            assert_eq!(mode.as_str().parse::<OverlapMode>().unwrap(), mode);
        }
    }

    #[test]
    fn prefix_overlap_rounds_are_bit_identical_to_off() {
        // The same seeded first-m cluster, run with overlap off and with
        // prefix overlap, must land on bit-identical parameters: the
        // round matrix is frozen at the quorum and combine is
        // partition-invariant. The stragglers' cost (30 ms) dwarfs the
        // late-acceptance window (3 chunks at d = 9000 ⇒ 150 virtual µs),
        // so the caches stay identical too and the equality holds across
        // rounds; the prefix run must also report drive progress
        // overlapped with the combine tail. The `overlap_window` knob
        // (chunks claimed per drive slice) only re-buckets the same grid,
        // so every window value must land on the same parameters too.
        let run = |overlap: OverlapMode, window: usize| -> (Vec<f32>, u64) {
            let problem = Arc::new(QuadraticProblem::new(9_000, 0.05, 7));
            let faults = FaultModel {
                cost: crate::transport::ComputeCost {
                    base_us: 300,
                    slow_workers: 2,
                    slow_factor: 100.0,
                },
                ..Default::default()
            };
            let (server, workers) =
                build(TransportKind::Pooled, 7, faults, &Parallelism::new(2));
            let pairs = workers
                .into_iter()
                .enumerate()
                .map(|(i, ep)| (ep, GradSource::quadratic(Arc::clone(&problem), i, 8)))
                .collect();
            serve_workers(pairs);
            let mut coord = Coordinator::builder(GarKind::MultiKrum.instantiate(7, 2).unwrap())
                .options(CoordinatorOptions {
                    round_timeout: Duration::from_secs(10),
                    schedule: LrSchedule::Fixed { base: 0.2 },
                    seed: 3,
                    collect: CollectMode::FirstM,
                    overlap,
                    overlap_window: window,
                    ..Default::default()
                })
                .build(server, vec![0.0; 9_000], 0.2, 0.0)
                .unwrap();
            let mut saved = 0u64;
            for _ in 0..4 {
                let out = run_next(&mut coord).unwrap();
                assert_eq!(out.collected, 5, "{overlap}: fast-tier quorum");
                assert_eq!(out.missing, 2, "{overlap}: stragglers cached out");
                saved += out.overlap_saved_us;
            }
            let params = coord.params().to_vec();
            coord.shutdown();
            (params, saved)
        };
        let (p_off, saved_off) = run(OverlapMode::Off, 1);
        let (p_prefix, saved_prefix) = run(OverlapMode::Prefix, 1);
        assert_eq!(p_off, p_prefix, "prefix overlap must not change the model");
        assert_eq!(saved_off, 0);
        assert!(
            saved_prefix > 0,
            "prefix overlap must report drive progress during the combine tail"
        );
        for window in [2usize, 8, 1024] {
            let (p_w, _) = run(OverlapMode::Prefix, window);
            assert_eq!(p_off, p_w, "overlap_window={window} must not change the model");
        }
        // The straggler cache must be equally (un)populated: no late
        // arrival fits the window, so no run salvages anything.
        // (Divergence here would leak into round ≥ 2 parameters, which
        // the equality above already rules out.)
    }

    #[test]
    fn with_gar_swaps_rule() {
        let (coord, _p) = quadratic_cluster(
            7,
            1,
            0,
            GarKind::MultiKrum,
            AttackKind::None,
            8,
            0.05,
            CollectMode::All,
        );
        let swapped = coord
            .with_gar(GarKind::Median.instantiate(7, 1).unwrap())
            .unwrap();
        assert_eq!(swapped.gar_name(), "median");
        let bad = GarKind::Median.instantiate(9, 1).unwrap();
        assert!(swapped.with_gar(bad).is_err());
    }

    #[test]
    fn scripted_churn_shrinks_and_rejoins() {
        // Workers 0..2 leave at round 2 and rejoin at round 4: the view
        // shrinks to 5, the GAR re-instantiates at n' = 5 (multi-krum
        // min_n(1) = 5), and the full-fleet path resumes on rejoin.
        let churn = ChurnModel {
            leave_round: 2,
            leave_workers: 2,
            rejoin_round: 4,
        };
        let problem = Arc::new(QuadraticProblem::new(16, 0.05, 7));
        let faults = FaultModel {
            churn,
            ..Default::default()
        };
        let par = Parallelism::new(2);
        let (server, workers) = build(TransportKind::default(), 7, faults, &par);
        let pairs = workers
            .into_iter()
            .enumerate()
            .map(|(i, ep)| (ep, GradSource::quadratic(Arc::clone(&problem), i, 8)))
            .collect();
        serve_workers(pairs);
        let mut coord =
            Coordinator::builder(GarKind::MultiKrum.instantiate_parallel(7, 1, &par).unwrap())
                .options(CoordinatorOptions {
                    round_timeout: Duration::from_secs(10),
                    churn,
                    ..Default::default()
                })
                .elastic(GarKind::MultiKrum, par.clone())
                .build(server, vec![0.0; 16], 0.1, 0.0)
                .unwrap();
        let expected_active = [7usize, 5, 5, 7];
        for (i, &active) in expected_active.iter().enumerate() {
            let view = coord.next_view();
            assert_eq!(view.active(), active, "round {}", i + 1);
            let out = coord.run_round(&view).unwrap();
            assert_eq!(out.collected, active, "round {}", i + 1);
            assert_eq!(out.missing, 0, "round {}", i + 1);
            assert!(
                out.selected.iter().all(|&w| view.contains(w)),
                "round {}: selected {:?} outside view {:?}",
                i + 1,
                out.selected,
                view.workers
            );
        }
        // leave (round 2) + rejoin (round 4).
        assert_eq!(coord.metrics.counter("membership_view_changes"), 2);
        assert!(coord.params().iter().all(|v| v.is_finite()));
        coord.shutdown();
    }

    #[test]
    fn journal_replay_after_interruption_is_bit_identical() {
        let path =
            std::env::temp_dir().join(format!("mb_core_journal_{}.mbj", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let run = |journal: Option<PathBuf>, steps: usize| -> (Vec<f32>, u64, u64) {
            let problem = Arc::new(QuadraticProblem::new(16, 0.05, 7));
            let (server, workers) = build(
                TransportKind::default(),
                7,
                FaultModel::default(),
                &Parallelism::new(2),
            );
            let pairs = workers
                .into_iter()
                .enumerate()
                .map(|(i, ep)| (ep, GradSource::quadratic(Arc::clone(&problem), i, 8)))
                .collect();
            serve_workers(pairs);
            let mut coord = Coordinator::builder(GarKind::MultiKrum.instantiate(7, 1).unwrap())
                .options(CoordinatorOptions {
                    round_timeout: Duration::from_secs(10),
                    journal,
                    ..Default::default()
                })
                .build(server, vec![0.0; 16], 0.1, 0.0)
                .unwrap();
            for _ in 0..steps {
                run_next(&mut coord).unwrap();
            }
            let params = coord.params().to_vec();
            let replayed = coord.metrics.counter("journal_replayed");
            let committed = coord.metrics.counter("journal_committed");
            coord.shutdown();
            (params, replayed, committed)
        };
        // Interrupted run: 3 rounds committed, then the coordinator is
        // dropped (every record is fsync'd at commit, so there is no
        // flush path to miss on the way out — the crash case).
        let (_params, replayed, committed) = run(Some(path.clone()), 3);
        assert_eq!((replayed, committed), (0, 3));
        // Resumed run over the same journal: verifies rounds 1..=3
        // against their recorded checksums, then commits 4..=6.
        let (resumed, replayed, committed) = run(Some(path.clone()), 6);
        assert_eq!((replayed, committed), (3, 3));
        // Uninterrupted reference run.
        let (reference, _, _) = run(None, 6);
        assert_eq!(resumed, reference, "recovery replay must be bit-identical");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn shrunken_view_needs_an_elastic_factory() {
        let (mut coord, _p) = quadratic_cluster(
            7,
            1,
            0,
            GarKind::MultiKrum,
            AttackKind::None,
            8,
            0.05,
            CollectMode::All,
        );
        let mut view = coord.next_view();
        view.workers.remove(0);
        let err = coord.run_round(&view).unwrap_err().to_string();
        assert!(err.contains("elastic"), "{err}");
        // The failed round must not have advanced the counter.
        assert_eq!(coord.round(), 0);
        coord.shutdown();
    }

    #[test]
    fn builder_cross_knob_validation() {
        // Churn without an elastic factory is rejected at build time.
        let (server, _workers) = star(7, FaultModel::default());
        let churn = ChurnModel {
            leave_round: 2,
            leave_workers: 1,
            rejoin_round: 0,
        };
        let err = Coordinator::builder(GarKind::MultiKrum.instantiate(7, 1).unwrap())
            .options(CoordinatorOptions {
                churn,
                ..Default::default()
            })
            .build(server, vec![0.0; 8], 0.1, 0.0)
            .unwrap_err()
            .to_string();
        assert!(err.contains("elastic"), "{err}");

        // A scripted shrink below the rule's quorum is rejected too:
        // multi-krum min_n(1) = 5, but 7 − 3 = 4.
        let (server, _workers) = star(7, FaultModel::default());
        let churn = ChurnModel {
            leave_round: 2,
            leave_workers: 3,
            rejoin_round: 0,
        };
        let err = Coordinator::builder(GarKind::MultiKrum.instantiate(7, 1).unwrap())
            .options(CoordinatorOptions {
                churn,
                ..Default::default()
            })
            .elastic(GarKind::MultiKrum, Parallelism::sequential())
            .build(server, vec![0.0; 8], 0.1, 0.0)
            .unwrap_err()
            .to_string();
        assert!(err.contains("min_n"), "{err}");

        // byz > 0 without an attack is still rejected.
        let (server, _workers) = star(6, FaultModel::default());
        let err = Coordinator::builder(GarKind::MultiKrum.instantiate(7, 1).unwrap())
            .attack(None, 1)
            .build(server, vec![0.0; 8], 0.1, 0.0)
            .unwrap_err()
            .to_string();
        assert!(err.contains("attack"), "{err}");
    }
}
