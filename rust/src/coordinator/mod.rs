//! The parameter-server coordinator — Layer 3's core.
//!
//! One [`Coordinator`] owns the model state and drives synchronous
//! distributed SGD rounds (the parameter-server setting of the paper's
//! §I): broadcast parameters, collect the honest gradients over the
//! simulated transport (with timeout + last-known-gradient fallback for
//! stragglers/drops), let the Byzantine coalition forge its `f` rows
//! (omniscient threat model, §II-C), run the pre-aggregation stages, run
//! the GAR's O(n²) *selection* phase, then apply the fused O(d)
//! combine+SGD pass (no separate full-d aggregate materialisation).
//! [`launch`] wires a full cluster from an
//! [`crate::config::ExperimentConfig`].
//!
//! Elasticity and durability ride on two sibling modules: a per-round
//! [`MembershipView`] names the workers expected this round (a full
//! view is bit-identical to the fixed-fleet path), and an append-only
//! [`Journal`] makes committed rounds durable so an interrupted run
//! resumes — via verified deterministic replay — bit-identical to an
//! uninterrupted one.

#![deny(missing_docs)]

mod builder;
mod core;
mod evaluator;
mod journal;
mod membership;

pub use builder::{launch, LaunchedCluster};
pub(crate) use core::fused_combine_update;
pub use core::{Coordinator, CoordinatorBuilder, CoordinatorOptions, OverlapMode, RoundOutcome};
pub use evaluator::Evaluator;
pub use journal::{Journal, RoundRecord};
pub use membership::MembershipView;
