//! (α,f)-cone + √d-leeway measurement — the empirical counterpart of
//! Lemma 1 (weak resilience: E GAR stays in the correct cone) and
//! Definition 2 (strong resilience: per-coordinate deviation O(1/√d)).
//!
//! Setup: correct gradients are `g + N(0, σ²I)` with `g` the all-ones
//! direction normalised to ‖g‖ = 1 (so per-coordinate scale is 1/√d, the
//! high-dimensional regime of Fig. 1). The coalition plays
//! little-is-enough — the attack strong resilience exists to stop. For
//! each GAR and d we estimate, over many trials:
//!
//! * `cos_angle` = ⟨Ē GAR, g⟩ / ‖g‖² — Lemma 1's condition (i); must stay
//!   bounded away from 0 for every resilient rule (weak resilience);
//! * `leeway` = √d · mean_i |GAR_i − nearest correct G_i| — Definition 2's
//!   per-coordinate deviation, scaled by √d. Bounded in d for
//!   BULYAN/MULTI-BULYAN (strong); growing for the weak rules, reflecting
//!   the √d attacker budget.

use crate::attacks::{Attack, AttackCtx, LittleIsEnough};
use crate::gar::GarKind;
use crate::tensor::GradMatrix;
use crate::Result;
use crate::util::Rng64;

#[derive(Debug, Clone)]
pub struct ConeRow {
    pub gar: GarKind,
    pub d: usize,
    pub cos_angle: f64,
    pub leeway_sqrt_d: f64,
}

#[derive(Debug, Clone)]
pub struct ConeConfig {
    pub n: usize,
    pub f: usize,
    pub dims: Vec<usize>,
    /// Noise as a multiple of the per-coordinate signal 1/√d.
    pub sigma_rel: f32,
    pub trials: usize,
    pub seed: u64,
    pub gars: Vec<GarKind>,
}

impl Default for ConeConfig {
    fn default() -> Self {
        Self {
            n: 11,
            f: 2,
            dims: vec![16, 64, 256, 1024, 4096],
            sigma_rel: 0.5,
            trials: 64,
            seed: 1,
            gars: vec![
                GarKind::Average,
                GarKind::Median,
                GarKind::MultiKrum,
                GarKind::MultiBulyan,
            ],
        }
    }
}

pub fn run(cfg: &ConeConfig, quiet: bool) -> Result<Vec<ConeRow>> {
    let (n, f) = (cfg.n, cfg.f);
    let honest = n - f;
    let attack = LittleIsEnough::new(Some(1.5));
    let mut rows = Vec::new();
    for &kind in &cfg.gars {
        let gar_f = if kind == GarKind::Average { 0 } else { f };
        let gar = kind.instantiate(n, gar_f)?;
        for &d in &cfg.dims {
            let coord = 1.0 / (d as f32).sqrt(); // g_i so that ‖g‖ = 1
            let sigma = cfg.sigma_rel * coord;
            let mut rng =
                Rng64::seed_from_u64(cfg.seed ^ ((d as u64) << 8) ^ (kind as u64));
            let mut mean_out = vec![0.0f64; d];
            let mut leeway_acc = 0.0f64;
            for _ in 0..cfg.trials {
                let mut grads = GradMatrix::zeros(n, d);
                for i in 0..honest {
                    let row = grads.row_mut(i);
                    for v in row.iter_mut() {
                        *v = coord + sigma * rng.gaussian();
                    }
                }
                let correct = grads.gather_rows(&(0..honest).collect::<Vec<_>>());
                let ctx = AttackCtx::new(&correct, f, n);
                let forged = attack.forge(&ctx, &mut rng)?;
                for b in 0..f {
                    grads.set_row(honest + b, forged.row(b));
                }
                let out = gar.aggregate(&grads)?;
                // Leeway: per-coordinate distance to the *nearest correct
                // worker's value* at that coordinate (Definition 2 asks
                // for existence of a close correct gradient).
                let mut dev_sum = 0.0f64;
                for j in 0..d {
                    let mut best = f32::INFINITY;
                    for i in 0..honest {
                        best = best.min((out[j] - correct.row(i)[j]).abs());
                    }
                    dev_sum += best as f64;
                    mean_out[j] += out[j] as f64;
                }
                leeway_acc += dev_sum / d as f64;
            }
            for v in mean_out.iter_mut() {
                *v /= cfg.trials as f64;
            }
            // ⟨E GAR, g⟩ with g_j = 1/√d and ‖g‖ = 1.
            let cos_angle: f64 = mean_out.iter().map(|&v| v * coord as f64).sum();
            let leeway_sqrt_d = (d as f64).sqrt() * leeway_acc / cfg.trials as f64;
            if !quiet {
                println!(
                    "cone gar={:<13} d={:<6} ⟨E GAR, g⟩/‖g‖²={:>7.4}  √d·leeway={:>8.4}",
                    kind.as_str(),
                    d,
                    cos_angle,
                    leeway_sqrt_d
                );
            }
            rows.push(ConeRow {
                gar: kind,
                d,
                cos_angle,
                leeway_sqrt_d,
            });
        }
    }
    let csv: Vec<String> = rows
        .iter()
        .map(|r| format!("{},{},{:.6},{:.6}", r.gar, r.d, r.cos_angle, r.leeway_sqrt_d))
        .collect();
    super::write_csv("cone.csv", "gar,d,cos_angle,leeway_sqrt_d", &csv)?;
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resilient_rules_stay_in_the_cone() {
        let _env = crate::bench::env_lock();
        std::env::set_var("MB_RESULTS_DIR", std::env::temp_dir().join("mb_cone_test"));
        let cfg = ConeConfig {
            dims: vec![64, 512],
            trials: 24,
            ..Default::default()
        };
        let rows = run(&cfg, true).unwrap();
        for r in &rows {
            // Lemma 1 condition (i): positive scalar product with g.
            assert!(
                r.cos_angle > 0.2,
                "{} at d={} left the cone: {}",
                r.gar,
                r.d,
                r.cos_angle
            );
        }
        // Strong vs weak: at the largest d, MULTI-BULYAN's √d-scaled
        // leeway must be below MULTI-KRUM's (the median step removes the
        // LIE shift; multi-krum averages it in).
        let at = |g: GarKind, d: usize| {
            rows.iter()
                .find(|r| r.gar == g && r.d == d)
                .unwrap()
                .leeway_sqrt_d
        };
        assert!(
            at(GarKind::MultiBulyan, 512) < at(GarKind::MultiKrum, 512),
            "strong resilience should shrink the leeway"
        );
        std::fs::remove_dir_all(super::super::results_dir()).ok();
        std::env::remove_var("MB_RESULTS_DIR");
    }
}
