//! Fig. 2 — aggregation time as a function of the number of gradients.
//!
//! Paper protocol (§V-A): `n ∈ {7, 9, …, 39}`, `f = ⌊(n−3)/4⌋`,
//! `d ∈ {10⁵, 10⁶, 10⁷}`, gradients i.i.d. `U(0,1)^d`; 7 runs per point,
//! keep the 5 closest to the median, report mean ± std. GARs: MULTI-KRUM,
//! MULTI-BULYAN, MEDIAN (the PyTorch baseline of the paper → our native
//! `CoordMedian`).
//!
//! Our default grid scales `d` down one decade (CPU testbed, see DESIGN.md
//! §Substitutions); `--full` restores the paper's exact grid.

use crate::gar::{GarKind, GarScratch};
use crate::metrics::TimingProtocol;
use crate::tensor::GradMatrix;
use crate::Result;
use crate::util::Rng64;

/// One measured point.
#[derive(Debug, Clone)]
pub struct Point {
    pub gar: GarKind,
    pub n: usize,
    pub f: usize,
    pub d: usize,
    pub mean_ms: f64,
    pub std_ms: f64,
}

/// Grid parameters.
#[derive(Debug, Clone)]
pub struct Fig2Config {
    pub dims: Vec<usize>,
    pub ns: Vec<usize>,
    pub gars: Vec<GarKind>,
    pub protocol: TimingProtocol,
    pub seed: u64,
}

impl Fig2Config {
    /// CPU-scaled default grid (see DESIGN.md §Substitutions).
    pub fn default_grid() -> Self {
        Self {
            dims: vec![10_000, 100_000, 1_000_000],
            ns: (7..=39).step_by(4).collect(),
            gars: vec![GarKind::MultiKrum, GarKind::MultiBulyan, GarKind::Median],
            protocol: TimingProtocol::default(),
            seed: 1,
        }
    }

    /// The paper's exact grid (minutes of runtime on CPU).
    pub fn full_grid() -> Self {
        Self {
            dims: vec![100_000, 1_000_000, 10_000_000],
            ns: (7..=39).step_by(2).collect(),
            ..Self::default_grid()
        }
    }

    /// Tiny grid for tests.
    pub fn smoke() -> Self {
        Self {
            dims: vec![1_000],
            ns: vec![7, 11],
            gars: vec![GarKind::MultiKrum, GarKind::MultiBulyan, GarKind::Median],
            protocol: TimingProtocol::quick(),
            seed: 1,
        }
    }
}

/// Run the sweep, print the series, write `results/fig2.csv`.
pub fn run(cfg: &Fig2Config, quiet: bool) -> Result<Vec<Point>> {
    let mut points = Vec::new();
    for &d in &cfg.dims {
        if !quiet {
            println!("\n== Fig. 2 series: d = {d} ==");
            println!("{:>4} {:>3}  {}", "n", "f", cfg
                .gars
                .iter()
                .map(|g| format!("{:>22}", g.as_str()))
                .collect::<String>());
        }
        for &n in &cfg.ns {
            let f = super::fig2_f(n);
            let mut rng = Rng64::seed_from_u64(cfg.seed ^ (d as u64) ^ ((n as u64) << 32));
            let grads = GradMatrix::uniform(n, d, 0.0, 1.0, &mut rng);
            let mut line = format!("{n:>4} {f:>3}  ");
            for &kind in &cfg.gars {
                if n < kind.min_n(f) {
                    line.push_str(&format!("{:>22}", "-"));
                    continue;
                }
                let gar = kind.instantiate(n, f)?;
                let mut out = vec![0.0f32; d];
                let mut scratch = GarScratch::new();
                let (mean_ms, std_ms) = cfg.protocol.measure(|| {
                    gar.aggregate_with_scratch(&grads, &mut out, &mut scratch)
                        .expect("aggregation failed");
                });
                line.push_str(&format!("{:>14.3}±{:>6.3}ms", mean_ms, std_ms));
                points.push(Point {
                    gar: kind,
                    n,
                    f,
                    d,
                    mean_ms,
                    std_ms,
                });
            }
            if !quiet {
                println!("{line}");
            }
        }
    }
    let rows: Vec<String> = points
        .iter()
        .map(|p| {
            format!(
                "{},{},{},{},{:.6},{:.6}",
                p.gar, p.n, p.f, p.d, p.mean_ms, p.std_ms
            )
        })
        .collect();
    let path = super::write_csv("fig2.csv", "gar,n,f,d,mean_ms,std_ms", &rows)?;
    if !quiet {
        println!("\nwrote {path:?}");
        summarize_crossovers(&points);
    }
    Ok(points)
}

/// Print, per d, up to which n MULTI-KRUM / MULTI-BULYAN beat MEDIAN —
/// the crossover structure that is Fig. 2's headline observation.
pub fn summarize_crossovers(points: &[Point]) {
    let dims: std::collections::BTreeSet<usize> = points.iter().map(|p| p.d).collect();
    for d in dims {
        let med: std::collections::BTreeMap<usize, f64> = points
            .iter()
            .filter(|p| p.d == d && p.gar == GarKind::Median)
            .map(|p| (p.n, p.mean_ms))
            .collect();
        for kind in [GarKind::MultiKrum, GarKind::MultiBulyan] {
            let mut best: Option<usize> = None;
            for p in points.iter().filter(|p| p.d == d && p.gar == kind) {
                if let Some(&m) = med.get(&p.n) {
                    if p.mean_ms <= m {
                        best = Some(best.map_or(p.n, |b: usize| b.max(p.n)));
                    }
                }
            }
            match best {
                Some(n) => println!("d={d}: {kind} faster than median up to n ≤ {n}"),
                None => println!("d={d}: {kind} never beats median on this grid"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_grid_produces_all_points() {
        let _env = crate::bench::env_lock();
        std::env::set_var(
            "MB_RESULTS_DIR",
            std::env::temp_dir().join("mb_fig2_test"),
        );
        let points = run(&Fig2Config::smoke(), true).unwrap();
        // 1 dim × 2 n × 3 gars = 6 points.
        assert_eq!(points.len(), 6);
        assert!(points.iter().all(|p| p.mean_ms >= 0.0));
        std::fs::remove_dir_all(super::super::results_dir()).ok();
        std::env::remove_var("MB_RESULTS_DIR");
    }
}
