//! Resilience gauntlet — the weak/strong Byzantine-resilience claims.
//!
//! Every GAR × every attack on the quadratic workload (known optimum, so
//! "converged" is unambiguous). Expected shape:
//!
//! * averaging breaks under every value attack (one Byzantine suffices, §I);
//! * weakly-resilient rules (KRUM, MULTI-KRUM, MEDIAN, trimmed mean)
//!   survive the cheap attacks but drift under little-is-enough (the √d
//!   leeway of Fig. 1);
//! * BULYAN / MULTI-BULYAN converge under everything (strong resilience,
//!   Theorem 2.i) as long as n ≥ 4f+3.

use crate::attacks::AttackKind;
use crate::config::{ClusterConfig, ExperimentConfig, ModelConfig, TrainConfig};
use crate::coordinator::launch;
use crate::gar::GarKind;
use crate::Result;

#[derive(Debug, Clone)]
pub struct GauntletRow {
    pub gar: GarKind,
    pub attack: &'static str,
    pub final_loss: f32,
    pub converged: bool,
    /// Byzantine-filtering precision: the fraction of the GAR's selected
    /// rows (summed over the run via `MetricsRecorder::selections`) that
    /// belonged to honest workers. 1.0 = the rule never picked a forged
    /// row; coordinate-wise rules (median/trimmed-mean/average) report
    /// all rows each round, so their precision sits at `(n − byz)/n` by
    /// construction. NaN when nothing was selected.
    pub selection_precision: f64,
    /// Selection recall (the Bareilles et al. 2026 selection-quality
    /// counterpart): the fraction of honest gradient submissions the rule
    /// actually used — honest selections / (honest workers × rounds).
    /// 1.0 = no honest gradient was ever filtered out; single-selection
    /// rules (KRUM, MEDIAN) sit near 1/n by construction. Precision says
    /// "what we kept was honest", recall says "we kept the honest ones".
    /// NaN when no round ran.
    pub selection_recall: f64,
}

#[derive(Debug, Clone)]
pub struct GauntletConfig {
    pub n: usize,
    pub f: usize,
    pub dim: usize,
    pub noise: f32,
    pub steps: usize,
    pub threshold: f32,
    pub seed: u64,
    pub gars: Vec<GarKind>,
    pub attacks: Vec<AttackKind>,
}

impl Default for GauntletConfig {
    fn default() -> Self {
        Self {
            n: 11,
            f: 2,
            dim: 512,
            noise: 0.5,
            steps: 400,
            threshold: 5e-3,
            seed: 1,
            gars: vec![
                GarKind::Average,
                GarKind::Median,
                GarKind::TrimmedMean,
                GarKind::Krum,
                GarKind::MultiKrum,
                GarKind::Bulyan,
                GarKind::MultiBulyan,
            ],
            attacks: {
                let mut a = vec![AttackKind::None];
                a.extend(AttackKind::gauntlet());
                a
            },
        }
    }
}

pub fn run(cfg: &GauntletConfig, quiet: bool) -> Result<Vec<GauntletRow>> {
    let mut rows = Vec::new();
    if !quiet {
        println!(
            "{:<14} {}",
            "gar \\ attack",
            cfg.attacks
                .iter()
                .map(|a| format!("{:>24}", a.label()))
                .collect::<String>()
        );
    }
    for &gar in &cfg.gars {
        let mut line = format!("{:<14} ", gar.as_str());
        for &attack in &cfg.attacks {
            let byz = if attack == AttackKind::None { 0 } else { cfg.f };
            let exp = ExperimentConfig {
                cluster: ClusterConfig {
                    n: cfg.n,
                    // Averaging declares f=0 (it has no resilience
                    // contract) but still suffers `byz` actual attackers.
                    f: if gar == GarKind::Average { 0 } else { cfg.f },
                    actual_byzantine: Some(byz),
                    net_delay_us: 0,
                    drop_prob: 0.0,
                    round_timeout_ms: 60_000,
                    ..Default::default()
                },
                gar,
                pre: Vec::new(),
                attack,
                model: ModelConfig::Quadratic {
                    dim: cfg.dim,
                    noise: cfg.noise,
                },
                train: TrainConfig {
                    learning_rate: 0.1,
                    momentum: 0.0,
                    steps: cfg.steps,
                    batch_size: 8,
                    eval_every: 0,
                    seed: cfg.seed,
                },
                threads: 1,
                transport: Default::default(),
                collect: Default::default(),
                output_dir: None,
            };
            let cluster = launch(&exp, None)?;
            let mut coordinator = cluster.coordinator;
            let mut evaluator = cluster.evaluator;
            coordinator.train(cfg.steps, 0, &mut evaluator)?;
            let final_loss = coordinator.metrics.final_loss().unwrap_or(f32::INFINITY);
            // Byzantine-filtering precision/recall from the per-worker
            // selection counts (forged rows occupy indices honest..n).
            let selections = coordinator.metrics.selections();
            let rounds = coordinator.metrics.counter("rounds");
            let honest = cfg.n - byz;
            let total: u64 = selections.iter().sum();
            let honest_hits: u64 = selections[..honest.min(selections.len())].iter().sum();
            let selection_precision = if total == 0 {
                f64::NAN
            } else {
                honest_hits as f64 / total as f64
            };
            let honest_submissions = honest as u64 * rounds;
            let selection_recall = if honest_submissions == 0 {
                f64::NAN
            } else {
                honest_hits as f64 / honest_submissions as f64
            };
            coordinator.shutdown();
            let converged = final_loss.is_finite() && final_loss < cfg.threshold;
            line.push_str(&format!(
                "{:>10.2e} p={:<4.2}r={:<4.2}{:>4}",
                final_loss,
                selection_precision,
                selection_recall,
                if converged { "ok" } else { "FAIL" }
            ));
            rows.push(GauntletRow {
                gar,
                attack: attack.label(),
                final_loss,
                converged,
                selection_precision,
                selection_recall,
            });
        }
        if !quiet {
            println!("{line}");
        }
    }
    let csv: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "{},{},{},{},{:.4},{:.4}",
                r.gar,
                r.attack,
                r.final_loss,
                r.converged,
                r.selection_precision,
                r.selection_recall
            )
        })
        .collect();
    super::write_csv(
        "resilience.csv",
        "gar,attack,final_loss,converged,selection_precision,selection_recall",
        &csv,
    )?;
    Ok(rows)
}
