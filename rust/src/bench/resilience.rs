//! Resilience gauntlet — the weak/strong Byzantine-resilience claims.
//!
//! Every GAR × every attack on the quadratic workload (known optimum, so
//! "converged" is unambiguous). Expected shape:
//!
//! * averaging breaks under every value attack (one Byzantine suffices, §I);
//! * weakly-resilient rules (KRUM, MULTI-KRUM, MEDIAN, trimmed mean)
//!   survive the cheap attacks but drift under little-is-enough (the √d
//!   leeway of Fig. 1);
//! * BULYAN / MULTI-BULYAN converge under everything (strong resilience,
//!   Theorem 2.i) as long as n ≥ 4f+3.
//!
//! Besides the run-level `results/resilience.csv`, the gauntlet emits the
//! per-round selection-quality *curve* (`results/regret_curve.csv`,
//! round → regret/precision/recall — the Bareilles et al. 2026 lens):
//! `regret` is the cumulative count of forged rows the rule selected up
//! to that round, so a flat curve means the rule locked the coalition
//! out early and a linear curve means it never learned to.

use crate::attacks::AttackKind;
use crate::config::{ClusterConfig, ExperimentConfig, ModelConfig, TrainConfig};
use crate::coordinator::launch;
use crate::gar::GarKind;
use crate::Result;

/// One per-round point of the selection-quality curve.
#[derive(Debug, Clone)]
pub struct RegretPoint {
    pub gar: GarKind,
    pub attack: &'static str,
    /// 1-based round index.
    pub round: u64,
    /// Cumulative forged-row selections up to and including this round.
    pub regret: u64,
    /// This round's selection precision (honest fraction of the selected
    /// rows; NaN when the rule selected nothing).
    pub precision: f64,
    /// This round's selection recall (fraction of honest submissions the
    /// rule used).
    pub recall: f64,
}

#[derive(Debug, Clone)]
pub struct GauntletRow {
    pub gar: GarKind,
    pub attack: &'static str,
    pub final_loss: f32,
    pub converged: bool,
    /// Byzantine-filtering precision: the fraction of the GAR's selected
    /// rows (summed over the run via `MetricsRecorder::selections`) that
    /// belonged to honest workers. 1.0 = the rule never picked a forged
    /// row; coordinate-wise rules (median/trimmed-mean/average) report
    /// all rows each round, so their precision sits at `(n − byz)/n` by
    /// construction. NaN when nothing was selected.
    pub selection_precision: f64,
    /// Selection recall (the Bareilles et al. 2026 selection-quality
    /// counterpart): the fraction of honest gradient submissions the rule
    /// actually used — honest selections / (honest workers × rounds).
    /// 1.0 = no honest gradient was ever filtered out; single-selection
    /// rules (KRUM, MEDIAN) sit near 1/n by construction. Precision says
    /// "what we kept was honest", recall says "we kept the honest ones".
    /// NaN when no round ran.
    pub selection_recall: f64,
}

#[derive(Debug, Clone)]
pub struct GauntletConfig {
    pub n: usize,
    pub f: usize,
    pub dim: usize,
    pub noise: f32,
    pub steps: usize,
    pub threshold: f32,
    pub seed: u64,
    pub gars: Vec<GarKind>,
    pub attacks: Vec<AttackKind>,
}

impl Default for GauntletConfig {
    fn default() -> Self {
        Self {
            n: 11,
            f: 2,
            dim: 512,
            noise: 0.5,
            steps: 400,
            threshold: 5e-3,
            seed: 1,
            gars: vec![
                GarKind::Average,
                GarKind::Median,
                GarKind::TrimmedMean,
                GarKind::Krum,
                GarKind::MultiKrum,
                GarKind::Bulyan,
                GarKind::MultiBulyan,
            ],
            attacks: {
                let mut a = vec![AttackKind::None];
                a.extend(AttackKind::gauntlet());
                a
            },
        }
    }
}

pub fn run(cfg: &GauntletConfig, quiet: bool) -> Result<Vec<GauntletRow>> {
    let mut rows = Vec::new();
    let mut curve: Vec<RegretPoint> = Vec::new();
    if !quiet {
        println!(
            "{:<14} {}",
            "gar \\ attack",
            cfg.attacks
                .iter()
                .map(|a| format!("{:>24}", a.label()))
                .collect::<String>()
        );
    }
    for &gar in &cfg.gars {
        let mut line = format!("{:<14} ", gar.as_str());
        for &attack in &cfg.attacks {
            let byz = if attack == AttackKind::None { 0 } else { cfg.f };
            let exp = ExperimentConfig {
                cluster: ClusterConfig {
                    n: cfg.n,
                    // Averaging declares f=0 (it has no resilience
                    // contract) but still suffers `byz` actual attackers.
                    f: if gar == GarKind::Average { 0 } else { cfg.f },
                    actual_byzantine: Some(byz),
                    net_delay_us: 0,
                    drop_prob: 0.0,
                    round_timeout_ms: 60_000,
                    ..Default::default()
                },
                gar,
                pre: Vec::new(),
                attack,
                model: ModelConfig::Quadratic {
                    dim: cfg.dim,
                    noise: cfg.noise,
                },
                train: TrainConfig {
                    learning_rate: 0.1,
                    momentum: 0.0,
                    steps: cfg.steps,
                    batch_size: 8,
                    eval_every: 0,
                    seed: cfg.seed,
                },
                threads: 1,
                transport: Default::default(),
                collect: Default::default(),
                overlap: Default::default(),
                overlap_window: 1,
                codec: None,
                groups: 1,
                output_dir: None,
                journal: None,
                crash_after_round: None,
            };
            let cluster = launch(&exp, None)?;
            let mut coordinator = cluster.coordinator;
            let mut evaluator = cluster.evaluator;
            // Manual round loop (rather than `train`) so each round's
            // selection feeds the regret curve.
            let honest_n = cfg.n - byz;
            let mut regret = 0u64;
            for _ in 0..cfg.steps {
                let view = coordinator.next_view();
                let out = coordinator.run_round(&view)?;
                let total = out.selected.len() as u64;
                let byz_hits = out.selected.iter().filter(|&&w| w >= honest_n).count() as u64;
                let honest_hits = total - byz_hits;
                regret += byz_hits;
                curve.push(RegretPoint {
                    gar,
                    attack: attack.label(),
                    round: out.round,
                    regret,
                    precision: if total == 0 {
                        f64::NAN
                    } else {
                        honest_hits as f64 / total as f64
                    },
                    recall: honest_hits as f64 / honest_n as f64,
                });
            }
            let (final_loss, _) = evaluator.evaluate(coordinator.params())?;
            // Byzantine-filtering precision/recall from the per-worker
            // selection counts (forged rows occupy indices honest..n).
            let selections = coordinator.metrics.selections();
            let rounds = coordinator.metrics.counter("rounds");
            let honest = cfg.n - byz;
            let total: u64 = selections.iter().sum();
            let honest_hits: u64 = selections[..honest.min(selections.len())].iter().sum();
            let selection_precision = if total == 0 {
                f64::NAN
            } else {
                honest_hits as f64 / total as f64
            };
            let honest_submissions = honest as u64 * rounds;
            let selection_recall = if honest_submissions == 0 {
                f64::NAN
            } else {
                honest_hits as f64 / honest_submissions as f64
            };
            coordinator.shutdown();
            let converged = final_loss.is_finite() && final_loss < cfg.threshold;
            line.push_str(&format!(
                "{:>10.2e} p={:<4.2}r={:<4.2}{:>4}",
                final_loss,
                selection_precision,
                selection_recall,
                if converged { "ok" } else { "FAIL" }
            ));
            rows.push(GauntletRow {
                gar,
                attack: attack.label(),
                final_loss,
                converged,
                selection_precision,
                selection_recall,
            });
        }
        if !quiet {
            println!("{line}");
        }
    }
    let csv: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "{},{},{},{},{:.4},{:.4}",
                r.gar,
                r.attack,
                r.final_loss,
                r.converged,
                r.selection_precision,
                r.selection_recall
            )
        })
        .collect();
    super::write_csv(
        "resilience.csv",
        "gar,attack,final_loss,converged,selection_precision,selection_recall",
        &csv,
    )?;
    // The per-round selection-quality curve (regret = cumulative forged
    // selections) — uploaded as a CI artifact next to the aggregates.
    let curve_csv: Vec<String> = curve
        .iter()
        .map(|p| {
            format!(
                "{},{},{},{},{:.4},{:.4}",
                p.gar, p.attack, p.round, p.regret, p.precision, p.recall
            )
        })
        .collect();
    super::write_csv(
        "regret_curve.csv",
        "gar,attack,round,regret,precision,recall",
        &curve_csv,
    )?;
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gauntlet_emits_per_round_regret_curve() {
        let _env = crate::bench::env_lock();
        let dir = std::env::temp_dir().join("mb_resilience_bench_test");
        std::env::set_var("MB_RESULTS_DIR", &dir);
        let cfg = GauntletConfig {
            n: 11,
            f: 2,
            dim: 48,
            noise: 0.3,
            steps: 4,
            threshold: 5e-3,
            seed: 1,
            gars: vec![GarKind::Average, GarKind::MultiKrum],
            attacks: vec![AttackKind::None, AttackKind::SignFlip { scale: 10.0 }],
        };
        let rows = run(&cfg, true).unwrap();
        assert_eq!(rows.len(), 4);
        let text = std::fs::read_to_string(dir.join("regret_curve.csv")).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        // Header + one point per (gar, attack, round).
        assert_eq!(lines[0], "gar,attack,round,regret,precision,recall");
        assert_eq!(lines.len(), 1 + 2 * 2 * 4);
        // Under no attack there is nothing to regret; regret is
        // monotone within a cell by construction (cumulative count).
        for line in &lines[1..] {
            let cols: Vec<&str> = line.split(',').collect();
            assert_eq!(cols.len(), 6, "{line}");
            if cols[1] == "none" {
                assert_eq!(cols[3], "0", "{line}");
            }
        }
        // Multi-Krum under sign-flip: a real curve with sane precision.
        let mk: Vec<&&str> = lines
            .iter()
            .filter(|l| l.starts_with("multi-krum,sign-flip"))
            .collect();
        assert_eq!(mk.len(), 4);
        assert!(dir.join("resilience.csv").exists());
        std::fs::remove_dir_all(&dir).ok();
        std::env::remove_var("MB_RESULTS_DIR");
    }
}
