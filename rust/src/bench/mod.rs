//! Benchmark harnesses — one per paper table/figure plus the ablations
//! DESIGN.md's experiment index lists. Each harness prints the same
//! rows/series the paper reports and writes a CSV under `results/`.
//!
//! | id | paper artefact | function |
//! |---|---|---|
//! | fig2 | Fig. 2 aggregation time vs (n, d) | [`fig2::run`] |
//! | fig3 | Fig. 3 max top-1 accuracy vs batch size | [`fig3::run`] |
//! | dscaling | Theorem 2.ii O(d) claim | [`dscaling::run`] |
//! | dscale | grouped end-to-end O(d) gate to d = 10⁷ (CI-enforced slope band) | [`dscaling::run_dscale`] |
//! | slowdown | Theorems 1.ii/2.iii m̃/n slowdown | [`slowdown::run`] |
//! | straggler | first-m vs wait-all round-tail latency under the straggler cost model | [`straggler::run`] |
//! | resilience | weak/strong resilience under the attack gauntlet | [`resilience::run`] |
//! | codec | wire-codec bytes/latency/fidelity sweep | [`codec::run`] |
//! | cone | (α,f) cone + √d leeway | [`cone::run`] |
//! | check | CI perf-baseline gate over the GAR hot path | [`baseline::check`] |

pub mod baseline;
pub mod codec;
pub mod cone;
pub mod dscaling;
pub mod fig2;
pub mod fig3;
pub mod resilience;
pub mod slowdown;
pub mod straggler;

use crate::Result;
use std::io::Write;
use std::path::PathBuf;

/// Where bench CSVs land.
pub fn results_dir() -> PathBuf {
    std::env::var_os("MB_RESULTS_DIR")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("results"))
}

/// Write a CSV with a header line.
pub fn write_csv(name: &str, header: &str, rows: &[String]) -> Result<PathBuf> {
    let dir = results_dir();
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(name);
    let mut w = std::io::BufWriter::new(std::fs::File::create(&path)?);
    writeln!(w, "{header}")?;
    for r in rows {
        writeln!(w, "{r}")?;
    }
    Ok(path)
}

/// Fig. 2's f rule: `f = ⌊(n−3)/4⌋`.
pub fn fig2_f(n: usize) -> usize {
    (n - 3) / 4
}

/// Append a markdown fragment to the GitHub Actions step summary
/// (`$GITHUB_STEP_SUMMARY`) so bench results are readable on the run
/// page without downloading artifacts. No-op outside Actions (or if the
/// file cannot be written — a summary must never fail a bench).
pub fn step_summary(markdown: &str) {
    let Some(path) = std::env::var_os("GITHUB_STEP_SUMMARY") else {
        return;
    };
    if let Ok(mut f) = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)
    {
        let _ = writeln!(f, "{markdown}");
    }
}

/// Serialises tests that mutate the process-global `MB_RESULTS_DIR`
/// environment variable. `cargo test` runs tests concurrently in one
/// process; without this lock the bench tests race on set/remove and
/// delete each other's result directories mid-run.
#[cfg(test)]
pub(crate) fn env_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_f_matches_paper_rule() {
        assert_eq!(fig2_f(7), 1);
        assert_eq!(fig2_f(11), 2);
        assert_eq!(fig2_f(39), 9);
        // n ≥ 4f+3 always holds under this rule.
        for n in (7..=39).step_by(2) {
            assert!(n >= 4 * fig2_f(n) + 3);
        }
    }

    #[test]
    fn step_summary_appends_when_env_set() {
        let _env = env_lock();
        let prev = std::env::var_os("GITHUB_STEP_SUMMARY");
        let dir = std::env::temp_dir().join("mb_step_summary_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("summary.md");
        std::fs::write(&path, "").unwrap();
        std::env::set_var("GITHUB_STEP_SUMMARY", &path);
        step_summary("## table one");
        step_summary("| a | b |");
        std::env::remove_var("GITHUB_STEP_SUMMARY");
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "## table one\n| a | b |\n");
        // No env var: a no-op, never an error.
        step_summary("ignored");
        assert_eq!(std::fs::read_to_string(&path).unwrap(), text);
        std::fs::remove_dir_all(&dir).ok();
        // Restore whatever the process started with (in CI the verify
        // job's real step summary is set) rather than deleting it.
        if let Some(v) = prev {
            std::env::set_var("GITHUB_STEP_SUMMARY", v);
        }
    }

    #[test]
    fn csv_writes_under_results_dir() {
        let _env = env_lock();
        std::env::set_var("MB_RESULTS_DIR", std::env::temp_dir().join("mb_results_test"));
        let p = write_csv("t.csv", "a,b", &["1,2".into()]).unwrap();
        assert_eq!(std::fs::read_to_string(&p).unwrap(), "a,b\n1,2\n");
        std::fs::remove_dir_all(results_dir()).ok();
        std::env::remove_var("MB_RESULTS_DIR");
    }
}
