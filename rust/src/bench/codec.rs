//! Codec sweep — what each gradient wire codec costs and buys.
//!
//! Two measurements per codec (`crate::codec`):
//!
//! * **wire cost**, measured directly on the codec: real per-worker
//!   quadratic gradients are encoded whole (one chunk) and decoded back,
//!   giving bytes/round, encode µs and decode µs, plus the compression
//!   ratio against the raw 4-bytes-per-coordinate baseline;
//! * **training effect**, measured end to end: a codec × GAR × attack
//!   grid of seeded runs records rounds-to-target-loss, final loss and
//!   the selection precision/recall of the resilience gauntlet — so the
//!   lossy codecs' fidelity cost is visible next to their byte savings
//!   (top-k error feedback recovering convergence, int8 quantization
//!   noise, etc.).
//!
//! Writes `results/codec.csv` and appends a pass/fail markdown table to
//! `$GITHUB_STEP_SUMMARY` (the bench-gate acceptance bar: int8 and topk
//! must cut bytes/round at least 3× vs raw).

use crate::attacks::AttackKind;
use crate::codec::{decode, encoder, CodecKind};
use crate::config::{ClusterConfig, ExperimentConfig, ModelConfig, TrainConfig};
use crate::coordinator::launch;
use crate::data::QuadraticProblem;
use crate::gar::GarKind;
use crate::metrics::Stopwatch;
use crate::worker::GradSource;
use crate::Result;
use std::sync::Arc;

/// The minimum raw-vs-codec byte ratio the compressive codecs (int8,
/// topk) must achieve — the bench-gate acceptance bar.
pub const MIN_COMPRESSIVE_RATIO: f64 = 3.0;

/// Wire cost of one codec: all honest workers' gradients for one round,
/// averaged over a few rounds (top-k's error-feedback residual warms up).
#[derive(Debug, Clone)]
pub struct WireCost {
    pub bytes_per_round: u64,
    pub encode_us_per_round: f64,
    pub decode_us_per_round: f64,
}

/// One grid cell of the sweep.
#[derive(Debug, Clone)]
pub struct CodecRow {
    pub codec: CodecKind,
    pub gar: GarKind,
    pub attack: &'static str,
    pub bytes_per_round: u64,
    /// Raw bytes / this codec's bytes (1.0 for raw itself).
    pub ratio_vs_raw: f64,
    pub encode_us_per_round: f64,
    pub decode_us_per_round: f64,
    /// First round whose evaluated loss dropped below the target
    /// (−1 = never within the step budget).
    pub rounds_to_target: i64,
    pub final_loss: f32,
    /// Selection precision/recall, derived exactly like
    /// [`super::resilience`] (honest fraction of selected rows / honest
    /// submissions used) — reported for every codec so the lossy ones'
    /// effect on Byzantine filtering is visible.
    pub selection_precision: f64,
    pub selection_recall: f64,
}

#[derive(Debug, Clone)]
pub struct CodecBenchConfig {
    pub n: usize,
    pub f: usize,
    pub dim: usize,
    pub noise: f32,
    pub steps: usize,
    /// Loss threshold defining "converged" for rounds-to-target.
    pub target_loss: f32,
    pub seed: u64,
    /// Rounds averaged into the wire-cost measurement.
    pub wire_rounds: u64,
    pub gars: Vec<GarKind>,
    pub attacks: Vec<AttackKind>,
    pub codecs: Vec<CodecKind>,
}

impl Default for CodecBenchConfig {
    fn default() -> Self {
        Self {
            n: 11,
            f: 2,
            dim: 512,
            noise: 0.5,
            steps: 300,
            target_loss: 5e-3,
            seed: 1,
            wire_rounds: 4,
            gars: vec![GarKind::MultiKrum, GarKind::MultiBulyan],
            attacks: vec![AttackKind::None, AttackKind::SignFlip { scale: 5.0 }],
            codecs: CodecKind::ALL.to_vec(),
        }
    }
}

/// Measure one codec's wire cost on `workers` honest quadratic gradient
/// streams (whole-gradient encode — chunking at block multiples is
/// byte-identical, see `crate::codec`).
pub fn measure_wire(
    kind: CodecKind,
    dim: usize,
    noise: f32,
    seed: u64,
    workers: usize,
    batch: usize,
    rounds: u64,
) -> Result<WireCost> {
    let problem = Arc::new(QuadraticProblem::new(dim, noise, seed));
    let params = vec![0.1f32; dim];
    let mut sources: Vec<GradSource> = (0..workers)
        .map(|i| GradSource::quadratic(Arc::clone(&problem), i, batch))
        .collect();
    let mut encoders: Vec<_> = (0..workers).map(|_| encoder(kind)).collect();
    let mut grad = Vec::new();
    let mut enc = Vec::new();
    let mut dec = Vec::new();
    let mut bytes = 0u64;
    let mut encode_ms = 0.0f64;
    let mut decode_ms = 0.0f64;
    for round in 1..=rounds {
        for (i, src) in sources.iter_mut().enumerate() {
            src.gradient_into(&params, round, &mut grad)?;
            let sw = Stopwatch::start();
            encoders[i].encode(0, &grad, &mut enc);
            encode_ms += sw.elapsed_ms();
            bytes += enc.len() as u64;
            dec.clear();
            let sw = Stopwatch::start();
            decode(kind, 0, grad.len(), &enc, &mut dec)?;
            decode_ms += sw.elapsed_ms();
            anyhow::ensure!(
                dec.len() == grad.len(),
                "{kind:?}: decode returned {} of {} coordinates",
                dec.len(),
                grad.len()
            );
        }
    }
    Ok(WireCost {
        bytes_per_round: bytes / rounds,
        encode_us_per_round: encode_ms * 1000.0 / rounds as f64,
        decode_us_per_round: decode_ms * 1000.0 / rounds as f64,
    })
}

pub fn run(cfg: &CodecBenchConfig, quiet: bool) -> Result<Vec<CodecRow>> {
    let honest_workers = cfg.n - cfg.f;
    // Wire cost once per codec (it does not depend on gar/attack).
    let mut wire: Vec<(CodecKind, WireCost)> = Vec::new();
    for &kind in &cfg.codecs {
        wire.push((
            kind,
            measure_wire(kind, cfg.dim, cfg.noise, cfg.seed, honest_workers, 8, cfg.wire_rounds)?,
        ));
    }
    let raw_bytes = (honest_workers * cfg.dim * 4) as u64;

    let mut rows = Vec::new();
    for &(kind, ref cost) in &wire {
        let ratio = raw_bytes as f64 / cost.bytes_per_round.max(1) as f64;
        for &gar in &cfg.gars {
            for &attack in &cfg.attacks {
                let byz = if attack == AttackKind::None { 0 } else { cfg.f };
                let exp = ExperimentConfig {
                    cluster: ClusterConfig {
                        n: cfg.n,
                        f: cfg.f,
                        actual_byzantine: Some(byz),
                        round_timeout_ms: 60_000,
                        ..Default::default()
                    },
                    gar,
                    pre: Vec::new(),
                    attack,
                    model: ModelConfig::Quadratic {
                        dim: cfg.dim,
                        noise: cfg.noise,
                    },
                    train: TrainConfig {
                        learning_rate: 0.1,
                        momentum: 0.0,
                        steps: cfg.steps,
                        batch_size: 8,
                        eval_every: 0,
                        seed: cfg.seed,
                    },
                    threads: 1,
                    transport: Default::default(),
                    collect: Default::default(),
                    overlap: Default::default(),
                    overlap_window: 1,
                    codec: Some(kind),
                    groups: 1,
                    output_dir: None,
                    journal: None,
                    crash_after_round: None,
                };
                let cluster = launch(&exp, None)?;
                let mut coordinator = cluster.coordinator;
                let mut evaluator = cluster.evaluator;
                let mut rounds_to_target = -1i64;
                for r in 1..=cfg.steps {
                    let view = coordinator.next_view();
                    coordinator.run_round(&view)?;
                    let (loss, _) = evaluator.evaluate(coordinator.params())?;
                    if loss.is_finite() && loss < cfg.target_loss {
                        rounds_to_target = r as i64;
                        break;
                    }
                }
                let (final_loss, _) = evaluator.evaluate(coordinator.params())?;
                let selections = coordinator.metrics.selections();
                let rounds = coordinator.metrics.counter("rounds");
                let honest = cfg.n - byz;
                let total: u64 = selections.iter().sum();
                let honest_hits: u64 = selections[..honest.min(selections.len())].iter().sum();
                let selection_precision = if total == 0 {
                    f64::NAN
                } else {
                    honest_hits as f64 / total as f64
                };
                let honest_submissions = honest as u64 * rounds;
                let selection_recall = if honest_submissions == 0 {
                    f64::NAN
                } else {
                    honest_hits as f64 / honest_submissions as f64
                };
                coordinator.shutdown();
                if !quiet {
                    println!(
                        "codec={:<9} gar={:<12} attack={:<18} bytes/round={:>8} ({ratio:>5.1}x) \
                         rounds-to-{:.0e}={:>4} loss={:>10.3e} p={selection_precision:.2} \
                         r={selection_recall:.2}",
                        kind.as_str(),
                        gar.as_str(),
                        attack.label(),
                        cost.bytes_per_round,
                        cfg.target_loss,
                        rounds_to_target,
                        final_loss,
                    );
                }
                rows.push(CodecRow {
                    codec: kind,
                    gar,
                    attack: attack.label(),
                    bytes_per_round: cost.bytes_per_round,
                    ratio_vs_raw: ratio,
                    encode_us_per_round: cost.encode_us_per_round,
                    decode_us_per_round: cost.decode_us_per_round,
                    rounds_to_target,
                    final_loss,
                    selection_precision,
                    selection_recall,
                });
            }
        }
    }

    let csv: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "{},{},{},{},{:.3},{:.1},{:.1},{},{},{:.4},{:.4}",
                r.codec.as_str(),
                r.gar,
                r.attack,
                r.bytes_per_round,
                r.ratio_vs_raw,
                r.encode_us_per_round,
                r.decode_us_per_round,
                r.rounds_to_target,
                r.final_loss,
                r.selection_precision,
                r.selection_recall
            )
        })
        .collect();
    super::write_csv(
        "codec.csv",
        "codec,gar,attack,bytes_per_round,ratio_vs_raw,encode_us_per_round,\
         decode_us_per_round,rounds_to_target,final_loss,selection_precision,selection_recall",
        &csv,
    )?;

    // Step-summary table: one line per codec (wire cost + the acceptance
    // verdict), then the training grid.
    let mut md = String::from(
        "## bench codec\n\n\
         | codec | bytes/round | vs raw | encode µs | decode µs | ≥3× bar |\n\
         |---|---|---|---|---|---|\n",
    );
    for &(kind, ref cost) in &wire {
        let ratio = raw_bytes as f64 / cost.bytes_per_round.max(1) as f64;
        let verdict = if matches!(kind, CodecKind::Int8 | CodecKind::TopK) {
            if ratio >= MIN_COMPRESSIVE_RATIO {
                "pass"
            } else {
                "**FAIL**"
            }
        } else {
            "—"
        };
        md.push_str(&format!(
            "| {} | {} | {:.1}× | {:.1} | {:.1} | {} |\n",
            kind.as_str(),
            cost.bytes_per_round,
            ratio,
            cost.encode_us_per_round,
            cost.decode_us_per_round,
            verdict
        ));
    }
    md.push_str(
        "\n| codec | gar | attack | rounds→target | final loss | precision | recall |\n\
         |---|---|---|---|---|---|---|\n",
    );
    for r in &rows {
        md.push_str(&format!(
            "| {} | {} | {} | {} | {:.3e} | {:.2} | {:.2} |\n",
            r.codec.as_str(),
            r.gar,
            r.attack,
            if r.rounds_to_target < 0 {
                "never".to_string()
            } else {
                r.rounds_to_target.to_string()
            },
            r.final_loss,
            r.selection_precision,
            r.selection_recall
        ));
    }
    super::step_summary(&md);
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codec_sweep_writes_csv_and_compressive_codecs_hit_the_bar() {
        let _env = crate::bench::env_lock();
        let dir = std::env::temp_dir().join("mb_codec_bench_test");
        std::env::set_var("MB_RESULTS_DIR", &dir);
        let cfg = CodecBenchConfig {
            n: 11,
            f: 2,
            dim: 96,
            noise: 0.3,
            steps: 3,
            target_loss: 1e-12, // unreachable in 3 steps: pins "never"
            seed: 1,
            wire_rounds: 2,
            gars: vec![GarKind::MultiKrum],
            attacks: vec![AttackKind::None],
            codecs: CodecKind::ALL.to_vec(),
        };
        let rows = run(&cfg, true).unwrap();
        assert_eq!(rows.len(), CodecKind::ALL.len());
        for r in &rows {
            assert!(r.bytes_per_round > 0, "{:?}", r.codec);
            assert!(r.final_loss.is_finite(), "{:?}", r.codec);
            assert_eq!(r.rounds_to_target, -1, "{:?}", r.codec);
            // Selection quality is reported for every codec, lossy ones
            // included (the bench resilience lens).
            assert!(r.selection_precision > 0.0, "{:?}", r.codec);
            assert!(r.selection_recall > 0.0, "{:?}", r.codec);
            match r.codec {
                // The identity codec's measured bytes are exactly raw.
                CodecKind::Raw => assert!((r.ratio_vs_raw - 1.0).abs() < 1e-9),
                // The acceptance bar: compressive codecs cut ≥ 3×.
                CodecKind::Int8 | CodecKind::TopK => assert!(
                    r.ratio_vs_raw >= MIN_COMPRESSIVE_RATIO,
                    "{:?}: ratio {:.2}",
                    r.codec,
                    r.ratio_vs_raw
                ),
                _ => {}
            }
        }
        let text = std::fs::read_to_string(dir.join("codec.csv")).unwrap();
        assert!(text.starts_with("codec,gar,attack,bytes_per_round"));
        assert_eq!(text.lines().count(), 1 + rows.len());
        std::fs::remove_dir_all(&dir).ok();
        std::env::remove_var("MB_RESULTS_DIR");
    }

    #[test]
    fn lossy_codecs_still_converge_without_attack() {
        // fp16/int8/topk on the plain quadratic problem: quantization
        // noise and error feedback must not stop convergence to a loose
        // target (the end-to-end fidelity claim behind the byte savings).
        let _env = crate::bench::env_lock();
        let dir = std::env::temp_dir().join("mb_codec_bench_converge_test");
        std::env::set_var("MB_RESULTS_DIR", &dir);
        let cfg = CodecBenchConfig {
            n: 11,
            f: 2,
            dim: 48,
            noise: 0.05,
            steps: 120,
            target_loss: 1e-2,
            seed: 1,
            wire_rounds: 1,
            gars: vec![GarKind::MultiBulyan],
            attacks: vec![AttackKind::None],
            codecs: CodecKind::LOSSY.to_vec(),
        };
        let rows = run(&cfg, true).unwrap();
        for r in &rows {
            assert!(
                r.rounds_to_target > 0,
                "{:?}: loss {} never reached {}",
                r.codec,
                r.final_loss,
                cfg.target_loss
            );
        }
        std::fs::remove_dir_all(&dir).ok();
        std::env::remove_var("MB_RESULTS_DIR");
    }
}
