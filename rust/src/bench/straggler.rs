//! Straggler-race bench — the paper's m/n headline in wall-clock form:
//! with a deterministic per-worker compute-cost model (a slow tail of
//! stragglers), how much round-tail latency does `collect = "first-m"`
//! shave off versus waiting for every worker — and how much straggler
//! compute does `overlap = "prefix"` salvage *during* the combine tail?
//!
//! Expected shape: under `all`, every round's tail is the stragglers'
//! cost (real sleeps on the threaded transport, virtual-time slices — and
//! their real sliced compute — on the pooled one). Under `first-m` the
//! round returns at the fastest `m = n − f` gradients, the stragglers are
//! abandoned mid-computation (their remaining work is never executed),
//! and the tail collapses to the fast tier's cost. With prefix overlap on
//! top, the combine+update pass interleaves with further drive slices, so
//! the abandoned stragglers keep computing while the aggregate is applied
//! — the salvaged virtual microseconds are the `overlap_saved_us` column
//! (measured from the coordinator's metrics counter, not asserted).
//! Collected/missing counts are deterministic on both transports whenever
//! the cost gap is decisive, which this bench's configuration makes sure
//! of.
//!
//! Writes `results/straggler.csv` (uploaded as a CI artifact) and, under
//! GitHub Actions, a markdown table into the job's step summary.

use crate::config::{ClusterConfig, ExperimentConfig, ModelConfig, TrainConfig};
use crate::coordinator::{launch, OverlapMode};
use crate::gar::GarKind;
use crate::metrics::Stopwatch;
use crate::transport::{CollectMode, TransportKind};
use crate::Result;
use std::fmt::Write as _;

/// One (collect mode, transport, overlap mode) measurement.
#[derive(Debug, Clone)]
pub struct StragglerRow {
    pub collect: CollectMode,
    pub transport: TransportKind,
    pub overlap: OverlapMode,
    pub n: usize,
    /// Gradients the mode waits for (n, or m = n − f under first-m).
    pub expect: usize,
    pub rounds: usize,
    /// Mean round wall time over the measured rounds, milliseconds.
    pub mean_round_ms: f64,
    /// Worst (tail) round wall time, milliseconds.
    pub max_round_ms: f64,
    /// Mean `RoundOutcome::collected` per round (deterministic: n under
    /// `all` with a generous timeout, m under `first-m`).
    pub mean_collected: f64,
    /// Mean `RoundOutcome::missing` per round (straggler-cache rounds).
    pub mean_missing: f64,
    /// Total virtual µs of straggler drive progress overlapped with the
    /// combine tail across the measured rounds (prefix overlap only).
    pub overlap_saved_us: u64,
}

#[derive(Debug, Clone)]
pub struct StragglerConfig {
    pub n: usize,
    pub f: usize,
    pub dim: usize,
    /// Measured rounds (one extra warm-up round is run and discarded).
    pub rounds: usize,
    /// Baseline simulated compute cost per round, µs.
    pub base_cost_us: u64,
    /// Slow-tail size (must stay ≤ f so first-m never needs a straggler).
    pub stragglers: usize,
    pub straggler_factor: f64,
    /// Round timeout, ms — generous, so `all` really waits for the tail.
    pub timeout_ms: u64,
    pub threads: usize,
    pub seed: u64,
}

impl Default for StragglerConfig {
    fn default() -> Self {
        Self {
            n: 48,
            f: 8,
            dim: 20_000,
            rounds: 20,
            base_cost_us: 1_000,
            stragglers: 4,
            straggler_factor: 16.0,
            timeout_ms: 1_000,
            threads: 4,
            seed: 1,
        }
    }
}

/// Overlap modes exercised per transport: the prefix path is the pooled
/// time-sliced drive's feature (threaded and socket fall back to off, so
/// a second row there would duplicate the first).
fn overlap_modes(transport: TransportKind) -> &'static [OverlapMode] {
    match transport {
        TransportKind::Threaded | TransportKind::Socket => &[OverlapMode::Off],
        TransportKind::Pooled => &[OverlapMode::Off, OverlapMode::Prefix],
    }
}

pub fn run(cfg: &StragglerConfig, quiet: bool) -> Result<Vec<StragglerRow>> {
    anyhow::ensure!(
        cfg.stragglers <= cfg.f,
        "straggler bench: stragglers ({}) must be ≤ f ({}) so first-m \
         can always fill its quorum from the fast tier",
        cfg.stragglers,
        cfg.f
    );
    let mut rows = Vec::new();
    for transport in TransportKind::ALL {
        for collect in CollectMode::ALL {
            for &overlap in overlap_modes(transport) {
                let exp = ExperimentConfig {
                    cluster: ClusterConfig {
                        n: cfg.n,
                        f: cfg.f,
                        actual_byzantine: Some(0),
                        round_timeout_ms: cfg.timeout_ms,
                        compute_cost_us: cfg.base_cost_us,
                        stragglers: cfg.stragglers,
                        straggler_factor: cfg.straggler_factor,
                        ..Default::default()
                    },
                    gar: GarKind::MultiKrum,
                    pre: Vec::new(),
                    attack: crate::attacks::AttackKind::None,
                    model: ModelConfig::Quadratic {
                        dim: cfg.dim,
                        noise: 0.5,
                    },
                    train: TrainConfig {
                        learning_rate: 0.1,
                        momentum: 0.0,
                        steps: cfg.rounds + 1,
                        batch_size: 8,
                        eval_every: 0,
                        seed: cfg.seed,
                    },
                    threads: cfg.threads,
                    transport,
                    collect,
                    overlap,
                    overlap_window: 1,
                    codec: None,
                    groups: 1,
                    output_dir: None,
                    journal: None,
                    crash_after_round: None,
                };
                let expect = match collect {
                    CollectMode::All => cfg.n,
                    CollectMode::FirstM => cfg.n - cfg.f,
                };
                let cluster = launch(&exp, None)?;
                let mut coordinator = cluster.coordinator;
                // Warm-up round outside the measurement: it grows the
                // gradient arenas and populates the straggler cache.
                let view = coordinator.next_view();
                coordinator.run_round(&view)?;
                let saved_warmup = coordinator.metrics.counter("overlap_saved_us");
                let mut total_ms = 0.0f64;
                let mut max_ms = 0.0f64;
                let mut collected = 0u64;
                let mut missing = 0u64;
                for _ in 0..cfg.rounds {
                    let sw = Stopwatch::start();
                    let view = coordinator.next_view();
                    let out = coordinator.run_round(&view)?;
                    let ms = sw.elapsed_ms();
                    total_ms += ms;
                    max_ms = max_ms.max(ms);
                    collected += out.collected as u64;
                    missing += out.missing as u64;
                }
                let overlap_saved_us =
                    coordinator.metrics.counter("overlap_saved_us") - saved_warmup;
                coordinator.shutdown();
                let row = StragglerRow {
                    collect,
                    transport,
                    overlap,
                    n: cfg.n,
                    expect,
                    rounds: cfg.rounds,
                    mean_round_ms: total_ms / cfg.rounds as f64,
                    max_round_ms: max_ms,
                    mean_collected: collected as f64 / cfg.rounds as f64,
                    mean_missing: missing as f64 / cfg.rounds as f64,
                    overlap_saved_us,
                };
                if !quiet {
                    println!(
                        "straggler {:<9} {:<8} {:<7} n={:<4} expect={:<4} mean {:>9.3} ms   \
                         tail {:>9.3} ms   collected {:>6.1}   missing {:>5.1}   \
                         overlap_saved {:>8} µs",
                        row.collect,
                        row.transport,
                        row.overlap,
                        row.n,
                        row.expect,
                        row.mean_round_ms,
                        row.max_round_ms,
                        row.mean_collected,
                        row.mean_missing,
                        row.overlap_saved_us
                    );
                }
                rows.push(row);
            }
        }
    }
    let csv: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "{},{},{},{},{},{},{:.4},{:.4},{:.2},{:.2},{}",
                r.collect,
                r.transport,
                r.overlap,
                r.n,
                r.expect,
                r.rounds,
                r.mean_round_ms,
                r.max_round_ms,
                r.mean_collected,
                r.mean_missing,
                r.overlap_saved_us
            )
        })
        .collect();
    super::write_csv(
        "straggler.csv",
        "collect,transport,overlap,n,expect,rounds,mean_round_ms,max_round_ms,\
         mean_collected,mean_missing,overlap_saved_us",
        &csv,
    )?;
    super::step_summary(&summary_markdown(&rows));
    Ok(rows)
}

/// The straggler rows as a GitHub step-summary markdown table.
fn summary_markdown(rows: &[StragglerRow]) -> String {
    let mut md = String::from(
        "## bench straggler — first-m vs wait-all round tail\n\n\
         | collect | transport | overlap | expect | mean ms | tail ms | \
         collected | missing | overlap saved µs |\n\
         |---|---|---|---:|---:|---:|---:|---:|---:|\n",
    );
    for r in rows {
        let _ = writeln!(
            md,
            "| {} | {} | {} | {} | {:.3} | {:.3} | {:.1} | {:.1} | {} |",
            r.collect,
            r.transport,
            r.overlap,
            r.expect,
            r.mean_round_ms,
            r.max_round_ms,
            r.mean_collected,
            r.mean_missing,
            r.overlap_saved_us
        );
    }
    md
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn straggler_bench_counts_are_deterministic() {
        let _env = crate::bench::env_lock();
        let dir = std::env::temp_dir().join("mb_straggler_bench_test");
        std::env::set_var("MB_RESULTS_DIR", &dir);
        // Keep this run's markdown table out of any real CI step summary
        // (the verify job runs `cargo test` with the variable set).
        let prev_summary = std::env::var_os("GITHUB_STEP_SUMMARY");
        std::fs::create_dir_all(&dir).ok();
        std::env::set_var("GITHUB_STEP_SUMMARY", dir.join("summary.md"));
        let cfg = StragglerConfig {
            n: 12,
            f: 3,
            dim: 4_000,
            rounds: 3,
            base_cost_us: 400,
            stragglers: 2,
            straggler_factor: 10.0,
            timeout_ms: 1_000,
            threads: 2,
            seed: 1,
        };
        let rows = run(&cfg, true).unwrap();
        // threaded × 2 collect modes × off + pooled × 2 × (off|prefix).
        assert_eq!(rows.len(), 6);
        for r in &rows {
            assert!(r.mean_round_ms >= 0.0 && r.max_round_ms >= r.mean_round_ms / 2.0);
            match r.collect {
                // Generous timeout: wait-all really gets everyone.
                CollectMode::All => {
                    assert_eq!(r.expect, 12);
                    assert_eq!(r.mean_collected, 12.0, "{} {}", r.collect, r.transport);
                    assert_eq!(r.mean_missing, 0.0);
                }
                // First-m leaves exactly the straggler-free quorum... the
                // two stragglers lose the race on both transports.
                CollectMode::FirstM => {
                    assert_eq!(r.expect, 9);
                    assert_eq!(r.mean_collected, 9.0, "{} {}", r.collect, r.transport);
                    assert_eq!(r.mean_missing, 3.0);
                }
            }
            if r.overlap == OverlapMode::Off {
                assert_eq!(r.overlap_saved_us, 0, "{} {}", r.collect, r.transport);
            }
        }
        // The headline claim: prefix overlap on the straggler scenario
        // reports a nonzero overlap_saved_us (drive progress made while
        // the combine tail ran).
        let prefix_first_m = rows
            .iter()
            .find(|r| {
                r.transport == TransportKind::Pooled
                    && r.collect == CollectMode::FirstM
                    && r.overlap == OverlapMode::Prefix
            })
            .expect("pooled first-m prefix row");
        assert!(
            prefix_first_m.overlap_saved_us > 0,
            "prefix overlap must salvage straggler compute on the straggler scenario"
        );
        assert!(dir.join("straggler.csv").exists());
        // The summary table was written to the redirected file.
        let summary = std::fs::read_to_string(dir.join("summary.md")).unwrap();
        assert!(summary.contains("bench straggler"));
        assert!(summary.contains("overlap saved µs"));
        std::fs::remove_dir_all(&dir).ok();
        std::env::remove_var("MB_RESULTS_DIR");
        match prev_summary {
            Some(v) => std::env::set_var("GITHUB_STEP_SUMMARY", v),
            None => std::env::remove_var("GITHUB_STEP_SUMMARY"),
        }
    }
}
