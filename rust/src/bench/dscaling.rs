//! O(d) scaling check — Theorem 2.ii ("MULTI-BULYAN's cost in local
//! computation is O(d), like averaging").
//!
//! Fixed n, sweep d over decades, fit the log–log slope of aggregation
//! time vs d. A slope ≈ 1.0 is linear; robust alternatives from classical
//! statistics (PCA-based, §I footnote 2) would show ≥ 2.
//!
//! Two harnesses share the fit:
//!
//! * [`run`] (`bench dscaling`) times the bare GAR hot path over a
//!   pre-materialized n×d matrix — the Theorem 2.ii microbench.
//! * [`run_dscale`] (`bench dscale`) times whole end-to-end rounds
//!   through the two-level grouped coordinator (workers → streaming
//!   group reduction → root GAR → parameter update) and *gates* the
//!   fitted slope on linearity. This is the CI probe that the
//!   hierarchical collection path stays O(d) all the way to d = 10⁷ —
//!   a superlinear slope (an accidental n×d materialization, a
//!   quadratic reassembly path) fails the bench, not just a dashboard.

use crate::gar::{GarKind, GarScratch};
use crate::metrics::{Stopwatch, TimingProtocol};
use crate::tensor::GradMatrix;
use crate::Result;
use crate::util::Rng64;

#[derive(Debug, Clone)]
pub struct ScalingPoint {
    pub gar: GarKind,
    pub d: usize,
    pub mean_ms: f64,
}

#[derive(Debug, Clone)]
pub struct ScalingResult {
    pub gar: GarKind,
    pub points: Vec<ScalingPoint>,
    /// Log–log slope of time vs d.
    pub slope: f64,
}

/// Least-squares slope of ln(time) vs ln(d).
pub fn loglog_slope(points: &[(f64, f64)]) -> f64 {
    let n = points.len() as f64;
    let (mut sx, mut sy, mut sxx, mut sxy) = (0.0, 0.0, 0.0, 0.0);
    for &(x, y) in points {
        let (lx, ly) = (x.ln(), y.ln());
        sx += lx;
        sy += ly;
        sxx += lx * lx;
        sxy += lx * ly;
    }
    (n * sxy - sx * sy) / (n * sxx - sx * sx)
}

pub fn run(n: usize, dims: &[usize], gars: &[GarKind], quiet: bool) -> Result<Vec<ScalingResult>> {
    let f = super::fig2_f(n);
    let protocol = TimingProtocol::default();
    let mut results = Vec::new();
    for &kind in gars {
        anyhow::ensure!(n >= kind.min_n(f), "{kind}: n={n} too small for f={f}");
        let gar = kind.instantiate(n, f)?;
        let mut points = Vec::new();
        for &d in dims {
            let mut rng = Rng64::seed_from_u64(99 ^ d as u64);
            let grads = GradMatrix::uniform(n, d, 0.0, 1.0, &mut rng);
            let mut out = vec![0.0f32; d];
            let mut scratch = GarScratch::new();
            let (mean_ms, _) = protocol.measure(|| {
                gar.aggregate_with_scratch(&grads, &mut out, &mut scratch)
                    .unwrap();
            });
            points.push(ScalingPoint {
                gar: kind,
                d,
                mean_ms,
            });
            if !quiet {
                println!("dscaling gar={kind:<13} d={d:<9} {mean_ms:.3} ms");
            }
        }
        let slope = loglog_slope(
            &points
                .iter()
                .map(|p| (p.d as f64, p.mean_ms.max(1e-6)))
                .collect::<Vec<_>>(),
        );
        if !quiet {
            println!("dscaling gar={kind:<13} log-log slope = {slope:.3} (1.0 = linear in d)\n");
        }
        results.push(ScalingResult {
            gar: kind,
            points,
            slope,
        });
    }
    let rows: Vec<String> = results
        .iter()
        .flat_map(|r| {
            r.points
                .iter()
                .map(move |p| format!("{},{},{:.6},{:.4}", r.gar, p.d, p.mean_ms, r.slope))
        })
        .collect();
    super::write_csv("dscaling.csv", "gar,d,mean_ms,slope", &rows)?;
    Ok(results)
}

/// `bench dscale` — the end-to-end grouped-collection sweep.
#[derive(Debug, Clone)]
pub struct DscaleConfig {
    /// Cluster size (small on purpose: the sweep measures per-coordinate
    /// cost, not fan-out; n=9 keeps even the d=10⁷ point DRAM-resident).
    pub n: usize,
    /// Declared Byzantine bound (no workers actually attack here).
    pub f: usize,
    /// Two-level group count (> 1 so the streamed hierarchy is the path
    /// under test).
    pub groups: usize,
    /// Dimensions swept, ascending.
    pub dims: Vec<usize>,
    /// Untimed warm-up rounds per point (allocator + problem setup).
    pub warmup: usize,
    /// Timed rounds per point.
    pub rounds: usize,
    /// Accepted fitted log-log slope band; outside it the bench exits
    /// nonzero (1.0 = exactly linear in d).
    pub slope_min: f64,
    pub slope_max: f64,
}

impl DscaleConfig {
    /// CI grid: d to 3·10⁶ in one decade-and-a-half, one timed round per
    /// point.
    pub fn default_sweep() -> Self {
        Self {
            n: 9,
            f: 1,
            groups: 3,
            dims: vec![100_000, 300_000, 1_000_000, 3_000_000],
            warmup: 1,
            rounds: 1,
            slope_min: 0.7,
            slope_max: 1.35,
        }
    }

    /// `--full`: extend the sweep to the paper-scale d = 10⁷ point.
    pub fn full_sweep() -> Self {
        let mut cfg = Self::default_sweep();
        cfg.dims.push(10_000_000);
        cfg
    }
}

/// One `bench dscale` measurement.
#[derive(Debug, Clone)]
pub struct DscalePoint {
    pub d: usize,
    /// Mean wall-clock per full round (broadcast → streamed group
    /// reduction → root GAR → update), ms.
    pub round_ms: f64,
    /// High-water resident floats inside the group reducer for this run
    /// (the `group_reducer_peak_floats` counter) — the streamed-memory
    /// half of the story: it grows O(groups·d + n·block), never n×d.
    pub peak_floats: u64,
}

/// `bench dscale` result: the sweep plus the fitted log-log slope.
#[derive(Debug, Clone)]
pub struct DscaleResult {
    pub points: Vec<DscalePoint>,
    pub slope: f64,
}

/// Run the end-to-end grouped d-sweep and gate the slope on linearity.
///
/// Each point launches a fresh grouped cluster (trimmed-mean over
/// `cfg.groups` group rows, quadratic workload of dimension d on the
/// pooled transport), runs `warmup` untimed and `rounds` timed rounds,
/// and records mean ms/round. Writes `results/dscale.csv`, appends a
/// step-summary table in CI, and bails if the fitted slope leaves
/// `[slope_min, slope_max]`.
pub fn run_dscale(cfg: &DscaleConfig, quiet: bool) -> Result<DscaleResult> {
    use crate::config::{ClusterConfig, ExperimentConfig, ModelConfig, TrainConfig};
    anyhow::ensure!(cfg.dims.len() >= 2, "dscale needs ≥ 2 dims to fit a slope");
    anyhow::ensure!(cfg.rounds >= 1, "dscale needs ≥ 1 timed round per point");
    let mut points = Vec::new();
    for &d in &cfg.dims {
        let exp = ExperimentConfig {
            cluster: ClusterConfig {
                n: cfg.n,
                f: cfg.f,
                actual_byzantine: Some(0),
                ..Default::default()
            },
            gar: GarKind::TrimmedMean,
            pre: Vec::new(),
            attack: crate::attacks::AttackKind::None,
            model: ModelConfig::Quadratic { dim: d, noise: 0.1 },
            train: TrainConfig {
                steps: cfg.warmup + cfg.rounds,
                batch_size: 5,
                eval_every: 0,
                ..TrainConfig::default()
            },
            threads: 1,
            transport: Default::default(),
            collect: Default::default(),
            overlap: Default::default(),
            overlap_window: 1,
            codec: None,
            groups: cfg.groups,
            output_dir: None,
            journal: None,
            crash_after_round: None,
        };
        let cluster = crate::coordinator::launch(&exp, None)?;
        let mut coordinator = cluster.coordinator;
        for _ in 0..cfg.warmup {
            let view = coordinator.next_view();
            coordinator.run_round(&view)?;
        }
        let sw = Stopwatch::start();
        for _ in 0..cfg.rounds {
            let view = coordinator.next_view();
            coordinator.run_round(&view)?;
        }
        let round_ms = sw.elapsed_ms() / cfg.rounds as f64;
        let peak_floats = coordinator.metrics.counter("group_reducer_peak_floats");
        coordinator.shutdown();
        if !quiet {
            println!(
                "dscale d={d:<9} round {round_ms:10.3} ms  reducer peak {peak_floats} floats"
            );
        }
        points.push(DscalePoint {
            d,
            round_ms,
            peak_floats,
        });
    }
    let slope = loglog_slope(
        &points
            .iter()
            .map(|p| (p.d as f64, p.round_ms.max(1e-6)))
            .collect::<Vec<_>>(),
    );
    let ok = slope >= cfg.slope_min && slope <= cfg.slope_max;
    if !quiet {
        println!(
            "dscale log-log slope = {slope:.3} (gate [{:.2}, {:.2}]) — {}",
            cfg.slope_min,
            cfg.slope_max,
            if ok { "linear in d" } else { "VIOLATION" }
        );
    }
    let rows: Vec<String> = points
        .iter()
        .map(|p| format!("{},{:.6},{},{:.4}", p.d, p.round_ms, p.peak_floats, slope))
        .collect();
    super::write_csv("dscale.csv", "d,round_ms,peak_floats,slope", &rows)?;
    let mut md = String::from(
        "## bench dscale — grouped end-to-end O(d) gate\n\n\
         | d | round ms | reducer peak floats |\n|---:|---:|---:|\n",
    );
    for p in &points {
        md.push_str(&format!(
            "| {} | {:.3} | {} |\n",
            p.d, p.round_ms, p.peak_floats
        ));
    }
    md.push_str(&format!(
        "\nfitted log-log slope **{slope:.3}** (gate [{:.2}, {:.2}]): {}\n",
        cfg.slope_min,
        cfg.slope_max,
        if ok { "✅ linear" } else { "❌ violation" }
    ));
    super::step_summary(&md);
    anyhow::ensure!(
        ok,
        "dscale: fitted log-log slope {slope:.3} outside the linear band \
         [{:.2}, {:.2}] — the grouped collection path is no longer O(d)",
        cfg.slope_min,
        cfg.slope_max
    );
    Ok(DscaleResult { points, slope })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slope_of_exact_linear_data_is_one() {
        let pts: Vec<(f64, f64)> = (1..6).map(|k| (10f64.powi(k), 3.0 * 10f64.powi(k))).collect();
        assert!((loglog_slope(&pts) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn slope_of_quadratic_data_is_two() {
        let pts: Vec<(f64, f64)> = (1..6)
            .map(|k| {
                let d = 10f64.powi(k);
                (d, d * d)
            })
            .collect();
        assert!((loglog_slope(&pts) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn dscale_harness_runs_grouped_end_to_end() {
        let _env = crate::bench::env_lock();
        std::env::set_var("MB_RESULTS_DIR", std::env::temp_dir().join("mb_dscale_test"));
        // Tiny dims keep the test fast; the slope band is opened wide
        // because timer noise dominates at this scale — the real gate
        // runs at bench scale in CI. What this pins is the plumbing:
        // grouped launch, streamed rounds, peak accounting, CSV.
        let cfg = DscaleConfig {
            dims: vec![5_000, 50_000, 500_000],
            slope_min: -1.0,
            slope_max: 5.0,
            ..DscaleConfig::default_sweep()
        };
        let res = run_dscale(&cfg, true).unwrap();
        assert_eq!(res.points.len(), 3);
        for p in &res.points {
            // The reducer really ran (nonzero high-water mark) and never
            // came close to materializing the flat n×d matrix.
            assert!(p.peak_floats > 0);
            assert!(
                (p.peak_floats as usize) < cfg.n * p.d,
                "peak {} floats vs flat n·d = {}",
                p.peak_floats,
                cfg.n * p.d
            );
        }
        let csv =
            std::fs::read_to_string(crate::bench::results_dir().join("dscale.csv")).unwrap();
        assert!(csv.starts_with("d,round_ms,peak_floats,slope"));
        assert_eq!(csv.lines().count(), 4);
        std::fs::remove_dir_all(crate::bench::results_dir()).ok();
        std::env::remove_var("MB_RESULTS_DIR");
    }

    #[test]
    fn multibulyan_measures_linear_in_d() {
        // Small but decade-spanning grid; slope should be ≈ 1, certainly
        // far from 2. Generous tolerance to absorb timer noise at small d.
        let _env = crate::bench::env_lock();
        std::env::set_var(
            "MB_RESULTS_DIR",
            std::env::temp_dir().join("mb_dscaling_test"),
        );
        let res = run(
            11,
            &[20_000, 200_000, 2_000_000],
            &[GarKind::MultiBulyan],
            true,
        )
        .unwrap();
        let slope = res[0].slope;
        assert!(slope > 0.6 && slope < 1.5, "slope {slope}");
        std::fs::remove_dir_all(super::super::results_dir()).ok();
        std::env::remove_var("MB_RESULTS_DIR");
    }
}
