//! O(d) scaling check — Theorem 2.ii ("MULTI-BULYAN's cost in local
//! computation is O(d), like averaging").
//!
//! Fixed n, sweep d over decades, fit the log–log slope of aggregation
//! time vs d. A slope ≈ 1.0 is linear; robust alternatives from classical
//! statistics (PCA-based, §I footnote 2) would show ≥ 2.

use crate::gar::{GarKind, GarScratch};
use crate::metrics::TimingProtocol;
use crate::tensor::GradMatrix;
use crate::Result;
use crate::util::Rng64;

#[derive(Debug, Clone)]
pub struct ScalingPoint {
    pub gar: GarKind,
    pub d: usize,
    pub mean_ms: f64,
}

#[derive(Debug, Clone)]
pub struct ScalingResult {
    pub gar: GarKind,
    pub points: Vec<ScalingPoint>,
    /// Log–log slope of time vs d.
    pub slope: f64,
}

/// Least-squares slope of ln(time) vs ln(d).
pub fn loglog_slope(points: &[(f64, f64)]) -> f64 {
    let n = points.len() as f64;
    let (mut sx, mut sy, mut sxx, mut sxy) = (0.0, 0.0, 0.0, 0.0);
    for &(x, y) in points {
        let (lx, ly) = (x.ln(), y.ln());
        sx += lx;
        sy += ly;
        sxx += lx * lx;
        sxy += lx * ly;
    }
    (n * sxy - sx * sy) / (n * sxx - sx * sx)
}

pub fn run(n: usize, dims: &[usize], gars: &[GarKind], quiet: bool) -> Result<Vec<ScalingResult>> {
    let f = super::fig2_f(n);
    let protocol = TimingProtocol::default();
    let mut results = Vec::new();
    for &kind in gars {
        anyhow::ensure!(n >= kind.min_n(f), "{kind}: n={n} too small for f={f}");
        let gar = kind.instantiate(n, f)?;
        let mut points = Vec::new();
        for &d in dims {
            let mut rng = Rng64::seed_from_u64(99 ^ d as u64);
            let grads = GradMatrix::uniform(n, d, 0.0, 1.0, &mut rng);
            let mut out = vec![0.0f32; d];
            let mut scratch = GarScratch::new();
            let (mean_ms, _) = protocol.measure(|| {
                gar.aggregate_with_scratch(&grads, &mut out, &mut scratch)
                    .unwrap();
            });
            points.push(ScalingPoint {
                gar: kind,
                d,
                mean_ms,
            });
            if !quiet {
                println!("dscaling gar={kind:<13} d={d:<9} {mean_ms:.3} ms");
            }
        }
        let slope = loglog_slope(
            &points
                .iter()
                .map(|p| (p.d as f64, p.mean_ms.max(1e-6)))
                .collect::<Vec<_>>(),
        );
        if !quiet {
            println!("dscaling gar={kind:<13} log-log slope = {slope:.3} (1.0 = linear in d)\n");
        }
        results.push(ScalingResult {
            gar: kind,
            points,
            slope,
        });
    }
    let rows: Vec<String> = results
        .iter()
        .flat_map(|r| {
            r.points
                .iter()
                .map(move |p| format!("{},{},{:.6},{:.4}", r.gar, p.d, p.mean_ms, r.slope))
        })
        .collect();
    super::write_csv("dscaling.csv", "gar,d,mean_ms,slope", &rows)?;
    Ok(results)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slope_of_exact_linear_data_is_one() {
        let pts: Vec<(f64, f64)> = (1..6).map(|k| (10f64.powi(k), 3.0 * 10f64.powi(k))).collect();
        assert!((loglog_slope(&pts) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn slope_of_quadratic_data_is_two() {
        let pts: Vec<(f64, f64)> = (1..6)
            .map(|k| {
                let d = 10f64.powi(k);
                (d, d * d)
            })
            .collect();
        assert!((loglog_slope(&pts) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn multibulyan_measures_linear_in_d() {
        // Small but decade-spanning grid; slope should be ≈ 1, certainly
        // far from 2. Generous tolerance to absorb timer noise at small d.
        let _env = crate::bench::env_lock();
        std::env::set_var(
            "MB_RESULTS_DIR",
            std::env::temp_dir().join("mb_dscaling_test"),
        );
        let res = run(
            11,
            &[20_000, 200_000, 2_000_000],
            &[GarKind::MultiBulyan],
            true,
        )
        .unwrap();
        let slope = res[0].slope;
        assert!(slope > 0.6 && slope < 1.5, "slope {slope}");
        std::fs::remove_dir_all(super::super::results_dir()).ok();
        std::env::remove_var("MB_RESULTS_DIR");
    }
}
