//! Fig. 3 — maximum top-1 cross-accuracy per GAR and batch size.
//!
//! Paper protocol (§V-A): n = 11 workers, f = 2, **no attack**; conv model
//! on Fashion-MNIST; 3000 steps, lr 0.1, momentum 0.9; batch sizes
//! b ∈ {5, 10, …, 50}; 5 seeded repetitions; metric = max top-1 accuracy
//! over the run. GARs: averaging, MEDIAN, MULTI-KRUM, MULTI-BULYAN.
//!
//! The expected shape (the paper's headline for this figure): MEDIAN —
//! which keeps the informational equivalent of a single gradient — loses
//! tangible accuracy vs. averaging, while MULTI-KRUM and MULTI-BULYAN sit
//! at ≈ averaging. Defaults are CPU-scaled (fewer batch sizes/seeds/steps,
//! reduced-width model); `--full` restores the paper's grid.

use crate::config::{ExperimentConfig, ModelConfig};
use crate::coordinator::launch;
use crate::gar::GarKind;
use crate::runtime::{ComputeHandle, Manifest};
use crate::Result;

/// One cell of the Fig. 3 sweep.
#[derive(Debug, Clone)]
pub struct Cell {
    pub gar: GarKind,
    pub batch_size: usize,
    pub seed: u64,
    pub max_accuracy: f32,
    pub final_loss: f32,
}

#[derive(Debug, Clone)]
pub struct Fig3Config {
    pub model: String,
    pub n: usize,
    pub f: usize,
    pub gars: Vec<GarKind>,
    pub batch_sizes: Vec<usize>,
    pub seeds: Vec<u64>,
    pub steps: usize,
    pub eval_every: usize,
}

impl Fig3Config {
    /// CPU-scaled default (see DESIGN.md §Substitutions).
    pub fn default_sweep() -> Self {
        Self {
            model: "mlp".into(),
            n: 11,
            f: 2,
            gars: vec![
                GarKind::Average,
                GarKind::Median,
                GarKind::MultiKrum,
                GarKind::MultiBulyan,
            ],
            batch_sizes: vec![5, 25, 50],
            seeds: vec![1],
            steps: 150,
            eval_every: 25,
        }
    }

    /// The paper's protocol (hours of CPU runtime).
    pub fn full_sweep() -> Self {
        Self {
            model: "cnn".into(),
            batch_sizes: (1..=10).map(|k| 5 * k).collect(),
            seeds: (1..=5).collect(),
            steps: 3000,
            eval_every: 100,
            ..Self::default_sweep()
        }
    }
}

/// Run the sweep. Requires artifacts (`make artifacts`).
pub fn run(
    cfg: &Fig3Config,
    handle: ComputeHandle,
    manifest: &Manifest,
    quiet: bool,
) -> Result<Vec<Cell>> {
    // Check the requested batch sizes exist before burning time.
    let model = manifest.model(&cfg.model)?;
    let available = model.batch_sizes();
    for &b in &cfg.batch_sizes {
        anyhow::ensure!(
            available.contains(&b),
            "model '{}' has no b={b} gradient artifact (available {available:?}); \
             re-run `make artifacts`",
            cfg.model
        );
    }

    let mut cells = Vec::new();
    for &gar in &cfg.gars {
        for &b in &cfg.batch_sizes {
            for &seed in &cfg.seeds {
                let mut exp = ExperimentConfig::fig3_default(gar);
                exp.cluster.n = cfg.n;
                exp.cluster.f = if gar == GarKind::Average { 0 } else { cfg.f };
                exp.cluster.actual_byzantine = Some(0);
                exp.model = ModelConfig::Artifact {
                    name: cfg.model.clone(),
                    dir: manifest.dir.to_string_lossy().into_owned(),
                };
                exp.train.batch_size = b;
                exp.train.steps = cfg.steps;
                exp.train.eval_every = cfg.eval_every;
                exp.train.seed = seed;

                let mut cluster = launch(&exp, Some((handle.clone(), manifest.clone())))?;
                let mut evaluator = cluster.evaluator;
                cluster
                    .coordinator
                    .train(cfg.steps, cfg.eval_every, &mut evaluator)
                    ?;
                let max_accuracy = cluster.coordinator.metrics.max_accuracy();
                let final_loss = cluster.coordinator.metrics.final_loss().unwrap_or(f32::NAN);
                cluster.coordinator.shutdown();
                if !quiet {
                    println!(
                        "fig3 gar={gar:<13} b={b:<3} seed={seed} max_acc={max_accuracy:.4} final_loss={final_loss:.4}"
                    );
                }
                cells.push(Cell {
                    gar,
                    batch_size: b,
                    seed,
                    max_accuracy,
                    final_loss,
                });
            }
        }
    }

    let rows: Vec<String> = cells
        .iter()
        .map(|c| {
            format!(
                "{},{},{},{:.6},{:.6}",
                c.gar, c.batch_size, c.seed, c.max_accuracy, c.final_loss
            )
        })
        .collect();
    let path = super::write_csv("fig3.csv", "gar,batch_size,seed,max_accuracy,final_loss", &rows)?;
    if !quiet {
        println!("\nwrote {path:?}");
        print_summary(&cells);
    }
    Ok(cells)
}

/// Mean max-accuracy per (gar, batch size) — the Fig. 3 series.
pub fn print_summary(cells: &[Cell]) {
    use std::collections::BTreeMap;
    let mut by_key: BTreeMap<(String, usize), Vec<f32>> = BTreeMap::new();
    for c in cells {
        by_key
            .entry((c.gar.to_string(), c.batch_size))
            .or_default()
            .push(c.max_accuracy);
    }
    println!("\n{:<14} {:>5} {:>10} {:>8}", "gar", "b", "mean_acc", "std");
    for ((gar, b), accs) in by_key {
        println!(
            "{:<14} {:>5} {:>10.4} {:>8.4}",
            gar,
            b,
            crate::tensor::mean(&accs),
            crate::tensor::std_dev(&accs)
        );
    }
}
