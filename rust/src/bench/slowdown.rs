//! Slowdown ablation — Theorem 1.ii / Theorem 2.iii: in the Byzantine-free
//! case, MULTI-KRUM with parameter m behaves like averaging over m
//! workers, i.e. an m̃/n slowdown at m = m̃ vs averaging's n.
//!
//! Measurement: on the quadratic workload with fixed lr, SGD converges to
//! a noise plateau whose height is proportional to the variance of the
//! aggregated gradient — i.e. ∝ 1/m for an m-average. We therefore report
//!
//!   `slowdown ≈ plateau(average) / plateau(rule)`  (∈ (0, 1])
//!
//! which equals m̃/n for averaging-of-m̃ rules: the paper's "steps
//! averaging needs / steps the rule needs" expressed at the stationary
//! point (both views measure the same variance-reduction factor).
//! Expected: multi-krum(m) ≈ m/n, MULTI-BULYAN ≈ m̃/n, KRUM ≈ 1/n. The
//! coordinate-wise MEDIAN of k Gaussians has asymptotic efficiency 2/π
//! (classical statistics), so its measured slowdown sits near 0.64 on
//! this isotropic workload — its accuracy cost on the real task is what
//! Fig. 3 shows (see bench fig3).

use crate::config::{ClusterConfig, ExperimentConfig, ModelConfig, TrainConfig};
use crate::coordinator::launch;
use crate::gar::{Gar, GarKind, MultiKrum};
use crate::Result;

/// One rule's plateau measurement.
#[derive(Debug, Clone)]
pub struct SlowdownRow {
    pub label: String,
    /// Gradients effectively used (m̃ of the theory).
    pub gradients_used: usize,
    /// Mean loss over the plateau window.
    pub plateau: f64,
    /// plateau(average)/plateau(rule) — the measured slowdown factor.
    pub slowdown_vs_average: Option<f64>,
    /// Theoretical prediction m̃/n.
    pub predicted: f64,
}

#[derive(Debug, Clone)]
pub struct SlowdownConfig {
    pub n: usize,
    pub f: usize,
    pub dim: usize,
    pub noise: f32,
    pub batch_size: usize,
    /// Steps before the plateau window starts (burn-in).
    pub burn_in: usize,
    /// Plateau window length (losses averaged over it).
    pub window: usize,
    pub seed: u64,
}

impl Default for SlowdownConfig {
    fn default() -> Self {
        Self {
            n: 11,
            f: 2,
            dim: 256,
            noise: 2.0,
            batch_size: 1,
            burn_in: 400,
            window: 400,
            seed: 1,
        }
    }
}

/// Plateau loss for a boxed rule on the quadratic task.
fn plateau_loss(cfg: &SlowdownConfig, gar: Box<dyn Gar>) -> Result<f64> {
    let exp = ExperimentConfig {
        cluster: ClusterConfig {
            n: cfg.n,
            f: cfg.f,
            actual_byzantine: Some(0),
            net_delay_us: 0,
            drop_prob: 0.0,
            round_timeout_ms: 60_000,
            ..Default::default()
        },
        gar: GarKind::Average, // placeholder; instance swapped below
        pre: Vec::new(),
        attack: crate::attacks::AttackKind::None,
        model: ModelConfig::Quadratic {
            dim: cfg.dim,
            noise: cfg.noise,
        },
        train: TrainConfig {
            learning_rate: 0.05,
            momentum: 0.0,
            steps: cfg.burn_in + cfg.window,
            batch_size: cfg.batch_size,
            eval_every: 0,
            seed: cfg.seed,
        },
        threads: 1,
        transport: Default::default(),
        collect: Default::default(),
        overlap: Default::default(),
        overlap_window: 1,
        codec: None,
        groups: 1,
        output_dir: None,
        journal: None,
        crash_after_round: None,
    };
    let cluster = launch(&exp, None)?;
    let mut coordinator = cluster.coordinator.with_gar(gar)?;
    let mut evaluator = cluster.evaluator;
    for _ in 0..cfg.burn_in {
        let view = coordinator.next_view();
        coordinator.run_round(&view)?;
    }
    let mut acc = 0.0f64;
    for _ in 0..cfg.window {
        let view = coordinator.next_view();
        coordinator.run_round(&view)?;
        let (loss, _) = evaluator.evaluate(coordinator.params())?;
        acc += loss as f64;
    }
    coordinator.shutdown();
    Ok(acc / cfg.window as f64)
}

/// Run the sweep: averaging, m-Krum for several m, MULTI-BULYAN, KRUM,
/// MEDIAN.
pub fn run(cfg: &SlowdownConfig, quiet: bool) -> Result<Vec<SlowdownRow>> {
    let (n, f) = (cfg.n, cfg.f);
    let m_tilde = n - f - 2;
    let mut cases: Vec<(String, Box<dyn Gar>, usize)> = vec![(
        "average".into(),
        GarKind::Average.instantiate(n, 0)?,
        n,
    )];
    for m in [1, m_tilde / 2, m_tilde] {
        let m = m.max(1);
        let gar = MultiKrum::with_m(n, f, m)?;
        cases.push((format!("multi-krum(m={m})"), Box::new(gar), m));
    }
    cases.push((
        "multi-bulyan".into(),
        GarKind::MultiBulyan.instantiate(n, f)?,
        n - 2 * f - 2,
    ));
    cases.push(("krum".into(), GarKind::Krum.instantiate(n, f)?, 1));
    cases.push(("median".into(), GarKind::Median.instantiate(n, f)?, 1));

    let mut rows = Vec::new();
    let mut avg_plateau: Option<f64> = None;
    for (label, gar, used) in cases {
        let plateau = plateau_loss(cfg, gar)?;
        if label == "average" {
            avg_plateau = Some(plateau);
        }
        let slowdown = avg_plateau.map(|a| a / plateau);
        let row = SlowdownRow {
            label: label.clone(),
            gradients_used: used,
            plateau,
            slowdown_vs_average: slowdown,
            predicted: used as f64 / cfg.n as f64,
        };
        if !quiet {
            println!(
                "slowdown {:<18} m̃={:<3} plateau={:<12.3e} measured={:<8} predicted m̃/n={:.3}",
                row.label,
                row.gradients_used,
                row.plateau,
                row.slowdown_vs_average
                    .map_or("-".into(), |r| format!("{r:.3}")),
                row.predicted
            );
        }
        rows.push(row);
    }
    let csv: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "{},{},{:.6e},{:.4},{:.4}",
                r.label,
                r.gradients_used,
                r.plateau,
                r.slowdown_vs_average.unwrap_or(f64::NAN),
                r.predicted
            )
        })
        .collect();
    super::write_csv(
        "slowdown.csv",
        "rule,gradients_used,plateau_loss,measured_slowdown,predicted",
        &csv,
    )?;
    Ok(rows)
}

// ---------------------------------------------------------------------------
// Thread-scaling sweep — the other "slowdown": wall-clock of the
// aggregation hot path vs the `threads` knob. The paper's §V notes that
// "multi-Bulyan's parallelisability further adds to its efficiency"; this
// sweep measures exactly that, and doubles as a guard that the parallel
// engine returns bit-identical outputs while doing so.
// ---------------------------------------------------------------------------

/// One (gar, d, threads) measurement of the thread sweep.
#[derive(Debug, Clone)]
pub struct ThreadSweepRow {
    pub gar: GarKind,
    pub n: usize,
    pub d: usize,
    pub threads: usize,
    pub mean_ms: f64,
    /// mean_ms(threads = first entry of the sweep) / mean_ms(this row).
    pub speedup: f64,
    /// Unfused round tail: `aggregate_with_scratch` (select + full-d
    /// combine) followed by a separate full-d `Sgd::step` pass — the old
    /// coordinator shape.
    pub unfused_ms: f64,
    /// Fused round tail: `select_into` + the coordinator's
    /// `fused_combine_update` (combine and SGD update in one sharded
    /// traversal). Output verified bit-identical to the unfused pass.
    pub fused_ms: f64,
}

/// Measure aggregation wall-time per (gar, d, threads) triple and the
/// speedup vs the sweep's first thread count (conventionally 1). Also
/// asserts the parallel outputs are bit-identical to the first run.
///
/// Each cell additionally measures the coordinator round tail both ways —
/// `unfused_ms` (aggregate into a full-d buffer, then a separate full-d
/// SGD pass: the pre-redesign shape) vs `fused_ms` (`select_into` + the
/// fused combine+update traversal the coordinator actually runs) — so the
/// fusion win is measured, not asserted; the fused aggregate is verified
/// bit-identical to the unfused one.
///
/// Writes `results/thread_sweep.csv` when `write_csv` is set (the CSV is
/// a side effect callers like `benches/gar_micro.rs` opt out of).
#[allow(clippy::too_many_arguments)]
pub fn thread_sweep(
    n: usize,
    f: usize,
    dims: &[usize],
    thread_counts: &[usize],
    gars: &[GarKind],
    protocol: crate::metrics::TimingProtocol,
    quiet: bool,
    write_csv: bool,
) -> Result<Vec<ThreadSweepRow>> {
    use crate::coordinator::fused_combine_update;
    use crate::gar::{GarScratch, Selection};
    use crate::runtime::Parallelism;
    use crate::tensor::GradMatrix;
    use crate::training::Sgd;
    use crate::util::Rng64;

    anyhow::ensure!(!thread_counts.is_empty(), "thread_sweep: no thread counts");
    let mut rows = Vec::new();
    for &kind in gars {
        anyhow::ensure!(n >= kind.min_n(f), "{kind}: n={n} too small for f={f}");
        for &d in dims {
            let mut rng = Rng64::seed_from_u64(0xBEEF ^ d as u64 ^ ((n as u64) << 40));
            let grads = GradMatrix::uniform(n, d, 0.0, 1.0, &mut rng);
            let mut base_ms: Option<f64> = None;
            let mut reference: Option<Vec<f32>> = None;
            for &threads in thread_counts {
                let par = Parallelism::new(threads);
                let gar = kind.instantiate_parallel(n, f, &par)?;
                let mut out = vec![0.0f32; d];
                let mut scratch = GarScratch::new();
                let (mean_ms, _) = protocol.measure(|| {
                    gar.aggregate_with_scratch(&grads, &mut out, &mut scratch)
                        .expect("aggregation failed");
                });
                match &reference {
                    None => reference = Some(out.clone()),
                    Some(r) => anyhow::ensure!(
                        r == &out,
                        "{kind} d={d}: threads={threads} changed the aggregate"
                    ),
                }
                // Unfused round tail: the measured aggregate above plus a
                // separate full-d SGD pass.
                let mut params_u = vec![0.0f32; d];
                let mut opt_u = Sgd::new(d, 0.05, 0.9)?;
                let (unfused_ms, _) = protocol.measure(|| {
                    gar.aggregate_with_scratch(&grads, &mut out, &mut scratch)
                        .expect("aggregation failed");
                    opt_u.step(&mut params_u, &out);
                });
                // Fused round tail: selection + one combine+update
                // traversal (what `coordinator::run_round` executes).
                let mut sel = Selection::default();
                let mut agg_f = vec![0.0f32; d];
                let mut params_f = vec![0.0f32; d];
                let mut opt_f = Sgd::new(d, 0.05, 0.9)?;
                let (fused_ms, _) = protocol.measure(|| {
                    gar.select_into(&grads, &mut scratch, &mut sel)
                        .expect("selection failed");
                    fused_combine_update(
                        &par,
                        &sel,
                        &grads,
                        &mut agg_f,
                        &mut params_f,
                        &mut opt_f,
                        &mut scratch.shards,
                    )
                    .expect("fused combine failed");
                });
                anyhow::ensure!(
                    agg_f == out,
                    "{kind} d={d} threads={threads}: fused aggregate diverged"
                );
                let base = *base_ms.get_or_insert(mean_ms);
                let speedup = base / mean_ms.max(1e-9);
                if !quiet {
                    println!(
                        "threads gar={:<13} d={d:<9} threads={threads:<3} {mean_ms:>10.3} ms   \
                         speedup ×{speedup:.2}   unfused {unfused_ms:>10.3} ms   fused \
                         {fused_ms:>10.3} ms (×{:.2})",
                        kind.as_str(),
                        unfused_ms / fused_ms.max(1e-9)
                    );
                }
                rows.push(ThreadSweepRow {
                    gar: kind,
                    n,
                    d,
                    threads,
                    mean_ms,
                    speedup,
                    unfused_ms,
                    fused_ms,
                });
            }
        }
    }
    if write_csv {
        let csv: Vec<String> = rows
            .iter()
            .map(|r| {
                format!(
                    "{},{},{},{},{:.6},{:.4},{:.6},{:.6}",
                    r.gar, r.n, r.d, r.threads, r.mean_ms, r.speedup, r.unfused_ms, r.fused_ms
                )
            })
            .collect();
        super::write_csv(
            "thread_sweep.csv",
            "gar,n,d,threads,mean_ms,speedup,unfused_ms,fused_ms",
            &csv,
        )?;
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thread_sweep_smoke_outputs_stay_identical() {
        let _env = crate::bench::env_lock();
        std::env::set_var(
            "MB_RESULTS_DIR",
            std::env::temp_dir().join("mb_thread_sweep_test"),
        );
        let rows = thread_sweep(
            11,
            2,
            &[20_000],
            &[1, 2],
            &[GarKind::MultiBulyan, GarKind::Median],
            crate::metrics::TimingProtocol::quick(),
            true,
            true,
        )
        .unwrap();
        // 2 gars × 1 dim × 2 thread counts.
        assert_eq!(rows.len(), 4);
        assert!(rows.iter().all(|r| r.mean_ms >= 0.0 && r.speedup > 0.0));
        // The fused/unfused comparison is measured for every cell.
        assert!(rows.iter().all(|r| r.fused_ms >= 0.0 && r.unfused_ms >= 0.0));
        assert!(
            super::super::results_dir().join("thread_sweep.csv").exists(),
            "write_csv = true must produce the CSV"
        );
        std::fs::remove_dir_all(super::super::results_dir()).ok();
        std::env::remove_var("MB_RESULTS_DIR");
    }

    #[test]
    fn thread_sweep_csv_side_effect_is_optional() {
        let _env = crate::bench::env_lock();
        let dir = std::env::temp_dir().join("mb_thread_sweep_nocsv_test");
        std::fs::remove_dir_all(&dir).ok();
        std::env::set_var("MB_RESULTS_DIR", &dir);
        let rows = thread_sweep(
            11,
            2,
            &[10_000],
            &[1],
            &[GarKind::Median],
            crate::metrics::TimingProtocol::quick(),
            true,
            false,
        )
        .unwrap();
        assert_eq!(rows.len(), 1);
        assert!(
            !dir.join("thread_sweep.csv").exists(),
            "write_csv = false must not write the CSV"
        );
        std::fs::remove_dir_all(&dir).ok();
        std::env::remove_var("MB_RESULTS_DIR");
    }

    #[test]
    fn plateau_ordering_tracks_m() {
        let _env = crate::bench::env_lock();
        std::env::set_var(
            "MB_RESULTS_DIR",
            std::env::temp_dir().join("mb_slowdown_test"),
        );
        let cfg = SlowdownConfig {
            dim: 64,
            burn_in: 120,
            window: 120,
            ..Default::default()
        };
        let rows = run(&cfg, true).unwrap();
        let get = |label: &str| {
            rows.iter()
                .find(|r| r.label == label)
                .unwrap()
                .plateau
        };
        // Variance reduction: more averaged gradients ⇒ lower plateau.
        // (n=11, f=2 ⇒ m̃ = 7; the sweep runs m ∈ {1, 3, 7}.)
        assert!(get("average") < get("multi-krum(m=3)"));
        assert!(get("multi-krum(m=3)") < get("multi-krum(m=1)"));
        // MULTI-BULYAN (m̃=5) beats single-selection KRUM.
        assert!(get("multi-bulyan") < get("krum"));
        std::fs::remove_dir_all(super::super::results_dir()).ok();
        std::env::remove_var("MB_PROPTEST_CASES");
        std::env::remove_var("MB_RESULTS_DIR");
    }
}
