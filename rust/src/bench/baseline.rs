//! Perf-baseline gate (`multibulyan bench check`) — the CI tripwire for
//! the aggregation hot path.
//!
//! `bench threads` / `gar_micro` report speedups, but a report nobody
//! diffs is not a guard: this module runs a **small fixed GAR sweep**
//! (the same `bench::slowdown::thread_sweep` the reports use, CSV side
//! effect included so CI can archive `results/thread_sweep.csv`) and
//! compares each `(gar, d, threads)` mean against a committed baseline
//! file, failing when any measurement exceeds `baseline × tolerance`.
//!
//! The tolerance is deliberately generous (default 3×): shared CI runners
//! are noisy and the gate exists to catch *algorithmic* regressions — a
//! de-vectorised kernel, an accidentally-quadratic pass, a serialised
//! pool — which show up as integer multiples, not percentages. Refresh
//! the committed numbers with `bench check --update` on a quiet machine.
//!
//! Baseline file format (`BENCH_baseline.json` at the repo root):
//!
//! ```json
//! {
//!   "tolerance": 3.0,
//!   "entries": [
//!     {"gar": "multi-krum", "n": 11, "d": 100000, "threads": 1, "mean_ms": 9.0}
//!   ]
//! }
//! ```

use super::slowdown::{thread_sweep, ThreadSweepRow};
use crate::gar::GarKind;
use crate::metrics::TimingProtocol;
use crate::util::json::Json;
use crate::Result;
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::Path;

/// Default regression threshold: measured > baseline × tolerance fails.
pub const DEFAULT_TOLERANCE: f64 = 3.0;

/// The fixed sweep the gate measures — small enough for CI (seconds),
/// large enough that a hot-loop regression clears the noise floor.
const GATE_N: usize = 11;
const GATE_F: usize = 2;
const GATE_DIMS: &[usize] = &[100_000];
const GATE_THREADS: &[usize] = &[1, 2];
const GATE_GARS: &[GarKind] = &[GarKind::MultiKrum, GarKind::MultiBulyan, GarKind::Median];

/// One `(gar, d, threads)` cell's identity in the baseline file.
fn cell_key(gar: &str, d: usize, threads: usize) -> String {
    format!("{gar} d={d} threads={threads}")
}

/// What a gate run concluded.
#[derive(Debug)]
pub struct CheckOutcome {
    /// Cells measured and found within tolerance.
    pub passed: usize,
    /// Human-readable descriptions of cells over tolerance.
    pub regressions: Vec<String>,
    /// Measured cells with no baseline entry (stale baseline file).
    pub missing: Vec<String>,
    /// Baseline entries the gate sweep no longer measures (dead weight
    /// the gate would otherwise silently stop enforcing).
    pub stale: Vec<String>,
}

impl CheckOutcome {
    /// Turn a failed gate into a CLI-facing error (nonzero exit).
    pub fn bail_on_failure(&self) -> Result<()> {
        anyhow::ensure!(
            self.regressions.is_empty() && self.missing.is_empty() && self.stale.is_empty(),
            "bench check FAILED: {} regression(s), {} unbaselined cell(s), \
             {} stale baseline entr(y/ies) — run `bench check --update` on a \
             quiet machine to refresh BENCH_baseline.json if the change is \
             intentional",
            self.regressions.len(),
            self.missing.len(),
            self.stale.len()
        );
        Ok(())
    }
}

fn run_gate_sweep(quiet: bool) -> Result<Vec<ThreadSweepRow>> {
    thread_sweep(
        GATE_N,
        GATE_F,
        GATE_DIMS,
        GATE_THREADS,
        GATE_GARS,
        TimingProtocol::default(),
        quiet,
        true, // CSV: CI archives results/thread_sweep.csv as an artifact
    )
}

/// Parse the baseline file into (tolerance, cell → mean_ms).
fn load_baseline(path: &Path) -> Result<(f64, BTreeMap<String, f64>)> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading baseline {path:?}: {e}"))?;
    let json = Json::parse(&text)?;
    let tolerance = match json.field_opt("tolerance") {
        Some(t) => t.as_f64()?,
        None => DEFAULT_TOLERANCE,
    };
    anyhow::ensure!(
        tolerance >= 1.0,
        "baseline tolerance must be ≥ 1.0, got {tolerance}"
    );
    let mut cells = BTreeMap::new();
    for entry in json.field("entries")?.as_arr()? {
        let gar = entry.field("gar")?.as_str()?.to_string();
        let n = entry.field("n")?.as_usize()?;
        let d = entry.field("d")?.as_usize()?;
        let threads = entry.field("threads")?.as_usize()?;
        let mean_ms = entry.field("mean_ms")?.as_f64()?;
        anyhow::ensure!(
            n == GATE_N,
            "baseline entry for n={n}; the gate sweep is fixed at n={GATE_N}"
        );
        anyhow::ensure!(mean_ms > 0.0, "baseline mean_ms must be > 0");
        cells.insert(cell_key(&gar, d, threads), mean_ms);
    }
    anyhow::ensure!(!cells.is_empty(), "baseline {path:?} has no entries");
    Ok((tolerance, cells))
}

/// Run the gate sweep and compare against the committed baseline.
/// `tolerance_override` (the `--tolerance` flag) wins over the file's.
pub fn check(path: impl AsRef<Path>, tolerance_override: Option<f64>) -> Result<CheckOutcome> {
    let path = path.as_ref();
    let (file_tolerance, baseline) = load_baseline(path)?;
    let tolerance = tolerance_override.unwrap_or(file_tolerance);
    let rows = run_gate_sweep(false)?;
    let mut outcome = CheckOutcome {
        passed: 0,
        regressions: Vec::new(),
        missing: Vec::new(),
        stale: Vec::new(),
    };
    // (cell, baseline, measured, status) — the step-summary table rows.
    let mut table: Vec<(String, Option<f64>, f64, &'static str)> = Vec::new();
    let mut measured_keys = std::collections::BTreeSet::new();
    for row in &rows {
        let key = cell_key(row.gar.as_str(), row.d, row.threads);
        measured_keys.insert(key.clone());
        match baseline.get(&key) {
            None => {
                table.push((key.clone(), None, row.mean_ms, "MISSING"));
                outcome.missing.push(key);
            }
            Some(&base_ms) => {
                let limit = base_ms * tolerance;
                if row.mean_ms > limit {
                    table.push((key.clone(), Some(base_ms), row.mean_ms, "FAIL"));
                    outcome.regressions.push(format!(
                        "{key}: {:.3} ms > {limit:.3} ms (baseline {base_ms:.3} ms × {tolerance})",
                        row.mean_ms
                    ));
                } else {
                    table.push((key.clone(), Some(base_ms), row.mean_ms, "pass"));
                    outcome.passed += 1;
                }
            }
        }
    }
    // The reverse direction: a committed entry the sweep never measures
    // is a gate that silently stopped gating.
    outcome.stale = baseline
        .keys()
        .filter(|k| !measured_keys.contains(*k))
        .cloned()
        .collect();
    // Per-cell pass/fail as a step-summary markdown table (GitHub
    // Actions only; no-op elsewhere).
    {
        let mut md = format!(
            "## bench check — perf gate vs `{}` (tolerance {tolerance}×)\n\n\
             | cell | baseline ms | measured ms | ratio | status |\n\
             |---|---:|---:|---:|---|\n",
            path.display()
        );
        for (key, base_ms, measured_ms, status) in &table {
            match base_ms {
                Some(b) => {
                    let _ = writeln!(
                        md,
                        "| {key} | {b:.3} | {measured_ms:.3} | {:.2}× | {status} |",
                        measured_ms / b
                    );
                }
                None => {
                    let _ = writeln!(md, "| {key} | — | {measured_ms:.3} | — | {status} |");
                }
            }
        }
        for s in &outcome.stale {
            let _ = writeln!(md, "| {s} | — | — | — | STALE |");
        }
        super::step_summary(&md);
    }
    println!(
        "bench check: {} cell(s) within {tolerance}× of {path:?}, {} regression(s), \
         {} missing, {} stale",
        outcome.passed,
        outcome.regressions.len(),
        outcome.missing.len(),
        outcome.stale.len()
    );
    for r in &outcome.regressions {
        println!("  REGRESSION {r}");
    }
    for m in &outcome.missing {
        println!("  MISSING    {m} (measured but not in baseline)");
    }
    for s in &outcome.stale {
        println!("  STALE      {s} (in baseline but not measured by the gate sweep)");
    }
    Ok(outcome)
}

/// Re-measure the gate sweep and (re)write the baseline file. A
/// tolerance the maintainer customized in the existing file is
/// preserved; only a *missing* file falls back to the default — an
/// existing-but-invalid file is an error (never silently reset a
/// customized gate).
pub fn update(path: impl AsRef<Path>) -> Result<()> {
    let path = path.as_ref();
    let tolerance = if path.exists() {
        load_baseline(path)?.0
    } else {
        DEFAULT_TOLERANCE
    };
    let rows = run_gate_sweep(false)?;
    std::fs::write(path, render_baseline(&rows, tolerance))
        .map_err(|e| anyhow::anyhow!("writing baseline {path:?}: {e}"))?;
    println!("bench check: baseline rewritten to {path:?} ({} cells)", rows.len());
    Ok(())
}

/// Hand-indented JSON so the committed baseline diffs line-per-cell.
fn render_baseline(rows: &[ThreadSweepRow], tolerance: f64) -> String {
    let mut out = String::from("{\n");
    let _ = writeln!(
        out,
        "  \"_comment\": \"Perf baseline for `multibulyan bench check` (the CI gate): \
         a run fails when any gate-sweep cell exceeds mean_ms x tolerance. \
         Refresh with `bench check --update` on a quiet machine.\","
    );
    let _ = writeln!(out, "  \"tolerance\": {tolerance},");
    out.push_str("  \"entries\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let comma = if i + 1 < rows.len() { "," } else { "" };
        let _ = writeln!(
            out,
            "    {{\"gar\": \"{}\", \"n\": {}, \"d\": {}, \"threads\": {}, \"mean_ms\": {:.3}}}{comma}",
            r.gar.as_str(),
            r.n,
            r.d,
            r.threads,
            r.mean_ms.max(0.001)
        );
    }
    out.push_str("  ]\n}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_rows() -> Vec<ThreadSweepRow> {
        vec![
            ThreadSweepRow {
                gar: GarKind::MultiKrum,
                n: GATE_N,
                d: 100_000,
                threads: 1,
                mean_ms: 5.0,
                speedup: 1.0,
                unfused_ms: 5.5,
                fused_ms: 5.2,
            },
            ThreadSweepRow {
                gar: GarKind::Median,
                n: GATE_N,
                d: 100_000,
                threads: 2,
                mean_ms: 2.0,
                speedup: 2.5,
                unfused_ms: 2.4,
                fused_ms: 2.1,
            },
        ]
    }

    #[test]
    fn rendered_baseline_round_trips_through_loader() {
        let dir = std::env::temp_dir().join("mb_baseline_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("baseline.json");
        std::fs::write(&path, render_baseline(&fake_rows(), 3.0)).unwrap();
        let (tol, cells) = load_baseline(&path).unwrap();
        assert_eq!(tol, 3.0);
        assert_eq!(cells.len(), 2);
        assert_eq!(cells[&cell_key("multi-krum", 100_000, 1)], 5.0);
        assert_eq!(cells[&cell_key("median", 100_000, 2)], 2.0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn loader_rejects_bad_baselines() {
        let dir = std::env::temp_dir().join("mb_baseline_bad_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.json");
        std::fs::write(&path, "{\"entries\": []}").unwrap();
        assert!(load_baseline(&path).is_err(), "empty entries must fail");
        std::fs::write(&path, "{\"tolerance\": 0.5, \"entries\": [{\"gar\": \"median\", \"n\": 11, \"d\": 10, \"threads\": 1, \"mean_ms\": 1.0}]}").unwrap();
        assert!(load_baseline(&path).is_err(), "tolerance < 1 must fail");
        assert!(
            load_baseline(&dir.join("absent.json")).is_err(),
            "missing file must fail"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn outcome_gates_on_regressions_missing_and_stale_cells() {
        let clean = || CheckOutcome {
            passed: 6,
            regressions: Vec::new(),
            missing: Vec::new(),
            stale: Vec::new(),
        };
        assert!(clean().bail_on_failure().is_ok());
        let mut slow = clean();
        slow.regressions.push("median d=100000 threads=1: slow".into());
        assert!(slow.bail_on_failure().is_err());
        let mut unbaselined = clean();
        unbaselined.missing.push("median d=100000 threads=2".into());
        assert!(unbaselined.bail_on_failure().is_err());
        let mut stale = clean();
        stale.stale.push("krum d=5 threads=9".into());
        assert!(stale.bail_on_failure().is_err());
    }

    #[test]
    fn rendered_baseline_carries_custom_tolerance_and_comment() {
        let text = render_baseline(&fake_rows(), 1.5);
        assert!(text.contains("\"tolerance\": 1.5"));
        assert!(text.contains("_comment"));
        let json = Json::parse(&text).unwrap();
        assert_eq!(json.field("tolerance").unwrap().as_f64().unwrap(), 1.5);
    }
}
