//! Seeded PRNG — xoshiro256++ with a splitmix64 seeder, plus the sampling
//! helpers the rest of the crate needs (uniform ints/floats, Bernoulli,
//! Gaussian via Box–Muller). Deterministic across platforms; used for
//! every seeded protocol in the experiments (Fig. 2's U(0,1)^d gradients,
//! Fig. 3's seeds 1..5, fault injection, attack noise).
//!
//! References: Blackman & Vigna, "Scrambled linear pseudorandom number
//! generators" (xoshiro256++); Steele et al. (splitmix64).

/// xoshiro256++ generator.
#[derive(Debug, Clone)]
pub struct Rng64 {
    s: [u64; 4],
}

/// splitmix64 step — also exposed for hash-style seed mixing.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng64 {
    /// Seed the full 256-bit state from a u64 via splitmix64.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Panics if `n == 0`. (Lemire-style rejection
    /// to avoid modulo bias.)
    pub fn gen_range_usize(&mut self, n: usize) -> usize {
        assert!(n > 0, "gen_range_usize: empty range");
        let n = n as u64;
        // Rejection sampling on the top bits.
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Uniform i64 in `[lo, hi]` (inclusive).
    pub fn gen_range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        lo + (self.gen_range_usize(span as usize) as i64)
    }

    /// Uniform f32 in `[0, 1)` (24-bit mantissa resolution).
    #[inline]
    pub fn gen_f32(&mut self) -> f32 {
        ((self.next_u64() >> 40) as f32) * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f64 in `[0, 1)` (53-bit resolution).
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        ((self.next_u64() >> 11) as f64) * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[lo, hi)`.
    #[inline]
    pub fn gen_range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.gen_f32()
    }

    /// Bernoulli(p).
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn gaussian(&mut self) -> f32 {
        let u1 = self.gen_f32().max(f32::EPSILON);
        let u2 = self.gen_f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// A new independent generator split off this one (jump-free but
    /// mixing enough for test/simulation purposes).
    pub fn split(&mut self) -> Rng64 {
        Rng64::seed_from_u64(self.next_u64() ^ 0xDEAD_BEEF_CAFE_F00D)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng64::seed_from_u64(42);
        let mut b = Rng64::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng64::seed_from_u64(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_f32_in_unit_interval_with_flat_histogram() {
        let mut rng = Rng64::seed_from_u64(1);
        let mut buckets = [0u32; 10];
        for _ in 0..100_000 {
            let v = rng.gen_f32();
            assert!((0.0..1.0).contains(&v));
            buckets[(v * 10.0) as usize] += 1;
        }
        for &b in &buckets {
            assert!((8_500..11_500).contains(&b), "bucket count {b}");
        }
    }

    #[test]
    fn gen_range_usize_unbiased_at_small_n() {
        let mut rng = Rng64::seed_from_u64(5);
        let mut counts = [0u32; 7];
        for _ in 0..70_000 {
            counts[rng.gen_range_usize(7)] += 1;
        }
        for &c in &counts {
            assert!((9_300..10_700).contains(&c), "count {c}");
        }
    }

    #[test]
    fn gen_range_i64_inclusive_bounds() {
        let mut rng = Rng64::seed_from_u64(9);
        let (mut saw_lo, mut saw_hi) = (false, false);
        for _ in 0..10_000 {
            let v = rng.gen_range_i64(-3, 3);
            assert!((-3..=3).contains(&v));
            saw_lo |= v == -3;
            saw_hi |= v == 3;
        }
        assert!(saw_lo && saw_hi);
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Rng64::seed_from_u64(11);
        let samples: Vec<f32> = (0..50_000).map(|_| rng.gaussian()).collect();
        let mean = samples.iter().sum::<f32>() / samples.len() as f32;
        let var = samples.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>()
            / (samples.len() - 1) as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn bernoulli_rate() {
        let mut rng = Rng64::seed_from_u64(2);
        let hits = (0..100_000).filter(|_| rng.gen_bool(0.25)).count();
        assert!((24_000..26_000).contains(&hits), "hits {hits}");
    }

    #[test]
    fn split_streams_are_independent_enough() {
        let mut base = Rng64::seed_from_u64(7);
        let mut a = base.split();
        let mut b = base.split();
        let same = (0..1000).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
