//! Minimal JSON parser + writer (the offline environment has no
//! `serde_json`). Supports the full JSON grammar minus exotic number
//! forms; numbers are f64 (integers round-trip exactly up to 2⁵³, far
//! beyond any manifest value). Used for `artifacts/manifest.json` and the
//! results emission.

use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document (trailing whitespace allowed).
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing garbage at byte {}", p.pos);
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            other => bail!("expected object, got {}", other.kind()),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            other => bail!("expected array, got {}", other.kind()),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => bail!("expected string, got {}", other.kind()),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => bail!("expected number, got {}", other.kind()),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 || n > 9e15 {
            bail!("expected non-negative integer, got {n}");
        }
        Ok(n as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => bail!("expected bool, got {}", other.kind()),
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// Object field access with a helpful error.
    pub fn field(&self, name: &str) -> Result<&Json> {
        self.as_obj()?
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("missing field '{name}'"))
    }

    /// Optional field (None when absent or null).
    pub fn field_opt(&self, name: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(name).filter(|v| !v.is_null()),
            _ => None,
        }
    }

    fn kind(&self) -> &'static str {
        match self {
            Json::Null => "null",
            Json::Bool(_) => "bool",
            Json::Num(_) => "number",
            Json::Str(_) => "string",
            Json::Arr(_) => "array",
            Json::Obj(_) => "object",
        }
    }

    /// Serialize (compact).
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("unexpected end of input at byte {}", self.pos))
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek()? != b {
            bail!(
                "expected '{}' at byte {}, found '{}'",
                b as char,
                self.pos,
                self.peek()? as char
            );
        }
        self.pos += 1;
        Ok(())
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'n' => self.literal("null", Json::Null),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected character '{}' at byte {}", c as char, self.pos),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.pos += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let esc = self.peek()?;
                    self.pos += 1;
                    match esc {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.pos += 4;
                            // Surrogate pairs: handle the high half.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.bytes[self.pos..].starts_with(b"\\u") {
                                    let hex2 = std::str::from_utf8(
                                        &self.bytes[self.pos + 2..self.pos + 6],
                                    )?;
                                    let lo = u32::from_str_radix(hex2, 16)?;
                                    self.pos += 6;
                                    char::from_u32(
                                        0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00),
                                    )
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(ch.ok_or_else(|| anyhow::anyhow!("bad \\u escape"))?);
                        }
                        e => bail!("bad escape '\\{}'", e as char),
                    }
                }
                c if c < 0x20 => bail!("raw control character in string"),
                c => {
                    // Multi-byte UTF-8: copy continuation bytes verbatim.
                    let len = match c {
                        0x00..=0x7F => 1,
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let start = self.pos - 1;
                    self.pos = start + len;
                    if self.pos > self.bytes.len() {
                        bail!("truncated UTF-8");
                    }
                    s.push_str(std::str::from_utf8(&self.bytes[start..self.pos])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek()? == b'-' {
            self.pos += 1;
        }
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                c => bail!("expected ',' or ']' at byte {}, got '{}'", self.pos, c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let value = self.value()?;
            map.insert(key, value);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                c => bail!("expected ',' or '}}' at byte {}, got '{}'", self.pos, c as char),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-2.5e2").unwrap(), Json::Num(-250.0));
        assert_eq!(
            Json::parse(r#""a\nbA""#).unwrap(),
            Json::Str("a\nbA".into())
        );
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.field("a").unwrap().as_arr().unwrap().len(), 3);
        assert!(v.field("c").unwrap().is_null());
        assert!(v.field_opt("c").is_none());
        assert!(v.field("missing").is_err());
        assert_eq!(
            v.field("a").unwrap().as_arr().unwrap()[2]
                .field("b")
                .unwrap()
                .as_str()
                .unwrap(),
            "x"
        );
    }

    #[test]
    fn rejects_malformed() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("01x").is_err());
        assert!(Json::parse(r#"{"a":1} extra"#).is_err());
        assert!(Json::parse(r#""unterminated"#).is_err());
    }

    #[test]
    fn roundtrip() {
        let text = r#"{"arr":[1,2.5,true,null],"name":"hello \"world\"","n":42}"#;
        let v = Json::parse(text).unwrap();
        let out = v.to_string_compact();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn usize_accessor_guards() {
        assert_eq!(Json::parse("7").unwrap().as_usize().unwrap(), 7);
        assert!(Json::parse("-1").unwrap().as_usize().is_err());
        assert!(Json::parse("1.5").unwrap().as_usize().is_err());
    }

    #[test]
    fn unicode_and_surrogates() {
        let v = Json::parse(r#""π 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "π 😀");
    }
}
