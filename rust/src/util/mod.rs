//! In-repo substrates for the offline build environment: a seeded PRNG
//! (no `rand`), a JSON parser/writer (no `serde_json`) and a small
//! property-testing helper (no `proptest`). See Cargo.toml for why these
//! exist in-tree.

pub mod json;
pub mod proptest;
pub mod rng;

pub use rng::{splitmix64, Rng64};
