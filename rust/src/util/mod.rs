//! In-repo substrates for the offline build environment: a seeded PRNG
//! (no `rand`), a JSON parser/writer (no `serde_json`) and a small
//! property-testing helper (no `proptest`). See Cargo.toml for why these
//! exist in-tree.

pub mod json;
pub mod proptest;
pub mod rng;

pub use rng::{splitmix64, Rng64};

/// Invariant check compiled to nothing unless the `strict-invariants`
/// feature is on (`cargo test --features strict-invariants` in CI).
///
/// Unlike `debug_assert!` these stay off in default debug builds — the
/// pooled-transport tests drive hundreds of virtual rounds and the hot
/// fan-out closures run per shard per slice, so the checks are a
/// dedicated CI leg rather than a blanket debug tax. The `if cfg!`
/// form (not `#[cfg]`) keeps the condition type-checked in every build.
#[macro_export]
macro_rules! strict_assert {
    ($($arg:tt)*) => {
        if cfg!(feature = "strict-invariants") {
            assert!($($arg)*);
        }
    };
}

/// [`strict_assert!`] for equality, with the usual both-values message.
#[macro_export]
macro_rules! strict_assert_eq {
    ($($arg:tt)*) => {
        if cfg!(feature = "strict-invariants") {
            assert_eq!($($arg)*);
        }
    };
}

/// 64-bit FNV-1a over a byte stream — the stable, dependency-free digest
/// behind `train --params-checksum` (the CI determinism matrix compares
/// these across transport × threads × overlap legs).
pub fn fnv1a(bytes: impl IntoIterator<Item = u8>) -> u64 {
    let mut hash = 0xcbf2_9ce4_8422_2325u64;
    for b in bytes {
        hash ^= u64::from(b);
        hash = hash.wrapping_mul(0x0000_0100_0000_01b3);
    }
    hash
}

#[cfg(test)]
mod tests {
    use super::fnv1a;

    #[test]
    fn fnv1a_matches_reference_vectors() {
        // Published FNV-1a 64-bit test vectors (Noll's test suite) — the
        // digest now guards wire-frame integrity (docs/wire-protocol.md
        // §2), not just determinism diffing, so it must match the
        // published function exactly, not merely be self-consistent.
        assert_eq!(fnv1a([]), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(*b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a(*b"b"), 0xaf63_df4c_8601_f1a5);
        assert_eq!(fnv1a(*b"c"), 0xaf63_de4c_8601_eff2);
        assert_eq!(fnv1a(*b"ab"), 0x089c_4407_b545_986a);
        assert_eq!(fnv1a(*b"abc"), 0xe71f_a219_0541_574b);
        assert_eq!(fnv1a(*b"foobar"), 0x85944171f73967e8);
        assert_eq!(fnv1a(*b"chongo was here!\n"), 0x4681_0940_eff5_f915);
        // Sensitive to every bit of an f32 stream.
        let digest = |v: f32| fnv1a(v.to_le_bytes());
        assert_ne!(digest(0.0), digest(-0.0));
    }
}
