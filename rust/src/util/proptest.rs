//! Mini property-testing harness (the offline environment has no
//! `proptest`). [`check`] runs a property over `cases` seeded random
//! inputs and, on failure, reports the failing case's seed so it can be
//! replayed with [`replay`]. No shrinking — cases are kept small instead.

use super::rng::Rng64;

/// Number of cases for the heavier properties (overridable via the
/// `MB_PROPTEST_CASES` environment variable).
pub fn default_cases() -> u64 {
    std::env::var("MB_PROPTEST_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64)
}

/// Run `property(rng, case_index)` for `cases` deterministic cases.
/// Panics with the failing seed on the first violation.
pub fn check<F>(name: &str, cases: u64, mut property: F)
where
    F: FnMut(&mut Rng64, u64) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = 0x5EED_0000_0000_0000 ^ case.wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng64::seed_from_u64(seed);
        if let Err(msg) = property(&mut rng, case) {
            panic!(
                "property '{name}' failed at case {case} (replay seed {seed:#x}): {msg}"
            );
        }
    }
}

/// Re-run a single failing case by seed.
pub fn replay<F>(seed: u64, mut property: F) -> Result<(), String>
where
    F: FnMut(&mut Rng64, u64) -> Result<(), String>,
{
    let mut rng = Rng64::seed_from_u64(seed);
    property(&mut rng, 0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut ran = 0;
        check("trivial", 10, |rng, _| {
            ran += 1;
            let v = rng.gen_f32();
            if (0.0..1.0).contains(&v) {
                Ok(())
            } else {
                Err(format!("out of range: {v}"))
            }
        });
        assert_eq!(ran, 10);
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_seed() {
        check("always-fails", 3, |_, _| Err("nope".into()));
    }

    #[test]
    fn replay_reproduces_case_zero() {
        let mut first = None;
        check("record", 1, |rng, _| {
            first = Some(rng.next_u64());
            Ok(())
        });
        let seed = 0x5EED_0000_0000_0000u64;
        replay(seed, |rng, _| {
            assert_eq!(rng.next_u64(), first.unwrap());
            Ok(())
        })
        .unwrap();
    }
}
