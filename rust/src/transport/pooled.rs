//! The pooled batched backend: `n` logical workers multiplexed over the
//! crate's shared [`ThreadPool`] (via a [`Parallelism`] handle) instead of
//! `n` OS threads and `2n` mpsc channels.
//!
//! Per-round shared state, preallocated once:
//!
//! * **broadcast slot** — the server stores `(round, Arc<params>)` on
//!   [`Server::broadcast`]; nothing is sent anywhere.
//! * **gradient arena** — one [`GradSlot`] per worker (a reusable `Vec<f32>`
//!   plus a round tag and freshness flag). Worker `i` writes only slot `i`,
//!   so slots never contend; the per-slot `Mutex` is uncontended and exists
//!   to keep the server/worker hand-off safe without `unsafe`.
//!
//! [`Server::collect_with`] *drives* the round: it fans the registered
//! worker bodies out over the pool (`run_sharded`, dynamic claiming — load
//! balance for uneven gradient costs), each body writes its slot through
//! the fault-model [`Emitter`](super::Emitter), and the server then scans
//! the arena. Steady state: zero allocations, zero channel operations,
//! zero thread spawns per round.
//!
//! Because bodies run *on* the pool, a body must not submit nested
//! parallel regions to the same pool (see `runtime::pool` reentrancy
//! note) — the launcher hands pooled workers a sequential [`Parallelism`]
//! for their intra-gradient sharding.
//!
//! [`ThreadPool`]: crate::runtime::ThreadPool

use super::{lock, Emitter, EmitterSink, FaultModel, WorkerBody};
use crate::runtime::Parallelism;
use crate::util::Rng64;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// One worker's arena slot: the last gradient it emitted, tagged with the
/// round it answers. `fresh` is cleared when the server consumes the slot
/// so a gradient is delivered at most once (mirrors message consumption).
pub(super) struct GradSlot {
    pub(super) round: u64,
    pub(super) fresh: bool,
    pub(super) grad: Vec<f32>,
}

/// A registered logical worker: its body plus its private fault RNG
/// (seeded identically to the threaded backend's per-thread RNG).
struct Driver {
    body: Box<dyn WorkerBody>,
    rng: Rng64,
}

/// Per-worker cell. The two Mutexes are uncontended by construction —
/// exactly one pool task touches worker `i` during a drive, and the
/// server only reads slots after the drive's completion barrier.
struct Cell {
    driver: Mutex<Option<Driver>>,
    slot: Mutex<GradSlot>,
}

/// State shared between the server and the worker registration handles.
struct Runtime {
    cells: Vec<Cell>,
    faults: FaultModel,
    par: Parallelism,
    shutdown: AtomicBool,
}

impl Runtime {
    /// Run every registered body for `round` across the pool and let it
    /// write its arena slot. Blocks until all logical workers finished.
    fn drive(&self, round: u64, params: &Arc<Vec<f32>>) {
        if self.shutdown.load(Ordering::Acquire) {
            return;
        }
        let params: &[f32] = params;
        self.par.run_sharded(self.cells.len(), &|i| {
            let cell = &self.cells[i];
            let mut guard = lock(&cell.driver);
            let panicked = match guard.as_mut() {
                None => false,
                Some(driver) => {
                    let Driver { body, rng } = driver;
                    let mut emit = Emitter {
                        worker: i,
                        faults: self.faults,
                        rng,
                        sink: EmitterSink::Slot(&cell.slot),
                    };
                    catch_unwind(AssertUnwindSafe(|| body.on_round(round, params, &mut emit)))
                        .is_err()
                }
            };
            if panicked {
                // Crash-fault semantics, matching the threaded backend
                // where a panicking body kills only its worker thread:
                // silence this logical worker permanently and let the
                // server's missing-gradient fallback handle it.
                *guard = None;
            }
        });
    }
}

/// Pooled server half.
pub(super) struct Server {
    runtime: Arc<Runtime>,
    /// The broadcast slot: filled by `broadcast`, consumed (driven) by the
    /// next `collect_with`. A re-broadcast before a collect supersedes the
    /// previous round — the synchronous coordinator never does this.
    pending: Option<(u64, Arc<Vec<f32>>)>,
}

impl Server {
    pub(super) fn broadcast(&mut self, round: u64, params: Arc<Vec<f32>>) {
        self.pending = Some((round, params));
    }

    pub(super) fn collect_with(
        &mut self,
        round: u64,
        expect: usize,
        _timeout: Duration,
        on_gradient: &mut dyn FnMut(usize, &[f32]),
    ) -> usize {
        // The logical workers run to completion here, so the timeout has
        // nothing left to bound: a missing gradient is a fault-model drop
        // (or a silent body), never an un-preempted straggler.
        if let Some((r, params)) = self.pending.take() {
            self.runtime.drive(r, &params);
        }
        let mut got = 0;
        for (i, cell) in self.runtime.cells.iter().enumerate() {
            if got >= expect {
                break;
            }
            let mut slot = lock(&cell.slot);
            if slot.fresh && slot.round == round {
                slot.fresh = false;
                on_gradient(i, &slot.grad);
                got += 1;
            }
        }
        got
    }

    pub(super) fn shutdown(&self) {
        self.runtime.shutdown.store(true, Ordering::Release);
        for cell in &self.runtime.cells {
            lock(&cell.driver).take();
        }
    }

    pub(super) fn num_workers(&self) -> usize {
        self.runtime.cells.len()
    }
}

/// Registration handle for one logical worker.
pub(super) struct WorkerHandle {
    id: usize,
    runtime: Arc<Runtime>,
}

impl WorkerHandle {
    pub(super) fn id(&self) -> usize {
        self.id
    }

    /// Register `body` with the shared runtime (no thread is spawned —
    /// the server drives the body during `collect`).
    pub(super) fn serve(self, body: Box<dyn WorkerBody>) {
        let rng = self.runtime.faults.rng_for(self.id);
        *lock(&self.runtime.cells[self.id].driver) = Some(Driver { body, rng });
    }
}

/// Build the pooled star: the arena and cells are preallocated here; the
/// gradient buffers themselves grow to `d` on each worker's first emit
/// and are reused afterwards.
pub(super) fn star(
    n: usize,
    faults: FaultModel,
    par: Parallelism,
) -> (Server, Vec<WorkerHandle>) {
    let cells = (0..n)
        .map(|_| Cell {
            driver: Mutex::new(None),
            slot: Mutex::new(GradSlot {
                round: 0,
                fresh: false,
                grad: Vec::new(),
            }),
        })
        .collect();
    let runtime = Arc::new(Runtime {
        cells,
        faults,
        par,
        shutdown: AtomicBool::new(false),
    });
    let handles = (0..n)
        .map(|id| WorkerHandle {
            id,
            runtime: Arc::clone(&runtime),
        })
        .collect();
    (
        Server {
            runtime,
            pending: None,
        },
        handles,
    )
}
