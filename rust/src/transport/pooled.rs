//! The pooled batched backend: `n` logical workers multiplexed over the
//! crate's shared [`ThreadPool`] (via a [`Parallelism`] handle) instead of
//! `n` OS threads and `2n` mpsc channels.
//!
//! Per-round shared state, preallocated once:
//!
//! * **broadcast slot** — the server stores `(round, Arc<params>)` on
//!   [`Server::broadcast`]; nothing is sent anywhere.
//! * **gradient arena** — one [`GradSlot`] per worker (a reusable `Vec<f32>`
//!   plus a round tag and freshness flag). Worker `i` writes only slot `i`,
//!   so slots never contend; the per-slot `Mutex` is uncontended and exists
//!   to keep the server/worker hand-off safe without `unsafe`.
//!
//! [`Server::collect_with`] *drives* the round with a **time-sliced
//! drive**: a virtual clock advances in [`SLICE_US`]-microsecond slices,
//! and in each slice every still-running worker body is stepped
//! ([`WorkerBody::step_to`]) to the completed-work fraction its
//! [`ComputeCost`](super::ComputeCost) implies at the current virtual
//! time. Bodies that finish a slice emit through the fault-model
//! [`Emitter`](super::Emitter) and are delivered immediately, in
//! **completion order** (finishing slice, ties broken by ascending worker
//! index — the order a real parameter server would see arrivals). The
//! drive stops as soon as
//!
//! * `expect` gradients have been delivered (the first-m race: stragglers
//!   are abandoned mid-computation and their remaining work is never
//!   executed), or
//! * the collect timeout — interpreted in virtual microseconds — expires
//!   (a worker whose simulated cost exceeds the timeout deterministically
//!   misses the round), or
//! * every worker finished.
//!
//! Because the clock is virtual and the per-slice step order never feeds
//! back into the results, a seeded run is bit-identical for every thread
//! count, and identical to the threaded backend whenever the cost gaps
//! are decisive. With the cost model disabled (`base_us = 0`) every
//! worker completes in the first slice and the drive degenerates to the
//! old run-to-completion fan-out. Steady state: zero allocations, zero
//! channel operations, zero thread spawns per round (the drive's
//! `running`/`done` scratch is reused across rounds).
//!
//! Because bodies run *on* the pool, a body must not submit nested
//! parallel regions to the same pool (see `runtime::pool` reentrancy
//! note) — the launcher hands pooled workers a sequential [`Parallelism`]
//! for their intra-gradient sharding.
//!
//! [`ThreadPool`]: crate::runtime::ThreadPool
//! [`WorkerBody::step_to`]: super::WorkerBody::step_to

use super::{lock, Emitter, EmitterSink, FaultModel, StepOutcome, WorkerBody};
use crate::runtime::Parallelism;
use crate::util::Rng64;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Virtual-clock granularity of the time-sliced drive, microseconds. One
/// slice is one pool fan-out over the still-running workers; smaller
/// slices resolve finer cost differences at more fan-out overhead. Cost
/// models are expressed in hundreds-to-thousands of µs, so 50 µs keeps
/// quantisation error under a few percent.
const SLICE_US: u64 = 50;

/// One worker's arena slot: the last gradient it emitted, tagged with the
/// round it answers. `fresh` is cleared when the server consumes the slot
/// so a gradient is delivered at most once (mirrors message consumption).
pub(super) struct GradSlot {
    pub(super) round: u64,
    pub(super) fresh: bool,
    pub(super) grad: Vec<f32>,
}

/// A registered logical worker: its body plus its private fault RNG
/// (seeded identically to the threaded backend's per-thread RNG).
struct Driver {
    body: Box<dyn WorkerBody>,
    rng: Rng64,
}

/// Per-worker cell. The two Mutexes are uncontended by construction —
/// exactly one pool task touches worker `i` during a drive slice, and the
/// server only reads slots between slices (after the slice's completion
/// barrier).
struct Cell {
    driver: Mutex<Option<Driver>>,
    slot: Mutex<GradSlot>,
}

/// State shared between the server and the worker registration handles.
struct Runtime {
    cells: Vec<Cell>,
    faults: FaultModel,
    par: Parallelism,
    shutdown: AtomicBool,
}

/// The server's reusable drive scratch (no per-round allocation in the
/// steady state).
#[derive(Default)]
struct DriveState {
    /// Worker ids still computing this round, ascending; compacted as
    /// workers finish.
    running: Vec<usize>,
    /// Per-worker finished flag for the current slice's fan-out.
    done: Vec<AtomicBool>,
}

/// Pooled server half.
pub(super) struct Server {
    runtime: Arc<Runtime>,
    /// The broadcast slot: filled by `broadcast`, consumed (driven) by the
    /// next `collect_with`. A re-broadcast before a collect supersedes the
    /// previous round — the synchronous coordinator never does this.
    pending: Option<(u64, Arc<Vec<f32>>)>,
    drive: DriveState,
}

impl Server {
    pub(super) fn broadcast(&mut self, round: u64, params: Arc<Vec<f32>>) {
        self.pending = Some((round, params));
    }

    pub(super) fn collect_with(
        &mut self,
        round: u64,
        expect: usize,
        timeout: Duration,
        on_gradient: &mut dyn FnMut(usize, &[f32]) -> bool,
    ) -> usize {
        let mut got = 0;
        if let Some((r, params)) = self.pending.take() {
            got = self.drive_collect(r, &params, round, expect, timeout, on_gradient);
        }
        // Sweep any remaining fresh slots for `round` in worker-index
        // order: completion-order ties past `expect` that a retried
        // collect may still want, or a collect without a preceding
        // broadcast. Normally finds nothing.
        for (i, cell) in self.runtime.cells.iter().enumerate() {
            if got >= expect {
                break;
            }
            let mut slot = lock(&cell.slot);
            if slot.fresh && slot.round == round {
                slot.fresh = false;
                if on_gradient(i, &slot.grad) {
                    got += 1;
                }
            }
        }
        got
    }

    /// The time-sliced drive (module docs): run round `drive_round` at
    /// `params` across the pool, delivering gradients for `collect_round`
    /// in completion order until `expect` arrived, the virtual deadline
    /// passed, or everyone finished. Returns the number delivered.
    fn drive_collect(
        &mut self,
        drive_round: u64,
        params: &Arc<Vec<f32>>,
        collect_round: u64,
        expect: usize,
        timeout: Duration,
        on_gradient: &mut dyn FnMut(usize, &[f32]) -> bool,
    ) -> usize {
        let rt = Arc::clone(&self.runtime);
        if rt.shutdown.load(Ordering::Acquire) {
            return 0;
        }
        let n = rt.cells.len();
        let drive = &mut self.drive;
        drive.running.clear();
        drive.running.extend(0..n);
        while drive.done.len() < n {
            drive.done.push(AtomicBool::new(false));
        }
        let params: &[f32] = params;
        // The timeout bounds *virtual* time; the wall-clock deadline below
        // is only a safety net against pathological real compute costs.
        let virtual_deadline = timeout.as_micros().min(u128::from(u64::MAX)) as u64;
        let wall_deadline = Instant::now().checked_add(timeout);
        let mut t_virtual: u64 = 0;
        let mut got = 0;
        while !drive.running.is_empty() && got < expect {
            t_virtual = t_virtual.saturating_add(SLICE_US);
            {
                let running = &drive.running[..];
                let done = &drive.done[..];
                rt.par.run_sharded(running.len(), &|k| {
                    let i = running[k];
                    let cell = &rt.cells[i];
                    let mut guard = lock(&cell.driver);
                    let (finished, panicked) = match guard.as_mut() {
                        // Unregistered or silenced: nothing to drive.
                        None => (true, false),
                        Some(driver) => {
                            let cost = rt.faults.cost.cost_us_for(i);
                            let target = if cost == 0 {
                                1.0
                            } else {
                                (t_virtual as f64 / cost as f64).min(1.0)
                            };
                            let Driver { body, rng } = driver;
                            let mut emit = Emitter {
                                worker: i,
                                faults: rt.faults,
                                rng,
                                sink: EmitterSink::Slot(&cell.slot),
                            };
                            match catch_unwind(AssertUnwindSafe(|| {
                                body.step_to(drive_round, params, &mut emit, target)
                            })) {
                                Ok(StepOutcome::Done) => (true, false),
                                Ok(StepOutcome::Working) => (false, false),
                                Err(_) => (true, true),
                            }
                        }
                    };
                    if panicked {
                        // Crash-fault semantics, matching the threaded
                        // backend where a panicking body kills only its
                        // worker thread: silence this logical worker
                        // permanently and let the server's
                        // missing-gradient fallback handle it.
                        *guard = None;
                    }
                    done[i].store(finished, Ordering::Release);
                });
            }
            // Harvest: deliver this slice's finishers in ascending worker
            // index (completion order = finishing slice, then index) and
            // compact `running` in place (`retain` visits front-to-back
            // and preserves order).
            {
                let done = &drive.done;
                let cells = &rt.cells;
                drive.running.retain(|&i| {
                    if !done[i].load(Ordering::Acquire) {
                        return true;
                    }
                    if got < expect {
                        let mut slot = lock(&cells[i].slot);
                        if slot.fresh && slot.round == collect_round {
                            slot.fresh = false;
                            // A rejected gradient (callback returns
                            // false) is consumed but does not fill an
                            // `expect` slot.
                            if on_gradient(i, &slot.grad) {
                                got += 1;
                            }
                        }
                    }
                    false
                });
            }
            if t_virtual >= virtual_deadline {
                break; // stragglers deterministically miss the round
            }
            if rt.shutdown.load(Ordering::Acquire) {
                break;
            }
            if let Some(deadline) = wall_deadline {
                if Instant::now() >= deadline {
                    break; // wall-clock safety net
                }
            }
        }
        got
    }

    pub(super) fn shutdown(&self) {
        self.runtime.shutdown.store(true, Ordering::Release);
        for cell in &self.runtime.cells {
            lock(&cell.driver).take();
        }
    }

    pub(super) fn num_workers(&self) -> usize {
        self.runtime.cells.len()
    }
}

/// Registration handle for one logical worker.
pub(super) struct WorkerHandle {
    id: usize,
    runtime: Arc<Runtime>,
}

impl WorkerHandle {
    pub(super) fn id(&self) -> usize {
        self.id
    }

    /// Register `body` with the shared runtime (no thread is spawned —
    /// the server drives the body during `collect`).
    pub(super) fn serve(self, body: Box<dyn WorkerBody>) {
        let rng = self.runtime.faults.rng_for(self.id);
        *lock(&self.runtime.cells[self.id].driver) = Some(Driver { body, rng });
    }
}

/// Build the pooled star: the arena and cells are preallocated here; the
/// gradient buffers themselves grow to `d` on each worker's first emit
/// and are reused afterwards.
pub(super) fn star(
    n: usize,
    faults: FaultModel,
    par: Parallelism,
) -> (Server, Vec<WorkerHandle>) {
    let cells = (0..n)
        .map(|_| Cell {
            driver: Mutex::new(None),
            slot: Mutex::new(GradSlot {
                round: 0,
                fresh: false,
                grad: Vec::new(),
            }),
        })
        .collect();
    let runtime = Arc::new(Runtime {
        cells,
        faults,
        par,
        shutdown: AtomicBool::new(false),
    });
    let handles = (0..n)
        .map(|id| WorkerHandle {
            id,
            runtime: Arc::clone(&runtime),
        })
        .collect();
    (
        Server {
            runtime,
            pending: None,
            drive: DriveState::default(),
        },
        handles,
    )
}
