//! The pooled batched backend: `n` logical workers multiplexed over the
//! crate's shared [`ThreadPool`] (via a [`Parallelism`] handle) instead of
//! `n` OS threads and `2n` mpsc channels.
//!
//! Per-round shared state, preallocated once:
//!
//! * **broadcast slot** — the server stores `(round, Arc<params>)` on
//!   [`Server::broadcast`]; nothing is sent anywhere.
//! * **gradient arena** — one [`GradSlot`] per worker (a reusable `Vec<f32>`
//!   plus a round tag and freshness flag). Worker `i` writes only slot `i`,
//!   so slots never contend; the per-slot `Mutex` is uncontended and exists
//!   to keep the server/worker hand-off safe without `unsafe`.
//!
//! Collection runs as an **incremental session** (the
//! `collect_begin`/`collect_step` API of [`super::ServerEndpoint`]): each
//! step advances the **time-sliced drive** by one [`SLICE_US`]-microsecond
//! virtual slice, stepping every still-running worker body
//! ([`WorkerBody::step_to`]) to the completed-work fraction its
//! [`ComputeCost`](super::ComputeCost) implies at the current virtual
//! time. Bodies that finish a slice emit through the fault-model
//! [`Emitter`](super::Emitter) and are queued for delivery in
//! **completion order** (finishing slice, ties broken by ascending worker
//! index — the order a real parameter server would see arrivals), then
//! delivered to the step's callback while the session's quorum cap
//! (`expect`) has room. The session reports
//!
//! * `Quorum` as soon as `expect` gradients were accepted (the first-m
//!   race: the caller may stop here and abandon stragglers
//!   mid-computation — their remaining work is never executed — or lift
//!   the cap with `collect_extend` and keep stepping to salvage late
//!   arrivals), and
//! * `Exhausted` when the collect timeout — interpreted in virtual
//!   microseconds — expires (a worker whose simulated cost exceeds the
//!   timeout deterministically misses the round), or every worker
//!   finished, or the runtime shut down.
//!
//! Each step's slice fan-out can co-schedule **one auxiliary task** (the
//! `aux` hook): the coordinator's prefix-overlap mode uses it to run one
//! combine+update chunk on the same pool fan-out that steps the
//! stragglers, overlapping the O(d) aggregation tail with the remaining
//! collection. Exactly one aux task per slice keeps the late-acceptance
//! window a deterministic function of the chunk count — independent of
//! the thread count.
//!
//! Because the clock is virtual and the per-slice step order never feeds
//! back into the results, a seeded run is bit-identical for every thread
//! count, and identical to the threaded backend whenever the cost gaps
//! are decisive. With the cost model disabled (`base_us = 0`) every
//! worker completes in the first slice and the drive degenerates to the
//! old run-to-completion fan-out. Steady state: zero allocations, zero
//! channel operations, zero thread spawns per round (the drive's
//! `running`/`done`/`ready` scratch is reused across rounds).
//!
//! Because bodies run *on* the pool, a body must not submit nested
//! parallel regions to the same pool (see `runtime::pool` reentrancy
//! note) — the launcher hands pooled workers a sequential [`Parallelism`]
//! for their intra-gradient sharding.
//!
//! [`ThreadPool`]: crate::runtime::ThreadPool
//! [`WorkerBody::step_to`]: super::WorkerBody::step_to

use super::{lock, CollectStatus, Emitter, EmitterSink, FaultModel, StepOutcome, WorkerBody};
use crate::runtime::Parallelism;
use crate::util::Rng64;
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
// wall-clock: Instant appears in this virtual-clock backend ONLY as the
// collect safety net (see Session::wall_deadline); the drive itself runs
// on `t_virtual` and must never read real time.
use std::time::{Duration, Instant};

/// Virtual-clock granularity of the time-sliced drive, microseconds. One
/// slice is one pool fan-out over the still-running workers; smaller
/// slices resolve finer cost differences at more fan-out overhead. Cost
/// models are expressed in hundreds-to-thousands of µs, so 50 µs keeps
/// quantisation error under a few percent.
pub(crate) const SLICE_US: u64 = 50;

/// One worker's arena slot: the last gradient it emitted, tagged with the
/// round it answers. `fresh` is cleared when the server consumes the slot
/// so a gradient is delivered at most once (mirrors message consumption).
/// Under a non-raw gradient codec ([`super::Emitter::send_coded`])
/// the payload lands encoded in `enc` (tagged by `coded`) and the server
/// decodes it into `grad` in place at delivery — both buffers are arena
/// memory, reused across rounds.
pub(super) struct GradSlot {
    pub(super) round: u64,
    pub(super) fresh: bool,
    pub(super) grad: Vec<f32>,
    /// Encoded payload buffer (empty on the raw path).
    pub(super) enc: Vec<u8>,
    /// `Some((codec, coordinate count))` when `enc` carries the payload.
    pub(super) coded: Option<(crate::codec::CodecKind, usize)>,
}

/// Consume a fresh slot: clears `fresh` and, when the payload crossed
/// encoded, decodes it into `grad` in place. Returns whether `grad` now
/// holds a deliverable gradient — a payload that fails decode is consumed
/// *silently* (no callback, no quorum slot), the in-process analogue of
/// the socket transport's CODEC reject.
fn slot_gradient(slot: &mut GradSlot) -> bool {
    slot.fresh = false;
    match slot.coded.take() {
        None => true,
        Some((codec, count)) => {
            slot.grad.clear();
            crate::codec::decode(codec, 0, count, &slot.enc, &mut slot.grad).is_ok()
        }
    }
}

/// A registered logical worker: its body plus its private fault RNG
/// (seeded identically to the threaded backend's per-thread RNG).
struct Driver {
    body: Box<dyn WorkerBody>,
    rng: Rng64,
}

/// Per-worker cell. The two Mutexes are uncontended by construction —
/// exactly one pool task touches worker `i` during a drive slice, and the
/// server only reads slots between slices (after the slice's completion
/// barrier).
struct Cell {
    driver: Mutex<Option<Driver>>,
    slot: Mutex<GradSlot>,
}

/// State shared between the server and the worker registration handles.
struct Runtime {
    cells: Vec<Cell>,
    faults: FaultModel,
    par: Parallelism,
    shutdown: AtomicBool,
    /// Two-level mode (`groups > 1`): installed by the launcher via
    /// [`super::ServerEndpoint::install_group_reducer`]. When set, worker
    /// emits fold into the reducer's per-group slots and the arena slot
    /// carries only an empty "delivered" marker (see [`super::Emitter`]).
    group: Mutex<Option<Arc<crate::gar::GroupReducer>>>,
}

/// The server's reusable drive scratch (no per-round allocation in the
/// steady state).
#[derive(Default)]
struct DriveState {
    /// Worker ids still computing this round, ascending; compacted as
    /// workers finish.
    running: Vec<usize>,
    /// Per-worker finished flag for the current slice's fan-out.
    done: Vec<AtomicBool>,
    /// Finishers harvested in completion order but not yet delivered
    /// (delivery is capped at the session's `expect`; `collect_extend`
    /// lifts the cap so a late window can drain the queue).
    ready: VecDeque<usize>,
}

/// One in-flight incremental collection (`collect_begin` ..
/// `collect_finish`).
struct Session {
    /// Round being collected; stale slots are discarded.
    round: u64,
    /// Quorum cap: delivery stops consuming finishers once this many were
    /// accepted. `usize::MAX` after `collect_extend`.
    expect: usize,
    /// Collect timeout in virtual microseconds.
    virtual_deadline: u64,
    // wall-clock: safety net against pathological real compute costs —
    // the only real-time state in this backend.
    wall_deadline: Option<Instant>,
    /// The virtual clock, advanced [`SLICE_US`] per step.
    t_virtual: u64,
    /// Gradients accepted so far (callback returned `true`).
    accepted: usize,
    /// The broadcast being driven (`None`: collect without a preceding
    /// broadcast — only leftover fresh slots can be delivered).
    drive: Option<(u64, Arc<Vec<f32>>)>,
    /// Driving is over (deadline, every worker finished, or shutdown).
    done: bool,
    /// The one-time index-order sweep of leftover fresh slots ran.
    swept: bool,
}

/// Pooled server half.
pub(super) struct Server {
    runtime: Arc<Runtime>,
    /// The broadcast slot: filled by `broadcast`, consumed (driven) by the
    /// next collection. A re-broadcast before a collect supersedes the
    /// previous round — the synchronous coordinator never does this.
    pending: Option<(u64, Arc<Vec<f32>>)>,
    drive: DriveState,
    session: Option<Session>,
}

impl Server {
    pub(super) fn broadcast(&mut self, round: u64, params: Arc<Vec<f32>>) {
        self.pending = Some((round, params));
    }

    pub(super) fn collect_begin(&mut self, round: u64, expect: usize, timeout: Duration) {
        let n = self.runtime.cells.len();
        let broadcast = self.pending.take();
        self.drive.running.clear();
        self.drive.ready.clear();
        if broadcast.is_some() && !self.runtime.shutdown.load(Ordering::Acquire) {
            self.drive.running.extend(0..n);
        }
        while self.drive.done.len() < n {
            self.drive.done.push(AtomicBool::new(false));
        }
        // Quorum-slot accounting starts from a clean drive: one done flag
        // per worker, no finisher left over from an abandoned session.
        crate::strict_assert!(self.drive.done.len() >= n && self.drive.ready.is_empty());
        self.session = Some(Session {
            round,
            expect,
            virtual_deadline: timeout.as_micros().min(u128::from(u64::MAX)) as u64,
            // wall-clock: arms the safety net; the drive never reads it
            // except in the one guarded check below.
            wall_deadline: Instant::now().checked_add(timeout),
            t_virtual: 0,
            accepted: 0,
            drive: broadcast,
            done: false,
            swept: false,
        });
    }

    /// Advance the session by one drive slice, delivering queued/new
    /// finishers (below the quorum cap) to `on_gradient` — see the module
    /// docs. `aux`, when present, is co-scheduled as one extra task on the
    /// slice's pool fan-out (it runs only on slices that actually step
    /// workers).
    pub(super) fn collect_step(
        &mut self,
        on_gradient: &mut dyn FnMut(usize, &[f32]) -> bool,
        aux: Option<&(dyn Fn() + Sync)>,
    ) -> CollectStatus {
        let rt = Arc::clone(&self.runtime);
        let Some(sess) = self.session.as_mut() else {
            return CollectStatus::Exhausted;
        };
        let drive = &mut self.drive;
        // Queued finishers from earlier slices first (completion order).
        deliver_ready(&rt, drive, sess, on_gradient);
        if sess.accepted >= sess.expect {
            return CollectStatus::Quorum;
        }
        // One virtual slice, if anything is still running.
        if sess.done || drive.running.is_empty() {
            sess.done = true;
        } else if rt.shutdown.load(Ordering::Acquire) {
            sess.done = true;
        } else if let Some((drive_round, params)) = &sess.drive {
            sess.t_virtual = sess.t_virtual.saturating_add(SLICE_US);
            let t_virtual = sess.t_virtual;
            let drive_round = *drive_round;
            // Arena slot ownership: the fan-out below gives pool task `k`
            // exclusive access to cell `running[k]`, which requires the
            // running list to be duplicate-free (ascending ⇒ no dups).
            crate::strict_assert!(drive.running.windows(2).all(|w| w[0] < w[1]));
            {
                let running = &drive.running[..];
                let done = &drive.done[..];
                let params: &[f32] = params;
                // Two-level mode: clone the reducer handle once per slice
                // (outside the fan-out) so every task shares it without
                // touching the runtime's mutex on the hot path.
                let group = lock(&rt.group).clone();
                let group = group.as_deref();
                let extra = usize::from(aux.is_some());
                rt.par.run_sharded(running.len() + extra, &|k| {
                    if k >= running.len() {
                        // The co-scheduled auxiliary task (one per slice;
                        // the prefix-overlap combine chunk).
                        if let Some(aux) = aux {
                            aux();
                        }
                        return;
                    }
                    let i = running[k];
                    crate::strict_assert!(i < rt.cells.len());
                    let cell = &rt.cells[i];
                    let mut guard = lock(&cell.driver);
                    let (finished, panicked) = match guard.as_mut() {
                        // Unregistered or silenced: nothing to drive.
                        None => (true, false),
                        Some(driver) => {
                            let cost = rt.faults.cost.cost_us_for(i);
                            let target = if cost == 0 {
                                1.0
                            } else {
                                (t_virtual as f64 / cost as f64).min(1.0)
                            };
                            let Driver { body, rng } = driver;
                            let mut emit = Emitter {
                                worker: i,
                                faults: rt.faults,
                                rng,
                                sink: EmitterSink::Slot(&cell.slot),
                                group,
                            };
                            match catch_unwind(AssertUnwindSafe(|| {
                                body.step_to(drive_round, params, &mut emit, target)
                            })) {
                                Ok(StepOutcome::Done) => (true, false),
                                Ok(StepOutcome::Working) => (false, false),
                                Err(_) => (true, true),
                            }
                        }
                    };
                    if panicked {
                        // Crash-fault semantics, matching the threaded
                        // backend where a panicking body kills only its
                        // worker thread: silence this logical worker
                        // permanently and let the server's
                        // missing-gradient fallback handle it.
                        *guard = None;
                    }
                    done[i].store(finished, Ordering::Release);
                });
            }
            // Harvest: queue this slice's finishers in ascending worker
            // index (completion order = finishing slice, then index) and
            // compact `running` in place (`retain` visits front-to-back
            // and preserves order).
            {
                let DriveState { running, done, ready } = drive;
                running.retain(|&i| {
                    if done[i].load(Ordering::Acquire) {
                        // A worker finishes exactly once — it left
                        // `running` the slice it was queued.
                        crate::strict_assert!(!ready.contains(&i));
                        ready.push_back(i);
                        false
                    } else {
                        true
                    }
                });
            }
            if drive.running.is_empty() || t_virtual >= sess.virtual_deadline {
                sess.done = true; // stragglers deterministically miss the round
            }
            // wall-clock: the safety-net check — the single place the
            // virtual drive consults real time.
            if sess.wall_deadline.is_some_and(|d| Instant::now() >= d) {
                sess.done = true;
            }
        } else {
            // Collect without a preceding broadcast: nothing to drive.
            sess.done = true;
        }
        deliver_ready(&rt, drive, sess, on_gradient);
        // Once driving is over, sweep any remaining fresh slots for the
        // round in worker-index order: completion-order ties past the
        // quorum that a retried or capless collect may still want, or a
        // collect without a broadcast. Normally finds nothing.
        if sess.done && !sess.swept && drive.ready.is_empty() {
            sess.swept = true;
            for (i, cell) in rt.cells.iter().enumerate() {
                if sess.accepted >= sess.expect {
                    break;
                }
                let mut slot = lock(&cell.slot);
                if slot.fresh
                    && slot.round == sess.round
                    && slot_gradient(&mut slot)
                    && on_gradient(i, &slot.grad)
                {
                    sess.accepted += 1;
                }
            }
        }
        if sess.accepted >= sess.expect {
            CollectStatus::Quorum
        } else if sess.done && drive.ready.is_empty() {
            CollectStatus::Exhausted
        } else {
            CollectStatus::Pending
        }
    }

    pub(super) fn collect_extend(&mut self) {
        if let Some(sess) = self.session.as_mut() {
            sess.expect = usize::MAX;
        }
    }

    pub(super) fn collect_virtual_us(&self) -> u64 {
        self.session.as_ref().map_or(0, |s| s.t_virtual)
    }

    pub(super) fn collect_accepted(&self) -> usize {
        self.session.as_ref().map_or(0, |s| s.accepted)
    }

    pub(super) fn collect_finish(&mut self) {
        // Abandon the session: stragglers never execute their remaining
        // work; undelivered fresh slots go stale at the next broadcast.
        self.session = None;
        self.drive.running.clear();
        self.drive.ready.clear();
    }

    pub(super) fn install_group_reducer(&mut self, reducer: Arc<crate::gar::GroupReducer>) {
        *lock(&self.runtime.group) = Some(reducer);
    }

    pub(super) fn shutdown(&self) {
        self.runtime.shutdown.store(true, Ordering::Release);
        for cell in &self.runtime.cells {
            lock(&cell.driver).take();
        }
    }

    pub(super) fn num_workers(&self) -> usize {
        self.runtime.cells.len()
    }
}

/// Deliver queued finishers (completion order) while the quorum cap has
/// room. A rejected gradient (callback returns `false`) is consumed but
/// does not fill an `expect` slot; a finisher whose slot is stale or
/// empty (dropped message, silent body) is consumed without a callback.
fn deliver_ready(
    rt: &Runtime,
    drive: &mut DriveState,
    sess: &mut Session,
    on_gradient: &mut dyn FnMut(usize, &[f32]) -> bool,
) {
    while sess.accepted < sess.expect {
        let Some(i) = drive.ready.pop_front() else {
            break;
        };
        let mut slot = lock(&rt.cells[i].slot);
        if slot.fresh
            && slot.round == sess.round
            && slot_gradient(&mut slot)
            && on_gradient(i, &slot.grad)
        {
            sess.accepted += 1;
        }
    }
    // Quorum-slot accounting: delivery never overshoots the cap.
    crate::strict_assert!(sess.accepted <= sess.expect);
}

/// Registration handle for one logical worker.
pub(super) struct WorkerHandle {
    id: usize,
    runtime: Arc<Runtime>,
}

impl WorkerHandle {
    pub(super) fn id(&self) -> usize {
        self.id
    }

    /// Register `body` with the shared runtime (no thread is spawned —
    /// the server drives the body during `collect`).
    pub(super) fn serve(self, body: Box<dyn WorkerBody>) {
        let rng = self.runtime.faults.rng_for(self.id);
        *lock(&self.runtime.cells[self.id].driver) = Some(Driver { body, rng });
    }
}

/// Build the pooled star: the arena and cells are preallocated here; the
/// gradient buffers themselves grow to `d` on each worker's first emit
/// and are reused afterwards.
pub(super) fn star(
    n: usize,
    faults: FaultModel,
    par: Parallelism,
) -> (Server, Vec<WorkerHandle>) {
    let cells = (0..n)
        .map(|_| Cell {
            driver: Mutex::new(None),
            slot: Mutex::new(GradSlot {
                round: 0,
                fresh: false,
                grad: Vec::new(),
                enc: Vec::new(),
                coded: None,
            }),
        })
        .collect();
    let runtime = Arc::new(Runtime {
        cells,
        faults,
        par,
        shutdown: AtomicBool::new(false),
        group: Mutex::new(None),
    });
    let handles = (0..n)
        .map(|id| WorkerHandle {
            id,
            runtime: Arc::clone(&runtime),
        })
        .collect();
    (
        Server {
            runtime,
            pending: None,
            drive: DriveState::default(),
            session: None,
        },
        handles,
    )
}
