//! In-process simulated cluster transport.
//!
//! The paper's evaluation runs on a single machine; what matters for
//! Byzantine resilience is the *values* workers send, not the wire. This
//! module provides the parameter-server ⇄ worker message fabric with
//! injectable, seeded network faults (per-message delay and drop) so the
//! coordinator's timeout/fallback paths are exercised like they would be
//! on a real deployment (see DESIGN.md §Substitutions).
//!
//! Topology: a star. The server holds one [`ServerEndpoint`]; each logical
//! worker is represented by a [`WorkerEndpoint`] onto which the caller
//! installs a [`WorkerBody`] — the per-round gradient computation —
//! via [`WorkerEndpoint::serve`]. Parameters travel behind an `Arc` (no
//! per-worker copy of a 10⁷-float model); gradients come back through the
//! body's [`Emitter`], which applies the [`FaultModel`] on the way up.
//!
//! Two interchangeable backends implement the fabric
//! ([`TransportKind`], the `transport` config knob):
//!
//! * **`threaded`** — the classic simulation: one OS thread plus a pair of
//!   std-mpsc channels per worker. Faithful asynchrony (workers really do
//!   run concurrently, stragglers really do race the collect timeout) but
//!   caps realistic experiments at a few dozen workers.
//! * **`pooled`** (default) — the scaling backend: `n` *logical* workers
//!   multiplexed over the crate's [`runtime::pool::ThreadPool`]. A round
//!   uses one shared broadcast slot (round number + `Arc` params) and a
//!   preallocated per-worker gradient arena with one disjoint slot per
//!   worker — zero per-message allocations and zero channel sends on the
//!   hot path, so 128–512 logical workers cost buffers, not OS threads.
//!   The server *drives* the logical workers inside
//!   [`ServerEndpoint::collect`] with a **time-sliced drive**: bodies
//!   advance in cost-bounded steps ([`WorkerBody::step_to`]) along a
//!   virtual clock, gradients are delivered in **completion order** (the
//!   slice a worker finished in, ties broken by worker index), and the
//!   drive stops as soon as `expect` gradients arrived or the timeout —
//!   interpreted in *virtual* microseconds — expires. A straggler under
//!   the [`ComputeCost`] model is therefore preempted mid-computation
//!   exactly like a real slow machine racing a deadline, and its
//!   remaining work is never executed (the first-m latency win is real
//!   CPU time, not bookkeeping).
//!
//! Straggler *races* are driven by the deterministic per-worker
//! [`ComputeCost`] model: on the pooled backend cost is virtual time (a
//! seeded run is bit-identical for every thread count), on the threaded
//! backend the same cost is a real pre-compute sleep, so both backends
//! leave the same workers behind when the cost gaps are decisive.
//!
//! Both backends preserve the same observable semantics: broadcast →
//! collect with timeout, fault-model delay/drop on the worker → server
//! direction, and stale-round discard. Collection itself is an
//! **incremental session** ([`ServerEndpoint::collect_begin`] /
//! [`collect_step`](ServerEndpoint::collect_step) /
//! [`collect_finish`](ServerEndpoint::collect_finish)) that yields
//! accepted gradients in completion order and reports
//! [`CollectStatus::Quorum`] at the `expect` cap — the one-shot
//! [`ServerEndpoint::collect_with`] is a wrapper over it, and the
//! coordinator's prefix-overlap mode keeps the session open past the
//! quorum to co-schedule combine work with the remaining drive
//! ([`ServerEndpoint::collect_step_aux`]) and salvage late arrivals.
//! A third backend leaves the process: **`socket`** ([`socket`] module)
//! runs workers over TCP or Unix domain sockets speaking the
//! length-prefixed binary frame protocol specified in
//! `docs/wire-protocol.md` (magic, version, round id, worker id,
//! payload kind + length, FNV-1a payload checksum). Gradients stream as
//! chunk frames, collection mirrors the threaded backend's wall-clock
//! session, and workers are either in-process client threads
//! (self-hosted, the test/CI mode) or separate `multibulyan worker`
//! processes ([`SocketOptions`]). All three backends pass the shared
//! conformance suite in `rust/tests/transport_conformance.rs` as well
//! as the test harness at the bottom of this file.
//!
//! [`runtime::pool::ThreadPool`]: crate::runtime::ThreadPool

#![deny(missing_docs)]

mod pooled;
/// The wire transport (`transport = "socket"`): the frame codec, the
/// server/accept machinery and the worker-side client of
/// `docs/wire-protocol.md`, over TCP or Unix domain sockets.
pub mod socket;
mod threaded;

pub use socket::SocketOptions;

use crate::runtime::Parallelism;
use crate::util::Rng64;
use std::sync::{Mutex, MutexGuard};
use std::time::Duration;

/// Worker → server message: one gradient proposal.
#[derive(Debug, Clone)]
pub struct FromWorker {
    /// Sending worker's id.
    pub worker: usize,
    /// Round the gradient was computed for (stale rounds are discarded
    /// by the collect session).
    pub round: u64,
    /// The proposed gradient (empty when `coded` carries the payload).
    pub gradient: Vec<f32>,
    /// Set when the gradient crossed the transport in encoded form
    /// ([`Emitter::send_coded`] with a non-raw codec): the server decodes
    /// it at delivery and rejects a failing payload without letting it
    /// occupy a first-m quorum slot.
    pub coded: Option<CodedGradient>,
}

/// An encoded gradient payload in flight (the threaded backend's channel
/// message; the pooled backend stores the same triple in its arena slot
/// and the socket backend tags each GradientChunk frame instead).
#[derive(Debug, Clone)]
pub struct CodedGradient {
    /// Codec the bytes were produced by (decides [`crate::codec::decode`]).
    pub codec: crate::codec::CodecKind,
    /// Number of f32 coordinates the payload must decode to.
    pub count: usize,
    /// The encoded payload.
    pub bytes: Vec<u8>,
}

/// Network fault model (applied on the worker → server direction, where a
/// loss actually affects the round; a server → worker loss manifests the
/// same way — a missing gradient).
#[derive(Debug, Clone, Copy, Default)]
pub struct FaultModel {
    /// Mean one-way delay, microseconds (jittered U(0.5×, 1.5×)). On the
    /// threaded backend all workers sleep concurrently; on the pooled
    /// backend the sleeps occupy the driving pool threads, so per-round
    /// delay accumulates as ≈ n·delay/threads — prefer the threaded
    /// backend for experiments about *concurrent* network latency.
    pub delay_us: u64,
    /// Per-message drop probability.
    pub drop_prob: f64,
    /// Seed for the fault RNG.
    pub seed: u64,
    /// Deterministic per-worker simulated compute cost (straggler model).
    pub cost: ComputeCost,
    /// Scripted membership churn (elastic-cluster simulation): workers
    /// the model marks absent for a round emit nothing that round, like
    /// a cleanly departed machine. See [`ChurnModel`].
    pub churn: ChurnModel,
}

/// Scripted join/leave churn for the in-process backends — the
/// deterministic counterpart of the socket backend's live
/// Goodbye/crash-detected departure tracking. The first `leave_workers`
/// worker ids leave the cluster at `leave_round` (inclusive) and, if
/// `rejoin_round` is nonzero, rejoin at `rejoin_round` (inclusive).
/// The zero value (`Default`) scripts no churn at all.
///
/// Enforcement is at the [`Emitter`]: an absent worker's `send` /
/// `send_coded` is suppressed *before* the fault RNG draws (a departed
/// machine does not roll dice), uniformly across all three backends'
/// in-process workers. The coordinator receives the same model through
/// its options and derives each round's `MembershipView` from it, so
/// collection never waits out the timeout for a scripted absentee.
/// Low worker ids are deliberately the leavers, mirroring
/// [`ComputeCost::slow_workers`]: a path that silently favours
/// low-index workers gets caught immediately.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ChurnModel {
    /// First round (1-based, inclusive) the leavers are absent;
    /// 0 scripts no churn.
    pub leave_round: u64,
    /// How many workers (ids `0..leave_workers`) leave.
    pub leave_workers: usize,
    /// First round (inclusive) the leavers are back; 0 = never.
    pub rejoin_round: u64,
}

impl ChurnModel {
    /// Whether this model scripts no churn at all (every worker present
    /// in every round — the fixed-fleet fast path).
    pub fn is_static(&self) -> bool {
        self.leave_round == 0 || self.leave_workers == 0
    }

    /// Whether `worker` participates in `round`.
    pub fn present(&self, worker: usize, round: u64) -> bool {
        if self.is_static() || worker >= self.leave_workers {
            return true;
        }
        round < self.leave_round || (self.rejoin_round != 0 && round >= self.rejoin_round)
    }
}

/// Deterministic per-worker simulated compute-cost model — the straggler
/// knob. A worker's per-round gradient computation is assigned a cost in
/// *simulated microseconds*: on the pooled backend the cost is pure
/// virtual time (the time-sliced drive advances every worker along a
/// shared virtual clock, so races against the collect deadline are
/// bit-reproducible for every thread count); on the threaded backend the
/// same cost is a real `thread::sleep` before the gradient computation,
/// so stragglers race the wall-clock timeout for real. With decisive cost
/// gaps both backends leave the same workers behind, keeping seeded runs
/// transport-independent.
///
/// Cross-backend bit-identity caveat: a pooled straggler abandoned
/// mid-round never reaches `Emitter::send`, so its fault RNG is not
/// advanced, while the threaded worker eventually emits a (discarded)
/// stale message and does draw. The two backends therefore stay
/// bit-identical under the cost model as long as the fault RNG is inert
/// (`drop_prob = 0` and `delay_us = 0`, the usual straggler-experiment
/// setting) or no worker is ever abandoned; combining first-m races with
/// message drops makes the drop *pattern* — not the physics —
/// backend-dependent.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ComputeCost {
    /// Baseline per-round compute cost in simulated microseconds
    /// (0 disables the model entirely: every worker completes in the
    /// first drive slice, the pre-cost-model behaviour).
    pub base_us: u64,
    /// The first `slow_workers` worker ids are stragglers. (Low indices
    /// are deliberately the slow ones: a collection path that favours
    /// low-index workers — the pre-time-slice pooled scan did — is
    /// immediately caught by the cross-backend tests.)
    pub slow_workers: usize,
    /// Cost multiplier for stragglers (clamped to ≥ 1).
    pub slow_factor: f32,
}

impl ComputeCost {
    /// Simulated compute cost of one round for `worker`, microseconds.
    pub fn cost_us_for(&self, worker: usize) -> u64 {
        if self.base_us == 0 {
            return 0;
        }
        if worker < self.slow_workers {
            (self.base_us as f64 * f64::from(self.slow_factor.max(1.0))).round() as u64
        } else {
            self.base_us
        }
    }
}

/// Progress report of an incremental collection session (the
/// `collect_begin`/`collect_step` API of [`ServerEndpoint`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CollectStatus {
    /// More progress is possible: workers are still running (pooled) or
    /// the deadline has not passed (threaded) — step again.
    Pending,
    /// The session's quorum cap (`expect` accepted gradients) is met. The
    /// caller may stop collecting — abandoning stragglers exactly like
    /// the one-shot `collect_with` — or lift the cap with
    /// [`ServerEndpoint::collect_extend`] and keep stepping to salvage
    /// late arrivals while doing other work.
    Quorum,
    /// Collection is over below the cap: the timeout expired, every
    /// worker finished, the channel hung up, or the runtime shut down.
    Exhausted,
}

/// How many gradients a round's collection waits for (the `collect`
/// config knob / `--collect` CLI flag).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CollectMode {
    /// Wait (up to the round timeout) for every honest worker — the
    /// conservative default; stragglers are only lost to the timeout or
    /// the fault model.
    #[default]
    All,
    /// The paper's synchronous model (§I, and Blanchard et al. 2017):
    /// return as soon as the fastest `m = n − f` gradients arrived;
    /// stragglers fall through the server's last-good cache. This is what
    /// exhibits the m/n slowdown the paper proves.
    FirstM,
}

impl CollectMode {
    /// Every collect mode, in display order (test/bench sweeps).
    pub const ALL: [CollectMode; 2] = [CollectMode::All, CollectMode::FirstM];

    /// The knob spelling (`all` / `first-m`).
    pub fn as_str(self) -> &'static str {
        match self {
            CollectMode::All => "all",
            CollectMode::FirstM => "first-m",
        }
    }
}

impl std::fmt::Display for CollectMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl std::str::FromStr for CollectMode {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "all" => Ok(CollectMode::All),
            "first-m" | "first_m" | "firstm" => Ok(CollectMode::FirstM),
            other => anyhow::bail!("unknown collect mode '{other}' (first-m|all)"),
        }
    }
}

impl FaultModel {
    /// The per-worker fault RNG — one deterministic stream per worker id,
    /// identical across backends so a seeded run drops the same messages
    /// on either transport.
    fn rng_for(&self, worker: usize) -> Rng64 {
        Rng64::seed_from_u64(
            self.seed
                .wrapping_add(worker as u64)
                .wrapping_mul(0x9E3779B97F4A7C15),
        )
    }
}

/// Which transport backend a cluster runs on (the `transport` config
/// knob / `--transport` CLI flag).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TransportKind {
    /// One OS thread + one mpsc channel pair per worker.
    Threaded,
    /// Logical workers multiplexed over the shared thread pool (default).
    #[default]
    Pooled,
    /// Real sockets (TCP/UDS) speaking the `docs/wire-protocol.md`
    /// frame protocol; workers are in-process client threads or
    /// separate `multibulyan worker` processes (see [`socket`]).
    Socket,
}

impl TransportKind {
    /// Every backend, in display order (test/bench sweeps run the
    /// shared suites over all of these).
    pub const ALL: [TransportKind; 3] = [
        TransportKind::Threaded,
        TransportKind::Pooled,
        TransportKind::Socket,
    ];

    /// The knob spelling (`threaded` / `pooled` / `socket`).
    pub fn as_str(self) -> &'static str {
        match self {
            TransportKind::Threaded => "threaded",
            TransportKind::Pooled => "pooled",
            TransportKind::Socket => "socket",
        }
    }
}

impl std::fmt::Display for TransportKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl std::str::FromStr for TransportKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "threaded" => Ok(TransportKind::Threaded),
            "pooled" => Ok(TransportKind::Pooled),
            "socket" => Ok(TransportKind::Socket),
            other => anyhow::bail!("unknown transport '{other}' (threaded|pooled|socket)"),
        }
    }
}

/// The per-round behaviour of a logical worker: called once per broadcast
/// with the round number and current parameters; responds by calling
/// [`Emitter::send`] zero or more times (zero = a silent/crashed worker,
/// handled by the server's timeout/fallback path).
///
/// On the threaded backend the body runs on its worker's dedicated OS
/// thread; on the pooled backend it runs as a task on the shared thread
/// pool, so it must not submit parallel regions to that same pool
/// (the pool is not reentrant — see `runtime::pool`).
pub trait WorkerBody: Send {
    /// Run one round: compute whatever this worker proposes for `round`
    /// at `params` and deliver it through `emit` (zero sends = a
    /// silent/crashed worker).
    fn on_round(&mut self, round: u64, params: &[f32], emit: &mut Emitter<'_>);

    /// Cost-bounded stepping — how the pooled backend's time-sliced drive
    /// runs a body. `target ∈ [0, 1]` is the fraction of this round's
    /// work the body should have completed when the call returns; it is
    /// monotone within a round (the drive derives it from the virtual
    /// clock and the worker's [`ComputeCost`]). A call with a *new*
    /// `round` abandons any partial work from the previous round (the
    /// drive may stop stepping a straggler mid-round once enough
    /// gradients arrived — that abandoned work is never executed).
    /// `target = 1.0` must finish the round and emit.
    ///
    /// The default implementation cannot chunk the computation, so it
    /// defers *all* work to the completing call (`target ≥ 1.0`): the
    /// worker still finishes at the right virtual time, and an abandoned
    /// round costs nothing. Chunkable bodies (the quadratic
    /// [`GradWorker`](crate::worker::GradWorker)) override this to spread
    /// the real work across slices.
    fn step_to(
        &mut self,
        round: u64,
        params: &[f32],
        emit: &mut Emitter<'_>,
        target: f64,
    ) -> StepOutcome {
        if target >= 1.0 {
            self.on_round(round, params, emit);
            StepOutcome::Done
        } else {
            StepOutcome::Working
        }
    }
}

/// What one [`WorkerBody::step_to`] call left behind.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepOutcome {
    /// The round's computation is still in progress; step again.
    Working,
    /// The round is finished (and emitted, unless dropped/silent).
    Done,
}

/// The worker-side reply channel handed to [`WorkerBody::on_round`].
/// Applies the [`FaultModel`] (drop, then jittered delay) before
/// delivering the gradient to the server's backend-specific sink.
pub struct Emitter<'a> {
    worker: usize,
    faults: FaultModel,
    rng: &'a mut Rng64,
    sink: EmitterSink<'a>,
    /// Two-level mode (pooled backend, `groups > 1`): gradients are
    /// folded into the per-group reduction slots of this
    /// [`GroupReducer`](crate::gar::GroupReducer) instead of being
    /// buffered per worker; the arena slot then carries only an empty
    /// "delivered" notification. `None` on the flat path and on the
    /// threaded/socket backends (which ingest at the server side).
    group: Option<&'a crate::gar::GroupReducer>,
}

enum EmitterSink<'a> {
    /// Threaded backend: the worker → server mpsc channel.
    Channel(&'a std::sync::mpsc::Sender<FromWorker>),
    /// Pooled backend: this worker's arena slot.
    Slot(&'a Mutex<pooled::GradSlot>),
    /// Socket backend: the client connection — the gradient leaves as a
    /// sequence of GradientChunk frames (`docs/wire-protocol.md` §4.3),
    /// `scratch` reused as the frame buffer.
    Frame {
        stream: &'a mut socket::Stream,
        worker: u32,
        chunk: usize,
        scratch: &'a mut Vec<u8>,
    },
}

impl Emitter<'_> {
    /// This worker's id (also the shard id used by the data layer).
    pub fn worker(&self) -> usize {
        self.worker
    }

    /// Send a gradient for `round` back to the server, subject to the
    /// fault model. The slice is copied at the transport boundary; the
    /// pooled backend copies into a preallocated arena slot (no
    /// allocation in the steady state).
    pub fn send(&mut self, round: u64, gradient: &[f32]) {
        if !self.faults.churn.present(self.worker, round) {
            return; // scripted churn: departed this round, no RNG draw
        }
        if !self.faults_pass() {
            return; // dropped on the (simulated) wire
        }
        if let (Some(reducer), EmitterSink::Slot(slot)) = (self.group, &self.sink) {
            // Two-level mode: the gradient group-reduces block-by-block
            // inside the shared reducer (never buffered per worker), and
            // the worker's arena slot becomes an *empty* fresh marker so
            // the completion-order delivery machinery still fires — the
            // coordinator recognises the empty slice as a grouped-mode
            // notification and checks `GroupReducer::delivered` instead.
            // A stale-round submission leaves the slot alone, exactly
            // like the flat freshness rule below discards it.
            let outcome = reducer.ingest_full(self.worker, round, gradient);
            if !matches!(outcome, crate::gar::group::FullIngest::Stale) {
                let mut s = lock(slot);
                if !s.fresh || round >= s.round {
                    s.round = round;
                    s.fresh = true;
                    s.coded = None;
                    s.grad.clear();
                }
            }
            return;
        }
        match &mut self.sink {
            EmitterSink::Channel(tx) => {
                let _ = tx.send(FromWorker {
                    worker: self.worker,
                    round,
                    gradient: gradient.to_vec(),
                    coded: None,
                });
            }
            EmitterSink::Slot(slot) => {
                let mut s = lock(slot);
                // Never let an older round overwrite a fresher pending
                // gradient — the threaded backend delivers both messages
                // and the server discards only the stale one.
                if !s.fresh || round >= s.round {
                    s.round = round;
                    s.fresh = true;
                    s.coded = None;
                    s.grad.clear();
                    s.grad.extend_from_slice(gradient);
                }
            }
            EmitterSink::Frame {
                stream,
                worker,
                chunk,
                scratch,
            } => {
                socket::send_gradient_frames(stream, *worker, round, gradient, *chunk, scratch);
            }
        }
    }

    /// [`send`](Self::send) through a gradient codec (the `codec` config
    /// knob): `None` or a raw encoder is plain `send`; otherwise the
    /// gradient crosses the transport encoded and the server decodes it
    /// at delivery. The fault model is applied *before* encoding so a
    /// dropped message never advances stateful codec state (the `topk`
    /// error-feedback residual banks a dropped round's values only when
    /// the encoder actually ran — a drop leaves the residual untouched,
    /// exactly like a worker that never got to send).
    pub fn send_coded(
        &mut self,
        round: u64,
        gradient: &[f32],
        codec: Option<&mut dyn crate::codec::Codec>,
    ) {
        let Some(codec) = codec else {
            return self.send(round, gradient);
        };
        if codec.kind() == crate::codec::CodecKind::Raw {
            return self.send(round, gradient);
        }
        if !self.faults.churn.present(self.worker, round) {
            return; // scripted churn: departed this round, no RNG draw
        }
        if !self.faults_pass() {
            return; // dropped on the (simulated) wire, pre-encode
        }
        match &mut self.sink {
            EmitterSink::Channel(tx) => {
                let mut bytes = Vec::new();
                codec.encode(0, gradient, &mut bytes);
                let _ = tx.send(FromWorker {
                    worker: self.worker,
                    round,
                    gradient: Vec::new(),
                    coded: Some(CodedGradient {
                        codec: codec.kind(),
                        count: gradient.len(),
                        bytes,
                    }),
                });
            }
            EmitterSink::Slot(slot) => {
                let mut s = lock(slot);
                // Same freshness rule as `send`; the encoded bytes land in
                // the slot's `enc` buffer and are decoded into `grad` by
                // the server at delivery.
                if !s.fresh || round >= s.round {
                    s.round = round;
                    s.fresh = true;
                    s.grad.clear();
                    codec.encode(0, gradient, &mut s.enc);
                    s.coded = Some((codec.kind(), gradient.len()));
                }
            }
            EmitterSink::Frame {
                stream,
                worker,
                chunk,
                scratch,
            } => {
                socket::send_gradient_frames_coded(
                    stream, *worker, round, gradient, *chunk, codec, scratch,
                );
            }
        }
    }

    /// Apply the fault model: `false` means the message is dropped;
    /// otherwise the (jittered) delay has been slept out.
    fn faults_pass(&mut self) -> bool {
        if self.faults.drop_prob > 0.0 && self.rng.gen_bool(self.faults.drop_prob) {
            return false;
        }
        if self.faults.delay_us > 0 {
            let jitter = self.rng.gen_range_f32(0.5, 1.5);
            let us = (self.faults.delay_us as f32 * jitter) as u64;
            std::thread::sleep(Duration::from_micros(us));
        }
        true
    }
}

/// Server-side handle: broadcast, collect, shutdown — backend-agnostic.
pub struct ServerEndpoint {
    inner: ServerImpl,
}

enum ServerImpl {
    Threaded(threaded::Server),
    Pooled(pooled::Server),
    Socket(socket::Server),
}

impl ServerEndpoint {
    /// Announce round `round` at `params` to every worker. On the pooled
    /// backend this only fills the broadcast slot; the logical workers
    /// run when [`collect`](Self::collect) drives them.
    pub fn broadcast(&mut self, round: u64, params: std::sync::Arc<Vec<f32>>) {
        match &mut self.inner {
            ServerImpl::Threaded(s) => s.broadcast(round, params),
            ServerImpl::Pooled(s) => s.broadcast(round, params),
            ServerImpl::Socket(s) => s.broadcast(round, params),
        }
    }

    /// Open an incremental collection session for `round`: up to `expect`
    /// gradients will be accepted before [`collect_step`] reports
    /// [`CollectStatus::Quorum`], and `timeout` bounds the session
    /// (wall-clock on the threaded backend; *virtual* microseconds under
    /// the pooled backend's [`ComputeCost`] model, so a seeded race is
    /// bit-reproducible). On the pooled backend this consumes the pending
    /// broadcast — the logical workers run only while the session is
    /// stepped.
    ///
    /// [`collect_step`]: Self::collect_step
    pub fn collect_begin(&mut self, round: u64, expect: usize, timeout: Duration) {
        match &mut self.inner {
            ServerImpl::Threaded(s) => s.collect_begin(round, expect, timeout),
            ServerImpl::Pooled(s) => s.collect_begin(round, expect, timeout),
            ServerImpl::Socket(s) => s.collect_begin(round, expect, timeout),
        }
    }

    /// Advance the open session one step, delivering accepted gradients
    /// in completion order via `on_gradient` (pooled: one virtual drive
    /// slice; threaded: one bounded channel wait). The callback returns
    /// whether it *accepted* the gradient — a `false` (e.g. a malformed
    /// submission the server rejects) consumes the message but does not
    /// count toward `expect`, so a persistent bad actor cannot displace
    /// honest gradients from a first-m quorum. Stale-round gradients are
    /// discarded. `gradient` borrows transport-owned memory (the
    /// zero-copy path).
    pub fn collect_step(
        &mut self,
        mut on_gradient: impl FnMut(usize, &[f32]) -> bool,
    ) -> CollectStatus {
        self.collect_step_aux(&mut on_gradient, None)
    }

    /// [`collect_step`](Self::collect_step) with an optional auxiliary
    /// task co-scheduled alongside the collection's own progress: on the
    /// pooled backend `aux` runs as one extra task on the drive slice's
    /// pool fan-out (exactly once per slice — the prefix-overlap combine
    /// hook); on the threaded backend it runs inline before the channel
    /// poll. `aux` must be cheap relative to a slice and must not submit
    /// work to the same pool (reentrancy — see `runtime::pool`).
    pub fn collect_step_aux(
        &mut self,
        on_gradient: &mut dyn FnMut(usize, &[f32]) -> bool,
        aux: Option<&(dyn Fn() + Sync)>,
    ) -> CollectStatus {
        match &mut self.inner {
            ServerImpl::Threaded(s) => s.collect_step(on_gradient, aux),
            ServerImpl::Pooled(s) => s.collect_step(on_gradient, aux),
            ServerImpl::Socket(s) => s.collect_step(on_gradient, aux),
        }
    }

    /// Lift the open session's quorum cap: every subsequent completion is
    /// delivered (the late-acceptance window of the overlap path). The
    /// session still ends at its timeout.
    pub fn collect_extend(&mut self) {
        match &mut self.inner {
            ServerImpl::Threaded(s) => s.collect_extend(),
            ServerImpl::Pooled(s) => s.collect_extend(),
            ServerImpl::Socket(s) => s.collect_extend(),
        }
    }

    /// The open session's virtual clock, microseconds (pooled backend;
    /// always 0 on threaded, which has no virtual time). The coordinator
    /// differences this across the overlap window to report
    /// `overlap_saved_us`.
    pub fn collect_virtual_us(&self) -> u64 {
        match &self.inner {
            ServerImpl::Threaded(_) => 0,
            ServerImpl::Pooled(s) => s.collect_virtual_us(),
            // No virtual clock on real sockets, like threaded.
            ServerImpl::Socket(_) => 0,
        }
    }

    /// Gradients accepted by the open session so far.
    pub fn collect_accepted(&self) -> usize {
        match &self.inner {
            ServerImpl::Threaded(s) => s.collect_accepted(),
            ServerImpl::Pooled(s) => s.collect_accepted(),
            ServerImpl::Socket(s) => s.collect_accepted(),
        }
    }

    /// Close the session: remaining stragglers are abandoned (pooled:
    /// their unexecuted work never runs; threaded: their eventual message
    /// goes stale) exactly like the end of a one-shot `collect_with`.
    pub fn collect_finish(&mut self) {
        match &mut self.inner {
            ServerImpl::Threaded(s) => s.collect_finish(),
            ServerImpl::Pooled(s) => s.collect_finish(),
            ServerImpl::Socket(s) => s.collect_finish(),
        }
    }

    /// Collect up to `expect` gradients for `round`, calling
    /// `on_gradient(worker, gradient)` for each as it arrives; returns the
    /// number accepted. One-shot wrapper over the incremental session API
    /// (`collect_begin` + `collect_step` to quorum/exhaustion +
    /// `collect_finish`), so both paths share one set of collection
    /// semantics: completion-order delivery, accept/reject callback,
    /// stale-round discard, deadline honoured on both backends (wall
    /// clock on threaded, virtual microseconds on pooled — a worker whose
    /// simulated cost exceeds the timeout deterministically misses the
    /// round, and a straggler abandoned mid-round never executes its
    /// remaining work).
    ///
    /// This is the zero-copy path: `gradient` borrows transport-owned
    /// memory, so a full round makes no per-message allocation on the
    /// pooled backend.
    pub fn collect_with(
        &mut self,
        round: u64,
        expect: usize,
        timeout: Duration,
        mut on_gradient: impl FnMut(usize, &[f32]) -> bool,
    ) -> usize {
        self.collect_begin(round, expect, timeout);
        loop {
            match self.collect_step(&mut on_gradient) {
                CollectStatus::Pending => continue,
                CollectStatus::Quorum | CollectStatus::Exhausted => break,
            }
        }
        let got = self.collect_accepted();
        self.collect_finish();
        got
    }

    /// Owned-message convenience wrapper over
    /// [`collect_with`](Self::collect_with) (allocates per message and
    /// accepts everything; the coordinator hot path uses `collect_with`
    /// directly).
    pub fn collect(&mut self, round: u64, expect: usize, timeout: Duration) -> Vec<FromWorker> {
        let mut got = Vec::with_capacity(expect);
        self.collect_with(round, expect, timeout, |worker, gradient| {
            got.push(FromWorker {
                worker,
                round,
                gradient: gradient.to_vec(),
                coded: None,
            });
            true
        });
        got
    }

    /// Tell every worker to stop (threaded: join-free thread shutdown;
    /// pooled: drop the registered bodies so no further round runs them).
    pub fn shutdown(&self) {
        match &self.inner {
            ServerImpl::Threaded(s) => s.shutdown(),
            ServerImpl::Pooled(s) => s.shutdown(),
            ServerImpl::Socket(s) => s.shutdown(),
        }
    }

    /// Install the two-level [`GroupReducer`](crate::gar::GroupReducer)
    /// (`groups > 1`): from the next collection on, worker gradients
    /// group-reduce block-by-block inside the reducer and the per-worker
    /// delivery carries an *empty* slice as the "this worker delivered"
    /// notification — the coordinator checks
    /// [`GroupReducer::delivered`](crate::gar::GroupReducer::delivered)
    /// and reads the `g × d` result via
    /// [`GroupReducer::finalize_into`](crate::gar::GroupReducer::finalize_into).
    /// On the pooled backend the ingest happens at the worker's emitter
    /// (the arena slot shrinks to a marker); on the socket backend at
    /// chunk reassembly (whole gradients are never buffered); on the
    /// threaded backend this is a no-op — the channel already owns the
    /// vector, so the coordinator ingests full gradients at delivery.
    pub fn install_group_reducer(&mut self, reducer: std::sync::Arc<crate::gar::GroupReducer>) {
        match &mut self.inner {
            ServerImpl::Threaded(_) => {}
            ServerImpl::Pooled(s) => s.install_group_reducer(reducer),
            ServerImpl::Socket(s) => s.install_group_reducer(reducer),
        }
    }

    /// Number of logical workers this endpoint was built for (`n`).
    pub fn num_workers(&self) -> usize {
        match &self.inner {
            ServerImpl::Threaded(s) => s.num_workers(),
            ServerImpl::Pooled(s) => s.num_workers(),
            ServerImpl::Socket(s) => s.num_workers(),
        }
    }

    /// Worker ids the transport knows to have *departed*: on the socket
    /// backend these are workers that sent a Goodbye frame or whose
    /// connection died after registration (crash-detected) and have not
    /// re-Hello'd; sorted ascending. The in-process backends always
    /// return an empty list — their scripted churn is a [`ChurnModel`]
    /// the coordinator already holds, not a discovered fact. The
    /// coordinator subtracts these ids when deriving the next round's
    /// `MembershipView`.
    pub fn departed_workers(&self) -> Vec<usize> {
        match &self.inner {
            ServerImpl::Threaded(_) | ServerImpl::Pooled(_) => Vec::new(),
            ServerImpl::Socket(s) => s.departed_workers(),
        }
    }

    /// The bound listen address of the socket backend (`None` on the
    /// in-process backends). External `multibulyan worker` processes
    /// connect here; tests use it to speak raw frames at the server.
    pub fn socket_addr(&self) -> Option<&str> {
        match &self.inner {
            ServerImpl::Socket(s) => Some(s.addr()),
            _ => None,
        }
    }

    /// Which backend this endpoint runs on.
    pub fn transport(&self) -> TransportKind {
        match &self.inner {
            ServerImpl::Threaded(_) => TransportKind::Threaded,
            ServerImpl::Pooled(_) => TransportKind::Pooled,
            ServerImpl::Socket(_) => TransportKind::Socket,
        }
    }
}

/// Worker-side handle: install a [`WorkerBody`] to bring the logical
/// worker online.
pub struct WorkerEndpoint {
    inner: EndpointImpl,
}

enum EndpointImpl {
    Threaded(threaded::Worker),
    Pooled(pooled::WorkerHandle),
    Socket(socket::WorkerSlot),
}

impl WorkerEndpoint {
    /// This endpoint's logical worker id in `0..n`.
    pub fn id(&self) -> usize {
        match &self.inner {
            EndpointImpl::Threaded(w) => w.id(),
            EndpointImpl::Pooled(w) => w.id(),
            EndpointImpl::Socket(w) => w.id(),
        }
    }

    /// Install `body` and start serving rounds: spawns a dedicated OS
    /// thread on the threaded backend; registers the body with the shared
    /// runtime on the pooled backend (no thread); on the socket backend,
    /// spawns an in-process client thread that connects over the wire
    /// (or drops the body when the cluster is `external` — a separate
    /// `multibulyan worker` process owns this slot instead).
    pub fn serve(self, body: impl WorkerBody + 'static) {
        match self.inner {
            EndpointImpl::Threaded(w) => w.serve(Box::new(body)),
            EndpointImpl::Pooled(w) => w.serve(Box::new(body)),
            EndpointImpl::Socket(w) => w.serve(Box::new(body)),
        }
    }
}

/// Build a thread-per-worker star for `n` workers (the `threaded`
/// backend; see [`build`] for the knob-driven constructor).
pub fn star(n: usize, faults: FaultModel) -> (ServerEndpoint, Vec<WorkerEndpoint>) {
    let (server, workers) = threaded::star(n, faults);
    (
        ServerEndpoint {
            inner: ServerImpl::Threaded(server),
        },
        workers
            .into_iter()
            .map(|w| WorkerEndpoint {
                inner: EndpointImpl::Threaded(w),
            })
            .collect(),
    )
}

/// Build a pooled star for `n` logical workers, multiplexed over `par`
/// (`Parallelism::sequential()` drives them inline on the server thread —
/// correct, just serial).
pub fn star_pooled(
    n: usize,
    faults: FaultModel,
    par: &Parallelism,
) -> (ServerEndpoint, Vec<WorkerEndpoint>) {
    let (server, workers) = pooled::star(n, faults, par.clone());
    (
        ServerEndpoint {
            inner: ServerImpl::Pooled(server),
        },
        workers
            .into_iter()
            .map(|w| WorkerEndpoint {
                inner: EndpointImpl::Pooled(w),
            })
            .collect(),
    )
}

/// Build a socket star for `n` workers: binds the listener (or an
/// ephemeral loopback TCP port when `opts.listen` is `None`), spawns the
/// accept loop, and returns worker slots that either launch in-process
/// client threads (`serve`) or stand for external `multibulyan worker`
/// processes (`opts.external`). Fails if the address cannot be bound.
pub fn star_socket(
    n: usize,
    faults: FaultModel,
    opts: &SocketOptions,
) -> anyhow::Result<(ServerEndpoint, Vec<WorkerEndpoint>)> {
    let (server, workers) = socket::star(n, faults, opts)?;
    Ok((
        ServerEndpoint {
            inner: ServerImpl::Socket(server),
        },
        workers
            .into_iter()
            .map(|w| WorkerEndpoint {
                inner: EndpointImpl::Socket(w),
            })
            .collect(),
    ))
}

/// Build a star on the chosen backend — the infallible constructor tests
/// and benches use (`kind` is the `transport` config knob). The socket
/// arm binds an ephemeral loopback port with default options; use
/// [`build_cluster`] to pass listen/chunk knobs and surface bind errors.
pub fn build(
    kind: TransportKind,
    n: usize,
    faults: FaultModel,
    par: &Parallelism,
) -> (ServerEndpoint, Vec<WorkerEndpoint>) {
    match kind {
        TransportKind::Threaded => star(n, faults),
        TransportKind::Pooled => star_pooled(n, faults, par),
        TransportKind::Socket => star_socket(n, faults, &SocketOptions::default())
            .expect("binding an ephemeral loopback socket"),
    }
}

/// Knob-driven cluster constructor: like [`build`] but threads the socket
/// backend's [`SocketOptions`] through and surfaces bind failures instead
/// of panicking. The in-process backends ignore `socket` and cannot fail.
pub fn build_cluster(
    kind: TransportKind,
    n: usize,
    faults: FaultModel,
    par: &Parallelism,
    socket: &SocketOptions,
) -> anyhow::Result<(ServerEndpoint, Vec<WorkerEndpoint>)> {
    match kind {
        TransportKind::Threaded => Ok(star(n, faults)),
        TransportKind::Pooled => Ok(star_pooled(n, faults, par)),
        TransportKind::Socket => star_socket(n, faults, socket),
    }
}

/// Mutex lock that ignores poisoning: a panicked worker body already
/// surfaced through the pool's panic propagation; the transport state
/// itself (a gradient buffer + flags) is valid regardless.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::Instant;

    /// A test body: a plain function pointer over (id, round, params,
    /// emitter) — no closure-inference pitfalls, trivially `Send`.
    struct TestBody {
        id: usize,
        f: fn(usize, u64, &[f32], &mut Emitter<'_>),
    }

    impl WorkerBody for TestBody {
        fn on_round(&mut self, round: u64, params: &[f32], emit: &mut Emitter<'_>) {
            (self.f)(self.id, round, params, emit)
        }
    }

    /// Build a star on `kind` and install `f` as every worker's body.
    fn harness(
        kind: TransportKind,
        n: usize,
        faults: FaultModel,
        f: fn(usize, u64, &[f32], &mut Emitter<'_>),
    ) -> ServerEndpoint {
        let (server, workers) = build(kind, n, faults, &Parallelism::new(2));
        for w in workers {
            let id = w.id();
            w.serve(TestBody { id, f });
        }
        server
    }

    /// Run the same scenario on both backends.
    fn on_both(test: fn(TransportKind)) {
        for kind in TransportKind::ALL {
            test(kind);
        }
    }

    #[test]
    fn round_trip_without_faults() {
        on_both(|kind| {
            let mut server = harness(kind, 3, FaultModel::default(), |id, round, params, emit| {
                let g: Vec<f32> = params.iter().map(|p| p + id as f32).collect();
                emit.send(round, &g);
            });
            server.broadcast(1, Arc::new(vec![1.0, 2.0]));
            let got = server.collect(1, 3, Duration::from_secs(5));
            assert_eq!(got.len(), 3, "{kind}");
            let mut ids: Vec<usize> = got.iter().map(|m| m.worker).collect();
            ids.sort_unstable();
            assert_eq!(ids, vec![0, 1, 2], "{kind}");
            for m in &got {
                assert_eq!(m.gradient, vec![1.0 + m.worker as f32, 2.0 + m.worker as f32]);
            }
            server.shutdown();
        });
    }

    #[test]
    fn stale_rounds_are_discarded() {
        on_both(|kind| {
            let mut server = harness(kind, 1, FaultModel::default(), |_id, _round, _p, emit| {
                emit.send(0, &[9.0]); // stale
                emit.send(1, &[1.0]);
            });
            server.broadcast(1, Arc::new(vec![0.0]));
            let got = server.collect(1, 1, Duration::from_secs(5));
            assert_eq!(got.len(), 1, "{kind}");
            assert_eq!(got[0].gradient, vec![1.0], "{kind}");
            server.shutdown();
        });
    }

    #[test]
    fn stale_send_after_current_does_not_clobber() {
        // Reverse order of stale_rounds_are_discarded: the current-round
        // gradient must survive a later stale emit on both backends.
        on_both(|kind| {
            let mut server = harness(kind, 1, FaultModel::default(), |_id, _round, _p, emit| {
                emit.send(1, &[1.0]);
                emit.send(0, &[9.0]); // stale, after the current round
            });
            server.broadcast(1, Arc::new(vec![0.0]));
            let got = server.collect(1, 1, Duration::from_secs(5));
            assert_eq!(got.len(), 1, "{kind}");
            assert_eq!(got[0].gradient, vec![1.0], "{kind}");
            server.shutdown();
        });
    }

    #[test]
    fn body_panic_is_a_crashed_worker_not_a_server_crash() {
        // A panicking body must take down only its own logical worker
        // (threaded: the worker thread dies; pooled: the body is
        // silenced) — the server keeps collecting from the others.
        on_both(|kind| {
            let mut server = harness(kind, 3, FaultModel::default(), |id, round, _p, emit| {
                if id == 1 {
                    panic!("worker 1 crashed");
                }
                emit.send(round, &[id as f32]);
            });
            for round in 1..=2u64 {
                server.broadcast(round, Arc::new(vec![0.0]));
                let got = server.collect(round, 3, Duration::from_millis(300));
                let mut ids: Vec<usize> = got.iter().map(|m| m.worker).collect();
                ids.sort_unstable();
                assert_eq!(ids, vec![0, 2], "{kind} round {round}");
            }
            server.shutdown();
        });
    }

    #[test]
    fn full_drop_hits_timeout() {
        on_both(|kind| {
            let faults = FaultModel {
                drop_prob: 1.0,
                ..Default::default()
            };
            let mut server = harness(kind, 2, faults, |_id, round, _p, emit| {
                emit.send(round, &[1.0]);
            });
            server.broadcast(7, Arc::new(vec![0.0]));
            let got = server.collect(7, 2, Duration::from_millis(50));
            assert!(got.is_empty(), "{kind}");
            server.shutdown();
        });
    }

    #[test]
    fn delay_is_applied_but_bounded() {
        on_both(|kind| {
            let faults = FaultModel {
                delay_us: 2_000,
                ..Default::default()
            };
            let mut server = harness(kind, 1, faults, |_id, round, _p, emit| {
                emit.send(round, &[1.0]);
            });
            let t0 = Instant::now();
            server.broadcast(1, Arc::new(vec![0.0]));
            let got = server.collect(1, 1, Duration::from_secs(5));
            assert_eq!(got.len(), 1, "{kind}");
            assert!(t0.elapsed() >= Duration::from_micros(800), "{kind}");
            server.shutdown();
        });
    }

    #[test]
    fn partial_drop_delivers_some() {
        on_both(|kind| {
            let faults = FaultModel {
                drop_prob: 0.5,
                seed: 3,
                ..Default::default()
            };
            let mut server = harness(kind, 8, faults, |id, round, _p, emit| {
                emit.send(round, &[id as f32]);
            });
            server.broadcast(1, Arc::new(vec![0.0]));
            let got = server.collect(1, 8, Duration::from_millis(200));
            assert!(
                !got.is_empty() && got.len() < 8,
                "{kind}: got {}",
                got.len()
            );
            server.shutdown();
        });
    }

    #[test]
    fn drop_pattern_is_identical_across_backends() {
        // Same seed ⇒ the fault RNG drops the same workers' messages on
        // either backend — seeded experiments are transport-independent.
        let survivors = |kind: TransportKind| -> Vec<usize> {
            let faults = FaultModel {
                drop_prob: 0.4,
                seed: 11,
                ..Default::default()
            };
            let mut server = harness(kind, 16, faults, |id, round, _p, emit| {
                emit.send(round, &[id as f32]);
            });
            server.broadcast(1, Arc::new(vec![0.0]));
            let mut ids: Vec<usize> = server
                .collect(1, 16, Duration::from_millis(500))
                .iter()
                .map(|m| m.worker)
                .collect();
            server.shutdown();
            ids.sort_unstable();
            ids
        };
        let reference = survivors(TransportKind::Threaded);
        assert_eq!(reference, survivors(TransportKind::Pooled));
        assert_eq!(reference, survivors(TransportKind::Socket));
    }

    #[test]
    fn pooled_scales_to_hundreds_of_logical_workers() {
        // 256 logical workers over a 2-thread pool: the old transport
        // would need 256 OS threads for this round-trip.
        let mut server = harness(
            TransportKind::Pooled,
            256,
            FaultModel::default(),
            |id, round, params, emit| {
                let g: Vec<f32> = params.iter().map(|p| p * 2.0 + id as f32).collect();
                emit.send(round, &g);
            },
        );
        for round in 1..=3u64 {
            server.broadcast(round, Arc::new(vec![1.0, -1.0]));
            let got = server.collect(round, 256, Duration::from_secs(5));
            assert_eq!(got.len(), 256, "round {round}");
            for m in &got {
                assert_eq!(m.gradient[0], 2.0 + m.worker as f32);
            }
        }
        server.shutdown();
    }

    #[test]
    fn pooled_slot_freshness_is_per_round() {
        // A worker that answers only even rounds must not leak its old
        // gradient into the next round's collect (fresh flag + round tag).
        let mut server = harness(
            TransportKind::Pooled,
            1,
            FaultModel::default(),
            |_id, round, _p, emit| {
                if round % 2 == 0 {
                    emit.send(round, &[round as f32]);
                }
            },
        );
        server.broadcast(1, Arc::new(vec![0.0]));
        assert!(server.collect(1, 1, Duration::from_millis(10)).is_empty());
        server.broadcast(2, Arc::new(vec![0.0]));
        let got = server.collect(2, 1, Duration::from_millis(10));
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].gradient, vec![2.0]);
        server.broadcast(3, Arc::new(vec![0.0]));
        assert!(server.collect(3, 1, Duration::from_millis(10)).is_empty());
        server.shutdown();
    }

    #[test]
    fn pooled_shutdown_stops_driving_bodies() {
        let mut server = harness(
            TransportKind::Pooled,
            4,
            FaultModel::default(),
            |id, round, _p, emit| {
                emit.send(round, &[id as f32]);
            },
        );
        server.broadcast(1, Arc::new(vec![0.0]));
        assert_eq!(server.collect(1, 4, Duration::from_millis(10)).len(), 4);
        server.shutdown();
        server.broadcast(2, Arc::new(vec![0.0]));
        assert!(server.collect(2, 4, Duration::from_millis(10)).is_empty());
    }

    #[test]
    fn first_m_returns_the_fastest_workers_on_both_backends() {
        // Workers 0 and 1 are 40× stragglers; a first-m collect of 4 out
        // of 6 must deliver exactly the fast ones — on the pooled backend
        // by virtual time, on the threaded backend by a real race (the
        // 40× sleep gap makes the race's outcome deterministic).
        on_both(|kind| {
            let faults = FaultModel {
                cost: ComputeCost {
                    base_us: 500,
                    slow_workers: 2,
                    slow_factor: 40.0,
                },
                ..Default::default()
            };
            let mut server = harness(kind, 6, faults, |id, round, _p, emit| {
                emit.send(round, &[id as f32]);
            });
            server.broadcast(1, Arc::new(vec![0.0]));
            let got = server.collect(1, 4, Duration::from_secs(5));
            let mut ids: Vec<usize> = got.iter().map(|m| m.worker).collect();
            ids.sort_unstable();
            assert_eq!(ids, vec![2, 3, 4, 5], "{kind}");
            server.shutdown();
        });
    }

    #[test]
    fn pooled_first_m_is_deterministic_across_thread_counts() {
        let run = |threads: usize| -> Vec<usize> {
            let faults = FaultModel {
                cost: ComputeCost {
                    base_us: 300,
                    slow_workers: 3,
                    slow_factor: 10.0,
                },
                ..Default::default()
            };
            let (mut server, workers) =
                star_pooled(8, faults, &Parallelism::new(threads));
            for w in workers {
                let id = w.id();
                w.serve(TestBody {
                    id,
                    f: |id, round, _p, emit| emit.send(round, &[id as f32]),
                });
            }
            server.broadcast(1, Arc::new(vec![0.0]));
            let ids = server
                .collect(1, 5, Duration::from_secs(5))
                .iter()
                .map(|m| m.worker)
                .collect();
            server.shutdown();
            ids
        };
        let reference = run(1);
        assert_eq!(reference, vec![3, 4, 5, 6, 7], "fast tier, index order");
        assert_eq!(reference, run(2));
        assert_eq!(reference, run(4));
    }

    #[test]
    fn pooled_delivers_in_completion_order_not_index_order() {
        // Stragglers sit at the LOW indices, so index-order delivery
        // (the pre-time-slice scan) would lead with them; completion
        // order must lead with the fast tier.
        let faults = FaultModel {
            cost: ComputeCost {
                base_us: 400,
                slow_workers: 2,
                slow_factor: 8.0,
            },
            ..Default::default()
        };
        let mut server = harness(TransportKind::Pooled, 5, faults, |id, round, _p, emit| {
            emit.send(round, &[id as f32]);
        });
        server.broadcast(1, Arc::new(vec![0.0]));
        let ids: Vec<usize> = server
            .collect(1, 5, Duration::from_secs(5))
            .iter()
            .map(|m| m.worker)
            .collect();
        assert_eq!(ids, vec![2, 3, 4, 0, 1]);
        server.shutdown();
    }

    #[test]
    fn straggler_past_the_timeout_misses_the_round_on_both_backends() {
        // Wait-all collect with a timeout between the fast tier's cost
        // (1 ms) and the stragglers' (50 ms): both backends must leave
        // exactly the stragglers behind — virtually on pooled, by a real
        // wall-clock race on threaded.
        on_both(|kind| {
            let faults = FaultModel {
                cost: ComputeCost {
                    base_us: 1_000,
                    slow_workers: 2,
                    slow_factor: 50.0,
                },
                ..Default::default()
            };
            let mut server = harness(kind, 6, faults, |id, round, _p, emit| {
                emit.send(round, &[id as f32]);
            });
            server.broadcast(1, Arc::new(vec![0.0]));
            let got = server.collect(1, 6, Duration::from_millis(10));
            let mut ids: Vec<usize> = got.iter().map(|m| m.worker).collect();
            ids.sort_unstable();
            assert_eq!(ids, vec![2, 3, 4, 5], "{kind}");
            server.shutdown();
        });
    }

    #[test]
    fn abandoned_round_restarts_cleanly_on_the_next_broadcast() {
        // Round 1 abandons the straggler mid-computation (first-m met);
        // round 2 with a long timeout must still get a correct round-2
        // gradient from it — the partial round-1 work is discarded.
        let faults = FaultModel {
            cost: ComputeCost {
                base_us: 200,
                slow_workers: 1,
                slow_factor: 30.0,
            },
            ..Default::default()
        };
        let mut server = harness(TransportKind::Pooled, 3, faults, |id, round, _p, emit| {
            emit.send(round, &[round as f32 * 10.0 + id as f32]);
        });
        server.broadcast(1, Arc::new(vec![0.0]));
        let got = server.collect(1, 2, Duration::from_secs(5));
        let mut ids: Vec<usize> = got.iter().map(|m| m.worker).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![1, 2]);
        server.broadcast(2, Arc::new(vec![0.0]));
        let got = server.collect(2, 3, Duration::from_secs(5));
        assert_eq!(got.len(), 3);
        for m in &got {
            assert_eq!(m.gradient, vec![20.0 + m.worker as f32], "round-2 value");
        }
        server.shutdown();
    }

    #[test]
    fn compute_cost_model_is_deterministic_per_worker() {
        let cost = ComputeCost {
            base_us: 100,
            slow_workers: 2,
            slow_factor: 10.0,
        };
        assert_eq!(cost.cost_us_for(0), 1_000);
        assert_eq!(cost.cost_us_for(1), 1_000);
        assert_eq!(cost.cost_us_for(2), 100);
        // base 0 disables the model for every worker.
        let off = ComputeCost {
            base_us: 0,
            slow_workers: 2,
            slow_factor: 10.0,
        };
        assert_eq!(off.cost_us_for(0), 0);
        // factor below 1 is clamped (a "straggler" is never faster).
        let clamped = ComputeCost {
            base_us: 100,
            slow_workers: 1,
            slow_factor: 0.5,
        };
        assert_eq!(clamped.cost_us_for(0), 100);
    }

    #[test]
    fn collect_mode_parses_and_displays() {
        assert_eq!("first-m".parse::<CollectMode>().unwrap(), CollectMode::FirstM);
        assert_eq!("all".parse::<CollectMode>().unwrap(), CollectMode::All);
        assert!("most".parse::<CollectMode>().is_err());
        assert_eq!(CollectMode::default(), CollectMode::All);
        for mode in CollectMode::ALL {
            assert_eq!(mode.as_str().parse::<CollectMode>().unwrap(), mode);
        }
    }

    #[test]
    fn incremental_collect_reaches_quorum_then_salvages_late_arrivals() {
        // Pooled session: quorum at the 2 fast workers, then an extended
        // late window (aux co-scheduled per slice) harvests the straggler
        // that a one-shot first-m collect would abandon.
        use std::sync::atomic::{AtomicUsize, Ordering};

        let faults = FaultModel {
            cost: ComputeCost {
                base_us: 200,
                slow_workers: 1,
                slow_factor: 4.0,
            },
            ..Default::default()
        };
        let mut server = harness(TransportKind::Pooled, 3, faults, |id, round, _p, emit| {
            emit.send(round, &[id as f32]);
        });
        server.broadcast(1, Arc::new(vec![0.0]));
        server.collect_begin(1, 2, Duration::from_secs(5));
        let mut quorum_ids = Vec::new();
        loop {
            match server.collect_step(|w, _g| {
                quorum_ids.push(w);
                true
            }) {
                CollectStatus::Pending => continue,
                CollectStatus::Quorum => break,
                CollectStatus::Exhausted => panic!("quorum must be reachable"),
            }
        }
        assert_eq!(quorum_ids, vec![1, 2], "fast tier, completion order");
        let v_quorum = server.collect_virtual_us();
        assert!(v_quorum >= 200, "fast tier costs 200 µs of virtual time");

        server.collect_extend();
        let aux_runs = AtomicUsize::new(0);
        let aux = |/* one chunk of overlap work */| {
            aux_runs.fetch_add(1, Ordering::Relaxed);
        };
        let mut late_ids = Vec::new();
        loop {
            match server.collect_step_aux(
                &mut |w, _g| {
                    late_ids.push(w);
                    true
                },
                Some(&aux),
            ) {
                CollectStatus::Pending | CollectStatus::Quorum => continue,
                CollectStatus::Exhausted => break,
            }
        }
        assert_eq!(late_ids, vec![0], "the straggler lands in the late window");
        assert!(server.collect_virtual_us() > v_quorum, "clock advanced");
        assert!(aux_runs.load(Ordering::Relaxed) > 0, "aux co-scheduled");
        assert_eq!(server.collect_accepted(), 3);
        server.collect_finish();
        server.shutdown();
    }

    #[test]
    fn incremental_collect_matches_one_shot_on_both_backends() {
        // begin/step/finish must reproduce collect_with's semantics:
        // same accepted set at quorum, Exhausted at the deadline.
        on_both(|kind| {
            let mut server = harness(kind, 4, FaultModel::default(), |id, round, _p, emit| {
                emit.send(round, &[id as f32]);
            });
            server.broadcast(1, Arc::new(vec![0.0]));
            server.collect_begin(1, 4, Duration::from_secs(5));
            let mut got = Vec::new();
            loop {
                match server.collect_step(|w, _g| {
                    got.push(w);
                    true
                }) {
                    CollectStatus::Pending => continue,
                    CollectStatus::Quorum => break,
                    CollectStatus::Exhausted => panic!("{kind}: expected quorum"),
                }
            }
            assert_eq!(server.collect_accepted(), 4, "{kind}");
            server.collect_finish();
            got.sort_unstable();
            assert_eq!(got, vec![0, 1, 2, 3], "{kind}");

            // No broadcast: the session exhausts without delivering.
            server.collect_begin(2, 1, Duration::from_millis(20));
            let mut n = 0usize;
            loop {
                match server.collect_step(|_w, _g| {
                    n += 1;
                    true
                }) {
                    CollectStatus::Pending => continue,
                    CollectStatus::Quorum => panic!("{kind}: nothing was broadcast"),
                    CollectStatus::Exhausted => break,
                }
            }
            assert_eq!(n, 0, "{kind}");
            server.collect_finish();
            server.shutdown();
        });
    }

    #[test]
    fn transport_kind_parses_and_displays() {
        assert_eq!("threaded".parse::<TransportKind>().unwrap(), TransportKind::Threaded);
        assert_eq!("pooled".parse::<TransportKind>().unwrap(), TransportKind::Pooled);
        assert_eq!("socket".parse::<TransportKind>().unwrap(), TransportKind::Socket);
        assert!("carrier-pigeon".parse::<TransportKind>().is_err());
        assert_eq!(TransportKind::default(), TransportKind::Pooled);
        for kind in TransportKind::ALL {
            assert_eq!(kind.as_str().parse::<TransportKind>().unwrap(), kind);
        }
    }

    #[test]
    fn churn_model_presence_schedule() {
        let none = ChurnModel::default();
        assert!(none.is_static());
        assert!(none.present(0, 1) && none.present(7, 999));
        let leave = ChurnModel {
            leave_round: 3,
            leave_workers: 2,
            rejoin_round: 0,
        };
        assert!(leave.present(0, 2) && leave.present(1, 2));
        assert!(!leave.present(0, 3) && !leave.present(1, 100));
        assert!(leave.present(2, 3), "only the first leave_workers leave");
        let rejoin = ChurnModel {
            leave_round: 3,
            leave_workers: 1,
            rejoin_round: 5,
        };
        assert!(rejoin.present(0, 2));
        assert!(!rejoin.present(0, 3) && !rejoin.present(0, 4));
        assert!(rejoin.present(0, 5) && rejoin.present(0, 6));
    }

    #[test]
    fn scripted_churn_silences_departed_workers_on_every_backend() {
        // Workers 0..2 leave at round 2 and rejoin at round 4: the
        // emitter must suppress exactly their sends in rounds 2–3 on all
        // three backends, without perturbing the others.
        on_both(|kind| {
            let faults = FaultModel {
                churn: ChurnModel {
                    leave_round: 2,
                    leave_workers: 2,
                    rejoin_round: 4,
                },
                ..Default::default()
            };
            let mut server = harness(kind, 4, faults, |id, round, _p, emit| {
                emit.send(round, &[id as f32]);
            });
            let present = |server: &mut ServerEndpoint, round: u64, expect: usize| {
                server.broadcast(round, Arc::new(vec![0.0]));
                let mut ids: Vec<usize> = server
                    .collect(round, expect, Duration::from_millis(300))
                    .iter()
                    .map(|m| m.worker)
                    .collect();
                ids.sort_unstable();
                ids
            };
            assert_eq!(present(&mut server, 1, 4), vec![0, 1, 2, 3], "{kind}");
            assert_eq!(present(&mut server, 2, 2), vec![2, 3], "{kind}");
            assert_eq!(present(&mut server, 3, 2), vec![2, 3], "{kind}");
            assert_eq!(present(&mut server, 4, 4), vec![0, 1, 2, 3], "{kind}");
            server.shutdown();
        });
    }

    #[test]
    fn departed_workers_is_empty_on_in_process_backends() {
        for kind in [TransportKind::Threaded, TransportKind::Pooled] {
            let server = harness(kind, 2, FaultModel::default(), |_id, round, _p, emit| {
                emit.send(round, &[0.0]);
            });
            assert!(server.departed_workers().is_empty(), "{kind}");
            server.shutdown();
        }
    }

    #[test]
    fn socket_addr_is_exposed_only_by_the_socket_backend() {
        on_both(|kind| {
            let server = harness(kind, 1, FaultModel::default(), |_id, round, _p, emit| {
                emit.send(round, &[0.0]);
            });
            assert_eq!(
                server.socket_addr().is_some(),
                kind == TransportKind::Socket,
                "{kind}"
            );
            server.shutdown();
        });
    }
}
