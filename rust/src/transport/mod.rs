//! In-process simulated cluster transport.
//!
//! The paper's evaluation runs on a single machine; what matters for
//! Byzantine resilience is the *values* workers send, not the wire. This
//! module provides the parameter-server ⇄ worker message fabric as
//! std-mpsc channels between OS threads, with injectable, seeded network
//! faults (per-message delay and drop) so the coordinator's
//! timeout/fallback paths are exercised like they would be on a real
//! deployment (see DESIGN.md §Substitutions).
//!
//! Topology: a star. The server holds one [`ServerEndpoint`]; each worker
//! thread holds a [`WorkerEndpoint`]. Messages to workers carry the
//! current parameter vector behind an `Arc` (no per-worker copy of a
//! 10⁷-float model).

use crate::util::Rng64;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Server → worker messages.
#[derive(Debug, Clone)]
pub enum ToWorker {
    /// Start round `round`: compute a gradient at `params`.
    Round { round: u64, params: Arc<Vec<f32>> },
    /// Terminate the worker thread.
    Shutdown,
}

/// Worker → server message: one gradient proposal.
#[derive(Debug, Clone)]
pub struct FromWorker {
    pub worker: usize,
    pub round: u64,
    pub gradient: Vec<f32>,
}

/// Network fault model (applied on the worker → server direction, where a
/// loss actually affects the round; a server → worker loss manifests the
/// same way — a missing gradient).
#[derive(Debug, Clone, Copy, Default)]
pub struct FaultModel {
    /// Mean one-way delay, microseconds (jittered U(0.5×, 1.5×)).
    pub delay_us: u64,
    /// Per-message drop probability.
    pub drop_prob: f64,
    /// Seed for the fault RNG.
    pub seed: u64,
}

/// Worker-side handle.
pub struct WorkerEndpoint {
    pub id: usize,
    rx: mpsc::Receiver<ToWorker>,
    tx: mpsc::Sender<FromWorker>,
    faults: FaultModel,
    rng: Rng64,
}

impl WorkerEndpoint {
    /// Block until the next instruction from the server (None = channel
    /// closed, treat as shutdown).
    pub fn recv(&mut self) -> Option<ToWorker> {
        self.rx.recv().ok()
    }

    /// Send a gradient back, subject to the fault model.
    pub fn send(&mut self, round: u64, gradient: Vec<f32>) {
        if self.faults.drop_prob > 0.0 && self.rng.gen_bool(self.faults.drop_prob) {
            return; // dropped on the (simulated) wire
        }
        if self.faults.delay_us > 0 {
            let jitter = self.rng.gen_range_f32(0.5, 1.5);
            let us = (self.faults.delay_us as f32 * jitter) as u64;
            std::thread::sleep(Duration::from_micros(us));
        }
        let _ = self.tx.send(FromWorker {
            worker: self.id,
            round,
            gradient,
        });
    }
}

/// Server-side handle.
pub struct ServerEndpoint {
    to_workers: Vec<mpsc::Sender<ToWorker>>,
    from_workers: mpsc::Receiver<FromWorker>,
}

impl ServerEndpoint {
    /// Broadcast the round-start message to every worker.
    pub fn broadcast(&self, round: u64, params: Arc<Vec<f32>>) {
        for tx in &self.to_workers {
            let _ = tx.send(ToWorker::Round {
                round,
                params: Arc::clone(&params),
            });
        }
    }

    /// Tell every worker to stop.
    pub fn shutdown(&self) {
        for tx in &self.to_workers {
            let _ = tx.send(ToWorker::Shutdown);
        }
    }

    /// Collect up to `expect` gradients for `round`, or until `timeout`.
    /// Stale-round messages are discarded. Returns messages in arrival
    /// order (possibly fewer than `expect` on timeout/drops).
    pub fn collect(&mut self, round: u64, expect: usize, timeout: Duration) -> Vec<FromWorker> {
        let mut got = Vec::with_capacity(expect);
        let deadline = Instant::now() + timeout;
        while got.len() < expect {
            let remaining = deadline.saturating_duration_since(Instant::now());
            if remaining.is_zero() {
                break;
            }
            match self.from_workers.recv_timeout(remaining) {
                Ok(msg) if msg.round == round => got.push(msg),
                Ok(_stale) => continue,
                Err(_) => break,
            }
        }
        got
    }

    pub fn num_workers(&self) -> usize {
        self.to_workers.len()
    }
}

/// Build a star topology for `n` workers with the given fault model.
pub fn star(n: usize, faults: FaultModel) -> (ServerEndpoint, Vec<WorkerEndpoint>) {
    let (up_tx, up_rx) = mpsc::channel::<FromWorker>();
    let mut to_workers = Vec::with_capacity(n);
    let mut endpoints = Vec::with_capacity(n);
    for id in 0..n {
        let (down_tx, down_rx) = mpsc::channel::<ToWorker>();
        to_workers.push(down_tx);
        endpoints.push(WorkerEndpoint {
            id,
            rx: down_rx,
            tx: up_tx.clone(),
            faults,
            rng: Rng64::seed_from_u64(
                faults
                    .seed
                    .wrapping_add(id as u64)
                    .wrapping_mul(0x9E3779B97F4A7C15),
            ),
        });
    }
    (
        ServerEndpoint {
            to_workers,
            from_workers: up_rx,
        },
        endpoints,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_without_faults() {
        let (mut server, workers) = star(3, FaultModel::default());
        for mut w in workers {
            std::thread::spawn(move || {
                while let Some(ToWorker::Round { round, params }) = w.recv() {
                    let g: Vec<f32> = params.iter().map(|p| p + w.id as f32).collect();
                    w.send(round, g);
                }
            });
        }
        server.broadcast(1, Arc::new(vec![1.0, 2.0]));
        let got = server.collect(1, 3, Duration::from_secs(5));
        assert_eq!(got.len(), 3);
        let mut ids: Vec<usize> = got.iter().map(|m| m.worker).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2]);
        server.shutdown();
    }

    #[test]
    fn stale_rounds_are_discarded() {
        let (mut server, mut workers) = star(1, FaultModel::default());
        let mut w = workers.pop().unwrap();
        std::thread::spawn(move || {
            if let Some(ToWorker::Round { .. }) = w.recv() {
                w.send(0, vec![9.0]); // stale
                w.send(1, vec![1.0]);
            }
        });
        server.broadcast(1, Arc::new(vec![0.0]));
        let got = server.collect(1, 1, Duration::from_secs(5));
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].gradient, vec![1.0]);
    }

    #[test]
    fn full_drop_hits_timeout() {
        let faults = FaultModel {
            drop_prob: 1.0,
            ..Default::default()
        };
        let (mut server, workers) = star(2, faults);
        for mut w in workers {
            std::thread::spawn(move || {
                while let Some(ToWorker::Round { round, .. }) = w.recv() {
                    w.send(round, vec![1.0]);
                }
            });
        }
        server.broadcast(7, Arc::new(vec![0.0]));
        let got = server.collect(7, 2, Duration::from_millis(50));
        assert!(got.is_empty());
    }

    #[test]
    fn delay_is_applied_but_bounded() {
        let faults = FaultModel {
            delay_us: 2_000,
            ..Default::default()
        };
        let (mut server, mut workers) = star(1, faults);
        let mut w = workers.pop().unwrap();
        std::thread::spawn(move || {
            while let Some(ToWorker::Round { round, .. }) = w.recv() {
                w.send(round, vec![1.0]);
            }
        });
        let t0 = Instant::now();
        server.broadcast(1, Arc::new(vec![0.0]));
        let got = server.collect(1, 1, Duration::from_secs(5));
        assert_eq!(got.len(), 1);
        assert!(t0.elapsed() >= Duration::from_micros(800));
        server.shutdown();
    }

    #[test]
    fn partial_drop_delivers_some() {
        let faults = FaultModel {
            drop_prob: 0.5,
            seed: 3,
            ..Default::default()
        };
        let (mut server, workers) = star(8, faults);
        for mut w in workers {
            std::thread::spawn(move || {
                while let Some(ToWorker::Round { round, .. }) = w.recv() {
                    w.send(round, vec![w.id as f32]);
                }
            });
        }
        server.broadcast(1, Arc::new(vec![0.0]));
        let got = server.collect(1, 8, Duration::from_millis(200));
        assert!(!got.is_empty() && got.len() < 8, "got {}", got.len());
        server.shutdown();
    }
}
