//! In-process simulated cluster transport.
//!
//! The paper's evaluation runs on a single machine; what matters for
//! Byzantine resilience is the *values* workers send, not the wire. This
//! module provides the parameter-server ⇄ worker message fabric with
//! injectable, seeded network faults (per-message delay and drop) so the
//! coordinator's timeout/fallback paths are exercised like they would be
//! on a real deployment (see DESIGN.md §Substitutions).
//!
//! Topology: a star. The server holds one [`ServerEndpoint`]; each logical
//! worker is represented by a [`WorkerEndpoint`] onto which the caller
//! installs a [`WorkerBody`] — the per-round gradient computation —
//! via [`WorkerEndpoint::serve`]. Parameters travel behind an `Arc` (no
//! per-worker copy of a 10⁷-float model); gradients come back through the
//! body's [`Emitter`], which applies the [`FaultModel`] on the way up.
//!
//! Two interchangeable backends implement the fabric
//! ([`TransportKind`], the `transport` config knob):
//!
//! * **`threaded`** — the classic simulation: one OS thread plus a pair of
//!   std-mpsc channels per worker. Faithful asynchrony (workers really do
//!   run concurrently, stragglers really do race the collect timeout) but
//!   caps realistic experiments at a few dozen workers.
//! * **`pooled`** (default) — the scaling backend: `n` *logical* workers
//!   multiplexed over the crate's [`runtime::pool::ThreadPool`]. A round
//!   uses one shared broadcast slot (round number + `Arc` params) and a
//!   preallocated per-worker gradient arena with one disjoint slot per
//!   worker — zero per-message allocations and zero channel sends on the
//!   hot path, so 128–512 logical workers cost buffers, not OS threads.
//!   The server *drives* the logical workers inside
//!   [`ServerEndpoint::collect`]; a worker that would straggle past the
//!   timeout cannot be preempted mid-computation, so straggler loss is
//!   modelled via [`FaultModel::drop_prob`] (which exercises the same
//!   server fallback path).
//!
//! Both backends preserve the same observable semantics: broadcast →
//! collect with timeout, fault-model delay/drop on the worker → server
//! direction, and stale-round discard. The shared test harness at the
//! bottom of this file runs the whole transport suite against both.
//!
//! [`runtime::pool::ThreadPool`]: crate::runtime::ThreadPool

mod pooled;
mod threaded;

use crate::runtime::Parallelism;
use crate::util::Rng64;
use std::sync::{Mutex, MutexGuard};
use std::time::Duration;

/// Worker → server message: one gradient proposal.
#[derive(Debug, Clone)]
pub struct FromWorker {
    pub worker: usize,
    pub round: u64,
    pub gradient: Vec<f32>,
}

/// Network fault model (applied on the worker → server direction, where a
/// loss actually affects the round; a server → worker loss manifests the
/// same way — a missing gradient).
#[derive(Debug, Clone, Copy, Default)]
pub struct FaultModel {
    /// Mean one-way delay, microseconds (jittered U(0.5×, 1.5×)). On the
    /// threaded backend all workers sleep concurrently; on the pooled
    /// backend the sleeps occupy the driving pool threads, so per-round
    /// delay accumulates as ≈ n·delay/threads — prefer the threaded
    /// backend for experiments about *concurrent* network latency.
    pub delay_us: u64,
    /// Per-message drop probability.
    pub drop_prob: f64,
    /// Seed for the fault RNG.
    pub seed: u64,
}

impl FaultModel {
    /// The per-worker fault RNG — one deterministic stream per worker id,
    /// identical across backends so a seeded run drops the same messages
    /// on either transport.
    fn rng_for(&self, worker: usize) -> Rng64 {
        Rng64::seed_from_u64(
            self.seed
                .wrapping_add(worker as u64)
                .wrapping_mul(0x9E3779B97F4A7C15),
        )
    }
}

/// Which transport backend a cluster runs on (the `transport` config
/// knob / `--transport` CLI flag).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TransportKind {
    /// One OS thread + one mpsc channel pair per worker.
    Threaded,
    /// Logical workers multiplexed over the shared thread pool (default).
    #[default]
    Pooled,
}

impl TransportKind {
    pub const ALL: [TransportKind; 2] = [TransportKind::Threaded, TransportKind::Pooled];

    pub fn as_str(self) -> &'static str {
        match self {
            TransportKind::Threaded => "threaded",
            TransportKind::Pooled => "pooled",
        }
    }
}

impl std::fmt::Display for TransportKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl std::str::FromStr for TransportKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "threaded" => Ok(TransportKind::Threaded),
            "pooled" => Ok(TransportKind::Pooled),
            other => anyhow::bail!("unknown transport '{other}' (threaded|pooled)"),
        }
    }
}

/// The per-round behaviour of a logical worker: called once per broadcast
/// with the round number and current parameters; responds by calling
/// [`Emitter::send`] zero or more times (zero = a silent/crashed worker,
/// handled by the server's timeout/fallback path).
///
/// On the threaded backend the body runs on its worker's dedicated OS
/// thread; on the pooled backend it runs as a task on the shared thread
/// pool, so it must not submit parallel regions to that same pool
/// (the pool is not reentrant — see `runtime::pool`).
pub trait WorkerBody: Send {
    fn on_round(&mut self, round: u64, params: &[f32], emit: &mut Emitter<'_>);
}

/// The worker-side reply channel handed to [`WorkerBody::on_round`].
/// Applies the [`FaultModel`] (drop, then jittered delay) before
/// delivering the gradient to the server's backend-specific sink.
pub struct Emitter<'a> {
    worker: usize,
    faults: FaultModel,
    rng: &'a mut Rng64,
    sink: EmitterSink<'a>,
}

enum EmitterSink<'a> {
    /// Threaded backend: the worker → server mpsc channel.
    Channel(&'a std::sync::mpsc::Sender<FromWorker>),
    /// Pooled backend: this worker's arena slot.
    Slot(&'a Mutex<pooled::GradSlot>),
}

impl Emitter<'_> {
    /// This worker's id (also the shard id used by the data layer).
    pub fn worker(&self) -> usize {
        self.worker
    }

    /// Send a gradient for `round` back to the server, subject to the
    /// fault model. The slice is copied at the transport boundary; the
    /// pooled backend copies into a preallocated arena slot (no
    /// allocation in the steady state).
    pub fn send(&mut self, round: u64, gradient: &[f32]) {
        if self.faults.drop_prob > 0.0 && self.rng.gen_bool(self.faults.drop_prob) {
            return; // dropped on the (simulated) wire
        }
        if self.faults.delay_us > 0 {
            let jitter = self.rng.gen_range_f32(0.5, 1.5);
            let us = (self.faults.delay_us as f32 * jitter) as u64;
            std::thread::sleep(Duration::from_micros(us));
        }
        match &self.sink {
            EmitterSink::Channel(tx) => {
                let _ = tx.send(FromWorker {
                    worker: self.worker,
                    round,
                    gradient: gradient.to_vec(),
                });
            }
            EmitterSink::Slot(slot) => {
                let mut s = lock(slot);
                // Never let an older round overwrite a fresher pending
                // gradient — the threaded backend delivers both messages
                // and the server discards only the stale one.
                if !s.fresh || round >= s.round {
                    s.round = round;
                    s.fresh = true;
                    s.grad.clear();
                    s.grad.extend_from_slice(gradient);
                }
            }
        }
    }
}

/// Server-side handle: broadcast, collect, shutdown — backend-agnostic.
pub struct ServerEndpoint {
    inner: ServerImpl,
}

enum ServerImpl {
    Threaded(threaded::Server),
    Pooled(pooled::Server),
}

impl ServerEndpoint {
    /// Announce round `round` at `params` to every worker. On the pooled
    /// backend this only fills the broadcast slot; the logical workers
    /// run when [`collect`](Self::collect) drives them.
    pub fn broadcast(&mut self, round: u64, params: std::sync::Arc<Vec<f32>>) {
        match &mut self.inner {
            ServerImpl::Threaded(s) => s.broadcast(round, params),
            ServerImpl::Pooled(s) => s.broadcast(round, params),
        }
    }

    /// Collect up to `expect` gradients for `round`, calling
    /// `on_gradient(worker, gradient)` for each as it arrives; returns the
    /// number delivered. Stale-round gradients are discarded. The threaded
    /// backend waits up to `timeout` for stragglers; the pooled backend
    /// runs its logical workers to completion inside this call (see the
    /// module docs on straggler semantics), so fewer than `expect`
    /// deliveries mean fault-model drops, not a race.
    ///
    /// This is the zero-copy path: `gradient` borrows transport-owned
    /// memory, so a full round makes no per-message allocation on the
    /// pooled backend.
    pub fn collect_with(
        &mut self,
        round: u64,
        expect: usize,
        timeout: Duration,
        mut on_gradient: impl FnMut(usize, &[f32]),
    ) -> usize {
        match &mut self.inner {
            ServerImpl::Threaded(s) => s.collect_with(round, expect, timeout, &mut on_gradient),
            ServerImpl::Pooled(s) => s.collect_with(round, expect, timeout, &mut on_gradient),
        }
    }

    /// Owned-message convenience wrapper over
    /// [`collect_with`](Self::collect_with) (allocates per message; the
    /// coordinator hot path uses `collect_with` directly).
    pub fn collect(&mut self, round: u64, expect: usize, timeout: Duration) -> Vec<FromWorker> {
        let mut got = Vec::with_capacity(expect);
        self.collect_with(round, expect, timeout, |worker, gradient| {
            got.push(FromWorker {
                worker,
                round,
                gradient: gradient.to_vec(),
            });
        });
        got
    }

    /// Tell every worker to stop (threaded: join-free thread shutdown;
    /// pooled: drop the registered bodies so no further round runs them).
    pub fn shutdown(&self) {
        match &self.inner {
            ServerImpl::Threaded(s) => s.shutdown(),
            ServerImpl::Pooled(s) => s.shutdown(),
        }
    }

    pub fn num_workers(&self) -> usize {
        match &self.inner {
            ServerImpl::Threaded(s) => s.num_workers(),
            ServerImpl::Pooled(s) => s.num_workers(),
        }
    }

    /// Which backend this endpoint runs on.
    pub fn transport(&self) -> TransportKind {
        match &self.inner {
            ServerImpl::Threaded(_) => TransportKind::Threaded,
            ServerImpl::Pooled(_) => TransportKind::Pooled,
        }
    }
}

/// Worker-side handle: install a [`WorkerBody`] to bring the logical
/// worker online.
pub struct WorkerEndpoint {
    inner: EndpointImpl,
}

enum EndpointImpl {
    Threaded(threaded::Worker),
    Pooled(pooled::WorkerHandle),
}

impl WorkerEndpoint {
    pub fn id(&self) -> usize {
        match &self.inner {
            EndpointImpl::Threaded(w) => w.id(),
            EndpointImpl::Pooled(w) => w.id(),
        }
    }

    /// Install `body` and start serving rounds: spawns a dedicated OS
    /// thread on the threaded backend; registers the body with the shared
    /// runtime on the pooled backend (no thread).
    pub fn serve(self, body: impl WorkerBody + 'static) {
        match self.inner {
            EndpointImpl::Threaded(w) => w.serve(Box::new(body)),
            EndpointImpl::Pooled(w) => w.serve(Box::new(body)),
        }
    }
}

/// Build a thread-per-worker star for `n` workers (the `threaded`
/// backend; see [`build`] for the knob-driven constructor).
pub fn star(n: usize, faults: FaultModel) -> (ServerEndpoint, Vec<WorkerEndpoint>) {
    let (server, workers) = threaded::star(n, faults);
    (
        ServerEndpoint {
            inner: ServerImpl::Threaded(server),
        },
        workers
            .into_iter()
            .map(|w| WorkerEndpoint {
                inner: EndpointImpl::Threaded(w),
            })
            .collect(),
    )
}

/// Build a pooled star for `n` logical workers, multiplexed over `par`
/// (`Parallelism::sequential()` drives them inline on the server thread —
/// correct, just serial).
pub fn star_pooled(
    n: usize,
    faults: FaultModel,
    par: &Parallelism,
) -> (ServerEndpoint, Vec<WorkerEndpoint>) {
    let (server, workers) = pooled::star(n, faults, par.clone());
    (
        ServerEndpoint {
            inner: ServerImpl::Pooled(server),
        },
        workers
            .into_iter()
            .map(|w| WorkerEndpoint {
                inner: EndpointImpl::Pooled(w),
            })
            .collect(),
    )
}

/// Build a star on the chosen backend — the one constructor the launcher
/// uses (`kind` is the `transport` config knob).
pub fn build(
    kind: TransportKind,
    n: usize,
    faults: FaultModel,
    par: &Parallelism,
) -> (ServerEndpoint, Vec<WorkerEndpoint>) {
    match kind {
        TransportKind::Threaded => star(n, faults),
        TransportKind::Pooled => star_pooled(n, faults, par),
    }
}

/// Mutex lock that ignores poisoning: a panicked worker body already
/// surfaced through the pool's panic propagation; the transport state
/// itself (a gradient buffer + flags) is valid regardless.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::Instant;

    /// A test body: a plain function pointer over (id, round, params,
    /// emitter) — no closure-inference pitfalls, trivially `Send`.
    struct TestBody {
        id: usize,
        f: fn(usize, u64, &[f32], &mut Emitter<'_>),
    }

    impl WorkerBody for TestBody {
        fn on_round(&mut self, round: u64, params: &[f32], emit: &mut Emitter<'_>) {
            (self.f)(self.id, round, params, emit)
        }
    }

    /// Build a star on `kind` and install `f` as every worker's body.
    fn harness(
        kind: TransportKind,
        n: usize,
        faults: FaultModel,
        f: fn(usize, u64, &[f32], &mut Emitter<'_>),
    ) -> ServerEndpoint {
        let (server, workers) = build(kind, n, faults, &Parallelism::new(2));
        for w in workers {
            let id = w.id();
            w.serve(TestBody { id, f });
        }
        server
    }

    /// Run the same scenario on both backends.
    fn on_both(test: fn(TransportKind)) {
        for kind in TransportKind::ALL {
            test(kind);
        }
    }

    #[test]
    fn round_trip_without_faults() {
        on_both(|kind| {
            let mut server = harness(kind, 3, FaultModel::default(), |id, round, params, emit| {
                let g: Vec<f32> = params.iter().map(|p| p + id as f32).collect();
                emit.send(round, &g);
            });
            server.broadcast(1, Arc::new(vec![1.0, 2.0]));
            let got = server.collect(1, 3, Duration::from_secs(5));
            assert_eq!(got.len(), 3, "{kind}");
            let mut ids: Vec<usize> = got.iter().map(|m| m.worker).collect();
            ids.sort_unstable();
            assert_eq!(ids, vec![0, 1, 2], "{kind}");
            for m in &got {
                assert_eq!(m.gradient, vec![1.0 + m.worker as f32, 2.0 + m.worker as f32]);
            }
            server.shutdown();
        });
    }

    #[test]
    fn stale_rounds_are_discarded() {
        on_both(|kind| {
            let mut server = harness(kind, 1, FaultModel::default(), |_id, _round, _p, emit| {
                emit.send(0, &[9.0]); // stale
                emit.send(1, &[1.0]);
            });
            server.broadcast(1, Arc::new(vec![0.0]));
            let got = server.collect(1, 1, Duration::from_secs(5));
            assert_eq!(got.len(), 1, "{kind}");
            assert_eq!(got[0].gradient, vec![1.0], "{kind}");
            server.shutdown();
        });
    }

    #[test]
    fn stale_send_after_current_does_not_clobber() {
        // Reverse order of stale_rounds_are_discarded: the current-round
        // gradient must survive a later stale emit on both backends.
        on_both(|kind| {
            let mut server = harness(kind, 1, FaultModel::default(), |_id, _round, _p, emit| {
                emit.send(1, &[1.0]);
                emit.send(0, &[9.0]); // stale, after the current round
            });
            server.broadcast(1, Arc::new(vec![0.0]));
            let got = server.collect(1, 1, Duration::from_secs(5));
            assert_eq!(got.len(), 1, "{kind}");
            assert_eq!(got[0].gradient, vec![1.0], "{kind}");
            server.shutdown();
        });
    }

    #[test]
    fn body_panic_is_a_crashed_worker_not_a_server_crash() {
        // A panicking body must take down only its own logical worker
        // (threaded: the worker thread dies; pooled: the body is
        // silenced) — the server keeps collecting from the others.
        on_both(|kind| {
            let mut server = harness(kind, 3, FaultModel::default(), |id, round, _p, emit| {
                if id == 1 {
                    panic!("worker 1 crashed");
                }
                emit.send(round, &[id as f32]);
            });
            for round in 1..=2u64 {
                server.broadcast(round, Arc::new(vec![0.0]));
                let got = server.collect(round, 3, Duration::from_millis(300));
                let mut ids: Vec<usize> = got.iter().map(|m| m.worker).collect();
                ids.sort_unstable();
                assert_eq!(ids, vec![0, 2], "{kind} round {round}");
            }
            server.shutdown();
        });
    }

    #[test]
    fn full_drop_hits_timeout() {
        on_both(|kind| {
            let faults = FaultModel {
                drop_prob: 1.0,
                ..Default::default()
            };
            let mut server = harness(kind, 2, faults, |_id, round, _p, emit| {
                emit.send(round, &[1.0]);
            });
            server.broadcast(7, Arc::new(vec![0.0]));
            let got = server.collect(7, 2, Duration::from_millis(50));
            assert!(got.is_empty(), "{kind}");
            server.shutdown();
        });
    }

    #[test]
    fn delay_is_applied_but_bounded() {
        on_both(|kind| {
            let faults = FaultModel {
                delay_us: 2_000,
                ..Default::default()
            };
            let mut server = harness(kind, 1, faults, |_id, round, _p, emit| {
                emit.send(round, &[1.0]);
            });
            let t0 = Instant::now();
            server.broadcast(1, Arc::new(vec![0.0]));
            let got = server.collect(1, 1, Duration::from_secs(5));
            assert_eq!(got.len(), 1, "{kind}");
            assert!(t0.elapsed() >= Duration::from_micros(800), "{kind}");
            server.shutdown();
        });
    }

    #[test]
    fn partial_drop_delivers_some() {
        on_both(|kind| {
            let faults = FaultModel {
                drop_prob: 0.5,
                seed: 3,
                ..Default::default()
            };
            let mut server = harness(kind, 8, faults, |id, round, _p, emit| {
                emit.send(round, &[id as f32]);
            });
            server.broadcast(1, Arc::new(vec![0.0]));
            let got = server.collect(1, 8, Duration::from_millis(200));
            assert!(
                !got.is_empty() && got.len() < 8,
                "{kind}: got {}",
                got.len()
            );
            server.shutdown();
        });
    }

    #[test]
    fn drop_pattern_is_identical_across_backends() {
        // Same seed ⇒ the fault RNG drops the same workers' messages on
        // either backend — seeded experiments are transport-independent.
        let survivors = |kind: TransportKind| -> Vec<usize> {
            let faults = FaultModel {
                drop_prob: 0.4,
                seed: 11,
                ..Default::default()
            };
            let mut server = harness(kind, 16, faults, |id, round, _p, emit| {
                emit.send(round, &[id as f32]);
            });
            server.broadcast(1, Arc::new(vec![0.0]));
            let mut ids: Vec<usize> = server
                .collect(1, 16, Duration::from_millis(500))
                .iter()
                .map(|m| m.worker)
                .collect();
            server.shutdown();
            ids.sort_unstable();
            ids
        };
        assert_eq!(
            survivors(TransportKind::Threaded),
            survivors(TransportKind::Pooled)
        );
    }

    #[test]
    fn pooled_scales_to_hundreds_of_logical_workers() {
        // 256 logical workers over a 2-thread pool: the old transport
        // would need 256 OS threads for this round-trip.
        let mut server = harness(
            TransportKind::Pooled,
            256,
            FaultModel::default(),
            |id, round, params, emit| {
                let g: Vec<f32> = params.iter().map(|p| p * 2.0 + id as f32).collect();
                emit.send(round, &g);
            },
        );
        for round in 1..=3u64 {
            server.broadcast(round, Arc::new(vec![1.0, -1.0]));
            let got = server.collect(round, 256, Duration::from_secs(5));
            assert_eq!(got.len(), 256, "round {round}");
            for m in &got {
                assert_eq!(m.gradient[0], 2.0 + m.worker as f32);
            }
        }
        server.shutdown();
    }

    #[test]
    fn pooled_slot_freshness_is_per_round() {
        // A worker that answers only even rounds must not leak its old
        // gradient into the next round's collect (fresh flag + round tag).
        let mut server = harness(
            TransportKind::Pooled,
            1,
            FaultModel::default(),
            |_id, round, _p, emit| {
                if round % 2 == 0 {
                    emit.send(round, &[round as f32]);
                }
            },
        );
        server.broadcast(1, Arc::new(vec![0.0]));
        assert!(server.collect(1, 1, Duration::from_millis(10)).is_empty());
        server.broadcast(2, Arc::new(vec![0.0]));
        let got = server.collect(2, 1, Duration::from_millis(10));
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].gradient, vec![2.0]);
        server.broadcast(3, Arc::new(vec![0.0]));
        assert!(server.collect(3, 1, Duration::from_millis(10)).is_empty());
        server.shutdown();
    }

    #[test]
    fn pooled_shutdown_stops_driving_bodies() {
        let mut server = harness(
            TransportKind::Pooled,
            4,
            FaultModel::default(),
            |id, round, _p, emit| {
                emit.send(round, &[id as f32]);
            },
        );
        server.broadcast(1, Arc::new(vec![0.0]));
        assert_eq!(server.collect(1, 4, Duration::from_millis(10)).len(), 4);
        server.shutdown();
        server.broadcast(2, Arc::new(vec![0.0]));
        assert!(server.collect(2, 4, Duration::from_millis(10)).is_empty());
    }

    #[test]
    fn transport_kind_parses_and_displays() {
        assert_eq!("threaded".parse::<TransportKind>().unwrap(), TransportKind::Threaded);
        assert_eq!("pooled".parse::<TransportKind>().unwrap(), TransportKind::Pooled);
        assert!("carrier-pigeon".parse::<TransportKind>().is_err());
        assert_eq!(TransportKind::default(), TransportKind::Pooled);
        for kind in TransportKind::ALL {
            assert_eq!(kind.as_str().parse::<TransportKind>().unwrap(), kind);
        }
    }
}
