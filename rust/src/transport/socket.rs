//! The socket backend: real multi-process workers over a length-prefixed
//! binary frame protocol (TCP or Unix domain sockets).
//!
//! The wire format and the session state machine are specified
//! normatively in `docs/wire-protocol.md`; the section numbers cited in
//! comments below (§2, §5.1, §6.3, …) refer to that document, and the
//! shared conformance suite (`rust/tests/transport_conformance.rs`)
//! enforces them test-by-test.
//!
//! Shape: every frame is a 32-byte header (magic `"MBWP"`, protocol
//! version, payload kind, round id, worker id, payload length, FNV-1a
//! payload checksum — §2) followed by the payload. Gradients travel as a
//! sequence of [`GradientChunk`](PayloadKind::GradientChunk) frames so a
//! worker never has to materialize a full `d`-length byte buffer per
//! send (§4.3); the server reassembles them in order and delivers one
//! [`FromWorker`] per completed gradient. Collection mirrors the
//! threaded backend exactly: a wall-clock deadline-bounded incremental
//! session over an mpsc channel fed by per-connection reader threads,
//! so first-m quorums, accept/reject callbacks and stale-round discard
//! behave identically on all three backends (§6).
//!
//! Two deployment modes (selected by [`SocketOptions`]):
//!
//! * **self-hosted** (`external = false`, the default): the server binds
//!   an ephemeral loopback address (or the configured one) and
//!   `WorkerEndpoint::serve` spawns an in-process client thread per
//!   worker — same process, real sockets. This is what the tests and
//!   the CI determinism legs run.
//! * **external** (`external = true`): `serve` is a no-op and workers
//!   are separate processes (`multibulyan worker --connect <addr>
//!   --worker-id <k>`; see `examples/socket_cluster.sh`).
//!
//! Determinism: the client applies the same per-worker [`FaultModel`]
//! RNG stream and [`ComputeCost`](super::ComputeCost) pre-compute sleep
//! as the threaded backend (via the shared [`Emitter`]), and f32 values
//! round-trip bit-exactly through their little-endian encoding (§3), so
//! a seeded `train --params-checksum` run is bit-identical across
//! threaded, pooled and socket — the CI determinism matrix diffs all
//! three.

use super::{lock, CollectStatus, Emitter, EmitterSink, FaultModel, FromWorker, WorkerBody};
use crate::util::fnv1a;
use anyhow::Context;
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
// wall-clock: this backend talks to real processes over real sockets —
// the collect deadline is physical time, exactly like `threaded`.
use std::time::{Duration, Instant};

/// Frame magic, first four bytes of every frame (§2): "MBWP" —
/// MultiBulyan Wire Protocol.
pub const MAGIC: [u8; 4] = *b"MBWP";

/// Protocol version carried in every frame header (§5.2). A server
/// receiving any other version rejects the connection. Version 2 added
/// gradient-codec negotiation (§7): a Hello capability byte, and a
/// `count`/`codec` prefix on every GradientChunk payload. Version 3
/// added elastic membership (§8): the Goodbye frame, crash-detected
/// departure tracking, and an optional Hello flags byte whose bit 0
/// requests a rejoin (evicting a stale registration for the same id).
pub const VERSION: u16 = 3;

/// Fixed frame-header length in bytes (§2).
pub const HEADER_LEN: usize = 32;

/// Upper bound on a frame's payload length (§2); a header claiming more
/// is rejected before any payload is read.
pub const MAX_PAYLOAD: u32 = 1 << 30;

/// Default number of f32 coordinates per [`PayloadKind::GradientChunk`]
/// frame (the `socket_chunk` config knob / `--socket-chunk` flag).
pub const DEFAULT_CHUNK: usize = 16_384;

/// How long one incremental `collect_step` blocks on the reader channel
/// when aux work interleaves (same contract as the threaded backend).
const STEP: Duration = Duration::from_millis(1);

/// Read-timeout tick of per-connection reader threads: the granularity
/// at which a blocked read re-checks the shutdown flag.
const READ_TICK: Duration = Duration::from_millis(50);

/// Poll interval of the non-blocking accept loop.
const ACCEPT_TICK: Duration = Duration::from_millis(1);

/// Payload kinds (§4). The discriminant is the header's kind byte.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum PayloadKind {
    /// Worker → server registration (§4.1): worker id in the header;
    /// the payload is empty (codec `raw`, §7) or one codec capability
    /// byte. The server acks with a Hello back.
    Hello = 1,
    /// Server → worker round start (§4.2): payload is the full parameter
    /// vector as little-endian f32s.
    RoundResult = 2,
    /// Worker → server gradient piece (§4.3, §7): payload is
    /// `offset u32 | total u32 | count u32 | codec u8 | encoded bytes`,
    /// integers little-endian; `count` is the number of f32 coordinates
    /// the encoded bytes decode to.
    GradientChunk = 3,
    /// Server → worker refusal (§4.4): payload is one reason-code byte
    /// (the `REJECT_*` constants).
    Reject = 4,
    /// Either direction: orderly connection teardown (§4.5).
    Shutdown = 5,
    /// Worker → server orderly departure (§8.1, v3): the worker leaves
    /// the cluster but the run continues without it. Empty payload; the
    /// server marks the id departed (see
    /// [`super::ServerEndpoint::departed_workers`]) and frees its
    /// registration slot so a later Hello can rejoin.
    Goodbye = 6,
}

impl PayloadKind {
    /// Decode a header kind byte; `None` for unknown kinds (§5.3 —
    /// forward compatibility: the frame is skipped, not fatal).
    pub fn from_u8(b: u8) -> Option<Self> {
        match b {
            1 => Some(PayloadKind::Hello),
            2 => Some(PayloadKind::RoundResult),
            3 => Some(PayloadKind::GradientChunk),
            4 => Some(PayloadKind::Reject),
            5 => Some(PayloadKind::Shutdown),
            6 => Some(PayloadKind::Goodbye),
            _ => None,
        }
    }
}

/// One decoded frame (§2): the header fields that survive decoding plus
/// the verified payload.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Frame {
    /// Payload kind (header byte 6).
    pub kind: PayloadKind,
    /// Round id (header bytes 8..16). 0 when not round-scoped.
    pub round: u64,
    /// Worker id (header bytes 16..20); `u32::MAX` for server-originated
    /// broadcast-style frames.
    pub worker: u32,
    /// Checksum-verified payload bytes.
    pub payload: Vec<u8>,
}

/// Why a frame could not be read (§5). `Checksum`, `BadKind` leave the
/// stream positioned at the next frame (recoverable); the rest close
/// the connection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameError {
    /// Clean EOF before the first header byte.
    Closed,
    /// EOF mid-frame (short header or short payload).
    Truncated,
    /// First four bytes were not [`MAGIC`].
    BadMagic,
    /// Unsupported protocol version (§5.2).
    BadVersion(u16),
    /// Unknown kind byte; the payload was consumed, the stream is still
    /// in sync (§5.3).
    BadKind(u8),
    /// Header claimed a payload longer than [`MAX_PAYLOAD`].
    Oversize(u32),
    /// Payload bytes did not hash to the header checksum (§5.1).
    Checksum {
        /// Checksum the header claimed.
        expected: u64,
        /// FNV-1a of the payload actually received.
        got: u64,
    },
    /// Underlying socket error.
    Io(ErrorKind),
    /// The local endpoint is shutting down (reader threads poll the stop
    /// flag between read ticks).
    Shutdown,
}

/// Reject reason (§4.4): payload checksum mismatch.
pub const REJECT_CHECKSUM: u8 = 1;
/// Reject reason (§4.4): unknown payload kind.
pub const REJECT_UNKNOWN_KIND: u8 = 2;
/// Reject reason (§4.4): unsupported protocol version.
pub const REJECT_VERSION: u8 = 3;
/// Reject reason (§4.4): worker id out of the cluster's range.
pub const REJECT_BAD_WORKER: u8 = 4;
/// Reject reason (§4.4): another live connection already registered
/// this worker id (first connection wins — §6.5).
pub const REJECT_DUPLICATE: u8 = 5;
/// Reject reason (§4.4): structurally invalid payload or chunk sequence
/// (bad offset/total bookkeeping, non-f32-aligned length, …).
pub const REJECT_MALFORMED: u8 = 6;
/// Reject reason (§4.4, §7): unknown codec id, a chunk codec other than
/// the negotiated one (or `raw`), or an encoded payload that failed
/// decode — including the suspicious-expansion-ratio guard. The chunk
/// never reaches the collect session, so it cannot occupy a first-m
/// quorum slot.
pub const REJECT_CODEC: u8 = 7;

/// Human-readable name of a Reject reason code (§4.4).
pub fn reject_reason_str(code: u8) -> &'static str {
    match code {
        REJECT_CHECKSUM => "payload checksum mismatch",
        REJECT_UNKNOWN_KIND => "unknown payload kind",
        REJECT_VERSION => "unsupported protocol version",
        REJECT_BAD_WORKER => "worker id out of range",
        REJECT_DUPLICATE => "worker id already connected",
        REJECT_MALFORMED => "malformed payload",
        REJECT_CODEC => "codec negotiation or decode failure",
        _ => "unknown reason",
    }
}

/// Serialize `frame`'s header into `buf[..HEADER_LEN]` (§2 layout).
fn write_header(buf: &mut [u8], kind: PayloadKind, round: u64, worker: u32, len: u32, sum: u64) {
    buf[0..4].copy_from_slice(&MAGIC);
    buf[4..6].copy_from_slice(&VERSION.to_le_bytes());
    buf[6] = kind as u8;
    buf[7] = 0; // reserved, must be 0 (§2)
    buf[8..16].copy_from_slice(&round.to_le_bytes());
    buf[16..20].copy_from_slice(&worker.to_le_bytes());
    buf[20..24].copy_from_slice(&len.to_le_bytes());
    buf[24..32].copy_from_slice(&sum.to_le_bytes());
}

/// Encode a frame to bytes: header (with computed checksum) + payload.
/// `encode` → [`read_frame`] is bit-identity, property-tested (§3).
pub fn encode(frame: &Frame) -> Vec<u8> {
    let mut out = vec![0u8; HEADER_LEN];
    out.extend_from_slice(&frame.payload);
    let sum = fnv1a(frame.payload.iter().copied());
    write_header(
        &mut out[..HEADER_LEN],
        frame.kind,
        frame.round,
        frame.worker,
        frame.payload.len() as u32,
        sum,
    );
    out
}

/// Write one encoded frame to `w` and flush.
pub fn write_frame<W: Write>(w: &mut W, frame: &Frame) -> std::io::Result<()> {
    let bytes = encode(frame);
    w.write_all(&bytes)?;
    w.flush()
}

/// Read exactly `buf.len()` bytes, preserving partial fills across read
/// timeouts (std's `read_exact` would lose already-read bytes on a
/// `WouldBlock`/`TimedOut` tick). Returns `Ok(false)` on a clean EOF
/// before the first byte; a partial EOF is [`FrameError::Truncated`].
/// Between ticks, `stop` (if any) is polled so server reader threads
/// notice shutdown within one [`READ_TICK`].
fn read_full<R: Read>(
    r: &mut R,
    buf: &mut [u8],
    stop: Option<&AtomicBool>,
) -> Result<bool, FrameError> {
    let mut filled = 0;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => {
                return if filled == 0 {
                    Ok(false)
                } else {
                    Err(FrameError::Truncated)
                }
            }
            Ok(n) => filled += n,
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                if stop.is_some_and(|s| s.load(Ordering::SeqCst)) {
                    return Err(FrameError::Shutdown);
                }
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(FrameError::Io(e.kind())),
        }
    }
    Ok(true)
}

/// Consume and discard `len` payload bytes (keeps the stream in sync
/// after an unknown-kind header — §5.3).
fn discard<R: Read>(r: &mut R, mut len: usize, stop: Option<&AtomicBool>) -> Result<(), FrameError> {
    let mut buf = [0u8; 4096];
    while len > 0 {
        let take = len.min(buf.len());
        if !read_full(r, &mut buf[..take], stop)? {
            return Err(FrameError::Truncated);
        }
        len -= take;
    }
    Ok(())
}

/// Read and validate one frame (§2, §5). Works on any `Read` — sockets
/// here, byte slices in the codec tests. Error recoverability is as
/// documented on [`FrameError`].
pub fn read_frame<R: Read>(r: &mut R, stop: Option<&AtomicBool>) -> Result<Frame, FrameError> {
    let mut header = [0u8; HEADER_LEN];
    if !read_full(r, &mut header, stop)? {
        return Err(FrameError::Closed);
    }
    if header[0..4] != MAGIC {
        return Err(FrameError::BadMagic);
    }
    let version = u16::from_le_bytes([header[4], header[5]]);
    if version != VERSION {
        return Err(FrameError::BadVersion(version));
    }
    let raw_kind = header[6];
    let round = u64::from_le_bytes(header[8..16].try_into().expect("8-byte slice"));
    let worker = u32::from_le_bytes(header[16..20].try_into().expect("4-byte slice"));
    let len = u32::from_le_bytes(header[20..24].try_into().expect("4-byte slice"));
    let expected = u64::from_le_bytes(header[24..32].try_into().expect("8-byte slice"));
    if len > MAX_PAYLOAD {
        return Err(FrameError::Oversize(len));
    }
    let Some(kind) = PayloadKind::from_u8(raw_kind) else {
        discard(r, len as usize, stop)?;
        return Err(FrameError::BadKind(raw_kind));
    };
    let mut payload = vec![0u8; len as usize];
    if !read_full(r, &mut payload, stop)? {
        return Err(FrameError::Truncated);
    }
    let got = fnv1a(payload.iter().copied());
    if got != expected {
        return Err(FrameError::Checksum { expected, got });
    }
    Ok(Frame {
        kind,
        round,
        worker,
        payload,
    })
}

/// Encode a parameter vector as a RoundResult payload (§4.2): f32s in
/// little-endian byte order — the bit-exact round-trip the determinism
/// matrix depends on.
pub fn params_payload(params: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(params.len() * 4);
    for v in params {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// Decode a RoundResult payload back to f32s (§4.2).
pub fn parse_params(payload: &[u8]) -> anyhow::Result<Vec<f32>> {
    anyhow::ensure!(
        payload.len() % 4 == 0,
        "RoundResult payload length {} is not a multiple of 4",
        payload.len()
    );
    Ok(payload
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Length of the GradientChunk payload prefix (§4.3):
/// `offset u32 | total u32 | count u32 | codec u8`.
const CHUNK_PREFIX: usize = 13;

/// Split a GradientChunk payload into
/// `(offset, total, count, codec_id, encoded_bytes)` (§4.3, §7);
/// `None` if the payload is too short to carry the prefix.
fn parse_chunk(payload: &[u8]) -> Option<(u32, u32, u32, u8, &[u8])> {
    if payload.len() < CHUNK_PREFIX {
        return None;
    }
    let offset = u32::from_le_bytes(payload[0..4].try_into().ok()?);
    let total = u32::from_le_bytes(payload[4..8].try_into().ok()?);
    let count = u32::from_le_bytes(payload[8..12].try_into().ok()?);
    let codec = payload[12];
    Some((offset, total, count, codec, &payload[CHUNK_PREFIX..]))
}

/// Write one raw-codec GradientChunk frame for `values` at `offset` of
/// a `total`-coordinate gradient, reusing `scratch` as the frame buffer
/// — one `write_all` per frame, no full-gradient allocation (§4.3).
pub fn write_chunk_frame<W: Write>(
    w: &mut W,
    worker: u32,
    round: u64,
    offset: u32,
    total: u32,
    values: &[f32],
    scratch: &mut Vec<u8>,
) -> std::io::Result<()> {
    scratch.clear();
    scratch.reserve(HEADER_LEN + CHUNK_PREFIX + values.len() * 4);
    scratch.extend_from_slice(&[0u8; HEADER_LEN]);
    scratch.extend_from_slice(&offset.to_le_bytes());
    scratch.extend_from_slice(&total.to_le_bytes());
    scratch.extend_from_slice(&(values.len() as u32).to_le_bytes());
    scratch.push(crate::codec::CodecKind::Raw.wire_id());
    for v in values {
        scratch.extend_from_slice(&v.to_le_bytes());
    }
    finish_chunk_frame(w, worker, round, scratch)
}

/// Write one GradientChunk frame whose value bytes were already encoded
/// by `codec` (`count` coordinates at absolute `offset`) — the §7 coded
/// path of [`send_gradient_frames_coded`] and `WorkerClient::run_streaming`.
#[allow(clippy::too_many_arguments)] // mirrors the §4.3 payload prefix field-for-field
pub fn write_coded_chunk_frame<W: Write>(
    w: &mut W,
    worker: u32,
    round: u64,
    offset: u32,
    total: u32,
    count: u32,
    codec: u8,
    encoded: &[u8],
    scratch: &mut Vec<u8>,
) -> std::io::Result<()> {
    scratch.clear();
    scratch.reserve(HEADER_LEN + CHUNK_PREFIX + encoded.len());
    scratch.extend_from_slice(&[0u8; HEADER_LEN]);
    scratch.extend_from_slice(&offset.to_le_bytes());
    scratch.extend_from_slice(&total.to_le_bytes());
    scratch.extend_from_slice(&count.to_le_bytes());
    scratch.push(codec);
    scratch.extend_from_slice(encoded);
    finish_chunk_frame(w, worker, round, scratch)
}

/// Checksum + header over an assembled chunk payload in `scratch`
/// (header space already reserved at the front), then write and flush.
fn finish_chunk_frame<W: Write>(
    w: &mut W,
    worker: u32,
    round: u64,
    scratch: &mut Vec<u8>,
) -> std::io::Result<()> {
    let sum = fnv1a(scratch[HEADER_LEN..].iter().copied());
    let len = (scratch.len() - HEADER_LEN) as u32;
    write_header(
        &mut scratch[..HEADER_LEN],
        PayloadKind::GradientChunk,
        round,
        worker,
        len,
        sum,
    );
    w.write_all(scratch)?;
    w.flush()
}

/// Send one complete gradient as a raw chunk sequence (§4.3); used by
/// the shared [`Emitter`] sink. A write error means the server is gone —
/// the worker falls silent, indistinguishable from a crash (§6.4).
pub(super) fn send_gradient_frames(
    stream: &mut Stream,
    worker: u32,
    round: u64,
    gradient: &[f32],
    chunk: usize,
    scratch: &mut Vec<u8>,
) {
    let chunk = chunk.max(1);
    let total = gradient.len() as u32;
    let mut offset = 0usize;
    loop {
        let end = (offset + chunk).min(gradient.len());
        if write_chunk_frame(
            stream,
            worker,
            round,
            offset as u32,
            total,
            &gradient[offset..end],
            scratch,
        )
        .is_err()
        {
            return;
        }
        offset = end;
        if offset >= gradient.len() {
            break;
        }
    }
}

/// Send one complete gradient as a coded chunk sequence (§7): each chunk
/// is encoded at its absolute coordinate offset, so the server-side
/// decode reassembles the exact values a whole-gradient encode would
/// have produced as long as the chunk size is a multiple of
/// [`crate::codec::BLOCK`] (the default [`DEFAULT_CHUNK`] is).
pub(super) fn send_gradient_frames_coded(
    stream: &mut Stream,
    worker: u32,
    round: u64,
    gradient: &[f32],
    chunk: usize,
    codec: &mut dyn crate::codec::Codec,
    scratch: &mut Vec<u8>,
) {
    let chunk = chunk.max(1);
    let total = gradient.len() as u32;
    let id = codec.kind().wire_id();
    let mut enc = Vec::new();
    let mut offset = 0usize;
    loop {
        let end = (offset + chunk).min(gradient.len());
        codec.encode(offset, &gradient[offset..end], &mut enc);
        if write_coded_chunk_frame(
            stream,
            worker,
            round,
            offset as u32,
            total,
            (end - offset) as u32,
            id,
            &enc,
            scratch,
        )
        .is_err()
        {
            return;
        }
        offset = end;
        if offset >= gradient.len() {
            break;
        }
    }
}

// ---------------------------------------------------------------------
// Address handling and the TCP/UDS stream abstraction (§1).
// ---------------------------------------------------------------------

/// A parsed listen/connect address.
enum AddrSpec {
    /// `host:port`.
    Tcp(String),
    /// Filesystem path of a Unix domain socket.
    #[cfg(unix)]
    Unix(PathBuf),
}

/// Parse `tcp:HOST:PORT`, `unix:PATH`, or bare `HOST:PORT` (§1).
fn parse_addr(s: &str) -> anyhow::Result<AddrSpec> {
    if let Some(rest) = s.strip_prefix("tcp:") {
        return Ok(AddrSpec::Tcp(rest.to_string()));
    }
    if let Some(rest) = s.strip_prefix("unix:") {
        #[cfg(unix)]
        {
            return Ok(AddrSpec::Unix(PathBuf::from(rest)));
        }
        #[cfg(not(unix))]
        {
            let _ = rest;
            anyhow::bail!("unix socket addresses are not supported on this platform: {s}");
        }
    }
    anyhow::ensure!(
        s.contains(':'),
        "socket address '{s}' must be tcp:HOST:PORT, unix:PATH, or HOST:PORT"
    );
    Ok(AddrSpec::Tcp(s.to_string()))
}

/// One connected byte stream: TCP or Unix domain socket, behind a
/// common `Read`/`Write` face (the codec above is transport-agnostic).
pub enum Stream {
    /// TCP connection (Nagle disabled — frames are latency-sensitive).
    Tcp(TcpStream),
    /// Unix-domain-socket connection.
    #[cfg(unix)]
    Unix(UnixStream),
}

impl Stream {
    fn try_clone(&self) -> std::io::Result<Stream> {
        match self {
            Stream::Tcp(s) => s.try_clone().map(Stream::Tcp),
            #[cfg(unix)]
            Stream::Unix(s) => s.try_clone().map(Stream::Unix),
        }
    }

    fn set_read_timeout(&self, d: Option<Duration>) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.set_read_timeout(d),
            #[cfg(unix)]
            Stream::Unix(s) => s.set_read_timeout(d),
        }
    }

    fn set_nonblocking(&self, nb: bool) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.set_nonblocking(nb),
            #[cfg(unix)]
            Stream::Unix(s) => s.set_nonblocking(nb),
        }
    }

    fn shutdown_both(&self) {
        match self {
            Stream::Tcp(s) => {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
            #[cfg(unix)]
            Stream::Unix(s) => {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
        }
    }
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            Stream::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            Stream::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.flush(),
            #[cfg(unix)]
            Stream::Unix(s) => s.flush(),
        }
    }
}

/// Connect a raw stream to a server address (`tcp:HOST:PORT`,
/// `unix:PATH`, or bare `HOST:PORT`). Exposed for the conformance
/// suite's raw-frame tests; worker processes use [`connect`].
pub fn connect_stream(addr: &str) -> anyhow::Result<Stream> {
    match parse_addr(addr)? {
        AddrSpec::Tcp(hostport) => {
            let s = TcpStream::connect(&hostport)
                .with_context(|| format!("connecting to tcp:{hostport}"))?;
            let _ = s.set_nodelay(true);
            Ok(Stream::Tcp(s))
        }
        #[cfg(unix)]
        AddrSpec::Unix(path) => {
            let s = UnixStream::connect(&path)
                .with_context(|| format!("connecting to unix:{}", path.display()))?;
            Ok(Stream::Unix(s))
        }
    }
}

/// The listening half (server side).
enum Listener {
    Tcp(TcpListener),
    #[cfg(unix)]
    Unix(UnixListener, PathBuf),
}

impl Listener {
    /// Bind; returns the listener and, for UDS, the path to unlink at
    /// shutdown. A stale socket file from a crashed run is removed
    /// before binding.
    fn bind(spec: &AddrSpec) -> anyhow::Result<(Listener, Option<PathBuf>)> {
        match spec {
            AddrSpec::Tcp(hostport) => {
                let l = TcpListener::bind(hostport)
                    .with_context(|| format!("binding tcp:{hostport}"))?;
                Ok((Listener::Tcp(l), None))
            }
            #[cfg(unix)]
            AddrSpec::Unix(path) => {
                let _ = std::fs::remove_file(path);
                let l = UnixListener::bind(path)
                    .with_context(|| format!("binding unix:{}", path.display()))?;
                Ok((Listener::Unix(l, path.clone()), Some(path.clone())))
            }
        }
    }

    fn set_nonblocking(&self, nb: bool) -> std::io::Result<()> {
        match self {
            Listener::Tcp(l) => l.set_nonblocking(nb),
            #[cfg(unix)]
            Listener::Unix(l, _) => l.set_nonblocking(nb),
        }
    }

    fn accept(&self) -> std::io::Result<Stream> {
        match self {
            Listener::Tcp(l) => {
                let (s, _) = l.accept()?;
                let _ = s.set_nodelay(true);
                Ok(Stream::Tcp(s))
            }
            #[cfg(unix)]
            Listener::Unix(l, _) => {
                let (s, _) = l.accept()?;
                Ok(Stream::Unix(s))
            }
        }
    }

    /// Display form of the bound address, connectable by [`connect`].
    fn display_addr(&self) -> anyhow::Result<String> {
        match self {
            Listener::Tcp(l) => Ok(format!("tcp:{}", l.local_addr()?)),
            #[cfg(unix)]
            Listener::Unix(_, path) => Ok(format!("unix:{}", path.display())),
        }
    }
}

// ---------------------------------------------------------------------
// Options.
// ---------------------------------------------------------------------

/// Socket-backend construction knobs (the `[cluster]` config section's
/// `socket_listen`/`socket_chunk` keys and the corresponding CLI flags).
#[derive(Debug, Clone)]
pub struct SocketOptions {
    /// Listen address (`tcp:HOST:PORT` or `unix:PATH`). `None` binds an
    /// ephemeral loopback TCP port.
    pub listen: Option<String>,
    /// f32 coordinates per GradientChunk frame (≥ 1).
    pub chunk: usize,
    /// `true`: workers are external processes and
    /// `WorkerEndpoint::serve` is a no-op; `false` (default): `serve`
    /// spawns an in-process client thread per worker.
    pub external: bool,
    /// Gradient codec the in-process clients announce at Hello (§7) —
    /// the `codec` config knob. External worker processes negotiate
    /// their own capability via `multibulyan worker --codec`.
    pub codec: crate::codec::CodecKind,
}

impl Default for SocketOptions {
    fn default() -> Self {
        Self {
            listen: None,
            chunk: DEFAULT_CHUNK,
            external: false,
            codec: crate::codec::CodecKind::Raw,
        }
    }
}

// ---------------------------------------------------------------------
// Server half.
// ---------------------------------------------------------------------

/// State shared between the server handle, the accept thread and the
/// per-connection reader threads. One mutex covers both the connection
/// table and the pending broadcast so late-joiner replay (§6.5) cannot
/// race a concurrent `broadcast`.
struct ServerState {
    /// Write halves, indexed by worker id (a `Vec`, not a map — no hash
    /// iteration, and ids are dense by construction).
    conns: Vec<Option<Stream>>,
    /// Most recent broadcast, replayed to workers that register after
    /// it was sent (§6.1).
    pending: Option<(u64, Arc<Vec<f32>>)>,
    /// Departure flags (§8.1): set on an orderly Goodbye or a
    /// crash-detected disconnect, cleared when the id re-registers.
    /// Surfaced through [`super::ServerEndpoint::departed_workers`] so
    /// the coordinator can shrink the next round's membership view.
    departed: Vec<bool>,
    /// Registration generation per worker id. A reader thread records
    /// the generation it registered under and only deregisters/marks
    /// departure if it is still the current holder — an evicted stale
    /// reader (§8.2 rejoin) must not clobber its replacement.
    generation: Vec<u64>,
}

struct Shared {
    n: usize,
    state: Mutex<ServerState>,
    tx: mpsc::Sender<FromWorker>,
    stop: AtomicBool,
    /// UDS path to unlink at shutdown.
    cleanup: Option<PathBuf>,
    /// Two-level mode (`groups > 1`): installed by the launcher via
    /// [`super::ServerEndpoint::install_group_reducer`]. When set, each
    /// GradientChunk frame's coordinates fold straight into the
    /// reducer's per-group slots at reassembly — a whole gradient is
    /// never buffered per worker — and completion is announced to the
    /// collect session as an *empty* [`FromWorker`].
    group: Mutex<Option<Arc<crate::gar::GroupReducer>>>,
}

/// One in-flight incremental collection — identical bookkeeping to the
/// threaded backend's session (§6.2).
struct Session {
    round: u64,
    /// Quorum cap (`usize::MAX` after `collect_extend`).
    expect: usize,
    // wall-clock: real deadline that remote worker processes race.
    deadline: Option<Instant>,
    accepted: usize,
    disconnected: bool,
}

/// Socket server half: owns the reader-channel receiver and the shared
/// connection state; the accept loop and per-connection readers run on
/// their own threads.
pub(super) struct Server {
    shared: Arc<Shared>,
    from_workers: mpsc::Receiver<FromWorker>,
    addr: String,
    session: Option<Session>,
}

/// Build a Reject frame (§4.4).
fn reject_frame(round: u64, worker: u32, reason: u8) -> Frame {
    Frame {
        kind: PayloadKind::Reject,
        round,
        worker,
        payload: vec![reason],
    }
}

/// Send a Reject to a registered worker through its stored write half
/// (all server → worker writes are serialized under the state mutex so
/// frames never interleave mid-frame on one connection).
fn send_reject(shared: &Shared, worker: usize, round: u64, reason: u8) {
    let bytes = encode(&reject_frame(round, worker as u32, reason));
    let mut st = lock(&shared.state);
    if let Some(conn) = st.conns.get_mut(worker).and_then(|c| c.as_mut()) {
        let _ = conn.write_all(&bytes);
        let _ = conn.flush();
    }
}

/// In-order reassembly of one worker's chunked gradient (§4.3, §6.3):
/// chunks must arrive at offset 0 first and strictly in order; a round
/// change or any bookkeeping violation resets the assembly. Encoded
/// chunks (§7) are decoded straight into the assembly buffer — the
/// server never materializes the encoded gradient.
#[derive(Default)]
struct ChunkAssembly {
    round: u64,
    active: bool,
    total: usize,
    buf: Vec<f32>,
}

enum Feed {
    Partial,
    Complete(Vec<f32>),
    Malformed,
    /// Unknown codec id, a codec other than the negotiated one (or
    /// `raw`), or a payload that failed decode (§7) — rejected with
    /// [`REJECT_CODEC`], never reaching the collect session.
    Codec,
}

impl ChunkAssembly {
    fn reset(&mut self) {
        self.active = false;
        self.buf.clear();
    }

    fn feed(&mut self, round: u64, payload: &[u8], negotiated: crate::codec::CodecKind) -> Feed {
        let Some((offset, total, count, codec_id, bytes)) = parse_chunk(payload) else {
            self.reset();
            return Feed::Malformed;
        };
        let Some(codec) = crate::codec::CodecKind::from_wire(codec_id) else {
            self.reset();
            return Feed::Codec;
        };
        // A chunk may use only the Hello-negotiated codec; `raw` is
        // always acceptable (§7).
        if codec != negotiated && codec != crate::codec::CodecKind::Raw {
            self.reset();
            return Feed::Codec;
        }
        let (offset, total, count) = (offset as usize, total as usize, count as usize);
        // Allocation guard: the claimed coordinate counts are bounded by
        // what a maximal raw payload could carry before `reserve` runs —
        // a tiny encoded frame cannot command a huge allocation (the
        // per-payload expansion itself is bounded by the codec layer's
        // suspicious-ratio guard).
        if count > MAX_PAYLOAD as usize / 4 || total > MAX_PAYLOAD as usize / 4 {
            self.reset();
            return Feed::Malformed;
        }
        if codec == crate::codec::CodecKind::Raw && bytes.len() != count * 4 {
            self.reset();
            return Feed::Malformed;
        }
        if !self.active || round != self.round || total != self.total {
            // A new gradient begins; it must begin at offset 0 (§4.3).
            if offset != 0 {
                self.reset();
                return Feed::Malformed;
            }
            self.round = round;
            self.total = total;
            self.active = true;
            self.buf.clear();
        }
        if offset != self.buf.len() || self.buf.len() + count > self.total {
            self.reset();
            return Feed::Malformed;
        }
        if crate::codec::decode(codec, offset, count, bytes, &mut self.buf).is_err() {
            self.reset();
            return Feed::Codec;
        }
        if self.buf.len() == self.total {
            self.active = false;
            Feed::Complete(std::mem::take(&mut self.buf))
        } else {
            Feed::Partial
        }
    }
}

/// What [`feed_grouped`] left behind — [`Feed`] plus the grouped-mode
/// outcomes that have no flat-path analogue.
enum GroupFeed {
    /// Chunk folded into the reducer; more chunks expected.
    Accepted,
    /// This worker's gradient completed — announce with an empty
    /// [`FromWorker`].
    Completed,
    Malformed,
    /// Stale round (or duplicate completion) — silently consumed, like
    /// the flat session's stale discard.
    Stale,
    /// Codec violation, rejected with [`REJECT_CODEC`].
    Codec,
}

/// Grouped-mode chunk path (§6.3 under `groups > 1`): decode one
/// GradientChunk's coordinates into `scratch` (chunk-sized, reused) and
/// fold them into the [`GroupReducer`](crate::gar::GroupReducer) at the
/// worker's in-order cursor. Mirrors [`ChunkAssembly::feed`]'s wire
/// validation; the in-order/bounds bookkeeping lives in the reducer.
fn feed_grouped(
    scratch: &mut Vec<f32>,
    round: u64,
    payload: &[u8],
    negotiated: crate::codec::CodecKind,
    reducer: &crate::gar::GroupReducer,
    worker: usize,
) -> GroupFeed {
    use crate::gar::group::ChunkIngest;
    let Some((offset, _total, count, codec_id, bytes)) = parse_chunk(payload) else {
        return GroupFeed::Malformed;
    };
    let Some(codec) = crate::codec::CodecKind::from_wire(codec_id) else {
        return GroupFeed::Codec;
    };
    if codec != negotiated && codec != crate::codec::CodecKind::Raw {
        return GroupFeed::Codec;
    }
    let (offset, count) = (offset as usize, count as usize);
    if count > MAX_PAYLOAD as usize / 4 {
        return GroupFeed::Malformed;
    }
    if codec == crate::codec::CodecKind::Raw && bytes.len() != count * 4 {
        return GroupFeed::Malformed;
    }
    scratch.clear();
    if crate::codec::decode(codec, 0, count, bytes, scratch).is_err() {
        return GroupFeed::Codec;
    }
    match reducer.ingest_chunk(worker, round, offset, scratch) {
        ChunkIngest::Accepted => GroupFeed::Accepted,
        ChunkIngest::Completed => GroupFeed::Completed,
        ChunkIngest::Malformed => GroupFeed::Malformed,
        ChunkIngest::Stale => GroupFeed::Stale,
    }
}

/// Per-connection serve loop (§6): Hello handshake + registration, then
/// frames until EOF/Shutdown/stop. Runs on its own reader thread.
fn serve_conn(mut stream: Stream, shared: &Shared) {
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_read_timeout(Some(READ_TICK));
    // Handshake: the first frame must be a well-formed Hello (§6.5).
    let hello = match read_frame(&mut stream, Some(&shared.stop)) {
        Ok(f) => f,
        Err(FrameError::BadVersion(_)) => {
            let _ = write_frame(&mut stream, &reject_frame(0, u32::MAX, REJECT_VERSION));
            return;
        }
        Err(_) => return,
    };
    if hello.kind != PayloadKind::Hello {
        return;
    }
    let worker = hello.worker as usize;
    // Codec negotiation (§7) + membership flags (§8.2): an empty Hello
    // payload is codec `raw` (what every pre-§7 client sends); one byte
    // is a capability id; two bytes add a v3 flags byte whose bit 0
    // requests a rejoin. An unknown codec id or overlong payload is
    // rejected with REJECT_CODEC; reserved flag bits with
    // REJECT_MALFORMED. Either way the connection is closed.
    let (negotiated, rejoin) = match hello.payload.as_slice() {
        [] => (crate::codec::CodecKind::Raw, false),
        [id] | [id, _] => {
            let Some(kind) = crate::codec::CodecKind::from_wire(*id) else {
                let _ = write_frame(&mut stream, &reject_frame(0, hello.worker, REJECT_CODEC));
                return;
            };
            match hello.payload.get(1) {
                None => (kind, false),
                Some(flags) if flags & !0x01 == 0 => (kind, flags & 0x01 != 0),
                Some(_) => {
                    let _ =
                        write_frame(&mut stream, &reject_frame(0, hello.worker, REJECT_MALFORMED));
                    return;
                }
            }
        }
        _ => {
            let _ = write_frame(&mut stream, &reject_frame(0, hello.worker, REJECT_CODEC));
            return;
        }
    };
    let my_generation;
    {
        let mut st = lock(&shared.state);
        if shared.stop.load(Ordering::SeqCst) {
            return;
        }
        if worker >= shared.n {
            drop(st);
            let _ = write_frame(&mut stream, &reject_frame(0, hello.worker, REJECT_BAD_WORKER));
            return;
        }
        if st.conns[worker].is_some() {
            // An incumbent holds this id. A rejoin Hello (§8.2) evicts
            // it deterministically; otherwise the incumbent's liveness
            // is probed with a Hello ping (informational to clients,
            // §5.3) — a dead incumbent whose EOF has not yet been
            // observed is evicted, a live one wins and the newcomer is
            // turned away (§6.5).
            let evict = rejoin || {
                let conn = st.conns[worker].as_mut().expect("incumbent checked above");
                let ping = encode(&Frame {
                    kind: PayloadKind::Hello,
                    round: 0,
                    worker: hello.worker,
                    payload: Vec::new(),
                });
                conn.write_all(&ping).and_then(|()| conn.flush()).is_err()
            };
            if !evict {
                drop(st);
                let _ = write_frame(&mut stream, &reject_frame(0, hello.worker, REJECT_DUPLICATE));
                return;
            }
            if let Some(old) = st.conns[worker].take() {
                old.shutdown_both();
            }
        }
        let Ok(mut write_half) = stream.try_clone() else {
            return;
        };
        let ack = Frame {
            kind: PayloadKind::Hello,
            round: 0,
            worker: hello.worker,
            payload: Vec::new(),
        };
        if write_frame(&mut write_half, &ack).is_err() {
            return;
        }
        // Late-joiner replay: a worker that registers after a broadcast
        // still gets the current round (§6.1).
        if let Some((round, params)) = &st.pending {
            let _ = write_frame(
                &mut write_half,
                &Frame {
                    kind: PayloadKind::RoundResult,
                    round: *round,
                    worker: u32::MAX,
                    payload: params_payload(params),
                },
            );
        }
        st.conns[worker] = Some(write_half);
        // Registration clears any earlier departure and bumps the
        // generation so a stale evicted reader cannot deregister us.
        st.departed[worker] = false;
        st.generation[worker] = st.generation[worker].wrapping_add(1);
        my_generation = st.generation[worker];
    }
    let mut asm = ChunkAssembly::default();
    let mut gscratch: Vec<f32> = Vec::new();
    loop {
        match read_frame(&mut stream, Some(&shared.stop)) {
            Ok(f) => match f.kind {
                PayloadKind::GradientChunk => {
                    if f.worker as usize != worker {
                        // A chunk must carry the id this connection
                        // registered (§6.5).
                        asm.reset();
                        send_reject(shared, worker, f.round, REJECT_MALFORMED);
                        continue;
                    }
                    // Two-level mode: fold the chunk into the group
                    // reducer as it arrives instead of reassembling the
                    // whole gradient (the clone is one Arc bump per
                    // frame; the reducer itself is shared).
                    let group = lock(&shared.group).clone();
                    if let Some(reducer) = group {
                        match feed_grouped(
                            &mut gscratch,
                            f.round,
                            &f.payload,
                            negotiated,
                            &reducer,
                            worker,
                        ) {
                            GroupFeed::Completed => {
                                let _ = shared.tx.send(FromWorker {
                                    worker,
                                    round: f.round,
                                    gradient: Vec::new(),
                                    coded: None,
                                });
                            }
                            GroupFeed::Accepted | GroupFeed::Stale => {}
                            GroupFeed::Malformed => {
                                send_reject(shared, worker, f.round, REJECT_MALFORMED)
                            }
                            GroupFeed::Codec => {
                                send_reject(shared, worker, f.round, REJECT_CODEC)
                            }
                        }
                        continue;
                    }
                    match asm.feed(f.round, &f.payload, negotiated) {
                        Feed::Complete(gradient) => {
                            let _ = shared.tx.send(FromWorker {
                                worker,
                                round: f.round,
                                gradient,
                                coded: None,
                            });
                        }
                        Feed::Partial => {}
                        Feed::Malformed => send_reject(shared, worker, f.round, REJECT_MALFORMED),
                        // The §7 rule: a codec failure is rejected like a
                        // malformed chunk — consumed, answered, and never
                        // delivered, so it cannot occupy a quorum slot.
                        Feed::Codec => send_reject(shared, worker, f.round, REJECT_CODEC),
                    }
                }
                PayloadKind::Shutdown => break,
                // Orderly departure (§8.1): fall through to the exit
                // cleanup below, which marks the id departed.
                PayloadKind::Goodbye => break,
                PayloadKind::Hello => {}
                PayloadKind::RoundResult | PayloadKind::Reject => {
                    // Server-bound streams must not carry client-bound
                    // kinds; rejected but not fatal (§5.3).
                    send_reject(shared, worker, f.round, REJECT_MALFORMED);
                }
            },
            // Recoverable frame errors: the sender is told, the
            // connection survives, and the bad frame never reaches the
            // collect session — it cannot occupy a quorum slot (§5.1).
            Err(FrameError::Checksum { .. }) => send_reject(shared, worker, 0, REJECT_CHECKSUM),
            Err(FrameError::BadKind(_)) => send_reject(shared, worker, 0, REJECT_UNKNOWN_KIND),
            Err(FrameError::Shutdown) => break,
            // Closed/Truncated/BadMagic/BadVersion/Oversize/Io: the
            // stream cannot be trusted to be in sync — drop it (§5.3).
            Err(_) => break,
        }
    }
    let mut st = lock(&shared.state);
    if st.generation[worker] == my_generation {
        st.conns[worker] = None;
        if !shared.stop.load(Ordering::SeqCst) {
            // Goodbye or crash-detected disconnect (§8.1): the id is
            // reported by `departed_workers` until it re-registers. A
            // cluster-wide shutdown is not a departure.
            st.departed[worker] = true;
        }
    }
}

/// Accept loop: non-blocking accept + stop-flag poll, one reader thread
/// per accepted connection. Owns the listener; dropping it on exit
/// frees the port/path.
fn accept_loop(listener: Listener, shared: Arc<Shared>) {
    if listener.set_nonblocking(true).is_err() {
        return;
    }
    while !shared.stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok(stream) => {
                let shared = Arc::clone(&shared);
                let _ = std::thread::Builder::new()
                    .name("socket-conn".to_string())
                    .spawn(move || serve_conn(stream, &shared));
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => std::thread::sleep(ACCEPT_TICK),
            Err(_) => std::thread::sleep(ACCEPT_TICK),
        }
    }
}

impl Server {
    /// The bound listen address in [`connect`]-able form.
    pub(super) fn addr(&self) -> &str {
        &self.addr
    }

    pub(super) fn broadcast(&mut self, round: u64, params: Arc<Vec<f32>>) {
        let mut st = lock(&self.shared.state);
        st.pending = Some((round, Arc::clone(&params)));
        let bytes = encode(&Frame {
            kind: PayloadKind::RoundResult,
            round,
            worker: u32::MAX,
            payload: params_payload(&params),
        });
        for conn in st.conns.iter_mut().flatten() {
            // A write error means that worker is gone; its reader
            // thread will notice the EOF and deregister it (§6.4).
            let _ = conn.write_all(&bytes);
            let _ = conn.flush();
        }
    }

    pub(super) fn install_group_reducer(&mut self, reducer: Arc<crate::gar::GroupReducer>) {
        *lock(&self.shared.group) = Some(reducer);
    }

    pub(super) fn collect_begin(&mut self, round: u64, expect: usize, timeout: Duration) {
        self.session = Some(Session {
            round,
            expect,
            // wall-clock: arms the physical collect deadline (§6.2).
            deadline: Instant::now().checked_add(timeout),
            accepted: 0,
            disconnected: false,
        });
    }

    /// One wait on the reader channel, delivering at most one accepted
    /// gradient — byte-for-byte the threaded backend's session logic
    /// (§6.2, §6.3): stale rounds are discarded, a rejected gradient
    /// does not fill an `expect` slot, and `aux` (the prefix-overlap
    /// hook) runs inline with the wait capped at [`STEP`].
    pub(super) fn collect_step(
        &mut self,
        on_gradient: &mut dyn FnMut(usize, &[f32]) -> bool,
        aux: Option<&(dyn Fn() + Sync)>,
    ) -> CollectStatus {
        let Some(sess) = self.session.as_mut() else {
            return CollectStatus::Exhausted;
        };
        if sess.accepted >= sess.expect {
            return CollectStatus::Quorum;
        }
        if sess.disconnected {
            return CollectStatus::Exhausted;
        }
        let remaining = match sess.deadline {
            // wall-clock: time left until the physical deadline.
            Some(d) => d.saturating_duration_since(Instant::now()),
            None => STEP,
        };
        if remaining.is_zero() {
            return CollectStatus::Exhausted;
        }
        let wait = if let Some(aux) = aux {
            aux();
            remaining.min(STEP)
        } else {
            remaining
        };
        match self.from_workers.recv_timeout(wait) {
            Ok(msg) if msg.round == sess.round => {
                if on_gradient(msg.worker, &msg.gradient) {
                    sess.accepted += 1;
                }
                if sess.accepted >= sess.expect {
                    CollectStatus::Quorum
                } else {
                    CollectStatus::Pending
                }
            }
            Ok(_stale) => CollectStatus::Pending,
            Err(mpsc::RecvTimeoutError::Timeout) => CollectStatus::Pending,
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                sess.disconnected = true;
                CollectStatus::Exhausted
            }
        }
    }

    pub(super) fn collect_extend(&mut self) {
        if let Some(sess) = self.session.as_mut() {
            sess.expect = usize::MAX;
        }
    }

    pub(super) fn collect_accepted(&self) -> usize {
        self.session.as_ref().map_or(0, |s| s.accepted)
    }

    pub(super) fn collect_finish(&mut self) {
        self.session = None;
    }

    /// Idempotent: Shutdown frame + socket teardown to every live
    /// connection, stop the accept/reader threads, unlink a UDS path.
    pub(super) fn shutdown(&self) {
        if self.shared.stop.swap(true, Ordering::SeqCst) {
            return;
        }
        let mut st = lock(&self.shared.state);
        let bye = encode(&Frame {
            kind: PayloadKind::Shutdown,
            round: 0,
            worker: u32::MAX,
            payload: Vec::new(),
        });
        for conn in st.conns.iter_mut().flatten() {
            let _ = conn.write_all(&bye);
            let _ = conn.flush();
            conn.shutdown_both();
        }
        for slot in st.conns.iter_mut() {
            *slot = None;
        }
        st.pending = None;
        if let Some(path) = &self.shared.cleanup {
            let _ = std::fs::remove_file(path);
        }
    }

    pub(super) fn num_workers(&self) -> usize {
        self.shared.n
    }

    /// Worker ids that left the cluster — orderly Goodbye or
    /// crash-detected disconnect (§8.1) — and have not re-registered.
    /// Ascending by construction (index order of the flag vector).
    pub(super) fn departed_workers(&self) -> Vec<usize> {
        let st = lock(&self.shared.state);
        st.departed
            .iter()
            .enumerate()
            .filter_map(|(id, gone)| gone.then_some(id))
            .collect()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

// ---------------------------------------------------------------------
// Worker half.
// ---------------------------------------------------------------------

/// Socket worker slot: in self-hosted mode `serve` spawns an in-process
/// client thread; in external mode the slot is inert (the worker is
/// another process).
pub(super) struct WorkerSlot {
    id: usize,
    addr: String,
    faults: FaultModel,
    chunk: usize,
    external: bool,
    codec: crate::codec::CodecKind,
}

impl WorkerSlot {
    pub(super) fn id(&self) -> usize {
        self.id
    }

    pub(super) fn serve(self, body: Box<dyn WorkerBody>) {
        if self.external {
            drop(body);
            return;
        }
        spawn_client(self.addr, self.id, self.faults, self.chunk, self.codec, body);
    }
}

/// Spawn an in-process client thread: connect, handshake, serve rounds
/// with `body` until Shutdown/EOF. A body panic kills only this thread
/// — the connection closes, the server sees a crashed worker (§6.4).
fn spawn_client(
    addr: String,
    worker: usize,
    faults: FaultModel,
    chunk: usize,
    codec: crate::codec::CodecKind,
    mut body: Box<dyn WorkerBody>,
) {
    std::thread::Builder::new()
        .name(format!("socket-worker-{worker}"))
        .spawn(move || {
            let Ok(client) = connect(&addr, worker, chunk, codec) else {
                return;
            };
            let _ = client.run(&mut *body, faults);
        })
        .expect("spawning socket worker thread");
}

/// A connected, registered worker-side client (Hello handshake done).
/// Drive it with [`run`](Self::run) (any [`WorkerBody`], fault-model
/// aware — the in-process mode) or
/// [`run_streaming`](Self::run_streaming) (a
/// [`GradWorker`](crate::worker::GradWorker), chunk-cursor streaming —
/// the `multibulyan worker` CLI mode).
pub struct WorkerClient {
    stream: Stream,
    worker: u32,
    chunk: usize,
}

/// Connect to a server and register as `worker` (§6.5): sends Hello
/// carrying the codec capability byte (§7), waits for the server's
/// Hello ack. `chunk` is the GradientChunk size used for outgoing
/// gradients.
pub fn connect(
    addr: &str,
    worker: usize,
    chunk: usize,
    codec: crate::codec::CodecKind,
) -> anyhow::Result<WorkerClient> {
    connect_opts(addr, worker, chunk, codec, false)
}

/// Like [`connect`], with `rejoin` setting bit 0 of the v3 Hello flags
/// byte (§8.2): the server deterministically evicts a stale
/// registration for this worker id instead of answering
/// `REJECT_DUPLICATE`. This is the path a crashed-and-restarted
/// external worker takes (`multibulyan worker --rejoin`).
pub fn connect_opts(
    addr: &str,
    worker: usize,
    chunk: usize,
    codec: crate::codec::CodecKind,
    rejoin: bool,
) -> anyhow::Result<WorkerClient> {
    let mut stream = connect_stream(addr)?;
    let payload = if rejoin {
        vec![codec.wire_id(), 0x01]
    } else {
        vec![codec.wire_id()]
    };
    write_frame(
        &mut stream,
        &Frame {
            kind: PayloadKind::Hello,
            round: 0,
            worker: worker as u32,
            payload,
        },
    )
    .map_err(|e| anyhow::anyhow!("worker {worker}: sending Hello to {addr}: {e}"))?;
    match read_frame(&mut stream, None) {
        Ok(f) if f.kind == PayloadKind::Hello => Ok(WorkerClient {
            stream,
            worker: worker as u32,
            chunk: chunk.max(1),
        }),
        Ok(f) if f.kind == PayloadKind::Reject => anyhow::bail!(
            "server rejected worker {worker}: {}",
            reject_reason_str(f.payload.first().copied().unwrap_or(0))
        ),
        Ok(f) => anyhow::bail!("worker {worker}: unexpected handshake frame {:?}", f.kind),
        Err(e) => anyhow::bail!("worker {worker}: handshake with {addr} failed: {e:?}"),
    }
}

impl WorkerClient {
    /// Serve rounds with `body` until the server shuts down or the
    /// connection closes. Applies the same per-worker fault RNG stream
    /// and pre-compute cost sleep as the threaded backend — byte-order
    /// parity is what keeps seeded runs transport-independent.
    pub fn run(mut self, body: &mut dyn WorkerBody, faults: FaultModel) -> anyhow::Result<()> {
        let worker = self.worker as usize;
        let mut rng = faults.rng_for(worker);
        let cost_us = faults.cost.cost_us_for(worker);
        let mut scratch = Vec::new();
        loop {
            let frame = match read_frame(&mut self.stream, None) {
                Ok(f) => f,
                Err(FrameError::Closed) => return Ok(()),
                Err(e) => anyhow::bail!("worker {worker}: connection lost: {e:?}"),
            };
            match frame.kind {
                PayloadKind::RoundResult => {
                    let params = parse_params(&frame.payload)?;
                    if cost_us > 0 {
                        std::thread::sleep(Duration::from_micros(cost_us));
                    }
                    let mut emit = Emitter {
                        worker,
                        faults,
                        rng: &mut rng,
                        sink: EmitterSink::Frame {
                            stream: &mut self.stream,
                            worker: self.worker,
                            chunk: self.chunk,
                            scratch: &mut scratch,
                        },
                        // Two-level mode ingests server-side at chunk
                        // reassembly on this backend; the client always
                        // streams plain frames.
                        group: None,
                    };
                    body.on_round(frame.round, &params, &mut emit);
                }
                PayloadKind::Shutdown => return Ok(()),
                // Duplicate acks and server-side rejects of earlier
                // frames are informational; anything else addressed to
                // a client is ignored (§5.3).
                _ => {}
            }
        }
    }

    /// Serve rounds with a [`GradWorker`](crate::worker::GradWorker),
    /// streaming each gradient chunk as soon as its coordinates are
    /// computed (`GradWorker::stream_round` — a chunk-sized scratch
    /// instead of a full d-length buffer per send), encoding each chunk
    /// through the worker's configured codec (§7). No fault model: this
    /// is the real-process path of the `multibulyan worker` CLI.
    pub fn run_streaming(mut self, mut worker: crate::worker::GradWorker) -> anyhow::Result<()> {
        let id = self.worker;
        let chunk = self.chunk;
        let mut scratch = Vec::new();
        let mut enc = Vec::new();
        // The encoder moves out of the GradWorker so the stream closure
        // below can borrow it alongside the worker's own `&mut self`.
        let mut codec = worker.take_codec();
        loop {
            let frame = match read_frame(&mut self.stream, None) {
                Ok(f) => f,
                Err(FrameError::Closed) => return Ok(()),
                Err(e) => anyhow::bail!("worker {id}: connection lost: {e:?}"),
            };
            match frame.kind {
                PayloadKind::RoundResult => {
                    let params = parse_params(&frame.payload)?;
                    let round = frame.round;
                    let stream = &mut self.stream;
                    let codec = &mut codec;
                    let enc = &mut enc;
                    let scratch = &mut scratch;
                    // A failed gradient computation leaves the worker
                    // silent for the round (same policy as on_round); a
                    // partial chunk trail is discarded by the server's
                    // assembly reset on the next round (§4.3).
                    let _ = worker.stream_round(round, &params, chunk, &mut |offset, values, total| {
                        match codec.as_deref_mut() {
                            None => write_chunk_frame(
                                stream,
                                id,
                                round,
                                offset as u32,
                                total as u32,
                                values,
                                scratch,
                            )
                            .is_ok(),
                            Some(c) => {
                                c.encode(offset, values, enc);
                                write_coded_chunk_frame(
                                    stream,
                                    id,
                                    round,
                                    offset as u32,
                                    total as u32,
                                    values.len() as u32,
                                    c.kind().wire_id(),
                                    enc,
                                    scratch,
                                )
                                .is_ok()
                            }
                        }
                    });
                }
                PayloadKind::Shutdown => return Ok(()),
                _ => {}
            }
        }
    }

    /// Orderly departure (§8.1): send a Goodbye frame and close the
    /// connection. The server marks this id departed — the run
    /// continues without it — and the slot is free for a later rejoin.
    pub fn goodbye(mut self) -> anyhow::Result<()> {
        write_frame(
            &mut self.stream,
            &Frame {
                kind: PayloadKind::Goodbye,
                round: 0,
                worker: self.worker,
                payload: Vec::new(),
            },
        )
        .map_err(|e| anyhow::anyhow!("worker {}: sending Goodbye: {e}", self.worker))
    }
}

/// Build the socket star: bind per `opts`, start the accept thread,
/// hand out `n` worker slots (self-hosted client threads or inert
/// external placeholders — see [`SocketOptions::external`]).
pub(super) fn star(
    n: usize,
    faults: FaultModel,
    opts: &SocketOptions,
) -> anyhow::Result<(Server, Vec<WorkerSlot>)> {
    let spec = match &opts.listen {
        Some(a) => parse_addr(a)?,
        None => AddrSpec::Tcp("127.0.0.1:0".to_string()),
    };
    let (listener, cleanup) = Listener::bind(&spec)?;
    let addr = listener.display_addr()?;
    let (tx, rx) = mpsc::channel::<FromWorker>();
    let shared = Arc::new(Shared {
        n,
        state: Mutex::new(ServerState {
            conns: (0..n).map(|_| None).collect(),
            pending: None,
            departed: vec![false; n],
            generation: vec![0; n],
        }),
        tx,
        stop: AtomicBool::new(false),
        cleanup,
        group: Mutex::new(None),
    });
    {
        let shared = Arc::clone(&shared);
        std::thread::Builder::new()
            .name("socket-accept".to_string())
            .spawn(move || accept_loop(listener, shared))
            .expect("spawning socket accept thread");
    }
    let chunk = opts.chunk.max(1);
    let workers = (0..n)
        .map(|id| WorkerSlot {
            id,
            addr: addr.clone(),
            faults,
            chunk,
            external: opts.external,
            codec: opts.codec,
        })
        .collect();
    Ok((
        Server {
            shared,
            from_workers: rx,
            addr,
            session: None,
        },
        workers,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;

    fn roundtrip(frame: &Frame) {
        let bytes = encode(frame);
        let got = read_frame(&mut &bytes[..], None).expect("decode");
        assert_eq!(&got, frame);
    }

    #[test]
    fn codec_roundtrips_empty_payload() {
        roundtrip(&Frame {
            kind: PayloadKind::Hello,
            round: 0,
            worker: 7,
            payload: Vec::new(),
        });
    }

    #[test]
    fn codec_roundtrips_all_kinds_and_sizes() {
        for (kind, len) in [
            (PayloadKind::Hello, 0usize),
            (PayloadKind::RoundResult, 4),
            (PayloadKind::GradientChunk, 8 + 4 * DEFAULT_CHUNK),
            (PayloadKind::Reject, 1),
            (PayloadKind::Shutdown, 0),
            (PayloadKind::Goodbye, 0),
        ] {
            roundtrip(&Frame {
                kind,
                round: u64::MAX,
                worker: u32::MAX,
                payload: (0..len).map(|i| i as u8).collect(),
            });
        }
    }

    #[test]
    fn codec_encode_decode_is_bit_identity_proptested() {
        // The invariant-catalog property: encode → decode returns the
        // exact frame for arbitrary header fields and payload bytes.
        proptest::check("frame-codec-bit-identity", proptest::default_cases(), |rng, _| {
            let kinds = [
                PayloadKind::Hello,
                PayloadKind::RoundResult,
                PayloadKind::GradientChunk,
                PayloadKind::Reject,
                PayloadKind::Shutdown,
                PayloadKind::Goodbye,
            ];
            let frame = Frame {
                kind: kinds[rng.gen_range_usize(kinds.len())],
                round: rng.next_u64(),
                worker: rng.next_u64() as u32,
                payload: (0..rng.gen_range_usize(256)).map(|_| rng.next_u64() as u8).collect(),
            };
            let bytes = encode(&frame);
            let got = read_frame(&mut &bytes[..], None)
                .map_err(|e| format!("decode failed: {e:?}"))?;
            if got != frame {
                return Err(format!("decode mismatch: {got:?} != {frame:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn short_reads_are_closed_or_truncated() {
        assert_eq!(read_frame(&mut &[][..], None), Err(FrameError::Closed));
        let bytes = encode(&Frame {
            kind: PayloadKind::Hello,
            round: 1,
            worker: 2,
            payload: vec![9, 9],
        });
        // Short header.
        assert_eq!(
            read_frame(&mut &bytes[..10], None),
            Err(FrameError::Truncated)
        );
        // Short payload.
        assert_eq!(
            read_frame(&mut &bytes[..bytes.len() - 1], None),
            Err(FrameError::Truncated)
        );
    }

    #[test]
    fn corrupted_payload_fails_checksum() {
        let mut bytes = encode(&Frame {
            kind: PayloadKind::GradientChunk,
            round: 3,
            worker: 1,
            payload: vec![1, 2, 3, 4, 5, 6, 7, 8],
        });
        let last = bytes.len() - 1;
        bytes[last] ^= 0xFF;
        assert!(matches!(
            read_frame(&mut &bytes[..], None),
            Err(FrameError::Checksum { .. })
        ));
    }

    #[test]
    fn bad_magic_and_version_are_fatal() {
        let good = encode(&Frame {
            kind: PayloadKind::Hello,
            round: 0,
            worker: 0,
            payload: Vec::new(),
        });
        let mut bad_magic = good.clone();
        bad_magic[0] = b'X';
        assert_eq!(read_frame(&mut &bad_magic[..], None), Err(FrameError::BadMagic));
        let mut bad_version = good;
        bad_version[4] = 0xFF;
        bad_version[5] = 0xFF;
        assert_eq!(
            read_frame(&mut &bad_version[..], None),
            Err(FrameError::BadVersion(0xFFFF))
        );
    }

    #[test]
    fn unknown_kind_skips_payload_and_stays_in_sync() {
        // An unknown-kind frame is consumed whole, so the next frame on
        // the stream still parses (§5.3 forward compatibility).
        let mut bytes = vec![0u8; HEADER_LEN];
        let payload = [7u8; 16];
        write_header(
            &mut bytes,
            PayloadKind::Hello,
            5,
            1,
            payload.len() as u32,
            fnv1a(payload.iter().copied()),
        );
        bytes[6] = 99; // unknown kind byte
        bytes.extend_from_slice(&payload);
        let follow = Frame {
            kind: PayloadKind::Shutdown,
            round: 8,
            worker: 2,
            payload: Vec::new(),
        };
        bytes.extend_from_slice(&encode(&follow));
        let mut r = &bytes[..];
        assert_eq!(read_frame(&mut r, None), Err(FrameError::BadKind(99)));
        assert_eq!(read_frame(&mut r, None), Ok(follow));
    }

    #[test]
    fn oversize_length_is_rejected_before_payload_read() {
        let mut bytes = vec![0u8; HEADER_LEN];
        write_header(&mut bytes, PayloadKind::GradientChunk, 0, 0, MAX_PAYLOAD + 1, 0);
        assert_eq!(
            read_frame(&mut &bytes[..], None),
            Err(FrameError::Oversize(MAX_PAYLOAD + 1))
        );
    }

    use crate::codec::CodecKind;

    fn chunk_payload(offset: u32, total: u32, values: &[f32]) -> Vec<u8> {
        let mut p = Vec::new();
        p.extend_from_slice(&offset.to_le_bytes());
        p.extend_from_slice(&total.to_le_bytes());
        p.extend_from_slice(&(values.len() as u32).to_le_bytes());
        p.push(CodecKind::Raw.wire_id());
        for v in values {
            p.extend_from_slice(&v.to_le_bytes());
        }
        p
    }

    #[test]
    fn chunk_assembly_reassembles_in_order() {
        let mut asm = ChunkAssembly::default();
        assert!(matches!(
            asm.feed(4, &chunk_payload(0, 3, &[1.0, 2.0]), CodecKind::Raw),
            Feed::Partial
        ));
        match asm.feed(4, &chunk_payload(2, 3, &[3.0]), CodecKind::Raw) {
            Feed::Complete(g) => assert_eq!(g, vec![1.0, 2.0, 3.0]),
            _ => panic!("expected completion"),
        }
    }

    #[test]
    fn chunk_assembly_rejects_out_of_order_and_overflow() {
        let mut asm = ChunkAssembly::default();
        // New gradient not starting at 0.
        assert!(matches!(
            asm.feed(1, &chunk_payload(4, 8, &[0.0]), CodecKind::Raw),
            Feed::Malformed
        ));
        // Gap in offsets.
        assert!(matches!(
            asm.feed(2, &chunk_payload(0, 4, &[0.0]), CodecKind::Raw),
            Feed::Partial
        ));
        assert!(matches!(
            asm.feed(2, &chunk_payload(2, 4, &[0.0]), CodecKind::Raw),
            Feed::Malformed
        ));
        // More values than `total`.
        assert!(matches!(
            asm.feed(3, &chunk_payload(0, 1, &[0.0, 0.0]), CodecKind::Raw),
            Feed::Malformed
        ));
        // Payload too short for the chunk prefix.
        assert!(matches!(asm.feed(4, &[0, 0, 0], CodecKind::Raw), Feed::Malformed));
        // Raw value bytes disagreeing with the declared count.
        let mut lying = chunk_payload(0, 2, &[1.0, 2.0]);
        lying.truncate(lying.len() - 1);
        assert!(matches!(asm.feed(5, &lying, CodecKind::Raw), Feed::Malformed));
    }

    #[test]
    fn chunk_assembly_round_change_resets() {
        let mut asm = ChunkAssembly::default();
        assert!(matches!(
            asm.feed(1, &chunk_payload(0, 4, &[1.0]), CodecKind::Raw),
            Feed::Partial
        ));
        // New round abandons the partial gradient (§6.3).
        match asm.feed(2, &chunk_payload(0, 1, &[9.0]), CodecKind::Raw) {
            Feed::Complete(g) => assert_eq!(g, vec![9.0]),
            _ => panic!("expected completion"),
        }
    }

    /// Build a coded chunk payload for `values` at `offset` of `total`
    /// through a real encoder (the §7 format).
    fn coded_payload(
        codec: &mut dyn crate::codec::Codec,
        offset: u32,
        total: u32,
        values: &[f32],
    ) -> Vec<u8> {
        let mut enc = Vec::new();
        codec.encode(offset as usize, values, &mut enc);
        let mut p = Vec::new();
        p.extend_from_slice(&offset.to_le_bytes());
        p.extend_from_slice(&total.to_le_bytes());
        p.extend_from_slice(&(values.len() as u32).to_le_bytes());
        p.push(codec.kind().wire_id());
        p.extend_from_slice(&enc);
        p
    }

    #[test]
    fn chunk_assembly_decodes_negotiated_codec_chunks() {
        let mut enc = crate::codec::encoder(CodecKind::Lossless);
        let mut asm = ChunkAssembly::default();
        let values = [0.0f32, -1.5, 3.25, f32::INFINITY];
        match asm.feed(
            1,
            &coded_payload(enc.as_mut(), 0, 4, &values),
            CodecKind::Lossless,
        ) {
            Feed::Complete(g) => {
                assert_eq!(g.len(), 4);
                for (a, b) in g.iter().zip(values.iter()) {
                    assert_eq!(a.to_bits(), b.to_bits(), "lossless bit round-trip");
                }
            }
            _ => panic!("expected completion"),
        }
    }

    #[test]
    fn chunk_assembly_rejects_codec_violations_as_codec_not_malformed() {
        let mut asm = ChunkAssembly::default();
        // Unknown codec id.
        let mut p = chunk_payload(0, 1, &[1.0]);
        p[12] = 250;
        assert!(matches!(asm.feed(1, &p, CodecKind::Raw), Feed::Codec));
        // A codec the connection did not negotiate (fp16 under raw).
        let mut fp16 = crate::codec::encoder(CodecKind::Fp16);
        let p = coded_payload(fp16.as_mut(), 0, 2, &[1.0, 2.0]);
        assert!(matches!(asm.feed(2, &p, CodecKind::Raw), Feed::Codec));
        // Negotiated codec but an undecodable payload: claim far more
        // coordinates than the bytes can honestly expand to.
        let mut p = Vec::new();
        p.extend_from_slice(&0u32.to_le_bytes());
        p.extend_from_slice(&1_000_000u32.to_le_bytes());
        p.extend_from_slice(&1_000_000u32.to_le_bytes());
        p.push(CodecKind::Lossless.wire_id());
        p.extend_from_slice(&[1, 0]);
        assert!(matches!(asm.feed(3, &p, CodecKind::Lossless), Feed::Codec));
        // Raw chunks are always acceptable on a lossy-negotiated
        // connection (§7).
        assert!(matches!(
            asm.feed(4, &chunk_payload(0, 1, &[1.0]), CodecKind::Int8),
            Feed::Complete(_)
        ));
    }

    #[test]
    fn params_payload_roundtrips_bit_exactly() {
        let params = vec![0.0f32, -0.0, 1.5, f32::MIN_POSITIVE, -1e30, f32::INFINITY];
        let back = parse_params(&params_payload(&params)).unwrap();
        for (a, b) in params.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert!(parse_params(&[0, 0, 0]).is_err());
    }

    #[test]
    fn addr_parse_accepts_tcp_unix_and_bare_forms() {
        assert!(matches!(parse_addr("tcp:127.0.0.1:0"), Ok(AddrSpec::Tcp(a)) if a == "127.0.0.1:0"));
        assert!(matches!(parse_addr("127.0.0.1:9"), Ok(AddrSpec::Tcp(_))));
        #[cfg(unix)]
        assert!(matches!(parse_addr("unix:/tmp/mb.sock"), Ok(AddrSpec::Unix(_))));
        assert!(parse_addr("no-port-here").is_err());
    }
}
