//! The thread-per-worker backend: one OS thread plus a pair of std-mpsc
//! channels per worker — the faithful-asynchrony simulation (workers race
//! the collect timeout for real). The [`ComputeCost`](super::ComputeCost)
//! model manifests here as a real pre-compute sleep, so a straggler's
//! race against the wall-clock deadline is physical, not simulated. See
//! the module docs in [`super`](crate::transport) for how it compares to
//! the pooled backend.

use super::{CollectStatus, Emitter, EmitterSink, FaultModel, FromWorker, WorkerBody};
use std::sync::mpsc;
use std::sync::Arc;
// wall-clock: this backend has no virtual clock — workers physically
// race the collect deadline, which is the asynchrony being simulated.
use std::time::{Duration, Instant};

/// Wall-clock granularity of one incremental collect step: the longest a
/// single [`Server::collect_step`] blocks on the worker channel before
/// reporting `Pending` (so an interleaving caller — the prefix-overlap
/// combine — regains control promptly).
const STEP: Duration = Duration::from_millis(1);

/// Server → worker messages (internal to this backend; the pooled backend
/// has no message objects at all).
enum ToWorker {
    /// Start round `round`: compute a gradient at `params`.
    Round { round: u64, params: Arc<Vec<f32>> },
    /// Terminate the worker thread.
    Shutdown,
}

/// One in-flight incremental collection (`collect_begin` ..
/// `collect_finish`); the threaded backend has no virtual clock, so the
/// session is just the deadline bookkeeping around the mpsc channel.
struct Session {
    round: u64,
    /// Quorum cap (`usize::MAX` after `collect_extend`).
    expect: usize,
    // wall-clock: real deadline the worker threads race.
    deadline: Option<Instant>,
    accepted: usize,
    /// Every worker sender hung up — no further message can arrive.
    disconnected: bool,
}

/// Threaded server half.
pub(super) struct Server {
    to_workers: Vec<mpsc::Sender<ToWorker>>,
    from_workers: mpsc::Receiver<FromWorker>,
    session: Option<Session>,
    /// Reusable decode buffer for encoded messages (`FromWorker::coded`).
    decode_scratch: Vec<f32>,
}

impl Server {
    pub(super) fn broadcast(&mut self, round: u64, params: Arc<Vec<f32>>) {
        for tx in &self.to_workers {
            let _ = tx.send(ToWorker::Round {
                round,
                params: Arc::clone(&params),
            });
        }
    }

    pub(super) fn collect_begin(&mut self, round: u64, expect: usize, timeout: Duration) {
        self.session = Some(Session {
            round,
            expect,
            // wall-clock: arms the physical collect deadline.
            deadline: Instant::now().checked_add(timeout),
            accepted: 0,
            disconnected: false,
        });
    }

    /// One wait on the worker channel, delivering at most one accepted
    /// gradient. Without `aux` the wait blocks up to the session deadline
    /// (one syscall, exactly the pre-session `collect_with` behaviour);
    /// with `aux` — which runs inline first, this backend having no pool
    /// fan-out to co-schedule it on — the wait is capped at [`STEP`] so
    /// overlapped work keeps alternating with channel polls.
    pub(super) fn collect_step(
        &mut self,
        on_gradient: &mut dyn FnMut(usize, &[f32]) -> bool,
        aux: Option<&(dyn Fn() + Sync)>,
    ) -> CollectStatus {
        let Some(sess) = self.session.as_mut() else {
            return CollectStatus::Exhausted;
        };
        if sess.accepted >= sess.expect {
            return CollectStatus::Quorum;
        }
        if sess.disconnected {
            return CollectStatus::Exhausted;
        }
        let remaining = match sess.deadline {
            // wall-clock: time left until the physical deadline.
            Some(d) => d.saturating_duration_since(Instant::now()),
            None => STEP,
        };
        if remaining.is_zero() {
            return CollectStatus::Exhausted;
        }
        let wait = if let Some(aux) = aux {
            aux();
            remaining.min(STEP)
        } else {
            remaining
        };
        match self.from_workers.recv_timeout(wait) {
            Ok(msg) if msg.round == sess.round => {
                // A rejected gradient (callback returns false) is
                // consumed but does not fill an `expect` slot — and
                // neither does an encoded payload that fails decode (the
                // in-process analogue of the socket CODEC reject).
                let accepted = match &msg.coded {
                    None => on_gradient(msg.worker, &msg.gradient),
                    Some(c) => {
                        self.decode_scratch.clear();
                        crate::codec::decode(
                            c.codec,
                            0,
                            c.count,
                            &c.bytes,
                            &mut self.decode_scratch,
                        )
                        .is_ok()
                            && on_gradient(msg.worker, &self.decode_scratch)
                    }
                };
                if accepted {
                    sess.accepted += 1;
                }
                if sess.accepted >= sess.expect {
                    CollectStatus::Quorum
                } else {
                    CollectStatus::Pending
                }
            }
            Ok(_stale) => CollectStatus::Pending,
            Err(mpsc::RecvTimeoutError::Timeout) => CollectStatus::Pending,
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                sess.disconnected = true;
                CollectStatus::Exhausted
            }
        }
    }

    pub(super) fn collect_extend(&mut self) {
        if let Some(sess) = self.session.as_mut() {
            sess.expect = usize::MAX;
        }
    }

    pub(super) fn collect_accepted(&self) -> usize {
        self.session.as_ref().map_or(0, |s| s.accepted)
    }

    pub(super) fn collect_finish(&mut self) {
        self.session = None;
    }

    pub(super) fn shutdown(&self) {
        for tx in &self.to_workers {
            let _ = tx.send(ToWorker::Shutdown);
        }
    }

    pub(super) fn num_workers(&self) -> usize {
        self.to_workers.len()
    }
}

/// Threaded worker half: holds the channel ends until a body is installed.
pub(super) struct Worker {
    id: usize,
    rx: mpsc::Receiver<ToWorker>,
    tx: mpsc::Sender<FromWorker>,
    faults: FaultModel,
}

impl Worker {
    pub(super) fn id(&self) -> usize {
        self.id
    }

    /// Spawn the dedicated worker thread running `body` for every round
    /// until shutdown (or until the server side is dropped).
    pub(super) fn serve(self, mut body: Box<dyn WorkerBody>) {
        let Worker {
            id,
            rx,
            tx,
            faults,
        } = self;
        let mut rng = faults.rng_for(id);
        // Simulated compute cost: on this backend the worker really is
        // slow — it sleeps its cost before computing, racing the server's
        // wall-clock collect deadline like a genuinely loaded machine.
        let cost_us = faults.cost.cost_us_for(id);
        std::thread::Builder::new()
            .name(format!("worker-{id}"))
            .spawn(move || {
                while let Ok(msg) = rx.recv() {
                    match msg {
                        ToWorker::Round { round, params } => {
                            if cost_us > 0 {
                                std::thread::sleep(Duration::from_micros(cost_us));
                            }
                            let mut emit = Emitter {
                                worker: id,
                                faults,
                                rng: &mut rng,
                                sink: EmitterSink::Channel(&tx),
                                // Two-level mode ingests server-side on
                                // this backend (the channel already owns
                                // the vector) — see ServerEndpoint::
                                // install_group_reducer.
                                group: None,
                            };
                            body.on_round(round, &params, &mut emit);
                        }
                        ToWorker::Shutdown => break,
                    }
                }
            })
            .expect("spawning worker thread");
    }
}

/// Build the threaded star: n channel pairs, no threads yet (each worker's
/// thread starts when its body is installed).
pub(super) fn star(n: usize, faults: FaultModel) -> (Server, Vec<Worker>) {
    let (up_tx, up_rx) = mpsc::channel::<FromWorker>();
    let mut to_workers = Vec::with_capacity(n);
    let mut workers = Vec::with_capacity(n);
    for id in 0..n {
        let (down_tx, down_rx) = mpsc::channel::<ToWorker>();
        to_workers.push(down_tx);
        workers.push(Worker {
            id,
            rx: down_rx,
            tx: up_tx.clone(),
            faults,
        });
    }
    (
        Server {
            to_workers,
            from_workers: up_rx,
            session: None,
            decode_scratch: Vec::new(),
        },
        workers,
    )
}
