//! The thread-per-worker backend: one OS thread plus a pair of std-mpsc
//! channels per worker — the faithful-asynchrony simulation (workers race
//! the collect timeout for real). The [`ComputeCost`](super::ComputeCost)
//! model manifests here as a real pre-compute sleep, so a straggler's
//! race against the wall-clock deadline is physical, not simulated. See
//! the module docs in [`super`](crate::transport) for how it compares to
//! the pooled backend.

use super::{Emitter, EmitterSink, FaultModel, FromWorker, WorkerBody};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Server → worker messages (internal to this backend; the pooled backend
/// has no message objects at all).
enum ToWorker {
    /// Start round `round`: compute a gradient at `params`.
    Round { round: u64, params: Arc<Vec<f32>> },
    /// Terminate the worker thread.
    Shutdown,
}

/// Threaded server half.
pub(super) struct Server {
    to_workers: Vec<mpsc::Sender<ToWorker>>,
    from_workers: mpsc::Receiver<FromWorker>,
}

impl Server {
    pub(super) fn broadcast(&mut self, round: u64, params: Arc<Vec<f32>>) {
        for tx in &self.to_workers {
            let _ = tx.send(ToWorker::Round {
                round,
                params: Arc::clone(&params),
            });
        }
    }

    pub(super) fn collect_with(
        &mut self,
        round: u64,
        expect: usize,
        timeout: Duration,
        on_gradient: &mut dyn FnMut(usize, &[f32]) -> bool,
    ) -> usize {
        let mut got = 0;
        let deadline = Instant::now() + timeout;
        while got < expect {
            let remaining = deadline.saturating_duration_since(Instant::now());
            if remaining.is_zero() {
                break;
            }
            match self.from_workers.recv_timeout(remaining) {
                Ok(msg) if msg.round == round => {
                    // A rejected gradient (callback returns false) is
                    // consumed but does not fill an `expect` slot.
                    if on_gradient(msg.worker, &msg.gradient) {
                        got += 1;
                    }
                }
                Ok(_stale) => continue,
                Err(_) => break,
            }
        }
        got
    }

    pub(super) fn shutdown(&self) {
        for tx in &self.to_workers {
            let _ = tx.send(ToWorker::Shutdown);
        }
    }

    pub(super) fn num_workers(&self) -> usize {
        self.to_workers.len()
    }
}

/// Threaded worker half: holds the channel ends until a body is installed.
pub(super) struct Worker {
    id: usize,
    rx: mpsc::Receiver<ToWorker>,
    tx: mpsc::Sender<FromWorker>,
    faults: FaultModel,
}

impl Worker {
    pub(super) fn id(&self) -> usize {
        self.id
    }

    /// Spawn the dedicated worker thread running `body` for every round
    /// until shutdown (or until the server side is dropped).
    pub(super) fn serve(self, mut body: Box<dyn WorkerBody>) {
        let Worker {
            id,
            rx,
            tx,
            faults,
        } = self;
        let mut rng = faults.rng_for(id);
        // Simulated compute cost: on this backend the worker really is
        // slow — it sleeps its cost before computing, racing the server's
        // wall-clock collect deadline like a genuinely loaded machine.
        let cost_us = faults.cost.cost_us_for(id);
        std::thread::Builder::new()
            .name(format!("worker-{id}"))
            .spawn(move || {
                while let Ok(msg) = rx.recv() {
                    match msg {
                        ToWorker::Round { round, params } => {
                            if cost_us > 0 {
                                std::thread::sleep(Duration::from_micros(cost_us));
                            }
                            let mut emit = Emitter {
                                worker: id,
                                faults,
                                rng: &mut rng,
                                sink: EmitterSink::Channel(&tx),
                            };
                            body.on_round(round, &params, &mut emit);
                        }
                        ToWorker::Shutdown => break,
                    }
                }
            })
            .expect("spawning worker thread");
    }
}

/// Build the threaded star: n channel pairs, no threads yet (each worker's
/// thread starts when its body is installed).
pub(super) fn star(n: usize, faults: FaultModel) -> (Server, Vec<Worker>) {
    let (up_tx, up_rx) = mpsc::channel::<FromWorker>();
    let mut to_workers = Vec::with_capacity(n);
    let mut workers = Vec::with_capacity(n);
    for id in 0..n {
        let (down_tx, down_rx) = mpsc::channel::<ToWorker>();
        to_workers.push(down_tx);
        workers.push(Worker {
            id,
            rx: down_rx,
            tx: up_tx.clone(),
            faults,
        });
    }
    (
        Server {
            to_workers,
            from_workers: up_rx,
        },
        workers,
    )
}
