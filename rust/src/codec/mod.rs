//! Gradient wire codecs — byte encodings for f32 coordinate ranges.
//!
//! At the paper's headline regime (d = 10⁷–10⁹) gradient bytes dominate
//! every other per-round cost, so the transports compress the worker →
//! server direction through a common [`Codec`] seam: the socket backend
//! negotiates a codec at Hello and tags every GradientChunk frame with a
//! codec id (`docs/wire-protocol.md` §7), while the in-process backends
//! carry the encoded bytes through the channel message / arena slot and
//! decode on the server side — all three backends exercise the same
//! bytes, so the conformance suite covers them together.
//!
//! Five codecs ([`CodecKind`], the `codec` config knob):
//!
//! * **`raw`** — identity framing: little-endian f32, 4 bytes per
//!   coordinate. Bit-exact; the determinism-matrix reference.
//! * **`lossless`** — byte-shuffle (4 byte planes) + run-length/varint
//!   framing. Bit-exact (a bijection, property-tested below) and small
//!   on converged/sparse gradients; an incompressible chunk is stored
//!   verbatim, so the worst case is raw plus one mode byte.
//! * **`fp16`** — IEEE 754 half precision, round-to-nearest-even,
//!   2 bytes per coordinate (hand-rolled — no platform or nightly
//!   `f16` dependence, so the rounding is identical everywhere).
//! * **`int8`** — blockwise symmetric 8-bit quantization, ~1 byte per
//!   coordinate: each aligned [`BLOCK`]-coordinate block shares a
//!   power-of-two scale picked from the block's max magnitude.
//! * **`topk`** — blockwise top-k sparsification with error feedback:
//!   each block transmits its `BLOCK/16` largest-magnitude coordinates;
//!   the untransmitted remainder accumulates in a per-worker residual
//!   (carried by the encoder, which is why `GradWorker` owns one) and
//!   rides along on later rounds, so no mass is permanently lost.
//!
//! **Determinism contract.** Encoding and decoding are pure byte/f32
//! functions of their input (plus, for `topk`, the encoder's residual
//! state): no wall clock, no hashing, no platform-dependent float paths
//! (quantization scales are exact powers of two built by bit
//! manipulation — never `powi`, whose 1-ULP slack is documented). Blocks
//! align to *absolute* coordinate offsets, so an encoder that sees the
//! gradient in chunks produces the same values as one that sees it whole
//! whenever the chunk size is a multiple of [`BLOCK`] (the socket
//! default, 16384, is). One caveat: a NaN coordinate fed to `topk` passes
//! through the residual *addition*, and IEEE leaves NaN payload
//! propagation to the platform — every other path is bit-exact.
//!
//! **Decode safety.** [`decode`] is fed attacker-controlled bytes on the
//! socket path, so it validates everything and allocates nothing it was
//! not promised: a claimed coordinate count more than
//! [`MAX_DECODE_RATIO`]× the payload size is rejected before any
//! allocation (the suspicious-ratio guard; every encoding this module
//! produces stays far under the cap because RLE run lengths are bounded
//! by [`MAX_RUN`]), and any truncated, malformed or trailing byte is a
//! [`CodecError`]. The transports surface a failed decode as a rejected
//! gradient: consumed, never delivered, and never occupying a first-m
//! quorum slot (socket: `Reject` code 7, `CODEC`).

use anyhow::bail;

/// Quantization/sparsification block size, in f32 coordinates. Blocks
/// align to absolute coordinate offsets (block `b` covers coordinates
/// `[b·BLOCK, (b+1)·BLOCK)`), which is what makes chunked encoding agree
/// with whole-gradient encoding for chunk sizes that are multiples of
/// this (see the module docs).
pub const BLOCK: usize = 4096;

/// Decode-side expansion cap: a chunk claiming more coordinates than
/// `MAX_DECODE_RATIO ×` its payload length is rejected before any
/// allocation. The honest worst cases sit far below it: an all-zero
/// `lossless` chunk decodes ≈ 341 coordinates per byte (runs are capped
/// at [`MAX_RUN`]), and a minimal `topk` block ≈ 512.
pub const MAX_DECODE_RATIO: usize = 2048;

/// Cap on a single run length in the `lossless` RLE stream. Bounding the
/// run bounds the decode expansion ratio (see [`MAX_DECODE_RATIO`]); the
/// encoder splits longer runs, the decoder rejects them.
pub const MAX_RUN: usize = 4096;

/// Per-block transmitted fraction for `topk`: `len / 16` coordinates
/// (floor, minimum 1).
const TOPK_DENOM: usize = 16;

/// Stored-mode threshold for `int8` (2¹²⁰): a block whose max magnitude
/// reaches it — or that contains a non-finite value — is stored verbatim,
/// because near `f32::MAX` the reconstruction `q·2^e` could overflow to
/// infinity. Storing is lossless, so idempotence survives the fallback.
const INT8_STORED_THRESH: f32 = f32::from_bits(247u32 << 23); // biased exp 120+127

/// Which gradient codec a worker encodes with (the `codec` config knob /
/// `--codec` CLI flag). At the config level `off` means "no codec stage
/// installed at all" — byte-identical to `raw` on the wire, which is what
/// the CI determinism matrix checks.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CodecKind {
    /// Identity framing: little-endian f32 (default).
    #[default]
    Raw,
    /// Byte-shuffle + RLE/varint lossless framing.
    Lossless,
    /// IEEE half-precision quantization (2 bytes per coordinate).
    Fp16,
    /// Blockwise symmetric 8-bit quantization (~1 byte per coordinate).
    Int8,
    /// Blockwise top-k sparsification with error feedback.
    TopK,
}

impl CodecKind {
    /// Every codec, in display order (test/bench sweeps).
    pub const ALL: [CodecKind; 5] = [
        CodecKind::Raw,
        CodecKind::Lossless,
        CodecKind::Fp16,
        CodecKind::Int8,
        CodecKind::TopK,
    ];

    /// The lossy codecs (`bench codec` reports selection quality under
    /// attack for each of these).
    pub const LOSSY: [CodecKind; 3] = [CodecKind::Fp16, CodecKind::Int8, CodecKind::TopK];

    /// The knob spelling (`raw` / `lossless` / `fp16` / `int8` / `topk`).
    pub fn as_str(self) -> &'static str {
        match self {
            CodecKind::Raw => "raw",
            CodecKind::Lossless => "lossless",
            CodecKind::Fp16 => "fp16",
            CodecKind::Int8 => "int8",
            CodecKind::TopK => "topk",
        }
    }

    /// Whether `decode(encode(v))` is bit-identical to `v` for every
    /// input (`raw` and `lossless`).
    pub fn is_lossless(self) -> bool {
        matches!(self, CodecKind::Raw | CodecKind::Lossless)
    }

    /// The on-wire codec id: the GradientChunk `codec` byte and the Hello
    /// capability byte (`docs/wire-protocol.md` §7).
    pub fn wire_id(self) -> u8 {
        match self {
            CodecKind::Raw => 0,
            CodecKind::Lossless => 1,
            CodecKind::Fp16 => 2,
            CodecKind::Int8 => 3,
            CodecKind::TopK => 4,
        }
    }

    /// Parse an on-wire codec id. `None` means unknown — the server
    /// answers with `Reject` code `CODEC` (`docs/wire-protocol.md` §7).
    pub fn from_wire(id: u8) -> Option<CodecKind> {
        match id {
            0 => Some(CodecKind::Raw),
            1 => Some(CodecKind::Lossless),
            2 => Some(CodecKind::Fp16),
            3 => Some(CodecKind::Int8),
            4 => Some(CodecKind::TopK),
            _ => None,
        }
    }
}

impl std::fmt::Display for CodecKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl std::str::FromStr for CodecKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "raw" => Ok(CodecKind::Raw),
            "lossless" => Ok(CodecKind::Lossless),
            "fp16" => Ok(CodecKind::Fp16),
            "int8" => Ok(CodecKind::Int8),
            "topk" | "top-k" => Ok(CodecKind::TopK),
            other => bail!("unknown codec '{other}' (raw|lossless|fp16|int8|topk)"),
        }
    }
}

/// Why a decode was refused. The message is static and diagnostic-only;
/// the transports map every decode failure to one rejected gradient
/// regardless of the reason.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CodecError(pub &'static str);

impl std::fmt::Display for CodecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "codec: {}", self.0)
    }
}

impl std::error::Error for CodecError {}

/// A gradient encoder. Stateless for every codec except `topk`, whose
/// error-feedback residual lives in the encoder — which is why encoders
/// are per-worker values (each `GradWorker` owns one for the lifetime of
/// a run) rather than free functions, and why `encode` takes `&mut self`.
///
/// `offset` is the absolute coordinate index of `values[0]` within the
/// full gradient: the block-structured codecs (`int8`, `topk`) align
/// their blocks to absolute offsets so chunked encoding agrees with
/// whole-gradient encoding (see [`BLOCK`]).
///
/// Decoding is the free function [`decode`]: no codec needs state to
/// decode (the `topk` residual is encoder-side only), so the server never
/// holds per-worker codec state.
pub trait Codec: Send {
    /// Which codec this is (tags frames on the socket transport and the
    /// in-process coded messages).
    fn kind(&self) -> CodecKind;

    /// Encode `values` — starting at absolute coordinate `offset` — into
    /// `out`, replacing its previous contents.
    fn encode(&mut self, offset: usize, values: &[f32], out: &mut Vec<u8>);
}

/// Build a fresh encoder for `kind` (empty residual state for `topk`).
pub fn encoder(kind: CodecKind) -> Box<dyn Codec> {
    match kind {
        CodecKind::Raw => Box::new(Raw),
        CodecKind::Lossless => Box::new(Lossless {
            shuffled: Vec::new(),
            rle: Vec::new(),
        }),
        CodecKind::Fp16 => Box::new(Fp16),
        CodecKind::Int8 => Box::new(Int8),
        CodecKind::TopK => Box::new(TopK {
            residual: Vec::new(),
            order: Vec::new(),
        }),
    }
}

/// Decode `count` coordinates — starting at absolute coordinate `offset`
/// — from `bytes`, appending them to `out`. On success exactly `count`
/// values were appended; on error `out` is left exactly as it was.
/// `bytes` may be attacker-controlled (see the module docs' decode-safety
/// paragraph): everything is validated, and the suspicious-ratio guard
/// runs before any allocation.
pub fn decode(
    kind: CodecKind,
    offset: usize,
    count: usize,
    bytes: &[u8],
    out: &mut Vec<f32>,
) -> Result<(), CodecError> {
    if count > bytes.len().saturating_mul(MAX_DECODE_RATIO) {
        return Err(CodecError("suspicious expansion ratio"));
    }
    let start = out.len();
    let result = match kind {
        CodecKind::Raw => decode_raw(count, bytes, out),
        CodecKind::Lossless => decode_lossless(count, bytes, out),
        CodecKind::Fp16 => decode_fp16(count, bytes, out),
        CodecKind::Int8 => decode_int8(offset, count, bytes, out),
        CodecKind::TopK => decode_topk(offset, count, bytes, out),
    };
    if result.is_err() {
        out.truncate(start);
    } else {
        debug_assert_eq!(out.len(), start + count);
    }
    result
}

// ---------------------------------------------------------------------
// raw
// ---------------------------------------------------------------------

/// `raw`: identity framing, little-endian f32.
struct Raw;

impl Codec for Raw {
    fn kind(&self) -> CodecKind {
        CodecKind::Raw
    }

    fn encode(&mut self, _offset: usize, values: &[f32], out: &mut Vec<u8>) {
        out.clear();
        out.reserve(values.len() * 4);
        for &v in values {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
}

fn decode_raw(count: usize, bytes: &[u8], out: &mut Vec<f32>) -> Result<(), CodecError> {
    if Some(bytes.len()) != count.checked_mul(4) {
        return Err(CodecError("raw: payload length != 4·count"));
    }
    out.reserve(count);
    for le in bytes.chunks_exact(4) {
        out.push(f32::from_le_bytes([le[0], le[1], le[2], le[3]]));
    }
    Ok(())
}

// ---------------------------------------------------------------------
// lossless: byte-shuffle + RLE/varint
// ---------------------------------------------------------------------

/// `lossless`: the chunk's f32s are split into their 4 little-endian byte
/// planes (all byte-0s, then all byte-1s, …) — sign/exponent bytes of
/// nearby coordinates correlate, so the upper planes are long runs — then
/// run-length encoded as `(byte, varint run)` pairs with runs capped at
/// [`MAX_RUN`]. A chunk the pairs do not shrink is stored verbatim behind
/// the 1-byte mode tag instead.
struct Lossless {
    /// Byte-plane scratch, reused across chunks.
    shuffled: Vec<u8>,
    /// RLE output scratch, reused across chunks.
    rle: Vec<u8>,
}

impl Codec for Lossless {
    fn kind(&self) -> CodecKind {
        CodecKind::Lossless
    }

    fn encode(&mut self, _offset: usize, values: &[f32], out: &mut Vec<u8>) {
        out.clear();
        self.shuffled.clear();
        self.shuffled.reserve(values.len() * 4);
        for b in 0..4 {
            for &v in values {
                self.shuffled.push(v.to_le_bytes()[b]);
            }
        }
        self.rle.clear();
        rle_encode(&self.shuffled, &mut self.rle);
        if self.rle.len() < values.len() * 4 {
            out.reserve(1 + self.rle.len());
            out.push(1);
            out.extend_from_slice(&self.rle);
        } else {
            out.reserve(1 + values.len() * 4);
            out.push(0);
            for &v in values {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
    }
}

/// RLE with runs capped at [`MAX_RUN`] (the cap is what bounds the decode
/// expansion ratio — see [`MAX_DECODE_RATIO`]).
fn rle_encode(bytes: &[u8], out: &mut Vec<u8>) {
    let mut i = 0usize;
    while i < bytes.len() {
        let b = bytes[i];
        let mut run = 1usize;
        while run < MAX_RUN && i + run < bytes.len() && bytes[i + run] == b {
            run += 1;
        }
        out.push(b);
        write_varint(run as u64, out);
        i += run;
    }
}

/// LEB128: low 7 bits first, high bit set on continuation bytes.
fn write_varint(mut v: u64, out: &mut Vec<u8>) {
    loop {
        let byte = (v & 0x7F) as u8;
        v >>= 7;
        if v == 0 {
            out.push(byte);
            return;
        }
        out.push(byte | 0x80);
    }
}

/// LEB128 decode at `*pos`, advancing it past the varint.
fn read_varint(bytes: &[u8], pos: &mut usize) -> Result<u64, CodecError> {
    let mut v = 0u64;
    let mut shift = 0u32;
    loop {
        let b = *bytes.get(*pos).ok_or(CodecError("varint truncated"))?;
        *pos += 1;
        v |= u64::from(b & 0x7F) << shift;
        if b & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
        if shift >= 64 {
            return Err(CodecError("varint overflow"));
        }
    }
}

fn decode_lossless(count: usize, bytes: &[u8], out: &mut Vec<f32>) -> Result<(), CodecError> {
    let (&mode, body) = bytes
        .split_first()
        .ok_or(CodecError("lossless: empty payload"))?;
    let planes = count
        .checked_mul(4)
        .ok_or(CodecError("lossless: count overflow"))?;
    match mode {
        0 => {
            // Stored chunks are plain little-endian f32 (not shuffled).
            if body.len() != planes {
                return Err(CodecError("lossless: stored length != 4·count"));
            }
            out.reserve(count);
            for le in body.chunks_exact(4) {
                out.push(f32::from_le_bytes([le[0], le[1], le[2], le[3]]));
            }
            Ok(())
        }
        1 => {
            let mut shuffled = Vec::with_capacity(planes);
            let mut pos = 0usize;
            while shuffled.len() < planes {
                let b = *body.get(pos).ok_or(CodecError("lossless: truncated run"))?;
                pos += 1;
                let run = read_varint(body, &mut pos)? as usize;
                if run == 0 || run > MAX_RUN {
                    return Err(CodecError("lossless: run length out of range"));
                }
                if shuffled.len() + run > planes {
                    return Err(CodecError("lossless: run overruns the chunk"));
                }
                let grown = shuffled.len() + run;
                shuffled.resize(grown, b);
            }
            if pos != body.len() {
                return Err(CodecError("lossless: trailing bytes"));
            }
            out.reserve(count);
            for i in 0..count {
                out.push(f32::from_le_bytes([
                    shuffled[i],
                    shuffled[count + i],
                    shuffled[2 * count + i],
                    shuffled[3 * count + i],
                ]));
            }
            Ok(())
        }
        _ => Err(CodecError("lossless: unknown mode")),
    }
}

// ---------------------------------------------------------------------
// fp16: hand-rolled IEEE 754 binary16, round-to-nearest-even
// ---------------------------------------------------------------------

/// `fp16`: per-coordinate IEEE half precision, u16 LE on the wire.
struct Fp16;

impl Codec for Fp16 {
    fn kind(&self) -> CodecKind {
        CodecKind::Fp16
    }

    fn encode(&mut self, _offset: usize, values: &[f32], out: &mut Vec<u8>) {
        out.clear();
        out.reserve(values.len() * 2);
        for &v in values {
            out.extend_from_slice(&f32_to_f16(v).to_le_bytes());
        }
    }
}

fn decode_fp16(count: usize, bytes: &[u8], out: &mut Vec<f32>) -> Result<(), CodecError> {
    if Some(bytes.len()) != count.checked_mul(2) {
        return Err(CodecError("fp16: payload length != 2·count"));
    }
    out.reserve(count);
    for le in bytes.chunks_exact(2) {
        out.push(f16_to_f32(u16::from_le_bytes([le[0], le[1]])));
    }
    Ok(())
}

/// f32 → binary16, round-to-nearest-even. NaN collapses to the canonical
/// quiet NaN `0x7E00` (payload and sign dropped — deterministic); values
/// beyond the half range (±65504, e.g. ±1e30) overflow to ±infinity.
fn f32_to_f16(v: f32) -> u16 {
    let bits = v.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let man = bits & 0x7F_FFFF;
    if exp == 0xFF {
        return if man == 0 { sign | 0x7C00 } else { 0x7E00 };
    }
    let e = exp - 127; // unbiased
    if e > 15 {
        return sign | 0x7C00; // overflow → ±inf
    }
    if e >= -14 {
        // Normal half: drop 13 mantissa bits with RNE; a carry out of the
        // mantissa correctly bumps the exponent (up to ±inf at e = 15).
        let m = man >> 13;
        let rem = man & 0x1FFF;
        let mut h = sign | (((e + 15) as u16) << 10) | m as u16;
        if rem > 0x1000 || (rem == 0x1000 && (m & 1) == 1) {
            h += 1;
        }
        return h;
    }
    if e < -25 {
        return sign; // below half of the smallest subnormal → ±0
    }
    // Subnormal half: add the implicit bit, shift out 13 + deficit, RNE.
    let full = man | 0x80_0000;
    let shift = (13 + (-14 - e)) as u32;
    let m = full >> shift;
    let rem = full & ((1u32 << shift) - 1);
    let half = 1u32 << (shift - 1);
    let mut h = sign | m as u16;
    if rem > half || (rem == half && (m & 1) == 1) {
        h += 1;
    }
    h
}

/// binary16 → f32 (exact — every half value is representable). Any NaN
/// half decodes to the canonical quiet NaN `0x7FC00000`.
fn f16_to_f32(h: u16) -> f32 {
    let sign = u32::from(h & 0x8000) << 16;
    let exp = u32::from(h >> 10) & 0x1F;
    let man = u32::from(h & 0x3FF);
    let bits = if exp == 0x1F {
        if man == 0 {
            sign | 0x7F80_0000
        } else {
            0x7FC0_0000
        }
    } else if exp == 0 {
        if man == 0 {
            sign
        } else {
            // Subnormal half: normalize into an f32 normal.
            let mut e = 113u32; // 127 - 15 + 1
            let mut m = man;
            while m & 0x400 == 0 {
                m <<= 1;
                e -= 1;
            }
            sign | (e << 23) | ((m & 0x3FF) << 13)
        }
    } else {
        sign | ((exp + 112) << 23) | (man << 13) // 112 = 127 - 15
    };
    f32::from_bits(bits)
}

// ---------------------------------------------------------------------
// int8: blockwise symmetric power-of-two quantization
// ---------------------------------------------------------------------

/// `int8`: per aligned block, a mode byte (1 = quantized, 0 = stored
/// f32), then for mode 1 an exponent `e` (i16 LE) and one i8 per
/// coordinate: `q = round(v / 2^e).clamp(-127, 127)` with the smallest
/// `e ≥ -126` such that `127·2^e ≥ max|v|`. The scale is an exact power
/// of two, so `q·2^e` is exact in f32 and quantize→dequantize is
/// idempotent on the grid.
struct Int8;

impl Codec for Int8 {
    fn kind(&self) -> CodecKind {
        CodecKind::Int8
    }

    fn encode(&mut self, offset: usize, values: &[f32], out: &mut Vec<u8>) {
        out.clear();
        let mut i = 0usize;
        while i < values.len() {
            let abs = offset + i;
            let len = (BLOCK - abs % BLOCK).min(values.len() - i);
            encode_int8_block(&values[i..i + len], out);
            i += len;
        }
    }
}

fn encode_int8_block(block: &[f32], out: &mut Vec<u8>) {
    let mut maxabs = 0.0f32;
    let mut quantizable = true;
    for &v in block {
        if !v.is_finite() {
            quantizable = false;
            break;
        }
        let a = v.abs();
        if a > maxabs {
            maxabs = a;
        }
    }
    if !quantizable || maxabs >= INT8_STORED_THRESH {
        out.push(0);
        for &v in block {
            out.extend_from_slice(&v.to_le_bytes());
        }
        return;
    }
    let e = int8_exponent(maxabs);
    let scale = pow2(e);
    out.push(1);
    out.extend_from_slice(&(e as i16).to_le_bytes());
    for &v in block {
        // Division by an exact power of two, then round half away from
        // zero (`f32::round`) — both fully determined by IEEE semantics.
        let q = (v / scale).round().clamp(-127.0, 127.0) as i8;
        out.push(q as u8);
    }
}

/// The smallest exponent `e ≥ -126` with `127·2^e ≥ maxabs` (`maxabs`
/// finite and below [`INT8_STORED_THRESH`]). Found by a ≤ 3-step search
/// up from the estimate `exponent(maxabs) − 7` — no float logarithm,
/// whose libm implementation the determinism contract must not depend on.
fn int8_exponent(maxabs: f32) -> i32 {
    if maxabs == 0.0 {
        return -126;
    }
    let biased = ((maxabs.to_bits() >> 23) & 0xFF) as i32;
    let mut e = (biased - 127 - 7).max(-126);
    while 127.0 * pow2(e) < maxabs {
        e += 1;
    }
    e
}

/// 2^e as f32 for normal exponents `e ∈ [-126, 127]`, built exactly by
/// bit manipulation (`f32::powi` documents 1-ULP slack — not
/// deterministic enough for a codec).
fn pow2(e: i32) -> f32 {
    debug_assert!((-126..=127).contains(&e));
    f32::from_bits(((e + 127) as u32) << 23)
}

fn decode_int8(
    offset: usize,
    count: usize,
    bytes: &[u8],
    out: &mut Vec<f32>,
) -> Result<(), CodecError> {
    let mut pos = 0usize;
    let mut i = 0usize;
    while i < count {
        let abs = offset + i;
        let len = (BLOCK - abs % BLOCK).min(count - i);
        let mode = *bytes
            .get(pos)
            .ok_or(CodecError("int8: truncated block header"))?;
        pos += 1;
        match mode {
            0 => {
                let data = bytes
                    .get(pos..pos + len * 4)
                    .ok_or(CodecError("int8: truncated stored block"))?;
                for le in data.chunks_exact(4) {
                    out.push(f32::from_le_bytes([le[0], le[1], le[2], le[3]]));
                }
                pos += len * 4;
            }
            1 => {
                let eb = bytes
                    .get(pos..pos + 2)
                    .ok_or(CodecError("int8: truncated exponent"))?;
                let e = i32::from(i16::from_le_bytes([eb[0], eb[1]]));
                if !(-126..=127).contains(&e) {
                    return Err(CodecError("int8: exponent out of range"));
                }
                pos += 2;
                let data = bytes
                    .get(pos..pos + len)
                    .ok_or(CodecError("int8: truncated block"))?;
                let scale = pow2(e);
                for &qb in data {
                    out.push(f32::from(qb as i8) * scale);
                }
                pos += len;
            }
            _ => return Err(CodecError("int8: unknown block mode")),
        }
        i += len;
    }
    if pos != bytes.len() {
        return Err(CodecError("int8: trailing bytes"));
    }
    Ok(())
}

// ---------------------------------------------------------------------
// topk: blockwise sparsification with error feedback
// ---------------------------------------------------------------------

/// `topk`: per aligned block of `L` coordinates, `m = min(L, max(1,
/// L/16))` entries `(local index u16 LE, value f32 LE)` preceded by `m`
/// (u16 LE), sorted by ascending index. Selection is by descending `|a|`
/// (`total_cmp`, so even NaN ordering is deterministic), ties by
/// ascending index, where `a = value + residual`; a selected coordinate
/// transmits `a` and zeroes its residual, an unselected one banks `a` for
/// the next round (error feedback — the untransmitted mass is delayed,
/// not lost).
struct TopK {
    /// Error-feedback residual, indexed by absolute coordinate and grown
    /// on demand. This is the per-worker state: each worker owns one
    /// encoder for the lifetime of a run.
    residual: Vec<f32>,
    /// Selection scratch: local indices of the current block.
    order: Vec<usize>,
}

impl Codec for TopK {
    fn kind(&self) -> CodecKind {
        CodecKind::TopK
    }

    fn encode(&mut self, offset: usize, values: &[f32], out: &mut Vec<u8>) {
        out.clear();
        let end = offset + values.len();
        if self.residual.len() < end {
            self.residual.resize(end, 0.0);
        }
        let TopK { residual, order } = self;
        let mut i = 0usize;
        while i < values.len() {
            let abs = offset + i;
            let len = (BLOCK - abs % BLOCK).min(values.len() - i);
            topk_encode_block(
                &mut residual[abs..abs + len],
                order,
                &values[i..i + len],
                out,
            );
            i += len;
        }
    }
}

fn topk_encode_block(res: &mut [f32], order: &mut Vec<usize>, block: &[f32], out: &mut Vec<u8>) {
    // a = this round's value plus the banked residual, accumulated in
    // place: what is not selected below simply stays banked.
    for (r, &v) in res.iter_mut().zip(block) {
        *r += v;
    }
    let m = (block.len() / TOPK_DENOM).max(1).min(block.len());
    order.clear();
    order.extend(0..block.len());
    // Deterministic despite the "unstable" partition: the comparator is a
    // total order (total_cmp, ties by index).
    order.select_nth_unstable_by(m - 1, |&i, &j| {
        let (ai, aj) = (res[i].abs(), res[j].abs());
        aj.total_cmp(&ai).then(i.cmp(&j))
    });
    order.truncate(m);
    order.sort_unstable();
    out.extend_from_slice(&(m as u16).to_le_bytes());
    for &i in order.iter() {
        out.extend_from_slice(&(i as u16).to_le_bytes());
        out.extend_from_slice(&res[i].to_le_bytes());
        res[i] = 0.0; // transmitted: the residual is spent
    }
}

fn decode_topk(
    offset: usize,
    count: usize,
    bytes: &[u8],
    out: &mut Vec<f32>,
) -> Result<(), CodecError> {
    let mut pos = 0usize;
    let mut i = 0usize;
    while i < count {
        let abs = offset + i;
        let len = (BLOCK - abs % BLOCK).min(count - i);
        let mb = bytes
            .get(pos..pos + 2)
            .ok_or(CodecError("topk: truncated block header"))?;
        let m = usize::from(u16::from_le_bytes([mb[0], mb[1]]));
        pos += 2;
        if m > len {
            return Err(CodecError("topk: more entries than coordinates"));
        }
        let base = out.len();
        out.resize(base + len, 0.0);
        let mut prev: Option<usize> = None;
        for _ in 0..m {
            let eb = bytes
                .get(pos..pos + 6)
                .ok_or(CodecError("topk: truncated entry"))?;
            let idx = usize::from(u16::from_le_bytes([eb[0], eb[1]]));
            if idx >= len || prev.is_some_and(|p| idx <= p) {
                return Err(CodecError("topk: entry indices not strictly increasing"));
            }
            prev = Some(idx);
            out[base + idx] = f32::from_le_bytes([eb[2], eb[3], eb[4], eb[5]]);
            pos += 6;
        }
        i += len;
    }
    if pos != bytes.len() {
        return Err(CodecError("topk: trailing bytes"));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{proptest, Rng64};

    /// Adversarially mixed coordinates: arbitrary bit patterns (NaN, ±inf,
    /// subnormals), exact zeros, ±1e30, and ordinary small values.
    fn gen_values(rng: &mut Rng64, len: usize) -> Vec<f32> {
        (0..len)
            .map(|_| match rng.gen_range_usize(8) {
                0 => f32::from_bits(rng.next_u64() as u32),
                1 => 0.0,
                2 => 1e30,
                3 => -1e30,
                _ => (rng.gen_f32() - 0.5) * 4.0,
            })
            .collect()
    }

    fn round_trip(kind: CodecKind, offset: usize, values: &[f32]) -> Vec<f32> {
        let mut enc = encoder(kind);
        let mut bytes = Vec::new();
        enc.encode(offset, values, &mut bytes);
        let mut back = Vec::new();
        decode(kind, offset, values.len(), &bytes, &mut back).expect("well-formed encode");
        back
    }

    fn bits(values: &[f32]) -> Vec<u32> {
        values.iter().map(|v| v.to_bits()).collect()
    }

    #[test]
    fn codec_kind_parses_and_displays() {
        assert_eq!("raw".parse::<CodecKind>().unwrap(), CodecKind::Raw);
        assert_eq!("topk".parse::<CodecKind>().unwrap(), CodecKind::TopK);
        assert_eq!(CodecKind::default(), CodecKind::Raw);
        for kind in CodecKind::ALL {
            assert_eq!(kind.as_str().parse::<CodecKind>().unwrap(), kind);
            assert_eq!(CodecKind::from_wire(kind.wire_id()), Some(kind));
        }
        let err = "gzip".parse::<CodecKind>().unwrap_err().to_string();
        assert!(
            err.contains("raw|lossless|fp16|int8|topk"),
            "error must list the valid names: {err}"
        );
        // "off" is a config-level spelling (no codec stage), not a codec.
        assert!("off".parse::<CodecKind>().is_err());
        assert_eq!(CodecKind::from_wire(9), None);
    }

    #[test]
    fn lossless_codecs_round_trip_bit_identical_property() {
        // Invariant catalog: codec determinism — raw and lossless are
        // bijections on every bit pattern, including NaN payloads, ±1e30
        // and non-finite coordinates, at arbitrary chunk sizes/offsets.
        proptest::check(
            "raw/lossless bijection",
            proptest::default_cases(),
            |rng, _case| {
                let len = rng.gen_range_usize(300);
                let offset = rng.gen_range_usize(3) * BLOCK + rng.gen_range_usize(40);
                let values = gen_values(rng, len);
                for kind in [CodecKind::Raw, CodecKind::Lossless] {
                    let back = round_trip(kind, offset, &values);
                    if bits(&back) != bits(&values) {
                        return Err(format!("{kind}: decode(encode(v)) != v (len {len})"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn quantize_dequantize_is_idempotent_property() {
        // Satellite invariant: one lossy pass projects onto the codec's
        // grid; a second pass is the identity on the grid, bit for bit.
        proptest::check(
            "lossy idempotence",
            proptest::default_cases(),
            |rng, _case| {
                let len = 1 + rng.gen_range_usize(200);
                let offset = rng.gen_range_usize(2) * BLOCK;
                let values = gen_values(rng, len);
                for kind in CodecKind::LOSSY {
                    let once = round_trip(kind, offset, &values);
                    let twice = round_trip(kind, offset, &once);
                    if bits(&twice) != bits(&once) {
                        return Err(format!("{kind}: second pass moved grid values"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn chunked_encoding_agrees_with_whole_gradient_at_block_boundaries() {
        // The absolute-offset block alignment: splitting the gradient at
        // BLOCK multiples and encoding each piece (with one encoder, so
        // topk residual state carries over) decodes to exactly the values
        // of a whole-gradient encode by a fresh encoder.
        let mut rng = Rng64::seed_from_u64(0xB10C);
        let values = gen_values(&mut rng, BLOCK + 123);
        for kind in CodecKind::ALL {
            let whole = round_trip(kind, 0, &values);
            let mut enc = encoder(kind);
            let mut pieces = Vec::new();
            for (start, piece) in [(0, &values[..BLOCK]), (BLOCK, &values[BLOCK..])] {
                let mut bytes = Vec::new();
                enc.encode(start, piece, &mut bytes);
                decode(kind, start, piece.len(), &bytes, &mut pieces).unwrap();
            }
            assert_eq!(bits(&pieces), bits(&whole), "{kind}");
        }
    }

    #[test]
    fn decode_never_panics_on_garbage_and_leaves_out_untouched_on_error() {
        proptest::check("garbage decode", proptest::default_cases(), |rng, _case| {
            let blen = rng.gen_range_usize(80);
            let bytes: Vec<u8> = (0..blen).map(|_| rng.next_u64() as u8).collect();
            let count = rng.gen_range_usize(200);
            let offset = rng.gen_range_usize(2) * BLOCK;
            for kind in CodecKind::ALL {
                let mut out = vec![7.0f32; 3];
                match decode(kind, offset, count, &bytes, &mut out) {
                    Ok(()) => {
                        if out.len() != 3 + count {
                            return Err(format!("{kind}: Ok but appended wrong count"));
                        }
                    }
                    Err(_) => {
                        if out != vec![7.0f32; 3] {
                            return Err(format!("{kind}: Err mutated out"));
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn suspicious_ratio_guard_rejects_before_allocating() {
        // A 2-byte payload claiming 10 000 coordinates is a zip bomb: the
        // guard fires for every codec, before any allocation.
        for kind in CodecKind::ALL {
            let mut out = Vec::new();
            let err = decode(kind, 0, 10_000, &[1u8, 0], &mut out).unwrap_err();
            assert_eq!(err, CodecError("suspicious expansion ratio"), "{kind}");
            assert!(out.is_empty(), "{kind}");
        }
        // ... while an honest all-zero lossless chunk of the default
        // socket chunk size stays under the cap (runs are MAX_RUN-capped).
        let zeros = vec![0.0f32; 16_384];
        let mut enc = encoder(CodecKind::Lossless);
        let mut bytes = Vec::new();
        enc.encode(0, &zeros, &mut bytes);
        assert!(bytes.len() * MAX_DECODE_RATIO >= zeros.len(), "guard-safe");
        assert!(bytes.len() < 100, "compresses hard: {} bytes", bytes.len());
        let mut back = Vec::new();
        decode(CodecKind::Lossless, 0, zeros.len(), &bytes, &mut back).unwrap();
        assert_eq!(bits(&back), bits(&zeros));
    }

    #[test]
    fn fp16_reference_vectors() {
        assert_eq!(f32_to_f16(1.0), 0x3C00);
        assert_eq!(f32_to_f16(-2.5), 0xC100);
        assert_eq!(f32_to_f16(65504.0), 0x7BFF); // half::MAX
        assert_eq!(f32_to_f16(65520.0), 0x7C00); // rounds up to +inf
        assert_eq!(f32_to_f16(1e30), 0x7C00);
        assert_eq!(f32_to_f16(-1e30), 0xFC00);
        assert_eq!(f32_to_f16(f32::NAN), 0x7E00); // canonical
        assert_eq!(f32_to_f16(6e-8), 0x0001); // smallest subnormal
        assert_eq!(f16_to_f32(0x3C00).to_bits(), 1.0f32.to_bits());
        assert_eq!(f16_to_f32(0x0001), 5.960_464_5e-8);
        assert_eq!(f16_to_f32(0x7E01).to_bits(), 0x7FC0_0000); // NaN canon
        assert_eq!(f16_to_f32(0xFC00), f32::NEG_INFINITY);
        // ±0 keep their sign through the round trip.
        assert_eq!(f16_to_f32(f32_to_f16(-0.0)).to_bits(), (-0.0f32).to_bits());
    }

    #[test]
    fn int8_grid_is_exact_and_extremes_fall_back_to_stored() {
        // 127·2^-6 ≈ 1.98 covers max|v| = 1.0, so e = -6 and 1.0 → q=64
        // reconstructs exactly; 0.25 → q=16 likewise.
        let back = round_trip(CodecKind::Int8, 0, &[1.0, -0.5, 0.25, 0.0]);
        assert_eq!(back, vec![1.0, -0.5, 0.25, 0.0]);
        // Non-finite and near-MAX blocks are stored verbatim (lossless).
        let wild = [f32::INFINITY, f32::MAX, -1e38, f32::NAN, 2.0];
        let back = round_trip(CodecKind::Int8, 0, &wild);
        assert_eq!(bits(&back), bits(&wild));
    }

    #[test]
    fn topk_transmits_the_largest_and_banks_the_rest() {
        // 32 coordinates → m = 2. Round 1 sends the two largest; the
        // remaining mass waits in the residual and rides out on round 2
        // even though the round-2 input is all zero (error feedback).
        let mut values = vec![0.0f32; 32];
        values[4] = 10.0;
        values[9] = -9.0;
        values[20] = 1.0;
        values[21] = 1.0;
        let mut enc = encoder(CodecKind::TopK);
        let mut bytes = Vec::new();
        enc.encode(0, &values, &mut bytes);
        assert_eq!(bytes.len(), 2 + 2 * 6, "m=2 entries");
        let mut r1 = Vec::new();
        decode(CodecKind::TopK, 0, 32, &bytes, &mut r1).unwrap();
        let mut want = vec![0.0f32; 32];
        want[4] = 10.0;
        want[9] = -9.0;
        assert_eq!(r1, want);

        enc.encode(0, &vec![0.0f32; 32], &mut bytes);
        let mut r2 = Vec::new();
        decode(CodecKind::TopK, 0, 32, &bytes, &mut r2).unwrap();
        let mut want2 = vec![0.0f32; 32];
        want2[20] = 1.0;
        want2[21] = 1.0;
        assert_eq!(r2, want2, "banked residual transmitted next round");
    }

    #[test]
    fn topk_rejects_unsorted_and_out_of_range_entries() {
        // m=2, idx 5 then idx 3: not strictly increasing.
        let mut bad = Vec::new();
        bad.extend_from_slice(&2u16.to_le_bytes());
        bad.extend_from_slice(&5u16.to_le_bytes());
        bad.extend_from_slice(&1.0f32.to_le_bytes());
        bad.extend_from_slice(&3u16.to_le_bytes());
        bad.extend_from_slice(&1.0f32.to_le_bytes());
        let mut out = Vec::new();
        assert!(decode(CodecKind::TopK, 0, 32, &bad, &mut out).is_err());
        assert!(out.is_empty());
        // idx beyond the block length.
        let mut bad = Vec::new();
        bad.extend_from_slice(&1u16.to_le_bytes());
        bad.extend_from_slice(&40u16.to_le_bytes());
        bad.extend_from_slice(&1.0f32.to_le_bytes());
        assert!(decode(CodecKind::TopK, 0, 32, &bad, &mut out).is_err());
    }

    #[test]
    fn lossy_codecs_cut_bytes_at_least_3x_on_smooth_gradients() {
        // The acceptance-criteria ratio at the codec layer: int8 ≈ 4×,
        // topk ≈ 10×+ vs raw's 4 bytes/coordinate on a typical smooth
        // (finite, similar-magnitude) gradient.
        let mut rng = Rng64::seed_from_u64(42);
        let values: Vec<f32> = (0..2 * BLOCK).map(|_| rng.gen_f32() - 0.5).collect();
        let raw_len = values.len() * 4;
        for kind in [CodecKind::Int8, CodecKind::TopK] {
            let mut enc = encoder(kind);
            let mut bytes = Vec::new();
            enc.encode(0, &values, &mut bytes);
            assert!(
                bytes.len() * 3 <= raw_len,
                "{kind}: {} bytes vs raw {raw_len}",
                bytes.len()
            );
        }
    }
}
