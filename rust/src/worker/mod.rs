//! Worker runtime: the processes that compute gradient proposals.
//!
//! An honest worker receives the current parameters, samples a minibatch
//! from **its own shard** of the training data, computes the gradient and
//! sends it back (the parameter-server recipe of the paper's §I). The
//! gradient computation is either the rust-native quadratic problem (tests
//! and fast ablations) or an AOT-compiled JAX model executed through the
//! PJRT compute thread ([`GradSource::Artifact`]).
//!
//! A worker's transport-facing half is [`GradWorker`], a
//! [`WorkerBody`] installed on a [`WorkerEndpoint`] — the same body runs
//! unchanged on a dedicated OS thread (threaded transport) or as a pool
//! task of the pooled runtime (see `transport`). It computes into a
//! reusable buffer, so the quadratic path allocates nothing per round in
//! the steady state, and the quadratic gradient itself can be
//! coordinate-sharded across a [`Parallelism`] handle
//! ([`GradSource::quadratic_sharded`]).
//!
//! Byzantine workers are *not* simulated as independent threads: the
//! paper's threat model is an omniscient coalition that observes every
//! correct gradient before choosing its own (§II-C). The coordinator
//! therefore collects the `n − f` honest gradients and lets the
//! [`crate::attacks::Attack`] forge the remaining `f` rows with full
//! knowledge — the strongest adversary the GARs must survive.

use crate::data::{shard_indices, Batch, FashionLike, QuadraticProblem, TokenStream, IMAGE_DIM};
use crate::runtime::{ArgValue, ComputeHandle, Parallelism};
use crate::transport::{Emitter, StepOutcome, WorkerBody, WorkerEndpoint};
use crate::util::Rng64;
use crate::Result;
use std::sync::Arc;

/// The minibatch seed mixes (round, worker) so workers draw independent
/// minibatches each round, deterministically — shared by the one-shot and
/// the time-sliced (chunked) gradient paths, which must agree bit for
/// bit.
fn quadratic_round_seed(round: u64, worker_id: usize) -> u64 {
    round
        .wrapping_mul(0x517C_C1B7_2722_0A95)
        .wrapping_add(worker_id as u64)
}

/// Where a worker's gradients come from.
pub enum GradSource {
    /// Rust-native synthetic quadratic problem (exact oracle available).
    Quadratic {
        problem: Arc<QuadraticProblem>,
        worker_id: usize,
        batch_size: usize,
        /// Intra-gradient coordinate sharding (sequential by default; the
        /// launcher passes the shared pool on the threaded transport —
        /// pooled logical workers already run *on* that pool, so they
        /// stay sequential to respect its non-reentrancy).
        par: Parallelism,
    },
    /// AOT classifier artifact over a FashionLike shard.
    Artifact {
        handle: ComputeHandle,
        /// Gradient artifact name (fixed batch size baked in).
        artifact: String,
        dataset: Arc<FashionLike>,
        /// This worker's shard id and total shard count.
        shard: usize,
        num_shards: usize,
        batch_size: usize,
        rng: Rng64,
    },
    /// AOT language-model artifact over a TokenStream shard.
    Lm {
        handle: ComputeHandle,
        artifact: String,
        stream: Arc<TokenStream>,
        seq_len: usize,
        shard: usize,
        num_shards: usize,
        batch_size: usize,
        rng: Rng64,
    },
}

impl GradSource {
    /// Compute the gradient at `params` for round `round` into `out`
    /// (resized as needed, reused across rounds); returns the minibatch
    /// loss.
    pub fn gradient_into(
        &mut self,
        params: &[f32],
        round: u64,
        out: &mut Vec<f32>,
    ) -> Result<f32> {
        match self {
            GradSource::Quadratic {
                problem,
                worker_id,
                batch_size,
                par,
            } => {
                let seed = quadratic_round_seed(round, *worker_id);
                problem.stochastic_gradient_into(params, *batch_size, seed, par, out);
                Ok(problem.loss(params))
            }
            GradSource::Artifact {
                handle,
                artifact,
                dataset,
                shard,
                num_shards,
                batch_size,
                rng,
            } => {
                // Sample batch_size indices uniformly from this shard.
                let shard_size =
                    crate::data::shard_len(dataset.train_len(), *shard, *num_shards);
                anyhow::ensure!(shard_size > 0, "worker shard is empty");
                let all: Vec<usize> =
                    shard_indices(dataset.train_len(), *shard, *num_shards).collect();
                let picked: Vec<usize> = (0..*batch_size)
                    .map(|_| all[rng.gen_range_usize(shard_size)])
                    .collect();
                let mut batch = Batch::new(*batch_size, IMAGE_DIM);
                dataset.fill_batch(0, &picked, &mut batch);
                let result = handle.execute(
                    artifact,
                    vec![
                        ArgValue::f32_vec(params.to_vec()),
                        ArgValue::F32(batch.features, vec![*batch_size, IMAGE_DIM]),
                        ArgValue::I32(batch.labels, vec![*batch_size]),
                    ],
                )?;
                let grad = result
                    .first()
                    .cloned()
                    .ok_or_else(|| anyhow::anyhow!("grad artifact returned no outputs"))?;
                let loss = result
                    .get(1)
                    .and_then(|l| l.first().copied())
                    .unwrap_or(f32::NAN);
                *out = grad;
                Ok(loss)
            }
            GradSource::Lm {
                handle,
                artifact,
                stream,
                seq_len,
                shard,
                num_shards,
                batch_size,
                rng,
            } => {
                let b = *batch_size;
                let l = *seq_len;
                let mut tokens = Vec::with_capacity(b * l);
                let mut targets = Vec::with_capacity(b * l);
                for _ in 0..b {
                    // Stream ids partitioned by shard: id ≡ shard (mod k).
                    let base = rng.next_u64() >> 1; // keep MSB clear (eval ids)
                    let sid = base
                        .wrapping_mul(*num_shards as u64)
                        .wrapping_add(*shard as u64)
                        & 0x7FFF_FFFF_FFFF_FFFF;
                    let (inp, tgt) = stream.sequence(sid, l);
                    tokens.extend(inp);
                    targets.extend(tgt);
                }
                let result = handle.execute(
                    artifact,
                    vec![
                        ArgValue::f32_vec(params.to_vec()),
                        ArgValue::I32(tokens, vec![b, l]),
                        ArgValue::I32(targets, vec![b, l]),
                    ],
                )?;
                let grad = result
                    .first()
                    .cloned()
                    .ok_or_else(|| anyhow::anyhow!("lm grad artifact returned no outputs"))?;
                let loss = result
                    .get(1)
                    .and_then(|o| o.first().copied())
                    .unwrap_or(f32::NAN);
                *out = grad;
                Ok(loss)
            }
        }
    }

    /// Allocating wrapper over [`gradient_into`](Self::gradient_into):
    /// `(gradient, minibatch_loss)` at `params` for round `round`.
    pub fn gradient(&mut self, params: &[f32], round: u64) -> Result<(Vec<f32>, f32)> {
        let mut out = Vec::new();
        let loss = self.gradient_into(params, round, &mut out)?;
        Ok((out, loss))
    }

    /// Quadratic source shortcut used throughout the tests (sequential
    /// gradient computation).
    pub fn quadratic(problem: Arc<QuadraticProblem>, worker_id: usize, batch_size: usize) -> Self {
        Self::quadratic_sharded(problem, worker_id, batch_size, Parallelism::sequential())
    }

    /// Quadratic source whose O(d) gradient pass is coordinate-sharded
    /// across `par` (`runtime::shard_slice`; bit-identical to sequential
    /// for every thread count).
    pub fn quadratic_sharded(
        problem: Arc<QuadraticProblem>,
        worker_id: usize,
        batch_size: usize,
        par: Parallelism,
    ) -> Self {
        GradSource::Quadratic {
            problem,
            worker_id,
            batch_size,
            par,
        }
    }

    /// Seeded classifier-artifact source.
    #[allow(clippy::too_many_arguments)]
    pub fn artifact(
        handle: ComputeHandle,
        artifact: String,
        dataset: Arc<FashionLike>,
        shard: usize,
        num_shards: usize,
        batch_size: usize,
        seed: u64,
    ) -> Self {
        GradSource::Artifact {
            handle,
            artifact,
            dataset,
            shard,
            num_shards,
            batch_size,
            rng: Rng64::seed_from_u64(seed ^ ((shard as u64) << 17)),
        }
    }

    /// Seeded LM-artifact source.
    #[allow(clippy::too_many_arguments)]
    pub fn lm(
        handle: ComputeHandle,
        artifact: String,
        stream: Arc<TokenStream>,
        seq_len: usize,
        shard: usize,
        num_shards: usize,
        batch_size: usize,
        seed: u64,
    ) -> Self {
        GradSource::Lm {
            handle,
            artifact,
            stream,
            seq_len,
            shard,
            num_shards,
            batch_size,
            rng: Rng64::seed_from_u64(seed ^ ((shard as u64) << 21) ^ 0x1111),
        }
    }
}

/// The cost-bounded stepping cursor of the time-sliced drive (transport
/// `pooled`): which round is in flight and how many coordinates of the
/// chunked quadratic gradient have been computed so far. The chunks
/// partition the coordinate space exactly like a `shard_slice` fan-out
/// does, and the quadratic noise is counter-seeded per coordinate, so the
/// incremental computation is bit-identical to the one-shot
/// [`GradSource::gradient_into`] path.
#[derive(Default)]
struct StepBody {
    round: u64,
    /// Coordinates `0..done` of `round`'s gradient are computed.
    done: usize,
    started: bool,
}

/// The honest worker body: answer every round from a [`GradSource`],
/// reusing one gradient buffer across rounds.
pub struct GradWorker {
    source: GradSource,
    buf: Vec<f32>,
    step: StepBody,
    /// Wire codec for outgoing gradients (`None` = raw f32 frames). A
    /// stateful codec (top-k error feedback) lives here so the residual
    /// persists across rounds, per worker.
    codec: Option<Box<dyn crate::codec::Codec>>,
}

impl GradWorker {
    /// Wrap `source` as a transport-installable body with a reusable
    /// gradient buffer.
    pub fn new(source: GradSource) -> Self {
        Self::with_codec(source, None)
    }

    /// Like [`new`](Self::new), but encoding outgoing gradients with
    /// `codec`. `None` and `Some(Raw)` both mean uncoded frames — raw is
    /// the identity, so skipping the encoder keeps the pre-codec fast
    /// path byte-for-byte.
    pub fn with_codec(source: GradSource, codec: Option<crate::codec::CodecKind>) -> Self {
        let codec = match codec {
            None | Some(crate::codec::CodecKind::Raw) => None,
            Some(kind) => Some(crate::codec::encoder(kind)),
        };
        Self {
            source,
            buf: Vec::new(),
            step: StepBody::default(),
            codec,
        }
    }

    /// Move the codec out of the body (the socket streaming loop encodes
    /// chunk-by-chunk itself, borrowing the body mutably at the same
    /// time).
    pub fn take_codec(&mut self) -> Option<Box<dyn crate::codec::Codec>> {
        self.codec.take()
    }

    /// Stream round `round`'s gradient in `chunk`-coordinate pieces
    /// through `piece(offset, values, total)`, called strictly in offset
    /// order with `total = d`; an empty gradient still emits one
    /// `(0, [], 0)` piece. Returns early (without error) when `piece`
    /// returns `false` — the caller's send path is broken and the round
    /// is abandoned.
    ///
    /// On a quadratic source this reuses the `StepBody` chunking
    /// recipe: each range is computed with the counter-seeded
    /// `stochastic_gradient_range` into a chunk-sized scratch, so no
    /// full d-length buffer is ever materialized and the concatenation
    /// of pieces is bit-identical to the one-shot
    /// [`GradSource::gradient_into`] path. Artifact/LM sources execute
    /// atomically (PJRT), so they compute once and stream the result.
    pub fn stream_round(
        &mut self,
        round: u64,
        params: &[f32],
        chunk: usize,
        piece: &mut dyn FnMut(usize, &[f32], usize) -> bool,
    ) -> Result<()> {
        let chunk = chunk.max(1);
        if let GradSource::Quadratic {
            problem,
            worker_id,
            batch_size,
            ..
        } = &self.source
        {
            let d = problem.dim();
            if d == 0 {
                piece(0, &[], 0);
                return Ok(());
            }
            let seed = quadratic_round_seed(round, *worker_id);
            self.buf.clear();
            self.buf.resize(chunk.min(d), 0.0);
            let mut done = 0usize;
            while done < d {
                let len = chunk.min(d - done);
                problem.stochastic_gradient_range(
                    params,
                    *batch_size,
                    seed,
                    done,
                    &mut self.buf[..len],
                );
                if !piece(done, &self.buf[..len], d) {
                    return Ok(());
                }
                done += len;
            }
            return Ok(());
        }
        // Atomic sources: one full computation, then chunk-wise sends.
        self.source.gradient_into(params, round, &mut self.buf)?;
        let d = self.buf.len();
        if d == 0 {
            piece(0, &[], 0);
            return Ok(());
        }
        let mut done = 0usize;
        while done < d {
            let len = chunk.min(d - done);
            if !piece(done, &self.buf[done..done + len], d) {
                return Ok(());
            }
            done += len;
        }
        Ok(())
    }
}

impl WorkerBody for GradWorker {
    fn on_round(&mut self, round: u64, params: &[f32], emit: &mut Emitter<'_>) {
        match self.source.gradient_into(params, round, &mut self.buf) {
            Ok(_loss) => emit.send_coded(round, &self.buf, self.codec.as_deref_mut()),
            // A failed computation is indistinguishable from a crashed
            // worker: stay silent, let the server's timeout path handle
            // it.
            Err(_) => {}
        }
    }

    fn step_to(
        &mut self,
        round: u64,
        params: &[f32],
        emit: &mut Emitter<'_>,
        target: f64,
    ) -> StepOutcome {
        // Only the rust-native quadratic source can be preempted
        // mid-gradient; PJRT-backed artifact executions are atomic, so
        // they keep the default defer-to-completion stepping.
        if !matches!(self.source, GradSource::Quadratic { .. }) {
            return if target >= 1.0 {
                self.on_round(round, params, emit);
                StepOutcome::Done
            } else {
                StepOutcome::Working
            };
        }
        let GradSource::Quadratic {
            problem,
            worker_id,
            batch_size,
            ..
        } = &self.source
        else {
            unreachable!("checked above");
        };
        let d = problem.dim();
        if !self.step.started || self.step.round != round {
            // New round (or an abandoned one): discard partial work.
            self.step = StepBody {
                round,
                done: 0,
                started: true,
            };
            self.buf.clear();
            self.buf.resize(d, 0.0);
        }
        let goal = ((target.clamp(0.0, 1.0) * d as f64).floor() as usize).min(d);
        if goal > self.step.done {
            let seed = quadratic_round_seed(round, *worker_id);
            problem.stochastic_gradient_range(
                params,
                *batch_size,
                seed,
                self.step.done,
                &mut self.buf[self.step.done..goal],
            );
            self.step.done = goal;
        }
        if target >= 1.0 && self.step.done == d {
            emit.send_coded(round, &self.buf, self.codec.as_deref_mut());
            StepOutcome::Done
        } else {
            StepOutcome::Working
        }
    }
}

/// Bring a set of workers online: install a [`GradWorker`] body per
/// `(endpoint, source)` pair (spawns a thread per worker on the threaded
/// transport; registers with the shared runtime on the pooled one).
pub fn serve_workers(pairs: Vec<(WorkerEndpoint, GradSource)>) {
    serve_workers_coded(pairs, None);
}

/// [`serve_workers`] with an outgoing gradient codec: every body gets its
/// own encoder instance (stateful codecs keep per-worker residuals).
pub fn serve_workers_coded(
    pairs: Vec<(WorkerEndpoint, GradSource)>,
    codec: Option<crate::codec::CodecKind>,
) {
    for (endpoint, source) in pairs {
        endpoint.serve(GradWorker::with_codec(source, codec));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::{star, star_pooled, star_socket, FaultModel, SocketOptions, TransportKind};
    use std::time::Duration;

    #[test]
    fn quadratic_source_round_trip() {
        let problem = Arc::new(QuadraticProblem::new(16, 0.1, 3));
        let (mut server, workers) = star(2, FaultModel::default());
        let pairs = workers
            .into_iter()
            .enumerate()
            .map(|(i, ep)| (ep, GradSource::quadratic(Arc::clone(&problem), i, 8)))
            .collect();
        serve_workers(pairs);
        let params = Arc::new(vec![0.5f32; 16]);
        server.broadcast(1, Arc::clone(&params));
        let got = server.collect(1, 2, Duration::from_secs(5));
        assert_eq!(got.len(), 2);
        for msg in &got {
            assert_eq!(msg.gradient.len(), 16);
            assert!(msg.gradient.iter().all(|v| v.is_finite()));
        }
        // Different workers draw different minibatches.
        assert_ne!(got[0].gradient, got[1].gradient);
        server.shutdown();
    }

    #[test]
    fn worker_gradients_are_deterministic_per_round() {
        let problem = Arc::new(QuadraticProblem::new(8, 0.2, 9));
        let mut src = GradSource::quadratic(Arc::clone(&problem), 0, 4);
        let p = vec![0.1f32; 8];
        let (g1, _) = src.gradient(&p, 5).unwrap();
        let (g2, _) = src.gradient(&p, 5).unwrap();
        let (g3, _) = src.gradient(&p, 6).unwrap();
        assert_eq!(g1, g2);
        assert_ne!(g1, g3);
    }

    #[test]
    fn same_worker_sends_identical_gradients_on_both_transports() {
        // GradWorker + seeded fault RNGs are transport-independent: a
        // seeded round must deliver bit-identical gradients either way.
        let run = |kind: TransportKind| -> Vec<Vec<f32>> {
            let problem = Arc::new(QuadraticProblem::new(32, 0.4, 17));
            let par = crate::runtime::Parallelism::new(2);
            let (mut server, workers) = match kind {
                TransportKind::Threaded => star(3, FaultModel::default()),
                TransportKind::Pooled => star_pooled(3, FaultModel::default(), &par),
                TransportKind::Socket => {
                    star_socket(3, FaultModel::default(), &SocketOptions::default())
                        .expect("loopback bind")
                }
            };
            let pairs = workers
                .into_iter()
                .enumerate()
                .map(|(i, ep)| (ep, GradSource::quadratic(Arc::clone(&problem), i, 4)))
                .collect();
            serve_workers(pairs);
            server.broadcast(1, Arc::new(vec![0.25f32; 32]));
            let mut got = server.collect(1, 3, Duration::from_secs(5));
            server.shutdown();
            got.sort_by_key(|m| m.worker);
            got.into_iter().map(|m| m.gradient).collect()
        };
        let reference = run(TransportKind::Threaded);
        assert_eq!(reference, run(TransportKind::Pooled));
        assert_eq!(reference, run(TransportKind::Socket));
    }

    #[test]
    fn stream_round_is_bit_identical_to_one_shot_for_every_chunk_size() {
        // The socket worker's chunk-wise send path must reproduce the
        // one-shot gradient exactly (wire spec §4.3's in-order contract
        // plus the counter-seeded range recipe).
        let problem = Arc::new(QuadraticProblem::new(37, 0.3, 5));
        let p = vec![0.2f32; 37];
        let one_shot = {
            let mut src = GradSource::quadratic(Arc::clone(&problem), 2, 6);
            src.gradient(&p, 9).unwrap().0
        };
        for chunk in [1usize, 5, 16, 37, 64] {
            let mut w = GradWorker::new(GradSource::quadratic(Arc::clone(&problem), 2, 6));
            let mut streamed = vec![0.0f32; 37];
            let mut offsets = Vec::new();
            w.stream_round(9, &p, chunk, &mut |offset, values, total| {
                assert_eq!(total, 37);
                offsets.push(offset);
                streamed[offset..offset + values.len()].copy_from_slice(values);
                true
            })
            .unwrap();
            assert_eq!(streamed, one_shot, "chunk {chunk}");
            assert!(offsets.windows(2).all(|w| w[0] < w[1]), "in offset order");
        }
        // A false return abandons the round without error.
        let mut w = GradWorker::new(GradSource::quadratic(Arc::clone(&problem), 2, 6));
        let mut calls = 0usize;
        w.stream_round(9, &p, 8, &mut |_o, _v, _t| {
            calls += 1;
            false
        })
        .unwrap();
        assert_eq!(calls, 1);
    }
}
