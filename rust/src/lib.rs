//! # multibulyan
//!
//! Reference implementation of **"Fast and Robust Distributed Learning in
//! High Dimension"** (El-Mhamdi, Guerraoui, Rouault — CS.DC 2019): the
//! MULTI-KRUM and MULTI-BULYAN Byzantine-resilient gradient aggregation
//! rules (GARs), embedded in a full distributed-SGD runtime.
//!
//! The system is a three-layer stack:
//!
//! * **Layer 1 (build time)** — Pallas kernels for the aggregation hot
//!   spots (pairwise squared distances, coordinate-wise median / trimmed
//!   average, fused SGD update), under `python/compile/kernels/`.
//! * **Layer 2 (build time)** — JAX model forward/backward and full GAR
//!   graphs, lowered once to HLO text artifacts by `python/compile/aot.py`.
//! * **Layer 3 (this crate, request path)** — the rust coordinator: a
//!   parameter server, simulated worker cluster, Byzantine attack library,
//!   native GAR implementations, and a PJRT runtime that loads and executes
//!   the AOT artifacts. Python never runs on the request path.
//!
//! ## Quick start
//!
//! ```no_run
//! use multibulyan::gar::{Gar, GarKind};
//! use multibulyan::tensor::GradMatrix;
//!
//! // 11 workers, dimension 1000, f = 2 Byzantine tolerated.
//! let grads = GradMatrix::from_fn(11, 1000, |i, j| (i + j) as f32);
//! let gar = GarKind::MultiBulyan.instantiate(11, 2).unwrap();
//! let aggregated = gar.aggregate(&grads).unwrap();
//! assert_eq!(aggregated.len(), 1000);
//! ```
//!
//! See `examples/` for end-to-end drivers and `DESIGN.md` for the full
//! system inventory and experiment index.

pub mod attacks;
pub mod bench;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod gar;
pub mod metrics;
pub mod runtime;
pub mod tensor;
pub mod training;
pub mod transport;
pub mod util;
pub mod worker;

/// Crate-wide result type (thin alias over `anyhow`).
pub type Result<T> = anyhow::Result<T>;
