//! # multibulyan
//!
//! Reference implementation of **"Fast and Robust Distributed Learning in
//! High Dimension"** (El-Mhamdi, Guerraoui, Rouault — CS.DC 2019): the
//! MULTI-KRUM and MULTI-BULYAN Byzantine-resilient gradient aggregation
//! rules (GARs), embedded in a full distributed-SGD runtime.
//!
//! The system is a three-layer stack:
//!
//! * **Layer 1 (build time)** — Pallas kernels for the aggregation hot
//!   spots (pairwise squared distances, coordinate-wise median / trimmed
//!   average, fused SGD update), under `python/compile/kernels/`.
//! * **Layer 2 (build time)** — JAX model forward/backward and full GAR
//!   graphs, lowered once to HLO text artifacts by `python/compile/aot.py`.
//! * **Layer 3 (this crate, request path)** — the rust coordinator: a
//!   parameter server, simulated worker cluster, Byzantine attack library,
//!   native GAR implementations, and a PJRT runtime that loads and executes
//!   the AOT artifacts. Python never runs on the request path. (In the
//!   offline build the PJRT client is the `runtime::xla_stub` shim:
//!   artifact execution reports "PJRT unavailable" at runtime while the
//!   rust-native quadratic workload runs everything end-to-end.)
//!
//! ## Two-phase GAR API
//!
//! Every rule splits into an O(n²) **selection** phase
//! ([`gar::Gar::select`], returning a typed [`gar::Selection`]) and an
//! O(d) coordinate-wise **combine** phase callable per coordinate range
//! ([`gar::Gar::combine`]) — the cost split of the paper's Theorem 2(ii)
//! made structural. The coordinator fuses combine with the SGD update in
//! one sharded traversal, reports which workers each round selected, and
//! [`gar::pipeline`] composes rules with pre-aggregation stages
//! (`gar = "rmom(0.9)+multi-bulyan"` — resilient momentum).
//!
//! ## Parallel aggregation engine
//!
//! Every GAR hot loop is sharded across a crate-internal, std-only thread
//! pool ([`runtime::ThreadPool`] + [`runtime::Parallelism`]):
//!
//! * the O(n²d) pairwise-distance pass splits the `d` dimension into
//!   fixed-width chunks, computes per-chunk partial `n × n` matrices, and
//!   reduces them with a fixed pairwise tree whose shape depends only on
//!   the chunk count ([`gar::pairwise_sq_distances_sharded`]);
//! * the O(nd)/O(θd) per-coordinate passes (median, trimmed mean, the
//!   BULYAN trimmed average, every row-average) split the output vector
//!   into disjoint coordinate ranges with per-shard scratch buffers
//!   ([`runtime::shard_slice`] / [`runtime::shard_zip`]).
//!
//! Both decompositions depend only on `d` — never on the thread count —
//! so aggregation results are **bit-identical** for every `threads`
//! setting (enforced by `tests/prop_gar.rs`); the knob is purely latency.
//! It flows from config (`threads = 4` at the top level, or
//! `--threads 4` on the CLI; `0` auto-detects, `1` — the default — is
//! sequential) through [`coordinator::launch`] into
//! [`gar::GarKind::instantiate_parallel`], the large per-round buffers
//! are reused via the per-shard members of [`gar::GarScratch`], and the
//! fan-out itself derives each shard's disjoint range from the shard
//! index — the steady-state round is allocation-free.
//!
//! ## Pooled worker runtime
//!
//! The simulated cluster ships three transports ([`transport`], the
//! `transport` config knob): `threaded` (one OS thread + mpsc pair per
//! worker — faithful asynchrony, caps at a few dozen workers); the
//! default `pooled`, which multiplexes `n` *logical* workers over the
//! same shared thread pool using a per-round broadcast slot plus a
//! preallocated per-worker gradient arena — zero per-message allocations
//! and no channels, so experiments run with 128–512 logical workers
//! in-process; and `socket`, real processes over TCP/Unix sockets
//! speaking the length-prefixed frame protocol of
//! `docs/wire-protocol.md` (in-process loopback clients by default,
//! external `multibulyan worker` processes via `socket_listen`).
//! Gradients are counter-seeded per `(round, worker, coordinate)` and
//! fault RNGs are per-worker, so seeded runs are bit-identical across
//! transports *and* thread counts.
//!
//! ## Quick start
//!
//! ```no_run
//! use multibulyan::gar::{Gar, GarKind};
//! use multibulyan::tensor::GradMatrix;
//!
//! // 11 workers, dimension 1000, f = 2 Byzantine tolerated.
//! let grads = GradMatrix::from_fn(11, 1000, |i, j| (i + j) as f32);
//! let gar = GarKind::MultiBulyan.instantiate(11, 2).unwrap();
//! let aggregated = gar.aggregate(&grads).unwrap();
//! assert_eq!(aggregated.len(), 1000);
//! ```
//!
//! ## Invariant linter
//!
//! The determinism and safety invariants above (pool-only parallelism,
//! virtual time, fixed reduction trees, audited `unsafe`) are machine
//! checked by the in-repo [`lint`] pass — `multibulyan lint` walks the
//! source tree at the token/line level and exits nonzero on violations;
//! `scripts/verify.sh` and CI run it on every change. See the
//! "Invariant catalog" section in README.md.
//!
//! See `examples/` for end-to-end drivers and `DESIGN.md` for the full
//! system inventory and experiment index.

pub mod attacks;
pub mod bench;
pub mod codec;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod gar;
pub mod lint;
pub mod metrics;
pub mod runtime;
pub mod tensor;
pub mod training;
pub mod transport;
pub mod util;
pub mod worker;

/// Crate-wide result type (thin alias over `anyhow`).
pub type Result<T> = anyhow::Result<T>;
