//! The omniscient attack of "The Hidden Vulnerability of Distributed
//! Learning in Byzantium" [El Mhamdi et al., ICML 2018 — ref [12]]: the
//! coalition knows every correct gradient and crafts the *most legitimate
//! but harmful vector possible* (paper §II-C-b).
//!
//! Strategy: pick a harmful direction `a` (here: the opposite of the true
//! gradient estimate, the worst direction for convergence), then binary-
//! search the largest deviation `λ` such that the forged vector
//! `mean(correct) + λ·a` would still be selected by Krum against the
//! actual correct gradients of this round. With `f` colluders proposing
//! the same vector, their mutual distance is 0, which shrinks their Krum
//! score — the coalition exploits exactly the weakness the paper's Fig. 1
//! depicts, and the deviation it achieves grows with `√d` (the leeway
//! BULYAN's median then removes).

use super::{Attack, AttackCtx};
use crate::gar::{krum_scores_from_distances, pairwise_sq_distances_into};
use crate::tensor::{l2_norm, GradMatrix};
use crate::Result;
use crate::util::Rng64;

/// Omniscient coalition: harmful direction with Krum-selectability check.
#[derive(Debug, Clone)]
pub struct Omniscient {
    /// Binary-search precision on λ, relative to ‖mean(correct)‖.
    epsilon: f32,
}

impl Omniscient {
    pub fn new(epsilon: f32) -> Self {
        Self {
            epsilon: epsilon.max(1e-6),
        }
    }

    /// Would a coalition proposing `byz` (f identical copies) win Krum
    /// against `correct`? Builds the full (n×n) view the server would see.
    fn coalition_wins_krum(&self, ctx: &AttackCtx<'_>, byz: &[f32]) -> bool {
        let k = ctx.correct.n();
        let n = ctx.n;
        let mut rows: Vec<Vec<f32>> = (0..k).map(|i| ctx.correct.row(i).to_vec()).collect();
        rows.extend(std::iter::repeat(byz.to_vec()).take(ctx.f));
        let all = GradMatrix::from_rows(&rows);
        let mut dist = vec![0.0f32; n * n];
        pairwise_sq_distances_into(&all, &mut dist);
        let pool: Vec<usize> = (0..n).collect();
        let mut scores = Vec::new();
        krum_scores_from_distances(&dist, n, &pool, ctx.f, &mut scores);
        let winner = crate::tensor::argselect_smallest(&scores, 1)[0];
        winner >= k // a Byzantine index won
    }
}

impl Attack for Omniscient {
    fn name(&self) -> &'static str {
        "omniscient"
    }

    fn forge(&self, ctx: &AttackCtx<'_>, _rng: &mut Rng64) -> Result<GradMatrix> {
        let mean = ctx.correct_mean();
        let norm = l2_norm(&mean).max(1e-12);
        // Harmful direction: against the descent direction, unit norm.
        let dir: Vec<f32> = mean.iter().map(|v| -v / norm).collect();

        // Binary search the largest selectable deviation λ ∈ [0, λ_hi].
        let mut lo = 0.0f32;
        let mut hi = 4.0 * norm;
        let mut byz = mean.clone();
        let build = |lambda: f32| -> Vec<f32> {
            mean.iter()
                .zip(&dir)
                .map(|(m, a)| m + lambda * a)
                .collect()
        };
        // If even λ=0 (pure mean replay) does not win, still send it: the
        // coalition at worst mimics the mean, which remains the most
        // harmful *selectable* choice under this parametrisation.
        if self.coalition_wins_krum(ctx, &build(hi)) {
            byz = build(hi);
        } else {
            let tol = self.epsilon * norm;
            while hi - lo > tol {
                let mid = 0.5 * (lo + hi);
                if self.coalition_wins_krum(ctx, &build(mid)) {
                    lo = mid;
                } else {
                    hi = mid;
                }
            }
            byz = if lo > 0.0 { build(lo) } else { byz };
        }
        Ok(GradMatrix::from_rows(&vec![byz; ctx.f]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    fn correct_cluster(k: usize, d: usize, seed: u64) -> GradMatrix {
        let mut rng = Rng64::seed_from_u64(seed);
        GradMatrix::from_fn(k, d, |_, j| {
            1.0 + (j as f32 * 0.001) + rng.gen_range_f32(-0.05, 0.05)
        })
    }

    #[test]
    fn forged_vector_is_selectable_by_krum() {
        let correct = correct_cluster(9, 32, 11);
        let ctx = AttackCtx::new(&correct, 2, 11);
        let mut rng = Rng64::seed_from_u64(0);
        let forged = Omniscient::new(0.05).forge(&ctx, &mut rng).unwrap();
        // The produced vector either wins Krum or degenerates to the mean.
        let att = Omniscient::new(0.05);
        let wins = att.coalition_wins_krum(&ctx, forged.row(0));
        let mean = ctx.correct_mean();
        let is_mean = forged
            .row(0)
            .iter()
            .zip(&mean)
            .all(|(a, b)| (a - b).abs() < 1e-5);
        assert!(wins || is_mean);
    }

    #[test]
    fn deviation_is_against_the_gradient() {
        let correct = correct_cluster(9, 32, 5);
        let ctx = AttackCtx::new(&correct, 2, 11);
        let mut rng = Rng64::seed_from_u64(0);
        let forged = Omniscient::new(0.05).forge(&ctx, &mut rng).unwrap();
        let mean = ctx.correct_mean();
        // ⟨forged − mean, mean⟩ ≤ 0: the deviation opposes descent.
        let dot: f32 = forged
            .row(0)
            .iter()
            .zip(&mean)
            .map(|(b, m)| (b - m) * m)
            .sum();
        assert!(dot <= 1e-3);
    }
}
