//! Baseline attacks: cheap strategies that suffice to break averaging
//! (the paper's §II-C "weak vs strong" discussion — these cost O(n·d) per
//! round, within the workload of an ordinary worker).

use super::{Attack, AttackCtx};
use crate::tensor::GradMatrix;
use crate::Result;
use crate::util::Rng64;

/// Send `−scale · mean(correct)` — the classic reversed-gradient collusion.
/// Pulls the average backwards along the descent direction; trivially
/// filtered by any distance- or median-based rule.
#[derive(Debug, Clone)]
pub struct SignFlip {
    scale: f32,
}

impl SignFlip {
    pub fn new(scale: f32) -> Self {
        Self { scale }
    }
}

impl Attack for SignFlip {
    fn name(&self) -> &'static str {
        "sign-flip"
    }

    fn forge(&self, ctx: &AttackCtx<'_>, _rng: &mut Rng64) -> Result<GradMatrix> {
        let mut row = ctx.correct_mean();
        crate::tensor::scale(&mut row, -self.scale);
        Ok(GradMatrix::from_rows(&vec![row; ctx.f]))
    }
}

/// Independent N(0, scale²) noise per coordinate — breaks averaging when
/// `scale` dominates the true gradient's magnitude.
#[derive(Debug, Clone)]
pub struct RandomGauss {
    scale: f32,
}

impl RandomGauss {
    pub fn new(scale: f32) -> Self {
        Self { scale }
    }
}

impl Attack for RandomGauss {
    fn name(&self) -> &'static str {
        "random-gauss"
    }

    fn forge(&self, ctx: &AttackCtx<'_>, rng: &mut Rng64) -> Result<GradMatrix> {
        let d = ctx.correct.d();
        Ok(GradMatrix::from_fn(ctx.f, d, |_, _| {
            rng.gaussian() * self.scale
        }))
    }
}

/// Magnitude blow-up: ±∞-like huge values (or NaN when `nan` is set).
/// Instantly corrupts any rule that sums Byzantine inputs, and exercises
/// the NaN-ordering paths of the selection rules.
#[derive(Debug, Clone)]
pub struct Infinity {
    nan: bool,
}

impl Infinity {
    pub fn new(nan: bool) -> Self {
        Self { nan }
    }
}

impl Attack for Infinity {
    fn name(&self) -> &'static str {
        if self.nan {
            "nan"
        } else {
            "infinity"
        }
    }

    fn forge(&self, ctx: &AttackCtx<'_>, _rng: &mut Rng64) -> Result<GradMatrix> {
        let v = if self.nan { f32::NAN } else { 1e30 };
        Ok(GradMatrix::from_fn(ctx.f, ctx.correct.d(), |i, _| {
            if self.nan || i % 2 == 0 {
                v
            } else {
                -v
            }
        }))
    }
}

/// All Byzantines replay correct worker 0's gradient verbatim. Harmless to
/// convergence but biases selection frequency — a probe for the
/// selection-diagnostics path, and the building block of "mimic"-style
/// heterogeneity attacks.
#[derive(Debug, Clone)]
pub struct Mimic;

impl Attack for Mimic {
    fn name(&self) -> &'static str {
        "mimic"
    }

    fn forge(&self, ctx: &AttackCtx<'_>, _rng: &mut Rng64) -> Result<GradMatrix> {
        let row = ctx.correct.row(0).to_vec();
        Ok(GradMatrix::from_rows(&vec![row; ctx.f]))
    }
}

/// Send exactly zero: attempts to stall progress by diluting the average.
#[derive(Debug, Clone)]
pub struct Zero;

impl Attack for Zero {
    fn name(&self) -> &'static str {
        "zero"
    }

    fn forge(&self, ctx: &AttackCtx<'_>, _rng: &mut Rng64) -> Result<GradMatrix> {
        Ok(GradMatrix::zeros(ctx.f, ctx.correct.d()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
        fn ctx_fixture() -> GradMatrix {
        GradMatrix::from_rows(&[vec![1.0, -2.0], vec![3.0, -4.0]])
    }

    #[test]
    fn sign_flip_negates_mean() {
        let correct = ctx_fixture();
        let ctx = AttackCtx::new(&correct, 2, 4);
        let mut rng = Rng64::seed_from_u64(0);
        let forged = SignFlip::new(2.0).forge(&ctx, &mut rng).unwrap();
        assert_eq!(forged.row(0), &[-4.0, 6.0]);
        assert_eq!(forged.row(1), forged.row(0));
    }

    #[test]
    fn random_gauss_has_roughly_right_scale() {
        let correct = GradMatrix::zeros(2, 4096);
        let ctx = AttackCtx::new(&correct, 1, 3);
        let mut rng = Rng64::seed_from_u64(3);
        let forged = RandomGauss::new(5.0).forge(&ctx, &mut rng).unwrap();
        let std = crate::tensor::std_dev(forged.row(0));
        assert!((std - 5.0).abs() < 0.5, "std {std}");
    }

    #[test]
    fn infinity_and_nan_modes() {
        let correct = ctx_fixture();
        let ctx = AttackCtx::new(&correct, 2, 4);
        let mut rng = Rng64::seed_from_u64(0);
        let inf = Infinity::new(false).forge(&ctx, &mut rng).unwrap();
        assert!(inf.row(0)[0] > 1e29 && inf.row(1)[0] < -1e29);
        let nan = Infinity::new(true).forge(&ctx, &mut rng).unwrap();
        assert!(nan.row(0)[0].is_nan());
    }

    #[test]
    fn mimic_copies_worker_zero() {
        let correct = ctx_fixture();
        let ctx = AttackCtx::new(&correct, 1, 3);
        let mut rng = Rng64::seed_from_u64(0);
        let forged = Mimic.forge(&ctx, &mut rng).unwrap();
        assert_eq!(forged.row(0), correct.row(0));
    }
}
